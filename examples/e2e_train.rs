//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```
//!
//! * **L1/L2** — the `simpledla` mini CNN (Pallas conv/GEMM kernels inside a
//!   JAX train step) was AOT-lowered to `artifacts/simpledla_train.hlo.txt`.
//! * **Runtime** — this binary loads the HLO text, compiles it on PJRT-CPU
//!   and trains for several hundred steps on synthetic CIFAR-10, logging
//!   the loss curve.  Python is not involved.
//! * **L3** — FROST profiles the model on the virtual RTX 3080 testbed,
//!   picks the ED²P-optimal power cap, and the hybrid accountant books the
//!   run's energy per Eqs. 1–5 under both the default and the capped
//!   configuration.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use frost::config::{setup_no1, ProfilerConfig};
use frost::data::SyntheticCifar;
use frost::frost::PowerProfiler;
use frost::pipeline::{calibrated_workload, HybridAccountant};
use frost::power::{CpuPowerModel, DramPowerModel, GpuPowerModel};
use frost::runtime::{InferenceSession, Runtime, TrainSession};
use frost::simulator::{ExecutionModel, Testbed};
use frost::util::Joules;
use frost::zoo::Manifest;

const MODEL: &str = "simpledla";
const STEPS: u64 = 300;

fn exec_model(hw: &frost::config::HardwareConfig) -> ExecutionModel {
    ExecutionModel::new(
        GpuPowerModel::new(hw.gpu.clone()),
        CpuPowerModel::new(hw.cpu.clone()),
        DramPowerModel::new(hw.dimms.clone()),
    )
}

fn main() -> anyhow::Result<()> {
    let hw = setup_no1();
    let manifest = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    println!("== e2e: {MODEL} on PJRT-{} / virtual {} ==", rt.platform(), hw.gpu.name);

    // ---- real training with loss curve --------------------------------
    let mut session = TrainSession::new(&rt, &manifest, MODEL)?;
    let m = manifest.model(MODEL).unwrap();
    println!(
        "model: {} params, train batch {}, {:.1} MFLOP/sample (XLA-counted)",
        session.model.param_count,
        session.batch,
        m.train_flops_per_sample().unwrap_or(0.0) / 1e6
    );

    let workload = calibrated_workload(m, &hw.gpu, None)?;
    let mut acct = HybridAccountant::new(
        exec_model(&hw),
        workload.clone(),
        session.batch,
        hw.gpu.tdp_w,
        hw.gpu.min_cap_frac,
        42,
    );

    let mut ds = SyntheticCifar::new(0);
    let mut curve: Vec<(u64, f32, f32)> = Vec::new();
    for i in 0..STEPS {
        let batch = ds.next_batch(session.batch as usize);
        let metrics = session.step(&batch)?;
        acct.on_train_step(metrics.wall_s);
        if i % 20 == 0 || i + 1 == STEPS {
            println!(
                "step {:>4}  loss {:.4}  acc {:.3}  wall {:.1} ms",
                i, metrics.loss, metrics.accuracy, metrics.wall_s * 1e3
            );
        }
        curve.push((i, metrics.loss, metrics.accuracy));
    }
    let first = curve.first().unwrap();
    let last = curve.last().unwrap();
    anyhow::ensure!(
        last.1 < first.1 * 0.5,
        "training must reduce loss by >2x: {} -> {}",
        first.1,
        last.1
    );

    // Held-out evaluation with the trained parameters.
    let params: Vec<xla::Literal> = session
        .params()
        .iter()
        .map(|p| {
            let dims: Vec<i64> =
                p.array_shape().unwrap().dims().iter().map(|&d| d as i64).collect();
            p.reshape(&dims).unwrap()
        })
        .collect();
    let mut infer = InferenceSession::with_params(&rt, &manifest, MODEL, params)?;
    let eval = ds.eval_batch(infer.batch as usize, 99);
    let acc = infer.accuracy(&eval)?;
    println!("held-out accuracy after {STEPS} steps: {:.1}%", acc * 100.0);
    anyhow::ensure!(acc > 0.5, "trained model must beat chance by far, got {acc}");

    let uncapped = acct.finish(Joules(0.0));
    let mean_step = session.mean_step_time().unwrap();
    println!(
        "uncapped: {} over {} (mean power {}, mean step {:.1} ms)",
        uncapped.gross,
        uncapped.duration,
        uncapped.mean_power(),
        mean_step * 1e3
    );

    // ---- FROST decision on the virtual testbed -------------------------
    let mut tb = Testbed::new(hw.clone(), 42);
    let profiler = PowerProfiler::new(ProfilerConfig::default()); // ED²P
    let outcome = profiler.profile(&mut tb, &workload, session.batch);
    println!(
        "FROST: cap {:.1}% of TDP, est. saving {:.1}% at {:+.1}% time (fit err {:.2}%)",
        outcome.optimal_cap * 100.0,
        outcome.est_energy_saving * 100.0,
        (outcome.est_slowdown - 1.0) * 100.0,
        outcome.fit.rel_error * 100.0
    );

    // ---- re-book the same real run under the chosen cap ----------------
    let mut capped_acct = HybridAccountant::new(
        exec_model(&hw),
        workload.clone(),
        session.batch,
        hw.gpu.tdp_w,
        hw.gpu.min_cap_frac,
        42,
    );
    capped_acct.set_cap_frac(outcome.optimal_cap);
    // Real step times, stretched by the simulated slowdown of the cap.
    for _ in 0..STEPS {
        capped_acct.on_train_step(mean_step * outcome.est_slowdown);
    }
    let capped = capped_acct.finish(outcome.profiling_energy);
    let saving = 1.0 - (capped.gross.0 / outcome.est_slowdown.max(1.0))
        / uncapped.gross.0.max(1e-9);
    println!(
        "capped:   {} over {} (mean power {}, incl. {} profiling charge)",
        capped.gross,
        capped.duration,
        capped.mean_power(),
        outcome.profiling_energy
    );
    println!(
        "energy saving on this run: {:.1}% (accuracy unchanged: capping never \
         alters numerics)",
        outcome.est_energy_saving * 100.0
    );
    let _ = saving;
    println!("e2e OK");
    Ok(())
}
