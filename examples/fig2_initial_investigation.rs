//! Regenerates paper Fig. 2 (initial energy investigation, Sec. IV-A):
//! 16 models × 100 epochs — accuracy vs energy (2a), energy vs time (2b),
//! utilisation vs power (2c), with the Pearson r the paper quotes.
//!
//! ```bash
//! cargo run --release --example fig2_initial_investigation [-- setup2]
//! ```

use frost::config::{setup_no1, setup_no2};
use frost::figures::fig2_investigation;

fn main() {
    let setup2 = std::env::args().any(|a| a == "setup2");
    let hw = if setup2 { setup_no2() } else { setup_no1() };
    let out = fig2_investigation(&hw, 100, 42);
    print!("{}", out.table.to_table());
    println!();
    println!("Fig 2a  r(accuracy, energy) = {:>6.3}   [paper: 0.34 — weak]", out.r_accuracy_energy);
    println!("Fig 2b  r(energy, time)     = {:>6.3}   [paper: 0.999 — linear]", out.r_energy_time);
    println!("Fig 2c  r(util, power)      = {:>6.3}   [high, saturating ~300 W]", out.r_util_power);
}
