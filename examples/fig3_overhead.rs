//! Regenerates paper Fig. 3 (Sec. IV-B): inference wall time with FROST /
//! CodeCarbon-like / Eco2AI-like / no measurement attached — on REAL PJRT
//! inference through the AOT artifacts.
//!
//! ```bash
//! make artifacts && cargo run --release --example fig3_overhead [-- SAMPLES]
//! ```
//!
//! The paper runs 50k CIFAR-10 samples × 100 experiments on a GPU; on the
//! CPU-interpret substrate the default is 2 560 samples × 2 reps (recorded
//! as such in EXPERIMENTS.md).

use frost::config::setup_no1;
use frost::figures::fig3_overhead;

fn main() -> anyhow::Result<()> {
    let samples: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2560);
    let s = fig3_overhead(&setup_no1(), &["lenet", "mobilenet_mini"], samples, 2)?;
    print!("{}", s.to_table());
    println!();
    for (label, row) in s.labels.iter().zip(&s.rows) {
        println!(
            "{label}: FROST {:+.1}% vs baseline | CodeCarbon-like {:+.1}% | Eco2AI-like {:+.1}%",
            (row[4] - 1.0) * 100.0,
            (row[5] - 1.0) * 100.0,
            (row[6] - 1.0) * 100.0
        );
    }
    println!("[paper: FROST ≈ baseline; the 1 Hz analytics tools add slight overhead]");
    Ok(())
}
