//! Regenerates paper Fig. 4 (Sec. IV-C): energy & time vs power cap for
//! MobileNet, DenseNet and EfficientNet on setup no.2, with each model's
//! optimal limit (paper: 60% / 60% / 40%).
//!
//! ```bash
//! cargo run --release --example fig4_power_capping
//! ```

use frost::config::setup_no2;
use frost::figures::fig4_power_capping;

fn main() {
    let s = fig4_power_capping(&setup_no2(), &["MobileNet", "DenseNet", "EfficientNet"], 42);
    print!("{}", s.to_table());
    println!();
    for model in ["MobileNet", "DenseNet", "EfficientNet"] {
        let i = s.labels.iter().position(|l| l.starts_with(model)).unwrap();
        println!(
            "{model:<13} optimal cap {:>5.1}%  (energy saving {:.1}%)",
            s.rows[i][3], s.rows[i][4]
        );
    }
    println!("[paper: MobileNet 60%, DenseNet 60%, EfficientNet 40%]");
}
