//! Regenerates paper Fig. 5 (Sec. IV-C): ResNet swept at 1% power-cap
//! increments on setup no.2, and the ED^xP optima for x ∈ {1, 2, 3}.
//!
//! ```bash
//! cargo run --release --example fig5_finegrained
//! ```

use frost::config::setup_no2;
use frost::figures::fig5_fine_grained;

fn main() {
    let out = fig5_fine_grained(&setup_no2(), "ResNet", 42);
    // Print a decimated view of the 71-point sweep (every 5th point).
    let mut thin = frost::util::Series::new(out.sweep.name.clone(), &["cap_pct", "rel_energy", "rel_time"]);
    for (i, (label, row)) in out.sweep.labels.iter().zip(&out.sweep.rows).enumerate() {
        if i % 5 == 0 || i == out.sweep.len() - 1 {
            thin.push(label.clone(), row.clone());
        }
    }
    print!("{}", thin.to_table());
    println!();
    for (m, cap, saving, delay) in &out.optima {
        println!("ED{m}P: optimal cap {cap:>5.1}%  saving {saving:>5.1}%  delay {delay:+.1}%");
    }
    println!("[paper: optimum rises with x; ED3P optima reach the maximum; EDP saves most]");
}
