//! Regenerates paper Fig. 6 (Sec. IV-C): per-model energy saving vs delay
//! under ED²P, plus the headline means — paper: 26.4% saving at +6.9% time
//! on setup no.1; 17.7% at +5.5% on setup no.2.
//!
//! ```bash
//! cargo run --release --example fig6_tradeoff
//! ```

use frost::config::{setup_no1, setup_no2};
use frost::figures::fig6_tradeoff;

fn main() {
    for (hw, paper) in [
        (setup_no1(), "26.4% @ +6.9%"),
        (setup_no2(), "17.7% @ +5.5%"),
    ] {
        let out = fig6_tradeoff(&hw, 2.0, 42);
        print!("{}", out.table.to_table());
        println!(
            "MEAN {}: saving {:.1}% at {:+.1}% time   [paper: {paper}]\n",
            hw.name, out.mean_saving_pct, out.mean_delay_pct
        );
    }
}
