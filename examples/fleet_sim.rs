//! Multi-host O-RAN fleet simulation (the fleet-scale extension of the
//! paper's single-host evaluation).
//!
//! ```bash
//! cargo run --release --example fleet_sim
//! ```
//!
//! Eight ML-enabled sites (hardware alternating between the paper's setups
//! no.1 and no.2, workloads rotating through the zoo, QoS classes rotating
//! through the A1 policy classes) run under one SMO/non-RT RIC. The non-RT
//! RIC staggers FROST profiling across the fleet, the SMO water-fills a
//! global GPU power budget into per-site A1 policies, and the run is
//! compared against the identical fleet at stock power caps.

use frost::oran::FleetConfig;

fn main() -> anyhow::Result<()> {
    let config = FleetConfig {
        sites: 8,
        seed: 7,
        rounds: 8,
        budget_frac: 0.7,
        max_concurrent_profiles: 3,
        ..FleetConfig::default()
    };
    println!(
        "fleet up: {} sites, staggered profiling (max {}/round), GPU budget {:.0}% of ΣTDP\n",
        config.sites,
        config.max_concurrent_profiles,
        config.budget_frac * 100.0
    );

    let out = frost::figures::fleet_comparison(&config)?;
    print!("{}", out.table.to_table());

    println!("\n=== fleet roll-up ===");
    for site in &out.frost.sites {
        println!(
            "  {:<7} {:<28} cap {:>5.1}%  round {:>7.1} kJ  profiling {:>7.1} kJ  acc {:.1}%",
            site.name,
            site.model,
            site.cap_frac * 100.0,
            site.round_energy_j / 1e3,
            site.profiling_energy_j / 1e3,
            site.accuracy * 100.0
        );
    }
    println!(
        "\nsteady-state fleet saving: {:.1}% (baseline {:.1} kJ/round → {:.1} kJ/round)",
        out.steady_saving_frac * 100.0,
        out.baseline_round_j / 1e3,
        out.frost_round_j / 1e3
    );
    println!(
        "mean FROST estimate      : {:.1}% per site  [paper band: 10-26%]",
        out.mean_est_saving_frac * 100.0
    );
    if let Some(budget) = out.frost.budget_w {
        println!(
            "global GPU budget        : {:.0} W, enforced cap power {:.0} W",
            budget, out.frost.cap_power_w
        );
    }
    println!(
        "accuracy                 : {}",
        if out.accuracy_unchanged { "unchanged on every site" } else { "CHANGED" }
    );
    Ok(())
}
