//! Full O-RAN deployment scenario (paper Fig. 1 + Sec. II).
//!
//! ```bash
//! cargo run --release --example oran_deployment
//! ```
//!
//! Two inference hosts (the paper's setups no.1 and no.2) under one SMO.
//! Three ML services with different QoS classes arrive; each walks the
//! six-step AI/ML lifecycle with FROST profiling injected before
//! deployment.  The demo shows the A1 policy machinery steering the ED^mP
//! exponent per service, exactly as Sec. III-C proposes.

use frost::config::{setup_no1, setup_no2};
use frost::frost::{EnergyPolicy, QosClass};
use frost::oran::MlLifecycle;
use frost::zoo::model_by_name;

fn main() -> anyhow::Result<()> {
    let mut lc = MlLifecycle::new(vec![setup_no1(), setup_no2()], 0.80, 7);
    println!("O-RAN fabric up: SMO, non-RT RIC, near-RT RIC, 2 hosts\n");

    // Three services, three QoS classes (paper Sec. III-C / use-case paper):
    let services = [
        // Background V2X trajectory model: maximise savings.
        ("DenseNet", "host1", QosClass::EnergySaver),
        // Traffic steering: the balanced default.
        ("ResNet", "host1", QosClass::Balanced),
        // Near-RT slicing control: latency critical.
        ("MobileNetV2", "host2", QosClass::LatencyCritical),
    ];

    for (model, host, qos) in services {
        let entry = model_by_name(model).unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let policy = EnergyPolicy {
            id: format!("{model}-policy"),
            qos,
            ..EnergyPolicy::default_policy()
        };
        println!("--- {model} on {host} ({:?} / {}) ---", qos, qos.criterion());
        let stages = lc.run_workflow(model, w, host, policy, 60, 50_000)?;
        let entry = lc.nonrt.catalogue.get(model).unwrap();
        println!(
            "  lifecycle: {} stages, catalogue v{}, accuracy {:.2}%",
            stages.len(),
            entry.version,
            entry.validation_accuracy * 100.0
        );
        println!(
            "  FROST decision: cap {:.1}% of TDP",
            entry.optimal_cap.unwrap() * 100.0
        );
        let rec = lc.smo.profile_records.iter().rev().find(|r| r.model == model).unwrap();
        println!(
            "  estimated: {:.1}% energy saved at {:+.1}% time",
            rec.est_energy_saving * 100.0,
            (rec.est_slowdown - 1.0) * 100.0
        );
        println!();
    }

    println!("=== deployment summary ===");
    println!("models in catalogue : {}", lc.nonrt.catalogue.len());
    println!("xApps deployed      : {}", lc.nearrt.xapps().len());
    println!("KPM reports         : {}", lc.smo.kpms.len());
    println!("fabric traffic      : {:?}", lc.bus.stats());
    println!(
        "energy reported     : {:.1} kJ",
        lc.smo.total_reported_energy() / 1e3
    );
    println!(
        "mean energy saving  : {:.1}% across FROST decisions",
        lc.smo.mean_energy_saving() * 100.0
    );

    // QoS classes must order the chosen caps: latency-critical >= balanced
    // >= energy-saver is the expected *tendency* (paper Fig. 5).
    let cap = |m: &str| {
        lc.smo
            .profile_records
            .iter()
            .rev()
            .find(|r| r.model == m)
            .unwrap()
            .optimal_cap
    };
    println!(
        "\ncaps by QoS: energy-saver {:.0}% <= balanced {:.0}% (different models; \
         latency-critical {:.0}% runs on the other testbed)",
        cap("DenseNet") * 100.0,
        cap("ResNet") * 100.0,
        cap("MobileNetV2") * 100.0
    );
    Ok(())
}
