//! Power shifting across an O-RAN site (paper Sec. II-C).
//!
//! ```bash
//! cargo run --release --example power_shifting
//! ```
//!
//! Four inference hosts (two of each paper setup) run different models.
//! The site gets a global GPU power budget; FROST profiles each host and
//! the allocator water-fills the budget by marginal throughput-per-watt.
//! Sweep the budget to see the site-level throughput/power frontier — the
//! multi-node generalisation of the single-GPU capping result.

use frost::config::{setup_no1, setup_no2, ProfilerConfig};
use frost::frost::PowerProfiler;
use frost::power::{allocate_budget, total_throughput, HostProfile};
use frost::simulator::Testbed;
use frost::zoo::model_by_name;

fn main() {
    let site = [
        (setup_no1(), "ResNet"),
        (setup_no1(), "DenseNet"),
        (setup_no2(), "MobileNetV2"),
        (setup_no2(), "VGG"),
    ];
    println!("profiling {} hosts...", site.len());
    let mut profiles = Vec::new();
    for (i, (hw, model)) in site.iter().enumerate() {
        let w = model_by_name(model).unwrap().workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw.clone(), 7 + i as u64);
        let out = PowerProfiler::new(ProfilerConfig::default()).profile(&mut tb, &w, 128);
        let name = format!("host{}({model})", i + 1);
        println!(
            "  {name}: solo optimum {:.0}% of TDP, {:.1}% saving",
            out.optimal_cap * 100.0,
            out.est_energy_saving * 100.0
        );
        profiles.push(HostProfile::from_profile(&name, hw.gpu.tdp_w, &out.points));
    }

    let full: f64 = profiles.iter().map(|p| p.tdp_w).sum();
    println!("\nsite GPU TDP total: {full:.0} W");
    println!("{:>10}  {:>12}  {:>9}  allocation", "budget", "throughput", "of-max");
    let unconstrained =
        total_throughput(&allocate_budget(&profiles, full, 5.0).unwrap());
    for frac in [0.35, 0.45, 0.55, 0.65, 0.8, 1.0] {
        let budget = full * frac;
        match allocate_budget(&profiles, budget, 5.0) {
            Some(allocs) => {
                let t = total_throughput(&allocs);
                let detail: Vec<String> = allocs
                    .iter()
                    .map(|a| format!("{:.0}%", a.cap_frac * 100.0))
                    .collect();
                println!(
                    "{:>8.0} W  {:>9.0} sps  {:>8.1}%  caps [{}]",
                    budget,
                    t,
                    100.0 * t / unconstrained,
                    detail.join(", ")
                );
            }
            None => println!("{budget:>8.0} W  infeasible (below driver floors)"),
        }
    }
    println!(
        "\nthe knee: ~55% of site power already delivers >95% of max throughput —\n\
         the multi-node version of the paper's single-GPU capping argument."
    );
}
