//! Quickstart: profile one model with FROST and apply the optimal cap.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the minimal API tour: build a virtual testbed (paper setup
//! no.1), pick a model from the zoo, run the eight-limit profiler under an
//! ED²P policy, and inspect the decision.

use frost::config::{setup_no1, ProfilerConfig};
use frost::frost::{EnergyPolicy, PowerProfiler};
use frost::simulator::Testbed;
use frost::zoo::model_by_name;

fn main() {
    // 1. The hardware FROST manages: i7-8700K + RTX 3080 (paper setup no.1).
    let hw = setup_no1();
    let mut testbed = Testbed::new(hw.clone(), 42);

    // 2. The model the SMO just asked us to host.
    let entry = model_by_name("DenseNet").expect("in the zoo");
    let workload = entry.workload(&hw.gpu);

    // 3. FROST: eight power limits x 30 s windows, ED²P criterion,
    //    default A1 policy (cap range 30-100%, +25% slowdown budget).
    let profiler = PowerProfiler::with_policy(
        ProfilerConfig::default(),
        EnergyPolicy::default_policy(),
    );
    let outcome = profiler.profile(&mut testbed, &workload, 128);

    println!("FROST quickstart — {} on {}", outcome.model, hw.gpu.name);
    println!("criterion          : {}", outcome.criterion);
    println!("profiled points    : {}", outcome.points.len());
    for p in &outcome.points {
        println!(
            "  cap {:>4.0}%  {:>7.2} mJ/sample  {:>7.2} µs/sample  {:>6.1} W",
            p.cap_frac * 100.0,
            p.energy_per_sample_j * 1e3,
            p.time_per_sample_s * 1e6,
            p.mean_power.0
        );
    }
    println!(
        "fit                : rel err {:.2}% (good: {})",
        outcome.fit.rel_error * 100.0,
        outcome.fit.good_fit
    );
    println!(
        "decision           : cap at {:.1}% of TDP ({:.0} W)",
        outcome.optimal_cap * 100.0,
        outcome.optimal_cap * hw.gpu.tdp_w
    );
    println!(
        "estimated effect   : {:.1}% energy saved at {:+.1}% time",
        outcome.est_energy_saving * 100.0,
        (outcome.est_slowdown - 1.0) * 100.0
    );
    // The testbed is now running at the chosen cap:
    assert!((testbed.cap_frac() - outcome.optimal_cap).abs() < 1e-9);
    println!("testbed now capped : {:.1}%", testbed.cap_frac() * 100.0);
}
