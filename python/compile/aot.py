"""AOT bridge: lower the L2/L1 stack to HLO **text** artifacts for Rust.

Run once at build time (``make artifacts``); Python never appears on the
request path.  For every trainable model we emit three artifacts:

    artifacts/<model>_init.hlo.txt    () -> (step, params..., m..., v...)
    artifacts/<model>_train.hlo.txt   (state..., x, y) -> (state..., loss, acc)
    artifacts/<model>_infer.hlo.txt   (params..., x) -> (logits, preds)

plus ``artifacts/manifest.json`` describing shapes, the state layout, and
the analytic cost model (FLOPs / bytes) that seeds the Rust simulator's
workload descriptors.

HLO **text** — not ``HloModuleProto.serialize()`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_BATCH = 64
INFER_BATCH = 128  # paper batch size (Sec. IV)
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr) -> dict:
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def _shape_dtype(arrs):
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs]


def lower_model(name: str, out_dir: str, train_batch: int, infer_batch: int) -> dict:
    """Lower init/train/infer for one model; return its manifest entry."""
    state = M.init_state(name, SEED)
    n_params = len(M.init_params(name, SEED))
    n_state = len(state)

    x_tr = jax.ShapeDtypeStruct((train_batch, *M.IMAGE_SHAPE), jnp.float32)
    y_tr = jax.ShapeDtypeStruct((train_batch,), jnp.int32)
    x_in = jax.ShapeDtypeStruct((infer_batch, *M.IMAGE_SHAPE), jnp.float32)

    entry: dict = {
        "n_params": n_params,
        "n_state": n_state,
        "param_count": M.param_count(name),
        "state_specs": [_spec(s) for s in state],
    }

    # --- init: no-arg function baking the seeded initial state ------------
    init_fn = lambda: tuple(M.init_state(name, SEED))  # noqa: E731
    lo = jax.jit(init_fn).lower()
    path = os.path.join(out_dir, f"{name}_init.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lo))
    entry["init"] = {"file": os.path.basename(path), "n_outputs": n_state}

    # --- train step --------------------------------------------------------
    train_fn = M.make_train_step(name)
    lo = jax.jit(train_fn).lower(*_shape_dtype(state), x_tr, y_tr)
    flops = None
    try:
        ca = lo.compile().cost_analysis()
        if ca and "flops" in ca:
            flops = float(ca["flops"])
    except Exception as e:  # pragma: no cover - cost analysis is best-effort
        print(f"  [warn] cost_analysis failed for {name}: {e}", file=sys.stderr)
    path = os.path.join(out_dir, f"{name}_train.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lo))
    entry["train"] = {
        "file": os.path.basename(path),
        "batch": train_batch,
        "inputs": entry["state_specs"]
        + [
            {"shape": list(x_tr.shape), "dtype": "float32"},
            {"shape": list(y_tr.shape), "dtype": "int32"},
        ],
        "n_outputs": n_state + 2,
        "flops_xla": flops,
        "flops_analytic": M.model_flops(name, train_batch, training=True),
    }

    # --- inference ----------------------------------------------------------
    infer_fn = M.make_infer(name)
    params = M.init_params(name, SEED)
    lo = jax.jit(infer_fn).lower(*_shape_dtype(params), x_in)
    flops = None
    try:
        ca = lo.compile().cost_analysis()
        if ca and "flops" in ca:
            flops = float(ca["flops"])
    except Exception:  # pragma: no cover
        pass
    path = os.path.join(out_dir, f"{name}_infer.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lo))
    entry["infer"] = {
        "file": os.path.basename(path),
        "batch": infer_batch,
        "n_inputs": n_params + 1,
        "n_outputs": 2,
        "flops_xla": flops,
        "flops_analytic": M.model_flops(name, infer_batch, training=False),
    }

    # --- per-layer cost (seeds the Rust workload descriptors) --------------
    entry["layer_costs"] = [
        {"layer": c.name, "flops": c.flops, "bytes": c.bytes_accessed}
        for c in M.forward_cost(name, train_batch)
    ]
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; HLO artifacts go to its directory")
    ap.add_argument("--models", nargs="*", default=list(M.TRAINABLE_MODELS))
    ap.add_argument("--train-batch", type=int, default=TRAIN_BATCH)
    ap.add_argument("--infer-batch", type=int, default=INFER_BATCH)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "jax_version": jax.__version__,
        "seed": SEED,
        "image_shape": list(M.IMAGE_SHAPE),
        "num_classes": M.NUM_CLASSES,
        "hyperparameters": {
            "optimizer": "adam",
            "learning_rate": M.LEARNING_RATE,
            "beta1": M.ADAM_B1,
            "beta2": M.ADAM_B2,
            "eps": M.ADAM_EPS,
            "loss": "categorical_cross_entropy",
        },
        "models": {},
    }
    for name in args.models:
        print(f"lowering {name} ...")
        manifest["models"][name] = lower_model(
            name, out_dir, args.train_batch, args.infer_batch
        )

    blob = json.dumps(manifest, indent=2, sort_keys=True)
    manifest["sha256"] = hashlib.sha256(blob.encode()).hexdigest()
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
