"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .conv2d import conv2d, conv2d_flops, depthwise_conv2d
from .matmul import (
    BlockConfig,
    TPU_BLOCK_K,
    TPU_BLOCK_M,
    TPU_BLOCK_N,
    block_policy,
    dense,
    matmul,
    matmul_flops,
    vmem_bytes,
)

__all__ = [
    "BlockConfig",
    "TPU_BLOCK_K",
    "TPU_BLOCK_M",
    "TPU_BLOCK_N",
    "block_policy",
    "conv2d",
    "conv2d_flops",
    "dense",
    "depthwise_conv2d",
    "matmul",
    "matmul_flops",
    "vmem_bytes",
]
