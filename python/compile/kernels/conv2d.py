"""L1: 2-D convolution lowered onto the Pallas tiled matmul (im2col).

On the paper's CUDA targets cuDNN implements convolution as implicit GEMM;
we make that explicit: patch extraction (pure data movement, fused by XLA)
followed by the Pallas matmul kernel, so every convolution FLOP flows
through the same power-capped hot-spot kernel as the dense layers.

NHWC activations, HWIO weights (kh, kw, in_c, out_c) — JAX conventions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import matmul as mm


def _patches(x: jax.Array, kh: int, kw: int, stride: int, padding: str) -> jax.Array:
    """Extract im2col patches: (B, H', W', C*kh*kw) with (C, kh, kw) order.

    ``conv_general_dilated_patches`` emits the feature dim ordered as
    (spatial..., channel) varying fastest over the *filter* positions within
    each input channel — i.e. (C, kh, kw).  The weight reshape in
    :func:`conv2d` matches this ordering; the pair is validated against the
    ``lax.conv_general_dilated`` oracle in ``python/tests/test_kernels.py``.
    """
    p = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return p


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Convolution as im2col + Pallas GEMM.

    Args:
        x: (B, H, W, C) activations.
        w: (kh, kw, C, O) filters.
        b: optional (O,) bias.
        stride: spatial stride (same in both dims).
        padding: "SAME" or "VALID".

    Returns:
        (B, H', W', O) activations in f32.
    """
    kh, kw, c, o = w.shape
    patches = _patches(x, kh, kw, stride, padding)  # (B, H', W', C*kh*kw)
    bsz, ho, wo, feat = patches.shape
    lhs = patches.reshape(bsz * ho * wo, feat)
    # (kh, kw, C, O) -> (C, kh, kw, O) to match the patches feature order.
    rhs = jnp.transpose(w, (2, 0, 1, 3)).reshape(c * kh * kw, o)
    out = mm.matmul(lhs, rhs).reshape(bsz, ho, wo, o)
    if b is not None:
        out = out + b[None, None, None, :]
    return out


def depthwise_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Depthwise convolution (MobileNet-style), per-channel filters.

    Depthwise convs are bandwidth-bound (arithmetic intensity < 2 FLOP/B) and
    gain nothing from an MXU GEMM kernel; we keep them on the XLA native
    path (`feature_group_count = C`) — the pointwise 1x1 convs that dominate
    MobileNet FLOPs still run through the Pallas GEMM.

    Args:
        x: (B, H, W, C).
        w: (kh, kw, C, 1) per-channel filters.
    """
    c = x.shape[-1]
    assert w.shape[2] == c and w.shape[3] == 1, f"bad depthwise filter {w.shape}"
    return lax.conv_general_dilated(
        x,
        w.reshape(w.shape[0], w.shape[1], 1, c),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def conv2d_flops(
    batch: int, h_out: int, w_out: int, kh: int, kw: int, c_in: int, c_out: int
) -> int:
    """FLOPs of one conv layer (2 * MACs) — for the AOT cost manifest."""
    return mm.matmul_flops(batch * h_out * w_out, kh * kw * c_in, c_out)
