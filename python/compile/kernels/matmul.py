"""L1 Pallas kernel: tiled matmul — the compute hot-spot of every model.

The paper's workloads are CNNs whose training cost is dominated by GEMMs
(convolutions lowered through im2col, plus the dense classifier head).  On
the paper's CUDA targets these are the kernels the GPU power cap throttles;
here they are Pallas kernels so that the *same* hot-spot structure flows
through the AOT bridge into the Rust runtime.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): instead of CUDA
threadblocks + shared memory we express the HBM<->VMEM schedule with a
`BlockSpec` grid.  The canonical TPU tiling is 128x128x128 (MXU-systolic
shaped, f32 accumulation); on this repo's CPU-PJRT correctness path the
grid-step overhead of interpret mode dominates, so `block_policy` widens
blocks (fewer grid steps) while keeping the identical kernel body.  The
TPU-shaped constants are exported for the VMEM/MXU estimates recorded in
EXPERIMENTS.md §Perf.

All Pallas calls use ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Canonical TPU tile (MXU systolic array is 128x128; VMEM-friendly).
TPU_BLOCK_M = 128
TPU_BLOCK_N = 128
TPU_BLOCK_K = 128

# CPU-interpret policy caps: keep grids small (per-step overhead ~ms).
_CPU_MAX_BLOCK_M = 4096
_CPU_MAX_BLOCK_N = 512
_CPU_MAX_BLOCK_K = 4096


class BlockConfig(NamedTuple):
    """Block shape for one pallas matmul call."""

    bm: int
    bn: int
    bk: int


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def block_policy(m: int, k: int, n: int) -> BlockConfig:
    """Pick block sizes for an (m, k) @ (k, n) matmul.

    Policy: pad every dim to a multiple of 8 (sublane-friendly), then use the
    full padded dim as the block up to the CPU caps.  On small CNN GEMMs this
    yields a grid of 1-8 steps, which keeps interpret-mode overhead near the
    pure-XLA roofline while preserving the tiled kernel structure.
    """
    mp = _round_up(m, 8)
    kp = _round_up(k, 8)
    np_ = _round_up(n, 8)
    bm = min(mp, _CPU_MAX_BLOCK_M)
    bn = min(np_, _CPU_MAX_BLOCK_N)
    bk = min(kp, _CPU_MAX_BLOCK_K)
    return BlockConfig(bm=bm, bn=bn, bk=bk)


def vmem_bytes(cfg: BlockConfig, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (x, w and o blocks).

    Used by DESIGN.md/EXPERIMENTS.md §Perf to check the kernel against the
    ~16 MiB/core VMEM budget of a TPU.
    """
    return dtype_bytes * (cfg.bm * cfg.bk + cfg.bk * cfg.bn + cfg.bm * cfg.bn)


def _mm_kernel(x_ref, w_ref, o_ref):
    """Pallas kernel body: one (bm, bk) x (bk, bn) MXU tile, f32 accumulate.

    The output block is revisited across the k grid dimension and doubles as
    the accumulator (out index_map ignores k), which avoids a scratch
    allocation and works identically in interpret and compiled modes.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_pallas(x: jax.Array, w: jax.Array, cfg: BlockConfig) -> jax.Array:
    """Raw pallas tiled matmul over padded operands."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    mp, kp, np_ = _round_up(m, cfg.bm), _round_up(k, cfg.bk), _round_up(n, cfg.bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    grid = (mp // cfg.bm, np_ // cfg.bn, kp // cfg.bk)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cfg.bk, cfg.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((cfg.bm, cfg.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """``x @ w`` through the Pallas tiled kernel, differentiable.

    A ``custom_vjp`` routes the backward pass through the same Pallas kernel
    (``dx = g @ w.T``, ``dw = x.T @ g``) instead of relying on pallas_call
    transpose rules, so the *entire* train-step GEMM traffic is kernel
    traffic — exactly what the paper's power cap throttles.
    """
    cfg = block_policy(x.shape[0], x.shape[1], w.shape[1])
    return _matmul_pallas(x, w, cfg)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    g = g.astype(jnp.float32)
    dx_cfg = block_policy(g.shape[0], g.shape[1], w.shape[0])
    dw_cfg = block_policy(x.shape[1], x.shape[0], g.shape[1])
    dx = _matmul_pallas(g, w.T, dx_cfg)
    dw = _matmul_pallas(x.T, g, dw_cfg)
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.jit, static_argnames=())
def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Dense layer ``x @ w + b`` with the Pallas matmul on the hot path."""
    return matmul(x, w) + b[None, :]


def matmul_flops(m: int, k: int, n: int) -> int:
    """MACs*2 for one GEMM — consumed by the AOT cost manifest."""
    return 2 * m * k * n
