"""Pure-jnp/lax oracles for the Pallas kernels.

These are the CORE correctness signal of the build path: every kernel must
match its oracle to float tolerance before `aot.py` is allowed to emit
artifacts (enforced by pytest at build time, see Makefile `test`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul.matmul: plain XLA dot in f32."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul.dense."""
    return matmul_ref(x, w) + b[None, :]


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Oracle for kernels.conv2d.conv2d: native XLA convolution."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b[None, None, None, :]
    return out


def depthwise_conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """Oracle for kernels.conv2d.depthwise_conv2d via explicit per-channel loop."""
    c = x.shape[-1]
    outs = []
    for ch in range(c):
        outs.append(
            lax.conv_general_dilated(
                x[..., ch : ch + 1],
                w[:, :, ch : ch + 1, :],
                window_strides=(stride, stride),
                padding=padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        )
    return jnp.concatenate(outs, axis=-1)
