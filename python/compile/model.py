"""L2: JAX CNN model family — forward, loss, Adam train step, inference.

This is the "given a CNN model, a dataset, and a training setup" half of the
paper's problem statement.  Four small-but-real CNN architectures mirror the
paper's zoo diversity (classic LeNet, a plain deep stack, residual blocks,
depthwise-separable blocks); all convolution/dense FLOPs flow through the L1
Pallas kernels so that the AOT-lowered HLO has the paper's hot-spot
structure.  The Rust coordinator (L3) never imports this module — it loads
the HLO text artifacts produced by :mod:`compile.aot`.

State layout (the contract with ``rust/src/runtime``):

    state = [step(f32 scalar), *params, *m, *v]

``train_step(*state, x, y)`` returns ``(*state', loss, acc)`` with state
tensors in the *same order*, so the Rust training loop simply feeds outputs
``0..n_state`` back as inputs ``0..n_state``.

Hyperparameters follow the paper (Sec. IV): Adam, lr 1e-3, categorical
cross-entropy.  (Batch size is a lowering parameter; the paper's 128 is the
inference default, training artifacts default to 64 to bound CPU-interpret
step time.)
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels import conv2d, conv2d_flops, dense, depthwise_conv2d, matmul_flops

# Paper hyperparameters (Sec. IV).
LEARNING_RATE = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

NUM_CLASSES = 10
IMAGE_SHAPE = (32, 32, 3)  # CIFAR-10

# ---------------------------------------------------------------------------
# Architecture IR
# ---------------------------------------------------------------------------
# Layers are declarative tuples interpreted by `init_params` / `apply`:
#   ("conv", out_c, k, stride, padding)      conv + bias + relu
#   ("conv_linear", out_c, k, stride, pad)   conv + bias (no activation)
#   ("dwsep", out_c, stride)                 depthwise 3x3 + pointwise 1x1, relu
#   ("res", out_c, stride)                   2x conv residual block, relu
#   ("avgpool", k)                           average pool kxk stride k
#   ("maxpool", k)                           max pool kxk stride k
#   ("gap",)                                 global average pool
#   ("flatten",)
#   ("dense", n)                             dense + bias + relu
#   ("dense_linear", n)                      dense + bias (logits head)

ARCHS: dict[str, list[tuple[Any, ...]]] = {
    # Classic LeNet-5 (the paper's outlier model — too small to load a GPU).
    "lenet": [
        ("conv", 6, 5, 1, "VALID"),
        ("avgpool", 2),
        ("conv", 16, 5, 1, "VALID"),
        ("avgpool", 2),
        ("flatten",),
        ("dense", 120),
        ("dense", 84),
        ("dense_linear", NUM_CLASSES),
    ],
    # Plain deep conv stack (SimpleDLA-flavoured).
    "simpledla": [
        ("conv", 32, 3, 1, "SAME"),
        ("conv", 32, 3, 2, "SAME"),
        ("conv", 64, 3, 1, "SAME"),
        ("conv", 64, 3, 2, "SAME"),
        ("conv", 128, 3, 2, "SAME"),
        ("gap",),
        ("dense_linear", NUM_CLASSES),
    ],
    # Residual network (ResNet-flavoured).
    "resnet_mini": [
        ("conv", 16, 3, 1, "SAME"),
        ("res", 16, 1),
        ("res", 32, 2),
        ("res", 64, 2),
        ("gap",),
        ("dense_linear", NUM_CLASSES),
    ],
    # Depthwise-separable network (MobileNet-flavoured).
    "mobilenet_mini": [
        ("conv", 16, 3, 2, "SAME"),
        ("dwsep", 32, 1),
        ("dwsep", 64, 2),
        ("dwsep", 128, 2),
        ("gap",),
        ("dense_linear", NUM_CLASSES),
    ],
}

TRAINABLE_MODELS = tuple(sorted(ARCHS))


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _conv_init(rng: jax.Array, kh: int, kw: int, cin: int, cout: int):
    """He-normal conv filter + zero bias."""
    std = (2.0 / (kh * kw * cin)) ** 0.5
    w = jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * std
    return w, jnp.zeros((cout,), jnp.float32)


def _dense_init(rng: jax.Array, nin: int, nout: int):
    std = (2.0 / nin) ** 0.5
    w = jax.random.normal(rng, (nin, nout), jnp.float32) * std
    return w, jnp.zeros((nout,), jnp.float32)


def _shape_after(layers: Sequence[tuple], upto: int) -> tuple[int, int, int]:
    """Spatial/channel shape after `upto` layers, starting from IMAGE_SHAPE."""
    h, w, c = IMAGE_SHAPE
    flat = None
    for layer in layers[:upto]:
        kind = layer[0]
        if kind in ("conv", "conv_linear"):
            _, cout, k, s, pad = layer
            if pad == "VALID":
                h, w = (h - k) // s + 1, (w - k) // s + 1
            else:
                h, w = -(-h // s), -(-w // s)
            c = cout
        elif kind == "dwsep":
            _, cout, s = layer
            h, w = -(-h // s), -(-w // s)
            c = cout
        elif kind == "res":
            _, cout, s = layer
            h, w = -(-h // s), -(-w // s)
            c = cout
        elif kind in ("avgpool", "maxpool"):
            k = layer[1]
            h, w = h // k, w // k
        elif kind == "gap":
            flat = c
            h = w = 1
        elif kind == "flatten":
            flat = h * w * c
        elif kind in ("dense", "dense_linear"):
            flat = layer[1]
    if flat is not None:
        return 1, 1, flat
    return h, w, c


def init_params(name: str, seed: int = 0) -> list[jax.Array]:
    """Build the flat parameter list for architecture `name`."""
    layers = ARCHS[name]
    rng = jax.random.PRNGKey(seed)
    params: list[jax.Array] = []
    for i, layer in enumerate(layers):
        kind = layer[0]
        _, _, cin = _shape_after(layers, i)
        if i == 0:
            cin = IMAGE_SHAPE[2]
        else:
            cin = _shape_after(layers, i)[2]
        if kind in ("conv", "conv_linear"):
            _, cout, k, _, _ = layer
            rng, sub = jax.random.split(rng)
            w, b = _conv_init(sub, k, k, cin, cout)
            params += [w, b]
        elif kind == "dwsep":
            _, cout, _ = layer
            rng, s1 = jax.random.split(rng)
            rng, s2 = jax.random.split(rng)
            dw = jax.random.normal(s1, (3, 3, cin, 1), jnp.float32) * (2.0 / 9) ** 0.5
            pw, pb = _conv_init(s2, 1, 1, cin, cout)
            params += [dw, pw, pb]
        elif kind == "res":
            _, cout, s = layer
            rng, s1 = jax.random.split(rng)
            rng, s2 = jax.random.split(rng)
            w1, b1 = _conv_init(s1, 3, 3, cin, cout)
            w2, b2 = _conv_init(s2, 3, 3, cout, cout)
            params += [w1, b1, w2, b2]
            if s != 1 or cin != cout:
                rng, s3 = jax.random.split(rng)
                ws, bs = _conv_init(s3, 1, 1, cin, cout)
                params += [ws, bs]
        elif kind in ("dense", "dense_linear"):
            nout = layer[1]
            nin = _shape_after(layers, i)[0] * _shape_after(layers, i)[1]
            # flatten dim computed by _shape_after at this index:
            h, w_, c = _shape_after(layers, i)
            nin = h * w_ * c
            rng, sub = jax.random.split(rng)
            w, b = _dense_init(sub, nin, nout)
            params += [w, b]
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _avg_pool(x: jax.Array, k: int) -> jax.Array:
    b, h, w, c = x.shape
    x = x[:, : h // k * k, : w // k * k, :]
    x = x.reshape(b, h // k, k, w // k, k, c)
    return x.mean(axis=(2, 4))


def _max_pool(x: jax.Array, k: int) -> jax.Array:
    b, h, w, c = x.shape
    x = x[:, : h // k * k, : w // k * k, :]
    x = x.reshape(b, h // k, k, w // k, k, c)
    return x.max(axis=(2, 4))


def apply(name: str, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Forward pass: (B, 32, 32, 3) images -> (B, 10) logits."""
    layers = ARCHS[name]
    p = list(params)
    i = 0

    def take(n: int):
        nonlocal i
        out = p[i : i + n]
        i += n
        return out

    for li, layer in enumerate(layers):
        kind = layer[0]
        if kind == "conv":
            _, cout, k, s, pad = layer
            w, b = take(2)
            x = jax.nn.relu(conv2d(x, w, b, stride=s, padding=pad))
        elif kind == "conv_linear":
            _, cout, k, s, pad = layer
            w, b = take(2)
            x = conv2d(x, w, b, stride=s, padding=pad)
        elif kind == "dwsep":
            _, cout, s = layer
            dw, pw, pb = take(3)
            x = depthwise_conv2d(x, dw, stride=s, padding="SAME")
            x = jax.nn.relu(conv2d(x, pw, pb, stride=1, padding="SAME"))
        elif kind == "res":
            _, cout, s = layer
            cin = x.shape[-1]
            w1, b1, w2, b2 = take(4)
            y = jax.nn.relu(conv2d(x, w1, b1, stride=s, padding="SAME"))
            y = conv2d(y, w2, b2, stride=1, padding="SAME")
            if s != 1 or cin != cout:
                ws, bs = take(2)
                x = conv2d(x, ws, bs, stride=s, padding="SAME")
            x = jax.nn.relu(x + y)
        elif kind == "avgpool":
            x = _avg_pool(x, layer[1])
        elif kind == "maxpool":
            x = _max_pool(x, layer[1])
        elif kind == "gap":
            x = x.mean(axis=(1, 2))
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "dense":
            w, b = take(2)
            x = jax.nn.relu(dense(x, w, b))
        elif kind == "dense_linear":
            w, b = take(2)
            x = dense(x, w, b)
        else:  # pragma: no cover - IR is static
            raise ValueError(f"unknown layer {kind}")
    assert i == len(p), f"{name}: consumed {i} of {len(p)} params"
    return x


# ---------------------------------------------------------------------------
# Loss / train step / inference
# ---------------------------------------------------------------------------


def loss_and_acc(
    name: str, params: Sequence[jax.Array], x: jax.Array, y: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Categorical cross-entropy + accuracy (paper Sec. IV hyperparameters)."""
    logits = apply(name, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=jnp.float32)
    loss = -(onehot * logp).sum(axis=-1).mean()
    acc = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32).mean()
    return loss, acc


def make_train_step(name: str):
    """Adam train step over the flat state layout (see module docstring)."""
    n = len(init_params(name))

    def train_step(*args):
        step = args[0]
        params = list(args[1 : 1 + n])
        m = list(args[1 + n : 1 + 2 * n])
        v = list(args[1 + 2 * n : 1 + 3 * n])
        x, y = args[1 + 3 * n], args[2 + 3 * n]

        (loss, acc), grads = jax.value_and_grad(
            lambda ps: loss_and_acc(name, ps, x, y), has_aux=True
        )(params)

        step1 = step + 1.0
        # Bias-corrected Adam.
        lr_t = LEARNING_RATE * jnp.sqrt(1.0 - ADAM_B2**step1) / (1.0 - ADAM_B1**step1)
        new_params, new_m, new_v = [], [], []
        for pi, mi, vi, gi in zip(params, m, v, grads):
            mi1 = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
            vi1 = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
            pi1 = pi - lr_t * mi1 / (jnp.sqrt(vi1) + ADAM_EPS)
            new_params.append(pi1)
            new_m.append(mi1)
            new_v.append(vi1)
        return (step1, *new_params, *new_m, *new_v, loss, acc)

    return train_step


def make_infer(name: str):
    """Inference fn: (params..., x) -> (logits, predictions)."""
    n = len(init_params(name))

    def infer(*args):
        params = list(args[:n])
        x = args[n]
        logits = apply(name, params, x)
        return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return infer


def init_state(name: str, seed: int = 0) -> list[jax.Array]:
    """Initial flat state [step, params..., m..., v...] for `name`."""
    params = init_params(name, seed)
    zeros = [jnp.zeros_like(p) for p in params]
    zeros2 = [jnp.zeros_like(p) for p in params]
    return [jnp.zeros((), jnp.float32), *params, *zeros, *zeros2]


# ---------------------------------------------------------------------------
# Cost model (consumed by the AOT manifest -> Rust zoo)
# ---------------------------------------------------------------------------


class LayerCost(NamedTuple):
    name: str
    flops: int
    bytes_accessed: int


def forward_cost(name: str, batch: int) -> list[LayerCost]:
    """Analytic per-layer forward cost: FLOPs and HBM bytes (f32)."""
    layers = ARCHS[name]
    costs: list[LayerCost] = []
    h, w, c = IMAGE_SHAPE
    for i, layer in enumerate(layers):
        kind = layer[0]
        hin, win, cin = (h, w, c) if i == 0 else _shape_after(layers, i)
        if i == 0:
            hin, win, cin = IMAGE_SHAPE
        ho, wo, co = _shape_after(layers, i + 1)
        if kind in ("conv", "conv_linear"):
            k = layer[2]
            fl = conv2d_flops(batch, ho, wo, k, k, cin, co)
            by = 4 * batch * (hin * win * cin + ho * wo * co) + 4 * k * k * cin * co
        elif kind == "dwsep":
            fl = 2 * batch * ho * wo * cin * 9 + conv2d_flops(batch, ho, wo, 1, 1, cin, co)
            by = 4 * batch * (hin * win * cin + 2 * ho * wo * co)
        elif kind == "res":
            fl = conv2d_flops(batch, ho, wo, 3, 3, cin, co) + conv2d_flops(
                batch, ho, wo, 3, 3, co, co
            )
            if cin != co or layer[2] != 1:
                fl += conv2d_flops(batch, ho, wo, 1, 1, cin, co)
            by = 4 * batch * (hin * win * cin + 3 * ho * wo * co)
        elif kind in ("dense", "dense_linear"):
            nin = hin * win * cin
            fl = matmul_flops(batch, nin, layer[1])
            by = 4 * (batch * (nin + layer[1]) + nin * layer[1])
        else:
            fl = 0
            by = 4 * batch * hin * win * cin
        costs.append(LayerCost(f"{i}:{kind}", fl, by))
    return costs


def model_flops(name: str, batch: int, training: bool = True) -> int:
    """Total FLOPs per batch; backward ~= 2x forward for conv nets."""
    fwd = sum(c.flops for c in forward_cost(name, batch))
    return fwd * 3 if training else fwd


def param_count(name: str) -> int:
    return int(sum(p.size for p in init_params(name)))
