"""AOT bridge tests: HLO-text emission and manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    """Any jitted fn must lower to parseable HLO text with an ENTRY."""
    lo = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lo)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_to_hlo_text_contains_no_serialized_proto():
    """Interchange format is text — regression guard for the 64-bit-id trap."""
    lo = jax.jit(lambda a: (a * 2,)).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
    text = aot.to_hlo_text(lo)
    assert text.isprintable() or "\n" in text  # plain text, not proto bytes


def test_lower_model_tiny(tmp_path):
    """Full lower_model pass for the smallest arch into a temp dir."""
    entry = aot.lower_model("lenet", str(tmp_path), train_batch=4, infer_batch=4)
    assert entry["n_state"] == 1 + 3 * entry["n_params"]
    for kind in ("init", "train", "infer"):
        f = tmp_path / entry[kind]["file"]
        assert f.exists(), f"missing artifact {f}"
        assert "HloModule" in f.read_text()[:200]
    assert entry["train"]["n_outputs"] == entry["n_state"] + 2
    assert entry["train"]["flops_analytic"] > 0
    assert len(entry["layer_costs"]) == len(M.ARCHS["lenet"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_consistency():
    """The checked-out artifacts/ must be self-consistent with model.py."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    assert set(m["models"]) <= set(M.TRAINABLE_MODELS)
    for name, entry in m["models"].items():
        assert entry["param_count"] == M.param_count(name)
        assert entry["n_state"] == len(M.init_state(name))
        for kind in ("init", "train", "infer"):
            assert os.path.exists(os.path.join(ARTIFACTS, entry[kind]["file"]))
        # state spec shapes match a fresh init
        fresh = M.init_state(name)
        for spec, arr in zip(entry["state_specs"], fresh):
            assert tuple(spec["shape"]) == arr.shape
