"""Kernel vs oracle — the CORE correctness signal of the build path.

hypothesis sweeps shapes/strides/paddings; every Pallas result must match
the pure-XLA reference to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_policy,
    conv2d,
    depthwise_conv2d,
    dense,
    matmul,
    vmem_bytes,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 1e-5
ATOL = 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)), np.asarray(ref.matmul_ref(x, w)), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 128),
        (129, 65, 33),  # forces padding on every dim
        (1024, 27, 16),  # first-conv im2col shape
        (5, 2304, 512),  # wide-K GEMM
    ],
)
def test_matmul_shapes(m, k, n):
    rng = np.random.default_rng(42)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    got = matmul(x, w)
    assert got.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=RTOL, atol=ATOL
    )


def test_matmul_grad_matches_ref():
    """custom_vjp backward must equal autodiff through the oracle."""
    rng = np.random.default_rng(7)
    x, w = _rand(rng, 17, 9), _rand(rng, 9, 5)

    def f_pallas(x, w):
        return (matmul(x, w) ** 2).sum()

    def f_ref(x, w):
        return (ref.matmul_ref(x, w) ** 2).sum()

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-4, atol=1e-4)


def test_dense_matches_ref():
    rng = np.random.default_rng(3)
    x, w, b = _rand(rng, 32, 400), _rand(rng, 400, 120), _rand(rng, 120)
    np.testing.assert_allclose(
        np.asarray(dense(x, w, b)),
        np.asarray(ref.dense_ref(x, w, b)),
        rtol=RTOL,
        atol=ATOL,
    )


def test_block_policy_divides_padded_dims():
    cfg = block_policy(129, 65, 33)
    assert cfg.bm % 8 == 0 and cfg.bn % 8 == 0 and cfg.bk % 8 == 0
    assert vmem_bytes(cfg) > 0


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.integers(4, 16),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref_hypothesis(b, hw, cin, cout, k, stride, padding, seed):
    if padding == "VALID" and k > hw:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, hw, hw, cin)
    w = _rand(rng, k, k, cin, cout)
    bias = _rand(rng, cout)
    got = conv2d(x, w, bias, stride=stride, padding=padding)
    want = ref.conv2d_ref(x, w, bias, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"), (1, "VALID")])
def test_conv2d_cifar_shape(stride, padding):
    rng = np.random.default_rng(0)
    x = _rand(rng, 8, 32, 32, 3)
    w = _rand(rng, 3, 3, 3, 16)
    got = conv2d(x, w, stride=stride, padding=padding)
    want = ref.conv2d_ref(x, w, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv2d_grad_flows():
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 8, 8, 3)
    w = _rand(rng, 3, 3, 3, 4)

    g = jax.grad(lambda w: conv2d(x, w).sum())(w)
    gr = jax.grad(lambda w: ref.conv2d_ref(x, w).sum())(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.integers(4, 12),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_ref_hypothesis(b, hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, hw, hw, c)
    w = _rand(rng, 3, 3, c, 1)
    got = depthwise_conv2d(x, w, stride=stride)
    want = ref.depthwise_conv2d_ref(x, w, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
