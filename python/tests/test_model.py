"""L2 model tests: shapes, state layout contract, and learning progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _fake_batch(batch=8, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, *M.IMAGE_SHAPE), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, M.NUM_CLASSES, batch, dtype=np.int32))
    return x, y


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_forward_shapes(name):
    params = M.init_params(name)
    x, _ = _fake_batch()
    logits = M.apply(name, params, x)
    assert logits.shape == (8, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_state_layout_contract(name):
    """state = [step, params, m, v] and train_step preserves the layout."""
    state = M.init_state(name)
    n = len(M.init_params(name))
    assert len(state) == 1 + 3 * n
    assert state[0].shape == ()

    x, y = _fake_batch()
    out = M.make_train_step(name)(*state, x, y)
    assert len(out) == len(state) + 2  # + loss + acc
    for s_in, s_out in zip(state, out):
        assert s_in.shape == s_out.shape
        assert s_in.dtype == s_out.dtype
    assert float(out[0]) == 1.0  # step incremented


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_infer_outputs(name):
    params = M.init_params(name)
    x, _ = _fake_batch()
    logits, preds = M.make_infer(name)(*params, x)
    assert logits.shape == (8, M.NUM_CLASSES)
    assert preds.shape == (8,)
    assert preds.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(preds), np.asarray(jnp.argmax(logits, axis=-1))
    )


def test_loss_decreases_lenet():
    """A few Adam steps on a fixed batch must reduce CCE (sanity of grads)."""
    name = "lenet"
    state = list(M.init_state(name))
    x, y = _fake_batch(batch=16, seed=1)
    step_fn = jax.jit(M.make_train_step(name))
    first_loss = None
    last_loss = None
    for _ in range(8):
        out = step_fn(*state, x, y)
        state = list(out[:-2])
        loss = float(out[-2])
        if first_loss is None:
            first_loss = loss
        last_loss = loss
    assert last_loss < first_loss, f"loss did not decrease: {first_loss} -> {last_loss}"


def test_param_count_positive_and_stable():
    c1 = M.param_count("lenet")
    c2 = M.param_count("lenet")
    assert c1 == c2 > 10_000  # LeNet-5 is ~62k params


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_forward_cost_positive(name):
    costs = M.forward_cost(name, 64)
    assert sum(c.flops for c in costs) > 0
    assert all(c.bytes_accessed > 0 for c in costs)
    assert M.model_flops(name, 64, training=True) == 3 * M.model_flops(
        name, 64, training=False
    )


def test_init_deterministic():
    a = M.init_params("resnet_mini", seed=0)
    b = M.init_params("resnet_mini", seed=0)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
