"""Cross-layer contract tests: the Python model family must keep the
promises the Rust coordinator relies on (flat state layout, shapes, costs).
hypothesis sweeps batch sizes and seeds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=8, deadline=None)
@given(batch=st.sampled_from([1, 2, 8, 16]), seed=st.integers(0, 10_000))
def test_forward_any_batch_lenet(batch, seed):
    """Forward must work at any batch size (lowering picks one statically,
    but the function itself is batch-polymorphic)."""
    params = M.init_params("lenet", seed=0)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, *M.IMAGE_SHAPE), dtype=np.float32))
    logits = M.apply("lenet", params, x)
    assert logits.shape == (batch, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_different_seeds_give_different_params(seed):
    a = M.init_params("simpledla", seed=seed)
    b = M.init_params("simpledla", seed=seed + 1)
    diffs = sum(float(jnp.abs(x - y).sum()) for x, y in zip(a, b))
    assert diffs > 0.0


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_state_order_is_step_params_m_v(name):
    """The Rust executor feeds outputs[0..n_state] back as inputs — that is
    only sound if the state tuple order is exactly [step, params, m, v]."""
    state = M.init_state(name)
    n = len(M.init_params(name))
    # step scalar
    assert state[0].shape == ()
    # params match a fresh init exactly
    fresh = M.init_params(name, seed=0)
    for s, p in zip(state[1 : 1 + n], fresh):
        np.testing.assert_array_equal(np.asarray(s), np.asarray(p))
    # m and v start at zero
    for s in state[1 + n :]:
        assert float(jnp.abs(s).sum()) == 0.0


@pytest.mark.parametrize("name", M.TRAINABLE_MODELS)
def test_two_train_steps_advance_counter_and_change_params(name):
    state = list(M.init_state(name))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, *M.IMAGE_SHAPE), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 4, dtype=np.int32))
    step_fn = M.make_train_step(name)
    out1 = step_fn(*state, x, y)
    out2 = step_fn(*out1[:-2], x, y)
    assert float(out2[0]) == 2.0
    n = len(M.init_params(name))
    moved = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(state[1 : 1 + n], out2[1 : 1 + n])
    )
    assert moved > 0.0, "parameters must move under Adam"


def test_cost_model_scales_linearly_with_batch():
    f64 = M.model_flops("resnet_mini", 64)
    f128 = M.model_flops("resnet_mini", 128)
    assert abs(f128 / f64 - 2.0) < 0.01


def test_cost_model_ranks_architectures_sanely():
    """resnet_mini (full convs) must cost more per sample than
    mobilenet_mini (depthwise separable) and lenet."""
    costs = {n: M.model_flops(n, 64) for n in M.TRAINABLE_MODELS}
    assert costs["resnet_mini"] > costs["mobilenet_mini"]
    assert costs["resnet_mini"] > costs["lenet"]
    assert costs["simpledla"] > costs["lenet"]


def test_loss_is_cce_at_uniform_logits():
    """Categorical cross-entropy of uniform predictions is ln(10)."""
    params = M.init_params("lenet")
    zeroed = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, *M.IMAGE_SHAPE), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 8, dtype=np.int32))
    loss, acc = M.loss_and_acc("lenet", zeroed, x, y)
    np.testing.assert_allclose(float(loss), np.log(10.0), rtol=1e-5)
