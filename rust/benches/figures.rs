//! Benches for the figure-regeneration harnesses — the end-to-end cost of
//! reproducing each paper table/figure on the virtual testbeds.
//!
//! One bench per evaluation artefact (DESIGN.md §5):
//!   Fig. 2  — 16 models × 100 epochs initial investigation
//!   Fig. 4  — 3-model × 8-cap capping sweep
//!   Fig. 5  — 71-point fine-grained sweep + 3 ED^xP optimisations
//!   Fig. 6  — 16-model ED²P tradeoff (the headline numbers)
//! (Fig. 3 exercises real PJRT inference and lives in `benches/runtime.rs`.)

use frost::config::{setup_no1, setup_no2};
use frost::figures;
use frost::util::bench::{bench, group};

fn main() {
    group("figure regeneration (simulated testbeds)");

    bench("fig2: 16 models x 100 epochs", 3.0, || {
        figures::fig2_investigation(&setup_no1(), 100, 42)
    });

    bench("fig4: 3 models x 8 caps (setup no.2)", 3.0, || {
        figures::fig4_power_capping(&setup_no2(), &["MobileNet", "DenseNet", "EfficientNet"], 42)
    });

    bench("fig5: ResNet 71-cap sweep + ED^xP optima", 3.0, || {
        figures::fig5_fine_grained(&setup_no2(), "ResNet", 42)
    });

    bench("fig6: 16-model ED2P tradeoff (setup no.1)", 3.0, || {
        figures::fig6_tradeoff(&setup_no1(), 2.0, 42)
    });

    bench("fig6: both setups (paper headline)", 4.0, || {
        (
            figures::fig6_tradeoff(&setup_no1(), 2.0, 42),
            figures::fig6_tradeoff(&setup_no2(), 2.0, 42),
        )
    });
}
