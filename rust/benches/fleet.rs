//! Fleet hot-path benches: steady-state round throughput at 4/16/64 sites,
//! the region-tier sweep (§16) at 64/256/1,000/10,000 sites with ~√N
//! regions — the 64-site point pairs with the flat 64-site bench for the
//! flat-vs-hierarchical comparison — plus the cached-vs-uncached
//! execution-model microbench.
//!
//! This is the perf trajectory the ROADMAP's "as fast as the hardware
//! allows" north star is measured against: the numbers land in
//! `BENCH_fleet.json` (written to the working directory; CI uploads it as
//! an artifact), and the checked-in copy at the repository root records
//! the pre-/post-optimisation pair for each PR that touches the hot path.
//!
//! The suite definition lives in `frost::oran::fleet::run_bench_suite`,
//! shared with the `frost bench` CLI subcommand so the two recorders
//! cannot drift.

use frost::oran::run_bench_suite;
use frost::util::bench::{write_json, BenchStats};

fn main() {
    let results = run_bench_suite(2.0).expect("fleet bench suite");
    let refs: Vec<(&str, BenchStats)> =
        results.iter().map(|(n, s)| (n.as_str(), *s)).collect();
    write_json("BENCH_fleet.json", "fleet", &refs).expect("write BENCH_fleet.json");
}
