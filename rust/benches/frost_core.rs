//! Benches for FROST's decision core: the response fit (Eqs. 6–7), the
//! downhill simplex, the ED^mP scoring, and the full 8-cap profiling sweep.
//!
//! These are the operations that run *online* inside an O-RAN deployment
//! every time a new model arrives, so their latency budget matters (the
//! paper's profiler touches the hardware for 8 × 30 s; the decision math
//! itself must be negligible next to that).

use frost::config::{setup_no1, setup_no2, ProfilerConfig};
use frost::frost::fit::fit_response;
use frost::frost::{nelder_mead, EdpCriterion, NelderMeadOptions, PowerProfiler};
use frost::simulator::Testbed;
use frost::util::bench::{bench, group};
use frost::zoo::model_by_name;

fn paper_shaped_points() -> Vec<(f64, f64)> {
    (3..=10)
        .map(|i| {
            let x = i as f64 / 10.0;
            (x, 3.0 * (-14.0 * (x - 0.3)).exp() + 1.0 / (1.0 + (-6.0 * (x - 0.55)).exp()) + 2.0)
        })
        .collect()
}

fn main() {
    group("frost decision core");

    let pts = paper_shaped_points();
    bench("fit_response (7-coef LSQ, 8 points)", 1.0, || {
        fit_response(&pts, 0.05)
    });

    let fit = fit_response(&pts, 0.05);
    bench("F(x) argmin via downhill simplex", 0.5, || fit.minimize(0.3, 1.0));

    bench("nelder_mead rosenbrock-2d", 0.5, || {
        nelder_mead(
            |x| (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2),
            &[-1.2, 1.0],
            &NelderMeadOptions { max_evals: 20_000, ..Default::default() },
        )
    });

    let c = EdpCriterion::ed2p();
    bench("ED2P score", 0.2, || c.score(std::hint::black_box(0.05), 1.5e-4));

    group("profiler sweeps (virtual 30 s windows)");
    let w = model_by_name("ResNet").unwrap().workload(&setup_no1().gpu);
    bench("8-cap profile sweep (ResNet, setup no.2)", 2.0, || {
        let mut tb = Testbed::new(setup_no2(), 42);
        PowerProfiler::new(ProfilerConfig::default()).profile(&mut tb, &w, 128)
    });
    bench("71-cap fine-grained sweep (ResNet)", 2.0, || {
        let mut tb = Testbed::new(setup_no2(), 42);
        PowerProfiler::new(ProfilerConfig::fine_grained()).profile(&mut tb, &w, 128)
    });
}
