//! Benches for the REAL request path: PJRT compile/train/infer latency for
//! the AOT artifacts, plus the Fig. 3 tool drag measured on genuine
//! inference steps.  Skips gracefully when `make artifacts` hasn't run.

use frost::config::setup_no1;
use frost::data::SyntheticCifar;
use frost::pipeline::calibrated_workload;
use frost::runtime::{InferenceSession, Runtime, TrainSession};
use frost::util::bench::{bench, group};
use frost::zoo::Manifest;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        println!("artifacts not built — run `make artifacts` first; skipping runtime benches");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    group(&format!("PJRT request path (platform: {})", rt.platform()));

    // Compile cost (paid once per model at startup).
    let lenet = manifest.model("lenet").unwrap();
    bench("compile lenet_train.hlo.txt", 3.0, || {
        rt.load(manifest.artifact_path(&lenet.train)).unwrap()
    });

    for name in ["lenet", "mobilenet_mini", "simpledla", "resnet_mini"] {
        let mut session = TrainSession::new(&rt, &manifest, name).unwrap();
        let mut ds = SyntheticCifar::new(1);
        let batch = ds.next_batch(session.batch as usize);
        session.step(&batch).unwrap(); // warmup
        let stats = bench(&format!("train step {name} (batch {})", session.batch), 3.0, || {
            session.step(&batch).unwrap()
        });
        let sps = session.batch as f64 * stats.throughput_per_s();
        println!("       -> {sps:.0} samples/s training");
    }

    for name in ["lenet", "mobilenet_mini"] {
        let mut session = InferenceSession::new(&rt, &manifest, name).unwrap();
        let ds = SyntheticCifar::new(2);
        let batch = ds.eval_batch(session.batch as usize, 3);
        session.run(&batch.images).unwrap(); // warmup
        let stats = bench(&format!("infer step {name} (batch {})", session.batch), 3.0, || {
            session.run(&batch.images).unwrap()
        });
        let sps = session.batch as f64 * stats.throughput_per_s();
        println!("       -> {sps:.0} samples/s inference");
    }

    group("fig3 overhead on real inference (1 rep, small)");
    let hw = setup_no1();
    let m = manifest.model("lenet").unwrap();
    let w = calibrated_workload(m, &hw.gpu, None).unwrap();
    let results = frost::pipeline::run_overhead_experiment(
        &rt, &manifest, &hw, &w, "lenet", 1280, 1,
    )
    .unwrap();
    for r in &results {
        println!(
            "tool {:<16} {:>8.3} s  ({:+.2}% vs baseline, {} samples collected)",
            r.tool,
            r.wall_s,
            (r.relative - 1.0) * 100.0,
            r.tool_samples
        );
    }
}
