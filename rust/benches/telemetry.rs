//! Benches for the telemetry hot path — the per-sample cost FROST adds to
//! a running ML pipeline.  The paper's requirement (Sec. IV-B): overhead
//! indistinguishable from the no-measurement baseline.  DESIGN.md §Perf
//! budgets < 1% of the inference loop; these benches quantify each piece.

use std::sync::Arc;

use frost::simulator::Testbed;
use frost::telemetry::hub::{PowerReading, TelemetryHub};
use frost::telemetry::nvml::NvmlDevice;
use frost::telemetry::rapl::{RaplDomain, RaplMsr};
use frost::telemetry::sampler::PowerSampler;
use frost::telemetry::tools::{CodeCarbonLike, Eco2AiLike, FrostTool, MeasurementTool};
use frost::util::bench::{bench, group};
use frost::util::{Seconds, Watts};
use frost::config::setup_no1;
use frost::zoo::model_by_name;

fn reading(at: f64) -> PowerReading {
    PowerReading {
        at: Seconds(at),
        gpu: Watts(280.0),
        cpu: Watts(65.0),
        dram: Watts(24.0),
        gpu_util: 0.97,
        freq_mhz: 1650.0,
    }
}

fn main() {
    group("telemetry primitives");

    let hub = Arc::new(TelemetryHub::new());
    let mut t = 0.0;
    bench("hub publish", 0.5, || {
        t += 0.01;
        hub.publish(reading(t));
    });

    let nvml = NvmlDevice::new(hub.clone(), 320.0, 0.3125, 1);
    bench("nvml power_usage read", 0.5, || nvml.power_usage_mw());

    let rapl = RaplMsr::new(hub.clone(), RaplDomain::Pkg, 1);
    bench("rapl counter read", 0.5, || rapl.read_raw());

    let mut sampler = PowerSampler::new(hub.clone(), 320.0, 0.3125, Seconds(0.1), 2);
    let mut ts = 0.0;
    bench("sampler poll (mostly not due)", 0.5, || {
        ts += 0.001;
        sampler.poll(Seconds(ts))
    });

    group("measurement tool ticks (the Fig. 3 mechanism)");
    let mut frost_tool = FrostTool::new(hub.clone(), 320.0, 3);
    let mut tf = 0.0;
    frost_tool.on_tick(Seconds(0.0));
    bench("FROST tick (due)", 0.5, || {
        tf += 0.2; // always due at 0.1 s period
        frost_tool.on_tick(Seconds(tf));
    });

    let mut cc = CodeCarbonLike::new(hub.clone(), 320.0, 3);
    let mut tc = 0.0;
    cc.on_tick(Seconds(0.0));
    bench("CodeCarbon-like tick (due)", 1.0, || {
        tc += 2.0;
        cc.on_tick(Seconds(tc));
    });

    let mut eco = Eco2AiLike::new(hub.clone(), 320.0, 3);
    let mut te = 0.0;
    eco.on_tick(Seconds(0.0));
    bench("Eco2AI-like tick (due)", 1.0, || {
        te += 2.0;
        eco.on_tick(Seconds(te));
    });

    group("simulator step throughput");
    let hw = setup_no1();
    let w = model_by_name("ResNet").unwrap().workload(&hw.gpu);
    let mut tb = Testbed::new(hw.clone(), 5);
    bench("testbed train step (roofline + capping fixpoint)", 1.0, || {
        tb.train_steps(&w, 128, 1)
    });
    let mut tb2 = Testbed::new(hw, 5);
    bench("testbed train epoch (fast path, 391 steps)", 1.0, || {
        tb2.train_epoch(&w, 128, 50_000)
    });
}
