//! Traffic hot-path benches: slot throughput at 1k / 100k / 5M users per
//! site, exact per-request vs aggregated count path, plus the SLO
//! roll-up (sort vs histogram) microbench.
//!
//! The numbers land in `BENCH_traffic.json` (written to the working
//! directory; CI uploads it as an artifact), and the checked-in copy at
//! the repository root records the pre-/post-optimisation pair — the
//! "millions of users" point on the ROADMAP's perf trajectory.
//!
//! The suite definition lives in `frost::traffic::run_traffic_bench_suite`,
//! shared with the `frost bench --traffic` CLI subcommand so the two
//! recorders cannot drift.

use frost::traffic::run_traffic_bench_suite;
use frost::util::bench::{write_json, BenchStats};

fn main() {
    let results = run_traffic_bench_suite(2.0).expect("traffic bench suite");
    let refs: Vec<(&str, BenchStats)> =
        results.iter().map(|(n, s)| (n.as_str(), *s)).collect();
    write_json("BENCH_traffic.json", "traffic", &refs).expect("write BENCH_traffic.json");
}
