//! R1 violation: float ordering through `partial_cmp`.

pub fn pick(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
