//! R1 clean: `total_cmp` is total over NaN and panic-free.
//! The word partial_cmp in this comment must not fire the rule.

pub fn pick(xs: &mut [f64]) {
    let prose = "partial_cmp inside a string must not fire either";
    let _ = prose;
    xs.sort_by(|a, b| a.total_cmp(b));
}
