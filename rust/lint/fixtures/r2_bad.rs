//! R2 violation: hash-ordered collection in a simulation path.

use std::collections::HashMap;

pub fn report() -> String {
    let mut m: HashMap<String, f64> = HashMap::new();
    m.insert("site-0".into(), 1.0);
    let mut out = String::new();
    for (k, v) in &m {
        out.push_str(&format!("{k}={v};"));
    }
    out
}
