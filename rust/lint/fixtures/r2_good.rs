//! R2 clean: ordered collection; the bare `use` of HashMap is exempt
//! (declarations do not iterate — usage sites are what matter).

use std::collections::BTreeMap;
use std::collections::HashMap;

pub fn report() -> String {
    let mut m: BTreeMap<String, f64> = BTreeMap::new();
    m.insert("site-0".into(), 1.0);
    let mut out = String::new();
    for (k, v) in &m {
        out.push_str(&format!("{k}={v};"));
    }
    out
}
