//! R3 violations: wall clock and unseeded randomness in simulation logic.

use std::time::Instant;

pub fn step_elapsed() -> u128 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos()
}

pub fn roll() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
