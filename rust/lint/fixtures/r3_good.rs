//! R3 clean: time and randomness both derive from injected state.

pub fn step_elapsed(clock_ns: u64, last_ns: u64) -> u64 {
    clock_ns.saturating_sub(last_ns)
}

pub fn roll(seed: u64) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}
