//! R4 violation: unclamped float→int `as` cast. Saturation silently maps
//! NaN to 0 and infinity to MAX.

pub fn bucket(x: f64) -> usize {
    (x * 10.0).floor() as usize
}
