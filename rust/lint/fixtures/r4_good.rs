//! R4 clean: the value is bounded before (or immediately after) the cast.

pub fn bucket(x: f64) -> usize {
    (x * 10.0).floor().clamp(0.0, 100.0) as usize
}

pub fn bucket_after(x: f64) -> u64 {
    ((x * 10.0).floor() as u64).min(100)
}

pub fn int_cast_untouched(n: u64) -> u32 {
    (n / 2) as u32
}
