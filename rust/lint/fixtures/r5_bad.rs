//! R5 violations: float accumulation in functions that collect thread
//! results — the reduction order follows nondeterministic completion
//! order, and float addition is not associative.

use std::sync::mpsc::Receiver;

pub fn merge(rx: &Receiver<f64>, n: usize) -> f64 {
    let mut total = 0.0f64;
    for _ in 0..n {
        total += rx.recv().unwrap();
    }
    total
}

pub fn drain(rx: &Receiver<f64>) -> f64 {
    rx.try_iter().sum::<f64>()
}
