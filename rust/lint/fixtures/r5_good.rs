//! R5 clean: results land in site-index slots first, so the final
//! reduction runs in a fixed order regardless of completion order.

use std::sync::mpsc::Receiver;

pub fn merge(rx: &Receiver<(usize, f64)>, n: usize) -> f64 {
    let mut slots = vec![0.0f64; n];
    for _ in 0..n {
        let (site, value) = rx.recv().unwrap();
        slots[site] = value;
    }
    slots.iter().sum()
}
