//! A reason-less allow is itself an error (SUP) and suppresses nothing:
//! the R3 finding below stays unsuppressed.

use std::time::Instant;

pub fn broken() -> u128 {
    // frost-lint: allow(R3)
    let t0 = Instant::now();
    t0.elapsed().as_nanos()
}
