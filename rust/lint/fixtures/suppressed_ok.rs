//! Valid suppressions: standalone form covers the next code line,
//! trailing form covers its own line. Reasons are mandatory and surface
//! in the report.

use std::time::Instant;

pub fn wall_elapsed() -> u128 {
    // frost-lint: allow(R3, reason = "benchmark harness measures real wall time")
    let t0 = Instant::now();
    t0.elapsed().as_nanos()
}

pub fn wall_elapsed_trailing() -> u128 {
    let t0 = Instant::now(); // frost-lint: allow(R3, reason = "real time is the point here")
    t0.elapsed().as_nanos()
}
