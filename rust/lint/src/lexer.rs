//! A hand-rolled Rust lexer, just deep enough for lint rules.
//!
//! Produces a flat token stream with line numbers plus the comment list
//! (suppression directives live in comments).  String/char/lifetime
//! disambiguation and nested block comments are handled; the token
//! *content* of string literals is deliberately dropped so that a rule
//! like "no `partial_cmp`" can never fire on prose or test data.
//!
//! Not handled (documented misses, all conservative): raw identifiers
//! (`r#fn`) lex as `r # fn`, and float evidence does not flow through
//! turbofish walls (`to_vec::<f32>()`).  Neither occurs in this tree.

/// One lexed token.  Literal payloads are dropped — rules only ever need
/// the *kind* (and, for identifiers, the spelling).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    /// Integer literal (including hex/octal/binary).
    Int,
    /// Float literal: has a fractional part, an exponent, or an `f32`/
    /// `f64` suffix.
    Float,
    /// String literal (normal, raw, or byte; content dropped).
    Str,
    /// Character or byte literal.
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Life,
    /// Any other single character (operators, delimiters, …).
    Punct(char),
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    /// 1-based line of the token's first character.
    pub line: u32,
}

#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment *starts* on.
    pub line: u32,
    /// Full comment text including the `//` / `/*` introducer.
    pub text: String,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Scan a quoted literal. `b[start]` must be the opening quote; returns
/// the index just past the closing quote (or the end of input), counting
/// newlines into `line`.
fn scan_quoted(b: &[char], start: usize, quote: char, line: &mut u32) -> usize {
    let mut j = start + 1;
    while j < b.len() {
        match b[j] {
            '\\' => j += 2,
            '\n' => {
                *line += 1;
                j += 1;
            }
            c if c == quote => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Lex `src` into tokens + comments.  Never fails: unrecognised bytes
/// become `Punct`s and unterminated literals run to end-of-input.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // ----------------------------------------------------- comments
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment { line, text: b[start..i].iter().collect() });
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start_line = line;
            let start = i;
            i += 2;
            let mut depth = 1u32;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment {
                line: start_line,
                text: b[start..i.min(n)].iter().collect(),
            });
            continue;
        }

        // ------------------------------------- string / char literals
        if c == '"' {
            let tok_line = line;
            i = scan_quoted(&b, i, '"', &mut line);
            out.tokens.push(Token { tok: Tok::Str, line: tok_line });
            continue;
        }
        if c == 'b' && i + 1 < n && b[i + 1] == '"' {
            let tok_line = line;
            i = scan_quoted(&b, i + 1, '"', &mut line);
            out.tokens.push(Token { tok: Tok::Str, line: tok_line });
            continue;
        }
        if c == 'b' && i + 1 < n && b[i + 1] == '\'' {
            let tok_line = line;
            i = scan_quoted(&b, i + 1, '\'', &mut line);
            out.tokens.push(Token { tok: Tok::Char, line: tok_line });
            continue;
        }
        // Raw strings: r"…", r#"…"#, br"…", br##"…"##.
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                let tok_line = line;
                j += 1;
                'raw: while j < n {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if b[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                i = j;
                out.tokens.push(Token { tok: Tok::Str, line: tok_line });
                continue;
            }
            // Not a raw string after all — fall through to the identifier
            // path below (`r` / `b` are ordinary ident starts).
        }
        if c == '\'' {
            let tok_line = line;
            let is_char = match b.get(i + 1) {
                Some('\\') => true,
                Some(_) => b.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                i = scan_quoted(&b, i, '\'', &mut line);
                out.tokens.push(Token { tok: Tok::Char, line: tok_line });
            } else {
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.tokens.push(Token { tok: Tok::Life, line: tok_line });
            }
            continue;
        }

        // ------------------------------------------------------ numbers
        if c.is_ascii_digit() {
            let tok_line = line;
            let mut j = i + 1;
            let mut float = false;
            if c == '0' && j < n && matches!(b[j], 'x' | 'o' | 'b') {
                j += 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
            } else {
                while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                    j += 1;
                }
                if j + 1 < n && b[j] == '.' && b[j + 1].is_ascii_digit() {
                    float = true;
                    j += 1;
                    while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                        j += 1;
                    }
                }
                if j < n && matches!(b[j], 'e' | 'E') {
                    let k = if j + 1 < n && matches!(b[j + 1], '+' | '-') { j + 2 } else { j + 1 };
                    if k < n && b[k].is_ascii_digit() {
                        float = true;
                        j = k + 1;
                        while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                            j += 1;
                        }
                    }
                }
                let sfx_start = j;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                let sfx: String = b[sfx_start..j].iter().collect();
                if sfx.contains("f32") || sfx.contains("f64") {
                    float = true;
                }
            }
            out.tokens.push(Token {
                tok: if float { Tok::Float } else { Tok::Int },
                line: tok_line,
            });
            i = j;
            continue;
        }

        // --------------------------------------------------- identifiers
        if c.is_alphabetic() || c == '_' {
            let tok_line = line;
            let mut j = i + 1;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            out.tokens.push(Token {
                tok: Tok::Ident(b[i..j].iter().collect()),
                line: tok_line,
            });
            i = j;
            continue;
        }

        // -------------------------------------------------- punctuation
        out.tokens.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_their_content() {
        let src = r##"
            // partial_cmp in a line comment
            /* HashMap in a /* nested */ block comment */
            let s = "Instant::now() inside a string";
            let r = r#"thread_rng "quoted" raw"#;
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|s| s == "partial_cmp"));
        assert!(!ids.iter().any(|s| s == "HashMap"));
        assert!(!ids.iter().any(|s| s == "Instant"));
        assert!(!ids.iter().any(|s| s == "thread_rng"));
        assert_eq!(lex(src).comments.len(), 2);
    }

    #[test]
    fn float_vs_int_literals() {
        let kinds: Vec<Tok> = lex("1 1.5 1e3 0x1F 1_000 2.0f64 7f32 3u64")
            .tokens
            .into_iter()
            .map(|t| t.tok)
            .collect();
        assert_eq!(
            kinds,
            vec![
                Tok::Int,
                Tok::Float,
                Tok::Float,
                Tok::Int,
                Tok::Int,
                Tok::Float,
                Tok::Float,
                Tok::Int
            ]
        );
    }

    #[test]
    fn range_dots_do_not_make_floats() {
        let kinds: Vec<Tok> = lex("0..24").tokens.into_iter().map(|t| t.tok).collect();
        assert_eq!(kinds, vec![Tok::Int, Tok::Punct('.'), Tok::Punct('.'), Tok::Int]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let kinds: Vec<Tok> = lex("'a 'x' '\\n' 'static b'z'")
            .tokens
            .into_iter()
            .map(|t| t.tok)
            .collect();
        assert_eq!(kinds, vec![Tok::Life, Tok::Char, Tok::Char, Tok::Life, Tok::Char]);
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "let a = 1;\nlet b = \"two\nlines\";\nlet c = 3;";
        let lx = lex(src);
        let c_tok = lx
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("c".into()))
            .unwrap();
        assert_eq!(c_tok.line, 4, "the two-line string literal spans lines 2-3");
    }
}
