//! frost-lint — determinism & NaN-safety static analysis for the FROST
//! tree (DESIGN.md §12).
//!
//! The library walks a set of roots, lexes every `.rs` file with the
//! in-crate lexer, and applies the R1–R5 invariant rules.  Everything is
//! deterministic: the directory walk is sorted, findings are sorted, and
//! the JSON summary is emitted with stable key order.

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{lint_source, FileLint, Finding, RULE_IDS};

/// The tree slices the invariants govern, relative to the repo root.
pub const DEFAULT_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// `(file, line, rules)` for well-formed allows that matched nothing.
    pub unused_allows: Vec<(String, u32, String)>,
    pub files_scanned: usize,
}

impl Report {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    pub fn suppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_some())
    }

    /// Machine-readable summary (hand-rolled JSON; the crate is std-only).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str(&format!("  \"unsuppressed\": {},\n", self.unsuppressed().count()));
        s.push_str(&format!("  \"suppressed\": {},\n", self.suppressed().count()));

        s.push_str("  \"by_rule\": {");
        let mut first = true;
        for rule in RULE_IDS.iter().chain(std::iter::once(&"SUP")) {
            let n = self.unsuppressed().filter(|f| f.rule == *rule).count();
            if !first {
                s.push_str(", ");
            }
            first = false;
            s.push_str(&format!("\"{rule}\": {n}"));
        }
        s.push_str("},\n");

        s.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str("\n    {");
            s.push_str(&format!("\"rule\": \"{}\", ", json_escape(&f.rule)));
            s.push_str(&format!("\"file\": \"{}\", ", json_escape(&f.file)));
            s.push_str(&format!("\"line\": {}, ", f.line));
            s.push_str(&format!("\"message\": \"{}\", ", json_escape(&f.message)));
            match &f.suppressed {
                Some(r) => s.push_str(&format!(
                    "\"suppressed\": true, \"reason\": \"{}\"",
                    json_escape(r)
                )),
                None => s.push_str("\"suppressed\": false"),
            }
            s.push('}');
        }
        if !self.findings.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("],\n");

        s.push_str("  \"unused_allows\": [");
        for (i, (file, line, rules)) in self.unused_allows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rules\": \"{}\"}}",
                json_escape(file),
                line,
                json_escape(rules)
            ));
        }
        if !self.unused_allows.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}\n");
        s
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Sorted recursive collection of `.rs` files under `dir`.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan `roots` (relative to `repo_root`; missing roots are skipped so the
/// binary works from partial checkouts) and return the merged report.
pub fn scan_roots(repo_root: &Path, roots: &[&str]) -> io::Result<Report> {
    let mut report = Report::default();
    for root in roots {
        let dir = repo_root.join(root);
        if !dir.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&dir, &mut files)?;
        for path in files {
            let src = fs::read_to_string(&path)?;
            let rel = path
                .strip_prefix(repo_root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let fl = lint_source(&rel, &src);
            report.findings.extend(fl.findings);
            report
                .unused_allows
                .extend(fl.unused_allows.into_iter().map(|(l, r)| (rel.clone(), l, r)));
            report.files_scanned += 1;
        }
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    report.unused_allows.sort();
    Ok(report)
}
