//! frost-lint CLI.
//!
//! ```text
//! frost-lint [--deny-all] [--json PATH|-] [--root DIR] [ROOTS...]
//! ```
//!
//! * `--deny-all`  exit non-zero if any unsuppressed finding remains
//!   (including broken suppression directives).  This is the CI mode.
//! * `--json P`    write the machine-readable summary to `P` (`-` for
//!   stdout).
//! * `--root DIR`  repo root; defaults to two levels above this crate's
//!   manifest (`rust/lint` → repo).
//! * `ROOTS...`    scan roots relative to the repo root; default
//!   `rust/src rust/tests rust/benches examples`.

use std::path::PathBuf;
use std::process::ExitCode;

use frost_lint::{scan_roots, DEFAULT_ROOTS};

fn usage() -> ! {
    eprintln!("usage: frost-lint [--deny-all] [--json PATH|-] [--root DIR] [ROOTS...]");
    std::process::exit(2);
}

fn main() -> ExitCode {
    let mut deny_all = false;
    let mut json_to: Option<String> = None;
    let mut repo_root: Option<PathBuf> = None;
    let mut roots: Vec<String> = Vec::new();

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--deny-all" => deny_all = true,
            "--json" => json_to = Some(args.next().unwrap_or_else(|| usage())),
            "--root" => repo_root = Some(PathBuf::from(args.next().unwrap_or_else(|| usage()))),
            "--help" | "-h" => usage(),
            flag if flag.starts_with('-') => usage(),
            root => roots.push(root.to_string()),
        }
    }

    let repo_root = repo_root.unwrap_or_else(|| {
        // rust/lint/Cargo.toml → repo root is ../.. from the manifest.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
    });
    let roots: Vec<&str> = if roots.is_empty() {
        DEFAULT_ROOTS.to_vec()
    } else {
        roots.iter().map(|s| s.as_str()).collect()
    };

    let report = match scan_roots(&repo_root, &roots) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("frost-lint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    let unsuppressed: Vec<_> = report.unsuppressed().collect();
    for f in &unsuppressed {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    for f in report.suppressed() {
        println!(
            "{}:{}: [{}] suppressed — {}",
            f.file,
            f.line,
            f.rule,
            f.suppressed.as_deref().unwrap_or("")
        );
    }
    for (file, line, rules) in &report.unused_allows {
        println!("{file}:{line}: warning: unused allow({rules})");
    }
    println!(
        "frost-lint: {} files scanned, {} unsuppressed finding(s), {} suppressed",
        report.files_scanned,
        unsuppressed.len(),
        report.suppressed().count()
    );

    if let Some(dest) = json_to {
        let json = report.to_json();
        if dest == "-" {
            print!("{json}");
        } else if let Err(e) = std::fs::write(&dest, json) {
            eprintln!("frost-lint: cannot write {dest}: {e}");
            return ExitCode::from(2);
        }
    }

    if deny_all && !unsuppressed.is_empty() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
