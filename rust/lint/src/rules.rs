//! The invariant catalogue (DESIGN.md §12) as token-tree rules.
//!
//! Every FROST result rests on bit-identical simulation output across
//! seeds and worker-thread counts.  These rules mechanically enforce the
//! hazards that have actually bitten this tree:
//!
//! * **R1** — float ordering via `partial_cmp` (NaN panics / `None`
//!   surprises in sort-or-min-max); require `total_cmp`.
//! * **R2** — `HashMap`/`HashSet` in simulation/merge/report paths
//!   (`src/`): hash iteration order is nondeterministic across runs;
//!   require `BTreeMap`/`BTreeSet` or an explicit sort.  `use`
//!   declarations are exempt (the *usage* sites are what matter).
//! * **R3** — wall-clock (`Instant::now` / `SystemTime::now`) or
//!   unseeded randomness (`thread_rng`, `OsRng`, …) inside simulation
//!   logic; real-hardware paths carry reasoned suppressions.
//! * **R4** — `as` casts from float expressions to integer widths with
//!   no clamp in sight: the cast saturates (NaN → 0) and silently
//!   launders non-finite values into plausible integers.
//! * **R5** — float accumulation inside a function that collects
//!   thread results (`recv`/`try_iter`/zero-arg `join`): completion
//!   order is nondeterministic and float addition is not associative;
//!   merge through the site-index-ordered helpers instead.
//!
//! Each rule supports a scoped suppression:
//! `// frost-lint: allow(R3, reason = "...")` — the reason is mandatory
//! and surfaced in the report.  A trailing comment covers its own line;
//! a standalone comment covers the next line holding code.

use crate::lexer::{lex, Lexed, Tok, Token};

pub const RULE_IDS: [&str; 5] = ["R1", "R2", "R3", "R4", "R5"];

/// Integer target widths for R4.
const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Method names that mark an expression as float-valued (R4/R5 evidence).
const FLOAT_METHODS: [&str; 16] = [
    "ceil",
    "floor",
    "round",
    "trunc",
    "fract",
    "sqrt",
    "cbrt",
    "powf",
    "powi",
    "exp",
    "exp2",
    "ln",
    "log2",
    "log10",
    "to_radians",
    "to_degrees",
];

/// Identifiers that count as bounding the value before/after a cast.
const CLAMP_METHODS: [&str; 3] = ["clamp", "min", "max"];

/// Unseeded randomness identifiers (R3).
const RANDOM_IDENTS: [&str; 5] =
    ["thread_rng", "from_entropy", "OsRng", "getrandom", "RandomState"];

/// Channel/thread collection markers (R5).
const THREAD_MARKERS: [&str; 3] = ["recv", "try_recv", "try_iter"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// `"R1"`…`"R5"`, or `"SUP"` for a broken suppression directive
    /// (which can itself never be suppressed).
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// `Some(reason)` when covered by a `frost-lint: allow(...)`.
    pub suppressed: Option<String>,
}

#[derive(Debug, Default)]
pub struct FileLint {
    pub findings: Vec<Finding>,
    /// `(line, rule-list)` of well-formed allows that matched nothing.
    pub unused_allows: Vec<(u32, String)>,
}

fn ident_at<'a>(t: &'a [Token], i: usize) -> Option<&'a str> {
    match t.get(i).map(|x| &x.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(t: &[Token], i: usize, c: char) -> bool {
    matches!(t.get(i).map(|x| &x.tok), Some(Tok::Punct(p)) if *p == c)
}

fn finding(rule: &str, file: &str, line: u32, message: impl Into<String>) -> Finding {
    Finding {
        rule: rule.to_string(),
        file: file.to_string(),
        line,
        message: message.into(),
        suppressed: None,
    }
}

/// R2 applies to simulation/merge/report paths: everything under a
/// `src/` directory.  Tests, benches and examples may use hash
/// collections freely (they never feed merged simulation output).
fn in_sim_scope(path: &str) -> bool {
    path.contains("src/")
}

fn rule_r1(lx: &Lexed, path: &str, out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    for i in 1..t.len() {
        if ident_at(t, i) == Some("partial_cmp")
            && (punct_at(t, i - 1, '.') || punct_at(t, i - 1, ':'))
        {
            out.push(finding(
                "R1",
                path,
                t[i].line,
                "float ordering via `partial_cmp` — use `total_cmp` (total over NaN, panic-free)",
            ));
        }
    }
}

fn rule_r2(lx: &Lexed, path: &str, out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    let mut in_use = false;
    for i in 0..t.len() {
        match &t[i].tok {
            Tok::Punct(';') => in_use = false,
            Tok::Ident(s) if s == "use" => in_use = true,
            Tok::Ident(s) if !in_use && (s == "HashMap" || s == "HashSet") => {
                out.push(finding(
                    "R2",
                    path,
                    t[i].line,
                    format!(
                        "`{s}` in a simulation/merge/report path — hash iteration order is \
                         nondeterministic; use `BTreeMap`/`BTreeSet` or sort before iterating"
                    ),
                ));
            }
            _ => {}
        }
    }
}

fn rule_r3(lx: &Lexed, path: &str, out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    for i in 0..t.len() {
        let Some(s) = ident_at(t, i) else { continue };
        if (s == "Instant" || s == "SystemTime")
            && punct_at(t, i + 1, ':')
            && punct_at(t, i + 2, ':')
            && ident_at(t, i + 3) == Some("now")
        {
            out.push(finding(
                "R3",
                path,
                t[i].line,
                format!(
                    "wall-clock `{s}::now` in simulation logic — inject a seeded `Clock`, or \
                     suppress with a reason where real time is the point"
                ),
            ));
        }
        if RANDOM_IDENTS.contains(&s) {
            out.push(finding(
                "R3",
                path,
                t[i].line,
                format!("unseeded randomness (`{s}`) — derive all randomness from the run seed"),
            ));
        }
    }
}

/// Walk backwards from the `as` token over one postfix expression
/// (identifiers, literals, `.`/`?`/`::` chains, balanced `()`/`[]`
/// groups).  Returns the window start index.
fn cast_head_start(t: &[Token], as_idx: usize) -> usize {
    let mut j = as_idx;
    while j > 0 {
        let k = j - 1;
        match &t[k].tok {
            Tok::Punct(')') => j = match_open(t, k, '(', ')'),
            Tok::Punct(']') => j = match_open(t, k, '[', ']'),
            Tok::Ident(_)
            | Tok::Int
            | Tok::Float
            | Tok::Str
            | Tok::Punct('.')
            | Tok::Punct('?')
            | Tok::Punct(':') => j = k,
            _ => break,
        }
    }
    j
}

/// Index of the `open` delimiter matching the `close` delimiter at
/// `close_idx` (0 if unbalanced).
fn match_open(t: &[Token], close_idx: usize, open: char, close: char) -> usize {
    let mut depth = 0i32;
    let mut m = close_idx;
    loop {
        match &t[m].tok {
            Tok::Punct(c) if *c == close => depth += 1,
            Tok::Punct(c) if *c == open => {
                depth -= 1;
                if depth == 0 {
                    return m;
                }
            }
            _ => {}
        }
        if m == 0 {
            return 0;
        }
        m -= 1;
    }
}

/// Float evidence in a token window: a float literal, an `f64`/`f32`
/// spelling, or a `.float_method(` chain.
fn float_evidence(w: &[Token]) -> bool {
    for (k, tok) in w.iter().enumerate() {
        match &tok.tok {
            Tok::Float => return true,
            Tok::Ident(s) if s == "f64" || s == "f32" => return true,
            Tok::Ident(s) if FLOAT_METHODS.contains(&s.as_str()) => {
                if k > 0 && matches!(w[k - 1].tok, Tok::Punct('.')) {
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

fn clamp_evidence(w: &[Token]) -> bool {
    w.iter().any(|tok| matches!(&tok.tok, Tok::Ident(s) if CLAMP_METHODS.contains(&s.as_str())))
}

fn rule_r4(lx: &Lexed, path: &str, out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    for i in 0..t.len() {
        if ident_at(t, i) != Some("as") {
            continue;
        }
        let Some(ty) = ident_at(t, i + 1) else { continue };
        if !INT_TYPES.contains(&ty) {
            continue;
        }
        let start = cast_head_start(t, i);
        let window = &t[start..i];
        if !float_evidence(window) {
            continue;
        }
        let mut clamped = clamp_evidence(window);
        // `(… as u64).clamp(…)` — a bound chained onto the cast counts.
        if !clamped {
            let mut k = i + 2;
            while punct_at(t, k, ')') {
                k += 1;
            }
            if punct_at(t, k, '.') {
                if let Some(m) = ident_at(t, k + 1) {
                    clamped = CLAMP_METHODS.contains(&m);
                }
            }
        }
        if !clamped {
            out.push(finding(
                "R4",
                path,
                t[i].line,
                format!(
                    "float→`{ty}` `as` cast without a clamp — saturation maps NaN to 0 and ∞ to \
                     MAX silently; bound the value first (`.clamp(lo, hi)` / `.max(0.0)`)"
                ),
            ));
        }
    }
}

/// Token span of one `fn` body (indices of `{` … `}`).
struct FnSpan {
    start: usize,
    end: usize,
}

fn fn_spans(t: &[Token]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for i in 0..t.len() {
        if ident_at(t, i) != Some("fn") {
            continue;
        }
        // Named functions only: `fn(f64) -> f64` pointer types have `(`
        // right after the keyword and carry no body.
        if !matches!(t.get(i + 1).map(|x| &x.tok), Some(Tok::Ident(_))) {
            continue;
        }
        // Find the body's `{` (or `;` for a bodiless trait method).
        let mut depth = 0i32;
        let mut j = i + 2;
        let mut open = None;
        while j < t.len() {
            match &t[j].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => {
                    open = Some(j);
                    break;
                }
                Tok::Punct(';') if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(o) = open else { continue };
        let mut braces = 0i32;
        let mut e = o;
        while e < t.len() {
            match &t[e].tok {
                Tok::Punct('{') => braces += 1,
                Tok::Punct('}') => {
                    braces -= 1;
                    if braces == 0 {
                        break;
                    }
                }
                _ => {}
            }
            e += 1;
        }
        spans.push(FnSpan { start: o, end: e.min(t.len().saturating_sub(1)) });
    }
    spans
}

/// The innermost function span containing token `idx`.
fn enclosing_fn(spans: &[FnSpan], idx: usize) -> Option<usize> {
    spans
        .iter()
        .enumerate()
        .filter(|(_, s)| s.start <= idx && idx <= s.end)
        .min_by_key(|(_, s)| s.end - s.start)
        .map(|(k, _)| k)
}

fn rule_r5(lx: &Lexed, path: &str, out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    let spans = fn_spans(t);
    if spans.is_empty() {
        return;
    }

    // Which functions collect thread results?
    let mut has_marker = vec![false; spans.len()];
    for i in 1..t.len() {
        let Some(s) = ident_at(t, i) else { continue };
        if !(punct_at(t, i - 1, '.') && punct_at(t, i + 1, '(')) {
            continue;
        }
        let marked = THREAD_MARKERS.contains(&s) || (s == "join" && punct_at(t, i + 2, ')'));
        if marked {
            if let Some(f) = enclosing_fn(&spans, i) {
                has_marker[f] = true;
            }
        }
    }
    if !has_marker.iter().any(|&m| m) {
        return;
    }

    let report = |out: &mut Vec<Finding>, line: u32| {
        out.push(finding(
            "R5",
            path,
            line,
            "float accumulation in a function that collects thread results — completion order \
             is nondeterministic and float addition is not associative; merge in site-index \
             order via the ordered merge helpers",
        ));
    };

    for i in 0..t.len() {
        let Some(f) = enclosing_fn(&spans, i) else { continue };
        if !has_marker[f] {
            continue;
        }
        // `.sum::<f64>()` / `.product::<f32>()`.
        if let Some(s) = ident_at(t, i) {
            if (s == "sum" || s == "product")
                && punct_at(t, i + 1, ':')
                && punct_at(t, i + 2, ':')
                && punct_at(t, i + 3, '<')
                && matches!(ident_at(t, i + 4), Some("f64") | Some("f32"))
            {
                report(out, t[i].line);
                continue;
            }
        }
        // `lhs += rhs` with float evidence in the statement or a
        // float-typed declaration of the accumulator root.
        if punct_at(t, i, '+') && punct_at(t, i + 1, '=') {
            let mut s = i;
            while s > 0 {
                if matches!(t[s - 1].tok, Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}')) {
                    break;
                }
                s -= 1;
            }
            let mut e = i;
            while e < t.len() && !matches!(t[e].tok, Tok::Punct(';')) {
                e += 1;
            }
            let mut is_float = float_evidence(&t[s..e]);
            if !is_float {
                // Does the accumulator's root identifier have a float
                // declaration in this function (`x: f64` / `x = 0.0`)?
                let root = cast_head_start(t, i);
                if let Some(name) = ident_at(t, root) {
                    let span = &spans[f];
                    for k in span.start..span.end.min(t.len().saturating_sub(2)) {
                        if ident_at(t, k) == Some(name)
                            && ((punct_at(t, k + 1, ':')
                                && matches!(ident_at(t, k + 2), Some("f64") | Some("f32")))
                                || (punct_at(t, k + 1, '=')
                                    && matches!(t.get(k + 2).map(|x| &x.tok), Some(Tok::Float))))
                        {
                            is_float = true;
                            break;
                        }
                    }
                }
            }
            if is_float {
                report(out, t[i].line);
            }
        }
    }
}

// ------------------------------------------------------------------ allows

struct Allow {
    line: u32,
    rules: Vec<String>,
    reason: String,
    /// The single source line this allow covers.
    target: u32,
    used: bool,
}

/// Parse one comment for a `frost-lint:` directive.
///
/// Returns `None` for ordinary comments, `Some(Err(msg))` for a directive
/// that is malformed (unknown rule, missing reason, bad syntax — all of
/// which become unsuppressible `SUP` findings), and
/// `Some(Ok((rules, reason)))` for a valid allow.
fn parse_directive(text: &str) -> Option<Result<(Vec<String>, String), String>> {
    let at = text.find("frost-lint:")?;
    let rest = text[at + "frost-lint:".len()..].trim();
    let Some(args) = rest.strip_prefix("allow") else {
        return Some(Err(
            "unknown frost-lint directive (expected `allow(R…, reason = \"…\")`)".to_string(),
        ));
    };
    let args = args.trim_start();
    let inner = match args.strip_prefix('(') {
        Some(a) => match a.rfind(')') {
            Some(p) => &a[..p],
            None => return Some(Err("unclosed `allow(`".to_string())),
        },
        None => return Some(Err("expected `(` after `allow`".to_string())),
    };
    let (rules_part, reason) = match inner.find("reason") {
        Some(rp) => {
            let tail = inner[rp + "reason".len()..].trim_start();
            let Some(tail) = tail.strip_prefix('=') else {
                return Some(Err("expected `=` after `reason`".to_string()));
            };
            let tail = tail.trim_start();
            let Some(tail) = tail.strip_prefix('"') else {
                return Some(Err("expected a quoted string after `reason =`".to_string()));
            };
            let Some(endq) = tail.find('"') else {
                return Some(Err("unclosed reason string".to_string()));
            };
            (&inner[..rp], tail[..endq].trim().to_string())
        }
        None => return Some(Err("missing mandatory `reason = \"…\"` in allow".to_string())),
    };
    if reason.is_empty() {
        return Some(Err("suppression reason must not be empty".to_string()));
    }
    let mut rules = Vec::new();
    for item in rules_part.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        if !RULE_IDS.contains(&item) {
            return Some(Err(format!("unknown rule id `{item}` in allow")));
        }
        rules.push(item.to_string());
    }
    if rules.is_empty() {
        return Some(Err("allow lists no rules".to_string()));
    }
    Some(Ok((rules, reason)))
}

/// Lint one source file.  `rel_path` is repo-relative and only used for
/// reporting and for R2's path scoping.
pub fn lint_source(rel_path: &str, src: &str) -> FileLint {
    let lx = lex(src);
    let path = rel_path.replace('\\', "/");
    let mut findings = Vec::new();

    rule_r1(&lx, &path, &mut findings);
    if in_sim_scope(&path) {
        rule_r2(&lx, &path, &mut findings);
    }
    rule_r3(&lx, &path, &mut findings);
    rule_r4(&lx, &path, &mut findings);
    rule_r5(&lx, &path, &mut findings);

    // Collect directives; broken ones are findings themselves.
    let mut allows: Vec<Allow> = Vec::new();
    for c in &lx.comments {
        match parse_directive(&c.text) {
            None => {}
            Some(Err(msg)) => findings.push(finding("SUP", &path, c.line, msg)),
            Some(Ok((rules, reason))) => {
                let trailing = lx.tokens.iter().any(|t| t.line == c.line);
                let target = if trailing {
                    c.line
                } else {
                    lx.tokens
                        .iter()
                        .map(|t| t.line)
                        .filter(|&l| l > c.line)
                        .min()
                        .unwrap_or(c.line)
                };
                allows.push(Allow { line: c.line, rules, reason, target, used: false });
            }
        }
    }

    for f in &mut findings {
        if f.rule == "SUP" {
            continue;
        }
        for a in allows.iter_mut() {
            if f.line == a.target && a.rules.iter().any(|r| r == &f.rule) {
                f.suppressed = Some(a.reason.clone());
                a.used = true;
                break;
            }
        }
    }

    let unused_allows = allows
        .iter()
        .filter(|a| !a.used)
        .map(|a| (a.line, a.rules.join(",")))
        .collect();

    findings.sort_by(|a, b| (a.line, a.rule.as_str()).cmp(&(b.line, b.rule.as_str())));
    FileLint { findings, unused_allows }
}
