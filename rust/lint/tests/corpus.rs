//! Fixture corpus for the R1–R5 rules plus the meta-test pinning the
//! real tree to zero unsuppressed findings.
//!
//! Fixtures are compiled into the test binary with `include_str!` and
//! linted under synthetic repo-relative paths so each case exercises the
//! intended scope (`rust/src/...` for simulation paths).

use frost_lint::{lint_source, scan_roots, Finding, DEFAULT_ROOTS};
use std::path::PathBuf;

fn unsuppressed(src: &str, rel_path: &str) -> Vec<Finding> {
    lint_source(rel_path, src)
        .findings
        .into_iter()
        .filter(|f| f.suppressed.is_none())
        .collect()
}

fn rules_of(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

const SIM_PATH: &str = "rust/src/simulator/fixture.rs";

// ------------------------------------------------------------------- R1

#[test]
fn r1_bad_partial_cmp_is_caught() {
    let f = unsuppressed(include_str!("../fixtures/r1_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["R1"], "{f:?}");
}

#[test]
fn r1_good_total_cmp_is_clean() {
    let f = unsuppressed(include_str!("../fixtures/r1_good.rs"), SIM_PATH);
    assert!(f.is_empty(), "comment/string prose must not fire R1: {f:?}");
}

// ------------------------------------------------------------------- R2

#[test]
fn r2_bad_hashmap_in_sim_path_is_caught() {
    let f = unsuppressed(include_str!("../fixtures/r2_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["R2", "R2"], "{f:?}");
}

#[test]
fn r2_scope_is_limited_to_src() {
    // The same source under tests/ is allowed: test-local hash maps never
    // feed merged simulation output.
    let f = unsuppressed(include_str!("../fixtures/r2_bad.rs"), "rust/tests/fixture.rs");
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn r2_good_btreemap_and_bare_use_are_clean() {
    let f = unsuppressed(include_str!("../fixtures/r2_good.rs"), SIM_PATH);
    assert!(f.is_empty(), "use-declarations must be exempt: {f:?}");
}

// ------------------------------------------------------------------- R3

#[test]
fn r3_bad_wall_clock_and_entropy_are_caught() {
    let f = unsuppressed(include_str!("../fixtures/r3_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["R3", "R3"], "{f:?}");
    assert!(f[0].message.contains("Instant::now"), "{f:?}");
    assert!(f[1].message.contains("thread_rng"), "{f:?}");
}

#[test]
fn r3_good_injected_time_and_seed_are_clean() {
    let f = unsuppressed(include_str!("../fixtures/r3_good.rs"), SIM_PATH);
    assert!(f.is_empty(), "{f:?}");
}

// ------------------------------------------------------------------- R4

#[test]
fn r4_bad_unclamped_float_cast_is_caught() {
    let f = unsuppressed(include_str!("../fixtures/r4_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["R4"], "{f:?}");
}

#[test]
fn r4_good_clamped_casts_are_clean() {
    // Clamp before the cast, bound chained after it, and a pure integer
    // cast — none may fire.
    let f = unsuppressed(include_str!("../fixtures/r4_good.rs"), SIM_PATH);
    assert!(f.is_empty(), "{f:?}");
}

// ------------------------------------------------------------------- R5

#[test]
fn r5_bad_thread_merge_accumulation_is_caught() {
    let f = unsuppressed(include_str!("../fixtures/r5_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["R5", "R5"], "{f:?}");
}

#[test]
fn r5_good_index_slot_merge_is_clean() {
    let f = unsuppressed(include_str!("../fixtures/r5_good.rs"), SIM_PATH);
    assert!(f.is_empty(), "{f:?}");
}

// ----------------------------------------------------------- suppressions

#[test]
fn suppression_standalone_and_trailing_forms_work() {
    let fl = lint_source(SIM_PATH, include_str!("../fixtures/suppressed_ok.rs"));
    let unsup: Vec<_> = fl.findings.iter().filter(|f| f.suppressed.is_none()).collect();
    assert!(unsup.is_empty(), "{unsup:?}");
    let sup: Vec<_> = fl.findings.iter().filter(|f| f.suppressed.is_some()).collect();
    assert_eq!(sup.len(), 2, "{:?}", fl.findings);
    assert_eq!(sup[0].suppressed.as_deref(), Some("benchmark harness measures real wall time"));
    assert_eq!(sup[1].suppressed.as_deref(), Some("real time is the point here"));
    assert!(fl.unused_allows.is_empty(), "{:?}", fl.unused_allows);
}

#[test]
fn reasonless_allow_is_an_error_and_suppresses_nothing() {
    let f = unsuppressed(include_str!("../fixtures/suppressed_bad.rs"), SIM_PATH);
    assert_eq!(rules_of(&f), vec!["SUP", "R3"], "{f:?}");
    assert!(f[0].message.contains("reason"), "{f:?}");
}

#[test]
fn unknown_rule_id_in_allow_is_an_error() {
    let src = "// frost-lint: allow(R9, reason = \"no such rule\")\nfn nothing() {}\n";
    let f = unsuppressed(src, SIM_PATH);
    assert_eq!(rules_of(&f), vec!["SUP"], "{f:?}");
    assert!(f[0].message.contains("R9"), "{f:?}");
}

#[test]
fn unused_allow_is_reported_as_warning_not_failure() {
    let src = "// frost-lint: allow(R1, reason = \"covers nothing\")\nfn clean() {}\n";
    let fl = lint_source(SIM_PATH, src);
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    assert_eq!(fl.unused_allows.len(), 1, "{:?}", fl.unused_allows);
    assert_eq!(fl.unused_allows[0].1, "R1");
}

#[test]
fn suppression_covers_only_its_own_line() {
    let src = "\
// frost-lint: allow(R3, reason = \"first use only\")
let a = Instant::now();
let b = Instant::now();
";
    let fl = lint_source(SIM_PATH, src);
    let unsup: Vec<_> = fl.findings.iter().filter(|f| f.suppressed.is_none()).collect();
    assert_eq!(unsup.len(), 1, "second site must stay flagged: {:?}", fl.findings);
    assert_eq!(unsup[0].line, 3);
}

// -------------------------------------------------------------- meta-test

/// The whole point: the real tree, scanned with the shipped defaults,
/// reports zero unsuppressed findings, every remaining suppression is
/// well-formed and load-bearing, and at least one reasoned suppression
/// exists (the rules actually see the tree).
#[test]
fn real_tree_passes_deny_all() {
    let repo_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let report = scan_roots(&repo_root, &DEFAULT_ROOTS).expect("scan repo");
    assert!(report.files_scanned > 50, "walk found too few files — wrong root?");

    let unsup: Vec<_> = report.unsuppressed().collect();
    assert!(
        unsup.is_empty(),
        "unsuppressed findings in the tree:\n{}",
        unsup
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.suppressed().count() > 0, "expected at least one reasoned allow in the tree");
    assert!(report.unused_allows.is_empty(), "stale allows: {:?}", report.unused_allows);
}

/// The §13 chaos surfaces are deterministic-by-construction and must stay
/// that way: the fault plan (per-message seeded RNG, no wall clock) and
/// the chaos integration tests are linted here *by name*, under their
/// real tree paths so the R2 scope applies exactly as in the full scan —
/// a regression that moves them out of `DEFAULT_ROOTS` is caught too.
#[test]
fn chaos_surfaces_are_covered_and_clean() {
    assert!(DEFAULT_ROOTS.contains(&"rust/src"), "fault plan must stay in a scanned root");
    assert!(DEFAULT_ROOTS.contains(&"rust/tests"), "chaos tests must stay in a scanned root");
    let f = unsuppressed(include_str!("../../src/oran/faults.rs"), "rust/src/oran/faults.rs");
    assert!(f.is_empty(), "oran/faults.rs must be R1–R5 clean: {f:?}");
    let f = unsuppressed(include_str!("../../tests/chaos.rs"), "rust/tests/chaos.rs");
    assert!(f.is_empty(), "tests/chaos.rs must be R1–R5 clean: {f:?}");
}

/// The §14 observability spine must itself obey the determinism rules it
/// exists to audit: the trace sink/metrics registry, the two streaming
/// serialisers and the query engine are linted *by name* under their real
/// tree paths (same rationale as the chaos surfaces above).
#[test]
fn obs_surfaces_are_covered_and_clean() {
    for (src, path) in [
        (include_str!("../../src/obs/mod.rs"), "rust/src/obs/mod.rs"),
        (include_str!("../../src/obs/export.rs"), "rust/src/obs/export.rs"),
        (include_str!("../../src/obs/query.rs"), "rust/src/obs/query.rs"),
        (include_str!("../../tests/trace.rs"), "rust/tests/trace.rs"),
    ] {
        let f = unsuppressed(src, path);
        assert!(f.is_empty(), "{path} must be R1–R5 clean: {f:?}");
    }
}

/// The §15 checkpoint subsystem is the layer that makes crashes
/// recoverable bit-exactly, so it must itself be deterministic: the
/// container reader/writer, the state codec, the whole-fleet snapshot
/// assembly, and the crash/resume battery are linted *by name* under
/// their real tree paths (same rationale as the chaos surfaces above).
#[test]
fn ckpt_surfaces_are_covered_and_clean() {
    for (src, path) in [
        (include_str!("../../src/ckpt/mod.rs"), "rust/src/ckpt/mod.rs"),
        (include_str!("../../src/ckpt/io.rs"), "rust/src/ckpt/io.rs"),
        (include_str!("../../src/ckpt/codec.rs"), "rust/src/ckpt/codec.rs"),
        (include_str!("../../src/ckpt/snapshot.rs"), "rust/src/ckpt/snapshot.rs"),
        (include_str!("../../tests/ckpt.rs"), "rust/tests/ckpt.rs"),
    ] {
        let f = unsuppressed(src, path);
        assert!(f.is_empty(), "{path} must be R1–R5 clean: {f:?}");
    }
}

/// The §16 region tier multiplies every determinism hazard by the region
/// count — gateway merge order, two-level water-fill, steady-delta
/// replay — so the split `fleet/` module, the shared two-level budget
/// audit, and the region integration battery are linted *by name* under
/// their real tree paths (same rationale as the chaos surfaces above).
#[test]
fn region_surfaces_are_covered_and_clean() {
    for (src, path) in [
        (include_str!("../../src/oran/fleet/mod.rs"), "rust/src/oran/fleet/mod.rs"),
        (include_str!("../../src/oran/fleet/region.rs"), "rust/src/oran/fleet/region.rs"),
        (
            include_str!("../../src/oran/fleet/coordinator.rs"),
            "rust/src/oran/fleet/coordinator.rs",
        ),
        (include_str!("../../src/oran/fleet/round.rs"), "rust/src/oran/fleet/round.rs"),
        (include_str!("../../src/oran/fleet/report.rs"), "rust/src/oran/fleet/report.rs"),
        (include_str!("../../src/figures/audit.rs"), "rust/src/figures/audit.rs"),
        (include_str!("../../tests/region.rs"), "rust/tests/region.rs"),
    ] {
        let f = unsuppressed(src, path);
        assert!(f.is_empty(), "{path} must be R1–R5 clean: {f:?}");
    }
}

#[test]
fn json_summary_is_well_formed_enough() {
    let repo_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let report = scan_roots(&repo_root, &DEFAULT_ROOTS).expect("scan repo");
    let json = report.to_json();
    assert!(json.contains("\"files_scanned\""));
    assert!(json.contains("\"by_rule\""));
    assert!(json.contains("\"unsuppressed\": 0"));
    assert_eq!(
        json.matches('{').count(),
        json.matches('}').count(),
        "unbalanced braces in JSON output"
    );
}
