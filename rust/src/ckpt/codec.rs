//! Bit-exact value codecs for snapshot sections (DESIGN.md §15).
//!
//! Writers take an open [`JsonStream`] (the incremental path — no
//! intermediate [`Json`] trees); readers take the lazily parsed [`Json`]
//! node of the value.  Conventions:
//!
//! * `u64` and `f64` cross the boundary as 16-char lowercase hex strings
//!   ([`hex_u64`] / [`hex_f64`] of the IEEE-754 bits).  JSON numbers are
//!   f64: they lose `u64` precision above 2⁵³, print `-0.0` as `0`, and
//!   the streaming writer nulls non-finite values — all three corrupt a
//!   bit-identity contract ([`f64::NEG_INFINITY`] legitimately occurs in
//!   the SMO's KPM watermarks).
//! * Structurally small integers (indices, rounds, versions, lengths)
//!   use exact decimal fields (`u64_field` / [`Json::as_i64`]).
//! * `Option<f64>` is hex-or-empty-string (`""` = `None`), so a `None`
//!   never collides with a serialised NaN.
//! * Optional strings/ids are present-or-absent fields.
//! * `&'static str` values restore through [`intern_static`]: a closed
//!   known-name table first, a leaked owned string as the fallback for
//!   forward compatibility.
//!
//! Every reader is total over corrupt input: malformed nodes produce an
//! error, never a panic or a half-decoded value.

use std::io::Write;

use anyhow::{Context, Result};

use crate::frost::edp::EdpCriterion;
use crate::frost::fit::{FitResult, ResponseModel};
use crate::frost::policy::{EnergyPolicy, QosClass};
use crate::frost::profiler::{ProfileOutcome, ProfilePoint};
use crate::metrics::{LatencyHistogram, StreamingSummary};
use crate::obs::export::JsonStream;
use crate::obs::{CapCause, TraceData, TraceEvent};
use crate::oran::catalogue::{CatalogueEntry, ModelState};
use crate::oran::faults::{FaultConfig, FaultLedger};
use crate::oran::messages::{KpmReport, LifecycleEvent, OranMessage};
use crate::oran::smo::ProfileRecord;
use crate::scenario::{Phase, Scenario, ScenarioEvent, TimedEvent};
use crate::simulator::WorkloadDescriptor;
use crate::telemetry::hub::PowerReading;
use crate::telemetry::sampler::{PowerSample, SamplerCkpt};
use crate::traffic::{
    ArrivalKind, DiurnalProfile, SloSpec, SlotReport, TrafficConfig, TrafficPath,
};
use crate::util::{Joules, Json, Pcg32, Seconds, Series, Watts};

// ------------------------------------------------------------ primitives

/// `u64` as 16 lowercase hex chars — exact for the full range.
pub fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

/// `f64` as the hex of its IEEE-754 bits — exact for every value
/// including `-0.0`, infinities and NaN payloads.
pub fn hex_f64(v: f64) -> String {
    hex_u64(v.to_bits())
}

pub fn parse_hex_u64(s: &str) -> Result<u64> {
    anyhow::ensure!(s.len() == 16, "bad hex64 literal '{s}' (length {})", s.len());
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex64 literal '{s}'"))
}

pub fn parse_hex_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(s)?))
}

/// Resolve a decoded string against a closed table of known
/// `&'static str` values; unknown names leak a boxed copy (bounded by
/// snapshot content, only reachable on forward-version data).
pub fn intern_static(s: &str, known: &[&'static str]) -> &'static str {
    for k in known {
        if *k == s {
            return *k;
        }
    }
    Box::leak(s.to_string().into_boxed_str())
}

// -------------------------------------------------------- field writers

pub fn w_u64<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, v: u64) {
    js.str_field(name, &hex_u64(v));
}

pub fn w_f64<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, v: f64) {
    js.str_field(name, &hex_f64(v));
}

/// `Option<f64>` as hex-or-empty-string.
pub fn w_opt_f64<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, v: Option<f64>) {
    match v {
        Some(x) => js.str_field(name, &hex_f64(x)),
        None => js.str_field(name, ""),
    }
}

/// `Option<u64>` as hex-or-empty-string.
pub fn w_opt_u64<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, v: Option<u64>) {
    match v {
        Some(x) => js.str_field(name, &hex_u64(x)),
        None => js.str_field(name, ""),
    }
}

// -------------------------------------------------------- field readers

fn field<'a>(j: &'a Json, name: &str) -> Result<&'a Json> {
    j.req(name)
}

/// Hex-encoded `u64` field.
pub fn ju64(j: &Json, name: &str) -> Result<u64> {
    vu64(field(j, name)?).with_context(|| format!("field '{name}'"))
}

/// Hex-encoded `f64` field.
pub fn jf64(j: &Json, name: &str) -> Result<f64> {
    vf64(field(j, name)?).with_context(|| format!("field '{name}'"))
}

/// Hex-or-empty `Option<f64>` field.
pub fn jopt_f64(j: &Json, name: &str) -> Result<Option<f64>> {
    let s = jstr(j, name)?;
    if s.is_empty() {
        Ok(None)
    } else {
        Ok(Some(parse_hex_f64(s).with_context(|| format!("field '{name}'"))?))
    }
}

/// Hex-or-empty `Option<u64>` field.
pub fn jopt_u64(j: &Json, name: &str) -> Result<Option<u64>> {
    let s = jstr(j, name)?;
    if s.is_empty() {
        Ok(None)
    } else {
        Ok(Some(parse_hex_u64(s).with_context(|| format!("field '{name}'"))?))
    }
}

/// Exact decimal integer field (bounded values only).
pub fn ji64(j: &Json, name: &str) -> Result<i64> {
    field(j, name)?
        .as_i64()
        .with_context(|| format!("field '{name}' is not an exact integer"))
}

pub fn ju32(j: &Json, name: &str) -> Result<u32> {
    u32::try_from(ji64(j, name)?)
        .ok()
        .with_context(|| format!("field '{name}' out of u32 range"))
}

pub fn jusize(j: &Json, name: &str) -> Result<usize> {
    field(j, name)?
        .as_usize()
        .with_context(|| format!("field '{name}' is not a usize"))
}

pub fn jstr<'a>(j: &'a Json, name: &str) -> Result<&'a str> {
    field(j, name)?
        .as_str()
        .with_context(|| format!("field '{name}' is not a string"))
}

pub fn jbool(j: &Json, name: &str) -> Result<bool> {
    field(j, name)?
        .as_bool()
        .with_context(|| format!("field '{name}' is not a bool"))
}

pub fn jarr<'a>(j: &'a Json, name: &str) -> Result<&'a [Json]> {
    field(j, name)?
        .as_arr()
        .with_context(|| format!("field '{name}' is not an array"))
}

/// Optional string field (present-or-absent encoding).
pub fn jopt_string(j: &Json, name: &str) -> Result<Option<String>> {
    match j.get(name) {
        Some(v) => Ok(Some(
            v.as_str()
                .with_context(|| format!("field '{name}' is not a string"))?
                .to_string(),
        )),
        None => Ok(None),
    }
}

/// Hex `u64` array element.
pub fn vu64(j: &Json) -> Result<u64> {
    parse_hex_u64(j.as_str().context("expected a hex64 string")?)
}

/// Hex `f64` array element.
pub fn vf64(j: &Json) -> Result<f64> {
    parse_hex_f64(j.as_str().context("expected a hex64 string")?)
}

// ------------------------------------------------------- leaf-type codecs

pub fn w_pcg32<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, rng: &Pcg32) {
    let (state, inc) = rng.state_parts();
    js.begin_obj(name);
    w_u64(js, Some("state"), state);
    w_u64(js, Some("inc"), inc);
    js.end_obj();
}

pub fn r_pcg32(j: &Json) -> Result<Pcg32> {
    Ok(Pcg32::from_parts(ju64(j, "state")?, ju64(j, "inc")?))
}

pub fn w_summary<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, s: &StreamingSummary) {
    let (n, mean, m2, min, max) = s.state_parts();
    js.begin_obj(name);
    w_u64(js, Some("n"), n);
    w_f64(js, Some("mean"), mean);
    w_f64(js, Some("m2"), m2);
    w_f64(js, Some("min"), min);
    w_f64(js, Some("max"), max);
    js.end_obj();
}

pub fn r_summary(j: &Json) -> Result<StreamingSummary> {
    Ok(StreamingSummary::from_parts(
        ju64(j, "n")?,
        jf64(j, "mean")?,
        jf64(j, "m2")?,
        jf64(j, "min")?,
        jf64(j, "max")?,
    ))
}

pub fn w_hist<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, h: &LatencyHistogram) {
    js.begin_obj(name);
    js.begin_arr(Some("bins"));
    for (i, n) in h.occupied_bins() {
        js.begin_arr(None);
        js.u64_field(None, i as u64);
        w_u64(js, None, n);
        js.end_arr();
    }
    js.end_arr();
    w_u64(js, Some("nf"), h.non_finite());
    js.end_obj();
}

pub fn r_hist(j: &Json) -> Result<LatencyHistogram> {
    let mut bins = Vec::new();
    for pair in jarr(j, "bins")? {
        let p = pair.as_arr().context("histogram bin pair is not an array")?;
        anyhow::ensure!(p.len() == 2, "histogram bin pair has {} elements", p.len());
        let i = p[0].as_usize().context("histogram bin index")?;
        let n = vu64(&p[1]).context("histogram bin count")?;
        bins.push((i, n));
    }
    LatencyHistogram::from_sparse_bins(bins, ju64(j, "nf")?)
        .context("histogram bin index out of range")
}

pub fn w_power_reading<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, r: &PowerReading) {
    js.begin_obj(name);
    w_f64(js, Some("at"), r.at.0);
    w_f64(js, Some("gpu"), r.gpu.0);
    w_f64(js, Some("cpu"), r.cpu.0);
    w_f64(js, Some("dram"), r.dram.0);
    w_f64(js, Some("gpu_util"), r.gpu_util);
    w_f64(js, Some("freq_mhz"), r.freq_mhz);
    js.end_obj();
}

pub fn r_power_reading(j: &Json) -> Result<PowerReading> {
    Ok(PowerReading {
        at: Seconds(jf64(j, "at")?),
        gpu: Watts(jf64(j, "gpu")?),
        cpu: Watts(jf64(j, "cpu")?),
        dram: Watts(jf64(j, "dram")?),
        gpu_util: jf64(j, "gpu_util")?,
        freq_mhz: jf64(j, "freq_mhz")?,
    })
}

pub fn w_power_sample<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, p: &PowerSample) {
    js.begin_obj(name);
    w_f64(js, Some("at"), p.at.0);
    w_f64(js, Some("gpu"), p.gpu.0);
    w_f64(js, Some("cpu"), p.cpu.0);
    w_f64(js, Some("dram"), p.dram.0);
    w_f64(js, Some("gpu_util"), p.gpu_util);
    js.end_obj();
}

pub fn r_power_sample(j: &Json) -> Result<PowerSample> {
    Ok(PowerSample {
        at: Seconds(jf64(j, "at")?),
        gpu: Watts(jf64(j, "gpu")?),
        cpu: Watts(jf64(j, "cpu")?),
        dram: Watts(jf64(j, "dram")?),
        gpu_util: jf64(j, "gpu_util")?,
    })
}

/// The whole [`crate::telemetry::sampler::PowerSampler`] mutable state,
/// nested NVML/RAPL counters included.
pub fn w_sampler<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, s: &SamplerCkpt) {
    js.begin_obj(name);
    let ((state, inc), limit_mw) = s.nvml;
    js.begin_obj(Some("nvml"));
    w_u64(js, Some("state"), state);
    w_u64(js, Some("inc"), inc);
    w_u64(js, Some("limit_mw"), limit_mw);
    js.end_obj();
    let (last_true_j, counter) = s.rapl_pkg;
    js.begin_obj(Some("rapl"));
    w_f64(js, Some("last_true_j"), last_true_j);
    js.u64_field(Some("counter"), u64::from(counter));
    js.end_obj();
    w_opt_f64(js, Some("next_due"), s.next_due.map(|t| t.0));
    if let Some((t, c)) = s.last_pkg {
        js.begin_obj(Some("last_pkg"));
        w_f64(js, Some("t"), t.0);
        js.u64_field(Some("c"), u64::from(c));
        js.end_obj();
    }
    js.begin_arr(Some("samples"));
    for p in &s.samples {
        w_power_sample(js, None, p);
    }
    js.end_arr();
    w_u64(js, Some("evicted"), s.evicted);
    w_summary(js, Some("gpu_w"), &s.gpu_w);
    w_summary(js, Some("total_w"), &s.total_w);
    js.end_obj();
}

pub fn r_sampler(j: &Json) -> Result<SamplerCkpt> {
    let nv = field(j, "nvml")?;
    let rapl = field(j, "rapl")?;
    let last_pkg = match j.get("last_pkg") {
        Some(lp) => Some((Seconds(jf64(lp, "t")?), ju32(lp, "c")?)),
        None => None,
    };
    let mut samples = Vec::new();
    for p in jarr(j, "samples")? {
        samples.push(r_power_sample(p)?);
    }
    Ok(SamplerCkpt {
        nvml: ((ju64(nv, "state")?, ju64(nv, "inc")?), ju64(nv, "limit_mw")?),
        rapl_pkg: (jf64(rapl, "last_true_j")?, ju32(rapl, "counter")?),
        next_due: jopt_f64(j, "next_due")?.map(Seconds),
        last_pkg,
        samples,
        evicted: ju64(j, "evicted")?,
        gpu_w: r_summary(field(j, "gpu_w")?)?,
        total_w: r_summary(field(j, "total_w")?)?,
    })
}

pub fn w_policy<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, p: &EnergyPolicy) {
    js.begin_obj(name);
    js.str_field(Some("id"), &p.id);
    js.str_field(Some("qos"), p.qos.as_str());
    w_f64(js, Some("min_cap_frac"), p.min_cap_frac);
    w_f64(js, Some("max_cap_frac"), p.max_cap_frac);
    js.bool_field(Some("enabled"), p.enabled);
    w_f64(js, Some("max_slowdown"), p.max_slowdown);
    js.u64_field(Some("lease_rounds"), u64::from(p.lease_rounds));
    js.end_obj();
}

pub fn r_policy(j: &Json) -> Result<EnergyPolicy> {
    let p = EnergyPolicy {
        id: jstr(j, "id")?.to_string(),
        qos: QosClass::parse(jstr(j, "qos")?)?,
        min_cap_frac: jf64(j, "min_cap_frac")?,
        max_cap_frac: jf64(j, "max_cap_frac")?,
        enabled: jbool(j, "enabled")?,
        max_slowdown: jf64(j, "max_slowdown")?,
        lease_rounds: ju32(j, "lease_rounds")?,
    };
    // Any live policy passed `put_policy` validation; re-validating here
    // rejects corrupt snapshots before they reach the fleet.
    p.validate()?;
    Ok(p)
}

pub fn w_workload<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, w: &WorkloadDescriptor) {
    js.begin_obj(name);
    js.str_field(Some("name"), &w.name);
    w_f64(js, Some("train_flops_per_sample"), w.train_flops_per_sample);
    w_f64(js, Some("infer_flops_per_sample"), w.infer_flops_per_sample);
    w_f64(js, Some("train_bytes_per_sample"), w.train_bytes_per_sample);
    w_f64(js, Some("infer_bytes_per_sample"), w.infer_bytes_per_sample);
    w_f64(js, Some("host_s_per_batch"), w.host_s_per_batch);
    w_f64(js, Some("kernel_efficiency"), w.kernel_efficiency);
    w_f64(js, Some("cpu_util"), w.cpu_util);
    w_u64(js, Some("params"), w.params);
    w_f64(js, Some("reference_accuracy"), w.reference_accuracy);
    js.end_obj();
}

pub fn r_workload(j: &Json) -> Result<WorkloadDescriptor> {
    Ok(WorkloadDescriptor {
        name: jstr(j, "name")?.to_string(),
        train_flops_per_sample: jf64(j, "train_flops_per_sample")?,
        infer_flops_per_sample: jf64(j, "infer_flops_per_sample")?,
        train_bytes_per_sample: jf64(j, "train_bytes_per_sample")?,
        infer_bytes_per_sample: jf64(j, "infer_bytes_per_sample")?,
        host_s_per_batch: jf64(j, "host_s_per_batch")?,
        kernel_efficiency: jf64(j, "kernel_efficiency")?,
        cpu_util: jf64(j, "cpu_util")?,
        params: ju64(j, "params")?,
        reference_accuracy: jf64(j, "reference_accuracy")?,
    })
}

pub fn w_kpm<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, k: &KpmReport) {
    js.begin_obj(name);
    js.str_field(Some("host"), &k.host);
    w_f64(js, Some("at"), k.at.0);
    if let Some(m) = &k.model {
        js.str_field(Some("model"), m);
    }
    w_f64(js, Some("gpu_power_w"), k.gpu_power_w);
    w_f64(js, Some("cpu_power_w"), k.cpu_power_w);
    w_f64(js, Some("dram_power_w"), k.dram_power_w);
    w_f64(js, Some("gpu_util"), k.gpu_util);
    w_f64(js, Some("cap_frac"), k.cap_frac);
    w_u64(js, Some("samples_processed"), k.samples_processed);
    w_f64(js, Some("energy_j"), k.energy_j);
    w_f64(js, Some("offered_load_per_s"), k.offered_load_per_s);
    w_f64(js, Some("p99_latency_s"), k.p99_latency_s);
    w_u64(js, Some("seq"), k.seq);
    js.end_obj();
}

pub fn r_kpm(j: &Json) -> Result<KpmReport> {
    Ok(KpmReport {
        host: jstr(j, "host")?.to_string(),
        at: Seconds(jf64(j, "at")?),
        model: jopt_string(j, "model")?,
        gpu_power_w: jf64(j, "gpu_power_w")?,
        cpu_power_w: jf64(j, "cpu_power_w")?,
        dram_power_w: jf64(j, "dram_power_w")?,
        gpu_util: jf64(j, "gpu_util")?,
        cap_frac: jf64(j, "cap_frac")?,
        samples_processed: ju64(j, "samples_processed")?,
        energy_j: jf64(j, "energy_j")?,
        offered_load_per_s: jf64(j, "offered_load_per_s")?,
        p99_latency_s: jf64(j, "p99_latency_s")?,
        seq: ju64(j, "seq")?,
    })
}

pub fn w_lifecycle<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, e: &LifecycleEvent) {
    js.begin_obj(name);
    match e {
        LifecycleEvent::DataCollected { dataset, samples } => {
            js.str_field(Some("t"), "data_collected");
            js.str_field(Some("dataset"), dataset);
            w_u64(js, Some("samples"), *samples);
        }
        LifecycleEvent::TrainingStarted { model, host } => {
            js.str_field(Some("t"), "training_started");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
        }
        LifecycleEvent::TrainingFinished { model, host, accuracy, energy_j } => {
            js.str_field(Some("t"), "training_finished");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
            w_f64(js, Some("accuracy"), *accuracy);
            w_f64(js, Some("energy_j"), *energy_j);
        }
        LifecycleEvent::Validated { model, accuracy, passed } => {
            js.str_field(Some("t"), "validated");
            js.str_field(Some("model"), model);
            w_f64(js, Some("accuracy"), *accuracy);
            js.bool_field(Some("passed"), *passed);
        }
        LifecycleEvent::Published { model, version } => {
            js.str_field(Some("t"), "published");
            js.str_field(Some("model"), model);
            js.u64_field(Some("version"), u64::from(*version));
        }
        LifecycleEvent::Deployed { model, host, as_xapp } => {
            js.str_field(Some("t"), "deployed");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
            js.bool_field(Some("as_xapp"), *as_xapp);
        }
        LifecycleEvent::InferenceReport { model, host, samples, latency_s } => {
            js.str_field(Some("t"), "inference_report");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
            w_u64(js, Some("samples"), *samples);
            w_f64(js, Some("latency_s"), *latency_s);
        }
        LifecycleEvent::FlaggedForRetraining { model, reason } => {
            js.str_field(Some("t"), "flagged_for_retraining");
            js.str_field(Some("model"), model);
            js.str_field(Some("reason"), reason);
        }
        LifecycleEvent::Retired { model } => {
            js.str_field(Some("t"), "retired");
            js.str_field(Some("model"), model);
        }
    }
    js.end_obj();
}

pub fn r_lifecycle(j: &Json) -> Result<LifecycleEvent> {
    let model = || jstr(j, "model").map(str::to_string);
    let host = || jstr(j, "host").map(str::to_string);
    Ok(match jstr(j, "t")? {
        "data_collected" => LifecycleEvent::DataCollected {
            dataset: jstr(j, "dataset")?.to_string(),
            samples: ju64(j, "samples")?,
        },
        "training_started" => {
            LifecycleEvent::TrainingStarted { model: model()?, host: host()? }
        }
        "training_finished" => LifecycleEvent::TrainingFinished {
            model: model()?,
            host: host()?,
            accuracy: jf64(j, "accuracy")?,
            energy_j: jf64(j, "energy_j")?,
        },
        "validated" => LifecycleEvent::Validated {
            model: model()?,
            accuracy: jf64(j, "accuracy")?,
            passed: jbool(j, "passed")?,
        },
        "published" => LifecycleEvent::Published { model: model()?, version: ju32(j, "version")? },
        "deployed" => LifecycleEvent::Deployed {
            model: model()?,
            host: host()?,
            as_xapp: jbool(j, "as_xapp")?,
        },
        "inference_report" => LifecycleEvent::InferenceReport {
            model: model()?,
            host: host()?,
            samples: ju64(j, "samples")?,
            latency_s: jf64(j, "latency_s")?,
        },
        "flagged_for_retraining" => LifecycleEvent::FlaggedForRetraining {
            model: model()?,
            reason: jstr(j, "reason")?.to_string(),
        },
        "retired" => LifecycleEvent::Retired { model: model()? },
        other => anyhow::bail!("unknown lifecycle event tag '{other}'"),
    })
}

pub fn w_oran_msg<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, m: &OranMessage) {
    js.begin_obj(name);
    match m {
        OranMessage::PolicyUpdate(p) => {
            js.str_field(Some("t"), "policy_update");
            w_policy(js, Some("policy"), p);
        }
        OranMessage::PolicyDelete { id } => {
            js.str_field(Some("t"), "policy_delete");
            js.str_field(Some("id"), id);
        }
        OranMessage::Kpm(k) => {
            js.str_field(Some("t"), "kpm");
            w_kpm(js, Some("kpm"), k);
        }
        OranMessage::Lifecycle(e) => {
            js.str_field(Some("t"), "lifecycle");
            w_lifecycle(js, Some("event"), e);
        }
        OranMessage::ProfileRequest { model, host } => {
            js.str_field(Some("t"), "profile_request");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
        }
        OranMessage::ProfileResult {
            model,
            host,
            optimal_cap,
            est_energy_saving,
            est_slowdown,
            profiling_energy_j,
        } => {
            js.str_field(Some("t"), "profile_result");
            js.str_field(Some("model"), model);
            js.str_field(Some("host"), host);
            w_f64(js, Some("optimal_cap"), *optimal_cap);
            w_f64(js, Some("est_energy_saving"), *est_energy_saving);
            w_f64(js, Some("est_slowdown"), *est_slowdown);
            w_f64(js, Some("profiling_energy_j"), *profiling_energy_j);
        }
    }
    js.end_obj();
}

pub fn r_oran_msg(j: &Json) -> Result<OranMessage> {
    Ok(match jstr(j, "t")? {
        "policy_update" => OranMessage::PolicyUpdate(r_policy(field(j, "policy")?)?),
        "policy_delete" => OranMessage::PolicyDelete { id: jstr(j, "id")?.to_string() },
        "kpm" => OranMessage::Kpm(r_kpm(field(j, "kpm")?)?),
        "lifecycle" => OranMessage::Lifecycle(r_lifecycle(field(j, "event")?)?),
        "profile_request" => OranMessage::ProfileRequest {
            model: jstr(j, "model")?.to_string(),
            host: jstr(j, "host")?.to_string(),
        },
        "profile_result" => OranMessage::ProfileResult {
            model: jstr(j, "model")?.to_string(),
            host: jstr(j, "host")?.to_string(),
            optimal_cap: jf64(j, "optimal_cap")?,
            est_energy_saving: jf64(j, "est_energy_saving")?,
            est_slowdown: jf64(j, "est_slowdown")?,
            profiling_energy_j: jf64(j, "profiling_energy_j")?,
        },
        other => anyhow::bail!("unknown O-RAN message tag '{other}'"),
    })
}

pub fn w_profile_record<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, r: &ProfileRecord) {
    js.begin_obj(name);
    js.str_field(Some("model"), &r.model);
    js.str_field(Some("host"), &r.host);
    w_f64(js, Some("optimal_cap"), r.optimal_cap);
    w_f64(js, Some("est_energy_saving"), r.est_energy_saving);
    w_f64(js, Some("est_slowdown"), r.est_slowdown);
    w_f64(js, Some("profiling_energy_j"), r.profiling_energy_j);
    js.end_obj();
}

pub fn r_profile_record(j: &Json) -> Result<ProfileRecord> {
    Ok(ProfileRecord {
        model: jstr(j, "model")?.to_string(),
        host: jstr(j, "host")?.to_string(),
        optimal_cap: jf64(j, "optimal_cap")?,
        est_energy_saving: jf64(j, "est_energy_saving")?,
        est_slowdown: jf64(j, "est_slowdown")?,
        profiling_energy_j: jf64(j, "profiling_energy_j")?,
    })
}

fn w_profile_point<W: Write>(js: &mut JsonStream<W>, p: &ProfilePoint) {
    js.begin_obj(None);
    w_f64(js, Some("cap_frac"), p.cap_frac);
    w_f64(js, Some("window"), p.window.0);
    w_u64(js, Some("steps"), p.steps);
    w_u64(js, Some("samples"), p.samples);
    w_f64(js, Some("energy"), p.energy.0);
    w_f64(js, Some("mean_power"), p.mean_power.0);
    w_f64(js, Some("energy_per_sample_j"), p.energy_per_sample_j);
    w_f64(js, Some("time_per_sample_s"), p.time_per_sample_s);
    w_f64(js, Some("score"), p.score);
    js.end_obj();
}

fn r_profile_point(j: &Json) -> Result<ProfilePoint> {
    Ok(ProfilePoint {
        cap_frac: jf64(j, "cap_frac")?,
        window: Seconds(jf64(j, "window")?),
        steps: ju64(j, "steps")?,
        samples: ju64(j, "samples")?,
        energy: Joules(jf64(j, "energy")?),
        mean_power: Watts(jf64(j, "mean_power")?),
        energy_per_sample_j: jf64(j, "energy_per_sample_j")?,
        time_per_sample_s: jf64(j, "time_per_sample_s")?,
        score: jf64(j, "score")?,
    })
}

fn w_fit<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, f: &FitResult) {
    js.begin_obj(name);
    js.begin_arr(Some("model"));
    for v in [f.model.a, f.model.b, f.model.c, f.model.d, f.model.e, f.model.f, f.model.g] {
        w_f64(js, None, v);
    }
    js.end_arr();
    w_f64(js, Some("rel_error"), f.rel_error);
    js.bool_field(Some("good_fit"), f.good_fit);
    js.begin_arr(Some("points"));
    for (x, y) in &f.points {
        js.begin_arr(None);
        w_f64(js, None, *x);
        w_f64(js, None, *y);
        js.end_arr();
    }
    js.end_arr();
    js.end_obj();
}

fn r_fit(j: &Json) -> Result<FitResult> {
    let m = jarr(j, "model")?;
    anyhow::ensure!(m.len() == 7, "response model has {} coefficients, expected 7", m.len());
    let c: Vec<f64> = m.iter().map(vf64).collect::<Result<_>>()?;
    let mut points = Vec::new();
    for p in jarr(j, "points")? {
        let xy = p.as_arr().context("fit point is not an array")?;
        anyhow::ensure!(xy.len() == 2, "fit point has {} elements", xy.len());
        points.push((vf64(&xy[0])?, vf64(&xy[1])?));
    }
    Ok(FitResult {
        model: ResponseModel { a: c[0], b: c[1], c: c[2], d: c[3], e: c[4], f: c[5], g: c[6] },
        rel_error: jf64(j, "rel_error")?,
        good_fit: jbool(j, "good_fit")?,
        points,
    })
}

pub fn w_profile_outcome<W: Write>(
    js: &mut JsonStream<W>,
    name: Option<&str>,
    o: &ProfileOutcome,
) {
    js.begin_obj(name);
    js.str_field(Some("model"), &o.model);
    w_f64(js, Some("exponent"), o.criterion.exponent);
    js.begin_arr(Some("points"));
    for p in &o.points {
        w_profile_point(js, p);
    }
    js.end_arr();
    w_fit(js, Some("fit"), &o.fit);
    w_f64(js, Some("optimal_cap"), o.optimal_cap);
    w_f64(js, Some("profiling_energy"), o.profiling_energy.0);
    w_f64(js, Some("idle_power"), o.idle_power.0);
    w_f64(js, Some("est_energy_saving"), o.est_energy_saving);
    w_f64(js, Some("est_slowdown"), o.est_slowdown);
    js.end_obj();
}

pub fn r_profile_outcome(j: &Json) -> Result<ProfileOutcome> {
    let mut points = Vec::new();
    for p in jarr(j, "points")? {
        points.push(r_profile_point(p)?);
    }
    Ok(ProfileOutcome {
        model: jstr(j, "model")?.to_string(),
        criterion: EdpCriterion { exponent: jf64(j, "exponent")? },
        points,
        fit: r_fit(field(j, "fit")?)?,
        optimal_cap: jf64(j, "optimal_cap")?,
        profiling_energy: Joules(jf64(j, "profiling_energy")?),
        idle_power: Watts(jf64(j, "idle_power")?),
        est_energy_saving: jf64(j, "est_energy_saving")?,
        est_slowdown: jf64(j, "est_slowdown")?,
    })
}

pub fn w_slot_report<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, r: &SlotReport) {
    js.begin_obj(name);
    js.u64_field(Some("slot_in_day"), u64::from(r.slot_in_day));
    w_f64(js, Some("t0"), r.t0);
    w_u64(js, Some("offered"), r.offered);
    w_u64(js, Some("served"), r.served);
    w_u64(js, Some("dropped"), r.dropped);
    w_u64(js, Some("late"), r.late);
    w_u64(js, Some("batches"), r.batches);
    w_u64(js, Some("batch_samples"), r.batch_samples);
    w_f64(js, Some("busy_s"), r.busy_s);
    w_f64(js, Some("energy_j"), r.energy_j);
    w_f64(js, Some("gpu_busy_power_w"), r.gpu_busy_power_w);
    w_f64(js, Some("offered_rate_per_s"), r.offered_rate_per_s);
    w_f64(js, Some("cap_frac"), r.cap_frac);
    js.end_obj();
}

pub fn r_slot_report(j: &Json) -> Result<SlotReport> {
    Ok(SlotReport {
        slot_in_day: ju32(j, "slot_in_day")?,
        t0: jf64(j, "t0")?,
        offered: ju64(j, "offered")?,
        served: ju64(j, "served")?,
        dropped: ju64(j, "dropped")?,
        late: ju64(j, "late")?,
        batches: ju64(j, "batches")?,
        batch_samples: ju64(j, "batch_samples")?,
        busy_s: jf64(j, "busy_s")?,
        energy_j: jf64(j, "energy_j")?,
        gpu_busy_power_w: jf64(j, "gpu_busy_power_w")?,
        offered_rate_per_s: jf64(j, "offered_rate_per_s")?,
        cap_frac: jf64(j, "cap_frac")?,
    })
}

pub fn w_series<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, s: &Series) {
    js.begin_obj(name);
    js.str_field(Some("name"), &s.name);
    js.begin_arr(Some("columns"));
    for c in &s.columns {
        js.str_field(None, c);
    }
    js.end_arr();
    js.begin_arr(Some("labels"));
    for l in &s.labels {
        js.str_field(None, l);
    }
    js.end_arr();
    js.begin_arr(Some("rows"));
    for row in &s.rows {
        js.begin_arr(None);
        for v in row {
            w_f64(js, None, *v);
        }
        js.end_arr();
    }
    js.end_arr();
    js.end_obj();
}

pub fn r_series(j: &Json) -> Result<Series> {
    let strs = |name: &str| -> Result<Vec<String>> {
        jarr(j, name)?
            .iter()
            .map(|v| {
                v.as_str().map(str::to_string).with_context(|| format!("{name} element"))
            })
            .collect()
    };
    let mut rows = Vec::new();
    for row in jarr(j, "rows")? {
        let cells = row.as_arr().context("series row is not an array")?;
        rows.push(cells.iter().map(vf64).collect::<Result<Vec<f64>>>()?);
    }
    Ok(Series {
        name: jstr(j, "name")?.to_string(),
        columns: strs("columns")?,
        labels: strs("labels")?,
        rows,
    })
}

// ---------------------------------------------------- scenario / faults

pub fn w_scenario_event<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, e: &ScenarioEvent) {
    js.begin_obj(name);
    let opt_site = |js: &mut JsonStream<W>, site: &Option<usize>| {
        if let Some(s) = site {
            js.u64_field(Some("site"), *s as u64);
        }
    };
    match e {
        ScenarioEvent::BudgetStep { budget_frac } => {
            js.str_field(Some("t"), "budget_step");
            w_f64(js, Some("budget_frac"), *budget_frac);
        }
        ScenarioEvent::SiteDown { site } => {
            js.str_field(Some("t"), "site_down");
            js.u64_field(Some("site"), *site as u64);
        }
        ScenarioEvent::SiteUp { site } => {
            js.str_field(Some("t"), "site_up");
            js.u64_field(Some("site"), *site as u64);
        }
        ScenarioEvent::SurgeStart { mult, site } => {
            js.str_field(Some("t"), "surge_start");
            w_f64(js, Some("mult"), *mult);
            opt_site(js, site);
        }
        ScenarioEvent::SurgeEnd { site } => {
            js.str_field(Some("t"), "surge_end");
            opt_site(js, site);
        }
        ScenarioEvent::Derate { site, max_cap_frac } => {
            js.str_field(Some("t"), "derate");
            js.u64_field(Some("site"), *site as u64);
            w_f64(js, Some("max_cap_frac"), *max_cap_frac);
        }
        ScenarioEvent::DerateEnd { site } => {
            js.str_field(Some("t"), "derate_end");
            js.u64_field(Some("site"), *site as u64);
        }
    }
    js.end_obj();
}

pub fn r_scenario_event(j: &Json) -> Result<ScenarioEvent> {
    let site = || jusize(j, "site");
    let opt_site = || -> Result<Option<usize>> {
        match j.get("site") {
            Some(v) => Ok(Some(v.as_usize().context("field 'site' is not a usize")?)),
            None => Ok(None),
        }
    };
    Ok(match jstr(j, "t")? {
        "budget_step" => ScenarioEvent::BudgetStep { budget_frac: jf64(j, "budget_frac")? },
        "site_down" => ScenarioEvent::SiteDown { site: site()? },
        "site_up" => ScenarioEvent::SiteUp { site: site()? },
        "surge_start" => {
            ScenarioEvent::SurgeStart { mult: jf64(j, "mult")?, site: opt_site()? }
        }
        "surge_end" => ScenarioEvent::SurgeEnd { site: opt_site()? },
        "derate" => ScenarioEvent::Derate {
            site: site()?,
            max_cap_frac: jf64(j, "max_cap_frac")?,
        },
        "derate_end" => ScenarioEvent::DerateEnd { site: site()? },
        other => anyhow::bail!("unknown scenario event tag '{other}'"),
    })
}

pub fn w_scenario<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, sc: &Scenario) {
    js.begin_obj(name);
    js.str_field(Some("name"), &sc.name);
    js.u64_field(Some("region_size"), sc.region_size as u64);
    js.begin_arr(Some("events"));
    for te in &sc.events {
        js.begin_obj(None);
        js.u64_field(Some("round"), u64::from(te.round));
        w_scenario_event(js, Some("event"), &te.event);
        js.end_obj();
    }
    js.end_arr();
    js.begin_arr(Some("phases"));
    for p in &sc.phases {
        js.begin_obj(None);
        js.str_field(Some("name"), &p.name);
        js.u64_field(Some("from_slot"), u64::from(p.from_slot));
        js.u64_field(Some("to_slot"), u64::from(p.to_slot));
        js.end_obj();
    }
    js.end_arr();
    js.end_obj();
}

pub fn r_scenario(j: &Json) -> Result<Scenario> {
    let mut events = Vec::new();
    for te in jarr(j, "events")? {
        events.push(TimedEvent {
            round: ju32(te, "round")?,
            event: r_scenario_event(field(te, "event")?)?,
        });
    }
    let mut phases = Vec::new();
    for p in jarr(j, "phases")? {
        phases.push(Phase {
            name: jstr(p, "name")?.to_string(),
            from_slot: ju32(p, "from_slot")?,
            to_slot: ju32(p, "to_slot")?,
        });
    }
    Ok(Scenario {
        name: jstr(j, "name")?.to_string(),
        events,
        phases,
        region_size: jusize(j, "region_size")?,
    })
}

pub fn w_fault_ledger<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, l: &FaultLedger) {
    js.begin_obj(name);
    w_u64(js, Some("dropped"), l.dropped);
    w_u64(js, Some("delayed"), l.delayed);
    w_u64(js, Some("delay_dropped"), l.delay_dropped);
    w_u64(js, Some("duplicated"), l.duplicated);
    w_u64(js, Some("reordered"), l.reordered);
    w_u64(js, Some("corrupted_nan"), l.corrupted_nan);
    w_u64(js, Some("corrupted_stale"), l.corrupted_stale);
    w_u64(js, Some("corrupted_nvml"), l.corrupted_nvml);
    w_u64(js, Some("released"), l.released);
    js.end_obj();
}

pub fn r_fault_ledger(j: &Json) -> Result<FaultLedger> {
    Ok(FaultLedger {
        dropped: ju64(j, "dropped")?,
        delayed: ju64(j, "delayed")?,
        delay_dropped: ju64(j, "delay_dropped")?,
        duplicated: ju64(j, "duplicated")?,
        reordered: ju64(j, "reordered")?,
        corrupted_nan: ju64(j, "corrupted_nan")?,
        corrupted_stale: ju64(j, "corrupted_stale")?,
        corrupted_nvml: ju64(j, "corrupted_nvml")?,
        released: ju64(j, "released")?,
    })
}

pub fn w_fault_config<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, c: &FaultConfig) {
    js.begin_obj(name);
    w_u64(js, Some("seed"), c.seed);
    w_f64(js, Some("drop_p"), c.drop_p);
    w_f64(js, Some("delay_p"), c.delay_p);
    js.u64_field(Some("max_delay_rounds"), u64::from(c.max_delay_rounds));
    w_f64(js, Some("dup_p"), c.dup_p);
    w_f64(js, Some("reorder_p"), c.reorder_p);
    w_f64(js, Some("kpm_nan_p"), c.kpm_nan_p);
    w_f64(js, Some("kpm_stale_p"), c.kpm_stale_p);
    w_f64(js, Some("nvml_fail_p"), c.nvml_fail_p);
    js.u64_field(Some("start_round"), u64::from(c.start_round));
    js.u64_field(Some("end_round"), u64::from(c.end_round));
    js.u64_field(Some("max_held"), c.max_held as u64);
    js.bool_field(Some("fault_a1"), c.fault_a1);
    js.bool_field(Some("fault_o1"), c.fault_o1);
    js.bool_field(Some("fault_o2"), c.fault_o2);
    js.end_obj();
}

pub fn r_fault_config(j: &Json) -> Result<FaultConfig> {
    Ok(FaultConfig {
        seed: ju64(j, "seed")?,
        drop_p: jf64(j, "drop_p")?,
        delay_p: jf64(j, "delay_p")?,
        max_delay_rounds: ju32(j, "max_delay_rounds")?,
        dup_p: jf64(j, "dup_p")?,
        reorder_p: jf64(j, "reorder_p")?,
        kpm_nan_p: jf64(j, "kpm_nan_p")?,
        kpm_stale_p: jf64(j, "kpm_stale_p")?,
        nvml_fail_p: jf64(j, "nvml_fail_p")?,
        start_round: ju32(j, "start_round")?,
        end_round: ju32(j, "end_round")?,
        max_held: jusize(j, "max_held")?,
        fault_a1: jbool(j, "fault_a1")?,
        fault_o1: jbool(j, "fault_o1")?,
        fault_o2: jbool(j, "fault_o2")?,
    })
}

// ---------------------------------------------------------- trace events

/// Ledger fate names a fault trace event can carry (see
/// `FaultPlan::apply`); the checkpoint decoder interns against this set.
pub const KNOWN_FATES: &[&'static str] = &[
    "dropped",
    "delayed",
    "delay_dropped",
    "duplicated",
    "reordered",
    "corrupted_nan",
    "corrupted_stale",
    "corrupted_nvml",
    "released",
];

/// O-RAN interface names carried on fault trace events ("-" marks a
/// release, which has no single interface).
pub const KNOWN_INTERFACES: &[&'static str] = &["A1", "O1", "O2", "-"];

/// SMO KPM-validation reject reasons (see `Smo::step`).
pub const KNOWN_KPM_REASONS: &[&'static str] =
    &["non_finite", "negative_power", "stale_timestamp", "duplicate_seq"];

pub fn w_trace_event<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, e: &TraceEvent) {
    js.begin_obj(name);
    w_u64(js, Some("id"), e.id);
    js.u64_field(Some("round"), u64::from(e.round));
    if let Some(site) = e.site {
        js.u64_field(Some("site"), u64::from(site));
    }
    if let Some(region) = e.region {
        js.u64_field(Some("region"), u64::from(region));
    }
    js.str_field(Some("kind"), e.data.kind());
    match &e.data {
        TraceData::RoundStart | TraceData::Reprofile => {}
        TraceData::RoundEnd { cap_power_w } => {
            w_f64(js, Some("cap_power_w"), *cap_power_w);
        }
        TraceData::SiteRound { cap_frac, down } => {
            w_f64(js, Some("cap_frac"), *cap_frac);
            js.bool_field(Some("down"), *down);
        }
        TraceData::Scenario { event, detail } => {
            w_scenario_event(js, Some("event"), event);
            js.str_field(Some("detail"), detail);
        }
        TraceData::Fault { fate, interface, count } => {
            js.str_field(Some("fate"), fate);
            js.str_field(Some("interface"), interface);
            w_u64(js, Some("count"), *count);
        }
        TraceData::KpmReject { host, reason } => {
            js.str_field(Some("host"), host);
            js.str_field(Some("reason"), reason);
        }
        TraceData::Lifecycle { detail } => {
            js.str_field(Some("detail"), detail);
        }
        TraceData::CapChange { cause, from, to, trigger } => {
            js.str_field(Some("cause"), cause.as_str());
            w_f64(js, Some("from"), *from);
            w_f64(js, Some("to"), *to);
            w_opt_u64(js, Some("trigger"), *trigger);
        }
        TraceData::Quarantine { host, entered } => {
            js.str_field(Some("host"), host);
            js.bool_field(Some("entered"), *entered);
        }
    }
    js.end_obj();
}

pub fn r_trace_event(j: &Json) -> Result<TraceEvent> {
    let data = match jstr(j, "kind")? {
        "round_start" => TraceData::RoundStart,
        "round_end" => TraceData::RoundEnd { cap_power_w: jf64(j, "cap_power_w")? },
        "site_round" => TraceData::SiteRound {
            cap_frac: jf64(j, "cap_frac")?,
            down: jbool(j, "down")?,
        },
        "scenario" => TraceData::Scenario {
            event: r_scenario_event(field(j, "event")?)?,
            detail: jstr(j, "detail")?.to_string(),
        },
        "fault" => TraceData::Fault {
            fate: intern_static(jstr(j, "fate")?, KNOWN_FATES),
            interface: intern_static(jstr(j, "interface")?, KNOWN_INTERFACES),
            count: ju64(j, "count")?,
        },
        "kpm_reject" => TraceData::KpmReject {
            host: jstr(j, "host")?.to_string(),
            reason: intern_static(jstr(j, "reason")?, KNOWN_KPM_REASONS),
        },
        "lifecycle" => TraceData::Lifecycle { detail: jstr(j, "detail")?.to_string() },
        "cap_change" => {
            let cause_s = jstr(j, "cause")?;
            TraceData::CapChange {
                cause: CapCause::from_str_name(cause_s)
                    .with_context(|| format!("unknown cap cause '{cause_s}'"))?,
                from: jf64(j, "from")?,
                to: jf64(j, "to")?,
                trigger: jopt_u64(j, "trigger")?,
            }
        }
        "reprofile" => TraceData::Reprofile,
        "quarantine" => TraceData::Quarantine {
            host: jstr(j, "host")?.to_string(),
            entered: jbool(j, "entered")?,
        },
        other => anyhow::bail!("unknown trace event kind '{other}'"),
    };
    let site = match j.get("site") {
        Some(v) => Some(
            u32::try_from(v.as_i64().context("trace site")?)
                .ok()
                .context("trace site out of range")?,
        ),
        None => None,
    };
    let region = match j.get("region") {
        Some(v) => Some(
            u32::try_from(v.as_i64().context("trace region")?)
                .ok()
                .context("trace region out of range")?,
        ),
        None => None,
    };
    Ok(TraceEvent { id: ju64(j, "id")?, round: ju32(j, "round")?, site, region, data })
}

// ------------------------------------------------------- catalogue types

fn model_state_str(s: ModelState) -> &'static str {
    match s {
        ModelState::Trained => "trained",
        ModelState::Validated => "validated",
        ModelState::Published => "published",
        ModelState::Deployed => "deployed",
        ModelState::FlaggedForUpdate => "flagged_for_update",
        ModelState::Retired => "retired",
    }
}

fn parse_model_state(s: &str) -> Result<ModelState> {
    Ok(match s {
        "trained" => ModelState::Trained,
        "validated" => ModelState::Validated,
        "published" => ModelState::Published,
        "deployed" => ModelState::Deployed,
        "flagged_for_update" => ModelState::FlaggedForUpdate,
        "retired" => ModelState::Retired,
        other => anyhow::bail!("unknown model state '{other}'"),
    })
}

pub fn w_catalogue_entry<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, e: &CatalogueEntry) {
    js.begin_obj(name);
    js.str_field(Some("name"), &e.name);
    js.u64_field(Some("version"), u64::from(e.version));
    js.str_field(Some("state"), model_state_str(e.state));
    w_f64(js, Some("validation_accuracy"), e.validation_accuracy);
    w_opt_f64(js, Some("optimal_cap"), e.optimal_cap);
    if let Some(a) = &e.artifact {
        js.str_field(Some("artifact"), a);
    }
    js.end_obj();
}

pub fn r_catalogue_entry(j: &Json) -> Result<CatalogueEntry> {
    Ok(CatalogueEntry {
        name: jstr(j, "name")?.to_string(),
        version: ju32(j, "version")?,
        state: parse_model_state(jstr(j, "state")?)?,
        validation_accuracy: jf64(j, "validation_accuracy")?,
        optimal_cap: jopt_f64(j, "optimal_cap")?,
        artifact: jopt_string(j, "artifact")?,
    })
}

// -------------------------------------------------------- traffic config

fn arrival_kind_tag(k: &ArrivalKind) -> &'static str {
    match k {
        ArrivalKind::Poisson => "poisson",
        ArrivalKind::Mmpp { .. } => "mmpp",
    }
}

pub fn w_traffic_config<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, t: &TrafficConfig) {
    js.begin_obj(name);
    w_u64(js, Some("users_per_site"), t.users_per_site);
    w_f64(js, Some("requests_per_user_per_day"), t.requests_per_user_per_day);
    w_f64(js, Some("day_s"), t.day_s);
    js.u64_field(Some("slots_per_day"), u64::from(t.slots_per_day));
    js.u64_field(Some("warmup_rounds"), u64::from(t.warmup_rounds));
    js.u64_field(Some("max_batch"), u64::from(t.max_batch));
    js.begin_obj(Some("kind"));
    js.str_field(Some("t"), arrival_kind_tag(&t.kind));
    if let ArrivalKind::Mmpp { calm_mult, burst_mult, mean_dwell_s } = t.kind {
        w_f64(js, Some("calm_mult"), calm_mult);
        w_f64(js, Some("burst_mult"), burst_mult);
        w_f64(js, Some("mean_dwell_s"), mean_dwell_s);
    }
    js.end_obj();
    js.begin_arr(Some("diurnal"));
    for w in t.diurnal.normalised_weights() {
        w_f64(js, None, *w);
    }
    js.end_arr();
    js.begin_obj(Some("slo"));
    w_f64(js, Some("latency_critical_s"), t.slo.latency_critical_s);
    w_f64(js, Some("balanced_s"), t.slo.balanced_s);
    w_f64(js, Some("energy_saver_s"), t.slo.energy_saver_s);
    js.end_obj();
    w_u64(js, Some("exact_request_threshold"), t.exact_request_threshold);
    let path = match t.path {
        TrafficPath::Auto => "auto",
        TrafficPath::ForceExact => "force_exact",
        TrafficPath::ForceAggregate => "force_aggregate",
    };
    js.str_field(Some("path"), path);
    js.end_obj();
}

pub fn r_traffic_config(j: &Json) -> Result<TrafficConfig> {
    let k = field(j, "kind")?;
    let kind = match jstr(k, "t")? {
        "poisson" => ArrivalKind::Poisson,
        "mmpp" => ArrivalKind::Mmpp {
            calm_mult: jf64(k, "calm_mult")?,
            burst_mult: jf64(k, "burst_mult")?,
            mean_dwell_s: jf64(k, "mean_dwell_s")?,
        },
        other => anyhow::bail!("unknown arrival kind '{other}'"),
    };
    let dw = jarr(j, "diurnal")?;
    anyhow::ensure!(dw.len() == 24, "diurnal profile has {} weights, expected 24", dw.len());
    let mut weights = [0.0f64; 24];
    for (i, v) in dw.iter().enumerate() {
        weights[i] = vf64(v).context("diurnal weight")?;
    }
    let slo = field(j, "slo")?;
    let path = match jstr(j, "path")? {
        "auto" => TrafficPath::Auto,
        "force_exact" => TrafficPath::ForceExact,
        "force_aggregate" => TrafficPath::ForceAggregate,
        other => anyhow::bail!("unknown traffic path '{other}'"),
    };
    Ok(TrafficConfig {
        users_per_site: ju64(j, "users_per_site")?,
        requests_per_user_per_day: jf64(j, "requests_per_user_per_day")?,
        day_s: jf64(j, "day_s")?,
        slots_per_day: ju32(j, "slots_per_day")?,
        warmup_rounds: ju32(j, "warmup_rounds")?,
        max_batch: ju32(j, "max_batch")?,
        kind,
        diurnal: DiurnalProfile::from_normalised(weights)?,
        slo: SloSpec {
            latency_critical_s: jf64(slo, "latency_critical_s")?,
            balanced_s: jf64(slo, "balanced_s")?,
            energy_saver_s: jf64(slo, "energy_saver_s")?,
        },
        exact_request_threshold: ju64(j, "exact_request_threshold")?,
        path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::JsonStream;

    /// Write one object through the streaming writer, parse it back.
    fn line<F: FnOnce(&mut JsonStream<&mut Vec<u8>>)>(f: F) -> Json {
        let mut out = Vec::new();
        let mut js = JsonStream::new(&mut out);
        js.begin_obj(None);
        f(&mut js);
        js.end_obj();
        js.finish().unwrap();
        let text = std::str::from_utf8(&out).unwrap();
        Json::parse(text.trim_end()).unwrap()
    }

    #[test]
    fn hex_f64_round_trips_hostile_values() {
        for v in [
            0.0,
            -0.0,
            1.5,
            -271.25,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::NAN,
            1.0e-308,
        ] {
            let j = line(|js| w_f64(js, Some("x"), v));
            let back = jf64(&j, "x").unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "value {v}");
        }
    }

    #[test]
    fn hex_u64_round_trips_the_full_range() {
        for v in [0u64, 1, (1 << 53) + 1, u64::MAX, 0xdead_beef_f00d_cafe] {
            let j = line(|js| w_u64(js, Some("x"), v));
            assert_eq!(ju64(&j, "x").unwrap(), v);
        }
    }

    #[test]
    fn bad_hex_is_rejected_not_guessed() {
        assert!(parse_hex_u64("").is_err());
        assert!(parse_hex_u64("0123").is_err(), "short literal");
        assert!(parse_hex_u64("00000000000000zz").is_err(), "non-hex digits");
        assert!(parse_hex_u64("00000000000000001").is_err(), "too long");
    }

    #[test]
    fn options_distinguish_none_from_nan() {
        let j = line(|js| {
            w_opt_f64(js, Some("none"), None);
            w_opt_f64(js, Some("nan"), Some(f64::NAN));
            w_opt_u64(js, Some("unone"), None);
            w_opt_u64(js, Some("usome"), Some(7));
        });
        assert_eq!(jopt_f64(&j, "none").unwrap(), None);
        assert!(jopt_f64(&j, "nan").unwrap().unwrap().is_nan());
        assert_eq!(jopt_u64(&j, "unone").unwrap(), None);
        assert_eq!(jopt_u64(&j, "usome").unwrap(), Some(7));
    }

    #[test]
    fn intern_static_prefers_the_known_table() {
        let known: &[&'static str] = &["alpha", "beta"];
        let a = intern_static("alpha", known);
        assert!(std::ptr::eq(a, known[0]));
        assert_eq!(intern_static("novel", known), "novel");
    }

    #[test]
    fn pcg32_round_trips_mid_stream() {
        let mut rng = Pcg32::new(42, 7);
        for _ in 0..13 {
            rng.next_u32();
        }
        let j = line(|js| w_pcg32(js, Some("rng"), &rng));
        let mut back = r_pcg32(j.req("rng").unwrap()).unwrap();
        assert_eq!(back.state_parts(), rng.state_parts());
        assert_eq!(back.next_u32(), rng.next_u32(), "streams continue identically");
    }

    #[test]
    fn summary_round_trips_including_empty() {
        let mut s = StreamingSummary::new();
        for x in [1.0, -3.5, 2.25] {
            s.push(x);
        }
        for orig in [s, StreamingSummary::new()] {
            let j = line(|js| w_summary(js, Some("s"), &orig));
            let back = r_summary(j.req("s").unwrap()).unwrap();
            assert_eq!(back.state_parts(), orig.state_parts());
        }
    }

    #[test]
    fn histogram_round_trips_sparsely() {
        let mut h = LatencyHistogram::new();
        for v in [0.001, 0.25, 4.0, f64::NAN, 1.0e9] {
            h.record(v);
        }
        let j = line(|js| w_hist(js, Some("h"), &h));
        let back = r_hist(j.req("h").unwrap()).unwrap();
        let orig_bins: Vec<(usize, u64)> = h.occupied_bins().collect();
        let back_bins: Vec<(usize, u64)> = back.occupied_bins().collect();
        assert_eq!(back_bins, orig_bins);
        assert_eq!(back.non_finite(), h.non_finite());
    }

    #[test]
    fn policy_round_trips() {
        let p = EnergyPolicy {
            id: "p-9".into(),
            qos: QosClass::LatencyCritical,
            min_cap_frac: 0.35,
            max_cap_frac: 0.9,
            enabled: true,
            max_slowdown: 1.07,
            lease_rounds: 6,
        };
        let j = line(|js| w_policy(js, Some("p"), &p));
        assert_eq!(r_policy(j.req("p").unwrap()).unwrap(), p);
    }

    #[test]
    fn kpm_round_trips_with_and_without_model() {
        for model in [Some("ResNet".to_string()), None] {
            let k = KpmReport {
                host: "site03".into(),
                at: Seconds(1234.5),
                model,
                gpu_power_w: 151.25,
                cpu_power_w: f64::NAN,
                dram_power_w: 24.0,
                gpu_util: 0.83,
                cap_frac: 0.7,
                samples_processed: (1 << 54) + 3,
                energy_j: -0.0,
                offered_load_per_s: 12.5,
                p99_latency_s: 0.04,
                seq: u64::MAX,
            };
            let j = line(|js| w_kpm(js, Some("k"), &k));
            let back = r_kpm(j.req("k").unwrap()).unwrap();
            // NaN breaks derived PartialEq; compare the exact bits via Debug
            // of bit-faithful fields plus the NaN field separately.
            assert!(back.cpu_power_w.is_nan());
            assert_eq!(back.energy_j.to_bits(), k.energy_j.to_bits(), "-0.0 preserved");
            assert_eq!(back.samples_processed, k.samples_processed);
            assert_eq!(back.seq, k.seq);
            assert_eq!(back.host, k.host);
            assert_eq!(back.model, k.model);
        }
    }

    #[test]
    fn lifecycle_events_round_trip() {
        let events = vec![
            LifecycleEvent::DataCollected { dataset: "cifar10".into(), samples: 50_000 },
            LifecycleEvent::TrainingStarted { model: "m".into(), host: "h".into() },
            LifecycleEvent::TrainingFinished {
                model: "m".into(),
                host: "h".into(),
                accuracy: 0.97,
                energy_j: 1.5e6,
            },
            LifecycleEvent::Validated { model: "m".into(), accuracy: 0.97, passed: true },
            LifecycleEvent::Published { model: "m".into(), version: 3 },
            LifecycleEvent::Deployed { model: "m".into(), host: "h".into(), as_xapp: false },
            LifecycleEvent::InferenceReport {
                model: "m".into(),
                host: "h".into(),
                samples: 10,
                latency_s: 0.01,
            },
            LifecycleEvent::FlaggedForRetraining { model: "m".into(), reason: "drift".into() },
            LifecycleEvent::Retired { model: "m".into() },
        ];
        for e in events {
            let j = line(|js| w_lifecycle(js, Some("e"), &e));
            assert_eq!(r_lifecycle(j.req("e").unwrap()).unwrap(), e);
        }
    }

    #[test]
    fn oran_messages_round_trip() {
        let msgs = vec![
            OranMessage::PolicyUpdate(EnergyPolicy::default_policy()),
            OranMessage::PolicyDelete { id: "frost-default".into() },
            OranMessage::Lifecycle(LifecycleEvent::Retired { model: "m".into() }),
            OranMessage::ProfileRequest { model: "m".into(), host: "h".into() },
            OranMessage::ProfileResult {
                model: "m".into(),
                host: "h".into(),
                optimal_cap: 0.65,
                est_energy_saving: 0.2,
                est_slowdown: 1.04,
                profiling_energy_j: 4.2e4,
            },
        ];
        for m in msgs {
            let j = line(|js| w_oran_msg(js, Some("m"), &m));
            assert_eq!(r_oran_msg(j.req("m").unwrap()).unwrap(), m);
        }
        // Kpm separately (NaN-free payload → PartialEq works).
        let k = KpmReport {
            host: "s".into(),
            at: Seconds(1.0),
            model: None,
            gpu_power_w: 100.0,
            cpu_power_w: 50.0,
            dram_power_w: 24.0,
            gpu_util: 0.5,
            cap_frac: 1.0,
            samples_processed: 5,
            energy_j: 10.0,
            offered_load_per_s: 0.0,
            p99_latency_s: 0.0,
            seq: 1,
        };
        let m = OranMessage::Kpm(k);
        let j = line(|js| w_oran_msg(js, Some("m"), &m));
        assert_eq!(r_oran_msg(j.req("m").unwrap()).unwrap(), m);
    }

    #[test]
    fn profile_outcome_round_trips_via_debug_identity() {
        let o = ProfileOutcome {
            model: "ResNet".into(),
            criterion: EdpCriterion { exponent: 2.0 },
            points: vec![ProfilePoint {
                cap_frac: 0.6,
                window: Seconds(30.0),
                steps: 123,
                samples: 15_744,
                energy: Joules(5_000.5),
                mean_power: Watts(166.7),
                energy_per_sample_j: 0.317,
                time_per_sample_s: 0.0019,
                score: 1.15e-3,
            }],
            fit: FitResult {
                model: ResponseModel {
                    a: 1.0,
                    b: -2.0,
                    c: 3.0,
                    d: -0.0,
                    e: 5.5,
                    f: 6.25,
                    g: -7.0,
                },
                rel_error: 0.012,
                good_fit: true,
                points: vec![(0.3, 1.2), (1.0, 1.0)],
            },
            optimal_cap: 0.62,
            profiling_energy: Joules(4.0e4),
            idle_power: Watts(38.0),
            est_energy_saving: 0.21,
            est_slowdown: 1.05,
        };
        let j = line(|js| w_profile_outcome(js, Some("o"), &o));
        let back = r_profile_outcome(j.req("o").unwrap()).unwrap();
        assert_eq!(format!("{back:?}"), format!("{o:?}"));
    }

    #[test]
    fn slot_report_and_series_round_trip() {
        let r = SlotReport {
            slot_in_day: 17,
            t0: 2_550.0,
            offered: 120_345,
            served: 120_000,
            dropped: 300,
            late: 45,
            batches: 1_900,
            batch_samples: 120_000,
            busy_s: 88.25,
            energy_j: 1.3e4,
            gpu_busy_power_w: 147.0,
            offered_rate_per_s: 802.3,
            cap_frac: 0.75,
        };
        let j = line(|js| w_slot_report(js, Some("r"), &r));
        assert_eq!(r_slot_report(j.req("r").unwrap()).unwrap(), r);

        let s = Series {
            name: "chaos".into(),
            columns: vec!["round".into(), "cap_w".into()],
            rows: vec![vec![1.0, 600.0], vec![2.0, 580.5]],
            labels: vec!["a".into(), "b".into()],
        };
        let j = line(|js| w_series(js, Some("s"), &s));
        assert_eq!(r_series(j.req("s").unwrap()).unwrap(), s);
    }

    #[test]
    fn scenario_events_round_trip() {
        let events = vec![
            ScenarioEvent::BudgetStep { budget_frac: 0.6 },
            ScenarioEvent::SiteDown { site: 3 },
            ScenarioEvent::SiteUp { site: 3 },
            ScenarioEvent::SurgeStart { mult: 2.5, site: Some(1) },
            ScenarioEvent::SurgeStart { mult: 1.8, site: None },
            ScenarioEvent::SurgeEnd { site: None },
            ScenarioEvent::SurgeEnd { site: Some(2) },
            ScenarioEvent::Derate { site: 0, max_cap_frac: 0.55 },
            ScenarioEvent::DerateEnd { site: 0 },
        ];
        for e in &events {
            let j = line(|js| w_scenario_event(js, Some("e"), e));
            assert_eq!(r_scenario_event(j.req("e").unwrap()).unwrap(), *e);
        }
        let sc = Scenario {
            name: "grid-step".into(),
            events: vec![TimedEvent { round: 9, event: events[0] }],
            phases: vec![Phase { name: "pre".into(), from_slot: 0, to_slot: 7 }],
            region_size: 4,
        };
        let j = line(|js| w_scenario(js, Some("sc"), &sc));
        assert_eq!(r_scenario(j.req("sc").unwrap()).unwrap(), sc);
    }

    #[test]
    fn fault_config_and_ledger_round_trip() {
        let c = FaultConfig {
            seed: 0xFA57,
            drop_p: 0.05,
            delay_p: 0.1,
            max_delay_rounds: 2,
            dup_p: 0.02,
            reorder_p: 0.08,
            kpm_nan_p: 0.04,
            kpm_stale_p: 0.04,
            nvml_fail_p: 0.03,
            start_round: 2,
            end_round: 40,
            max_held: 256,
            fault_a1: true,
            fault_o1: true,
            fault_o2: false,
        };
        let j = line(|js| w_fault_config(js, Some("c"), &c));
        assert_eq!(r_fault_config(j.req("c").unwrap()).unwrap(), c);

        let l = FaultLedger {
            dropped: 3,
            delayed: 5,
            delay_dropped: 1,
            duplicated: 2,
            reordered: 4,
            corrupted_nan: 1,
            corrupted_stale: 2,
            corrupted_nvml: 1,
            released: 5,
        };
        let j = line(|js| w_fault_ledger(js, Some("l"), &l));
        assert_eq!(r_fault_ledger(j.req("l").unwrap()).unwrap(), l);
    }

    #[test]
    fn trace_events_round_trip_across_every_kind() {
        let events = vec![
            TraceEvent { id: 1, round: 1, site: None, region: None, data: TraceData::RoundStart },
            TraceEvent {
                id: 2,
                round: 1,
                site: Some(0),
                region: Some(0),
                data: TraceData::SiteRound { cap_frac: 0.8, down: false },
            },
            TraceEvent {
                id: 3,
                round: 1,
                site: Some(2),
                region: Some(1),
                data: TraceData::CapChange {
                    cause: CapCause::WaterFill,
                    from: 1.0,
                    to: 0.6,
                    trigger: Some(1),
                },
            },
            TraceEvent {
                id: 4,
                round: 1,
                site: None,
                region: None,
                data: TraceData::CapChange {
                    cause: CapCause::Recovery,
                    from: 0.6,
                    to: 1.0,
                    trigger: None,
                },
            },
            TraceEvent {
                id: 5,
                round: 2,
                site: Some(1),
                region: Some(0),
                data: TraceData::Scenario {
                    event: ScenarioEvent::SiteDown { site: 1 },
                    detail: "site 1 down".into(),
                },
            },
            TraceEvent {
                id: 6,
                round: 2,
                site: None,
                region: None,
                data: TraceData::Fault { fate: "delayed", interface: "O1", count: 2 },
            },
            TraceEvent {
                id: 7,
                round: 2,
                site: Some(3),
                region: None,
                data: TraceData::KpmReject { host: "site03".into(), reason: "duplicate_seq" },
            },
            TraceEvent {
                id: 8,
                round: 2,
                site: None,
                region: None,
                data: TraceData::Lifecycle { detail: "published m v2".into() },
            },
            TraceEvent { id: 9, round: 3, site: Some(0), region: None, data: TraceData::Reprofile },
            TraceEvent {
                id: 10,
                round: 3,
                site: Some(0),
                region: Some(2),
                data: TraceData::Quarantine { host: "site00".into(), entered: true },
            },
            TraceEvent {
                id: 11,
                round: 3,
                site: None,
                region: None,
                data: TraceData::RoundEnd { cap_power_w: 612.5 },
            },
        ];
        for e in &events {
            let j = line(|js| w_trace_event(js, Some("e"), e));
            assert_eq!(r_trace_event(j.req("e").unwrap()).unwrap(), *e);
        }
    }

    #[test]
    fn catalogue_entries_round_trip() {
        let entries = vec![
            CatalogueEntry {
                name: "ResNet".into(),
                version: 2,
                state: ModelState::Deployed,
                validation_accuracy: 0.955,
                optimal_cap: Some(0.62),
                artifact: Some("resnet_mini".into()),
            },
            CatalogueEntry {
                name: "LeNet".into(),
                version: 1,
                state: ModelState::Trained,
                validation_accuracy: 0.754,
                optimal_cap: None,
                artifact: None,
            },
        ];
        for e in &entries {
            let j = line(|js| w_catalogue_entry(js, Some("e"), e));
            assert_eq!(r_catalogue_entry(j.req("e").unwrap()).unwrap(), *e);
        }
        assert!(parse_model_state("warp").is_err());
    }

    #[test]
    fn traffic_config_round_trips_both_kinds() {
        let mut t = TrafficConfig::default();
        t.kind = ArrivalKind::bursty();
        t.path = crate::traffic::TrafficPath::ForceAggregate;
        for cfg in [TrafficConfig::default(), t] {
            let j = line(|js| w_traffic_config(js, Some("t"), &cfg));
            let back = r_traffic_config(j.req("t").unwrap()).unwrap();
            assert_eq!(back.users_per_site, cfg.users_per_site);
            assert_eq!(back.kind, cfg.kind);
            assert_eq!(back.path, cfg.path);
            assert_eq!(
                back.diurnal.normalised_weights(),
                cfg.diurnal.normalised_weights(),
                "weights survive bit-exactly without renormalisation"
            );
            assert_eq!(back.slo, cfg.slo);
            assert_eq!(back.exact_request_threshold, cfg.exact_request_threshold);
        }
    }

    #[test]
    fn sampler_ckpt_round_trips() {
        let mut gpu_w = StreamingSummary::new();
        gpu_w.push(100.0);
        let s = SamplerCkpt {
            nvml: ((0x1234, 0x5678), 150_000),
            rapl_pkg: (1234.5, 0xDEAD_BEEF),
            next_due: Some(Seconds(17.3)),
            last_pkg: Some((Seconds(17.2), 42)),
            samples: vec![PowerSample {
                at: Seconds(17.2),
                gpu: Watts(140.0),
                cpu: Watts(60.0),
                dram: Watts(24.0),
                gpu_util: 0.9,
            }],
            evicted: 3,
            gpu_w,
            total_w: StreamingSummary::new(),
        };
        let j = line(|js| w_sampler(js, Some("s"), &s));
        let back = r_sampler(j.req("s").unwrap()).unwrap();
        assert_eq!(back.nvml, s.nvml);
        assert_eq!(back.rapl_pkg, s.rapl_pkg);
        assert_eq!(back.next_due, s.next_due);
        assert_eq!(back.last_pkg, s.last_pkg);
        assert_eq!(back.samples, s.samples);
        assert_eq!(back.evicted, s.evicted);
        assert_eq!(back.gpu_w.state_parts(), s.gpu_w.state_parts());

        // And the None/absent cases.
        let none = SamplerCkpt { next_due: None, last_pkg: None, ..s };
        let j = line(|js| w_sampler(js, Some("s"), &none));
        let back = r_sampler(j.req("s").unwrap()).unwrap();
        assert_eq!(back.next_due, None);
        assert_eq!(back.last_pkg, None);
    }
}
