//! Snapshot container IO (DESIGN.md §15): the hashing writer, atomic
//! file handling, keep-last-K retention, and the validating lazy reader.
//!
//! The write path streams every section line through a [`HashingWriter`]
//! so the footer checksum costs no second pass; the read path validates
//! the whole container up front (UTF-8, trailing newline, footer
//! checksum, header version) and then parses individual sections lazily
//! — a resume only pays for the sections it touches, and a corrupt file
//! can never be *half*-restored.

use std::fs::{self, File};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::obs::export::JsonStream;
use crate::util::Json;

use super::codec::{hex_u64, parse_hex_u64};
use super::FORMAT_VERSION;

/// Snapshot file extension (`snap-r<round:06>.frostsnap`).
pub const SNAP_EXT: &str = "frostsnap";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over a byte slice — the same constants the bus's edge hash
/// uses, kept dependency-free and byte-order independent.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A [`Write`] adapter folding every written byte into a running
/// FNV-1a 64 digest.  The snapshot writer threads all section lines
/// through it; the footer itself is written to the inner writer after
/// [`HashingWriter::into_parts`], so the digest covers exactly the bytes
/// that precede the footer line.
pub struct HashingWriter<W: Write> {
    inner: W,
    hash: u64,
}

impl<W: Write> HashingWriter<W> {
    pub fn new(inner: W) -> HashingWriter<W> {
        HashingWriter { inner, hash: FNV_OFFSET }
    }

    /// Digest of everything written so far.
    pub fn digest(&self) -> u64 {
        self.hash
    }

    pub fn into_parts(self) -> (W, u64) {
        (self.inner, self.hash)
    }
}

impl<W: Write> Write for HashingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        for &b in &buf[..n] {
            self.hash ^= u64::from(b);
            self.hash = self.hash.wrapping_mul(FNV_PRIME);
        }
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Identity of a snapshot: what kind of run it belongs to and where in
/// the run it was taken.  Serialised as the first line of the container
/// and validated (version first) before any section parses.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotHeader {
    /// Driver kind: `"fleet"`, `"scenario"` or `"chaos"` — `frost resume`
    /// dispatches on it.
    pub kind: String,
    /// Round the snapshot was taken at (state is *after* this round).
    pub round: u32,
    /// The run's fleet seed.
    pub seed: u64,
    /// Number of sites (cross-checked against the restored config).
    pub sites: usize,
    /// Scenario or chaos preset name ("" for a plain fleet run).
    pub preset: String,
}

/// Streaming snapshot writer: one JSONL section per [`SnapshotWriter::section`]
/// call, each line hashed as it is written, the checksum footer appended
/// by [`SnapshotWriter::finish`].
pub struct SnapshotWriter<W: Write> {
    out: HashingWriter<W>,
}

impl<W: Write> SnapshotWriter<W> {
    /// Open a writer and emit the header line.
    pub fn new(out: W, header: &SnapshotHeader) -> io::Result<SnapshotWriter<W>> {
        let mut sw = SnapshotWriter { out: HashingWriter::new(out) };
        sw.section("header", |js| {
            js.u64_field(Some("version"), u64::from(FORMAT_VERSION));
            js.str_field(Some("kind"), &header.kind);
            js.u64_field(Some("round"), u64::from(header.round));
            js.str_field(Some("seed"), &hex_u64(header.seed));
            js.u64_field(Some("sites"), header.sites as u64);
            js.str_field(Some("preset"), &header.preset);
        })?;
        Ok(sw)
    }

    /// Write one section line: `{"s":"<name>", …body fields…}`.  The
    /// closure receives the open [`JsonStream`] positioned inside the
    /// object, after the `"s"` tag.
    pub fn section<F>(&mut self, name: &str, body: F) -> io::Result<()>
    where
        F: FnOnce(&mut JsonStream<&mut HashingWriter<W>>),
    {
        let mut js = JsonStream::new(&mut self.out);
        js.begin_obj(None);
        js.str_field(Some("s"), name);
        body(&mut js);
        js.end_obj();
        js.finish().map(|_| ())
    }

    /// Append the checksum footer (written past the hasher, so the
    /// stored digest covers every byte before the footer line) and
    /// return the inner writer.
    pub fn finish(self) -> io::Result<W> {
        let (mut out, digest) = self.out.into_parts();
        let mut js = JsonStream::new(&mut out);
        js.begin_obj(None);
        js.str_field(Some("s"), "footer");
        js.str_field(Some("fnv64"), &hex_u64(digest));
        js.end_obj();
        js.finish()?;
        Ok(out)
    }
}

/// Canonical snapshot path for a round: zero-padded so lexicographic
/// directory order is round order.
pub fn snapshot_path(dir: &Path, round: u32) -> PathBuf {
    dir.join(format!("snap-r{round:06}.{SNAP_EXT}"))
}

/// Write one snapshot atomically: temp file in `dir`, fsync, rename over
/// the final name, fsync the directory.  A crash at any point leaves the
/// directory with either the old snapshot set or the completed new file
/// — never a torn `.frostsnap`.
pub fn write_snapshot_file<F>(dir: &Path, header: &SnapshotHeader, body: F) -> Result<PathBuf>
where
    F: FnOnce(&mut SnapshotWriter<BufWriter<File>>) -> Result<()>,
{
    fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let path = snapshot_path(dir, header.round);
    let tmp = dir.join(format!("snap-r{:06}.tmp", header.round));
    let file =
        File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
    let mut sw = SnapshotWriter::new(BufWriter::new(file), header)
        .with_context(|| format!("write snapshot header to {}", tmp.display()))?;
    body(&mut sw)?;
    let buf = sw
        .finish()
        .with_context(|| format!("write snapshot footer to {}", tmp.display()))?;
    let file = buf.into_inner().map_err(|e| e.into_error()).context("flush snapshot")?;
    file.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    drop(file);
    fs::rename(&tmp, &path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    // Make the rename itself durable.  Directory fsync is best-effort:
    // some filesystems refuse to sync a directory handle, and the rename
    // above already guarantees no torn file exists either way.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(path)
}

/// All snapshot files in `dir`, oldest → newest (a missing directory is
/// an empty set, not an error).  `.tmp` leftovers from a crashed write
/// are excluded by the extension filter.
pub fn list_snapshots(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut snaps = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(snaps),
        Err(e) => {
            return Err(e)
                .with_context(|| format!("read checkpoint dir {}", dir.display()))
        }
    };
    for entry in rd {
        let p = entry
            .with_context(|| format!("read checkpoint dir {}", dir.display()))?
            .path();
        let named_like_snapshot = p.extension().and_then(|e| e.to_str()) == Some(SNAP_EXT)
            && p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("snap-r"))
                .unwrap_or(false);
        if named_like_snapshot && p.is_file() {
            snaps.push(p);
        }
    }
    snaps.sort();
    Ok(snaps)
}

/// Keep-last-K retention: delete all but the newest `keep` snapshots.
/// Returns the removed paths (for logging/CI artifact bookkeeping).
pub fn prune_snapshots(dir: &Path, keep: usize) -> Result<Vec<PathBuf>> {
    let keep = keep.max(1);
    let mut snaps = list_snapshots(dir)?;
    let mut removed = Vec::new();
    while snaps.len() > keep {
        let p = snaps.remove(0);
        fs::remove_file(&p)
            .with_context(|| format!("remove old snapshot {}", p.display()))?;
        removed.push(p);
    }
    Ok(removed)
}

/// Cheap section-name extraction.  Every line the writer emits starts
/// `{"s":"<name>"` with an escape-free name; anything else falls back to
/// a full parse in the caller.
fn section_name(line: &str) -> Option<&str> {
    let rest = line.strip_prefix("{\"s\":\"")?;
    let end = rest.find('"')?;
    let name = &rest[..end];
    if name.ends_with('\\') {
        return None; // escaped quote — not one of ours; full-parse instead
    }
    Some(name)
}

/// A loaded, validated snapshot.  Loading verifies the container as a
/// whole (checksum, footer, header version); section payloads stay as
/// raw lines and parse lazily on access, so a resume pays only for what
/// it reads.
#[derive(Debug)]
pub struct Snapshot {
    pub path: PathBuf,
    pub header: SnapshotHeader,
    /// Raw body lines (header included, footer excluded), file order.
    lines: Vec<String>,
    /// `(section name, index into lines)`, file order.
    index: Vec<(String, usize)>,
}

impl Snapshot {
    /// Load and validate one snapshot file.  Truncated, corrupt, or
    /// version-mismatched files are rejected *in full* — there is no
    /// partial restore path.
    pub fn load(path: &Path) -> Result<Snapshot> {
        let bytes =
            fs::read(path).with_context(|| format!("read snapshot {}", path.display()))?;
        Snapshot::from_bytes(path.to_path_buf(), bytes)
    }

    fn from_bytes(path: PathBuf, bytes: Vec<u8>) -> Result<Snapshot> {
        let text = String::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("snapshot {} is not UTF-8", path.display()))?;
        anyhow::ensure!(
            text.ends_with('\n'),
            "snapshot {} is truncated (no trailing newline)",
            path.display()
        );
        let mut lines: Vec<&str> = text[..text.len() - 1].split('\n').collect();
        anyhow::ensure!(lines.len() >= 2, "snapshot {} is too short", path.display());
        let footer_line = lines.pop().expect("length checked above");

        // 1. Footer + checksum over every byte before the footer line.
        let footer = Json::parse(footer_line).map_err(|e| {
            anyhow::anyhow!("snapshot {} footer unreadable: {e}", path.display())
        })?;
        anyhow::ensure!(
            footer.get("s").and_then(|s| s.as_str()) == Some("footer"),
            "snapshot {} is truncated (last line is not the footer)",
            path.display()
        );
        let want =
            parse_hex_u64(footer.req("fnv64")?.as_str().context("footer fnv64")?)
                .context("footer fnv64")?;
        let hashed = text.len() - footer_line.len() - 1;
        let got = fnv1a64(&text.as_bytes()[..hashed]);
        anyhow::ensure!(
            got == want,
            "snapshot {} fails its checksum (stored {}, computed {}) — rejecting the file",
            path.display(),
            hex_u64(want),
            hex_u64(got)
        );

        // 2. Header, version first.
        let header_json = Json::parse(lines[0]).map_err(|e| {
            anyhow::anyhow!("snapshot {} header unreadable: {e}", path.display())
        })?;
        anyhow::ensure!(
            header_json.get("s").and_then(|s| s.as_str()) == Some("header"),
            "snapshot {} does not start with a header line",
            path.display()
        );
        let version = header_json.req("version")?.as_i64().context("header version")?;
        anyhow::ensure!(
            version == i64::from(FORMAT_VERSION),
            "snapshot {} has format version {version}; this build reads version {FORMAT_VERSION}",
            path.display()
        );
        let header = SnapshotHeader {
            kind: header_json.req("kind")?.as_str().context("header kind")?.to_string(),
            round: u32::try_from(
                header_json.req("round")?.as_i64().context("header round")?,
            )
            .ok()
            .context("header round out of range")?,
            seed: parse_hex_u64(
                header_json.req("seed")?.as_str().context("header seed")?,
            )
            .context("header seed")?,
            sites: header_json.req("sites")?.as_usize().context("header sites")?,
            preset: header_json
                .req("preset")?
                .as_str()
                .context("header preset")?
                .to_string(),
        };

        // 3. Section index: cheap prefix extraction, full parse fallback.
        let mut index = Vec::with_capacity(lines.len());
        for (i, line) in lines.iter().enumerate() {
            let name = match section_name(line) {
                Some(n) => n.to_string(),
                None => Json::parse(line)
                    .map_err(|e| {
                        anyhow::anyhow!(
                            "snapshot {} line {} unreadable: {e}",
                            path.display(),
                            i + 1
                        )
                    })?
                    .req("s")?
                    .as_str()
                    .context("section name")?
                    .to_string(),
            };
            index.push((name, i));
        }
        let lines = lines.into_iter().map(str::to_string).collect();
        Ok(Snapshot { path, header, lines, index })
    }

    /// Parse the unique section `name`; error if absent or duplicated.
    pub fn section(&self, name: &str) -> Result<Json> {
        let mut hits = self.index.iter().filter(|(n, _)| n.as_str() == name);
        let (_, i) = hits.next().with_context(|| {
            format!("snapshot {} has no '{name}' section", self.path.display())
        })?;
        anyhow::ensure!(
            hits.next().is_none(),
            "snapshot {} has multiple '{name}' sections",
            self.path.display()
        );
        self.parse_line(*i)
    }

    /// Parse every section named `name`, in file order (used for
    /// repeated per-site sections).
    pub fn sections(&self, name: &str) -> Result<Vec<Json>> {
        self.index
            .iter()
            .filter(|(n, _)| n.as_str() == name)
            .map(|(_, i)| self.parse_line(*i))
            .collect()
    }

    pub fn has_section(&self, name: &str) -> bool {
        self.index.iter().any(|(n, _)| n.as_str() == name)
    }

    fn parse_line(&self, i: usize) -> Result<Json> {
        Json::parse(&self.lines[i]).map_err(|e| {
            anyhow::anyhow!("snapshot {} line {}: {e}", self.path.display(), i + 1)
        })
    }
}

/// Load the newest loadable snapshot in `dir`, walking newest → oldest
/// past files that fail validation — the recovery path after a crash
/// corrupted the most recent write.  Returns the snapshot plus every
/// rejected `(path, error)` pair so callers can surface the fallback.
pub fn load_latest(dir: &Path) -> Result<(Snapshot, Vec<(PathBuf, anyhow::Error)>)> {
    let snaps = list_snapshots(dir)?;
    anyhow::ensure!(!snaps.is_empty(), "no snapshots in {}", dir.display());
    let mut rejected = Vec::new();
    for p in snaps.iter().rev() {
        match Snapshot::load(p) {
            Ok(s) => return Ok((s, rejected)),
            Err(e) => rejected.push((p.clone(), e)),
        }
    }
    let detail = rejected
        .iter()
        .map(|(p, e)| format!("  {}: {e:#}", p.display()))
        .collect::<Vec<_>>()
        .join("\n");
    anyhow::bail!("every snapshot in {} failed to load:\n{detail}", dir.display())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header(round: u32) -> SnapshotHeader {
        SnapshotHeader {
            kind: "fleet".into(),
            round,
            seed: 0x0102_0304_0506_0708,
            sites: 4,
            preset: String::new(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("frost-ckpt-io-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_one(dir: &Path, round: u32) -> PathBuf {
        write_snapshot_file(dir, &header(round), |sw| {
            sw.section("alpha", |js| {
                js.str_field(Some("v"), "first");
            })?;
            sw.section("site", |js| {
                js.u64_field(Some("i"), 0);
            })?;
            sw.section("site", |js| {
                js.u64_field(Some("i"), 1);
            })?;
            Ok(())
        })
        .unwrap()
    }

    #[test]
    fn fnv_matches_the_reference_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn hashing_writer_digest_matches_one_shot_hash() {
        let mut hw = HashingWriter::new(Vec::new());
        hw.write_all(b"hello ").unwrap();
        hw.write_all(b"world").unwrap();
        let (bytes, digest) = hw.into_parts();
        assert_eq!(bytes, b"hello world");
        assert_eq!(digest, fnv1a64(b"hello world"));
    }

    #[test]
    fn snapshot_round_trips_through_the_file_format() {
        let dir = tmpdir("roundtrip");
        let p = write_one(&dir, 7);
        assert_eq!(p, snapshot_path(&dir, 7));
        let s = Snapshot::load(&p).unwrap();
        assert_eq!(s.header, header(7));
        let a = s.section("alpha").unwrap();
        assert_eq!(a.req("v").unwrap().as_str(), Some("first"));
        let sites = s.sections("site").unwrap();
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[1].req("i").unwrap().as_i64(), Some(1));
        assert!(!s.has_section("gamma"));
        assert!(s.section("gamma").is_err(), "missing section is an error");
        assert!(s.section("site").is_err(), "duplicated section is an error for section()");
    }

    #[test]
    fn every_possible_truncation_is_rejected() {
        let dir = tmpdir("truncate");
        let p = write_one(&dir, 1);
        let full = fs::read(&p).unwrap();
        let t = dir.join(format!("cut.{SNAP_EXT}"));
        for cut in 0..full.len() {
            fs::write(&t, &full[..cut]).unwrap();
            assert!(
                Snapshot::load(&t).is_err(),
                "a {cut}-byte prefix of a {}-byte snapshot must be rejected",
                full.len()
            );
        }
    }

    #[test]
    fn bit_corruption_fails_the_checksum() {
        let dir = tmpdir("corrupt");
        let p = write_one(&dir, 1);
        let mut bytes = fs::read(&p).unwrap();
        // Flip case of the first 'f' (lands in the header's "fleet",
        // well before the footer line): still UTF-8, still valid JSON.
        let i = bytes.iter().position(|&b| b == b'f').unwrap();
        bytes[i] ^= 0x20;
        fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", Snapshot::load(&p).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn version_mismatch_is_rejected_even_with_a_valid_checksum() {
        let dir = tmpdir("version");
        let body = "{\"s\":\"header\",\"version\":99,\"kind\":\"fleet\",\"round\":1,\
                    \"seed\":\"0000000000000001\",\"sites\":1,\"preset\":\"\"}\n";
        let digest = fnv1a64(body.as_bytes());
        let p = snapshot_path(&dir, 1);
        fs::write(
            &p,
            format!("{body}{{\"s\":\"footer\",\"fnv64\":\"{}\"}}\n", hex_u64(digest)),
        )
        .unwrap();
        let err = format!("{:#}", Snapshot::load(&p).unwrap_err());
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn retention_keeps_the_newest_k() {
        let dir = tmpdir("retention");
        for r in 1..=5 {
            write_one(&dir, r);
        }
        let removed = prune_snapshots(&dir, 2).unwrap();
        assert_eq!(removed.len(), 3);
        assert_eq!(
            list_snapshots(&dir).unwrap(),
            vec![snapshot_path(&dir, 4), snapshot_path(&dir, 5)]
        );
        // Pruning an empty/missing dir is a no-op, keep=0 keeps one.
        assert!(prune_snapshots(&tmpdir("retention-empty"), 0).unwrap().is_empty());
    }

    #[test]
    fn load_latest_falls_back_past_a_corrupt_newest() {
        let dir = tmpdir("fallback");
        write_one(&dir, 1);
        let newest = write_one(&dir, 2);
        let mut bytes = fs::read(&newest).unwrap();
        let cut = bytes.len() - 9;
        bytes.truncate(cut);
        fs::write(&newest, &bytes).unwrap();
        let (snap, rejected) = load_latest(&dir).unwrap();
        assert_eq!(snap.header.round, 1, "fell back to the previous retained snapshot");
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0, newest);
    }

    #[test]
    fn load_latest_errors_when_no_snapshot_is_loadable() {
        let dir = tmpdir("allbad");
        let p = write_one(&dir, 1);
        fs::write(&p, b"garbage").unwrap();
        let err = format!("{:#}", load_latest(&dir).unwrap_err());
        assert!(err.contains("failed to load"), "{err}");
        assert!(load_latest(&tmpdir("empty")).is_err(), "empty dir is an error");
    }

    #[test]
    fn tmp_leftovers_are_invisible_to_listing() {
        let dir = tmpdir("leftover");
        write_one(&dir, 3);
        fs::write(dir.join("snap-r000009.tmp"), b"torn half-write").unwrap();
        assert_eq!(list_snapshots(&dir).unwrap(), vec![snapshot_path(&dir, 3)]);
    }
}
