//! Crash-safe checkpoint/resume (DESIGN.md §15).
//!
//! A **snapshot** is a versioned, checksummed JSONL file capturing the
//! complete deterministic state of a fleet run at a round boundary:
//! per-site arrival RNG streams, queued request groups, latency
//! histograms, SMO policy book and leases, quarantine and profile-retry
//! state machines, the scenario cursor, fault-plan RNG, monitor state,
//! metrics registry and trace sink.  Because round-boundary state is
//! thread-count-independent (§6), a snapshot taken under any worker
//! count resumes bit-identically under any other.
//!
//! Layout (one JSON object per line, written through
//! [`crate::obs::export::JsonStream`] — no intermediate [`crate::util::Json`]
//! trees):
//!
//! ```text
//! {"s":"header","version":2,"kind":"fleet","round":12,"seed":"…",…}
//! {"s":"<section>",…}                  // one line per stateful layer
//! {"s":"footer","fnv64":"<hex16>"}     // FNV-1a 64 of all prior bytes
//! ```
//!
//! Durability: snapshots are written to a temp file, fsynced, renamed
//! into place, and the directory is fsynced — a crash mid-write leaves
//! either the previous snapshot set intact or a `.tmp` file the reader
//! ignores.  The reader ([`io::Snapshot`]) hard-rejects truncated,
//! corrupt, or version-mismatched files; [`io::load_latest`] then falls
//! back to the previous retained snapshot (keep-last-K retention,
//! [`io::prune_snapshots`]).
//!
//! Number encoding: `u64` and `f64` values cross the boundary as 16-char
//! lowercase hex strings ([`codec::hex_u64`] / [`codec::hex_f64`]) —
//! JSON numbers are f64, which loses `u64` precision above 2⁵³, prints
//! `-0.0` as `0`, and nulls non-finite values (`NEG_INFINITY` is
//! legitimate state in the SMO's KPM watermarks).  Structurally small
//! integers (indices, rounds, lengths) use exact decimal fields.

pub mod codec;
pub mod io;
pub mod snapshot;

use std::path::PathBuf;

pub use io::{
    fnv1a64, list_snapshots, load_latest, prune_snapshots, snapshot_path, write_snapshot_file,
    HashingWriter, Snapshot, SnapshotHeader, SnapshotWriter, SNAP_EXT,
};
pub use snapshot::{
    restore_fleet, restore_fleet_with, snapshot_config, write_fleet_snapshot,
    write_fleet_snapshot_with,
};

/// Snapshot container format version.  Bump on any incompatible change
/// to the section layout; the reader rejects mismatches outright rather
/// than guessing at a half-compatible restore.
///
/// History: 1 = initial layout; 2 = region tier (§16) — trace events
/// carry a `region` tag, the config section gains a `regions` map, and
/// hierarchical fleets write a `regions` state section.
pub const FORMAT_VERSION: u32 = 2;

/// Default keep-last-K retention depth.
pub const DEFAULT_KEEP: usize = 3;

/// Checkpoint/crash-injection options threaded through the fleet,
/// scenario and chaos drivers (`frost fleet|scenario|chaos --checkpoint`).
#[derive(Debug, Clone)]
pub struct CkptOptions {
    /// Snapshot directory; `None` disables checkpointing entirely.
    pub dir: Option<PathBuf>,
    /// Snapshot cadence in rounds (0 is treated as 1).
    pub every: u32,
    /// Keep the newest `keep` snapshots (0 is treated as 1).
    pub keep: usize,
    /// Crash injection: kill the run immediately after the round-`crash_at`
    /// snapshot is durable.  The round is snapshotted even off-cadence so
    /// the crash point is always resumable.
    pub crash_at: Option<u32>,
}

impl CkptOptions {
    /// Checkpointing off — the no-op options plain (non-`_ckpt`) drivers
    /// delegate with.
    pub fn disabled() -> CkptOptions {
        CkptOptions { dir: None, every: 1, keep: DEFAULT_KEEP, crash_at: None }
    }

    /// Checkpoint into `dir` every round with default retention.
    pub fn at(dir: PathBuf) -> CkptOptions {
        CkptOptions { dir: Some(dir), every: 1, keep: DEFAULT_KEEP, crash_at: None }
    }

    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Should round `round` be snapshotted?  True on the cadence and,
    /// regardless of cadence, at the crash-injection round.
    pub fn due(&self, round: u32) -> bool {
        self.enabled() && (self.crash_at == Some(round) || round % self.every.max(1) == 0)
    }
}

impl Default for CkptOptions {
    fn default() -> CkptOptions {
        CkptOptions::disabled()
    }
}

/// What a checkpointable driver run produced: either the completed
/// report, or the injected crash point (round + durable snapshot) the
/// harness can resume from.
#[derive(Debug)]
pub enum DriveOutcome<T> {
    Done(T),
    Crashed { round: u32, snapshot: PathBuf },
}

impl<T> DriveOutcome<T> {
    /// Unwrap a run that cannot have crash injection armed.
    pub fn expect_done(self, what: &str) -> T {
        match self {
            DriveOutcome::Done(t) => t,
            DriveOutcome::Crashed { round, .. } => {
                panic!("{what}: crash injection fired at round {round} without --crash-at-round")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_options_are_never_due() {
        let o = CkptOptions::disabled();
        assert!(!o.enabled());
        for r in 0..20 {
            assert!(!o.due(r));
        }
    }

    #[test]
    fn cadence_and_crash_round_are_due() {
        let mut o = CkptOptions::at(PathBuf::from("/tmp/x"));
        o.every = 4;
        o.crash_at = Some(6);
        assert!(o.due(4) && o.due(8), "cadence rounds");
        assert!(o.due(6), "crash round forces an off-cadence snapshot");
        assert!(!o.due(5) && !o.due(7));
    }

    #[test]
    fn zero_cadence_is_treated_as_every_round() {
        let mut o = CkptOptions::at(PathBuf::from("/tmp/x"));
        o.every = 0;
        assert!(o.due(1) && o.due(2));
    }

    #[test]
    #[should_panic(expected = "crash injection fired")]
    fn expect_done_panics_on_a_crash_outcome() {
        let out: DriveOutcome<()> =
            DriveOutcome::Crashed { round: 3, snapshot: PathBuf::from("x") };
        out.expect_done("test");
    }
}
