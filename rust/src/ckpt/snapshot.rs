//! Whole-fleet snapshot: capture and bit-exact restore (DESIGN.md §15).
//!
//! [`write_fleet_snapshot`] streams every piece of mutable fleet state
//! through the [`super::codec`] writers into one versioned, checksummed
//! `.frostsnap` file; [`restore_fleet`] rebuilds a [`Fleet`] from it that
//! is indistinguishable from the uninterrupted run — same report bits,
//! same trace, same future random draws.
//!
//! Restore ordering contract (violations break bit-identity, so the order
//! is load-bearing and pinned by the round-trip tests):
//!
//! 1. `Fleet::new(config)` reconstructs everything derivable from config
//!    alone (endpoints, fault *plan*, traffic shapes, zoo wiring) and
//!    leaves construction chatter (subscriptions, initial pushes) behind.
//! 2. The global bus restore then *replaces* queue/inboxes/stats wholesale
//!    and restores held messages **after** the fault state — installing a
//!    fault plan clears the held buffer, so held must land last.
//! 3. Per site: host scalars, then the testbed (which installs the cap and
//!    defensively invalidates the step cache), then the step cache (whose
//!    counters overwrite that spurious invalidation), then telemetry,
//!    local bus, and traffic.
//! 4. SMO / non-RT RIC / coordinator state bypass the message-emitting
//!    mutators (`deploy`, `put_policy`, …) — replaying those onto the
//!    fabric would diverge from the run being resumed.
//! 5. `fleet.round` comes from the header last.
//!
//! Snapshot bytes are canonical: every unordered container is sorted (or
//! already `BTreeMap`-backed) before serialisation, so the same fleet
//! state always produces the same file — and a restore followed by a
//! snapshot reproduces the original file byte for byte (pinned below).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::frost::policy::QosClass;
use crate::obs::export::JsonStream;
use crate::obs::CapCause;
use crate::oran::fleet::{RegionRt, SteadyDelta};
use crate::oran::{
    Bus, Fleet, FleetConfig, FleetSite, NonRtRic, RegionMap, RegionSpec, SchedulerCkpt, Smo,
};
use crate::simulator::CacheCkpt;
use crate::util::Json;
use crate::zoo::all_models;

use super::codec::{
    hex_u64, intern_static, jarr, jbool, jf64, jopt_f64, jopt_u64, jstr, ju32, ju64, jusize,
    parse_hex_f64, parse_hex_u64, r_catalogue_entry, r_fault_config, r_fault_ledger,
    r_hist, r_kpm, r_lifecycle, r_oran_msg, r_pcg32, r_policy, r_power_reading,
    r_profile_outcome, r_profile_record, r_sampler, r_scenario, r_slot_report, r_summary,
    r_trace_event, r_traffic_config, r_workload, vf64, vu64, w_catalogue_entry, w_f64,
    w_fault_config, w_fault_ledger, w_hist, w_kpm, w_lifecycle, w_opt_f64, w_opt_u64,
    w_oran_msg, w_pcg32, w_policy, w_power_reading, w_profile_outcome, w_profile_record,
    w_sampler, w_scenario, w_slot_report, w_summary, w_trace_event, w_traffic_config,
    w_u64, w_workload, KNOWN_KPM_REASONS,
};
use super::io::{prune_snapshots, write_snapshot_file, Snapshot, SnapshotHeader, SnapshotWriter};

/// Keys `Bus::stats` can report: one per interface plus the drop counter.
/// (`codec::KNOWN_INTERFACES` alone misses `"dropped"`.)
pub const KNOWN_BUS_STATS: &[&'static str] = &["A1", "O1", "O2", "-", "dropped"];

/// Metric names the fleet registry holds at a round boundary.  Report-time
/// fold-in names are included too so a registry cloned from a report also
/// restores without leaking new interned strings.
pub const KNOWN_METRICS: &[&'static str] = &[
    "bus.A1",
    "bus.O1",
    "bus.O2",
    "bus.dropped",
    "cache.hits",
    "cache.invalidations",
    "cache.misses",
    "fleet.regions",
    "fleet.sites",
    "holdback.dropped",
    "kpm.rejected",
    "lease.expiries",
    "lease.renewals",
    "monitor.load_shifts",
    "monitor.rejected",
    "monitor.reprofiles",
    "quarantine.events",
    "region.disturbances",
    "region.gateway_kpms",
    "region.steady_rounds",
    "round.cap_w",
];

// ------------------------------------------------------------ config

fn w_fleet_config<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, c: &FleetConfig) {
    js.begin_obj(name);
    js.u64_field(Some("sites"), c.sites as u64);
    w_u64(js, Some("seed"), c.seed);
    js.u64_field(Some("threads"), c.threads as u64);
    js.u64_field(Some("rounds"), u64::from(c.rounds));
    js.u64_field(Some("train_epochs"), u64::from(c.train_epochs));
    w_u64(js, Some("samples_per_epoch"), c.samples_per_epoch);
    w_u64(js, Some("infer_steps_per_round"), c.infer_steps_per_round);
    w_f64(js, Some("budget_frac"), c.budget_frac);
    js.u64_field(Some("max_concurrent_profiles"), c.max_concurrent_profiles as u64);
    js.bool_field(Some("frost_enabled"), c.frost_enabled);
    js.u64_field(Some("churn_every"), u64::from(c.churn_every));
    w_f64(js, Some("min_accuracy"), c.min_accuracy);
    js.u64_field(Some("sample_retention"), c.sample_retention as u64);
    if let Some(t) = &c.traffic {
        w_traffic_config(js, Some("traffic"), t);
    }
    if let Some(s) = &c.scenario {
        w_scenario(js, Some("scenario"), s);
    }
    if let Some(f) = &c.faults {
        w_fault_config(js, Some("faults"), f);
    }
    if let Some(rm) = &c.regions {
        js.begin_obj(Some("regions"));
        js.begin_arr(Some("specs"));
        for s in &rm.regions {
            js.begin_obj(None);
            js.str_field(Some("name"), &s.name);
            w_f64(js, Some("weight"), s.weight);
            js.end_obj();
        }
        js.end_arr();
        js.begin_arr(Some("site_region"));
        for r in &rm.site_region {
            js.u64_field(None, u64::from(*r));
        }
        js.end_arr();
        js.end_obj();
    }
    js.u64_field(Some("policy_lease_rounds"), u64::from(c.policy_lease_rounds));
    js.u64_field(Some("profile_timeout_rounds"), u64::from(c.profile_timeout_rounds));
    js.u64_field(Some("profile_max_attempts"), u64::from(c.profile_max_attempts));
    js.u64_field(Some("quarantine_rounds"), u64::from(c.quarantine_rounds));
    js.u64_field(Some("holdback_cap"), c.holdback_cap as u64);
    js.bool_field(Some("trace"), c.trace);
    js.end_obj();
}

fn r_fleet_config(j: &Json) -> Result<FleetConfig> {
    Ok(FleetConfig {
        sites: jusize(j, "sites")?,
        seed: ju64(j, "seed")?,
        threads: jusize(j, "threads")?,
        rounds: ju32(j, "rounds")?,
        train_epochs: ju32(j, "train_epochs")?,
        samples_per_epoch: ju64(j, "samples_per_epoch")?,
        infer_steps_per_round: ju64(j, "infer_steps_per_round")?,
        budget_frac: jf64(j, "budget_frac")?,
        max_concurrent_profiles: jusize(j, "max_concurrent_profiles")?,
        frost_enabled: jbool(j, "frost_enabled")?,
        churn_every: ju32(j, "churn_every")?,
        min_accuracy: jf64(j, "min_accuracy")?,
        sample_retention: jusize(j, "sample_retention")?,
        traffic: match j.get("traffic") {
            Some(t) => Some(r_traffic_config(t)?),
            None => None,
        },
        scenario: match j.get("scenario") {
            Some(s) => Some(r_scenario(s)?),
            None => None,
        },
        faults: match j.get("faults") {
            Some(f) => Some(r_fault_config(f)?),
            None => None,
        },
        regions: match j.get("regions") {
            Some(r) => {
                let mut specs = Vec::new();
                for s in jarr(r, "specs")? {
                    specs.push(RegionSpec {
                        name: jstr(s, "name")?.to_string(),
                        weight: jf64(s, "weight")?,
                    });
                }
                let mut site_region = Vec::new();
                for v in jarr(r, "site_region")? {
                    site_region.push(
                        u32::try_from(v.as_i64().context("site_region entry")?)
                            .ok()
                            .context("site_region entry out of range")?,
                    );
                }
                Some(RegionMap { regions: specs, site_region })
            }
            None => None,
        },
        policy_lease_rounds: ju32(j, "policy_lease_rounds")?,
        profile_timeout_rounds: ju32(j, "profile_timeout_rounds")?,
        profile_max_attempts: ju32(j, "profile_max_attempts")?,
        quarantine_rounds: ju32(j, "quarantine_rounds")?,
        holdback_cap: jusize(j, "holdback_cap")?,
        trace: jbool(j, "trace")?,
    })
}

// ------------------------------------------------------------ bus

fn w_bus_fields<W: Write>(js: &mut JsonStream<W>, bus: &Bus, with_fault: bool) {
    js.begin_arr(Some("queue"));
    for (from, to, pending, msg) in bus.ckpt_queue() {
        js.begin_obj(None);
        js.str_field(Some("from"), &from);
        js.str_field(Some("to"), &to);
        js.bool_field(Some("pending"), pending);
        w_oran_msg(js, Some("m"), &msg);
        js.end_obj();
    }
    js.end_arr();
    js.begin_arr(Some("held"));
    for (due, from, to, pending, msg) in bus.ckpt_held() {
        js.begin_obj(None);
        js.u64_field(Some("due"), u64::from(due));
        js.str_field(Some("from"), &from);
        js.str_field(Some("to"), &to);
        js.bool_field(Some("pending"), pending);
        w_oran_msg(js, Some("m"), &msg);
        js.end_obj();
    }
    js.end_arr();
    js.begin_arr(Some("inboxes"));
    for (ep, msgs) in bus.ckpt_inboxes() {
        js.begin_obj(None);
        js.str_field(Some("ep"), &ep);
        js.begin_arr(Some("msgs"));
        for (from, msg) in msgs {
            js.begin_obj(None);
            js.str_field(Some("from"), &from);
            w_oran_msg(js, Some("m"), &msg);
            js.end_obj();
        }
        js.end_arr();
        js.end_obj();
    }
    js.end_arr();
    js.begin_obj(Some("stats"));
    for (k, v) in bus.stats() {
        w_u64(js, Some(k), v);
    }
    js.end_obj();
    if with_fault {
        if let Some((round, seq, ledger)) = bus.ckpt_fault_state() {
            js.begin_obj(Some("fault"));
            js.u64_field(Some("round"), u64::from(round));
            w_u64(js, Some("seq"), seq);
            w_fault_ledger(js, Some("ledger"), &ledger);
            js.end_obj();
        }
    }
}

fn restore_bus_fields(j: &Json, bus: &Bus, with_fault: bool) -> Result<()> {
    let mut queue = Vec::new();
    for it in jarr(j, "queue")? {
        queue.push((
            Arc::<str>::from(jstr(it, "from")?),
            Arc::<str>::from(jstr(it, "to")?),
            jbool(it, "pending")?,
            r_oran_msg(it.req("m")?)?,
        ));
    }
    bus.restore_ckpt_queue(queue);
    let mut inboxes = Vec::new();
    for it in jarr(j, "inboxes")? {
        let mut msgs = Vec::new();
        for m in jarr(it, "msgs")? {
            msgs.push((Arc::<str>::from(jstr(m, "from")?), r_oran_msg(m.req("m")?)?));
        }
        inboxes.push((Arc::<str>::from(jstr(it, "ep")?), msgs));
    }
    bus.restore_ckpt_inboxes(inboxes);
    let stats_obj = j.req("stats")?.as_obj().context("bus stats is not an object")?;
    let mut stats = Vec::new();
    for (k, v) in stats_obj {
        let raw =
            v.as_str().with_context(|| format!("bus stat '{k}' is not a string"))?;
        stats.push((intern_static(k.as_str(), KNOWN_BUS_STATS), parse_hex_u64(raw)?));
    }
    bus.restore_ckpt_stats(stats);
    if with_fault {
        if let Some(f) = j.get("fault") {
            bus.restore_ckpt_fault_state(
                ju32(f, "round")?,
                ju64(f, "seq")?,
                r_fault_ledger(f.req("ledger")?)?,
            );
        }
    }
    // Held messages land last: installing a fault plan (done by the
    // fleet reconstruction from config) clears the held buffer.
    let mut held = Vec::new();
    for it in jarr(j, "held")? {
        held.push((
            ju32(it, "due")?,
            Arc::<str>::from(jstr(it, "from")?),
            Arc::<str>::from(jstr(it, "to")?),
            jbool(it, "pending")?,
            r_oran_msg(it.req("m")?)?,
        ));
    }
    bus.restore_ckpt_held(held);
    Ok(())
}

// ------------------------------------------------------------ step cache

fn w_cache<W: Write>(js: &mut JsonStream<W>, name: Option<&str>, c: &CacheCkpt) {
    js.begin_obj(name);
    w_u64(js, Some("hits"), c.hits);
    w_u64(js, Some("misses"), c.misses);
    w_u64(js, Some("invalidations"), c.invalidations);
    js.begin_arr(Some("workloads"));
    for (bits, id) in &c.workloads {
        js.begin_obj(None);
        js.u64_field(Some("id"), u64::from(*id));
        js.begin_arr(Some("fp"));
        for b in bits {
            js.str_field(None, &hex_u64(*b));
        }
        js.end_arr();
        js.end_obj();
    }
    js.end_arr();
    js.begin_arr(Some("keys"));
    for (w, batch, train, cap) in &c.keys {
        js.begin_obj(None);
        js.u64_field(Some("w"), u64::from(*w));
        js.u64_field(Some("batch"), u64::from(*batch));
        js.bool_field(Some("train"), *train);
        w_u64(js, Some("cap"), *cap);
        js.end_obj();
    }
    js.end_arr();
    js.end_obj();
}

fn r_cache(j: &Json) -> Result<CacheCkpt> {
    let mut workloads = Vec::new();
    for it in jarr(j, "workloads")? {
        let fp = jarr(it, "fp")?;
        anyhow::ensure!(fp.len() == 7, "workload fingerprint must have 7 fields");
        let mut bits = [0u64; 7];
        for (slot, b) in bits.iter_mut().zip(fp) {
            *slot = vu64(b)?;
        }
        workloads.push((bits, ju32(it, "id")?));
    }
    let mut keys = Vec::new();
    for it in jarr(j, "keys")? {
        keys.push((ju32(it, "w")?, ju32(it, "batch")?, jbool(it, "train")?, ju64(it, "cap")?));
    }
    Ok(CacheCkpt {
        hits: ju64(j, "hits")?,
        misses: ju64(j, "misses")?,
        invalidations: ju64(j, "invalidations")?,
        workloads,
        keys,
    })
}

// ------------------------------------------------------------ site

fn w_site_fields<W: Write>(js: &mut JsonStream<W>, site: &FleetSite) {
    js.u64_field(Some("i"), site.index as u64);
    js.str_field(Some("name"), &site.name);
    // -- inference host --
    w_policy(js, Some("policy"), &site.host.policy);
    js.u64_field(Some("batch"), u64::from(site.host.batch));
    w_f64(js, Some("total_energy_j"), site.host.total_energy_j);
    w_u64(js, Some("total_samples"), site.host.total_samples);
    w_u64(js, Some("errors"), site.host.errors);
    w_u64(js, Some("lease_expiries"), site.host.lease_expiries);
    js.begin_arr(Some("profile_log"));
    for p in &site.host.profile_log {
        w_profile_outcome(js, None, p);
    }
    js.end_arr();
    let (store, kpm_seq, lease_left, pre_fallback_cap) = site.host.ckpt_state();
    js.begin_arr(Some("store"));
    for (k, w) in store {
        js.begin_obj(None);
        js.str_field(Some("k"), k.as_str());
        w_workload(js, Some("w"), w);
        js.end_obj();
    }
    js.end_arr();
    w_u64(js, Some("kpm_seq"), kpm_seq);
    w_opt_u64(js, Some("lease_left"), lease_left.map(u64::from));
    w_opt_f64(js, Some("pre_fallback_cap"), pre_fallback_cap);
    // -- testbed, then its step cache --
    let ((tb_state, tb_inc), tb_cap, tb_now) = site.host.testbed.ckpt_state();
    w_u64(js, Some("tb_rng_state"), tb_state);
    w_u64(js, Some("tb_rng_inc"), tb_inc);
    w_f64(js, Some("tb_cap"), tb_cap);
    w_f64(js, Some("tb_now"), tb_now);
    w_cache(js, Some("cache"), &site.host.testbed.ckpt_cache());
    // -- telemetry --
    let (cur, (gpu_j, cpu_j, dram_j), recent, evicted, total_w, gpu_w) = site.hub.ckpt_state();
    js.begin_obj(Some("hub"));
    w_power_reading(js, Some("cur"), &cur);
    w_f64(js, Some("gpu_j"), gpu_j);
    w_f64(js, Some("cpu_j"), cpu_j);
    w_f64(js, Some("dram_j"), dram_j);
    js.begin_arr(Some("recent"));
    for r in &recent {
        w_power_reading(js, None, r);
    }
    js.end_arr();
    w_u64(js, Some("evicted"), evicted);
    w_summary(js, Some("total_w"), &total_w);
    w_summary(js, Some("gpu_w"), &gpu_w);
    js.end_obj();
    w_sampler(js, Some("sampler"), &site.sampler.ckpt_state());
    // -- site scalars --
    let (zoo_index, rounds_run) = site.ckpt_site_state();
    js.u64_field(Some("zoo_index"), zoo_index as u64);
    js.u64_field(Some("rounds_run"), u64::from(rounds_run));
    js.str_field(Some("model_id"), &site.model_id);
    w_workload(js, Some("workload"), &site.workload);
    js.str_field(Some("qos"), site.qos.as_str());
    js.bool_field(Some("trained"), site.trained);
    js.u64_field(Some("epochs_trained"), u64::from(site.epochs_trained));
    w_f64(js, Some("workload_energy_j"), site.workload_energy_j);
    w_f64(js, Some("round_energy_j"), site.round_energy_j);
    w_f64(js, Some("profiling_energy_j"), site.profiling_energy_j);
    w_f64(js, Some("wall_s"), site.wall_s);
    w_u64(js, Some("samples"), site.samples);
    w_f64(js, Some("accuracy"), site.accuracy);
    w_f64(js, Some("last_gpu_power_w"), site.last_gpu_power_w);
    js.bool_field(Some("down"), site.down);
    // -- site-local fabric (never fault-injected) --
    js.begin_obj(Some("lbus"));
    w_bus_fields(js, site.ckpt_local_bus(), false);
    js.end_obj();
    // -- traffic --
    if let Some(tr) = &site.traffic {
        js.begin_obj(Some("traffic"));
        let (gen_rng, rate_mult, burst, next_switch) = tr.ckpt_gen().ckpt_state();
        w_pcg32(js, Some("gen_rng"), &gen_rng);
        w_f64(js, Some("gen_rate"), rate_mult);
        js.bool_field(Some("gen_burst"), burst);
        w_f64(js, Some("gen_next"), next_switch);
        let m = tr.ckpt_monitor();
        let (baseline, ewma, load_baseline, load_ewma, seen, last_reprofile, last_at) =
            m.ckpt_state();
        w_opt_f64(js, Some("mon_baseline"), baseline);
        w_opt_f64(js, Some("mon_ewma"), ewma);
        w_opt_f64(js, Some("mon_load_baseline"), load_baseline);
        w_opt_f64(js, Some("mon_load_ewma"), load_ewma);
        js.u64_field(Some("mon_seen"), seen as u64);
        w_opt_f64(js, Some("mon_last_reprofile"), last_reprofile);
        w_opt_f64(js, Some("mon_last_at"), last_at);
        w_u64(js, Some("mon_reprofiles"), m.reprofiles);
        w_u64(js, Some("mon_load_shifts"), m.load_shifts);
        w_u64(js, Some("mon_rejected"), m.rejected);
        w_u64(js, Some("pending_shed"), tr.ckpt_pending_shed());
        js.begin_arr(Some("srv_queue"));
        for (at, dl, n) in tr.server.queued_groups() {
            js.begin_obj(None);
            w_f64(js, Some("at"), at);
            w_f64(js, Some("dl"), dl);
            w_u64(js, Some("n"), n);
            js.end_obj();
        }
        js.end_arr();
        w_f64(js, Some("srv_t_free"), tr.server.t_free);
        w_u64(js, Some("srv_served"), tr.server.served);
        w_u64(js, Some("srv_dropped"), tr.server.dropped);
        w_u64(js, Some("srv_late"), tr.server.late);
        w_u64(js, Some("srv_batches"), tr.server.batches);
        w_u64(js, Some("srv_batch_samples"), tr.server.batch_samples);
        js.begin_arr(Some("latencies"));
        for l in &tr.latencies {
            w_f64(js, None, *l);
        }
        js.end_arr();
        w_hist(js, Some("hist"), &tr.hist);
        js.begin_arr(Some("phase_hists"));
        for h in &tr.phase_hists {
            w_hist(js, None, h);
        }
        js.end_arr();
        js.begin_arr(Some("slot_log"));
        for s in &tr.slot_log {
            w_slot_report(js, None, s);
        }
        js.end_arr();
        js.u64_field(Some("slots_served"), u64::from(tr.slots_served));
        w_u64(js, Some("offered_today"), tr.offered_today);
        w_f64(js, Some("day_energy_j"), tr.day_energy_j);
        w_u64(js, Some("reprofile_requests"), tr.reprofile_requests);
        js.end_obj();
    }
}

fn restore_site_fields(j: &Json, site: &mut FleetSite) -> Result<()> {
    let name = jstr(j, "name")?;
    anyhow::ensure!(
        name == site.name,
        "snapshot site '{name}' does not match reconstructed site '{}'",
        site.name
    );
    // -- inference host --
    site.host.policy = r_policy(j.req("policy")?)?;
    site.host.batch = ju32(j, "batch")?;
    site.host.total_energy_j = jf64(j, "total_energy_j")?;
    site.host.total_samples = ju64(j, "total_samples")?;
    site.host.errors = ju64(j, "errors")?;
    site.host.lease_expiries = ju64(j, "lease_expiries")?;
    site.host.profile_log =
        jarr(j, "profile_log")?.iter().map(r_profile_outcome).collect::<Result<Vec<_>>>()?;
    let mut store = BTreeMap::new();
    for it in jarr(j, "store")? {
        store.insert(jstr(it, "k")?.to_string(), r_workload(it.req("w")?)?);
    }
    let lease_left = match jopt_u64(j, "lease_left")? {
        Some(v) => Some(u32::try_from(v).ok().context("lease_left out of range")?),
        None => None,
    };
    site.host.restore_ckpt_state(
        store,
        ju64(j, "kpm_seq")?,
        lease_left,
        jopt_f64(j, "pre_fallback_cap")?,
    );
    // -- testbed first, then the step cache: the testbed hook installs the
    // cap the retained keys were solved under and bumps the invalidation
    // counter, which the cache restore overwrites --
    site.host.testbed.restore_ckpt_state((
        (ju64(j, "tb_rng_state")?, ju64(j, "tb_rng_inc")?),
        jf64(j, "tb_cap")?,
        jf64(j, "tb_now")?,
    ));
    site.host.testbed.restore_ckpt_cache(&r_cache(j.req("cache")?)?);
    // -- telemetry --
    let hub = j.req("hub")?;
    let recent =
        jarr(hub, "recent")?.iter().map(r_power_reading).collect::<Result<Vec<_>>>()?;
    site.hub.restore_ckpt_state((
        r_power_reading(hub.req("cur")?)?,
        (jf64(hub, "gpu_j")?, jf64(hub, "cpu_j")?, jf64(hub, "dram_j")?),
        recent,
        ju64(hub, "evicted")?,
        r_summary(hub.req("total_w")?)?,
        r_summary(hub.req("gpu_w")?)?,
    ));
    site.sampler.restore_ckpt_state(r_sampler(j.req("sampler")?)?);
    // -- site scalars --
    let zoo_index = jusize(j, "zoo_index")?;
    let zoo = all_models();
    anyhow::ensure!(
        zoo_index < zoo.len(),
        "zoo index {zoo_index} out of range ({} models)",
        zoo.len()
    );
    site.zoo_model = zoo[zoo_index].name;
    site.restore_ckpt_site_state(zoo_index, ju32(j, "rounds_run")?);
    site.model_id = jstr(j, "model_id")?.to_string();
    site.workload = r_workload(j.req("workload")?)?;
    site.qos = QosClass::parse(jstr(j, "qos")?)?;
    site.trained = jbool(j, "trained")?;
    site.epochs_trained = ju32(j, "epochs_trained")?;
    site.workload_energy_j = jf64(j, "workload_energy_j")?;
    site.round_energy_j = jf64(j, "round_energy_j")?;
    site.profiling_energy_j = jf64(j, "profiling_energy_j")?;
    site.wall_s = jf64(j, "wall_s")?;
    site.samples = ju64(j, "samples")?;
    site.accuracy = jf64(j, "accuracy")?;
    site.last_gpu_power_w = jf64(j, "last_gpu_power_w")?;
    site.down = jbool(j, "down")?;
    // -- site-local fabric --
    restore_bus_fields(j.req("lbus")?, site.ckpt_local_bus(), false)?;
    // -- traffic --
    match (j.get("traffic"), site.traffic.as_mut()) {
        (Some(t), Some(tr)) => {
            tr.ckpt_gen_mut().restore_ckpt_state(
                r_pcg32(t.req("gen_rng")?)?,
                jf64(t, "gen_rate")?,
                jbool(t, "gen_burst")?,
                jf64(t, "gen_next")?,
            );
            tr.ckpt_monitor_mut().restore_ckpt_state((
                jopt_f64(t, "mon_baseline")?,
                jopt_f64(t, "mon_ewma")?,
                jopt_f64(t, "mon_load_baseline")?,
                jopt_f64(t, "mon_load_ewma")?,
                jusize(t, "mon_seen")?,
                jopt_f64(t, "mon_last_reprofile")?,
                jopt_f64(t, "mon_last_at")?,
            ));
            let m = tr.ckpt_monitor_mut();
            m.reprofiles = ju64(t, "mon_reprofiles")?;
            m.load_shifts = ju64(t, "mon_load_shifts")?;
            m.rejected = ju64(t, "mon_rejected")?;
            tr.restore_ckpt_pending_shed(ju64(t, "pending_shed")?);
            let mut groups = Vec::new();
            for g in jarr(t, "srv_queue")? {
                groups.push((jf64(g, "at")?, jf64(g, "dl")?, ju64(g, "n")?));
            }
            tr.server.restore_ckpt_state(
                groups,
                jf64(t, "srv_t_free")?,
                ju64(t, "srv_served")?,
                ju64(t, "srv_dropped")?,
                ju64(t, "srv_late")?,
                ju64(t, "srv_batches")?,
                ju64(t, "srv_batch_samples")?,
            );
            tr.latencies =
                jarr(t, "latencies")?.iter().map(vf64).collect::<Result<Vec<_>>>()?;
            tr.hist = r_hist(t.req("hist")?)?;
            tr.phase_hists =
                jarr(t, "phase_hists")?.iter().map(r_hist).collect::<Result<Vec<_>>>()?;
            tr.slot_log =
                jarr(t, "slot_log")?.iter().map(r_slot_report).collect::<Result<Vec<_>>>()?;
            tr.slots_served = ju32(t, "slots_served")?;
            tr.offered_today = ju64(t, "offered_today")?;
            tr.day_energy_j = jf64(t, "day_energy_j")?;
            tr.reprofile_requests = ju64(t, "reprofile_requests")?;
        }
        (None, None) => {}
        (snap, live) => anyhow::bail!(
            "traffic mismatch for site '{name}': snapshot {}, reconstructed fleet {}",
            if snap.is_some() { "has it" } else { "lacks it" },
            if live.is_some() { "has it" } else { "lacks it" },
        ),
    }
    Ok(())
}

// ------------------------------------------------------------ smo

fn w_smo_fields<W: Write>(js: &mut JsonStream<W>, smo: &Smo) {
    js.str_field(Some("name"), &smo.name);
    let (offered_load, latency_p99, kpm_watermarks, kpm_rejects, policy_book) = smo.ckpt_state();
    js.begin_obj(Some("offered_load"));
    for (k, v) in offered_load {
        w_f64(js, Some(k.as_str()), *v);
    }
    js.end_obj();
    js.begin_obj(Some("latency_p99"));
    for (k, v) in latency_p99 {
        w_f64(js, Some(k.as_str()), *v);
    }
    js.end_obj();
    js.begin_arr(Some("kpm_watermarks"));
    for (k, (at, seq)) in kpm_watermarks {
        js.begin_obj(None);
        js.str_field(Some("k"), k.as_str());
        w_f64(js, Some("at"), *at);
        w_u64(js, Some("seq"), *seq);
        js.end_obj();
    }
    js.end_arr();
    js.begin_obj(Some("kpm_rejects"));
    for (k, v) in kpm_rejects {
        w_u64(js, Some(*k), *v);
    }
    js.end_obj();
    js.begin_arr(Some("policy_book"));
    for (k, p) in policy_book {
        js.begin_obj(None);
        js.str_field(Some("k"), k.as_str());
        w_policy(js, Some("p"), p);
        js.end_obj();
    }
    js.end_arr();
    js.begin_arr(Some("kpms"));
    for k in &smo.kpms {
        w_kpm(js, None, k);
    }
    js.end_arr();
    js.begin_arr(Some("profile_records"));
    for r in &smo.profile_records {
        w_profile_record(js, None, r);
    }
    js.end_arr();
    js.begin_arr(Some("lifecycle_log"));
    for e in &smo.lifecycle_log {
        w_lifecycle(js, None, e);
    }
    js.end_arr();
    let (a1_policies, a1_subscribers) = smo.a1.ckpt_state();
    js.begin_arr(Some("a1_policies"));
    for p in a1_policies {
        w_policy(js, None, p);
    }
    js.end_arr();
    js.begin_arr(Some("a1_subscribers"));
    for s in a1_subscribers {
        js.str_field(None, s.as_str());
    }
    js.end_arr();
}

fn restore_smo_fields(j: &Json, smo: &mut Smo) -> Result<()> {
    fn hex_map(j: &Json, name: &str) -> Result<BTreeMap<String, f64>> {
        let obj =
            j.req(name)?.as_obj().with_context(|| format!("'{name}' is not an object"))?;
        let mut m = BTreeMap::new();
        for (k, v) in obj {
            let raw = v
                .as_str()
                .with_context(|| format!("'{name}.{k}' is not a string"))?;
            m.insert(k.clone(), parse_hex_f64(raw)?);
        }
        Ok(m)
    }
    let name = jstr(j, "name")?;
    anyhow::ensure!(
        name == smo.name,
        "snapshot SMO '{name}' does not match reconstructed SMO '{}'",
        smo.name
    );
    let offered_load = hex_map(j, "offered_load")?;
    let latency_p99 = hex_map(j, "latency_p99")?;
    let mut kpm_watermarks = BTreeMap::new();
    for it in jarr(j, "kpm_watermarks")? {
        kpm_watermarks
            .insert(jstr(it, "k")?.to_string(), (jf64(it, "at")?, ju64(it, "seq")?));
    }
    let rejects_obj =
        j.req("kpm_rejects")?.as_obj().context("kpm_rejects is not an object")?;
    let mut kpm_rejects: BTreeMap<&'static str, u64> = BTreeMap::new();
    for (k, v) in rejects_obj {
        let raw =
            v.as_str().with_context(|| format!("kpm reject '{k}' is not a string"))?;
        kpm_rejects.insert(intern_static(k.as_str(), KNOWN_KPM_REASONS), parse_hex_u64(raw)?);
    }
    let mut policy_book = BTreeMap::new();
    for it in jarr(j, "policy_book")? {
        policy_book.insert(jstr(it, "k")?.to_string(), r_policy(it.req("p")?)?);
    }
    smo.restore_ckpt_state(offered_load, latency_p99, kpm_watermarks, kpm_rejects, policy_book);
    smo.kpms = jarr(j, "kpms")?.iter().map(r_kpm).collect::<Result<Vec<_>>>()?;
    smo.profile_records =
        jarr(j, "profile_records")?.iter().map(r_profile_record).collect::<Result<Vec<_>>>()?;
    smo.lifecycle_log =
        jarr(j, "lifecycle_log")?.iter().map(r_lifecycle).collect::<Result<Vec<_>>>()?;
    let policies = jarr(j, "a1_policies")?.iter().map(r_policy).collect::<Result<Vec<_>>>()?;
    let subscribers = jarr(j, "a1_subscribers")?
        .iter()
        .map(|s| {
            s.as_str().map(str::to_string).context("a1 subscriber is not a string")
        })
        .collect::<Result<Vec<_>>>()?;
    smo.a1.restore_ckpt_state(policies, subscribers);
    Ok(())
}

// ------------------------------------------------------------ non-RT RIC

fn w_nonrt_fields<W: Write>(js: &mut JsonStream<W>, nonrt: &NonRtRic) {
    js.str_field(Some("name"), &nonrt.name);
    js.begin_arr(Some("catalogue"));
    for e in nonrt.catalogue.ckpt_entries() {
        w_catalogue_entry(js, None, e);
    }
    js.end_arr();
    if let Some(s) = nonrt.ckpt_scheduler_state() {
        js.begin_obj(Some("sched"));
        js.u64_field(Some("cursor"), s.cursor as u64);
        w_u64(js, Some("requested"), s.requested);
        w_u64(js, Some("rng_state"), s.rng.0);
        w_u64(js, Some("rng_inc"), s.rng.1);
        w_u64(js, Some("round"), s.round);
        js.begin_arr(Some("pending"));
        for (sitename, attempts, next) in &s.pending {
            js.begin_obj(None);
            js.str_field(Some("site"), sitename.as_str());
            js.u64_field(Some("attempts"), u64::from(*attempts));
            w_u64(js, Some("next"), *next);
            js.end_obj();
        }
        js.end_arr();
        w_u64(js, Some("retries"), s.retries);
        js.end_obj();
    }
}

fn restore_nonrt_fields(j: &Json, nonrt: &mut NonRtRic) -> Result<()> {
    let name = jstr(j, "name")?;
    anyhow::ensure!(
        name == nonrt.name,
        "snapshot non-RT RIC '{name}' does not match '{}'",
        nonrt.name
    );
    let entries =
        jarr(j, "catalogue")?.iter().map(r_catalogue_entry).collect::<Result<Vec<_>>>()?;
    nonrt.catalogue.restore_ckpt_state(entries);
    if let Some(s) = j.get("sched") {
        let mut pending = Vec::new();
        for it in jarr(s, "pending")? {
            pending.push((jstr(it, "site")?.to_string(), ju32(it, "attempts")?, ju64(it, "next")?));
        }
        nonrt.restore_scheduler_state(&SchedulerCkpt {
            cursor: jusize(s, "cursor")?,
            requested: ju64(s, "requested")?,
            rng: (ju64(s, "rng_state")?, ju64(s, "rng_inc")?),
            round: ju64(s, "round")?,
            pending,
            retries: ju64(s, "retries")?,
        });
    }
    Ok(())
}

// ------------------------------------------------------------ coordinator

fn w_coord_fields<W: Write>(js: &mut JsonStream<W>, fleet: &Fleet) {
    let (profiles_ingested, lifecycle_ingested, budget_applied, ever_enforced, pending) =
        fleet.ckpt_coord_state();
    js.u64_field(Some("profiles_ingested"), profiles_ingested as u64);
    js.u64_field(Some("lifecycle_ingested"), lifecycle_ingested as u64);
    js.bool_field(Some("budget_applied"), budget_applied);
    js.bool_field(Some("ever_enforced"), ever_enforced);
    if let Some((cause, anchor)) = pending {
        js.begin_obj(Some("pending_cause"));
        js.str_field(Some("cause"), cause.as_str());
        w_opt_u64(js, Some("anchor"), anchor);
        js.end_obj();
    }
    js.begin_arr(Some("quarantine_release"));
    for r in fleet.ckpt_quarantine_release() {
        w_opt_u64(js, None, (*r).map(u64::from));
    }
    js.end_arr();
    let (quarantined, quarantine_events) = fleet.ckpt_profile_health();
    js.begin_arr(Some("quarantined"));
    for q in &quarantined {
        js.str_field(None, q.as_str());
    }
    js.end_arr();
    w_u64(js, Some("quarantine_events"), quarantine_events);
    js.begin_arr(Some("assignments"));
    for (h, m) in fleet.ckpt_assignments() {
        js.begin_obj(None);
        js.str_field(Some("h"), &h);
        js.str_field(Some("m"), &m);
        js.end_obj();
    }
    js.end_arr();
    if let Some((next, surge, derate, pre_derate, budget_frac)) = fleet.ckpt_scenario_state() {
        js.begin_obj(Some("scen"));
        js.u64_field(Some("next"), next as u64);
        js.begin_arr(Some("surge"));
        for v in surge {
            w_f64(js, None, *v);
        }
        js.end_arr();
        js.begin_arr(Some("derate"));
        for v in derate {
            w_f64(js, None, *v);
        }
        js.end_arr();
        js.begin_arr(Some("pre_derate"));
        for p in pre_derate {
            js.begin_obj(None);
            if let Some((cap, mult)) = p {
                w_f64(js, Some("cap"), *cap);
                w_f64(js, Some("mult"), *mult);
            }
            js.end_obj();
        }
        js.end_arr();
        w_f64(js, Some("budget_frac"), budget_frac);
        js.end_obj();
    }
}

fn restore_coord_fields(j: &Json, fleet: &mut Fleet) -> Result<()> {
    let mut release = Vec::new();
    for v in jarr(j, "quarantine_release")? {
        let s = v.as_str().context("quarantine_release element is not a string")?;
        release.push(if s.is_empty() {
            None
        } else {
            Some(
                u32::try_from(parse_hex_u64(s)?)
                    .ok()
                    .context("quarantine release round out of range")?,
            )
        });
    }
    fleet.restore_ckpt_quarantine_release(release);
    let quarantined = jarr(j, "quarantined")?
        .iter()
        .map(|v| {
            v.as_str().map(str::to_string).context("quarantined element is not a string")
        })
        .collect::<Result<Vec<_>>>()?;
    fleet.restore_ckpt_profile_health(quarantined, ju64(j, "quarantine_events")?);
    let mut assignments = Vec::new();
    for it in jarr(j, "assignments")? {
        assignments.push((jstr(it, "h")?.to_string(), jstr(it, "m")?.to_string()));
    }
    fleet.restore_ckpt_assignments(assignments);
    if let Some(s) = j.get("scen") {
        let surge = jarr(s, "surge")?.iter().map(vf64).collect::<Result<Vec<_>>>()?;
        let derate = jarr(s, "derate")?.iter().map(vf64).collect::<Result<Vec<_>>>()?;
        let mut pre = Vec::new();
        for p in jarr(s, "pre_derate")? {
            pre.push(match p.get("cap") {
                Some(_) => Some((jf64(p, "cap")?, jf64(p, "mult")?)),
                None => None,
            });
        }
        fleet.restore_ckpt_scenario_state(
            jusize(s, "next")?,
            surge,
            derate,
            pre,
            jf64(s, "budget_frac")?,
        );
    }
    let pending = match j.get("pending_cause") {
        Some(p) => {
            let cs = jstr(p, "cause")?;
            let cause = CapCause::from_str_name(cs)
                .with_context(|| format!("unknown cap cause '{cs}'"))?;
            Some((cause, jopt_u64(p, "anchor")?))
        }
        None => None,
    };
    fleet.restore_ckpt_coord_state(
        jusize(j, "profiles_ingested")?,
        jusize(j, "lifecycle_ingested")?,
        jbool(j, "budget_applied")?,
        jbool(j, "ever_enforced")?,
        pending,
    );
    Ok(())
}

// ------------------------------------------------------------ regions

/// `Option<SteadyDelta>` as an object: empty = `None` (the `pre_derate`
/// convention), else the six delta scalars under short keys.
fn w_opt_delta<W: Write>(js: &mut JsonStream<W>, d: &Option<SteadyDelta>) {
    js.begin_obj(None);
    if let Some(d) = d {
        w_f64(js, Some("dt"), d.d_total_j);
        w_f64(js, Some("dp"), d.d_profiling_j);
        w_f64(js, Some("rj"), d.round_j);
        w_f64(js, Some("dw"), d.d_wall_s);
        w_u64(js, Some("ds"), d.d_samples);
        w_f64(js, Some("gw"), d.last_gpu_power_w);
    }
    js.end_obj();
}

fn r_opt_delta(j: &Json) -> Result<Option<SteadyDelta>> {
    Ok(match j.get("dt") {
        Some(_) => Some(SteadyDelta {
            d_total_j: jf64(j, "dt")?,
            d_profiling_j: jf64(j, "dp")?,
            round_j: jf64(j, "rj")?,
            d_wall_s: jf64(j, "dw")?,
            d_samples: ju64(j, "ds")?,
            last_gpu_power_w: jf64(j, "gw")?,
        }),
        None => None,
    })
}

/// Region-tier runtime state (§16).  The map, member lists and gateway
/// endpoints are derivable from config ([`Fleet::new`] rebuilds them);
/// only the mutable coordination state crosses the boundary.
fn w_region_fields<W: Write>(js: &mut JsonStream<W>, rt: &RegionRt) {
    js.begin_arr(Some("gw_seq"));
    for s in &rt.gw_seq {
        w_u64(js, None, *s);
    }
    js.end_arr();
    js.begin_arr(Some("sub_budget_w"));
    for b in &rt.sub_budget_w {
        w_opt_f64(js, None, *b);
    }
    js.end_arr();
    js.begin_arr(Some("site_load"));
    for l in &rt.site_load {
        w_f64(js, None, *l);
    }
    js.end_arr();
    js.begin_arr(Some("steady"));
    for d in &rt.steady {
        w_opt_delta(js, d);
    }
    js.end_arr();
    js.begin_arr(Some("prev_delta"));
    for d in &rt.prev_delta {
        w_opt_delta(js, d);
    }
    js.end_arr();
    js.begin_arr(Some("dirty"));
    for d in &rt.dirty {
        js.bool_field(None, *d);
    }
    js.end_arr();
    js.begin_arr(Some("steady_rounds"));
    for s in &rt.steady_rounds {
        w_u64(js, None, *s);
    }
    js.end_arr();
    w_u64(js, Some("disturbances"), rt.disturbances);
}

fn restore_region_fields(j: &Json, rt: &mut RegionRt) -> Result<()> {
    let gw_seq = jarr(j, "gw_seq")?.iter().map(vu64).collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(
        gw_seq.len() == rt.gw_seq.len(),
        "regions section has {} regions, reconstructed fleet has {}",
        gw_seq.len(),
        rt.gw_seq.len()
    );
    let mut sub_budget_w = Vec::new();
    for v in jarr(j, "sub_budget_w")? {
        let s = v.as_str().context("sub_budget_w element is not a string")?;
        sub_budget_w.push(if s.is_empty() { None } else { Some(parse_hex_f64(s)?) });
    }
    anyhow::ensure!(sub_budget_w.len() == rt.sub_budget_w.len(), "sub_budget_w length mismatch");
    let site_load = jarr(j, "site_load")?.iter().map(vf64).collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(
        site_load.len() == rt.site_load.len(),
        "regions section covers {} sites, reconstructed fleet has {}",
        site_load.len(),
        rt.site_load.len()
    );
    let steady = jarr(j, "steady")?.iter().map(r_opt_delta).collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(steady.len() == rt.steady.len(), "steady length mismatch");
    let prev_delta =
        jarr(j, "prev_delta")?.iter().map(r_opt_delta).collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(prev_delta.len() == rt.prev_delta.len(), "prev_delta length mismatch");
    let mut dirty = Vec::new();
    for v in jarr(j, "dirty")? {
        dirty.push(v.as_bool().context("dirty element is not a bool")?);
    }
    anyhow::ensure!(dirty.len() == rt.dirty.len(), "dirty length mismatch");
    let steady_rounds =
        jarr(j, "steady_rounds")?.iter().map(vu64).collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(steady_rounds.len() == rt.steady_rounds.len(), "steady_rounds length mismatch");
    rt.gw_seq = gw_seq;
    rt.sub_budget_w = sub_budget_w;
    rt.site_load = site_load;
    rt.steady = steady;
    rt.prev_delta = prev_delta;
    rt.dirty = dirty;
    rt.steady_rounds = steady_rounds;
    rt.disturbances = ju64(j, "disturbances")?;
    Ok(())
}

// ------------------------------------------------------------ metrics + trace

fn w_metrics_fields<W: Write>(js: &mut JsonStream<W>, fleet: &Fleet) {
    let m = fleet.ckpt_metrics();
    js.begin_obj(Some("counters"));
    for (k, v) in m.counters() {
        w_u64(js, Some(k), v);
    }
    js.end_obj();
    js.begin_obj(Some("gauges"));
    for (k, v) in m.gauges() {
        w_f64(js, Some(k), v);
    }
    js.end_obj();
    js.begin_arr(Some("summaries"));
    for (k, s) in m.summaries() {
        js.begin_obj(None);
        js.str_field(Some("k"), k);
        w_summary(js, Some("s"), s);
        js.end_obj();
    }
    js.end_arr();
}

fn restore_metrics_fields(j: &Json, fleet: &mut Fleet) -> Result<()> {
    let cobj = j.req("counters")?.as_obj().context("counters is not an object")?;
    let mut counters = Vec::new();
    for (k, v) in cobj {
        let raw = v.as_str().with_context(|| format!("counter '{k}' is not a string"))?;
        counters.push((intern_static(k.as_str(), KNOWN_METRICS), parse_hex_u64(raw)?));
    }
    let gobj = j.req("gauges")?.as_obj().context("gauges is not an object")?;
    let mut gauges = Vec::new();
    for (k, v) in gobj {
        let raw = v.as_str().with_context(|| format!("gauge '{k}' is not a string"))?;
        gauges.push((intern_static(k.as_str(), KNOWN_METRICS), parse_hex_f64(raw)?));
    }
    let mut summaries = Vec::new();
    for it in jarr(j, "summaries")? {
        summaries.push((intern_static(jstr(it, "k")?, KNOWN_METRICS), r_summary(it.req("s")?)?));
    }
    fleet.ckpt_metrics_mut().restore_ckpt_state(counters, gauges, summaries);
    Ok(())
}

fn w_trace_fields<W: Write>(js: &mut JsonStream<W>, fleet: &Fleet) {
    let (round, anchor, events) = fleet.trace.ckpt_state();
    js.u64_field(Some("round"), u64::from(round));
    w_opt_u64(js, Some("anchor"), anchor);
    js.begin_arr(Some("events"));
    for e in events {
        w_trace_event(js, None, e);
    }
    js.end_arr();
}

fn restore_trace_fields(j: &Json, fleet: &mut Fleet) -> Result<()> {
    let events = jarr(j, "events")?.iter().map(r_trace_event).collect::<Result<Vec<_>>>()?;
    fleet.trace.restore_ckpt_state(ju32(j, "round")?, jopt_u64(j, "anchor")?, events);
    Ok(())
}

// ------------------------------------------------------------ entry points

/// Snapshot one fleet to `dir` and prune to the newest `keep` files.
pub fn write_fleet_snapshot(
    fleet: &Fleet,
    kind: &str,
    preset: &str,
    dir: &Path,
    keep: usize,
) -> Result<PathBuf> {
    write_fleet_snapshot_with(fleet, kind, preset, dir, keep, |_| Ok(()))
}

/// Like [`write_fleet_snapshot`], with `extra` appending driver-specific
/// sections (e.g. a figure driver's audit accumulators) before the footer.
pub fn write_fleet_snapshot_with<F>(
    fleet: &Fleet,
    kind: &str,
    preset: &str,
    dir: &Path,
    keep: usize,
    extra: F,
) -> Result<PathBuf>
where
    F: FnOnce(&mut SnapshotWriter<BufWriter<File>>) -> Result<()>,
{
    let header = SnapshotHeader {
        kind: kind.to_string(),
        round: fleet.round,
        seed: fleet.config.seed,
        sites: fleet.config.sites,
        preset: preset.to_string(),
    };
    let path = write_snapshot_file(dir, &header, |sw| {
        sw.section("config", |js| w_fleet_config(js, Some("c"), &fleet.config))?;
        sw.section("bus", |js| w_bus_fields(js, &fleet.bus, true))?;
        for site in &fleet.sites {
            sw.section("site", |js| w_site_fields(js, site))?;
        }
        sw.section("smo", |js| w_smo_fields(js, &fleet.smo))?;
        sw.section("nonrt", |js| w_nonrt_fields(js, &fleet.nonrt))?;
        sw.section("coord", |js| w_coord_fields(js, fleet))?;
        if let Some(rt) = fleet.ckpt_region_state() {
            sw.section("regions", |js| w_region_fields(js, rt))?;
        }
        sw.section("metrics", |js| w_metrics_fields(js, fleet))?;
        sw.section("trace", |js| w_trace_fields(js, fleet))?;
        extra(sw)?;
        Ok(())
    })?;
    prune_snapshots(dir, keep)?;
    Ok(path)
}

/// Parse just the config section of a snapshot — e.g. for the CLI to
/// rebuild output context (traffic shape, scenario name) before a resume.
pub fn snapshot_config(snap: &Snapshot) -> Result<FleetConfig> {
    let config_sec = snap.section("config")?;
    r_fleet_config(config_sec.req("c")?)
        .with_context(|| format!("snapshot {}: bad config section", snap.path.display()))
}

/// Rebuild a [`Fleet`] from a loaded snapshot, bit-exactly.
pub fn restore_fleet(snap: &Snapshot) -> Result<Fleet> {
    restore_fleet_with(snap, None)
}

/// [`restore_fleet`] with a worker-thread override.  Round-boundary state
/// is thread-count independent (DESIGN.md §6), so a snapshot taken under
/// any worker count resumes bit-identically under any other — `frost
/// resume --threads T` relies on this.
pub fn restore_fleet_with(snap: &Snapshot, threads: Option<usize>) -> Result<Fleet> {
    let mut config = snapshot_config(snap)?;
    if let Some(t) = threads {
        config.threads = t;
    }
    anyhow::ensure!(
        config.sites == snap.header.sites && config.seed == snap.header.seed,
        "snapshot {}: header (sites {}, seed {:#018x}) disagrees with config (sites {}, seed {:#018x})",
        snap.path.display(),
        snap.header.sites,
        snap.header.seed,
        config.sites,
        config.seed,
    );
    let mut fleet = Fleet::new(config)?;
    restore_bus_fields(&snap.section("bus")?, &fleet.bus, true)
        .with_context(|| format!("snapshot {}: bad bus section", snap.path.display()))?;
    let site_secs = snap.sections("site")?;
    anyhow::ensure!(
        site_secs.len() == fleet.sites.len(),
        "snapshot {} has {} site sections, reconstructed fleet has {} sites",
        snap.path.display(),
        site_secs.len(),
        fleet.sites.len(),
    );
    for (idx, sec) in site_secs.iter().enumerate() {
        let i = jusize(sec, "i")?;
        anyhow::ensure!(i == idx, "site sections out of order: got {i}, expected {idx}");
        restore_site_fields(sec, &mut fleet.sites[idx])
            .with_context(|| format!("snapshot {}: bad site section {idx}", snap.path.display()))?;
    }
    restore_smo_fields(&snap.section("smo")?, &mut fleet.smo)
        .with_context(|| format!("snapshot {}: bad smo section", snap.path.display()))?;
    restore_nonrt_fields(&snap.section("nonrt")?, &mut fleet.nonrt)
        .with_context(|| format!("snapshot {}: bad nonrt section", snap.path.display()))?;
    restore_coord_fields(&snap.section("coord")?, &mut fleet)
        .with_context(|| format!("snapshot {}: bad coord section", snap.path.display()))?;
    match fleet.ckpt_region_state_mut() {
        Some(rt) => {
            restore_region_fields(&snap.section("regions")?, rt).with_context(|| {
                format!("snapshot {}: bad regions section", snap.path.display())
            })?;
        }
        None => anyhow::ensure!(
            !snap.has_section("regions"),
            "snapshot {} has a regions section but its config is not hierarchical",
            snap.path.display()
        ),
    }
    restore_metrics_fields(&snap.section("metrics")?, &mut fleet)
        .with_context(|| format!("snapshot {}: bad metrics section", snap.path.display()))?;
    restore_trace_fields(&snap.section("trace")?, &mut fleet)
        .with_context(|| format!("snapshot {}: bad trace section", snap.path.display()))?;
    fleet.round = snap.header.round;
    Ok(fleet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;
    use crate::traffic::TrafficConfig;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("frost-ckpt-snap-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn fingerprint(f: &Fleet) -> String {
        format!("{:?}", f.report())
    }

    fn small_config() -> FleetConfig {
        FleetConfig {
            sites: 2,
            seed: 11,
            rounds: 4,
            train_epochs: 3,
            samples_per_epoch: 500,
            infer_steps_per_round: 4,
            trace: true,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn plain_fleet_resumes_bit_identically_to_the_uninterrupted_run() {
        let config = small_config();
        let mut gold = Fleet::new(config.clone()).unwrap();
        for _ in 0..config.rounds {
            gold.run_round().unwrap();
        }
        let mut half = Fleet::new(config).unwrap();
        half.run_round().unwrap();
        half.run_round().unwrap();
        let dir = tmpdir("plain");
        let path = write_fleet_snapshot(&half, "fleet", "-", &dir, 3).unwrap();
        drop(half);
        let snap = Snapshot::load(&path).unwrap();
        assert_eq!(snap.header.kind, "fleet");
        assert_eq!(snap.header.round, 2);
        let mut resumed = restore_fleet(&snap).unwrap();
        assert_eq!(resumed.round, 2);
        resumed.run_round().unwrap();
        resumed.run_round().unwrap();
        assert_eq!(fingerprint(&resumed), fingerprint(&gold));
    }

    #[test]
    fn snapshot_bytes_are_canonical_and_restore_is_a_fixed_point() {
        let mut fleet = Fleet::new(small_config()).unwrap();
        fleet.run_round().unwrap();
        let d1 = tmpdir("canon1");
        let d2 = tmpdir("canon2");
        let d3 = tmpdir("canon3");
        let p1 = write_fleet_snapshot(&fleet, "fleet", "-", &d1, 3).unwrap();
        let p2 = write_fleet_snapshot(&fleet, "fleet", "-", &d2, 3).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "same state must produce identical snapshot bytes"
        );
        let resumed = restore_fleet(&Snapshot::load(&p1).unwrap()).unwrap();
        let p3 = write_fleet_snapshot(&resumed, "fleet", "-", &d3, 3).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p3).unwrap(),
            "restore followed by snapshot must be a byte-level fixed point"
        );
    }

    #[test]
    fn traffic_scenario_fleet_resumes_mid_day_bit_identically() {
        let tr = TrafficConfig {
            users_per_site: 40,
            requests_per_user_per_day: 8.0,
            day_s: 600.0,
            slots_per_day: 4,
            warmup_rounds: 1,
            max_batch: 16,
            ..TrafficConfig::default()
        };
        let scen = Scenario::preset("grid-step", 2, &tr).unwrap();
        let config = FleetConfig {
            sites: 2,
            seed: 23,
            rounds: tr.rounds_for_one_day(),
            train_epochs: 3,
            samples_per_epoch: 500,
            max_concurrent_profiles: 2,
            budget_frac: 0.9,
            traffic: Some(tr),
            scenario: Some(scen),
            trace: true,
            ..FleetConfig::default()
        };
        let rounds = config.rounds;
        assert!(rounds >= 2, "need at least two rounds to split");
        let mut gold = Fleet::new(config.clone()).unwrap();
        for _ in 0..rounds {
            gold.run_round().unwrap();
        }
        let mut half = Fleet::new(config).unwrap();
        let split = rounds / 2;
        for _ in 0..split {
            half.run_round().unwrap();
        }
        let dir = tmpdir("scen");
        let path = write_fleet_snapshot(&half, "scenario", "grid-step", &dir, 2).unwrap();
        let snap = Snapshot::load(&path).unwrap();
        assert_eq!(snap.header.preset, "grid-step");
        let mut resumed = restore_fleet(&snap).unwrap();
        for _ in split..rounds {
            resumed.run_round().unwrap();
        }
        assert_eq!(fingerprint(&resumed), fingerprint(&gold));
        let gold_trace = format!("{:?}", gold.trace.ckpt_state());
        let res_trace = format!("{:?}", resumed.trace.ckpt_state());
        assert_eq!(res_trace, gold_trace, "trace events must match too");
    }

    #[test]
    fn region_fleet_resumes_bit_identically_and_writes_a_regions_section() {
        let config = FleetConfig {
            sites: 4,
            seed: 17,
            rounds: 6,
            train_epochs: 3,
            samples_per_epoch: 500,
            infer_steps_per_round: 4,
            budget_frac: 0.85,
            regions: Some(RegionMap::auto(4, 2).unwrap()),
            trace: true,
            ..FleetConfig::default()
        };
        let mut gold = Fleet::new(config.clone()).unwrap();
        for _ in 0..config.rounds {
            gold.run_round().unwrap();
        }
        let mut half = Fleet::new(config).unwrap();
        for _ in 0..3 {
            half.run_round().unwrap();
        }
        let dir = tmpdir("region");
        let path = write_fleet_snapshot(&half, "fleet", "-", &dir, 3).unwrap();
        let snap = Snapshot::load(&path).unwrap();
        assert!(snap.has_section("regions"), "hierarchical snapshot carries region state");
        let mut resumed = restore_fleet(&snap).unwrap();
        for _ in 3..6 {
            resumed.run_round().unwrap();
        }
        assert_eq!(fingerprint(&resumed), fingerprint(&gold));
    }

    #[test]
    fn restore_rejects_a_site_count_mismatch() {
        let mut fleet = Fleet::new(small_config()).unwrap();
        fleet.run_round().unwrap();
        let dir = tmpdir("mismatch");
        let path = write_fleet_snapshot(&fleet, "fleet", "-", &dir, 3).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Drop one site section wholesale and re-checksum: structurally
        // valid file, semantically inconsistent with its config.
        let body: String = text
            .lines()
            .filter(|l| !(l.contains("\"s\":\"site\"") && l.contains("\"i\":1")))
            .filter(|l| !l.contains("\"s\":\"footer\""))
            .map(|l| format!("{l}\n"))
            .collect();
        let digest = super::super::io::fnv1a64(body.as_bytes());
        let doctored = format!("{body}{{\"s\":\"footer\",\"fnv64\":\"{}\"}}\n", hex_u64(digest));
        std::fs::write(&path, doctored).unwrap();
        let snap = Snapshot::load(&path).unwrap();
        let err = restore_fleet(&snap).unwrap_err().to_string();
        assert!(err.contains("site sections"), "unexpected error: {err}");
    }
}
