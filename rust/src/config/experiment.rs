//! Experiment configuration: the knobs of the paper's evaluation (Sec. IV).

use crate::util::Json;
use anyhow::{Context, Result};

/// Training hyperparameters — fixed across the paper's evaluation:
/// batch 128, lr 1e-3, Adam, categorical cross-entropy, fixed seed.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingConfig {
    pub batch_size: u32,
    pub learning_rate: f64,
    pub epochs: u32,
    pub seed: u64,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig { batch_size: 128, learning_rate: 1e-3, epochs: 100, seed: 0 }
    }
}

/// FROST profiler parameters (Sec. III-C).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfilerConfig {
    /// Power-cap fractions to test. Paper: eight limits, 30%–100% in 10% steps.
    pub cap_fracs: Vec<f64>,
    /// Duration of each profiling window (paper: 30 s).
    pub window_s: f64,
    /// Duration of the idle baseline measurement `T_m` (Eqs. 1–2).
    pub idle_window_s: f64,
    /// Telemetry sampling period (paper: FROST samples every 0.1 s).
    pub sample_period_s: f64,
    /// `m` in ED^m P (paper: ED²P is the sweet spot).
    pub edp_exponent: f64,
    /// Relative fit-error threshold below which F(x) is accepted (paper: 5%).
    pub fit_error_threshold: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig {
            cap_fracs: (3..=10).map(|i| i as f64 / 10.0).collect(),
            window_s: 30.0,
            idle_window_s: 30.0,
            sample_period_s: 0.1,
            edp_exponent: 2.0,
            fit_error_threshold: 0.05,
        }
    }
}

impl ProfilerConfig {
    /// Fine-grained variant: 1% cap increments (paper Fig. 5).
    pub fn fine_grained() -> Self {
        ProfilerConfig {
            cap_fracs: (30..=100).map(|i| i as f64 / 100.0).collect(),
            ..Default::default()
        }
    }
}

/// A full experiment: hardware + training + profiler settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub hardware: super::HardwareConfig,
    pub training: TrainingConfig,
    pub profiler: ProfilerConfig,
}

impl TrainingConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("learning_rate", Json::Num(self.learning_rate)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(TrainingConfig {
            batch_size: j.req("batch_size")?.as_f64().context("batch_size")? as u32,
            learning_rate: j.req("learning_rate")?.as_f64().context("learning_rate")?,
            epochs: j.req("epochs")?.as_f64().context("epochs")? as u32,
            seed: j.req("seed")?.as_f64().context("seed")? as u64,
        })
    }
}

impl ProfilerConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cap_fracs", Json::arr_f64(&self.cap_fracs)),
            ("window_s", Json::Num(self.window_s)),
            ("idle_window_s", Json::Num(self.idle_window_s)),
            ("sample_period_s", Json::Num(self.sample_period_s)),
            ("edp_exponent", Json::Num(self.edp_exponent)),
            ("fit_error_threshold", Json::Num(self.fit_error_threshold)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let caps = j
            .req("cap_fracs")?
            .as_arr()
            .context("cap_fracs must be an array")?
            .iter()
            .map(|v| v.as_f64().context("cap_fracs entries must be numbers"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ProfilerConfig {
            cap_fracs: caps,
            window_s: j.req("window_s")?.as_f64().context("window_s")?,
            idle_window_s: j.req("idle_window_s")?.as_f64().context("idle_window_s")?,
            sample_period_s: j
                .req("sample_period_s")?
                .as_f64()
                .context("sample_period_s")?,
            edp_exponent: j.req("edp_exponent")?.as_f64().context("edp_exponent")?,
            fit_error_threshold: j
                .req("fit_error_threshold")?
                .as_f64()
                .context("fit_error_threshold")?,
        })
    }
}

impl ExperimentConfig {
    pub fn setup_no1() -> Self {
        ExperimentConfig {
            hardware: super::setup_no1(),
            training: TrainingConfig::default(),
            profiler: ProfilerConfig::default(),
        }
    }

    pub fn setup_no2() -> Self {
        ExperimentConfig {
            hardware: super::setup_no2(),
            training: TrainingConfig::default(),
            profiler: ProfilerConfig::default(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hardware", self.hardware.to_json()),
            ("training", self.training.to_json()),
            ("profiler", self.profiler.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ExperimentConfig {
            hardware: super::HardwareConfig::from_json(j.req("hardware")?)?,
            training: TrainingConfig::from_json(j.req("training")?)?,
            profiler: ProfilerConfig::from_json(j.req("profiler")?)?,
        })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        Ok(std::fs::write(path, self.to_json().pretty())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profiler_matches_paper() {
        let p = ProfilerConfig::default();
        assert_eq!(p.cap_fracs.len(), 8);
        assert_eq!(p.cap_fracs[0], 0.3);
        assert_eq!(*p.cap_fracs.last().unwrap(), 1.0);
        assert_eq!(p.window_s, 30.0);
        assert_eq!(p.edp_exponent, 2.0);
        assert_eq!(p.fit_error_threshold, 0.05);
    }

    #[test]
    fn fine_grained_has_71_caps() {
        let p = ProfilerConfig::fine_grained();
        assert_eq!(p.cap_fracs.len(), 71);
    }

    #[test]
    fn default_training_matches_paper() {
        let t = TrainingConfig::default();
        assert_eq!(t.batch_size, 128);
        assert_eq!(t.learning_rate, 1e-3);
        assert_eq!(t.epochs, 100);
    }

    #[test]
    fn experiment_json_roundtrip() {
        let e = ExperimentConfig::setup_no2();
        let back =
            ExperimentConfig::from_json(&Json::parse(&e.to_json().pretty()).unwrap())
                .unwrap();
        assert_eq!(e, back);
    }
}
