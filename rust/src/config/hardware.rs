//! Hardware testbed descriptions.
//!
//! The paper evaluates two setups (Sec. IV):
//!
//! * **no.1** — Intel i7-8700K, 64 GB DDR4 (4×16 GB @ 3600 MHz), RTX 3080
//! * **no.2** — Intel i9-11900KF, 128 GB DDR4 (4×32 GB @ 3200 MHz), RTX 3090
//!
//! We reconstruct both as virtual testbeds from datasheet constants; the
//! power physics lives in [`crate::power`].  Configs serialise to JSON via
//! the in-tree [`crate::util::Json`] (the build environment is offline —
//! DESIGN.md §2).

use crate::util::Json;
use anyhow::{Context, Result};

/// GPU datasheet constants driving the power/VF model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Thermal design power — the 100% power-cap reference (W).
    pub tdp_w: f64,
    /// Idle power draw (W).
    pub idle_w: f64,
    /// Base core clock (MHz) — sustainable at TDP on all-unit workloads.
    pub base_clock_mhz: f64,
    /// Boost core clock (MHz).
    pub boost_clock_mhz: f64,
    /// Minimum stable core clock under capping (MHz).
    pub min_clock_mhz: f64,
    /// Peak FP32 throughput at boost clock (GFLOP/s).
    pub peak_gflops: f64,
    /// Peak HBM/GDDR bandwidth (GB/s).
    pub mem_bw_gbs: f64,
    /// Fraction of (TDP − idle) that is static/leakage at nominal voltage.
    pub static_frac: f64,
    /// Lowest supported power-limit fraction exposed by the driver
    /// (nvidia-smi clamps around 30% on Ampere).
    pub min_cap_frac: f64,
    /// Voltage at the minimum stable clock (V).
    pub v_min: f64,
    /// Voltage at the knee frequency (V) — end of the efficient segment.
    pub v_knee: f64,
    /// Voltage at boost frequency (V) — top of the steep V² wall.
    pub v_max: f64,
    /// Knee as a fraction of boost clock: below it V(f) rises gently, above
    /// it the curve climbs the voltage wall (stock clocks sit deep in it).
    pub vf_knee_frac: f64,
}

/// CPU package constants (RAPL domain).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuSpec {
    pub name: String,
    pub tdp_w: f64,
    pub idle_w: f64,
    pub cores: u32,
    /// Whether the part exposes the RAPL DRAM domain (server parts only —
    /// both paper setups are consumer, hence the analytic DRAM model).
    pub rapl_dram_domain: bool,
}

/// One DRAM DIMM (drives `P_DRAM = N · 3/8 · S` per paper Sec. III-A).
#[derive(Debug, Clone, PartialEq)]
pub struct DimmSpec {
    pub size_gb: f64,
    pub freq_mhz: f64,
}

/// A complete testbed: the unit FROST profiles and reconfigures.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub name: String,
    pub cpu: CpuSpec,
    pub gpu: GpuSpec,
    pub dimms: Vec<DimmSpec>,
}

fn f(j: &Json, k: &str) -> Result<f64> {
    j.req(k)?.as_f64().with_context(|| format!("'{k}' must be a number"))
}

fn s(j: &Json, k: &str) -> Result<String> {
    Ok(j.req(k)?
        .as_str()
        .with_context(|| format!("'{k}' must be a string"))?
        .to_string())
}

impl GpuSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("tdp_w", Json::Num(self.tdp_w)),
            ("idle_w", Json::Num(self.idle_w)),
            ("base_clock_mhz", Json::Num(self.base_clock_mhz)),
            ("boost_clock_mhz", Json::Num(self.boost_clock_mhz)),
            ("min_clock_mhz", Json::Num(self.min_clock_mhz)),
            ("peak_gflops", Json::Num(self.peak_gflops)),
            ("mem_bw_gbs", Json::Num(self.mem_bw_gbs)),
            ("static_frac", Json::Num(self.static_frac)),
            ("min_cap_frac", Json::Num(self.min_cap_frac)),
            ("v_min", Json::Num(self.v_min)),
            ("v_knee", Json::Num(self.v_knee)),
            ("v_max", Json::Num(self.v_max)),
            ("vf_knee_frac", Json::Num(self.vf_knee_frac)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(GpuSpec {
            name: s(j, "name")?,
            tdp_w: f(j, "tdp_w")?,
            idle_w: f(j, "idle_w")?,
            base_clock_mhz: f(j, "base_clock_mhz")?,
            boost_clock_mhz: f(j, "boost_clock_mhz")?,
            min_clock_mhz: f(j, "min_clock_mhz")?,
            peak_gflops: f(j, "peak_gflops")?,
            mem_bw_gbs: f(j, "mem_bw_gbs")?,
            static_frac: f(j, "static_frac")?,
            min_cap_frac: f(j, "min_cap_frac")?,
            v_min: f(j, "v_min")?,
            v_knee: f(j, "v_knee")?,
            v_max: f(j, "v_max")?,
            vf_knee_frac: f(j, "vf_knee_frac")?,
        })
    }
}

impl CpuSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("tdp_w", Json::Num(self.tdp_w)),
            ("idle_w", Json::Num(self.idle_w)),
            ("cores", Json::Num(self.cores as f64)),
            ("rapl_dram_domain", Json::Bool(self.rapl_dram_domain)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(CpuSpec {
            name: s(j, "name")?,
            tdp_w: f(j, "tdp_w")?,
            idle_w: f(j, "idle_w")?,
            cores: f(j, "cores")? as u32,
            rapl_dram_domain: j.req("rapl_dram_domain")?.as_bool().unwrap_or(false),
        })
    }
}

impl DimmSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("size_gb", Json::Num(self.size_gb)),
            ("freq_mhz", Json::Num(self.freq_mhz)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(DimmSpec { size_gb: f(j, "size_gb")?, freq_mhz: f(j, "freq_mhz")? })
    }
}

impl HardwareConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("cpu", self.cpu.to_json()),
            ("gpu", self.gpu.to_json()),
            ("dimms", Json::Arr(self.dimms.iter().map(|d| d.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let dimms = j
            .req("dimms")?
            .as_arr()
            .context("'dimms' must be an array")?
            .iter()
            .map(DimmSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(HardwareConfig {
            name: s(j, "name")?,
            cpu: CpuSpec::from_json(j.req("cpu")?)?,
            gpu: GpuSpec::from_json(j.req("gpu")?)?,
            dimms,
        })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        Ok(std::fs::write(path, self.to_json().pretty())?)
    }

    /// Total installed DRAM (GB).
    pub fn dram_gb(&self) -> f64 {
        self.dimms.iter().map(|d| d.size_gb).sum()
    }
}

/// Paper setup no.1: i7-8700K + 64 GB DDR4-3600 + RTX 3080.
pub fn setup_no1() -> HardwareConfig {
    HardwareConfig {
        name: "setup_no1".into(),
        cpu: CpuSpec {
            name: "Intel Core i7-8700K".into(),
            tdp_w: 95.0,
            idle_w: 8.0,
            cores: 6,
            rapl_dram_domain: false,
        },
        gpu: GpuSpec {
            name: "NVIDIA GeForce RTX 3080".into(),
            tdp_w: 320.0,
            idle_w: 22.0,
            base_clock_mhz: 1440.0,
            boost_clock_mhz: 1710.0,
            min_clock_mhz: 210.0,
            peak_gflops: 29_770.0,
            mem_bw_gbs: 760.0,
            static_frac: 0.16,
            min_cap_frac: 0.3125, // 100 W floor / 320 W TDP (nvidia-smi)
            v_min: 0.725,
            v_knee: 0.831,
            v_max: 1.093,
            vf_knee_frac: 0.90,
        },
        dimms: vec![
            DimmSpec { size_gb: 16.0, freq_mhz: 3600.0 },
            DimmSpec { size_gb: 16.0, freq_mhz: 3600.0 },
            DimmSpec { size_gb: 16.0, freq_mhz: 3600.0 },
            DimmSpec { size_gb: 16.0, freq_mhz: 3600.0 },
        ],
    }
}

/// Paper setup no.2: i9-11900KF + 128 GB DDR4-3200 + RTX 3090.
pub fn setup_no2() -> HardwareConfig {
    HardwareConfig {
        name: "setup_no2".into(),
        cpu: CpuSpec {
            name: "Intel Core i9-11900KF".into(),
            tdp_w: 125.0,
            idle_w: 10.0,
            cores: 8,
            rapl_dram_domain: false,
        },
        gpu: GpuSpec {
            name: "NVIDIA GeForce RTX 3090".into(),
            tdp_w: 350.0,
            idle_w: 25.0,
            base_clock_mhz: 1395.0,
            boost_clock_mhz: 1695.0,
            min_clock_mhz: 210.0,
            peak_gflops: 35_580.0,
            mem_bw_gbs: 936.0,
            static_frac: 0.17,
            min_cap_frac: 0.286, // 100 W floor / 350 W TDP
            v_min: 0.725,
            v_knee: 0.843,
            v_max: 1.093,
            vf_knee_frac: 0.89,
        },
        dimms: vec![
            DimmSpec { size_gb: 32.0, freq_mhz: 3200.0 },
            DimmSpec { size_gb: 32.0, freq_mhz: 3200.0 },
            DimmSpec { size_gb: 32.0, freq_mhz: 3200.0 },
            DimmSpec { size_gb: 32.0, freq_mhz: 3200.0 },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_setups_match_paper() {
        let s1 = setup_no1();
        assert_eq!(s1.dram_gb(), 64.0);
        assert_eq!(s1.gpu.tdp_w, 320.0);
        let s2 = setup_no2();
        assert_eq!(s2.dram_gb(), 128.0);
        assert_eq!(s2.gpu.tdp_w, 350.0);
        assert!(!s1.cpu.rapl_dram_domain, "consumer CPU has no DRAM MSR");
    }

    #[test]
    fn json_roundtrip() {
        for hw in [setup_no1(), setup_no2()] {
            let text = hw.to_json().pretty();
            let back =
                HardwareConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(hw, back);
        }
    }

    #[test]
    fn missing_key_is_reported() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        let err = HardwareConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("dimms"), "err was: {err}");
    }

    #[test]
    fn vf_envelope_sane() {
        for hw in [setup_no1(), setup_no2()] {
            let g = &hw.gpu;
            assert!(g.min_clock_mhz < g.base_clock_mhz);
            assert!(g.base_clock_mhz < g.boost_clock_mhz);
            assert!(g.v_min < g.v_knee && g.v_knee < g.v_max);
            assert!(g.min_cap_frac > 0.2 && g.min_cap_frac < 0.5);
            assert!(g.idle_w < g.tdp_w * 0.15);
        }
    }
}
