//! Configuration system: hardware testbeds, experiment parameters, TOML I/O.

pub mod experiment;
pub mod hardware;

pub use experiment::{ExperimentConfig, ProfilerConfig, TrainingConfig};
pub use hardware::{CpuSpec, DimmSpec, GpuSpec, HardwareConfig, setup_no1, setup_no2};
