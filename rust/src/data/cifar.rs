//! Deterministic synthetic CIFAR-10 generator + batcher.
//!
//! Each class `c` gets a seeded per-class mean image (smooth low-frequency
//! pattern) and samples are `mean + noise`.  This gives a dataset a small
//! CNN can genuinely learn (the e2e example drives loss below chance within
//! a few hundred steps) while staying fully deterministic.

use crate::util::Pcg32;

pub const HEIGHT: usize = 32;
pub const WIDTH: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 10;
pub const SAMPLE_ELEMS: usize = HEIGHT * WIDTH * CHANNELS;
/// CIFAR-10 cardinality: 50k train + 10k test.
pub const TRAIN_SIZE: usize = 50_000;
pub const TEST_SIZE: usize = 10_000;

/// One batch in NHWC f32 + i32 labels — the exact layout the AOT-lowered
/// train/infer artifacts expect.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch_size: usize,
}

/// Streaming synthetic CIFAR-10.
#[derive(Debug, Clone)]
pub struct SyntheticCifar {
    /// Per-class mean images (CLASSES × SAMPLE_ELEMS).
    means: Vec<f32>,
    noise_std: f32,
    rng: Pcg32,
}

impl SyntheticCifar {
    pub fn new(seed: u64) -> Self {
        let mut mean_rng = Pcg32::new(seed, 0xC1FA);
        let mut means = vec![0f32; CLASSES * SAMPLE_ELEMS];
        for c in 0..CLASSES {
            // Smooth class pattern: sum of a few random 2-D cosines per channel.
            let mut coefs = Vec::new();
            for _ in 0..4 {
                coefs.push((
                    mean_rng.uniform(0.5, 3.0),  // fx
                    mean_rng.uniform(0.5, 3.0),  // fy
                    mean_rng.uniform(0.0, std::f64::consts::TAU), // phase
                    mean_rng.uniform(0.2, 0.5),  // amplitude
                ));
            }
            for h in 0..HEIGHT {
                for w in 0..WIDTH {
                    for ch in 0..CHANNELS {
                        let mut v = 0.0;
                        for (i, (fx, fy, p, a)) in coefs.iter().enumerate() {
                            let arg = fx * h as f64 / HEIGHT as f64
                                + fy * w as f64 / WIDTH as f64
                                + p
                                + (ch as f64 + i as f64) * 0.7;
                            v += a * (std::f64::consts::TAU * arg).cos();
                        }
                        means[c * SAMPLE_ELEMS
                            + (h * WIDTH + w) * CHANNELS
                            + ch] = v as f32;
                    }
                }
            }
        }
        SyntheticCifar { means, noise_std: 0.35, rng: Pcg32::new(seed, 0xDA7A) }
    }

    /// Next training batch (labels drawn uniformly, like a shuffled epoch).
    pub fn next_batch(&mut self, batch_size: usize) -> Batch {
        let mut images = vec![0f32; batch_size * SAMPLE_ELEMS];
        let mut labels = vec![0i32; batch_size];
        for b in 0..batch_size {
            let c = self.rng.below(CLASSES as u32) as usize;
            labels[b] = c as i32;
            let mean = &self.means[c * SAMPLE_ELEMS..(c + 1) * SAMPLE_ELEMS];
            let dst = &mut images[b * SAMPLE_ELEMS..(b + 1) * SAMPLE_ELEMS];
            for (d, m) in dst.iter_mut().zip(mean) {
                *d = m + self.noise_std * self.rng.normal() as f32;
            }
        }
        Batch { images, labels, batch_size }
    }

    /// A deterministic evaluation batch (fixed stream independent of
    /// training draws).
    pub fn eval_batch(&self, batch_size: usize, seed: u64) -> Batch {
        let mut rng = Pcg32::new(seed, 0xE7A1);
        let mut images = vec![0f32; batch_size * SAMPLE_ELEMS];
        let mut labels = vec![0i32; batch_size];
        for b in 0..batch_size {
            let c = rng.below(CLASSES as u32) as usize;
            labels[b] = c as i32;
            let mean = &self.means[c * SAMPLE_ELEMS..(c + 1) * SAMPLE_ELEMS];
            let dst = &mut images[b * SAMPLE_ELEMS..(b + 1) * SAMPLE_ELEMS];
            for (d, m) in dst.iter_mut().zip(mean) {
                *d = m + self.noise_std * rng.normal() as f32;
            }
        }
        Batch { images, labels, batch_size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut ds = SyntheticCifar::new(0);
        let b = ds.next_batch(64);
        assert_eq!(b.images.len(), 64 * SAMPLE_ELEMS);
        assert_eq!(b.labels.len(), 64);
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCifar::new(42);
        let mut b = SyntheticCifar::new(42);
        let ba = a.next_batch(16);
        let bb = b.next_batch(16);
        assert_eq!(ba.images, bb.images);
        assert_eq!(ba.labels, bb.labels);
    }

    #[test]
    fn classes_are_separable() {
        // Mean intra-class distance must be well below inter-class distance,
        // otherwise the e2e training demo cannot learn.
        let ds = SyntheticCifar::new(0);
        let b = ds.eval_batch(256, 1);
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
        };
        let mut intra = (0.0f64, 0u32);
        let mut inter = (0.0f64, 0u32);
        for i in 0..64 {
            for j in (i + 1)..64 {
                let xi = &b.images[i * SAMPLE_ELEMS..(i + 1) * SAMPLE_ELEMS];
                let xj = &b.images[j * SAMPLE_ELEMS..(j + 1) * SAMPLE_ELEMS];
                let d = dist(xi, xj) as f64;
                if b.labels[i] == b.labels[j] {
                    intra.0 += d;
                    intra.1 += 1;
                } else {
                    inter.0 += d;
                    inter.1 += 1;
                }
            }
        }
        let intra_mean = intra.0 / intra.1.max(1) as f64;
        let inter_mean = inter.0 / inter.1.max(1) as f64;
        assert!(
            inter_mean > intra_mean * 1.3,
            "classes not separable: intra {intra_mean} inter {inter_mean}"
        );
    }

    #[test]
    fn eval_batch_is_stable() {
        let ds = SyntheticCifar::new(0);
        let a = ds.eval_batch(32, 9);
        let b = ds.eval_batch(32, 9);
        assert_eq!(a.images, b.images);
        // Training draws don't disturb eval stream.
        let mut ds2 = SyntheticCifar::new(0);
        ds2.next_batch(128);
        let c = ds2.eval_batch(32, 9);
        assert_eq!(a.images, c.images);
    }

    #[test]
    fn pixel_stats_normalised() {
        let mut ds = SyntheticCifar::new(3);
        let b = ds.next_batch(128);
        let mean: f32 = b.images.iter().sum::<f32>() / b.images.len() as f32;
        let var: f32 = b.images.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / b.images.len() as f32;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!(var > 0.1 && var < 2.0, "var {var}");
    }
}
