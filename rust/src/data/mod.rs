//! Synthetic CIFAR-10: the dataset substitute (DESIGN.md §2).
//!
//! Deterministic class-conditional Gaussian-mixture images with CIFAR-10's
//! exact shapes and cardinality.  Energy behaviour depends on tensor shapes
//! and throughput, not pixel content, and the class structure keeps the
//! end-to-end training demo learnable.

pub mod cifar;

pub use cifar::{Batch, SyntheticCifar};
