//! Region-tier half of the budget conservation audit (DESIGN.md §16),
//! shared by the scenario and chaos harnesses.
//!
//! The flat audit (Σ applied-cap watts ≤ the budget in force, every
//! round) lives inline in each harness; this accumulator extends it to
//! the hierarchy's second level on rounds where regional sub-budgets are
//! in force: Σ regional sub-budgets must stay within the global budget,
//! and every region's applied-cap wattage must stay within its
//! sub-budget — including budget-step, outage, derate and churn rounds.

use crate::oran::RegionReport;

/// Two-level conservation accumulators.  All three travel in the
/// harnesses' snapshot `harness` sections so a resumed run audits the
/// whole day.
#[derive(Debug, Clone, Copy)]
pub(crate) struct RegionAudit {
    /// Audited rounds where at least one regional sub-budget was in
    /// force (0 on flat fleets).
    pub audited: usize,
    /// max over audited rounds of (Σ sub-budget W − global budget W).
    max_subbudget_excess_w: f64,
    /// max over audited rounds and regions of (region applied-cap W −
    /// region sub-budget W).
    max_region_excess_w: f64,
}

impl RegionAudit {
    pub fn new() -> Self {
        Self::resume(0, f64::NEG_INFINITY, f64::NEG_INFINITY)
    }

    /// Rebuild from snapshot accumulators.
    pub fn resume(audited: usize, max_subbudget_excess_w: f64, max_region_excess_w: f64) -> Self {
        Self { audited, max_subbudget_excess_w, max_region_excess_w }
    }

    /// Fold in one round's per-region roll-up.  Call only on rounds the
    /// flat audit covers (water-fill enforced, `budget_w` the budget in
    /// force).
    pub fn absorb(&mut self, regions: &[RegionReport], budget_w: f64) {
        let filled: Vec<(f64, f64)> = regions
            .iter()
            .filter_map(|r| r.sub_budget_w.map(|sub| (r.cap_power_w, sub)))
            .collect();
        if filled.is_empty() {
            return;
        }
        self.audited += 1;
        let sub_sum: f64 = filled.iter().map(|&(_, sub)| sub).sum();
        self.max_subbudget_excess_w = self.max_subbudget_excess_w.max(sub_sum - budget_w);
        for (cap_w, sub) in filled {
            self.max_region_excess_w = self.max_region_excess_w.max(cap_w - sub);
        }
    }

    /// Reported Σ-sub-budget excess (0 when no round was audited).
    pub fn max_subbudget_excess(&self) -> f64 {
        if self.audited > 0 {
            self.max_subbudget_excess_w
        } else {
            0.0
        }
    }

    /// Reported per-region cap excess (0 when no round was audited).
    pub fn max_region_excess(&self) -> f64 {
        if self.audited > 0 {
            self.max_region_excess_w
        } else {
            0.0
        }
    }

    /// Raw accumulators for the snapshot `harness` section.
    pub fn raw(&self) -> (usize, f64, f64) {
        (self.audited, self.max_subbudget_excess_w, self.max_region_excess_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(name: &str, cap_power_w: f64, sub_budget_w: Option<f64>) -> RegionReport {
        RegionReport {
            name: name.to_string(),
            sites: 2,
            up_sites: 2,
            workload_energy_j: 0.0,
            round_energy_j: 0.0,
            samples: 0,
            cap_power_w,
            sub_budget_w,
            offered_load_per_s: 0.0,
            steady_site_rounds: 0,
        }
    }

    #[test]
    fn flat_reports_never_advance_the_audit() {
        let mut a = RegionAudit::new();
        a.absorb(&[], 500.0);
        a.absorb(&[region("r", 200.0, None)], 500.0);
        assert_eq!(a.audited, 0);
        assert_eq!(a.max_subbudget_excess(), 0.0);
        assert_eq!(a.max_region_excess(), 0.0);
    }

    #[test]
    fn excesses_track_the_worst_round_and_region() {
        let mut a = RegionAudit::new();
        // Conserved round: sub-budgets sum under budget, caps under subs.
        a.absorb(&[region("a", 180.0, Some(200.0)), region("b", 290.0, Some(290.0))], 500.0);
        // Violating round: Σ subs = 520 > 500, and region b busts its sub.
        a.absorb(&[region("a", 180.0, Some(200.0)), region("b", 330.0, Some(320.0))], 500.0);
        assert_eq!(a.audited, 2);
        assert!((a.max_subbudget_excess() - 20.0).abs() < 1e-9);
        assert!((a.max_region_excess() - 10.0).abs() < 1e-9);
        let (n, sub, reg) = a.raw();
        let b = RegionAudit::resume(n, sub, reg);
        assert_eq!(b.max_subbudget_excess().to_bits(), a.max_subbudget_excess().to_bits());
        assert_eq!(b.max_region_excess().to_bits(), a.max_region_excess().to_bits());
    }
}
