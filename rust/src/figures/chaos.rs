//! Chaos roll-up: one FROST fleet run under a seeded fault-injection
//! preset (DESIGN.md §13), audited round by round.
//!
//! Unlike the scenario harness there is no baseline leg — the question a
//! chaos run answers is not "how much energy does FROST save" but "does
//! the control plane stay safe and heal itself while the fabric
//! misbehaves".  Concretely, every round the harness checks the budget
//! conservation invariant (Σ applied-cap watts ≤ the budget in force
//! whenever the water-fill is engaged) and tracks which sites sit in a
//! lease fallback or a profile quarantine; after the fault window closes
//! the run keeps going over a quiet tail long enough for every healing
//! path — lease renewal, retry, quarantine release, re-profile, budget
//! re-fill — to finish, and reports whether it did.
//!
//! The fault window is placed so it covers the initial profile stagger
//! (`start_round` 2): the `profile-flaps` preset is pointless if the O2
//! plane has nothing in flight while it flaps.

use anyhow::{Context, Result};

use crate::ckpt::codec::{jf64, ju32, jusize, r_series, w_f64, w_series};
use crate::ckpt::{
    restore_fleet_with, write_fleet_snapshot_with, CkptOptions, DriveOutcome, Snapshot,
};
use crate::obs::TraceSink;
use crate::oran::{FaultConfig, FaultLedger, Fleet, FleetConfig, FleetReport};
use crate::traffic::TrafficConfig;
use crate::util::Series;

use super::audit::RegionAudit;

/// A1 lease TTL used by chaos runs (rounds).
pub const CHAOS_LEASE_ROUNDS: u32 = 3;
/// Scheduler patience before a profile retry (rounds).
pub const CHAOS_PROFILE_TIMEOUT_ROUNDS: u32 = 2;
/// Profile issues (first + retries) before quarantine.
pub const CHAOS_PROFILE_MAX_ATTEMPTS: u32 = 2;
/// Rounds a quarantined site sits out.
pub const CHAOS_QUARANTINE_ROUNDS: u32 = 4;

/// Fault-free rounds after the window closes.  Sized for the longest
/// healing chain: a final in-window profile issue retries after at most
/// 2·timeout+1 rounds, may then quarantine for `CHAOS_QUARANTINE_ROUNDS`,
/// re-profiles on release and waits one more round for the result and the
/// budget re-fill — plus the lease TTL for any fallback still draining.
/// 2·2+1 + 4 + 2 + 3 = 12.
pub const CHAOS_QUIET_TAIL_ROUNDS: u32 = 12;

/// Build the fleet configuration for one chaos preset.  The run is
/// traffic-driven (a site under fire still has users to serve), enforces
/// a real power budget so conservation is auditable, and enables every
/// §13 resilience knob: leases, profile retry/quarantine, hold-back
/// bounds.
pub fn chaos_config(preset: &str, sites: usize, seed: u64, smoke: bool) -> Result<FleetConfig> {
    let tr = if smoke {
        TrafficConfig {
            users_per_site: 300,
            requests_per_user_per_day: 30.0,
            day_s: 2_400.0,
            slots_per_day: 16,
            warmup_rounds: 3,
            max_batch: 32,
            ..TrafficConfig::default()
        }
    } else {
        TrafficConfig {
            users_per_site: 800,
            requests_per_user_per_day: 40.0,
            day_s: 3_600.0,
            slots_per_day: 24,
            warmup_rounds: 3,
            max_batch: 32,
            ..TrafficConfig::default()
        }
    };
    let rounds = tr.rounds_for_one_day();
    anyhow::ensure!(
        rounds > CHAOS_QUIET_TAIL_ROUNDS + 2,
        "chaos runs need a fault window before the {CHAOS_QUIET_TAIL_ROUNDS}-round quiet tail"
    );
    let mut faults = FaultConfig::preset(preset, seed ^ 0xFA57)?;
    faults.start_round = 2;
    faults.end_round = rounds - CHAOS_QUIET_TAIL_ROUNDS;
    Ok(FleetConfig {
        sites,
        seed,
        rounds,
        train_epochs: if smoke { 30 } else { 60 },
        samples_per_epoch: if smoke { 5_000 } else { 20_000 },
        budget_frac: 0.85,
        max_concurrent_profiles: sites,
        traffic: Some(tr),
        faults: Some(faults),
        policy_lease_rounds: CHAOS_LEASE_ROUNDS,
        profile_timeout_rounds: CHAOS_PROFILE_TIMEOUT_ROUNDS,
        profile_max_attempts: CHAOS_PROFILE_MAX_ATTEMPTS,
        quarantine_rounds: CHAOS_QUARANTINE_ROUNDS,
        holdback_cap: 256,
        ..FleetConfig::default()
    })
}

/// Output of [`chaos_run`].
#[derive(Debug, Clone)]
pub struct ChaosFigOutput {
    /// One row per round: sites in lease fallback / quarantine, budget
    /// and applied-cap watts, the round's cap excess, and the cumulative
    /// rejected-KPM / injected-fault counters.
    pub round_table: Series,
    /// Everything the fault plan injected over the run.
    pub ledger: FaultLedger,
    /// max over audited rounds of (Σ applied-cap watts − budget watts);
    /// ≤ 0 ⇔ the budget was conserved in every round it was in force.
    pub max_cap_excess_w: f64,
    /// Rounds the conservation audit covered (water-fill in force).
    pub budget_audited_rounds: usize,
    /// Audited rounds where regional sub-budgets were in force (§16;
    /// 0 on flat fleets).
    pub region_audited_rounds: usize,
    /// max over region-audited rounds of (Σ regional sub-budget watts −
    /// global budget watts); ≤ 0 ⇔ the top level never over-committed.
    pub max_subbudget_excess_w: f64,
    /// max over region-audited rounds and regions of (region applied-cap
    /// watts − region sub-budget watts); ≤ 0 ⇔ every regional fill
    /// stayed within its allocation.
    pub max_region_excess_w: f64,
    /// Last round any site sat in a lease fallback or quarantine
    /// (0 = the control plane never degraded).
    pub last_unhealthy_round: u32,
    /// True when the final round ended with no site in fallback or
    /// quarantine and the budget water-fill back in force.
    pub healed: bool,
    pub report: FleetReport,
    /// The run's trace spine (empty unless `FleetConfig::trace`).
    pub trace: TraceSink,
}

/// Run one fault-injected fleet day round by round, auditing the budget
/// conservation invariant and the §13 self-healing machinery.
pub fn chaos_run(config: &FleetConfig) -> Result<ChaosFigOutput> {
    Ok(chaos_run_ckpt(config, "-", &CkptOptions::disabled())?.expect_done("chaos_run"))
}

/// [`chaos_run`] with checkpoint/crash-injection support.  The per-round
/// audit table and accumulators travel in the snapshot's `harness`
/// section, so a resumed run's `round_table` covers the whole day.
/// `preset` is recorded in the snapshot header for `frost resume`.
pub fn chaos_run_ckpt(
    config: &FleetConfig,
    preset: &str,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ChaosFigOutput>> {
    let faults = config.faults.clone().context("chaos_run needs FleetConfig::faults set")?;
    let fleet = Fleet::new(config.clone())?;
    let round_table = Series::new(
        format!(
            "Chaos run: {} sites, seed {}, faults in rounds {}..={}",
            config.sites, config.seed, faults.start_round, faults.end_round
        ),
        &["fallbacks", "quarantined", "budget_w", "cap_w", "excess_w", "kpm_rej", "faults"],
    );
    drive(fleet, round_table, ChaosAudit::new(), preset, opts)
}

/// Accumulators threaded through [`drive`] and the snapshot `harness`
/// section: the flat budget audit, the §16 region audit, and the
/// healing tracker.
struct ChaosAudit {
    audited: usize,
    max_cap_excess_w: f64,
    regions: RegionAudit,
    last_unhealthy_round: u32,
}

impl ChaosAudit {
    fn new() -> Self {
        Self {
            audited: 0,
            max_cap_excess_w: f64::NEG_INFINITY,
            regions: RegionAudit::new(),
            last_unhealthy_round: 0,
        }
    }
}

/// Resume a crashed [`chaos_run_ckpt`] from its snapshot, restoring the
/// audit table and accumulators alongside the fleet.  `threads`
/// overrides the snapshot's worker count (resume is thread-count
/// independent).
pub fn chaos_resume(
    snap: &Snapshot,
    threads: Option<usize>,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ChaosFigOutput>> {
    anyhow::ensure!(
        snap.header.kind == "chaos",
        "snapshot {} is a '{}' run, not a chaos run",
        snap.path.display(),
        snap.header.kind
    );
    let harness = snap.section("harness")?;
    let round_table = r_series(harness.req("rounds")?)?;
    let audit = ChaosAudit {
        audited: jusize(&harness, "audited")?,
        max_cap_excess_w: jf64(&harness, "max_excess")?,
        regions: RegionAudit::resume(
            jusize(&harness, "region_audited")?,
            jf64(&harness, "max_sub_excess")?,
            jf64(&harness, "max_region_excess")?,
        ),
        last_unhealthy_round: ju32(&harness, "last_unhealthy")?,
    };
    let fleet = restore_fleet_with(snap, threads)?;
    anyhow::ensure!(
        fleet.config.faults.is_some(),
        "chaos snapshot {} carries no fault plan",
        snap.path.display()
    );
    drive(fleet, round_table, audit, &snap.header.preset, opts)
}

fn drive(
    mut fleet: Fleet,
    mut round_table: Series,
    mut audit: ChaosAudit,
    preset: &str,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ChaosFigOutput>> {
    let rounds = fleet.config.rounds;
    let sites = fleet.config.sites;
    for round in (fleet.round + 1)..=rounds {
        fleet.run_round()?;
        let rep = fleet.report();
        let fallbacks = fleet.sites.iter().filter(|s| s.host.in_lease_fallback()).count();
        let quarantined = (0..sites).filter(|&i| fleet.is_quarantined(i)).count();
        if fallbacks + quarantined > 0 {
            audit.last_unhealthy_round = round;
        }
        let mut budget_w = 0.0;
        let mut excess_w = 0.0;
        if rep.budget_enforced {
            if let Some(b) = rep.budget_w {
                audit.audited += 1;
                budget_w = b;
                excess_w = rep.cap_power_w - b;
                audit.max_cap_excess_w = audit.max_cap_excess_w.max(excess_w);
                audit.regions.absorb(&rep.regions, b);
            }
        }
        round_table.push(format!("r{round:02}"), vec![
            fallbacks as f64,
            quarantined as f64,
            budget_w,
            rep.cap_power_w,
            excess_w,
            rep.kpm_rejected as f64,
            rep.fault_ledger.as_ref().map_or(0.0, |l| l.total() as f64),
        ]);
        if opts.due(round) {
            let dir = opts.dir.as_ref().expect("due() implies a snapshot directory");
            let snapshot = write_fleet_snapshot_with(&fleet, "chaos", preset, dir, opts.keep, |sw| {
                sw.section("harness", |js| {
                    w_series(js, Some("rounds"), &round_table);
                    js.u64_field(Some("audited"), audit.audited as u64);
                    w_f64(js, Some("max_excess"), audit.max_cap_excess_w);
                    let (ra, sub, reg) = audit.regions.raw();
                    js.u64_field(Some("region_audited"), ra as u64);
                    w_f64(js, Some("max_sub_excess"), sub);
                    w_f64(js, Some("max_region_excess"), reg);
                    js.u64_field(Some("last_unhealthy"), u64::from(audit.last_unhealthy_round));
                })?;
                Ok(())
            })?;
            if opts.crash_at == Some(round) {
                return Ok(DriveOutcome::Crashed { round, snapshot });
            }
        }
    }
    let report = fleet.report();
    let ledger = report.fault_ledger.clone().unwrap_or_default();
    let healed = report.budget_enforced
        && fleet.sites.iter().all(|s| !s.host.in_lease_fallback())
        && (0..sites).all(|i| !fleet.is_quarantined(i));
    Ok(DriveOutcome::Done(ChaosFigOutput {
        round_table,
        ledger,
        max_cap_excess_w: if audit.audited > 0 { audit.max_cap_excess_w } else { 0.0 },
        budget_audited_rounds: audit.audited,
        region_audited_rounds: audit.regions.audited,
        max_subbudget_excess_w: audit.regions.max_subbudget_excess(),
        max_region_excess_w: audit.regions.max_region_excess(),
        last_unhealthy_round: audit.last_unhealthy_round,
        healed,
        report,
        trace: fleet.trace,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oran::CHAOS_PRESETS;

    #[test]
    fn chaos_config_builds_every_preset_with_a_quiet_tail() {
        for preset in CHAOS_PRESETS {
            let cfg = chaos_config(preset, 4, 11, true).unwrap();
            let faults = cfg.faults.as_ref().unwrap();
            assert!(!faults.is_inert(), "{preset} must inject something");
            assert_eq!(faults.end_round + CHAOS_QUIET_TAIL_ROUNDS, cfg.rounds);
            assert!(cfg.policy_lease_rounds >= 2);
            assert!(cfg.profile_timeout_rounds >= 1);
            assert!(cfg.budget_frac < 1.0, "conservation must be auditable");
        }
        assert!(chaos_config("perfect-fabric", 4, 11, true).is_err());
    }

    #[test]
    fn chaos_run_requires_a_fault_plan() {
        let mut cfg = chaos_config("lossy-fabric", 2, 11, true).unwrap();
        cfg.faults = None;
        assert!(chaos_run(&cfg).is_err());
    }

    #[test]
    fn smoke_lossy_fabric_conserves_budget_and_heals() {
        let cfg = chaos_config("lossy-fabric", 4, 11, true).unwrap();
        let out = chaos_run(&cfg).unwrap();
        assert_eq!(out.round_table.len(), cfg.rounds as usize);
        assert!(out.ledger.total() > 0, "a lossy fabric must injure something");
        assert!(out.budget_audited_rounds > 0, "the water-fill must engage");
        assert!(
            out.max_cap_excess_w <= 1e-6,
            "budget exceeded by {} W",
            out.max_cap_excess_w
        );
        assert!(out.healed, "the fleet must heal over the quiet tail");
        assert!(out.report.lease_renewals > 0);
    }
}
