//! Fig. 2 — initial energy performance investigation (paper Sec. IV-A).
//!
//! All 16 models trained for 100 epochs on (synthetic) CIFAR-10 at batch
//! 128; per model we record best accuracy, total net training energy
//! (Eq. 1), training time, mean GPU utilisation and mean GPU power draw.
//!
//! Paper findings this harness must reproduce in shape:
//! * 2a — accuracy vs energy essentially uncorrelated (r = 0.34);
//! * 2b — energy vs training time strongly linear (r = 0.999);
//! * 2c — utilisation saturates near 100% while power keeps climbing past
//!   ~300 W (ResNeXt/PNASNet the hogs).

use crate::config::HardwareConfig;
use crate::metrics::stats::pearson;
use crate::simulator::Testbed;
use crate::util::{Pcg32, Seconds, Series};
use crate::zoo::all_models;

/// The three panels plus the correlation coefficients.
#[derive(Debug, Clone)]
pub struct Fig2Output {
    /// Per-model rows: accuracy, energy_kj, time_s, util_pct, gpu_power_w.
    pub table: Series,
    /// Pearson r accuracy↔energy (paper: 0.34).
    pub r_accuracy_energy: f64,
    /// Pearson r energy↔time (paper: 0.999).
    pub r_energy_time: f64,
    /// Pearson r utilisation↔power over the sub-300 W region.
    pub r_util_power: f64,
}

/// Run the investigation on one setup.
pub fn fig2_investigation(hw: &HardwareConfig, epochs: u32, seed: u64) -> Fig2Output {
    let reference_gpu = crate::config::setup_no1().gpu;
    let mut table = Series::new(
        format!("Fig2: 16 models x {epochs} epochs on {}", hw.name),
        &["accuracy", "energy_kj", "time_s", "util_pct", "gpu_power_w"],
    );
    let mut rng = Pcg32::new(seed, 0xF16);

    for (i, entry) in all_models().iter().enumerate() {
        let w = entry.workload(&reference_gpu);
        let mut tb = Testbed::new(hw.clone(), seed + i as u64);
        // Idle baseline over T_m (Eq. 1).
        let idle = tb.idle_window(Seconds(30.0));
        let mut energy = 0.0;
        let mut wall = 0.0;
        let mut gpu_energy = 0.0;
        let mut util = 0.0;
        for _ in 0..epochs {
            let agg = tb.train_epoch(&w, 128, 50_000);
            energy += agg.energy.0;
            wall += agg.wall.0;
            gpu_energy += agg.gpu_energy.0;
            util += agg.mean_util;
        }
        let net_energy = energy - idle.energy.0; // Eq. 1
        // Best accuracy after `epochs`: reference accuracy reached with a
        // ramp + small run-to-run noise (power caps never change numerics).
        let ramp = 1.0 - (-(epochs as f64) / 35.0).exp();
        let accuracy = (entry.reference_accuracy * (0.62 + 0.38 * ramp)
            + rng.normal() * 0.003)
            .clamp(0.0, 1.0);
        table.push(entry.name, vec![
            accuracy,
            net_energy / 1e3,
            wall,
            100.0 * util / epochs as f64,
            gpu_energy / wall,
        ]);
    }

    let acc = table.column("accuracy").unwrap();
    let energy = table.column("energy_kj").unwrap();
    let time = table.column("time_s").unwrap();
    let util = table.column("util_pct").unwrap();
    let power = table.column("gpu_power_w").unwrap();
    Fig2Output {
        r_accuracy_energy: pearson(&acc, &energy),
        r_energy_time: pearson(&energy, &time),
        r_util_power: pearson(&util, &power),
        table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::setup_no1;

    fn output() -> Fig2Output {
        fig2_investigation(&setup_no1(), 100, 0)
    }

    #[test]
    fn sixteen_rows() {
        let out = output();
        assert_eq!(out.table.len(), 16);
    }

    #[test]
    fn fig2a_weak_accuracy_energy_correlation() {
        let out = output();
        assert!(
            out.r_accuracy_energy.abs() < 0.7,
            "accuracy↔energy r = {} should be weak (paper: 0.34)",
            out.r_accuracy_energy
        );
    }

    #[test]
    fn fig2b_energy_time_strongly_linear() {
        let out = output();
        assert!(
            out.r_energy_time > 0.95,
            "energy↔time r = {} should be ~1 (paper: 0.999)",
            out.r_energy_time
        );
    }

    #[test]
    fn fig2c_power_saturation() {
        let out = output();
        let power = out.table.column("gpu_power_w").unwrap();
        let util = out.table.column("util_pct").unwrap();
        let max_power = power.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(max_power > 300.0, "top model must exceed 300 W, got {max_power}");
        // The hottest models gain no meaningful utilisation for their extra
        // power: every model above 290 W already sits above 95% util.
        for (p, u) in power.iter().zip(&util) {
            if *p > 290.0 {
                assert!(*u > 95.0, "model at {p} W has util {u}%");
            }
        }
    }

    #[test]
    fn resnet_vs_googlenet_energy_gap() {
        // Paper: "ResNet achieved 0.30% higher accuracy than GoogleNet
        // consuming 4x less energy". Shape check: ResNet cheaper & at least
        // as accurate.
        let out = output();
        let idx = |n: &str| out.table.labels.iter().position(|l| l == n).unwrap();
        let energy = out.table.column("energy_kj").unwrap();
        let acc = out.table.column("accuracy").unwrap();
        let (r, g) = (idx("ResNet"), idx("GoogLeNet"));
        assert!(energy[g] > 2.0 * energy[r], "GoogLeNet {} vs ResNet {}", energy[g], energy[r]);
        assert!(acc[r] > acc[g] - 0.01);
    }

    #[test]
    fn deterministic() {
        let a = fig2_investigation(&setup_no1(), 20, 7);
        let b = fig2_investigation(&setup_no1(), 20, 7);
        assert_eq!(a.table, b.table);
    }
}
