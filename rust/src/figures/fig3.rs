//! Fig. 3 — overhead of FROST vs CodeCarbon/Eco2AI vs baseline
//! (paper Sec. IV-B): time to infer across CIFAR-10 test samples with each
//! measurement tool attached, on *real* PJRT inference.

use anyhow::Result;

use crate::config::HardwareConfig;
use crate::pipeline::{calibrated_workload, run_overhead_experiment};
use crate::runtime::Runtime;
use crate::util::Series;
use crate::zoo::Manifest;

/// Run the overhead comparison for the trainable models.
///
/// `n_samples` is per (model, tool) run; the paper uses the 50k test set ×
/// 100 experiments — on the CPU-interpret substrate the default is scaled
/// down and recorded as such in EXPERIMENTS.md.
pub fn fig3_overhead(
    hw: &HardwareConfig,
    models: &[&str],
    n_samples: u64,
    reps: u32,
) -> Result<Series> {
    let manifest = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    let mut series = Series::new(
        format!("Fig3: inference overhead over {n_samples} samples x {reps} reps"),
        &["baseline_s", "frost_s", "codecarbon_s", "eco2ai_s", "frost_rel", "cc_rel", "eco_rel"],
    );
    for model in models {
        let m = manifest
            .model(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' not in manifest"))?;
        let w = calibrated_workload(m, &hw.gpu, None)?;
        let results =
            run_overhead_experiment(&rt, &manifest, hw, &w, model, n_samples, reps)?;
        let get = |n: &str| results.iter().find(|r| r.tool == n).unwrap();
        series.push(*model, vec![
            get("baseline").wall_s,
            get("FROST").wall_s,
            get("CodeCarbon-like").wall_s,
            get("Eco2AI-like").wall_s,
            get("FROST").relative,
            get("CodeCarbon-like").relative,
            get("Eco2AI-like").relative,
        ]);
    }
    Ok(series)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::setup_no1;

    #[test]
    fn overhead_series_shape() {
        if Manifest::load_default().is_err() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = fig3_overhead(&setup_no1(), &["lenet"], 640, 1).unwrap();
        assert_eq!(s.len(), 1);
        let frost_rel = s.column("frost_rel").unwrap()[0];
        assert!(
            frost_rel < 1.15,
            "FROST must track the baseline (paper Fig. 3), got {frost_rel}"
        );
    }
}
