//! Fig. 4 — power capping sweeps for three example models
//! (paper Sec. IV-C, setup no.2): energy and time vs the eight cap levels,
//! normalised to the 100% default, plus each model's optimal limit.
//!
//! Paper: MobileNet and DenseNet optimal at 60%, EfficientNet at 40%;
//! energy reductions are more significant than the delays introduced.

use crate::config::{HardwareConfig, ProfilerConfig};
use crate::frost::PowerProfiler;
use crate::simulator::Testbed;
use crate::util::Series;
use crate::zoo::model_by_name;

/// Sweep `models` on `hw`; one row per (model, cap) with relative
/// energy/time, plus a summary row per model carrying the fitted optimum.
pub fn fig4_power_capping(hw: &HardwareConfig, models: &[&str], seed: u64) -> Series {
    let reference_gpu = crate::config::setup_no1().gpu;
    let mut series = Series::new(
        format!("Fig4: power capping on {}", hw.name),
        &["cap_pct", "rel_energy", "rel_time", "optimal_cap_pct", "saving_pct"],
    );
    for model in models {
        let entry = model_by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
        let w = entry.workload(&reference_gpu);
        let mut tb = Testbed::new(hw.clone(), seed);
        let profiler = PowerProfiler::new(ProfilerConfig {
            edp_exponent: 1.0, // Fig. 4 shows the raw energy/time response
            ..Default::default()
        });
        let out = profiler.profile(&mut tb, &w, 128);
        let baseline = out.points.last().unwrap();
        for p in &out.points {
            series.push(format!("{model}@{:.0}%", p.cap_frac * 100.0), vec![
                p.cap_frac * 100.0,
                p.energy_per_sample_j / baseline.energy_per_sample_j,
                p.time_per_sample_s / baseline.time_per_sample_s,
                out.optimal_cap * 100.0,
                out.est_energy_saving * 100.0,
            ]);
        }
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::setup_no2;

    fn sweep() -> Series {
        fig4_power_capping(&setup_no2(), &["MobileNet", "DenseNet", "EfficientNet"], 42)
    }

    #[test]
    fn three_models_by_eight_caps() {
        let s = sweep();
        assert_eq!(s.len(), 24);
    }

    #[test]
    fn optima_interior_and_ordered() {
        let s = sweep();
        let opt = |model: &str| {
            let i = s.labels.iter().position(|l| l.starts_with(model)).unwrap();
            s.rows[i][3]
        };
        let (mob, den, eff) = (opt("MobileNet"), opt("DenseNet"), opt("EfficientNet"));
        // All interior (capping pays off for all three — paper Fig. 4)…
        for (name, o) in [("MobileNet", mob), ("DenseNet", den), ("EfficientNet", eff)] {
            assert!(o >= 30.0 && o <= 75.0, "{name} optimum {o}% not interior");
        }
        // …and EfficientNet (most bandwidth-bound) caps lowest (paper: 40%
        // vs 60%/60%).
        assert!(eff <= mob + 2.5 && eff <= den + 2.5, "eff {eff} mob {mob} den {den}");
    }

    #[test]
    fn energy_reductions_exceed_delays() {
        // Paper: "energy reductions were more significant than delays".
        let s = sweep();
        for (label, row) in s.labels.iter().zip(&s.rows) {
            let (cap, rel_e, rel_t) = (row[0], row[1], row[2]);
            if (45.0..95.0).contains(&cap) {
                let saving = 1.0 - rel_e;
                let delay = rel_t - 1.0;
                // Tolerance: deep in the memory-bound plateau both are ~0.
                assert!(
                    saving > delay - 0.01,
                    "{label}: saving {saving:.3} must exceed delay {delay:.3}"
                );
            }
        }
    }

    #[test]
    fn extreme_caps_blow_up() {
        // Paper: below 30–40% energy AND time increase sharply.
        let s = sweep();
        for model in ["MobileNet", "DenseNet", "EfficientNet"] {
            let rows: Vec<&Vec<f64>> = s
                .labels
                .iter()
                .zip(&s.rows)
                .filter(|(l, _)| l.starts_with(model))
                .map(|(_, r)| r)
                .collect();
            let at30 = rows.iter().find(|r| r[0] < 35.0).unwrap();
            let best_time = rows.iter().map(|r| r[2]).fold(f64::INFINITY, f64::min);
            assert!(
                at30[2] > best_time * 1.05,
                "{model}: 30% cap time {} should exceed best {}",
                at30[2],
                best_time
            );
        }
    }
}
