//! Fig. 5 — fine-grained experiment (paper Sec. IV-C): ResNet swept at 1%
//! cap increments on setup no.2, with the ED^xP optimum located for
//! x ∈ {1, 2, 3}.
//!
//! Paper findings: the more weight on delay, the higher the optimal cap;
//! for ED³P some optima sit at the maximum; EDP yields the biggest energy
//! savings.

use crate::config::{HardwareConfig, ProfilerConfig};
use crate::frost::{EdpCriterion, PowerProfiler};
use crate::simulator::Testbed;
use crate::util::Series;
use crate::zoo::model_by_name;

/// Output: the sweep plus per-criterion optima.
#[derive(Debug, Clone)]
pub struct Fig5Output {
    /// Rows per cap %: rel_energy, rel_time.
    pub sweep: Series,
    /// (exponent, optimal cap %, est saving %, est slowdown %).
    pub optima: Vec<(f64, f64, f64, f64)>,
}

pub fn fig5_fine_grained(hw: &HardwareConfig, model: &str, seed: u64) -> Fig5Output {
    let reference_gpu = crate::config::setup_no1().gpu;
    let entry = model_by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
    let w = entry.workload(&reference_gpu);

    // One fine sweep (71 caps) measured once…
    let mut tb = Testbed::new(hw.clone(), seed);
    let profiler = PowerProfiler::new(ProfilerConfig {
        edp_exponent: 1.0,
        ..ProfilerConfig::fine_grained()
    });
    let out = profiler.profile(&mut tb, &w, 128);
    let baseline = out.points.last().unwrap().clone();
    let mut sweep = Series::new(
        format!("Fig5: {model} fine-grained sweep on {}", hw.name),
        &["cap_pct", "rel_energy", "rel_time"],
    );
    for p in &out.points {
        sweep.push(format!("{:.0}%", p.cap_frac * 100.0), vec![
            p.cap_frac * 100.0,
            p.energy_per_sample_j / baseline.energy_per_sample_j,
            p.time_per_sample_s / baseline.time_per_sample_s,
        ]);
    }

    // …then re-scored under each ED^xP criterion (the measurements are the
    // same; only the decision metric changes).
    let mut optima = Vec::new();
    for exponent in [1.0, 2.0, 3.0] {
        let criterion = EdpCriterion::new(exponent);
        let xy: Vec<(f64, f64)> = out
            .points
            .iter()
            .map(|p| {
                (p.cap_frac, criterion.score(p.energy_per_sample_j, p.time_per_sample_s))
            })
            .collect();
        let fit = crate::frost::fit::fit_response(&xy, 0.05);
        let lo = out.points.first().unwrap().cap_frac;
        let hi = out.points.last().unwrap().cap_frac;
        let (opt, _) = fit.minimize(lo, hi);
        // Interpolate energy/time at the optimum from the measured sweep.
        let interp = |f: &dyn Fn(&crate::frost::ProfilePoint) -> f64| -> f64 {
            let mut prev = &out.points[0];
            if opt <= prev.cap_frac {
                return f(prev);
            }
            for p in &out.points[1..] {
                if opt <= p.cap_frac {
                    let t = (opt - prev.cap_frac) / (p.cap_frac - prev.cap_frac);
                    return f(prev) * (1.0 - t) + f(p) * t;
                }
                prev = p;
            }
            f(out.points.last().unwrap())
        };
        let e = interp(&|p| p.energy_per_sample_j);
        let t = interp(&|p| p.time_per_sample_s);
        optima.push((
            exponent,
            opt * 100.0,
            (1.0 - e / baseline.energy_per_sample_j) * 100.0,
            (t / baseline.time_per_sample_s - 1.0) * 100.0,
        ));
    }
    Fig5Output { sweep, optima }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::setup_no2;

    fn output() -> Fig5Output {
        fig5_fine_grained(&setup_no2(), "ResNet", 42)
    }

    #[test]
    fn sweep_covers_the_driver_range() {
        let out = output();
        assert!(out.sweep.len() >= 65, "{} points", out.sweep.len());
        let caps = out.sweep.column("cap_pct").unwrap();
        assert!(caps[0] <= 31.0);
        assert!(*caps.last().unwrap() >= 99.0);
    }

    #[test]
    fn optimum_rises_with_exponent() {
        // Paper: "the more weight attributed to delay, the higher the
        // optimal power limit becomes".
        let out = output();
        let caps: Vec<f64> = out.optima.iter().map(|o| o.1).collect();
        assert!(caps[1] >= caps[0] - 1.5, "ED2P {} < EDP {}", caps[1], caps[0]);
        assert!(caps[2] >= caps[1] - 1.5, "ED3P {} < ED2P {}", caps[2], caps[1]);
        assert!(caps[2] > caps[0], "ED3P must exceed EDP strictly");
        // More delay weight must not pick a *slower* configuration.
        let delays: Vec<f64> = out.optima.iter().map(|o| o.3).collect();
        assert!(delays[2] <= delays[0] + 0.5, "ED3P delay {} vs EDP {}", delays[2], delays[0]);
    }

    #[test]
    fn edp_gives_biggest_savings() {
        let out = output();
        let savings: Vec<f64> = out.optima.iter().map(|o| o.2).collect();
        assert!(
            savings[0] >= savings[1] - 0.5 && savings[0] >= savings[2] - 0.5,
            "EDP saving {savings:?} must be the largest"
        );
        assert!(savings[0] > 5.0, "EDP must deliver real savings, got {savings:?}");
    }

    #[test]
    fn time_monotone_nonincreasing_in_cap() {
        // More power never makes training slower (within noise).
        let out = output();
        let caps = out.sweep.column("cap_pct").unwrap();
        let times = out.sweep.column("rel_time").unwrap();
        for i in 1..caps.len() {
            assert!(
                times[i] <= times[i - 1] * 1.05,
                "time jumped at {}%: {} -> {}",
                caps[i],
                times[i - 1],
                times[i]
            );
        }
    }
}
