//! Fig. 6 — the tradeoff overview (paper Sec. IV-C): for every model,
//! energy reduction vs delay introduced under the chosen ED^mP criterion.
//!
//! Paper headline: with ED²P as the sweet spot, **26.4%** mean energy
//! saving on setup no.1 (vs 17.7% on no.2) at **+6.9%** (+5.5%) training
//! time; LeNet shows no change; power capping effective on all models and
//! both setups.

use crate::config::{HardwareConfig, ProfilerConfig};
use crate::frost::PowerProfiler;
use crate::simulator::Testbed;
use crate::util::Series;
use crate::zoo::all_models;

/// Per-model tradeoffs + the headline means.
#[derive(Debug, Clone)]
pub struct Fig6Output {
    /// Rows per model: optimal_cap_pct, saving_pct, delay_pct.
    pub table: Series,
    pub mean_saving_pct: f64,
    pub mean_delay_pct: f64,
}

/// Run the full-zoo tradeoff on one setup with the given ED^mP exponent
/// (paper uses m = 2 for this figure).
pub fn fig6_tradeoff(hw: &HardwareConfig, exponent: f64, seed: u64) -> Fig6Output {
    let reference_gpu = crate::config::setup_no1().gpu;
    let mut table = Series::new(
        format!("Fig6: ED{exponent}P tradeoff on {}", hw.name),
        &["optimal_cap_pct", "saving_pct", "delay_pct"],
    );
    let mut savings = Vec::new();
    let mut delays = Vec::new();
    for (i, entry) in all_models().iter().enumerate() {
        let w = entry.workload(&reference_gpu);
        let mut tb = Testbed::new(hw.clone(), seed + i as u64);
        let profiler = PowerProfiler::new(ProfilerConfig {
            edp_exponent: exponent,
            ..Default::default()
        });
        let out = profiler.profile(&mut tb, &w, 128);
        let saving = out.est_energy_saving * 100.0;
        let delay = (out.est_slowdown - 1.0) * 100.0;
        savings.push(saving);
        delays.push(delay);
        table.push(entry.name, vec![out.optimal_cap * 100.0, saving, delay]);
    }
    Fig6Output {
        table,
        mean_saving_pct: savings.iter().sum::<f64>() / savings.len() as f64,
        mean_delay_pct: delays.iter().sum::<f64>() / delays.len() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{setup_no1, setup_no2};

    #[test]
    fn headline_savings_in_paper_range() {
        // Paper: 26.4% (no.1) and 17.7% (no.2) mean savings with ED²P at
        // +6.9% / +5.5% time. The shape requirement: double-digit mean
        // savings, single-digit mean delay, on both setups.
        for (hw, name) in [(setup_no1(), "no1"), (setup_no2(), "no2")] {
            let out = fig6_tradeoff(&hw, 2.0, 42);
            assert!(
                out.mean_saving_pct > 10.0 && out.mean_saving_pct < 40.0,
                "setup {name}: mean saving {:.1}%",
                out.mean_saving_pct
            );
            assert!(
                out.mean_delay_pct < 10.0,
                "setup {name}: mean delay {:.1}%",
                out.mean_delay_pct
            );
            assert!(
                out.mean_saving_pct > out.mean_delay_pct,
                "savings must dominate delays"
            );
        }
    }

    #[test]
    fn all_sixteen_models_present() {
        let out = fig6_tradeoff(&setup_no1(), 2.0, 42);
        assert_eq!(out.table.len(), 16);
    }

    #[test]
    fn lenet_shows_no_change() {
        let out = fig6_tradeoff(&setup_no1(), 2.0, 42);
        let i = out.table.labels.iter().position(|l| l == "LeNet").unwrap();
        let saving = out.table.rows[i][1];
        let delay = out.table.rows[i][2];
        assert!(saving.abs() < 12.0, "LeNet saving {saving}% should be negligible");
        assert!(delay.abs() < 3.0, "LeNet delay {delay}%");
    }

    #[test]
    fn no_model_pays_more_delay_than_saving() {
        let out = fig6_tradeoff(&setup_no1(), 2.0, 42);
        for (label, row) in out.table.labels.iter().zip(&out.table.rows) {
            let (saving, delay) = (row[1], row[2]);
            if label != "LeNet" {
                assert!(
                    saving + 1.0 >= delay,
                    "{label}: delay {delay}% exceeds saving {saving}%"
                );
            }
        }
    }

    #[test]
    fn setup1_saves_more_than_setup2() {
        // Paper: 26.4% on no.1 vs 17.7% on no.2 (the 3090 was utilised
        // suboptimally by these models). Same ordering required.
        let s1 = fig6_tradeoff(&setup_no1(), 2.0, 42);
        let s2 = fig6_tradeoff(&setup_no2(), 2.0, 42);
        assert!(
            s1.mean_saving_pct > s2.mean_saving_pct - 2.0,
            "setup1 {:.1}% should be >= setup2 {:.1}%",
            s1.mean_saving_pct,
            s2.mean_saving_pct
        );
    }
}
