//! Fleet roll-up: an N-site FROST deployment vs the identical baseline
//! fleet (same seed, same hardware mix, same workloads, stock power caps).
//!
//! Extends the paper's single-host Fig. 6 tradeoff to RAN scale: the
//! headline number is the **steady-state fleet energy saving** — the final
//! orchestration round's workload energy under FROST relative to the
//! baseline's (initial training rounds run uncapped in both fleets, so
//! lifetime totals dilute the effect; the steady state is what a deployed
//! fleet pays forever). Per the paper, savings land in the 10–26% band
//! with no per-site accuracy loss.

use anyhow::Result;

use crate::ckpt::{restore_fleet_with, write_fleet_snapshot, CkptOptions, DriveOutcome, Snapshot};
use crate::obs::TraceSink;
use crate::oran::{Fleet, FleetConfig, FleetReport};
use crate::util::Series;

/// Output of [`fleet_comparison`].
#[derive(Debug, Clone)]
pub struct FleetFigOutput {
    /// One row per site: cap, ED^mP exponent, baseline/FROST steady-state
    /// energy, savings, accuracy.
    pub table: Series,
    /// One row per region (§16): membership, steady-state energy, cap
    /// wattage, sub-budget (−1 when none is in force), offered load and
    /// steady-replay site-rounds.  Empty on region-free fleets.
    pub region_table: Series,
    /// 1 − (FROST final-round fleet energy / baseline final-round energy).
    pub steady_saving_frac: f64,
    /// Mean of FROST's own per-site saving estimates (profiled sites).
    pub mean_est_saving_frac: f64,
    pub baseline_round_j: f64,
    pub frost_round_j: f64,
    /// Total energy charged to profiling sweeps across the fleet.
    pub profiling_j: f64,
    pub mean_cap_frac: f64,
    /// True iff no site's validation accuracy dropped under FROST.
    pub accuracy_unchanged: bool,
    pub kpm_reports: usize,
    /// The full FROST-run roll-up, for callers that want more detail.
    pub frost: FleetReport,
    /// The baseline roll-up.
    pub baseline: FleetReport,
    /// The FROST run's trace spine (empty unless `FleetConfig::trace`;
    /// the baseline run is not traced).
    pub trace: TraceSink,
}

/// Run the fleet twice — FROST on, then the stock-cap baseline — and
/// compare site by site. `config.frost_enabled` is overridden per run.
pub fn fleet_comparison(config: &FleetConfig) -> Result<FleetFigOutput> {
    Ok(fleet_comparison_ckpt(config, &CkptOptions::disabled())?.expect_done("fleet_comparison"))
}

/// [`fleet_comparison`] with checkpoint/crash-injection support: the
/// primary (FROST) leg snapshots on the configured cadence; the baseline
/// leg re-runs deterministically from config on resume, so it needs no
/// snapshots of its own.
pub fn fleet_comparison_ckpt(
    config: &FleetConfig,
    opts: &CkptOptions,
) -> Result<DriveOutcome<FleetFigOutput>> {
    let mut frost_cfg = config.clone();
    frost_cfg.frost_enabled = true;
    drive(Fleet::new(frost_cfg)?, opts)
}

/// Resume a crashed [`fleet_comparison_ckpt`] from its snapshot and run
/// it to completion, continuing to checkpoint under the same options.
/// `threads` overrides the snapshot's worker count (resume is
/// thread-count independent).
pub fn fleet_resume(
    snap: &Snapshot,
    threads: Option<usize>,
    opts: &CkptOptions,
) -> Result<DriveOutcome<FleetFigOutput>> {
    anyhow::ensure!(
        snap.header.kind == "fleet",
        "snapshot {} is a '{}' run, not a fleet comparison",
        snap.path.display(),
        snap.header.kind
    );
    drive(restore_fleet_with(snap, threads)?, opts)
}

fn drive(mut frost_fleet: Fleet, opts: &CkptOptions) -> Result<DriveOutcome<FleetFigOutput>> {
    let rounds = frost_fleet.config.rounds;
    for round in (frost_fleet.round + 1)..=rounds {
        frost_fleet.run_round()?;
        if opts.due(round) {
            let dir = opts.dir.as_ref().expect("due() implies a snapshot directory");
            let snapshot = write_fleet_snapshot(&frost_fleet, "fleet", "-", dir, opts.keep)?;
            if opts.crash_at == Some(round) {
                return Ok(DriveOutcome::Crashed { round, snapshot });
            }
        }
    }
    // The baseline leg is derived from the FROST leg's config, which
    // preserves the caller's settings except `frost_enabled` — so a
    // resumed run rebuilds the identical baseline.
    let mut base_cfg = (*frost_fleet.config).clone();
    base_cfg.frost_enabled = false;
    base_cfg.budget_frac = 1.0;
    // Only the FROST run is traced (it is the leg making cap decisions).
    base_cfg.trace = false;
    let sites = base_cfg.sites;
    let seed = base_cfg.seed;

    let frost = frost_fleet.report();
    let trace = frost_fleet.trace;
    let baseline = Fleet::new(base_cfg)?.run()?;

    let mut table = Series::new(
        format!("Fleet tradeoff: {sites} sites, seed {seed}"),
        &[
            "cap_pct",
            "edp_m",
            "base_round_kj",
            "frost_round_kj",
            "steady_saving_pct",
            "est_saving_pct",
            "accuracy_pct",
            "accuracy_delta_pp",
        ],
    );
    let mut accuracy_unchanged = true;
    for (f, b) in frost.sites.iter().zip(&baseline.sites) {
        let steady = if b.round_energy_j > 0.0 {
            1.0 - f.round_energy_j / b.round_energy_j
        } else {
            0.0
        };
        let delta_pp = (f.accuracy - b.accuracy) * 100.0;
        if f.accuracy + 1e-12 < b.accuracy {
            accuracy_unchanged = false;
        }
        table.push(format!("{} {}", f.name, f.model), vec![
            f.cap_frac * 100.0,
            f.qos.criterion().exponent,
            b.round_energy_j / 1e3,
            f.round_energy_j / 1e3,
            steady * 100.0,
            f.est_saving * 100.0,
            f.accuracy * 100.0,
            delta_pp,
        ]);
    }

    let mut region_table = Series::new(
        format!("Region roll-up: {} regions, {sites} sites", frost.regions.len()),
        &[
            "sites",
            "up_sites",
            "round_kj",
            "cap_w",
            "sub_budget_w",
            "load_per_s",
            "steady_site_rounds",
        ],
    );
    for r in &frost.regions {
        region_table.push(r.name.clone(), vec![
            r.sites as f64,
            r.up_sites as f64,
            r.round_energy_j / 1e3,
            r.cap_power_w,
            // −1 = no sub-budget in force (flat stepping or fill pending).
            r.sub_budget_w.unwrap_or(-1.0),
            r.offered_load_per_s,
            r.steady_site_rounds as f64,
        ]);
    }

    let steady_saving_frac = if baseline.fleet_round_energy_j > 0.0 {
        1.0 - frost.fleet_round_energy_j / baseline.fleet_round_energy_j
    } else {
        0.0
    };
    Ok(DriveOutcome::Done(FleetFigOutput {
        steady_saving_frac,
        mean_est_saving_frac: frost.mean_est_saving,
        baseline_round_j: baseline.fleet_round_energy_j,
        frost_round_j: frost.fleet_round_energy_j,
        profiling_j: frost.fleet_profiling_energy_j,
        mean_cap_frac: frost.mean_cap_frac,
        accuracy_unchanged,
        kpm_reports: frost.kpm_reports,
        table,
        region_table,
        frost,
        baseline,
        trace,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_comparison_saves_without_accuracy_loss() {
        let cfg = FleetConfig {
            sites: 4,
            seed: 21,
            rounds: 6,
            train_epochs: 40,
            samples_per_epoch: 10_000,
            infer_steps_per_round: 25,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        };
        let out = fleet_comparison(&cfg).unwrap();
        assert_eq!(out.table.len(), 4);
        assert!(
            out.steady_saving_frac > 0.02 && out.steady_saving_frac < 0.50,
            "steady saving {:.3}",
            out.steady_saving_frac
        );
        assert!(out.accuracy_unchanged, "capping must not change accuracy");
        assert!(out.profiling_j > 0.0);
        assert!(out.frost_round_j < out.baseline_round_j);
        // Per-site steady savings dominate: most sites save energy.
        let saving_col = out.table.column("steady_saving_pct").unwrap();
        let saved = saving_col.iter().filter(|&&s| s > 0.0).count();
        assert!(saved >= 3, "{saved} of 4 sites saved");
    }

    #[test]
    fn hierarchical_fleet_comparison_rolls_up_regions() {
        use crate::oran::RegionMap;
        let cfg = FleetConfig {
            sites: 6,
            seed: 21,
            rounds: 6,
            train_epochs: 5,
            samples_per_epoch: 1_000,
            infer_steps_per_round: 6,
            budget_frac: 0.85,
            regions: Some(RegionMap::auto(6, 2).unwrap()),
            ..FleetConfig::default()
        };
        let out = fleet_comparison(&cfg).unwrap();
        assert_eq!(out.region_table.len(), 2, "one row per region");
        assert_eq!(out.frost.regions.len(), 2);
        let total_sites: usize = out.frost.regions.iter().map(|r| r.sites).sum();
        assert_eq!(total_sites, 6, "regions partition the fleet");
        for r in &out.frost.regions {
            assert!(r.round_energy_j > 0.0, "{} energy", r.name);
            assert!(r.cap_power_w > 0.0, "{} cap wattage", r.name);
        }
        // With the budget enforced, the sub-budgets conserve it.
        if out.frost.budget_enforced {
            let budget = out.frost.budget_w.expect("budget_frac < 1 sets a budget");
            let sub_sum: f64 =
                out.frost.regions.iter().filter_map(|r| r.sub_budget_w).sum();
            assert!(sub_sum <= budget + 1e-6, "Σ sub-budgets {sub_sum} > {budget}");
        }
        // The flat baseline leg carries no region roll-up rows with
        // sub-budgets in force (the baseline enforces no budget).
        assert!(out.baseline.regions.iter().all(|r| r.sub_budget_w.is_none()));
    }

    #[test]
    fn fleet_comparison_crash_resume_matches_the_uninterrupted_run() {
        let cfg = FleetConfig {
            sites: 2,
            seed: 21,
            rounds: 4,
            train_epochs: 3,
            samples_per_epoch: 500,
            infer_steps_per_round: 4,
            ..FleetConfig::default()
        };
        let gold = fleet_comparison(&cfg).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("frost-fleet-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut opts = CkptOptions::at(dir);
        opts.crash_at = Some(2);
        let (round, snapshot) = match fleet_comparison_ckpt(&cfg, &opts).unwrap() {
            DriveOutcome::Crashed { round, snapshot } => (round, snapshot),
            DriveOutcome::Done(_) => panic!("crash injection must fire"),
        };
        assert_eq!(round, 2);
        opts.crash_at = None;
        let resumed = fleet_resume(&Snapshot::load(&snapshot).unwrap(), None, &opts)
            .unwrap()
            .expect_done("resume");
        assert_eq!(format!("{:?}", resumed.frost), format!("{:?}", gold.frost));
        assert_eq!(format!("{:?}", resumed.baseline), format!("{:?}", gold.baseline));
        assert_eq!(format!("{:?}", resumed.table), format!("{:?}", gold.table));
    }
}
