//! Figure/table regeneration harnesses — one per figure of the paper's
//! evaluation (DESIGN.md §5 experiment index).
//!
//! Every harness returns [`crate::util::Series`] tables so the CLI, the
//! integration tests and the benches all consume the same code path:
//!
//! * [`fig2`]  — initial energy investigation (accuracy/energy/time/util);
//! * [`fig3`]  — measurement-tool overhead on real PJRT inference;
//! * [`fig4`]  — power-capping sweeps for three example models;
//! * [`fig5`]  — fine-grained 1% sweep + ED^xP optima for ResNet;
//! * [`fig6`]  — energy-saving vs delay tradeoff across all 16 models,
//!   including the paper's headline means.

mod audit;
pub mod chaos;
pub mod fig2;
#[cfg(feature = "pjrt")]
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fleet;
pub mod scenario;
pub mod traffic;

pub use chaos::{
    chaos_config, chaos_resume, chaos_run, chaos_run_ckpt, ChaosFigOutput,
    CHAOS_QUIET_TAIL_ROUNDS,
};
pub use fig2::{fig2_investigation, Fig2Output};
#[cfg(feature = "pjrt")]
pub use fig3::fig3_overhead;
pub use fig4::fig4_power_capping;
pub use fig5::{fig5_fine_grained, Fig5Output};
pub use fig6::{fig6_tradeoff, Fig6Output};
pub use fleet::{fleet_comparison, fleet_comparison_ckpt, fleet_resume, FleetFigOutput};
pub use scenario::{
    scenario_comparison, scenario_comparison_ckpt, scenario_resume, PhaseSummary,
    ScenarioFigOutput,
};
pub use traffic::{traffic_comparison, TrafficFigOutput, QOS_CLASSES};
