//! Scenario roll-up: FROST vs stock caps over the same scripted
//! operational day (DESIGN.md §11).
//!
//! Both fleets run the identical seed, hardware mix, arrival streams
//! *and event script* — outages, flash crowds and derates hit the
//! baseline too (they are physical/world events); only budget steps are
//! FROST-side, since a stock-cap fleet enforces no budget.  The report
//! slices the day by the scenario's **phases** (per-phase energy, SLO
//! attainment and the latency_critical p99 from the per-phase
//! histograms) and carries the per-event ledger plus the budget
//! conservation audit: the maximum, over every round with the water-fill
//! in force, of Σ applied-cap watts minus the scripted budget — ≤ 0
//! means the fleet never exceeded the budget in any round, including
//! budget-step, churn and recovery rounds.

use anyhow::{Context, Result};

use crate::ckpt::codec::{jf64, jusize, w_f64};
use crate::ckpt::{
    restore_fleet_with, write_fleet_snapshot_with, CkptOptions, DriveOutcome, Snapshot,
};
use crate::frost::QosClass;
use crate::metrics::LatencyHistogram;
use crate::obs::TraceSink;
use crate::oran::{FiredEvent, Fleet, FleetConfig, FleetReport};
use crate::scenario::Scenario;
use crate::traffic::{SloSummary, TrafficConfig};
use crate::util::Series;

use super::audit::RegionAudit;
use super::traffic::class_day_rollup;

/// One phase of the scripted day, compared across the two fleets.
#[derive(Debug, Clone)]
pub struct PhaseSummary {
    pub name: String,
    pub from_slot: u32,
    pub to_slot: u32,
    /// True when a scripted outage overlaps this phase (the latency
    /// acceptance gate exempts outage windows).
    pub outage: bool,
    /// FROST-run request counters over the phase's slots.
    pub offered: u64,
    pub served: u64,
    pub dropped: u64,
    pub late: u64,
    pub frost_energy_j: f64,
    pub base_energy_j: f64,
    /// 1 − FROST/baseline over this phase.
    pub saving_frac: f64,
    /// latency_critical p99 within the phase (per-phase histograms).
    pub frost_lc_p99_s: f64,
    pub base_lc_p99_s: f64,
    pub frost_attainment: f64,
    pub base_attainment: f64,
}

/// Output of [`scenario_comparison`].
#[derive(Debug, Clone)]
pub struct ScenarioFigOutput {
    /// One row per scenario phase (energy both ways, LC p99, attainment).
    pub phase_table: Series,
    /// One row per QoS class over the whole day (same shape as the
    /// traffic harness's class table).
    pub class_table: Series,
    pub phases: Vec<PhaseSummary>,
    pub frost_slo: Vec<SloSummary>,
    pub base_slo: Vec<SloSummary>,
    pub frost_day_energy_j: f64,
    pub base_day_energy_j: f64,
    pub day_saving_frac: f64,
    /// Every fired event of the FROST run, in dispatch order (the
    /// baseline fires the identical script).
    pub event_log: Vec<FiredEvent>,
    /// max over audited rounds of (Σ applied-cap watts − budget watts);
    /// ≤ 0 ⇔ the budget was conserved in every round it was in force.
    pub max_cap_excess_w: f64,
    /// Rounds the conservation audit covered (water-fill in force).
    pub budget_audited_rounds: usize,
    /// Audited rounds where regional sub-budgets were in force (§16;
    /// 0 on flat fleets).
    pub region_audited_rounds: usize,
    /// max over region-audited rounds of (Σ regional sub-budget watts −
    /// global budget watts); ≤ 0 ⇔ the top-level allocation never
    /// over-committed the budget.
    pub max_subbudget_excess_w: f64,
    /// max over region-audited rounds and regions of (region applied-cap
    /// watts − region sub-budget watts); ≤ 0 ⇔ every regional fill
    /// stayed within its allocation.
    pub max_region_excess_w: f64,
    pub frost: FleetReport,
    pub baseline: FleetReport,
    /// The FROST run's trace spine (empty unless `FleetConfig::trace`;
    /// the baseline run is not traced — it enforces no caps).
    pub trace: TraceSink,
}

/// Per-class and per-phase aggregates of one fleet's scripted day.
struct DayCollect {
    day_energy_j: f64,
    slo: Vec<SloSummary>,
    phase_energy_j: Vec<f64>,
    /// offered/served/dropped/late per phase.
    phase_counts: Vec<(u64, u64, u64, u64)>,
    /// latency_critical per-phase histograms, merged in site order.
    lc_phase: Vec<LatencyHistogram>,
}

fn collect(fleet: &Fleet, scen: &Scenario, tr: &TrafficConfig) -> DayCollect {
    let n_phases = scen.phases.len();
    let mut phase_energy_j = vec![0.0; n_phases];
    let mut phase_counts = vec![(0u64, 0u64, 0u64, 0u64); n_phases];
    let mut lc_phase: Vec<LatencyHistogram> =
        (0..n_phases).map(|_| LatencyHistogram::new()).collect();
    let mut day_energy_j = 0.0;
    // Phase-sliced aggregates, in site-index order (§6); the per-class
    // day roll-up is the shared `class_day_rollup` the traffic harness
    // uses, so the two reports cannot drift.
    for site in &fleet.sites {
        let t = site.traffic.as_ref().expect("scenario fleets are traffic-driven");
        if site.qos == QosClass::LatencyCritical {
            for (p, h) in t.phase_hists.iter().enumerate() {
                lc_phase[p].merge(h);
            }
        }
        for s in &t.slot_log {
            let p = scen.phase_of_slot(s.slot_in_day);
            phase_energy_j[p] += s.energy_j;
            let pc = &mut phase_counts[p];
            pc.0 += s.offered;
            pc.1 += s.served;
            pc.2 += s.dropped;
            pc.3 += s.late;
        }
        day_energy_j += t.day_energy_j;
    }
    let slo = class_day_rollup(fleet, &tr.slo);
    DayCollect { day_energy_j, slo, phase_energy_j, phase_counts, lc_phase }
}

fn saving(frost_j: f64, base_j: f64) -> f64 {
    if base_j > 0.0 {
        1.0 - frost_j / base_j
    } else {
        0.0
    }
}

fn attainment((offered, served, _dropped, late): (u64, u64, u64, u64)) -> f64 {
    if offered > 0 {
        served.saturating_sub(late) as f64 / offered as f64
    } else {
        1.0
    }
}

/// Run the same scripted day twice — FROST on, then stock caps — and
/// compare per-phase energy, latency and attainment.  `config.traffic`
/// and `config.scenario` must both be set; `frost_enabled` is overridden
/// per run (the baseline also drops budget enforcement, but experiences
/// the identical outage/surge/derate script).
pub fn scenario_comparison(config: &FleetConfig) -> Result<ScenarioFigOutput> {
    Ok(scenario_comparison_ckpt(config, &CkptOptions::disabled())?
        .expect_done("scenario_comparison"))
}

/// [`scenario_comparison`] with checkpoint/crash-injection support: the
/// primary (FROST) leg snapshots on the configured cadence, carrying the
/// budget-audit accumulators in a `harness` section; the baseline leg
/// re-runs deterministically from config on resume.
pub fn scenario_comparison_ckpt(
    config: &FleetConfig,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ScenarioFigOutput>> {
    anyhow::ensure!(
        config.traffic.is_some(),
        "scenario_comparison needs FleetConfig::traffic set"
    );
    anyhow::ensure!(
        config.scenario.is_some(),
        "scenario_comparison needs FleetConfig::scenario set"
    );
    let mut frost_cfg = config.clone();
    frost_cfg.frost_enabled = true;
    drive(Fleet::new(frost_cfg)?, 0, f64::NEG_INFINITY, RegionAudit::new(), opts)
}

/// Resume a crashed [`scenario_comparison_ckpt`] from its snapshot,
/// restoring the budget-audit accumulators alongside the fleet.
/// `threads` overrides the snapshot's worker count (resume is
/// thread-count independent).
pub fn scenario_resume(
    snap: &Snapshot,
    threads: Option<usize>,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ScenarioFigOutput>> {
    anyhow::ensure!(
        snap.header.kind == "scenario",
        "snapshot {} is a '{}' run, not a scenario comparison",
        snap.path.display(),
        snap.header.kind
    );
    let harness = snap.section("harness")?;
    let audited = jusize(&harness, "audited")?;
    let max_cap_excess_w = jf64(&harness, "max_excess")?;
    let region_audit = RegionAudit::resume(
        jusize(&harness, "region_audited")?,
        jf64(&harness, "max_sub_excess")?,
        jf64(&harness, "max_region_excess")?,
    );
    drive(restore_fleet_with(snap, threads)?, audited, max_cap_excess_w, region_audit, opts)
}

fn drive(
    mut frost_fleet: Fleet,
    mut audited: usize,
    mut max_cap_excess_w: f64,
    mut region_audit: RegionAudit,
    opts: &CkptOptions,
) -> Result<DriveOutcome<ScenarioFigOutput>> {
    let tr = frost_fleet
        .config
        .traffic
        .clone()
        .context("scenario_comparison needs FleetConfig::traffic set")?;
    let scen = frost_fleet
        .config
        .scenario
        .clone()
        .context("scenario_comparison needs FleetConfig::scenario set")?;
    let mut base_cfg = (*frost_fleet.config).clone();
    base_cfg.frost_enabled = false;
    base_cfg.budget_frac = 1.0;
    // Only the FROST run is traced: the baseline enforces no caps, so a
    // second spine would double the export for no attribution value.
    base_cfg.trace = false;
    let sites = base_cfg.sites;
    let seed = base_cfg.seed;
    let rounds = base_cfg.rounds;

    // Drive the FROST run round by round so the budget conservation
    // invariant can be audited *every* round the water-fill is in force
    // (budget steps, outage/recovery and churn rounds included).
    for round in (frost_fleet.round + 1)..=rounds {
        frost_fleet.run_round()?;
        let rep = frost_fleet.report();
        if rep.budget_enforced {
            if let Some(budget_w) = rep.budget_w {
                audited += 1;
                max_cap_excess_w = max_cap_excess_w.max(rep.cap_power_w - budget_w);
                region_audit.absorb(&rep.regions, budget_w);
            }
        }
        if opts.due(round) {
            let dir = opts.dir.as_ref().expect("due() implies a snapshot directory");
            let snapshot = write_fleet_snapshot_with(
                &frost_fleet,
                "scenario",
                &scen.name,
                dir,
                opts.keep,
                |sw| {
                    sw.section("harness", |js| {
                        js.u64_field(Some("audited"), audited as u64);
                        w_f64(js, Some("max_excess"), max_cap_excess_w);
                        let (ra, sub, reg) = region_audit.raw();
                        js.u64_field(Some("region_audited"), ra as u64);
                        w_f64(js, Some("max_sub_excess"), sub);
                        w_f64(js, Some("max_region_excess"), reg);
                    })?;
                    Ok(())
                },
            )?;
            if opts.crash_at == Some(round) {
                return Ok(DriveOutcome::Crashed { round, snapshot });
            }
        }
    }
    let frost_report = frost_fleet.report();
    let mut base_fleet = Fleet::new(base_cfg)?;
    let base_report = base_fleet.run()?;

    let f = collect(&frost_fleet, &scen, &tr);
    let b = collect(&base_fleet, &scen, &tr);

    let mut phases = Vec::with_capacity(scen.phases.len());
    let mut phase_table = Series::new(
        format!("Scenario '{}': {sites} sites, seed {seed}", scen.name),
        &[
            "slots",
            "offered",
            "base_kj",
            "frost_kj",
            "saving_pct",
            "frost_lc_p99_ms",
            "base_lc_p99_ms",
            "frost_attain_pct",
            "base_attain_pct",
            "frost_dropped",
        ],
    );
    for (p, phase) in scen.phases.iter().enumerate() {
        let (offered, served, dropped, late) = f.phase_counts[p];
        let summary = PhaseSummary {
            name: phase.name.clone(),
            from_slot: phase.from_slot,
            to_slot: phase.to_slot,
            outage: scen.phase_has_outage(p, &tr),
            offered,
            served,
            dropped,
            late,
            frost_energy_j: f.phase_energy_j[p],
            base_energy_j: b.phase_energy_j[p],
            saving_frac: saving(f.phase_energy_j[p], b.phase_energy_j[p]),
            frost_lc_p99_s: f.lc_phase[p].percentile(0.99),
            base_lc_p99_s: b.lc_phase[p].percentile(0.99),
            frost_attainment: attainment(f.phase_counts[p]),
            base_attainment: attainment(b.phase_counts[p]),
        };
        phase_table.push(phase.name.clone(), vec![
            (phase.to_slot - phase.from_slot) as f64,
            summary.offered as f64,
            summary.base_energy_j / 1e3,
            summary.frost_energy_j / 1e3,
            summary.saving_frac * 100.0,
            summary.frost_lc_p99_s * 1e3,
            summary.base_lc_p99_s * 1e3,
            summary.frost_attainment * 100.0,
            summary.base_attainment * 100.0,
            summary.dropped as f64,
        ]);
        phases.push(summary);
    }

    let mut class_table = Series::new(
        "Scripted-day SLO per QoS class",
        &[
            "deadline_ms",
            "frost_p50_ms",
            "frost_p95_ms",
            "frost_p99_ms",
            "base_p99_ms",
            "frost_attain_pct",
            "base_attain_pct",
            "frost_dropped",
            "frost_late",
        ],
    );
    for (fs, bs) in f.slo.iter().zip(&b.slo) {
        class_table.push(fs.qos.as_str(), vec![
            fs.deadline_s * 1e3,
            fs.p50_s * 1e3,
            fs.p95_s * 1e3,
            fs.p99_s * 1e3,
            bs.p99_s * 1e3,
            fs.attainment * 100.0,
            bs.attainment * 100.0,
            fs.dropped as f64,
            fs.late as f64,
        ]);
    }

    Ok(DriveOutcome::Done(ScenarioFigOutput {
        phase_table,
        class_table,
        phases,
        frost_slo: f.slo,
        base_slo: b.slo,
        frost_day_energy_j: f.day_energy_j,
        base_day_energy_j: b.day_energy_j,
        day_saving_frac: saving(f.day_energy_j, b.day_energy_j),
        event_log: frost_fleet.fired_events(),
        max_cap_excess_w: if audited > 0 { max_cap_excess_w } else { 0.0 },
        budget_audited_rounds: audited,
        region_audited_rounds: region_audit.audited,
        max_subbudget_excess_w: region_audit.max_subbudget_excess(),
        max_region_excess_w: region_audit.max_region_excess(),
        frost: frost_report,
        baseline: base_report,
        trace: frost_fleet.trace,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn smoke_config(preset: &str) -> FleetConfig {
        let tr = TrafficConfig {
            users_per_site: 300,
            requests_per_user_per_day: 30.0,
            day_s: 900.0,
            slots_per_day: 6,
            warmup_rounds: 3,
            max_batch: 32,
            ..TrafficConfig::default()
        };
        let scen = Scenario::preset(preset, 4, &tr).expect("preset builds");
        FleetConfig {
            sites: 4,
            seed: 9,
            rounds: tr.rounds_for_one_day(),
            train_epochs: 40,
            samples_per_epoch: 5_000,
            max_concurrent_profiles: 4,
            budget_frac: if preset == "grid-step" { 0.9 } else { 1.0 },
            traffic: Some(tr),
            scenario: Some(scen),
            ..FleetConfig::default()
        }
    }

    #[test]
    fn scenario_comparison_reports_phases_events_and_saving() {
        let out = scenario_comparison(&smoke_config("outage-day")).unwrap();
        assert_eq!(out.phases.len(), 3);
        assert_eq!(out.phase_table.len(), 3);
        assert_eq!(out.class_table.len(), 3);
        assert_eq!(out.event_log.len(), 2, "outage + recovery fired");
        assert!(out.phases[1].outage && !out.phases[0].outage && !out.phases[2].outage);
        assert!(out.base_day_energy_j > 0.0 && out.frost_day_energy_j > 0.0);
        assert!(
            out.frost_day_energy_j < out.base_day_energy_j,
            "FROST day {} must undercut baseline {}",
            out.frost_day_energy_j,
            out.base_day_energy_j
        );
        // Conservation: offered = served + dropped per class (the day
        // flushes; outage sheds count as drops).
        for s in &out.frost_slo {
            assert_eq!(s.offered, s.served + s.dropped, "{:?}", s.qos);
            assert_eq!(s.non_finite, 0, "{:?}", s.qos);
        }
        // The baseline never profiles.
        assert_eq!(out.baseline.fleet_profiling_energy_j, 0.0);
    }

    #[test]
    fn scenario_comparison_requires_traffic_and_scenario() {
        let config = FleetConfig { sites: 2, ..FleetConfig::default() };
        assert!(scenario_comparison(&config).is_err());
        let mut config = smoke_config("outage-day");
        config.scenario = None;
        assert!(scenario_comparison(&config).is_err());
    }
}
