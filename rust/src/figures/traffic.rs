//! Traffic-day roll-up: a user-driven fleet under FROST vs the identical
//! stock-cap baseline (same seed, same hardware mix, same arrival
//! streams), compared over one seeded diurnal day (DESIGN.md §9).
//!
//! The headline numbers are the **traffic-day fleet energy saving**
//! (slot energy only — training and profiling are reported separately)
//! and the **SLO attainment per QoS class**: p50/p95/p99 request latency
//! against each class's deadline, plus dropped/late counts.  Off-peak and
//! peak slots are compared separately, because that is where a
//! load-blind cap and a FROST cap differ most.

use anyhow::{Context, Result};

use crate::config::setup_no1;
use crate::frost::QosClass;
use crate::metrics::LatencyHistogram;
use crate::oran::{Fleet, FleetConfig, FleetReport};
use crate::traffic::{SloSpec, SloSummary};
use crate::util::Series;

/// Class order used in every per-class table and vector.
pub const QOS_CLASSES: [QosClass; 3] =
    [QosClass::LatencyCritical, QosClass::Balanced, QosClass::EnergySaver];

/// Output of [`traffic_comparison`].
#[derive(Debug, Clone)]
pub struct TrafficFigOutput {
    /// One row per QoS class: deadline, FROST p50/p95/p99, baseline p99,
    /// attainment both ways, FROST dropped/late.
    pub class_table: Series,
    /// One row per slot of the day: offered rate, baseline/FROST energy,
    /// saving.
    pub slot_table: Series,
    /// One row per site: serving memory-boundedness (`infer_beta`), the
    /// day's demand, cap, energy both ways, and the site's p99.
    pub site_table: Series,
    pub frost_day_energy_j: f64,
    pub base_day_energy_j: f64,
    /// 1 − FROST/baseline over the whole traffic day.
    pub day_saving_frac: f64,
    /// Same, restricted to slots with below-mean offered load.
    pub offpeak_saving_frac: f64,
    /// Same, restricted to slots with above-mean offered load.
    pub peak_saving_frac: f64,
    /// Per-class roll-ups, in [`QOS_CLASSES`] order.
    pub frost_slo: Vec<SloSummary>,
    pub base_slo: Vec<SloSummary>,
    /// Monitor-requested re-profiles in the FROST run (signature drift or
    /// demand shift)…
    pub reprofile_requests: u64,
    /// …of which this many were demand-shift driven.
    pub load_shift_reprofiles: u64,
    pub frost: FleetReport,
    pub baseline: FleetReport,
}

/// The per-day aggregates of one fleet run.
struct DayCollect {
    day_energy_j: f64,
    slot_energy_j: Vec<f64>,
    slot_offered: Vec<u64>,
    slo: Vec<SloSummary>,
    reprofiles: u64,
    load_shifts: u64,
}

/// Per-QoS-class day roll-up shared by the traffic and scenario
/// harnesses (DESIGN.md §9/§11): merge every site's day histogram and
/// slot counters in site-index order (the §6 determinism contract) into
/// one [`SloSummary`] per [`QOS_CLASSES`] entry.  Latencies merge as
/// O(1) histograms (DESIGN.md §10) — no per-request vector is ever
/// concatenated or sorted, so the roll-up cost is independent of the
/// user count.
pub(crate) fn class_day_rollup(fleet: &Fleet, slo: &SloSpec) -> Vec<SloSummary> {
    let mut hists: Vec<LatencyHistogram> =
        (0..QOS_CLASSES.len()).map(|_| LatencyHistogram::new()).collect();
    let mut counts = [(0u64, 0u64, 0u64, 0u64); 3]; // offered/served/dropped/late
    for site in &fleet.sites {
        let t = site.traffic.as_ref().expect("traffic-driven fleet");
        let class = QOS_CLASSES.iter().position(|c| *c == site.qos).expect("known class");
        hists[class].merge(&t.hist);
        for s in &t.slot_log {
            counts[class].0 += s.offered;
            counts[class].1 += s.served;
            counts[class].2 += s.dropped;
            counts[class].3 += s.late;
        }
    }
    QOS_CLASSES
        .iter()
        .zip(hists.iter())
        .zip(counts.iter())
        .map(|((qos, hist), &(offered, served, dropped, late))| {
            SloSummary::from_histogram(
                *qos,
                slo.deadline_for(*qos),
                offered,
                served,
                dropped,
                late,
                hist,
            )
        })
        .collect()
}

fn collect_day(fleet: &Fleet, slots_per_day: u32, slo: &SloSpec) -> DayCollect {
    let n_slots = slots_per_day as usize;
    let mut slot_energy_j = vec![0.0; n_slots];
    let mut slot_offered = vec![0u64; n_slots];
    let mut day_energy_j = 0.0;
    let mut reprofiles = 0;
    let mut load_shifts = 0;
    // Site-index order everywhere: the aggregation itself is part of the
    // §6 determinism contract.
    for site in &fleet.sites {
        let t = site.traffic.as_ref().expect("traffic-driven fleet");
        for s in &t.slot_log {
            let k = (s.slot_in_day as usize).min(n_slots - 1);
            slot_energy_j[k] += s.energy_j;
            slot_offered[k] += s.offered;
        }
        day_energy_j += t.day_energy_j;
        reprofiles += t.reprofile_requests;
        load_shifts += t.load_shift_reprofiles();
    }
    let slo = class_day_rollup(fleet, slo);
    DayCollect { day_energy_j, slot_energy_j, slot_offered, slo, reprofiles, load_shifts }
}

fn saving(frost_j: f64, base_j: f64) -> f64 {
    if base_j > 0.0 {
        1.0 - frost_j / base_j
    } else {
        0.0
    }
}

/// Run the same seeded diurnal day twice — FROST on, then stock caps —
/// and compare energy and SLO attainment.  `config.traffic` must be set;
/// `frost_enabled` is overridden per run.
pub fn traffic_comparison(config: &FleetConfig) -> Result<TrafficFigOutput> {
    let tr = config
        .traffic
        .clone()
        .context("traffic_comparison needs FleetConfig::traffic set")?;
    let mut frost_cfg = config.clone();
    frost_cfg.frost_enabled = true;
    let mut base_cfg = config.clone();
    base_cfg.frost_enabled = false;
    base_cfg.budget_frac = 1.0;

    let mut frost_fleet = Fleet::new(frost_cfg)?;
    let frost_report = frost_fleet.run()?;
    let mut base_fleet = Fleet::new(base_cfg)?;
    let base_report = base_fleet.run()?;

    let f = collect_day(&frost_fleet, tr.slots_per_day, &tr.slo);
    let b = collect_day(&base_fleet, tr.slots_per_day, &tr.slo);

    let mut class_table = Series::new(
        format!("Traffic SLO: {} sites, seed {}", config.sites, config.seed),
        &[
            "deadline_ms",
            "frost_p50_ms",
            "frost_p95_ms",
            "frost_p99_ms",
            "base_p99_ms",
            "frost_attain_pct",
            "base_attain_pct",
            "frost_dropped",
            "frost_late",
        ],
    );
    for (fs, bs) in f.slo.iter().zip(&b.slo) {
        class_table.push(fs.qos.as_str(), vec![
            fs.deadline_s * 1e3,
            fs.p50_s * 1e3,
            fs.p95_s * 1e3,
            fs.p99_s * 1e3,
            bs.p99_s * 1e3,
            fs.attainment * 100.0,
            bs.attainment * 100.0,
            fs.dropped as f64,
            fs.late as f64,
        ]);
    }

    let slot_s = tr.slot_s();
    let mut slot_table = Series::new(
        format!("Traffic day: {} slots of {:.0} s", tr.slots_per_day, slot_s),
        &["offered_per_s", "base_kj", "frost_kj", "saving_pct"],
    );
    let mean_offered = f.slot_offered.iter().sum::<u64>() as f64
        / f.slot_offered.len().max(1) as f64;
    let (mut off_f, mut off_b, mut pk_f, mut pk_b) = (0.0, 0.0, 0.0, 0.0);
    for (k, (&fj, &bj)) in f.slot_energy_j.iter().zip(&b.slot_energy_j).enumerate() {
        let offered = f.slot_offered[k] as f64;
        slot_table.push(format!("slot {k:02}"), vec![
            offered / slot_s,
            bj / 1e3,
            fj / 1e3,
            saving(fj, bj) * 100.0,
        ]);
        if offered < mean_offered {
            off_f += fj;
            off_b += bj;
        } else {
            pk_f += fj;
            pk_b += bj;
        }
    }

    let reference_gpu = setup_no1().gpu;
    let mut site_table = Series::new(
        "Per-site traffic day",
        &[
            "infer_beta",
            "offered",
            "cap_pct",
            "base_day_kj",
            "frost_day_kj",
            "saving_pct",
            "p99_ms",
            "deadline_ms",
        ],
    );
    for (fsite, bsite) in frost_fleet.sites.iter().zip(&base_fleet.sites) {
        let ft = fsite.traffic.as_ref().expect("traffic-driven fleet");
        let bt = bsite.traffic.as_ref().expect("traffic-driven fleet");
        site_table.push(format!("{} {}", fsite.name, fsite.zoo_model), vec![
            // Serving is the memory-boundedness that decides how
            // cap-tolerant this site's traffic is.
            fsite.workload.infer_beta(&reference_gpu),
            ft.offered_today as f64,
            fsite.host.testbed.cap_frac() * 100.0,
            bt.day_energy_j / 1e3,
            ft.day_energy_j / 1e3,
            saving(ft.day_energy_j, bt.day_energy_j) * 100.0,
            // Histogram p99 — no clone-and-sort of the day's latency
            // vector (which the aggregated path does not even keep).
            ft.hist.percentile(0.99) * 1e3,
            ft.deadline_s * 1e3,
        ]);
    }

    Ok(TrafficFigOutput {
        class_table,
        slot_table,
        site_table,
        frost_day_energy_j: f.day_energy_j,
        base_day_energy_j: b.day_energy_j,
        day_saving_frac: saving(f.day_energy_j, b.day_energy_j),
        offpeak_saving_frac: saving(off_f, off_b),
        peak_saving_frac: saving(pk_f, pk_b),
        frost_slo: f.slo,
        base_slo: b.slo,
        reprofile_requests: f.reprofiles,
        load_shift_reprofiles: f.load_shifts,
        frost: frost_report,
        baseline: base_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::TrafficConfig;

    #[test]
    fn traffic_comparison_reports_classes_slots_and_saving() {
        let tr = TrafficConfig {
            users_per_site: 200,
            requests_per_user_per_day: 30.0,
            day_s: 600.0,
            slots_per_day: 6,
            warmup_rounds: 3,
            max_batch: 32,
            ..TrafficConfig::default()
        };
        let config = FleetConfig {
            sites: 3,
            seed: 9,
            rounds: tr.rounds_for_one_day(),
            train_epochs: 40,
            samples_per_epoch: 5_000,
            infer_steps_per_round: 10,
            max_concurrent_profiles: 3,
            traffic: Some(tr),
            ..FleetConfig::default()
        };
        let out = traffic_comparison(&config).unwrap();
        assert_eq!(out.class_table.len(), 3);
        assert_eq!(out.slot_table.len(), 6);
        assert_eq!(out.site_table.len(), 3);
        assert!(out.base_day_energy_j > 0.0);
        assert!(out.frost_day_energy_j > 0.0);
        assert!(
            out.frost_day_energy_j < out.base_day_energy_j,
            "FROST day {} must undercut baseline {}",
            out.frost_day_energy_j,
            out.base_day_energy_j
        );
        // Requests conserve per class: offered = served + dropped.
        for s in &out.frost_slo {
            assert_eq!(s.offered, s.served + s.dropped, "{:?}", s.qos);
        }
        // The baseline never profiles and never drops below stock caps.
        assert_eq!(out.baseline.fleet_profiling_energy_j, 0.0);
        for site in &out.baseline.sites {
            assert_eq!(site.cap_frac, 1.0);
        }
    }

    #[test]
    fn traffic_comparison_requires_traffic_config() {
        let config = FleetConfig { sites: 2, ..FleetConfig::default() };
        assert!(traffic_comparison(&config).is_err());
    }
}
