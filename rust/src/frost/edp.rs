//! The ED^mP decision criterion (paper Sec. III-C).
//!
//! `ED^m P = E · D^m`: energy times delay to the m-th power.  `m` weights
//! the delay term to match an application's QoS class: ED¹P favours energy
//! (largest savings), ED³P favours latency (optimum drifts to high caps),
//! ED²P is the paper's sweet spot (Fig. 5/6).

/// A configured criterion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdpCriterion {
    /// The delay exponent m ≥ 0.
    pub exponent: f64,
}

impl EdpCriterion {
    pub fn new(exponent: f64) -> Self {
        assert!(exponent >= 0.0, "ED^mP exponent must be non-negative");
        EdpCriterion { exponent }
    }

    /// Plain EDP (m = 1).
    pub fn edp() -> Self {
        Self::new(1.0)
    }

    /// The paper's sweet spot, ED²P.
    pub fn ed2p() -> Self {
        Self::new(2.0)
    }

    /// Latency-weighted ED³P.
    pub fn ed3p() -> Self {
        Self::new(3.0)
    }

    /// Pure energy (m = 0).
    pub fn energy_only() -> Self {
        Self::new(0.0)
    }

    /// Score a (energy, delay) pair; lower is better.
    pub fn score(&self, energy_j: f64, delay_s: f64) -> f64 {
        energy_j * delay_s.powf(self.exponent)
    }
}

impl std::fmt::Display for EdpCriterion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.exponent == 0.0 {
            write!(f, "E (energy only)")
        } else if (self.exponent - 1.0).abs() < 1e-12 {
            write!(f, "EDP")
        } else {
            write!(f, "ED{}P", self.exponent)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_definition() {
        let c = EdpCriterion::ed2p();
        assert_eq!(c.score(100.0, 2.0), 400.0);
        assert_eq!(EdpCriterion::edp().score(100.0, 2.0), 200.0);
        assert_eq!(EdpCriterion::energy_only().score(100.0, 2.0), 100.0);
    }

    #[test]
    fn higher_exponent_prefers_faster_configs() {
        // Config A: cheap but slow; config B: costly but fast.
        let a = (60.0, 12.0);
        let b = (100.0, 8.0);
        // Energy-only prefers A…
        assert!(EdpCriterion::energy_only().score(a.0, a.1)
            < EdpCriterion::energy_only().score(b.0, b.1));
        // …ED³P prefers B.
        assert!(EdpCriterion::ed3p().score(b.0, b.1) < EdpCriterion::ed3p().score(a.0, a.1));
    }

    #[test]
    fn display_names() {
        assert_eq!(EdpCriterion::edp().to_string(), "EDP");
        assert_eq!(EdpCriterion::ed2p().to_string(), "ED2P");
        assert_eq!(EdpCriterion::energy_only().to_string(), "E (energy only)");
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_exponent_rejected() {
        let _ = EdpCriterion::new(-1.0);
    }
}
