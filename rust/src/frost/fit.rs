//! The response-model fit (paper Eqs. 6–7).
//!
//! FROST models the profiled quantity (ED^mP per sample) as a function of
//! the power-cap fraction x:
//!
//! ```text
//! F(x) = a·e^(b·x − c) + d·σ(e·x − f) + g,     σ(z) = 1/(1 + e^(−z))
//! ```
//!
//! fitted by minimising mean-squared error over the profiled points
//! (Eq. 7).  The exponential arm captures the blow-up at aggressive caps,
//! the shifted logistic captures the saturation towards 100%, and `g`
//! floors the curve.  If the relative fit error drops below 5% the line is
//! considered a good fit (Sec. III-C); otherwise FROST falls back to the
//! best *measured* point.

use crate::metrics::stats::mean;

use super::simplex::{nelder_mead, NelderMeadOptions};

/// The seven coefficients of F.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResponseModel {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    pub e: f64,
    pub f: f64,
    pub g: f64,
}

impl ResponseModel {
    pub fn eval(&self, x: f64) -> f64 {
        let sig = 1.0 / (1.0 + (-(self.e * x - self.f)).exp());
        self.a * (self.b * x - self.c).exp() + self.d * sig + self.g
    }

}

/// Outcome of fitting F to the profiled points.
#[derive(Debug, Clone)]
pub struct FitResult {
    pub model: ResponseModel,
    /// Root-mean-square error relative to the mean observed value.
    pub rel_error: f64,
    /// `rel_error < threshold` (paper: 5%).
    pub good_fit: bool,
    /// The (x, y) points that were fitted, kept for fallback decisions.
    pub points: Vec<(f64, f64)>,
}

impl FitResult {
    /// Evaluate the fitted model (normalised y-scale is internal — this
    /// returns values on the original y scale).
    pub fn eval(&self, x: f64) -> f64 {
        self.model.eval(x)
    }

    /// Linear interpolation of the *measured* points at x.
    pub fn interp_measured(&self, x: f64) -> f64 {
        let mut prev = &self.points[0];
        if x <= prev.0 {
            return prev.1;
        }
        for p in &self.points[1..] {
            if x <= p.0 {
                let t = (x - prev.0) / (p.0 - prev.0);
                return prev.1 * (1.0 - t) + p.1 * t;
            }
            prev = p;
        }
        self.points.last().unwrap().1
    }

    /// Argmin of F over [lo, hi].
    ///
    /// The fitted curve (minimised with the downhill simplex) proposes a
    /// continuous optimum; the *measurements arbitrate*: the proposal
    /// competes against every profiled point on the measured (interpolated)
    /// scale and the best candidate wins.  This guards against the fit
    /// washing out a shallow interior dip — with eight 30 s measurements in
    /// hand there is no reason to let a ≤5%-error fit overrule them.  When
    /// the fit is poor (error above the paper's 5% gate), only the measured
    /// points compete.
    pub fn minimize(&self, lo: f64, hi: f64) -> (f64, f64) {
        let mut candidates: Vec<f64> = self
            .points
            .iter()
            .map(|(x, _)| *x)
            .filter(|x| (lo..=hi).contains(x))
            .collect();
        if self.good_fit {
            let (xf, _) = super::simplex::minimize_1d(|x| self.model.eval(x), lo, hi);
            candidates.push(xf);
        }
        candidates
            .into_iter()
            .map(|x| (x, self.interp_measured(x)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap_or((hi, f64::NAN))
    }
}

fn mse(model: &ResponseModel, pts: &[(f64, f64)]) -> f64 {
    pts.iter().map(|&(x, y)| (y - model.eval(x)).powi(2)).sum::<f64>() / pts.len() as f64
}

/// Inner variable-projection step: given the nonlinear shape parameters
/// (b, e, f), the model `F = A·e^(bx) + d·σ(ex−f) + g` is *linear* in
/// (A, d, g) — solve that 3×3 least-squares exactly (normal equations).
/// Returns the completed model (c folded to 0, a = A) and its MSE.
fn varpro_step(b: f64, e: f64, f: f64, pts: &[(f64, f64)]) -> (ResponseModel, f64) {
    // Basis vectors φ1 = e^(bx), φ2 = σ(ex−f), φ3 = 1.
    let mut g = [[0.0f64; 3]; 3]; // Gram matrix
    let mut rhs = [0.0f64; 3];
    for &(x, y) in pts {
        let p1 = (b * x).exp();
        let p2 = 1.0 / (1.0 + (-(e * x - f)).exp());
        let phi = [p1, p2, 1.0];
        for i in 0..3 {
            for j in 0..3 {
                g[i][j] += phi[i] * phi[j];
            }
            rhs[i] += phi[i] * y;
        }
    }
    // Tikhonov damping keeps near-collinear bases (e.g. b≈0 makes φ1≈φ3)
    // solvable without exploding coefficients.
    for (i, row) in g.iter_mut().enumerate() {
        row[i] += 1e-9;
    }
    let coef = solve3(&g, &rhs);
    let model = match coef {
        Some([a, d, gg]) => ResponseModel { a, b, c: 0.0, d, e, f, g: gg },
        None => ResponseModel { a: 0.0, b, c: 0.0, d: 0.0, e, f, g: 1.0 },
    };
    let err = mse(&model, pts);
    (model, err)
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(a: &[[f64; 3]; 3], b: &[f64; 3]) -> Option<[f64; 3]> {
    let mut m = [[0.0f64; 4]; 3];
    for i in 0..3 {
        m[i][..3].copy_from_slice(&a[i]);
        m[i][3] = b[i];
    }
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&i, &j| m[i][col].abs().total_cmp(&m[j][col].abs()))?;
        if m[piv][col].abs() < 1e-14 {
            return None;
        }
        m.swap(col, piv);
        for row in 0..3 {
            if row != col {
                let k = m[row][col] / m[col][col];
                for j in col..4 {
                    m[row][j] -= k * m[col][j];
                }
            }
        }
    }
    Some([m[0][3] / m[0][0], m[1][3] / m[1][1], m[2][3] / m[2][2]])
}

/// Fit F(x) to the profiled points (Eq. 7: minimise MSE over a..g).
///
/// Implementation: **variable projection** — `a·e^(bx−c)` is reparametrised
/// as `A·e^(bx)` with `A = a·e^(−c)` (the paper's (a, c) pair is redundant
/// up to this product), so (A, d, g) drop out as an exact inner linear
/// least-squares and Nelder–Mead only searches the 3 nonlinear shape
/// parameters (b, e, f).  ~40× faster than the naive 7-dimensional search
/// and finds equal-or-better optima (EXPERIMENTS.md §Perf).  y is
/// normalised to mean 1 during the fit so thresholds are scale-free.
pub fn fit_response(points: &[(f64, f64)], error_threshold: f64) -> FitResult {
    assert!(points.len() >= 4, "need at least 4 profile points to fit");
    let y_scale = mean(&points.iter().map(|p| p.1).collect::<Vec<_>>()).max(1e-30);
    let norm: Vec<(f64, f64)> = points.iter().map(|&(x, y)| (x, y / y_scale)).collect();

    // Multi-starts over the nonlinear shape (b, e, f): exponential decay or
    // growth on the left arm, logistic rise at a few positions/sharpnesses.
    let starts: &[[f64; 3]] = &[
        [-8.0, 6.0, 4.0],
        [-14.0, 6.0, 3.0],
        [-4.0, 10.0, 6.0],
        [3.0, -5.0, -3.0],
        [-20.0, 3.0, 1.5],
    ];
    let opts = NelderMeadOptions { max_evals: 400, ..Default::default() };
    let mut best: Option<(ResponseModel, f64)> = None;
    for s in starts {
        let r = nelder_mead(|p| varpro_step(p[0], p[1], p[2], &norm).1, s, &opts);
        let (m, err) = varpro_step(r.x[0], r.x[1], r.x[2], &norm);
        if best.as_ref().map_or(true, |(_, e)| err < *e) {
            best = Some((m, err));
        }
    }
    let (m_norm, err) = best.unwrap();
    // Relative RMSE on the normalised scale (mean y = 1).
    let rel_error = err.sqrt();

    // Rescale: F_orig(x) = y_scale * F_norm(x). a, d, g scale linearly.
    let model = ResponseModel {
        a: m_norm.a * y_scale,
        b: m_norm.b,
        c: m_norm.c,
        d: m_norm.d * y_scale,
        e: m_norm.e,
        f: m_norm.f,
        g: m_norm.g * y_scale,
    };
    FitResult {
        model,
        rel_error,
        good_fit: rel_error < error_threshold,
        points: points.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A ground-truth response shaped like the paper's Fig. 4 curves:
    /// sharp rise below ~40% cap, shallow minimum near 60%, mild rise to 100%.
    fn synthetic_curve(x: f64) -> f64 {
        3.0 * (-14.0 * (x - 0.3)).exp() + 1.0 / (1.0 + (-6.0 * (x - 0.55)).exp()) + 2.0
    }

    fn profile_points() -> Vec<(f64, f64)> {
        (3..=10).map(|i| {
            let x = i as f64 / 10.0;
            (x, synthetic_curve(x))
        }).collect()
    }

    #[test]
    fn fits_paper_shaped_curve_under_5pct() {
        let fit = fit_response(&profile_points(), 0.05);
        assert!(fit.good_fit, "rel_error = {}", fit.rel_error);
        for &(x, y) in &fit.points {
            let rel = ((fit.eval(x) - y) / y).abs();
            assert!(rel < 0.15, "point ({x}, {y}) off by {rel}");
        }
    }

    #[test]
    fn minimum_located_near_truth() {
        let fit = fit_response(&profile_points(), 0.05);
        let (x_min, _) = fit.minimize(0.3, 1.0);
        // True argmin of the synthetic curve on [0.3, 1]:
        let mut best = (0.3, f64::INFINITY);
        let mut x = 0.3;
        while x <= 1.0 {
            let y = synthetic_curve(x);
            if y < best.1 {
                best = (x, y);
            }
            x += 0.001;
        }
        assert!(
            (x_min - best.0).abs() < 0.08,
            "fit argmin {x_min} vs truth {}",
            best.0
        );
    }

    #[test]
    fn poor_fit_falls_back_to_measured_argmin() {
        // White-noise points can't be fitted under 5% — fallback must pick
        // the literal best measurement.
        let pts: Vec<(f64, f64)> = vec![
            (0.3, 5.0),
            (0.4, 1.0),
            (0.5, 9.0),
            (0.6, 2.0),
            (0.7, 8.0),
            (0.8, 0.5),
            (0.9, 7.0),
            (1.0, 6.0),
        ];
        let fit = fit_response(&pts, 0.005); // unattainable threshold
        assert!(!fit.good_fit);
        let (x_min, y_min) = fit.minimize(0.3, 1.0);
        assert_eq!((x_min, y_min), (0.8, 0.5));
    }

    #[test]
    fn monotone_decreasing_curve_optimises_to_full_power() {
        // LeNet-like: capping does nothing, EDP falls with cap -> pick 100%.
        let pts: Vec<(f64, f64)> =
            (3..=10).map(|i| (i as f64 / 10.0, 10.0 - i as f64)).collect();
        let fit = fit_response(&pts, 0.08);
        let (x_min, _) = fit.minimize(0.3, 1.0);
        assert!(x_min > 0.9, "expected ~1.0, got {x_min}");
    }

    #[test]
    fn scale_invariance() {
        // Same shape at 1000x the magnitude must fit equally well.
        let pts: Vec<(f64, f64)> =
            profile_points().into_iter().map(|(x, y)| (x, y * 1000.0)).collect();
        let fit = fit_response(&pts, 0.05);
        assert!(fit.good_fit, "rel_error = {}", fit.rel_error);
        let (x_min, _) = fit.minimize(0.3, 1.0);
        let fit_small = fit_response(&profile_points(), 0.05);
        let (x_min_small, _) = fit_small.minimize(0.3, 1.0);
        assert!((x_min - x_min_small).abs() < 0.05);
    }

    #[test]
    #[should_panic(expected = "at least 4")]
    fn too_few_points_rejected() {
        let _ = fit_response(&[(0.3, 1.0), (0.5, 2.0)], 0.05);
    }

    /// A NaN measurement (a poisoned profile sample) must flow through the
    /// whole fit → minimize path without panicking — the old
    /// `partial_cmp().unwrap()` sorts aborted here — and the argmin must
    /// still land on a real (finite) measured point.
    #[test]
    fn nan_sample_does_not_panic_and_fallback_stays_finite() {
        let mut pts = profile_points();
        pts[2].1 = f64::NAN;
        let fit = fit_response(&pts, 0.05);
        let (x_min, y_min) = fit.minimize(0.3, 1.0);
        assert!(x_min.is_finite(), "argmin x must be finite, got {x_min}");
        assert!((0.3..=1.0).contains(&x_min), "argmin {x_min} outside [0.3, 1]");
        assert!(
            y_min.is_finite(),
            "total_cmp orders NaN above every finite value, so the \
             minimum must be a real measurement, got {y_min}"
        );
        assert!((x_min - pts[2].0).abs() > 1e-12, "argmin must not be the NaN point");
    }
}
