//! FROST — the paper's contribution (Sec. III).
//!
//! * [`profiler`] — tests eight power limits (30%–100% of TDP) for 30 s
//!   each and picks the best configuration for the model at hand;
//! * [`fit`] — the response model `F(x) = a·e^(bx−c) + d·σ(ex−f) + g`
//!   fitted by least squares (Eqs. 6–7);
//! * [`simplex`] — the downhill-simplex (Nelder–Mead) minimiser used both
//!   for the fit and for locating the optimum of F;
//! * [`edp`] — the `ED^m P` decision criterion (energy × delay^m);
//! * [`policy`] — A1-style energy policies mapping QoS classes to `m` and
//!   cap bounds (managed by the SMO, Sec. III-C).

pub mod edp;
pub mod fit;
pub mod online;
pub mod policy;
pub mod profiler;
pub mod simplex;

pub use edp::EdpCriterion;
pub use online::{ContinuousMonitor, MonitorAction, MonitorConfig, Observation};
pub use fit::{FitResult, ResponseModel};
pub use policy::{EnergyPolicy, QosClass};
pub use profiler::{PowerProfiler, ProfileOutcome, ProfilePoint};
pub use simplex::{nelder_mead, NelderMeadOptions};
