//! Continuous operation: online monitoring + re-profiling triggers.
//!
//! Step vi of the O-RAN AI/ML workflow (paper Sec. II): deployed models
//! "are continuously monitored and, if required, are fine-tuned online".
//! A power cap chosen for yesterday's workload can be wrong after a model
//! update, a batch-size change or a dataset shift — this monitor watches
//! the KPM stream for drift in the power/throughput signature and asks
//! FROST to re-profile when it moves, with hysteresis and a cooldown so
//! profiling energy (Eqs. 4–5) isn't burned on noise.

use crate::util::Seconds;

/// One observation from the KPM stream.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub at: Seconds,
    pub gpu_power_w: f64,
    pub samples_per_s: f64,
    /// Offered request load (requests/s) behind this window.  Zero is
    /// data — a traffic-driven host reporting "no demand this window"
    /// moves the tracker just like any other value — while a host that is
    /// not traffic-driven reports a constant 0.0 and never develops a
    /// positive load baseline, so the demand trigger stays inert for it.
    /// A demand shift is a second re-profile trigger: the energy-optimal
    /// cap for a loaded server is not the optimal cap for a mostly-idle
    /// one (DESIGN.md §9).
    pub offered_load_per_s: f64,
}

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// EWMA smoothing factor per observation.
    pub alpha: f64,
    /// Relative drift in the power/throughput signature that triggers a
    /// re-profile.
    pub drift_threshold: f64,
    /// Minimum observations before the baseline is considered settled.
    pub warmup: usize,
    /// Minimum virtual time between re-profiles (profiling costs energy).
    pub cooldown: Seconds,
    /// Relative shift of the offered load (vs the settled baseline) that
    /// triggers a re-profile.  Only consulted when observations carry a
    /// positive `offered_load_per_s`.
    pub load_shift_threshold: f64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            alpha: 0.1,
            drift_threshold: 0.15,
            warmup: 20,
            cooldown: Seconds(600.0),
            load_shift_threshold: 0.5,
        }
    }
}

/// What the monitor wants done after an observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MonitorAction {
    /// Keep operating.
    None,
    /// Workload signature drifted: re-run the FROST profiler.
    Reprofile,
}

/// EWMA drift monitor over the energy-per-sample signature.
#[derive(Debug, Clone)]
pub struct ContinuousMonitor {
    config: MonitorConfig,
    /// Settled baseline J/sample (None until warm).
    baseline: Option<f64>,
    ewma: Option<f64>,
    /// Settled baseline offered load and its EWMA (None until the stream
    /// carries a positive load).
    load_baseline: Option<f64>,
    load_ewma: Option<f64>,
    seen: usize,
    last_reprofile: Option<Seconds>,
    /// Timestamp of the last *accepted* observation (None until the first).
    last_at: Option<Seconds>,
    /// Count of re-profiles triggered (for reporting).
    pub reprofiles: u64,
    /// How many of those carried an offered-load shift past the threshold.
    pub load_shifts: u64,
    /// Observations rejected before they touched any tracker: out-of-order
    /// or duplicate timestamps (a faulty fabric replaying/reordering O1
    /// telemetry, §13) and non-finite timestamps.
    pub rejected: u64,
}

impl ContinuousMonitor {
    pub fn new(config: MonitorConfig) -> Self {
        ContinuousMonitor {
            config,
            baseline: None,
            ewma: None,
            load_baseline: None,
            load_ewma: None,
            seen: 0,
            last_reprofile: None,
            last_at: None,
            reprofiles: 0,
            load_shifts: 0,
            rejected: 0,
        }
    }

    /// Mutable tracker state for checkpointing (DESIGN.md §15), in field
    /// order: baseline, ewma, load_baseline, load_ewma, seen,
    /// last_reprofile, last_at.  The counters are public and carried
    /// separately by the caller.
    #[allow(clippy::type_complexity)]
    pub fn ckpt_state(
        &self,
    ) -> (Option<f64>, Option<f64>, Option<f64>, Option<f64>, usize, Option<f64>, Option<f64>)
    {
        (
            self.baseline,
            self.ewma,
            self.load_baseline,
            self.load_ewma,
            self.seen,
            self.last_reprofile.map(|s| s.0),
            self.last_at.map(|s| s.0),
        )
    }

    /// Overwrite the tracker state from a checkpoint (the counterpart of
    /// [`ContinuousMonitor::ckpt_state`]; the config is rebuilt by the
    /// caller).
    #[allow(clippy::type_complexity)]
    pub fn restore_ckpt_state(
        &mut self,
        (baseline, ewma, load_baseline, load_ewma, seen, last_reprofile, last_at): (
            Option<f64>,
            Option<f64>,
            Option<f64>,
            Option<f64>,
            usize,
            Option<f64>,
            Option<f64>,
        ),
    ) {
        self.baseline = baseline;
        self.ewma = ewma;
        self.load_baseline = load_baseline;
        self.load_ewma = load_ewma;
        self.seen = seen;
        self.last_reprofile = last_reprofile.map(Seconds);
        self.last_at = last_at.map(Seconds);
    }

    /// Energy-per-sample signature of one observation.
    fn signature(obs: &Observation) -> f64 {
        if obs.samples_per_s <= 0.0 {
            return f64::INFINITY;
        }
        obs.gpu_power_w / obs.samples_per_s
    }

    /// EWMA-track the offered load.  Zero counts (a demand collapse must
    /// move the tracker); negative/NaN input is discarded as malformed.
    fn track_load(&mut self, load: f64) {
        if !load.is_finite() || load < 0.0 {
            return;
        }
        let a = self.config.alpha;
        self.load_ewma = Some(match self.load_ewma {
            Some(prev) => prev * (1.0 - a) + load * a,
            None => load,
        });
    }

    /// Feed one observation; returns the requested action.
    ///
    /// Observations must arrive in strictly increasing timestamp order: a
    /// duplicate or out-of-order `at` (a fabric replaying or reordering
    /// telemetry) is rejected wholesale — it moves neither the signature
    /// EWMA nor the load tracker — and counted in [`Self::rejected`].
    pub fn observe(&mut self, obs: Observation) -> MonitorAction {
        if !obs.at.0.is_finite() || self.last_at.is_some_and(|t| obs.at.0 <= t.0) {
            self.rejected += 1;
            return MonitorAction::None;
        }
        self.last_at = Some(obs.at);
        self.track_load(obs.offered_load_per_s);
        let sig = Self::signature(&obs);
        if !sig.is_finite() {
            // An idle window has no service signature, but the load
            // tracker above still saw the (possibly zero) demand.
            return MonitorAction::None;
        }
        let a = self.config.alpha;
        self.ewma = Some(match self.ewma {
            Some(prev) => prev * (1.0 - a) + sig * a,
            None => sig,
        });
        self.seen += 1;
        if self.seen < self.config.warmup {
            return MonitorAction::None;
        }
        let ewma = self.ewma.unwrap();
        match self.baseline {
            None => {
                self.baseline = Some(ewma);
                self.load_baseline = self.load_ewma;
                MonitorAction::None
            }
            Some(base) => {
                // A load stream that only started after the baseline
                // settled still gets a baseline to drift against.
                if self.load_baseline.is_none() {
                    self.load_baseline = self.load_ewma;
                }
                let drift = (ewma - base).abs() / base.max(1e-12);
                let load_shift = match (self.load_baseline, self.load_ewma) {
                    (Some(lb), Some(le)) if lb > 0.0 => (le - lb).abs() / lb,
                    // Demand appearing out of nowhere is an infinite
                    // relative shift; a flat-zero stream (e.g. a host
                    // that is not traffic-driven) never shifts.
                    (Some(lb), Some(le)) if le > 0.0 && lb <= 0.0 => f64::INFINITY,
                    _ => 0.0,
                };
                let cooled = self
                    .last_reprofile
                    .map_or(true, |t| obs.at.0 - t.0 >= self.config.cooldown.0);
                let drifted = drift > self.config.drift_threshold;
                let shifted = load_shift > self.config.load_shift_threshold;
                if (drifted || shifted) && cooled {
                    // Re-baseline on the new regime and request profiling.
                    self.baseline = Some(ewma);
                    if shifted {
                        self.load_shifts += 1;
                        // Snap the load tracker to the observed regime so
                        // one sustained shift fires once, instead of
                        // re-triggering every cooldown while the EWMA is
                        // still converging toward the new level.
                        if obs.offered_load_per_s.is_finite() && obs.offered_load_per_s >= 0.0
                        {
                            self.load_ewma = Some(obs.offered_load_per_s);
                        }
                    }
                    self.load_baseline = self.load_ewma;
                    self.last_reprofile = Some(obs.at);
                    self.reprofiles += 1;
                    MonitorAction::Reprofile
                } else {
                    MonitorAction::None
                }
            }
        }
    }

    pub fn baseline(&self) -> Option<f64> {
        self.baseline
    }

    /// The monitor's counter triple `(reprofiles, load_shifts, rejected)`
    /// — read whole by the fleet metrics registry (§14) so the fields
    /// cannot be picked up piecemeal and drift apart.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.reprofiles, self.load_shifts, self.rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(at: f64, power: f64, tput: f64) -> Observation {
        Observation {
            at: Seconds(at),
            gpu_power_w: power,
            samples_per_s: tput,
            offered_load_per_s: 0.0,
        }
    }

    fn obs_loaded(at: f64, power: f64, tput: f64, load: f64) -> Observation {
        Observation {
            at: Seconds(at),
            gpu_power_w: power,
            samples_per_s: tput,
            offered_load_per_s: load,
        }
    }

    fn feed_steady(m: &mut ContinuousMonitor, from: f64, n: usize, power: f64, tput: f64) -> u64 {
        let mut triggers = 0;
        for i in 0..n {
            if m.observe(obs(from + i as f64, power, tput)) == MonitorAction::Reprofile {
                triggers += 1;
            }
        }
        triggers
    }

    #[test]
    fn steady_workload_never_triggers() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        let t = feed_steady(&mut m, 0.0, 500, 280.0, 4000.0);
        assert_eq!(t, 0);
        assert!(m.baseline().is_some());
    }

    #[test]
    fn noise_within_threshold_ignored() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 50, 280.0, 4000.0);
        // ±5% power ripple.
        let mut triggers = 0;
        for i in 0..200 {
            let p = 280.0 * (1.0 + 0.05 * ((i % 7) as f64 - 3.0) / 3.0);
            if m.observe(obs(100.0 + i as f64, p, 4000.0)) == MonitorAction::Reprofile {
                triggers += 1;
            }
        }
        assert_eq!(triggers, 0);
    }

    #[test]
    fn regime_change_triggers_once() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        // Model update halves throughput at the same power: signature 2x.
        let t = feed_steady(&mut m, 100.0, 300, 280.0, 2000.0);
        assert_eq!(t, 1, "exactly one re-profile for one regime change");
        assert_eq!(m.reprofiles, 1);
    }

    #[test]
    fn cooldown_suppresses_thrash() {
        let cfg = MonitorConfig { cooldown: Seconds(1000.0), ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        // Oscillating regimes faster than the cooldown.
        let mut triggers = 0;
        for k in 0..6 {
            let tput = if k % 2 == 0 { 2000.0 } else { 4000.0 };
            triggers += feed_steady(&mut m, 100.0 + k as f64 * 100.0, 100, 280.0, tput);
        }
        assert!(triggers <= 1, "cooldown must limit re-profiles, got {triggers}");
    }

    #[test]
    fn zero_throughput_is_ignored() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        assert_eq!(m.observe(obs(200.0, 280.0, 0.0)), MonitorAction::None);
    }

    #[test]
    fn load_shift_triggers_reprofile_without_signature_drift() {
        // Constant power/throughput signature — only the offered load
        // moves (a diurnal morning ramp).  The demand tracker alone must
        // request exactly one re-profile for one sustained shift.
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        let mut triggers = 0;
        for i in 0..100 {
            if m.observe(obs_loaded(i as f64, 280.0, 4000.0, 10.0)) == MonitorAction::Reprofile
            {
                triggers += 1;
            }
        }
        assert_eq!(triggers, 0, "steady load must not trigger");
        for i in 0..200 {
            if m.observe(obs_loaded(100.0 + i as f64, 280.0, 4000.0, 40.0))
                == MonitorAction::Reprofile
            {
                triggers += 1;
            }
        }
        assert_eq!(triggers, 1, "one sustained load shift, one re-profile");
        assert_eq!(m.load_shifts, 1);
        assert_eq!(m.reprofiles, 1);
    }

    #[test]
    fn flat_zero_load_stream_never_shifts() {
        // A host that is not traffic-driven reports a constant 0.0: the
        // tracker sees it, but a zero baseline with zero demand can never
        // shift — only the signature can trigger, as before the field
        // existed.
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        let t = feed_steady(&mut m, 0.0, 500, 280.0, 4000.0);
        assert_eq!(t, 0);
        assert_eq!(m.load_shifts, 0);
    }

    #[test]
    fn demand_collapse_and_reappearance_both_shift() {
        // High → zero: the EWMA must decay and fire one re-profile; zero
        // baseline → positive demand is an infinite relative shift and
        // fires again after the cooldown.
        let cfg = MonitorConfig { cooldown: Seconds(50.0), ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        for i in 0..100 {
            m.observe(obs_loaded(i as f64, 280.0, 4000.0, 30.0));
        }
        let mut collapse_triggers = 0;
        for i in 0..100 {
            if m.observe(obs_loaded(100.0 + i as f64, 280.0, 4000.0, 0.0))
                == MonitorAction::Reprofile
            {
                collapse_triggers += 1;
            }
        }
        assert!(collapse_triggers >= 1, "demand collapse must re-profile");
        let mut rebound_triggers = 0;
        for i in 0..100 {
            if m.observe(obs_loaded(200.0 + i as f64, 280.0, 4000.0, 30.0))
                == MonitorAction::Reprofile
            {
                rebound_triggers += 1;
            }
        }
        assert!(rebound_triggers >= 1, "demand reappearing must re-profile");
        assert_eq!(m.load_shifts, m.reprofiles, "every trigger here was load-driven");
    }

    #[test]
    fn backwards_timestamps_do_not_bypass_cooldown() {
        // A KPM stream with a replayed/out-of-order timestamp must not be
        // able to sneak past the cooldown.  The ordering gate rejects such
        // observations outright before any tracker moves.
        let cfg = MonitorConfig { cooldown: Seconds(100.0), warmup: 1, ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        assert_eq!(m.observe(obs(0.0, 280.0, 4000.0)), MonitorAction::None); // baseline
        assert_eq!(m.observe(obs(1.0, 2800.0, 4000.0)), MonitorAction::Reprofile);
        // Massive drift, but stamped *before* the re-profile: rejected.
        assert_eq!(m.observe(obs(-50.0, 28_000.0, 4000.0)), MonitorAction::None);
        assert_eq!(m.observe(obs(0.5, 28_000.0, 4000.0)), MonitorAction::None);
        assert_eq!(m.reprofiles, 1);
        assert_eq!(m.rejected, 2);
    }

    #[test]
    fn out_of_order_observations_are_rejected_and_counted() {
        // A reordering fabric delivers a stale window after newer ones.
        // The stale observation must not move the signature EWMA: feed a
        // wildly drifted stale sample and confirm no re-profile ever fires
        // and the baseline stays where the in-order stream put it.
        let cfg = MonitorConfig { warmup: 1, ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        feed_steady(&mut m, 0.0, 50, 280.0, 4000.0);
        let base = m.baseline().unwrap();
        // at=10.0 is long past: huge signature, but it must be discarded.
        assert_eq!(m.observe(obs(10.0, 28_000.0, 4000.0)), MonitorAction::None);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.baseline().unwrap().to_bits(), base.to_bits());
        assert_eq!(m.reprofiles, 0);
    }

    #[test]
    fn duplicate_observations_are_rejected_and_counted() {
        // A duplicating fabric delivers the same window twice.  The copy
        // (same timestamp) must be dropped: the load tracker would
        // otherwise double-weight that window's demand.
        let cfg = MonitorConfig { warmup: 1, ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        for i in 0..50 {
            m.observe(obs_loaded(i as f64, 280.0, 4000.0, 10.0));
        }
        let before = m.rejected;
        assert_eq!(m.observe(obs_loaded(49.0, 280.0, 4000.0, 10.0)), MonitorAction::None);
        assert_eq!(m.rejected, before + 1);
        // Non-finite timestamps are malformed, not merely late: rejected.
        assert_eq!(m.observe(obs(f64::NAN, 280.0, 4000.0)), MonitorAction::None);
        assert_eq!(m.rejected, before + 2);
        assert_eq!(m.reprofiles, 0);
    }

    #[test]
    fn drift_exactly_at_cooldown_boundary_fires() {
        // The cooldown is inclusive: elapsed == cooldown may re-profile,
        // one tick less may not.
        let cfg = MonitorConfig { cooldown: Seconds(100.0), warmup: 1, ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        assert_eq!(m.observe(obs(0.0, 280.0, 4000.0)), MonitorAction::None); // baseline
        assert_eq!(m.observe(obs(1.0, 2800.0, 4000.0)), MonitorAction::Reprofile);
        // Still drifting hard, but 0.5 s inside the cooldown window.
        assert_eq!(m.observe(obs(100.5, 28_000.0, 4000.0)), MonitorAction::None);
        // Exactly at the boundary (1.0 + 100.0): fires.
        assert_eq!(m.observe(obs(101.0, 28_000.0, 4000.0)), MonitorAction::Reprofile);
        assert_eq!(m.reprofiles, 2);
    }
}
