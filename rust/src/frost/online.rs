//! Continuous operation: online monitoring + re-profiling triggers.
//!
//! Step vi of the O-RAN AI/ML workflow (paper Sec. II): deployed models
//! "are continuously monitored and, if required, are fine-tuned online".
//! A power cap chosen for yesterday's workload can be wrong after a model
//! update, a batch-size change or a dataset shift — this monitor watches
//! the KPM stream for drift in the power/throughput signature and asks
//! FROST to re-profile when it moves, with hysteresis and a cooldown so
//! profiling energy (Eqs. 4–5) isn't burned on noise.

use crate::util::Seconds;

/// One observation from the KPM stream.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub at: Seconds,
    pub gpu_power_w: f64,
    pub samples_per_s: f64,
}

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// EWMA smoothing factor per observation.
    pub alpha: f64,
    /// Relative drift in the power/throughput signature that triggers a
    /// re-profile.
    pub drift_threshold: f64,
    /// Minimum observations before the baseline is considered settled.
    pub warmup: usize,
    /// Minimum virtual time between re-profiles (profiling costs energy).
    pub cooldown: Seconds,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            alpha: 0.1,
            drift_threshold: 0.15,
            warmup: 20,
            cooldown: Seconds(600.0),
        }
    }
}

/// What the monitor wants done after an observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MonitorAction {
    /// Keep operating.
    None,
    /// Workload signature drifted: re-run the FROST profiler.
    Reprofile,
}

/// EWMA drift monitor over the energy-per-sample signature.
#[derive(Debug, Clone)]
pub struct ContinuousMonitor {
    config: MonitorConfig,
    /// Settled baseline J/sample (None until warm).
    baseline: Option<f64>,
    ewma: Option<f64>,
    seen: usize,
    last_reprofile: Option<Seconds>,
    /// Count of re-profiles triggered (for reporting).
    pub reprofiles: u64,
}

impl ContinuousMonitor {
    pub fn new(config: MonitorConfig) -> Self {
        ContinuousMonitor {
            config,
            baseline: None,
            ewma: None,
            seen: 0,
            last_reprofile: None,
            reprofiles: 0,
        }
    }

    /// Energy-per-sample signature of one observation.
    fn signature(obs: &Observation) -> f64 {
        if obs.samples_per_s <= 0.0 {
            return f64::INFINITY;
        }
        obs.gpu_power_w / obs.samples_per_s
    }

    /// Feed one observation; returns the requested action.
    pub fn observe(&mut self, obs: Observation) -> MonitorAction {
        let sig = Self::signature(&obs);
        if !sig.is_finite() {
            return MonitorAction::None;
        }
        let a = self.config.alpha;
        self.ewma = Some(match self.ewma {
            Some(prev) => prev * (1.0 - a) + sig * a,
            None => sig,
        });
        self.seen += 1;
        if self.seen < self.config.warmup {
            return MonitorAction::None;
        }
        let ewma = self.ewma.unwrap();
        match self.baseline {
            None => {
                self.baseline = Some(ewma);
                MonitorAction::None
            }
            Some(base) => {
                let drift = (ewma - base).abs() / base.max(1e-12);
                let cooled = self
                    .last_reprofile
                    .map_or(true, |t| obs.at.0 - t.0 >= self.config.cooldown.0);
                if drift > self.config.drift_threshold && cooled {
                    // Re-baseline on the new regime and request profiling.
                    self.baseline = Some(ewma);
                    self.last_reprofile = Some(obs.at);
                    self.reprofiles += 1;
                    MonitorAction::Reprofile
                } else {
                    MonitorAction::None
                }
            }
        }
    }

    pub fn baseline(&self) -> Option<f64> {
        self.baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(at: f64, power: f64, tput: f64) -> Observation {
        Observation { at: Seconds(at), gpu_power_w: power, samples_per_s: tput }
    }

    fn feed_steady(m: &mut ContinuousMonitor, from: f64, n: usize, power: f64, tput: f64) -> u64 {
        let mut triggers = 0;
        for i in 0..n {
            if m.observe(obs(from + i as f64, power, tput)) == MonitorAction::Reprofile {
                triggers += 1;
            }
        }
        triggers
    }

    #[test]
    fn steady_workload_never_triggers() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        let t = feed_steady(&mut m, 0.0, 500, 280.0, 4000.0);
        assert_eq!(t, 0);
        assert!(m.baseline().is_some());
    }

    #[test]
    fn noise_within_threshold_ignored() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 50, 280.0, 4000.0);
        // ±5% power ripple.
        let mut triggers = 0;
        for i in 0..200 {
            let p = 280.0 * (1.0 + 0.05 * ((i % 7) as f64 - 3.0) / 3.0);
            if m.observe(obs(100.0 + i as f64, p, 4000.0)) == MonitorAction::Reprofile {
                triggers += 1;
            }
        }
        assert_eq!(triggers, 0);
    }

    #[test]
    fn regime_change_triggers_once() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        // Model update halves throughput at the same power: signature 2x.
        let t = feed_steady(&mut m, 100.0, 300, 280.0, 2000.0);
        assert_eq!(t, 1, "exactly one re-profile for one regime change");
        assert_eq!(m.reprofiles, 1);
    }

    #[test]
    fn cooldown_suppresses_thrash() {
        let cfg = MonitorConfig { cooldown: Seconds(1000.0), ..Default::default() };
        let mut m = ContinuousMonitor::new(cfg);
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        // Oscillating regimes faster than the cooldown.
        let mut triggers = 0;
        for k in 0..6 {
            let tput = if k % 2 == 0 { 2000.0 } else { 4000.0 };
            triggers += feed_steady(&mut m, 100.0 + k as f64 * 100.0, 100, 280.0, tput);
        }
        assert!(triggers <= 1, "cooldown must limit re-profiles, got {triggers}");
    }

    #[test]
    fn zero_throughput_is_ignored() {
        let mut m = ContinuousMonitor::new(MonitorConfig::default());
        feed_steady(&mut m, 0.0, 100, 280.0, 4000.0);
        assert_eq!(m.observe(obs(200.0, 280.0, 0.0)), MonitorAction::None);
    }
}
