//! A1-style energy policies (paper Sec. III-C).
//!
//! "These decisions can align with pre-defined QoS characteristics and be
//! shaped as policies managed by the A1 Policy Management Service" — a
//! policy maps an application's QoS class to the ED^mP exponent and bounds
//! on the cap range FROST may choose from.  Policies travel over the O-RAN
//! A1 interface as JSON ([`crate::oran::a1`]).

use crate::util::Json;
use anyhow::{Context, Result};

use super::edp::EdpCriterion;

/// QoS class of the ML application the policy covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Background / batch training: maximise energy savings (EDP).
    EnergySaver,
    /// Default: the paper's ED²P sweet spot.
    Balanced,
    /// Near-RT inference: latency dominates (ED³P).
    LatencyCritical,
}

impl QosClass {
    pub fn criterion(self) -> EdpCriterion {
        match self {
            QosClass::EnergySaver => EdpCriterion::edp(),
            QosClass::Balanced => EdpCriterion::ed2p(),
            QosClass::LatencyCritical => EdpCriterion::ed3p(),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            QosClass::EnergySaver => "energy_saver",
            QosClass::Balanced => "balanced",
            QosClass::LatencyCritical => "latency_critical",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "energy_saver" => Ok(QosClass::EnergySaver),
            "balanced" => Ok(QosClass::Balanced),
            "latency_critical" => Ok(QosClass::LatencyCritical),
            other => anyhow::bail!("unknown QoS class '{other}'"),
        }
    }
}

/// An energy policy as distributed by the SMO via A1.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyPolicy {
    /// Policy instance id (A1 policy-instance identifier).
    pub id: String,
    pub qos: QosClass,
    /// FROST may only choose caps within these bounds.
    pub min_cap_frac: f64,
    pub max_cap_frac: f64,
    /// Master switch: false = leave hardware at defaults.
    pub enabled: bool,
    /// Maximum tolerated slowdown vs uncapped (1.10 = +10% time), enforced
    /// as a constraint on the chosen configuration.
    pub max_slowdown: f64,
    /// TTL in fleet rounds: a host that has not seen this policy renewed
    /// within `lease_rounds` rounds falls back to its conservative safe
    /// cap instead of running an indefinitely stale ceiling (§13).
    /// 0 = no lease (the policy never expires — the historical default).
    pub lease_rounds: u32,
}

impl EnergyPolicy {
    /// The paper's default evaluation policy: ED²P over the full 30–100%
    /// driver range with a liberal slowdown budget.
    pub fn default_policy() -> Self {
        EnergyPolicy {
            id: "frost-default".into(),
            qos: QosClass::Balanced,
            min_cap_frac: 0.3,
            max_cap_frac: 1.0,
            enabled: true,
            max_slowdown: 1.25,
            lease_rounds: 0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.min_cap_frac)
                && (0.0..=1.0).contains(&self.max_cap_frac)
                && self.min_cap_frac <= self.max_cap_frac,
            "cap bounds [{}, {}] invalid",
            self.min_cap_frac,
            self.max_cap_frac
        );
        anyhow::ensure!(self.max_slowdown >= 1.0, "max_slowdown must be >= 1.0");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("qos", Json::str(self.qos.as_str())),
            ("min_cap_frac", Json::Num(self.min_cap_frac)),
            ("max_cap_frac", Json::Num(self.max_cap_frac)),
            ("enabled", Json::Bool(self.enabled)),
            ("max_slowdown", Json::Num(self.max_slowdown)),
            ("lease_rounds", Json::Num(self.lease_rounds as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let policy = EnergyPolicy {
            id: j.req("id")?.as_str().context("id")?.to_string(),
            qos: QosClass::parse(j.req("qos")?.as_str().context("qos")?)?,
            min_cap_frac: j.req("min_cap_frac")?.as_f64().context("min_cap_frac")?,
            max_cap_frac: j.req("max_cap_frac")?.as_f64().context("max_cap_frac")?,
            enabled: j.req("enabled")?.as_bool().context("enabled")?,
            max_slowdown: j.req("max_slowdown")?.as_f64().context("max_slowdown")?,
            // Optional for pre-lease JSON: absent means "never expires".
            lease_rounds: match j.req("lease_rounds") {
                Ok(v) => v.as_f64().context("lease_rounds")?.clamp(0.0, u32::MAX as f64) as u32,
                Err(_) => 0,
            },
        };
        policy.validate()?;
        Ok(policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qos_maps_to_paper_exponents() {
        assert_eq!(QosClass::EnergySaver.criterion().exponent, 1.0);
        assert_eq!(QosClass::Balanced.criterion().exponent, 2.0);
        assert_eq!(QosClass::LatencyCritical.criterion().exponent, 3.0);
    }

    #[test]
    fn qos_roundtrip() {
        for q in [QosClass::EnergySaver, QosClass::Balanced, QosClass::LatencyCritical] {
            assert_eq!(QosClass::parse(q.as_str()).unwrap(), q);
        }
        assert!(QosClass::parse("turbo").is_err());
    }

    #[test]
    fn policy_json_roundtrip() {
        let mut p = EnergyPolicy::default_policy();
        let back = EnergyPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        p.lease_rounds = 6;
        let back = EnergyPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(back.lease_rounds, 6, "lease survives the JSON round trip");
    }

    #[test]
    fn pre_lease_json_defaults_to_no_expiry() {
        let j = Json::parse(
            r#"{"id": "x", "qos": "balanced", "min_cap_frac": 0.3,
            "max_cap_frac": 1.0, "enabled": true, "max_slowdown": 1.1}"#,
        )
        .unwrap();
        let p = EnergyPolicy::from_json(&j).unwrap();
        assert_eq!(p.lease_rounds, 0);
    }

    #[test]
    fn invalid_policies_rejected() {
        let mut p = EnergyPolicy::default_policy();
        p.min_cap_frac = 0.9;
        p.max_cap_frac = 0.4;
        assert!(p.validate().is_err());
        let mut p = EnergyPolicy::default_policy();
        p.max_slowdown = 0.5;
        assert!(p.validate().is_err());
        // And a malformed JSON policy must fail closed.
        let j = Json::parse(r#"{"id": "x", "qos": "warp", "min_cap_frac": 0.3,
            "max_cap_frac": 1.0, "enabled": true, "max_slowdown": 1.1}"#)
        .unwrap();
        assert!(EnergyPolicy::from_json(&j).is_err());
    }
}
