//! The FROST power profiler (paper Sec. III-C).
//!
//! When a new ML model arrives on an inference host, the profiler:
//!
//! 1. measures the idle baseline over the hardcoded window `T_m`;
//! 2. tests each power limit (default: eight, 30%–100% of TDP in 10% steps)
//!    for a brief window (default 30 s), measuring energy-per-sample and
//!    time-per-sample under each cap;
//! 3. scores each point with the policy's `ED^m P` criterion, fits
//!    `F(x)` by least squares (Eqs. 6–7), and locates the minimum with the
//!    downhill simplex;
//! 4. enforces the policy's cap bounds and slowdown budget, then applies
//!    the chosen cap.
//!
//! The energy consumed *by profiling itself* is accounted and charged to
//! the pipeline per Eqs. 4–5.

use crate::config::ProfilerConfig;
use crate::simulator::{Testbed, WorkloadDescriptor};
use crate::util::{Joules, Seconds, Watts};

use super::edp::EdpCriterion;
use super::fit::{fit_response, FitResult};
use super::policy::EnergyPolicy;

/// One profiled power limit.
#[derive(Debug, Clone)]
pub struct ProfilePoint {
    /// Cap fraction actually enforced by the driver (after clamping).
    pub cap_frac: f64,
    /// Profiling window wall time.
    pub window: Seconds,
    /// Batches executed in the window.
    pub steps: u64,
    /// Samples processed in the window.
    pub samples: u64,
    /// Gross platform energy over the window.
    pub energy: Joules,
    pub mean_power: Watts,
    pub energy_per_sample_j: f64,
    pub time_per_sample_s: f64,
    /// Criterion score (per-sample ED^mP); the quantity F(x) is fitted to.
    pub score: f64,
}

/// The profiler's decision for one model.
#[derive(Debug, Clone)]
pub struct ProfileOutcome {
    pub model: String,
    pub criterion: EdpCriterion,
    pub points: Vec<ProfilePoint>,
    pub fit: FitResult,
    /// The cap FROST chose (within policy bounds, slowdown-constrained).
    pub optimal_cap: f64,
    /// Energy consumed by the profiling sweep itself (the `8·∫P_pr dt`
    /// charge of Eqs. 4–5).
    pub profiling_energy: Joules,
    /// Idle platform power measured over `T_m`.
    pub idle_power: Watts,
    /// Estimated energy saving at `optimal_cap` vs the 100% default (>0 is
    /// a saving).
    pub est_energy_saving: f64,
    /// Estimated slowdown at `optimal_cap` vs the 100% default (1.05 =
    /// +5% time).
    pub est_slowdown: f64,
}

/// The profiler.
#[derive(Debug, Clone)]
pub struct PowerProfiler {
    pub config: ProfilerConfig,
    pub policy: EnergyPolicy,
    /// Some(m): explicit ED^mP override (no-policy construction);
    /// None: the A1 policy's QoS class decides.
    exponent_override: Option<f64>,
}

impl PowerProfiler {
    /// Standalone profiler: the config's `edp_exponent` is authoritative.
    pub fn new(config: ProfilerConfig) -> Self {
        PowerProfiler {
            policy: EnergyPolicy::default_policy(),
            exponent_override: Some(config.edp_exponent),
            config,
        }
    }

    /// Policy-driven profiler (the O-RAN deployment path): the A1 policy's
    /// QoS class selects the ED^mP exponent.
    pub fn with_policy(config: ProfilerConfig, policy: EnergyPolicy) -> Self {
        PowerProfiler { config, policy, exponent_override: None }
    }

    /// The active decision criterion.
    pub fn criterion(&self) -> EdpCriterion {
        match self.exponent_override {
            Some(m) => EdpCriterion::new(m),
            None => self.policy.qos.criterion(),
        }
    }

    /// Profile a (virtual-testbed) training workload and choose the cap.
    ///
    /// Restores the testbed to the chosen cap before returning.
    pub fn profile(
        &self,
        tb: &mut Testbed,
        w: &WorkloadDescriptor,
        batch: u32,
    ) -> ProfileOutcome {
        let criterion = self.criterion();

        // 1. Idle baseline over T_m (Eqs. 1–2).
        let idle = tb.idle_window(Seconds(self.config.idle_window_s));
        let idle_power = idle.energy.mean_power(idle.wall);

        // 2. Sweep the limits within policy bounds. A narrow policy window
        //    (e.g. a fleet power-budget allocation capping a site at 45%)
        //    can leave fewer coarse caps than the fit needs — densify the
        //    sweep across the allowed range instead of failing.
        let mut caps: Vec<f64> = self
            .config
            .cap_fracs
            .iter()
            .copied()
            .filter(|&c| {
                c >= self.policy.min_cap_frac - 1e-9 && c <= self.policy.max_cap_frac + 1e-9
            })
            .collect();
        if caps.len() < 4 {
            // Densify *within* the policy window: the sweep must never set
            // a cap the policy forbids — a fleet power budget may be in
            // force while a re-profile runs, and a LatencyCritical floor
            // must hold even during measurement. A (near-)degenerate
            // window yields repeated caps and a forced decision, which the
            // candidate-based minimiser handles.
            let floor = self.config.cap_fracs.first().copied().unwrap_or(0.3);
            let ceil = self.config.cap_fracs.last().copied().unwrap_or(1.0);
            let win_lo = self.policy.min_cap_frac.max(floor).min(ceil);
            let win_hi = self.policy.max_cap_frac.min(ceil).max(win_lo);
            caps = (0..6)
                .map(|i| win_lo + (win_hi - win_lo) * i as f64 / 5.0)
                .collect();
        }
        let mut points = Vec::new();
        let mut profiling_energy = Joules(0.0);
        for &cap in &caps {
            let enforced = tb.set_cap_frac(cap);
            let agg = tb.train_window(w, batch, Seconds(self.config.window_s));
            profiling_energy += agg.energy;
            let samples = agg.steps * batch as u64;
            let eps = agg.energy.0 / samples as f64;
            let tps = agg.wall.0 / samples as f64;
            points.push(ProfilePoint {
                cap_frac: enforced,
                window: agg.wall,
                steps: agg.steps,
                samples,
                energy: agg.energy,
                mean_power: agg.energy.mean_power(agg.wall),
                energy_per_sample_j: eps,
                time_per_sample_s: tps,
                score: criterion.score(eps, tps),
            });
        }
        assert!(
            points.len() >= 4,
            "policy bounds left too few caps to profile ({})",
            points.len()
        );

        // 3. Fit F(x) to the scores and minimise (Eqs. 6–7 + simplex).
        let xy: Vec<(f64, f64)> =
            points.iter().map(|p| (p.cap_frac, p.score)).collect();
        let fit = fit_response(&xy, self.config.fit_error_threshold);
        let lo = points.first().unwrap().cap_frac;
        let hi = points.last().unwrap().cap_frac;
        let (mut optimal_cap, _) = fit.minimize(lo, hi);

        // Decision window: policy bounds ∩ swept range. The sweep may range
        // wider than the policy (narrow fleet-budget windows), but the
        // decision never escapes it.
        let cap_lo = self.policy.min_cap_frac.max(lo).min(self.policy.max_cap_frac);
        let cap_hi = self.policy.max_cap_frac.min(hi).max(cap_lo);
        optimal_cap = optimal_cap.clamp(cap_lo, cap_hi);

        // 4. Enforce the slowdown budget: walk the cap up (time is monotone
        //    non-increasing in cap) until the estimate fits the policy —
        //    within the decision window. An explicit cap window takes
        //    precedence: if even cap_hi violates the slowdown budget, the
        //    decision stands at cap_hi.
        let baseline = points.last().unwrap(); // highest cap = reference
        while optimal_cap < cap_hi - 1e-6 {
            let t = interp(&points, optimal_cap, |p| p.time_per_sample_s);
            if t / baseline.time_per_sample_s <= self.policy.max_slowdown {
                break;
            }
            optimal_cap = (optimal_cap + 0.02).min(cap_hi);
        }

        let est_energy = interp(&points, optimal_cap, |p| p.energy_per_sample_j);
        let est_time = interp(&points, optimal_cap, |p| p.time_per_sample_s);
        let est_energy_saving = 1.0 - est_energy / baseline.energy_per_sample_j;
        let est_slowdown = est_time / baseline.time_per_sample_s;

        // 5. Apply the decision.
        let applied = if self.policy.enabled { optimal_cap } else { 1.0 };
        tb.set_cap_frac(applied);

        ProfileOutcome {
            model: w.name.clone(),
            criterion,
            points,
            fit,
            optimal_cap,
            profiling_energy,
            idle_power,
            est_energy_saving,
            est_slowdown,
        }
    }
}

/// Linear interpolation of a per-point quantity at an arbitrary cap.
fn interp(points: &[ProfilePoint], cap: f64, f: impl Fn(&ProfilePoint) -> f64) -> f64 {
    let mut prev = &points[0];
    if cap <= prev.cap_frac {
        return f(prev);
    }
    for p in &points[1..] {
        if cap <= p.cap_frac {
            let t = (cap - prev.cap_frac) / (p.cap_frac - prev.cap_frac);
            return f(prev) * (1.0 - t) + f(p) * t;
        }
        prev = p;
    }
    f(points.last().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{setup_no1, setup_no2, ProfilerConfig};
    use crate::frost::policy::QosClass;
    use crate::zoo::model_by_name;

    fn profile_model(name: &str, exponent: f64) -> ProfileOutcome {
        let hw = setup_no2();
        let entry = model_by_name(name).unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 42);
        let config = ProfilerConfig { edp_exponent: exponent, ..Default::default() };
        PowerProfiler::new(config).profile(&mut tb, &w, 128)
    }

    #[test]
    fn profiles_eight_points_with_good_fit() {
        let out = profile_model("ResNet", 1.0);
        assert_eq!(out.points.len(), 8);
        assert!(out.fit.good_fit, "rel_error {}", out.fit.rel_error);
        // Caps enforced in ascending order, clamped to the driver floor.
        for pair in out.points.windows(2) {
            assert!(pair[1].cap_frac > pair[0].cap_frac);
        }
        assert!(out.points[0].cap_frac >= 0.28);
    }

    #[test]
    fn optimal_cap_interior_for_balanced_model() {
        let out = profile_model("ResNet", 1.0);
        assert!(
            out.optimal_cap > 0.35 && out.optimal_cap < 0.95,
            "ResNet optimal cap {} not interior",
            out.optimal_cap
        );
        assert!(out.est_energy_saving > 0.05, "saving {}", out.est_energy_saving);
    }

    #[test]
    fn memory_bound_model_gets_lower_cap_than_compute_bound() {
        let eff = profile_model("EfficientNet", 1.0);
        let rx = profile_model("ResNeXt", 1.0);
        assert!(
            eff.optimal_cap < rx.optimal_cap,
            "EfficientNet {} should cap below ResNeXt {}",
            eff.optimal_cap,
            rx.optimal_cap
        );
    }

    #[test]
    fn higher_exponent_raises_optimal_cap() {
        // Paper Fig. 5: "the more weight attributed to delay, the higher
        // the optimal power limit becomes".
        let e1 = profile_model("ResNet", 1.0);
        let e3 = profile_model("ResNet", 3.0);
        assert!(
            e3.optimal_cap >= e1.optimal_cap - 0.02,
            "ED3P cap {} must not be below EDP cap {}",
            e3.optimal_cap,
            e1.optimal_cap
        );
    }

    #[test]
    fn lenet_outlier_keeps_high_cap() {
        // Paper: "LeNet was an outlier and showed no change in behaviour".
        let out = profile_model("LeNet", 1.0);
        // Capping a host-bound model neither saves much energy nor slows it;
        // the optimum must not promise meaningful savings.
        assert!(
            out.est_energy_saving.abs() < 0.12,
            "LeNet savings should be negligible, got {}",
            out.est_energy_saving
        );
        assert!(out.est_slowdown < 1.03);
    }

    #[test]
    fn profiling_energy_charged() {
        let out = profile_model("ResNet", 2.0);
        // Eight ~30 s windows at a few hundred watts -> tens of kJ.
        assert!(out.profiling_energy.0 > 8.0 * 30.0 * 100.0);
        assert!(out.profiling_energy.0 < 8.0 * 31.0 * 500.0);
        assert!(out.idle_power.0 > 20.0 && out.idle_power.0 < 150.0);
    }

    #[test]
    fn disabled_policy_leaves_default_cap() {
        let hw = setup_no2();
        let entry = model_by_name("ResNet").unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 1);
        let mut policy = EnergyPolicy::default_policy();
        policy.enabled = false;
        let out = PowerProfiler::with_policy(ProfilerConfig::default(), policy)
            .profile(&mut tb, &w, 128);
        assert_eq!(tb.cap_frac(), 1.0, "disabled policy must not cap");
        assert!(out.optimal_cap < 1.0, "recommendation still computed");
    }

    #[test]
    fn policy_bounds_respected() {
        let hw = setup_no2();
        let entry = model_by_name("EfficientNet").unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 1);
        let policy = EnergyPolicy {
            min_cap_frac: 0.6,
            max_cap_frac: 1.0,
            ..EnergyPolicy::default_policy()
        };
        let out = PowerProfiler::with_policy(ProfilerConfig::default(), policy)
            .profile(&mut tb, &w, 128);
        assert!(out.optimal_cap >= 0.6 - 1e-9);
        assert!(out.points.iter().all(|p| p.cap_frac >= 0.6 - 1e-9));
    }

    #[test]
    fn narrow_policy_window_densifies_inside_bounds() {
        // A fleet power-budget allocation can pin a site into a window that
        // contains fewer than four of the coarse 10% caps. The profiler
        // must densify *within* the window — sweeping outside it would
        // physically violate an in-force power budget during measurement.
        let hw = setup_no2();
        let entry = model_by_name("ResNet").unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 6);
        let policy = EnergyPolicy {
            min_cap_frac: 0.30,
            max_cap_frac: 0.45,
            ..EnergyPolicy::default_policy()
        };
        let out = PowerProfiler::with_policy(ProfilerConfig::default(), policy)
            .profile(&mut tb, &w, 128);
        assert!(out.points.len() >= 4, "{} points", out.points.len());
        for p in &out.points {
            assert!(
                p.cap_frac >= 0.30 - 1e-9 && p.cap_frac <= 0.45 + 1e-9,
                "swept cap {} escaped the policy window",
                p.cap_frac
            );
        }
        assert!(
            out.optimal_cap >= 0.30 - 1e-9 && out.optimal_cap <= 0.45 + 1e-9,
            "decision {} escaped the policy window",
            out.optimal_cap
        );
        // The applied cap honours the window too.
        assert!(tb.cap_frac() <= 0.45 + 1e-9);
    }

    #[test]
    fn latency_policy_bounds_slowdown() {
        let hw = setup_no2();
        let entry = model_by_name("VGG").unwrap(); // compute-bound: caps hurt
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 1);
        let policy = EnergyPolicy {
            qos: QosClass::LatencyCritical,
            max_slowdown: 1.05,
            ..EnergyPolicy::default_policy()
        };
        let out = PowerProfiler::with_policy(ProfilerConfig::default(), policy)
            .profile(&mut tb, &w, 128);
        assert!(
            out.est_slowdown <= 1.06,
            "slowdown {} exceeds policy budget",
            out.est_slowdown
        );
    }

    #[test]
    fn fine_grained_sweep_71_points() {
        let hw = setup_no2();
        let entry = model_by_name("ResNet").unwrap();
        let w = entry.workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw, 7);
        let out = PowerProfiler::new(ProfilerConfig::fine_grained())
            .profile(&mut tb, &w, 128);
        // 71 requested caps, but those below the 3090's driver floor (28.6%)
        // clamp to the same enforced value; all >= floor survive distinctly.
        assert!(out.points.len() >= 65, "{} points", out.points.len());
    }
}
