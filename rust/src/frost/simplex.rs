//! Downhill simplex (Nelder–Mead) minimiser — implemented from scratch.
//!
//! The paper uses "the downhill simplex algorithm" to find the minimum of
//! the fitted response F(x) (Sec. III-C); we additionally use it as the
//! inner optimiser of the nonlinear least-squares fit itself.  Standard
//! Nelder & Mead (1965) with the usual coefficients: reflection α = 1,
//! expansion γ = 2, contraction ρ = ½, shrink σ = ½.

/// Termination and scaling options.
#[derive(Debug, Clone)]
pub struct NelderMeadOptions {
    /// Maximum function evaluations.
    pub max_evals: usize,
    /// Stop when the simplex's function-value spread falls below this.
    pub f_tol: f64,
    /// Stop when the simplex's vertex spread falls below this.
    pub x_tol: f64,
    /// Initial simplex edge length relative to |x0| (absolute for zeros).
    pub initial_step: f64,
}

impl Default for NelderMeadOptions {
    fn default() -> Self {
        NelderMeadOptions {
            max_evals: 4000,
            f_tol: 1e-12,
            x_tol: 1e-10,
            initial_step: 0.1,
        }
    }
}

/// Result of a minimisation.
#[derive(Debug, Clone)]
pub struct SimplexResult {
    pub x: Vec<f64>,
    pub fx: f64,
    pub evals: usize,
    pub converged: bool,
}

/// Minimise `f` starting from `x0`.
pub fn nelder_mead(
    f: impl Fn(&[f64]) -> f64,
    x0: &[f64],
    opts: &NelderMeadOptions,
) -> SimplexResult {
    let n = x0.len();
    assert!(n >= 1, "need at least one dimension");
    const ALPHA: f64 = 1.0;
    const GAMMA: f64 = 2.0;
    const RHO: f64 = 0.5;
    const SIGMA: f64 = 0.5;

    let mut evals = 0usize;
    let eval = |x: &[f64], evals: &mut usize| -> f64 {
        *evals += 1;
        let v = f(x);
        if v.is_nan() {
            f64::INFINITY
        } else {
            v
        }
    };

    // Initial simplex: x0 plus one perturbed vertex per dimension.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let fx0 = eval(x0, &mut evals);
    simplex.push((x0.to_vec(), fx0));
    for i in 0..n {
        let mut v = x0.to_vec();
        let step = if v[i].abs() > 1e-12 {
            v[i].abs() * opts.initial_step
        } else {
            opts.initial_step
        };
        v[i] += step;
        let fv = eval(&v, &mut evals);
        simplex.push((v, fv));
    }

    while evals < opts.max_evals {
        simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
        let (best_f, worst_f) = (simplex[0].1, simplex[n].1);

        // Convergence checks.
        let f_spread = (worst_f - best_f).abs();
        let x_spread = (0..n)
            .map(|i| {
                let vals: Vec<f64> = simplex.iter().map(|(v, _)| v[i]).collect();
                let mx = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mn = vals.iter().cloned().fold(f64::INFINITY, f64::min);
                mx - mn
            })
            .fold(0.0f64, f64::max);
        if f_spread < opts.f_tol && x_spread < opts.x_tol {
            return SimplexResult {
                x: simplex[0].0.clone(),
                fx: simplex[0].1,
                evals,
                converged: true,
            };
        }

        // Centroid of all but worst.
        let mut centroid = vec![0.0; n];
        for (v, _) in &simplex[..n] {
            for (c, vi) in centroid.iter_mut().zip(v) {
                *c += vi / n as f64;
            }
        }

        let worst = simplex[n].clone();
        let reflected: Vec<f64> = centroid
            .iter()
            .zip(&worst.0)
            .map(|(c, w)| c + ALPHA * (c - w))
            .collect();
        let f_ref = eval(&reflected, &mut evals);

        if f_ref < simplex[0].1 {
            // Try expansion.
            let expanded: Vec<f64> = centroid
                .iter()
                .zip(&reflected)
                .map(|(c, r)| c + GAMMA * (r - c))
                .collect();
            let f_exp = eval(&expanded, &mut evals);
            simplex[n] = if f_exp < f_ref { (expanded, f_exp) } else { (reflected, f_ref) };
        } else if f_ref < simplex[n - 1].1 {
            simplex[n] = (reflected, f_ref);
        } else {
            // Contraction (outside if reflected better than worst, else inside).
            let towards = if f_ref < worst.1 { &reflected } else { &worst.0 };
            let contracted: Vec<f64> = centroid
                .iter()
                .zip(towards)
                .map(|(c, t)| c + RHO * (t - c))
                .collect();
            let f_con = eval(&contracted, &mut evals);
            if f_con < worst.1.min(f_ref) {
                simplex[n] = (contracted, f_con);
            } else {
                // Shrink towards best.
                let best = simplex[0].0.clone();
                for (v, fv) in simplex.iter_mut().skip(1) {
                    for (vi, bi) in v.iter_mut().zip(&best) {
                        *vi = bi + SIGMA * (*vi - bi);
                    }
                    *fv = eval(v, &mut evals);
                }
            }
        }
    }

    simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
    SimplexResult { x: simplex[0].0.clone(), fx: simplex[0].1, evals, converged: false }
}

/// Convenience: 1-D bounded minimisation by multi-start Nelder–Mead +
/// clamping — used to locate the optimum of the fitted F(x) over the cap
/// range [lo, hi].
pub fn minimize_1d(f: impl Fn(f64) -> f64, lo: f64, hi: f64) -> (f64, f64) {
    assert!(lo < hi);
    let wrapped = |x: &[f64]| {
        let xc = x[0];
        // Penalised bounds keep the simplex inside [lo, hi].
        if xc < lo || xc > hi {
            let d = (xc - hi).max(lo - xc);
            return f(xc.clamp(lo, hi)) + d * d * 1e6;
        }
        f(xc)
    };
    let opts = NelderMeadOptions { initial_step: (hi - lo) * 0.1, ..Default::default() };
    let mut best = (f64::NAN, f64::INFINITY);
    for k in 0..7 {
        let x0 = lo + (hi - lo) * (k as f64 + 0.5) / 7.0;
        let r = nelder_mead(&wrapped, &[x0], &opts);
        let x = r.x[0].clamp(lo, hi);
        let fx = f(x);
        if fx < best.1 {
            best = (x, fx);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        let r = nelder_mead(|x| (x[0] - 3.0).powi(2) + 2.0, &[0.0], &Default::default());
        assert!((r.x[0] - 3.0).abs() < 1e-4, "x = {}", r.x[0]);
        assert!((r.fx - 2.0).abs() < 1e-8);
        assert!(r.converged);
    }

    #[test]
    fn minimises_rosenbrock_2d() {
        let rosen =
            |x: &[f64]| (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2);
        let r = nelder_mead(rosen, &[-1.2, 1.0], &NelderMeadOptions {
            max_evals: 20_000,
            ..Default::default()
        });
        assert!((r.x[0] - 1.0).abs() < 1e-3 && (r.x[1] - 1.0).abs() < 1e-3, "{:?}", r.x);
    }

    #[test]
    fn minimises_5d_sphere() {
        let r = nelder_mead(
            |x| x.iter().map(|v| v * v).sum(),
            &[1.0, -2.0, 3.0, -4.0, 5.0],
            &NelderMeadOptions { max_evals: 20_000, ..Default::default() },
        );
        assert!(r.fx < 1e-6, "fx = {}", r.fx);
    }

    #[test]
    fn handles_nan_objective() {
        // NaN regions must not poison the search.
        let f = |x: &[f64]| if x[0] < 0.0 { f64::NAN } else { (x[0] - 1.0).powi(2) };
        let r = nelder_mead(f, &[2.0], &Default::default());
        assert!((r.x[0] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn bounded_1d_interior_minimum() {
        let (x, fx) = minimize_1d(|x| (x - 0.6).powi(2) + 1.0, 0.3, 1.0);
        assert!((x - 0.6).abs() < 1e-5);
        assert!((fx - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_1d_boundary_minimum() {
        // Monotone decreasing on the interval -> optimum at hi.
        let (x, _) = minimize_1d(|x| -x, 0.3, 1.0);
        assert!((x - 1.0).abs() < 1e-5, "x = {x}");
        // Monotone increasing -> optimum at lo.
        let (x, _) = minimize_1d(|x| x, 0.3, 1.0);
        assert!((x - 0.3).abs() < 1e-5, "x = {x}");
    }

    #[test]
    fn respects_eval_budget() {
        let mut count = std::cell::Cell::new(0usize);
        let _ = &mut count;
        let f = |x: &[f64]| {
            count.set(count.get() + 1);
            x[0].sin() * x[0].cos()
        };
        let r = nelder_mead(f, &[1.0], &NelderMeadOptions {
            max_evals: 50,
            f_tol: 0.0,
            x_tol: 0.0,
            ..Default::default()
        });
        assert!(!r.converged);
        assert!(count.get() <= 55, "evals {}", count.get());
    }
}
