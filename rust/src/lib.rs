//! # FROST — Flexible Reconfiguration method with Online System Tuning
//!
//! A reproduction of *"FROST: Towards Energy-efficient AI-on-5G Platforms —
//! A GPU Power Capping Evaluation"* (Mavromatis et al., 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! This crate is **Layer 3**: the coordinator.  It owns
//!
//! * the physics substrates replacing the paper's hardware (GPU/CPU/DRAM
//!   power models, NVML/RAPL-style telemetry interfaces) — [`power`],
//!   [`telemetry`], [`simulator`];
//! * the paper's contribution — the FROST power profiler, the
//!   `F(x) = a·e^(bx−c) + d·σ(ex−f) + g` response fit, the downhill-simplex
//!   minimiser and the `ED^m P` decision criterion — [`frost`];
//! * the O-RAN fabric it deploys into (SMO, non-RT/near-RT RICs, A1
//!   policies, the AI/ML lifecycle) — [`oran`];
//! * the real compute path: AOT-lowered JAX/Pallas models executed through
//!   PJRT — [`runtime`], [`pipeline`].
//!
//! Python (Layers 1 & 2, under `python/`) runs only at build time to emit
//! `artifacts/*.hlo.txt`; it is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every figure of the paper to a regeneration harness.

pub mod ckpt;
pub mod config;
pub mod data;
pub mod figures;
pub mod frost;
pub mod metrics;
pub mod obs;
pub mod oran;
pub mod pipeline;
pub mod power;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod simulator;
pub mod telemetry;
pub mod traffic;
pub mod util;
pub mod zoo;


pub use crate::power::{CpuPowerModel, DramPowerModel, GpuPowerModel};

