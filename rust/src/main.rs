//! `frost` — the L3 coordinator CLI.
//!
//! ```text
//! frost list-models                         the 16-model zoo
//! frost profile --model ResNet [--setup 2] [--exponent 2] [--fine]
//! frost figures [--fig all|2|3|4|5|6] [--setup 1] [--out DIR]
//! frost sweep --model DenseNet [--setup 2]  per-cap table (Fig. 4 style)
//! frost train --model lenet --steps 50      REAL PJRT training + hybrid account
//! frost overhead [--samples 2560]           REAL Fig. 3 experiment
//! frost oran-demo                           six-step AI/ML lifecycle
//! ```
//!
//! Argument parsing is in-tree (offline build — DESIGN.md §2).

use std::collections::HashMap;

use anyhow::{Context, Result};

use frost::config::{setup_no1, setup_no2, HardwareConfig, ProfilerConfig};
use frost::figures;
use frost::frost::{EnergyPolicy, PowerProfiler};
use frost::oran::MlLifecycle;
use frost::simulator::Testbed;
use frost::zoo::{all_models, model_by_name};

/// Minimal flag parser: `--key value` pairs + positional subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut key: Option<String> = None;
        for arg in it {
            if let Some(k) = arg.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    flags.insert(prev, "true".to_string()); // boolean flag
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, arg);
            }
        }
        if let Some(prev) = key.take() {
            flags.insert(prev, "true".to_string());
        }
        Args { cmd, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    fn num(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn setup(&self) -> HardwareConfig {
        match self.get_or("setup", "1") {
            "2" => setup_no2(),
            _ => setup_no1(),
        }
    }
}

fn main() {
    let args = Args::parse();
    let result = match args.cmd.as_str() {
        "list-models" => cmd_list_models(),
        "profile" => cmd_profile(&args),
        "sweep" => cmd_sweep(&args),
        "figures" => cmd_figures(&args),
        "train" => cmd_train(&args),
        "overhead" => cmd_overhead(&args),
        "oran-demo" => cmd_oran_demo(&args),
        "fleet" => cmd_fleet(&args),
        "bench" => cmd_bench(&args),
        "shift" => cmd_shift(&args),
        "dvfs-ablation" => cmd_dvfs_ablation(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
frost — energy-aware ML pipelines for O-RAN (paper reproduction)

USAGE: frost <command> [--flag value]...

COMMANDS:
  list-models                     show the 16-model zoo
  profile   --model NAME [--setup 1|2] [--exponent M] [--fine]
  sweep     --model NAME [--setup 1|2]      per-cap table (Fig. 4 style)
  figures   [--fig all|2|3|4|5|6] [--setup 1|2] [--out DIR] [--epochs N]
  train     --model NAME [--steps N] [--batch-seed S] [--cap FRAC]   (pjrt)
  overhead  [--samples N] [--reps R]        real Fig. 3 experiment   (pjrt)
  oran-demo [--model NAME] [--epochs N]     six-step AI/ML lifecycle
  fleet     [--sites N] [--seed S] [--rounds R] [--threads T]
            [--epochs N] [--samples N] [--infer-steps N]
            [--budget-frac F] [--max-profiles K] [--churn-every C]
            [--sample-retention N] [--out DIR] multi-host fleet simulation
  bench     [--target-s S] [--out FILE] [--force]  hot-path benches -> BENCH_fleet.json
  shift     [--budget-frac F]               site-level power shifting
  dvfs-ablation [--setup 1|2] [--exponent M]  capping vs DVFS per model

Commands marked (pjrt) execute real AOT artifacts and need a build with
--features pjrt plus real xla bindings (see DESIGN.md).
";

fn cmd_list_models() -> Result<()> {
    let gpu = setup_no1().gpu;
    println!(
        "{:<14} {:>12} {:>10} {:>6} {:>6} {:>9}  artifact",
        "model", "params", "MFLOP/img", "beta", "eff", "ref acc"
    );
    for m in all_models() {
        let w = m.workload(&gpu);
        println!(
            "{:<14} {:>12} {:>10.1} {:>6.2} {:>6.2} {:>8.2}%  {}",
            m.name,
            m.params,
            m.fwd_mflops,
            w.beta(&gpu),
            m.kernel_efficiency,
            m.reference_accuracy * 100.0,
            m.artifact.unwrap_or("-"),
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let hw = args.setup();
    let entry = model_by_name(model).with_context(|| format!("unknown model '{model}'"))?;
    let w = entry.workload(&setup_no1().gpu);
    let mut config = if args.get("fine").is_some() {
        ProfilerConfig::fine_grained()
    } else {
        ProfilerConfig::default()
    };
    config.edp_exponent = args.num("exponent", 2.0);
    let mut tb = Testbed::new(hw.clone(), 42);
    let profiler = PowerProfiler::new(config);
    let out = profiler.profile(&mut tb, &w, 128);
    println!("model        : {}", out.model);
    println!("hardware     : {} ({})", hw.name, hw.gpu.name);
    println!("criterion    : {}", out.criterion);
    println!("fit rel. err : {:.2}% (good fit: {})", out.fit.rel_error * 100.0, out.fit.good_fit);
    println!("optimal cap  : {:.1}% of TDP ({:.0} W)", out.optimal_cap * 100.0, out.optimal_cap * hw.gpu.tdp_w);
    println!("est. saving  : {:.1}% energy", out.est_energy_saving * 100.0);
    println!("est. slowdown: {:+.1}% time", (out.est_slowdown - 1.0) * 100.0);
    println!("profiling cost: {}", out.profiling_energy);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let hw = args.setup();
    let series = figures::fig4_power_capping(&hw, &[model], 42);
    print!("{}", series.to_table());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let hw = args.setup();
    let which = args.get_or("fig", "all");
    let epochs = args.num("epochs", 100.0) as u32;
    let out_dir = args.get("out");
    let mut emitted: Vec<(String, String)> = Vec::new();

    if which == "all" || which.starts_with('2') {
        let out = figures::fig2_investigation(&hw, epochs, 42);
        print!("{}", out.table.to_table());
        println!("r(accuracy, energy) = {:.3}   [paper: 0.34]", out.r_accuracy_energy);
        println!("r(energy, time)     = {:.3}   [paper: 0.999]", out.r_energy_time);
        println!();
        emitted.push(("fig2.csv".into(), out.table.to_csv()));
    }
    if which == "all" || which == "3" {
        #[cfg(feature = "pjrt")]
        {
            let samples = args.num("samples", 2560.0) as u64;
            match figures::fig3_overhead(&hw, &["lenet", "mobilenet_mini"], samples, 1) {
                Ok(s) => {
                    print!("{}", s.to_table());
                    println!();
                    emitted.push(("fig3.csv".into(), s.to_csv()));
                }
                Err(e) => eprintln!("fig3 skipped ({e}); run `make artifacts` first"),
            }
        }
        #[cfg(not(feature = "pjrt"))]
        eprintln!("fig3 skipped (real PJRT inference; rebuild with --features pjrt)");
    }
    if which == "all" || which == "4" {
        let s = figures::fig4_power_capping(&hw, &["MobileNet", "DenseNet", "EfficientNet"], 42);
        print!("{}", s.to_table());
        println!();
        emitted.push(("fig4.csv".into(), s.to_csv()));
    }
    if which == "all" || which == "5" {
        let out = figures::fig5_fine_grained(&hw, "ResNet", 42);
        print!("{}", out.sweep.to_table());
        for (m, cap, saving, delay) in &out.optima {
            println!("ED{m}P optimum: cap {cap:.1}%  saving {saving:.1}%  delay {delay:+.1}%");
        }
        println!();
        emitted.push(("fig5.csv".into(), out.sweep.to_csv()));
    }
    if which == "all" || which == "6" {
        let out = figures::fig6_tradeoff(&hw, args.num("exponent", 2.0), 42);
        print!("{}", out.table.to_table());
        println!(
            "MEAN: saving {:.1}% at {:+.1}% time  [paper {}: {}]",
            out.mean_saving_pct,
            out.mean_delay_pct,
            hw.name,
            if hw.name == "setup_no1" { "26.4% @ +6.9%" } else { "17.7% @ +5.5%" }
        );
        println!();
        emitted.push(("fig6.csv".into(), out.table.to_csv()));
    }
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        for (name, csv) in &emitted {
            let path = std::path::Path::new(dir).join(name);
            std::fs::write(&path, csv)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "'train' executes real AOT artifacts through PJRT; rebuild with --features pjrt"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use frost::data::SyntheticCifar;
    use frost::pipeline::{calibrated_workload, HybridAccountant};
    use frost::power::{CpuPowerModel, DramPowerModel, GpuPowerModel};
    use frost::runtime::{Runtime, TrainSession};
    use frost::simulator::ExecutionModel;
    use frost::util::Joules;
    use frost::zoo::Manifest;

    let model = args.get_or("model", "lenet");
    let steps = args.num("steps", 50.0) as u64;
    let cap = args.num("cap", 1.0);
    let hw = args.setup();
    let manifest = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    println!("platform: {} ({} devices)", rt.platform(), rt.device_count());
    let mut session = TrainSession::new(&rt, &manifest, model)?;
    println!("loaded {model}: {} params, batch {}", session.model.param_count, session.batch);

    let m = manifest.model(model).unwrap();
    let w = calibrated_workload(m, &hw.gpu, None)?;
    let exec = ExecutionModel::new(
        GpuPowerModel::new(hw.gpu.clone()),
        CpuPowerModel::new(hw.cpu.clone()),
        DramPowerModel::new(hw.dimms.clone()),
    );
    let mut acct = HybridAccountant::new(
        exec,
        w,
        session.batch,
        hw.gpu.tdp_w,
        hw.gpu.min_cap_frac,
        42,
    );
    acct.set_cap_frac(cap);

    let mut ds = SyntheticCifar::new(args.num("batch-seed", 0.0) as u64);
    for i in 0..steps {
        let batch = ds.next_batch(session.batch as usize);
        let metrics = session.step(&batch)?;
        acct.on_train_step(metrics.wall_s);
        if i % 10 == 0 || i + 1 == steps {
            println!(
                "step {:>4}  loss {:.4}  acc {:.3}  wall {:.1} ms",
                i,
                metrics.loss,
                metrics.accuracy,
                metrics.wall_s * 1e3
            );
        }
    }
    let account = acct.finish(Joules(0.0));
    println!("---");
    println!("steps          : {steps}");
    println!("mean step time : {:.1} ms", session.mean_step_time().unwrap_or(0.0) * 1e3);
    println!("gross energy   : {} over {}", account.gross, account.duration);
    println!("net energy     : {} (Eq. 1, idle baseline subtracted)", account.net());
    println!("mean power     : {} (virtual {})", account.mean_power(), hw.gpu.name);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_overhead(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "'overhead' measures real PJRT inference; rebuild with --features pjrt"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_overhead(args: &Args) -> Result<()> {
    let hw = args.setup();
    let samples = args.num("samples", 2560.0) as u64;
    let reps = args.num("reps", 1.0) as u32;
    let s = figures::fig3_overhead(&hw, &["lenet", "mobilenet_mini"], samples, reps)?;
    print!("{}", s.to_table());
    Ok(())
}

fn cmd_shift(args: &Args) -> Result<()> {
    use frost::power::{allocate_budget, total_throughput, HostProfile};
    let frac = args.num("budget-frac", 0.6);
    let site = [
        (setup_no1(), "ResNet"),
        (setup_no1(), "DenseNet"),
        (setup_no2(), "MobileNetV2"),
        (setup_no2(), "VGG"),
    ];
    let mut profiles = Vec::new();
    for (i, (hw, model)) in site.iter().enumerate() {
        let w = model_by_name(model).unwrap().workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw.clone(), 7 + i as u64);
        let out = PowerProfiler::new(ProfilerConfig::default()).profile(&mut tb, &w, 128);
        profiles.push(HostProfile::from_profile(
            &format!("host{}({model})", i + 1),
            hw.gpu.tdp_w,
            &out.points,
        ));
    }
    let full: f64 = profiles.iter().map(|p| p.tdp_w).sum();
    let budget = full * frac;
    let allocs = allocate_budget(&profiles, budget, 5.0)
        .context("budget below the driver floors")?;
    println!("site TDP {full:.0} W, budget {budget:.0} W ({:.0}%)", frac * 100.0);
    for a in &allocs {
        println!(
            "  {:<22} cap {:>5.1}%  ({:>5.0} W)  {:>8.0} samples/s",
            a.host,
            a.cap_frac * 100.0,
            a.watts,
            a.throughput
        );
    }
    println!("total throughput: {:.0} samples/s", total_throughput(&allocs));
    Ok(())
}

fn cmd_dvfs_ablation(args: &Args) -> Result<()> {
    use frost::simulator::capping_vs_dvfs;
    let hw = args.setup();
    let exponent = args.num("exponent", 1.0);
    println!(
        "{:<14} {:>14} {:>12} {:>14} {:>12}",
        "model", "capping_save%", "dvfs_save%", "capping_time%", "dvfs_time%"
    );
    for entry in all_models() {
        let w = entry.workload(&setup_no1().gpu);
        let row = capping_vs_dvfs(&hw, &w, 128, exponent, 5);
        println!(
            "{:<14} {:>14.1} {:>12.1} {:>+14.1} {:>+12.1}",
            row.model,
            row.capping_saving * 100.0,
            row.dvfs_saving * 100.0,
            (row.capping_slowdown - 1.0) * 100.0,
            (row.dvfs_slowdown - 1.0) * 100.0
        );
    }
    println!("
[paper Sec. II-C: DVFS is finer-grained (>= savings) but device-specific;");
    println!(" capping captures most of the benefit portably — the numbers above quantify it]");
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use frost::oran::FleetConfig;
    let config = FleetConfig {
        sites: args.num("sites", 16.0).max(1.0) as usize,
        seed: args.num("seed", 7.0) as u64,
        threads: args.num("threads", 0.0) as usize,
        rounds: args.num("rounds", 8.0).max(1.0) as u32,
        train_epochs: args.num("epochs", 60.0).max(1.0) as u32,
        samples_per_epoch: args.num("samples", 20_000.0).max(1.0) as u64,
        infer_steps_per_round: args.num("infer-steps", 40.0).max(1.0) as u64,
        budget_frac: args.num("budget-frac", 1.0),
        max_concurrent_profiles: args.num("max-profiles", 4.0).max(1.0) as usize,
        churn_every: args.num("churn-every", 0.0) as u32,
        sample_retention: args.num("sample-retention", 512.0).max(0.0) as usize,
        ..FleetConfig::default()
    };
    let sites = config.sites;
    let out = figures::fleet_comparison(&config)?;
    print!("{}", out.table.to_table());
    println!();
    println!("=== fleet KPM/energy roll-up ===");
    println!("sites                : {sites} (mixed setup no.1/no.2, zoo workloads)");
    println!("mean applied cap     : {:.1}% of TDP", out.mean_cap_frac * 100.0);
    println!(
        "steady-state energy  : {:.1} kJ/round under FROST vs {:.1} kJ/round baseline",
        out.frost_round_j / 1e3,
        out.baseline_round_j / 1e3
    );
    println!(
        "fleet energy saving  : {:.1}% steady state  [paper band: 10-26%]",
        out.steady_saving_frac * 100.0
    );
    println!(
        "mean FROST estimate  : {:.1}% per profiled site",
        out.mean_est_saving_frac * 100.0
    );
    println!("profiling charge     : {:.1} kJ (Eqs. 4-5)", out.profiling_j / 1e3);
    println!("KPM reports ingested : {}", out.kpm_reports);
    for (host, energy_j, samples, gpu_w) in &out.frost.kpm_by_host {
        println!(
            "  KPM {host}: {:>8.1} kJ over {:>9} samples, last GPU {:>5.0} W",
            energy_j / 1e3,
            samples,
            gpu_w
        );
    }
    if let Some(budget) = out.frost.budget_w {
        if out.frost.budget_enforced {
            println!(
                "global GPU budget    : {:.0} W; enforced worst-case cap power {:.0} W",
                budget, out.frost.cap_power_w
            );
        } else {
            println!(
                "global GPU budget    : {:.0} W; NOT yet enforced (profiling stagger \
                 incomplete — raise --rounds); current cap power {:.0} W",
                budget, out.frost.cap_power_w
            );
        }
    }
    println!(
        "per-site accuracy    : {}",
        if out.accuracy_unchanged { "unchanged vs baseline on every site" } else { "CHANGED (unexpected)" }
    );
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join("fleet.csv");
        std::fs::write(&path, out.table.to_csv())?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Fleet hot-path benches from the CLI (the same suite as
/// `cargo bench --bench fleet` — one definition, `oran::run_bench_suite`,
/// so the two recorders cannot drift; DESIGN.md §8), recorded to a
/// `BENCH_fleet.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    use frost::oran::run_bench_suite;
    use frost::util::bench::{write_json, BenchStats};
    let target = args.num("target-s", 2.0);
    let out = args.get_or("out", "BENCH_fleet.json");
    // Refuse to clobber the curated perf-trajectory record (the checked-in
    // root BENCH_fleet.json wraps baseline+optimized result sets) unless
    // explicitly forced; raw runs should land elsewhere (e.g. rust/, which
    // is gitignored).
    if args.get("force").is_none() {
        if let Ok(existing) = std::fs::read_to_string(out) {
            if existing.contains("frost-bench-v1+trajectory") {
                anyhow::bail!(
                    "{out} holds a curated trajectory record; \
                     pass --out FILE or --force to overwrite"
                );
            }
        }
    }
    let results = run_bench_suite(target)?;
    let refs: Vec<(&str, BenchStats)> =
        results.iter().map(|(n, s)| (n.as_str(), *s)).collect();
    write_json(out, "fleet", &refs)?;
    Ok(())
}

fn cmd_oran_demo(args: &Args) -> Result<()> {
    let model = args.get_or("model", "ResNet");
    let epochs = args.num("epochs", 60.0) as u32;
    let entry = model_by_name(model).with_context(|| format!("unknown model '{model}'"))?;
    let w = entry.workload(&setup_no1().gpu);
    let mut lc = MlLifecycle::new(vec![setup_no1(), setup_no2()], 0.80, 42);
    println!("O-RAN deployment: SMO + non-RT RIC + near-RT RIC + 2 hosts");
    let stages = lc.run_workflow(
        model,
        w,
        "host1",
        EnergyPolicy::default_policy(),
        epochs,
        50_000,
    )?;
    for (i, s) in stages.iter().enumerate() {
        println!("  step {}: {:?}", i + 1, s);
    }
    let cap = lc.nonrt.catalogue.get(model).unwrap().optimal_cap.unwrap();
    println!("FROST decision: cap {:.1}% of TDP", cap * 100.0);
    println!("KPM reports collected: {}", lc.smo.kpms.len());
    println!("fabric traffic: {:?}", lc.bus.stats());
    println!(
        "mean energy saving across decisions: {:.1}%",
        lc.smo.mean_energy_saving() * 100.0
    );
    Ok(())
}
