//! `frost` — the L3 coordinator CLI.
//!
//! ```text
//! frost list-models                         the 16-model zoo
//! frost profile --model ResNet [--setup 2] [--exponent 2] [--fine]
//! frost figures [--fig all|2|3|4|5|6] [--setup 1] [--out DIR]
//! frost sweep --model DenseNet [--setup 2]  per-cap table (Fig. 4 style)
//! frost train --model lenet --steps 50      REAL PJRT training + hybrid account
//! frost overhead [--samples 2560]           REAL Fig. 3 experiment
//! frost oran-demo                           six-step AI/ML lifecycle
//! ```
//!
//! Argument parsing is in-tree (offline build — DESIGN.md §2).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use frost::config::{setup_no1, setup_no2, HardwareConfig, ProfilerConfig};
use frost::figures;
use frost::frost::{EnergyPolicy, PowerProfiler};
use frost::oran::MlLifecycle;
use frost::simulator::Testbed;
use frost::zoo::{all_models, model_by_name};

/// Minimal flag parser: `--key value` pairs + positional subcommand
/// (plus trailing positionals, e.g. `frost scenario outage-day`).
struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    fn parse_from(mut it: impl Iterator<Item = String>) -> Args {
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut key: Option<String> = None;
        for arg in it {
            if let Some(k) = arg.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    flags.insert(prev, "true".to_string()); // boolean flag
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, arg);
            } else {
                positional.push(arg);
            }
        }
        if let Some(prev) = key.take() {
            flags.insert(prev, "true".to_string());
        }
        Args { cmd, flags, positional }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    fn num(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse `--key` as an unsigned integer with a lower bound; missing →
    /// default.  Malformed or out-of-range values are hard errors — the
    /// CLI never silently corrects a flag (e.g. the old `--sites 0` clamp
    /// quietly ran a 1-site fleet).
    fn require_u64(&self, key: &str, default: u64, min: u64) -> Result<u64> {
        let Some(raw) = self.get(key) else { return Ok(default) };
        let value: u64 = match raw.parse() {
            Ok(v) => v,
            Err(_) => anyhow::bail!(
                "invalid value for --{key}: '{raw}' is not a non-negative integer"
            ),
        };
        anyhow::ensure!(value >= min, "--{key} {value} is out of range (must be >= {min})");
        Ok(value)
    }

    /// [`Self::require_u64`] for u32-typed config fields: values past
    /// u32::MAX are range errors, never silent truncations.
    fn require_u32(&self, key: &str, default: u32, min: u32) -> Result<u32> {
        let value = self.require_u64(key, default as u64, min as u64)?;
        anyhow::ensure!(
            value <= u32::MAX as u64,
            "--{key} {value} is out of range (must be <= {})",
            u32::MAX
        );
        Ok(value as u32)
    }

    /// Parse `--key` as a finite float within `[min, max]`; missing →
    /// default, malformed or out-of-range → hard error.
    fn require_f64(&self, key: &str, default: f64, min: f64, max: f64) -> Result<f64> {
        let Some(raw) = self.get(key) else { return Ok(default) };
        let value: f64 = match raw.parse() {
            Ok(v) => v,
            Err(_) => anyhow::bail!("invalid value for --{key}: '{raw}' is not a number"),
        };
        anyhow::ensure!(
            value.is_finite() && value >= min && value <= max,
            "--{key} {value} is out of range [{min}, {max}]"
        );
        Ok(value)
    }

    fn setup(&self) -> HardwareConfig {
        match self.get_or("setup", "1") {
            "2" => setup_no2(),
            _ => setup_no1(),
        }
    }
}

fn main() {
    let args = Args::parse();
    let result = match args.cmd.as_str() {
        "list-models" => cmd_list_models(),
        "profile" => cmd_profile(&args),
        "sweep" => cmd_sweep(&args),
        "figures" => cmd_figures(&args),
        "train" => cmd_train(&args),
        "overhead" => cmd_overhead(&args),
        "oran-demo" => cmd_oran_demo(&args),
        "fleet" => cmd_fleet(&args),
        "traffic" => cmd_traffic(&args),
        "scenario" => cmd_scenario(&args),
        "chaos" => cmd_chaos(&args),
        "resume" => cmd_resume(&args),
        "trace" => cmd_trace(&args),
        "bench" => cmd_bench(&args),
        "shift" => cmd_shift(&args),
        "dvfs-ablation" => cmd_dvfs_ablation(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
frost — energy-aware ML pipelines for O-RAN (paper reproduction)

USAGE: frost <command> [--flag value]...

COMMANDS:
  list-models                     show the 16-model zoo
  profile   --model NAME [--setup 1|2] [--exponent M] [--fine]
  sweep     --model NAME [--setup 1|2]      per-cap table (Fig. 4 style)
  figures   [--fig all|2|3|4|5|6] [--setup 1|2] [--out DIR] [--epochs N]
  train     --model NAME [--steps N] [--batch-seed S] [--cap FRAC]   (pjrt)
  overhead  [--samples N] [--reps R]        real Fig. 3 experiment   (pjrt)
  oran-demo [--model NAME] [--epochs N]     six-step AI/ML lifecycle
  fleet     [--sites N] [--seed S] [--rounds R] [--threads T]
            [--epochs N] [--samples N] [--infer-steps N]
            [--budget-frac F] [--max-profiles K] [--churn-every C]
            [--sample-retention N] [--regions N | --region-map L] [--smoke]
            [--out DIR] [--trace FILE] [--json FILE]
            [--checkpoint DIR [--every N] [--keep K] [--crash-at-round R]]
            multi-host fleet simulation; --regions N auto-partitions the
            fleet into a hierarchical region tier (§16), --region-map
            0,0,1,.. assigns sites explicitly, --smoke is a CI-sized run
  traffic   [--sites N] [--seed S] [--threads T] [--users N]
            [--req-per-user R] [--day-s S] [--slots N] [--max-batch B]
            [--arrivals poisson|bursty] [--diurnal typical|flat|W0,..,W23]
            [--exact-threshold N] [--path auto|exact|aggregate]
            [--budget-frac F] [--smoke] [--out DIR]
            seeded diurnal day, FROST vs stock caps + SLOs
  scenario  PRESET [--sites N] [--seed S] [--threads T] [--users N]
            [--slots N] [--budget-frac F] [--regions N | --region-map L]
            [--smoke] [--out DIR] [--trace FILE] [--json FILE]
            [--checkpoint DIR [--every N] [--keep K] [--crash-at-round R]]
            scripted operational day (PRESET: outage-day, grid-step,
            flash-crowd, heatwave) — deterministic event engine, FROST
            vs stock caps with per-phase energy/latency/attainment
  chaos     PRESET [--sites N] [--seed S] [--threads T]
            [--regions N | --region-map L] [--smoke] [--out DIR]
            [--trace FILE]
            [--checkpoint DIR [--every N] [--keep K] [--crash-at-round R]]
            fault-injected fleet day (PRESET: lossy-fabric, slow-fabric,
            liar-telemetry, profile-flaps) — seeded fabric/telemetry
            faults vs the §13 self-healing control plane; hard-fails if
            the budget is busted or the fleet does not heal
  resume    SNAPSHOT.frostsnap [--threads T] [--json FILE] [--trace FILE]
            [--out DIR] [--checkpoint DIR [--every N] [--crash-at-round R]]
            resume a crashed --checkpoint run from its snapshot: the
            fleet is restored bit-exactly and the run finished — report,
            --json and --trace outputs match the uninterrupted run byte
            for byte, under any --threads
  trace     FILE.jsonl [--site N] [--region N] [--round A..B] [--kind K]
            [--explain SITE] [--summary]
            query a recorded TRACE_*.jsonl: stream matching lines, roll
            up counts, or reconstruct a site's cap-change causal chain
  bench     [--traffic] [--target-s S] [--out FILE] [--force]
            hot-path benches -> BENCH_fleet.json / BENCH_traffic.json
  shift     [--budget-frac F]               site-level power shifting
  dvfs-ablation [--setup 1|2] [--exponent M]  capping vs DVFS per model

Commands marked (pjrt) execute real AOT artifacts and need a build with
--features pjrt plus real xla bindings (see DESIGN.md).
";

fn cmd_list_models() -> Result<()> {
    let gpu = setup_no1().gpu;
    println!(
        "{:<14} {:>12} {:>10} {:>6} {:>7} {:>6} {:>9}  artifact",
        "model", "params", "MFLOP/img", "beta", "i-beta", "eff", "ref acc"
    );
    for m in all_models() {
        let w = m.workload(&gpu);
        println!(
            "{:<14} {:>12} {:>10.1} {:>6.2} {:>7.2} {:>6.2} {:>8.2}%  {}",
            m.name,
            m.params,
            m.fwd_mflops,
            w.beta(&gpu),
            w.infer_beta(&gpu),
            m.kernel_efficiency,
            m.reference_accuracy * 100.0,
            m.artifact.unwrap_or("-"),
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let hw = args.setup();
    let entry = model_by_name(model).with_context(|| format!("unknown model '{model}'"))?;
    let w = entry.workload(&setup_no1().gpu);
    let mut config = if args.get("fine").is_some() {
        ProfilerConfig::fine_grained()
    } else {
        ProfilerConfig::default()
    };
    config.edp_exponent = args.num("exponent", 2.0);
    let mut tb = Testbed::new(hw.clone(), 42);
    let profiler = PowerProfiler::new(config);
    let out = profiler.profile(&mut tb, &w, 128);
    println!("model        : {}", out.model);
    println!("hardware     : {} ({})", hw.name, hw.gpu.name);
    println!("criterion    : {}", out.criterion);
    println!("fit rel. err : {:.2}% (good fit: {})", out.fit.rel_error * 100.0, out.fit.good_fit);
    println!("optimal cap  : {:.1}% of TDP ({:.0} W)", out.optimal_cap * 100.0, out.optimal_cap * hw.gpu.tdp_w);
    println!("est. saving  : {:.1}% energy", out.est_energy_saving * 100.0);
    println!("est. slowdown: {:+.1}% time", (out.est_slowdown - 1.0) * 100.0);
    println!("profiling cost: {}", out.profiling_energy);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let hw = args.setup();
    let series = figures::fig4_power_capping(&hw, &[model], 42);
    print!("{}", series.to_table());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let hw = args.setup();
    let which = args.get_or("fig", "all");
    let epochs = args.require_u32("epochs", 100, 1)?;
    let out_dir = args.get("out");
    let mut emitted: Vec<(String, String)> = Vec::new();

    if which == "all" || which.starts_with('2') {
        let out = figures::fig2_investigation(&hw, epochs, 42);
        print!("{}", out.table.to_table());
        println!("r(accuracy, energy) = {:.3}   [paper: 0.34]", out.r_accuracy_energy);
        println!("r(energy, time)     = {:.3}   [paper: 0.999]", out.r_energy_time);
        println!();
        emitted.push(("fig2.csv".into(), out.table.to_csv()));
    }
    if which == "all" || which == "3" {
        #[cfg(feature = "pjrt")]
        {
            let samples = args.require_u64("samples", 2560, 1)?;
            match figures::fig3_overhead(&hw, &["lenet", "mobilenet_mini"], samples, 1) {
                Ok(s) => {
                    print!("{}", s.to_table());
                    println!();
                    emitted.push(("fig3.csv".into(), s.to_csv()));
                }
                Err(e) => eprintln!("fig3 skipped ({e}); run `make artifacts` first"),
            }
        }
        #[cfg(not(feature = "pjrt"))]
        eprintln!("fig3 skipped (real PJRT inference; rebuild with --features pjrt)");
    }
    if which == "all" || which == "4" {
        let s = figures::fig4_power_capping(&hw, &["MobileNet", "DenseNet", "EfficientNet"], 42);
        print!("{}", s.to_table());
        println!();
        emitted.push(("fig4.csv".into(), s.to_csv()));
    }
    if which == "all" || which == "5" {
        let out = figures::fig5_fine_grained(&hw, "ResNet", 42);
        print!("{}", out.sweep.to_table());
        for (m, cap, saving, delay) in &out.optima {
            println!("ED{m}P optimum: cap {cap:.1}%  saving {saving:.1}%  delay {delay:+.1}%");
        }
        println!();
        emitted.push(("fig5.csv".into(), out.sweep.to_csv()));
    }
    if which == "all" || which == "6" {
        let out = figures::fig6_tradeoff(&hw, args.num("exponent", 2.0), 42);
        print!("{}", out.table.to_table());
        println!(
            "MEAN: saving {:.1}% at {:+.1}% time  [paper {}: {}]",
            out.mean_saving_pct,
            out.mean_delay_pct,
            hw.name,
            if hw.name == "setup_no1" { "26.4% @ +6.9%" } else { "17.7% @ +5.5%" }
        );
        println!();
        emitted.push(("fig6.csv".into(), out.table.to_csv()));
    }
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        for (name, csv) in &emitted {
            let path = std::path::Path::new(dir).join(name);
            std::fs::write(&path, csv)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "'train' executes real AOT artifacts through PJRT; rebuild with --features pjrt"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use frost::data::SyntheticCifar;
    use frost::pipeline::{calibrated_workload, HybridAccountant};
    use frost::power::{CpuPowerModel, DramPowerModel, GpuPowerModel};
    use frost::runtime::{Runtime, TrainSession};
    use frost::simulator::ExecutionModel;
    use frost::util::Joules;
    use frost::zoo::Manifest;

    let model = args.get_or("model", "lenet");
    let steps = args.require_u64("steps", 50, 1)?;
    let cap = args.num("cap", 1.0);
    let hw = args.setup();
    let manifest = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    println!("platform: {} ({} devices)", rt.platform(), rt.device_count());
    let mut session = TrainSession::new(&rt, &manifest, model)?;
    println!("loaded {model}: {} params, batch {}", session.model.param_count, session.batch);

    let m = manifest.model(model).unwrap();
    let w = calibrated_workload(m, &hw.gpu, None)?;
    let exec = ExecutionModel::new(
        GpuPowerModel::new(hw.gpu.clone()),
        CpuPowerModel::new(hw.cpu.clone()),
        DramPowerModel::new(hw.dimms.clone()),
    );
    let mut acct = HybridAccountant::new(
        exec,
        w,
        session.batch,
        hw.gpu.tdp_w,
        hw.gpu.min_cap_frac,
        42,
    );
    acct.set_cap_frac(cap);

    let mut ds = SyntheticCifar::new(args.require_u64("batch-seed", 0, 0)?);
    for i in 0..steps {
        let batch = ds.next_batch(session.batch as usize);
        let metrics = session.step(&batch)?;
        acct.on_train_step(metrics.wall_s);
        if i % 10 == 0 || i + 1 == steps {
            println!(
                "step {:>4}  loss {:.4}  acc {:.3}  wall {:.1} ms",
                i,
                metrics.loss,
                metrics.accuracy,
                metrics.wall_s * 1e3
            );
        }
    }
    let account = acct.finish(Joules(0.0));
    println!("---");
    println!("steps          : {steps}");
    println!("mean step time : {:.1} ms", session.mean_step_time().unwrap_or(0.0) * 1e3);
    println!("gross energy   : {} over {}", account.gross, account.duration);
    println!("net energy     : {} (Eq. 1, idle baseline subtracted)", account.net());
    println!("mean power     : {} (virtual {})", account.mean_power(), hw.gpu.name);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_overhead(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "'overhead' measures real PJRT inference; rebuild with --features pjrt"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_overhead(args: &Args) -> Result<()> {
    let hw = args.setup();
    let samples = args.require_u64("samples", 2560, 1)?;
    let reps = args.require_u32("reps", 1, 1)?;
    let s = figures::fig3_overhead(&hw, &["lenet", "mobilenet_mini"], samples, reps)?;
    print!("{}", s.to_table());
    Ok(())
}

fn cmd_shift(args: &Args) -> Result<()> {
    use frost::power::{allocate_budget, total_throughput, HostProfile};
    let frac = args.num("budget-frac", 0.6);
    let site = [
        (setup_no1(), "ResNet"),
        (setup_no1(), "DenseNet"),
        (setup_no2(), "MobileNetV2"),
        (setup_no2(), "VGG"),
    ];
    let mut profiles = Vec::new();
    for (i, (hw, model)) in site.iter().enumerate() {
        let w = model_by_name(model).unwrap().workload(&setup_no1().gpu);
        let mut tb = Testbed::new(hw.clone(), 7 + i as u64);
        let out = PowerProfiler::new(ProfilerConfig::default()).profile(&mut tb, &w, 128);
        profiles.push(HostProfile::from_profile(
            &format!("host{}({model})", i + 1),
            hw.gpu.tdp_w,
            &out.points,
        ));
    }
    let full: f64 = profiles.iter().map(|p| p.tdp_w).sum();
    let budget = full * frac;
    let allocs = allocate_budget(&profiles, budget, 5.0)
        .context("budget below the driver floors")?;
    println!("site TDP {full:.0} W, budget {budget:.0} W ({:.0}%)", frac * 100.0);
    for a in &allocs {
        println!(
            "  {:<22} cap {:>5.1}%  ({:>5.0} W)  {:>8.0} samples/s",
            a.host,
            a.cap_frac * 100.0,
            a.watts,
            a.throughput
        );
    }
    println!("total throughput: {:.0} samples/s", total_throughput(&allocs));
    Ok(())
}

fn cmd_dvfs_ablation(args: &Args) -> Result<()> {
    use frost::simulator::capping_vs_dvfs;
    let hw = args.setup();
    let exponent = args.num("exponent", 1.0);
    println!(
        "{:<14} {:>14} {:>12} {:>14} {:>12}",
        "model", "capping_save%", "dvfs_save%", "capping_time%", "dvfs_time%"
    );
    for entry in all_models() {
        let w = entry.workload(&setup_no1().gpu);
        let row = capping_vs_dvfs(&hw, &w, 128, exponent, 5);
        println!(
            "{:<14} {:>14.1} {:>12.1} {:>+14.1} {:>+12.1}",
            row.model,
            row.capping_saving * 100.0,
            row.dvfs_saving * 100.0,
            (row.capping_slowdown - 1.0) * 100.0,
            (row.dvfs_slowdown - 1.0) * 100.0
        );
    }
    println!("
[paper Sec. II-C: DVFS is finer-grained (>= savings) but device-specific;");
    println!(" capping captures most of the benefit portably — the numbers above quantify it]");
    Ok(())
}

/// Parse `--regions N` / `--region-map "0,0,1,1"` into a [`RegionMap`]
/// (DESIGN.md §16), shared by `frost fleet|scenario|chaos`.  `--regions
/// 0`, more regions than sites, and a site mapped past the region count
/// are hard errors, never clamps.
///
/// [`RegionMap`]: frost::oran::RegionMap
fn region_map(args: &Args, sites: usize) -> Result<Option<frost::oran::RegionMap>> {
    use frost::oran::{RegionMap, RegionSpec};
    let explicit_n = match args.get("regions") {
        Some(_) => Some(args.require_u64("regions", 1, 0)? as usize),
        None => None,
    };
    let Some(raw) = args.get("region-map") else {
        return Ok(match explicit_n {
            Some(n) => Some(RegionMap::auto(sites, n)?),
            None => None,
        });
    };
    anyhow::ensure!(
        raw != "true",
        "--region-map needs a comma-separated site->region list (e.g. 0,0,1,1)"
    );
    let mut site_region = Vec::with_capacity(sites);
    for p in raw.split(',') {
        let r: u32 = p.trim().parse().map_err(|_| {
            anyhow::anyhow!("invalid value for --region-map: '{p}' is not a region index")
        })?;
        site_region.push(r);
    }
    anyhow::ensure!(
        site_region.len() == sites,
        "--region-map assigns {} sites but the fleet has {sites}",
        site_region.len()
    );
    // Without --regions the region count is inferred from the map; with
    // it, out-of-range assignments fail validation below.
    let n = match explicit_n {
        Some(n) => n,
        None => site_region.iter().map(|&r| r as usize + 1).max().unwrap_or(1),
    };
    anyhow::ensure!(n >= 1, "a fleet needs at least one region");
    let regions = (0..n)
        .map(|r| RegionSpec { name: format!("region{:02}", r + 1), weight: 1.0 })
        .collect();
    let rm = RegionMap { regions, site_region };
    rm.validate(sites)?;
    Ok(Some(rm))
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use frost::oran::FleetConfig;
    let trace_path = args.get("trace");
    // --smoke: a CI-sized run (shorter training, fewer rounds) that still
    // exercises the full coordination stack, e.g. a 1000-site region tier.
    let smoke = args.get("smoke").is_some();
    let sites = args.require_u64("sites", 16, 1)? as usize;
    let config = FleetConfig {
        sites,
        seed: args.require_u64("seed", 7, 0)?,
        threads: args.require_u64("threads", 0, 0)? as usize,
        rounds: args.require_u32("rounds", if smoke { 4 } else { 8 }, 1)?,
        train_epochs: args.require_u32("epochs", if smoke { 8 } else { 60 }, 1)?,
        samples_per_epoch: args.require_u64("samples", if smoke { 2_000 } else { 20_000 }, 1)?,
        infer_steps_per_round: args.require_u64("infer-steps", if smoke { 8 } else { 40 }, 1)?,
        budget_frac: args.require_f64("budget-frac", 1.0, 1e-6, 10.0)?,
        max_concurrent_profiles: args.require_u64("max-profiles", 4, 1)? as usize,
        churn_every: args.require_u32("churn-every", 0, 0)?,
        sample_retention: args
            .require_u64("sample-retention", if smoke { 64 } else { 512 }, 0)?
            as usize,
        regions: region_map(args, sites)?,
        trace: trace_path.is_some(),
        ..FleetConfig::default()
    };
    let opts = ckpt_options(args)?;
    match figures::fleet_comparison_ckpt(&config, &opts)? {
        frost::ckpt::DriveOutcome::Crashed { round, snapshot } => {
            announce_crash(round, &snapshot);
            Ok(())
        }
        frost::ckpt::DriveOutcome::Done(out) => print_fleet_output(args, &out, sites),
    }
}

/// Print/export the `frost fleet` report.  Shared verbatim with
/// `frost resume`, so a resumed run's stdout, `--out`, `--trace` and
/// `--json` outputs are byte-identical to the uninterrupted run's.
fn print_fleet_output(args: &Args, out: &figures::FleetFigOutput, sites: usize) -> Result<()> {
    let trace_path = args.get("trace");
    print!("{}", out.table.to_table());
    println!();
    println!("=== fleet KPM/energy roll-up ===");
    println!("sites                : {sites} (mixed setup no.1/no.2, zoo workloads)");
    println!("mean applied cap     : {:.1}% of TDP", out.mean_cap_frac * 100.0);
    println!(
        "steady-state energy  : {:.1} kJ/round under FROST vs {:.1} kJ/round baseline",
        out.frost_round_j / 1e3,
        out.baseline_round_j / 1e3
    );
    println!(
        "fleet energy saving  : {:.1}% steady state  [paper band: 10-26%]",
        out.steady_saving_frac * 100.0
    );
    println!(
        "mean FROST estimate  : {:.1}% per profiled site",
        out.mean_est_saving_frac * 100.0
    );
    println!("profiling charge     : {:.1} kJ (Eqs. 4-5)", out.profiling_j / 1e3);
    println!("KPM reports ingested : {}", out.kpm_reports);
    for (host, energy_j, samples, gpu_w) in &out.frost.kpm_by_host {
        println!(
            "  KPM {host}: {:>8.1} kJ over {:>9} samples, last GPU {:>5.0} W",
            energy_j / 1e3,
            samples,
            gpu_w
        );
    }
    if let Some(budget) = out.frost.budget_w {
        if out.frost.budget_enforced {
            println!(
                "global GPU budget    : {:.0} W; enforced worst-case cap power {:.0} W",
                budget, out.frost.cap_power_w
            );
        } else {
            println!(
                "global GPU budget    : {:.0} W; NOT yet enforced (profiling stagger \
                 incomplete — raise --rounds); current cap power {:.0} W",
                budget, out.frost.cap_power_w
            );
        }
    }
    println!(
        "per-site accuracy    : {}",
        if out.accuracy_unchanged { "unchanged vs baseline on every site" } else { "CHANGED (unexpected)" }
    );
    if !out.frost.regions.is_empty() {
        println!();
        println!("=== region roll-up (§16) ===");
        for r in &out.frost.regions {
            let sub = match r.sub_budget_w {
                Some(w) => format!("{w:.0} W"),
                None => "-".into(),
            };
            println!(
                "  {:<10} sites {:>4} (up {:>4})  round {:>9.1} kJ  cap {:>7.0} W  \
                 sub-budget {:>8}  load {:>9.1}/s  steady {:>6} site-rounds",
                r.name,
                r.sites,
                r.up_sites,
                r.round_energy_j / 1e3,
                r.cap_power_w,
                sub,
                r.offered_load_per_s,
                r.steady_site_rounds
            );
        }
    }
    println!();
    println!("=== fleet metrics (name-ordered, §14 registry) ===");
    for (name, v) in out.frost.metrics.counters() {
        println!("  {name:<22} {v}");
    }
    for (name, v) in out.frost.metrics.gauges() {
        println!("  {name:<22} {v}");
    }
    for (name, s) in out.frost.metrics.summaries() {
        let st = s.finish();
        println!(
            "  {name:<22} mean {:.1} (min {:.1}, max {:.1}, n {})",
            st.mean, st.min, st.max, st.n
        );
    }
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join("fleet.csv");
        std::fs::write(&path, out.table.to_csv())?;
        println!("wrote {}", path.display());
        if !out.frost.regions.is_empty() {
            let path = std::path::Path::new(dir).join("fleet_regions.csv");
            std::fs::write(&path, out.region_table.to_csv())?;
            println!("wrote {}", path.display());
        }
    }
    if let Some(p) = trace_path {
        frost::obs::export::write_trace(std::path::Path::new(p), &out.trace)?;
        println!("wrote {p} ({} trace events)", out.trace.len());
    }
    if let Some(p) = args.get("json") {
        write_fleet_json(p, out)?;
        println!("wrote {p}");
    }
    Ok(())
}

/// Streamed `--json` report for `frost fleet` (no intermediate tree —
/// DESIGN.md §14).
fn write_fleet_json(path: &str, out: &figures::FleetFigOutput) -> Result<()> {
    use frost::obs::export::JsonStream;
    let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut s = JsonStream::new(std::io::BufWriter::new(file));
    s.begin_obj(None);
    s.str_field(Some("report"), "fleet");
    s.num_field(Some("steady_saving_frac"), out.steady_saving_frac);
    s.num_field(Some("mean_est_saving_frac"), out.mean_est_saving_frac);
    s.num_field(Some("baseline_round_j"), out.baseline_round_j);
    s.num_field(Some("frost_round_j"), out.frost_round_j);
    s.num_field(Some("profiling_j"), out.profiling_j);
    s.num_field(Some("mean_cap_frac"), out.mean_cap_frac);
    s.bool_field(Some("accuracy_unchanged"), out.accuracy_unchanged);
    s.u64_field(Some("kpm_reports"), out.kpm_reports as u64);
    s.begin_arr(Some("sites"));
    for site in &out.frost.sites {
        s.begin_obj(None);
        s.str_field(Some("name"), &site.name);
        s.str_field(Some("model"), &site.model);
        s.num_field(Some("cap_frac"), site.cap_frac);
        s.num_field(Some("round_energy_j"), site.round_energy_j);
        s.num_field(Some("est_saving"), site.est_saving);
        s.num_field(Some("accuracy"), site.accuracy);
        s.end_obj();
    }
    s.end_arr();
    if !out.frost.regions.is_empty() {
        s.begin_arr(Some("regions"));
        for r in &out.frost.regions {
            s.begin_obj(None);
            s.str_field(Some("name"), &r.name);
            s.u64_field(Some("sites"), r.sites as u64);
            s.u64_field(Some("up_sites"), r.up_sites as u64);
            s.num_field(Some("round_energy_j"), r.round_energy_j);
            s.num_field(Some("cap_power_w"), r.cap_power_w);
            if let Some(w) = r.sub_budget_w {
                s.num_field(Some("sub_budget_w"), w);
            }
            s.num_field(Some("offered_load_per_s"), r.offered_load_per_s);
            s.u64_field(Some("steady_site_rounds"), r.steady_site_rounds);
            s.end_obj();
        }
        s.end_arr();
    }
    write_metrics_json(&mut s, &out.frost.metrics);
    s.end_obj();
    s.finish().context("writing json report")?;
    Ok(())
}

/// Shared `"metrics": {...}` section of the `--json` reports.
fn write_metrics_json<W: std::io::Write>(
    s: &mut frost::obs::export::JsonStream<W>,
    m: &frost::obs::MetricsRegistry,
) {
    s.begin_obj(Some("metrics"));
    s.begin_obj(Some("counters"));
    for (name, v) in m.counters() {
        s.u64_field(Some(name), v);
    }
    s.end_obj();
    s.begin_obj(Some("gauges"));
    for (name, v) in m.gauges() {
        s.num_field(Some(name), v);
    }
    s.end_obj();
    s.begin_obj(Some("summaries"));
    for (name, sum) in m.summaries() {
        let st = sum.finish();
        s.begin_obj(Some(name));
        s.u64_field(Some("n"), st.n as u64);
        s.num_field(Some("mean"), st.mean);
        s.num_field(Some("std"), st.std);
        s.num_field(Some("min"), st.min);
        s.num_field(Some("max"), st.max);
        s.end_obj();
    }
    s.end_obj();
    s.end_obj();
}

/// The acceptance scenario of DESIGN.md §9: run the same seeded diurnal
/// day twice (FROST vs stock caps) and report fleet energy saving plus
/// p50/p95/p99 latency and SLO attainment per QoS class.
fn cmd_traffic(args: &Args) -> Result<()> {
    use frost::oran::FleetConfig;
    use frost::traffic::{ArrivalKind, DiurnalProfile, TrafficConfig, TrafficPath};
    let smoke = args.get("smoke").is_some();
    let base = if smoke { TrafficConfig::smoke() } else { TrafficConfig::default() };
    let tr = TrafficConfig {
        users_per_site: args.require_u64("users", base.users_per_site, 1)?,
        requests_per_user_per_day: args.require_f64(
            "req-per-user",
            base.requests_per_user_per_day,
            1e-6,
            1e9,
        )?,
        day_s: args.require_f64("day-s", base.day_s, 1.0, 1e9)?,
        slots_per_day: args.require_u32("slots", base.slots_per_day, 2)?,
        max_batch: args.require_u32("max-batch", base.max_batch, 1)?,
        kind: match args.get_or("arrivals", "poisson") {
            "poisson" => ArrivalKind::Poisson,
            "bursty" => ArrivalKind::bursty(),
            other => anyhow::bail!(
                "invalid value for --arrivals: '{other}' (expected poisson or bursty)"
            ),
        },
        diurnal: match args.get_or("diurnal", "typical") {
            "typical" => DiurnalProfile::typical(),
            "flat" => DiurnalProfile::flat(),
            // 24 comma-separated hourly weights; a zero or non-finite
            // weight is a hard error from try_normalised, never a clamp.
            raw => {
                let parts: Vec<&str> = raw.split(',').collect();
                anyhow::ensure!(
                    parts.len() == 24,
                    "invalid value for --diurnal: expected typical, flat, or 24 \
                     comma-separated hourly weights (got {} values)",
                    parts.len()
                );
                let mut weights = [0.0f64; 24];
                for (h, p) in parts.iter().enumerate() {
                    weights[h] = p.trim().parse().map_err(|_| {
                        anyhow::anyhow!("invalid value for --diurnal: '{p}' is not a number")
                    })?;
                }
                DiurnalProfile::try_normalised(weights)
                    .context("invalid value for --diurnal")?
            }
        },
        exact_request_threshold: args.require_u64(
            "exact-threshold",
            base.exact_request_threshold,
            1,
        )?,
        path: match args.get_or("path", "auto") {
            "auto" => TrafficPath::Auto,
            "exact" => TrafficPath::ForceExact,
            "aggregate" => TrafficPath::ForceAggregate,
            other => anyhow::bail!(
                "invalid value for --path: '{other}' (expected auto, exact, or aggregate)"
            ),
        },
        ..base
    };
    // The smoke fleet still needs 3 sites so every QoS class (the i % 3
    // rotation) — including latency_critical — is exercised end to end.
    let sites = args.require_u64("sites", if smoke { 3 } else { 16 }, 1)? as usize;
    let config = FleetConfig {
        sites,
        seed: args.require_u64("seed", 7, 0)?,
        threads: args.require_u64("threads", 0, 0)? as usize,
        rounds: tr.rounds_for_one_day(),
        train_epochs: args.require_u32("epochs", if smoke { 30 } else { 60 }, 1)?,
        samples_per_epoch: if smoke { 5_000 } else { 20_000 },
        budget_frac: args.require_f64("budget-frac", 1.0, 1e-6, 10.0)?,
        // Wide stagger: every site is profiled before the day starts.
        max_concurrent_profiles: sites,
        traffic: Some(tr.clone()),
        ..FleetConfig::default()
    };
    let out = figures::traffic_comparison(&config)?;
    print!("{}", out.class_table.to_table());
    println!();
    print!("{}", out.slot_table.to_table());
    println!();
    print!("{}", out.site_table.to_table());
    println!();
    println!("=== traffic day roll-up ===");
    let kind = if tr.kind == ArrivalKind::Poisson { "poisson" } else { "bursty" };
    println!(
        "sites                : {sites}; {} slots of {:.0} s ({kind} arrivals, \
         {} users/site mean)",
        tr.slots_per_day,
        tr.slot_s(),
        tr.users_per_site
    );
    let aggregated_sites = (0..sites).filter(|&i| tr.aggregate_for_site(i)).count();
    println!(
        "serving path         : {} of {sites} sites aggregated (threshold {} req/slot, \
         path {:?})",
        aggregated_sites, tr.exact_request_threshold, tr.path
    );
    println!(
        "fleet day energy     : {:.1} kJ under FROST vs {:.1} kJ stock caps",
        out.frost_day_energy_j / 1e3,
        out.base_day_energy_j / 1e3
    );
    println!(
        "traffic-day saving   : {:.1}%  (off-peak {:.1}%, peak {:.1}%)",
        out.day_saving_frac * 100.0,
        out.offpeak_saving_frac * 100.0,
        out.peak_saving_frac * 100.0
    );
    println!(
        "profiling charge     : {:.1} kJ; monitor re-profiles: {} ({} demand-shift)",
        out.frost.fleet_profiling_energy_j / 1e3,
        out.reprofile_requests,
        out.load_shift_reprofiles
    );
    // The SMO-side view of the serving tail (KPM `p99_latency_s`): the
    // worst host p99 a latency-aware rApp would react to.
    if let Some((host, p99)) = out
        .frost
        .kpm_p99_by_host
        .iter()
        .max_by(|a, b| a.1.total_cmp(&b.1))
    {
        println!("worst host p99 (KPM) : {:.1} ms at {host}", p99 * 1e3);
    }
    for s in &out.frost_slo {
        println!(
            "SLO {:<16} : p50 {:>7.1} ms  p95 {:>7.1} ms  p99 {:>7.1} ms  \
             (deadline {:>6.0} ms)  attainment {:>6.2}%  dropped {}  late {}",
            s.qos.as_str(),
            s.p50_s * 1e3,
            s.p95_s * 1e3,
            s.p99_s * 1e3,
            s.deadline_s * 1e3,
            s.attainment * 100.0,
            s.dropped,
            s.late
        );
    }
    if let Some(budget) = out.frost.budget_w {
        println!(
            "global GPU budget    : {:.0} W (load-weighted water-fill){}",
            budget,
            if out.frost.budget_enforced { "" } else { " — NOT yet enforced" }
        );
    }
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        for (name, csv) in [
            ("traffic_slo.csv", out.class_table.to_csv()),
            ("traffic_slots.csv", out.slot_table.to_csv()),
            ("traffic_sites.csv", out.site_table.to_csv()),
        ] {
            let path = std::path::Path::new(dir).join(name);
            std::fs::write(&path, csv)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

/// The scripted operational day of DESIGN.md §11: run a deterministic
/// event preset (site outage, grid budget step, flash crowd, thermal
/// derating) over the same seeded diurnal day with FROST on and off, and
/// report per-phase energy/latency/attainment plus the per-event ledger
/// and the budget conservation audit.
fn cmd_scenario(args: &Args) -> Result<()> {
    use frost::oran::FleetConfig;
    use frost::scenario::{Scenario, PRESETS};
    use frost::traffic::TrafficConfig;
    let smoke = args.get("smoke").is_some();
    // The preset is required (positionally or via --preset): defaulting
    // would silently run the wrong scenario when a boolean flag eats the
    // positional (`frost scenario --smoke flash-crowd` parses the name as
    // the flag's value).
    let Some(preset) = args.get("preset").or_else(|| args.pos(0)) else {
        anyhow::bail!(
            "missing scenario preset: frost scenario PRESET (one of: {})",
            PRESETS.join(", ")
        );
    };
    anyhow::ensure!(
        PRESETS.contains(&preset),
        "unknown scenario preset '{preset}' (expected one of: {})",
        PRESETS.join(", ")
    );
    let base = if smoke { TrafficConfig::smoke() } else { TrafficConfig::default() };
    let tr = TrafficConfig {
        users_per_site: args.require_u64("users", base.users_per_site, 1)?,
        slots_per_day: args.require_u32("slots", base.slots_per_day, 3)?,
        max_batch: args.require_u32("max-batch", base.max_batch, 1)?,
        ..base
    };
    // 4+ sites so every QoS class is present and an outage has regional
    // survivors to absorb its users.
    let sites = args.require_u64("sites", if smoke { 4 } else { 8 }, 1)? as usize;
    let scen = Scenario::preset(preset, sites, &tr).context("building scenario preset")?;
    // grid-step scripts budget steps, so its runs enforce a budget by
    // default; the other presets run unbudgeted unless asked.
    let default_budget = if preset == "grid-step" { 0.9 } else { 1.0 };
    let trace_path = args.get("trace");
    let config = FleetConfig {
        sites,
        seed: args.require_u64("seed", 7, 0)?,
        threads: args.require_u64("threads", 0, 0)? as usize,
        rounds: tr.rounds_for_one_day(),
        train_epochs: args.require_u32("epochs", if smoke { 30 } else { 60 }, 1)?,
        samples_per_epoch: if smoke { 5_000 } else { 20_000 },
        budget_frac: args.require_f64("budget-frac", default_budget, 1e-6, 10.0)?,
        // Wide stagger: every site is profiled before the day starts.
        max_concurrent_profiles: sites,
        traffic: Some(tr.clone()),
        scenario: Some(scen.clone()),
        regions: region_map(args, sites)?,
        trace: trace_path.is_some(),
        ..FleetConfig::default()
    };
    let opts = ckpt_options(args)?;
    match figures::scenario_comparison_ckpt(&config, &opts)? {
        frost::ckpt::DriveOutcome::Crashed { round, snapshot } => {
            announce_crash(round, &snapshot);
            Ok(())
        }
        frost::ckpt::DriveOutcome::Done(out) => {
            print_scenario_output(args, &out, &tr, &scen.name, sites)
        }
    }
}

/// Print/export the `frost scenario` report.  Shared verbatim with
/// `frost resume`, so a resumed run's stdout, `--out`, `--trace` and
/// `--json` outputs are byte-identical to the uninterrupted run's.
fn print_scenario_output(
    args: &Args,
    out: &figures::ScenarioFigOutput,
    tr: &frost::traffic::TrafficConfig,
    scen_name: &str,
    sites: usize,
) -> Result<()> {
    let trace_path = args.get("trace");
    println!("=== scenario '{scen_name}' event ledger ===");
    for ev in &out.event_log {
        println!(
            "  round {:>3} (slot {:>2}): {}",
            ev.round,
            ev.round.saturating_sub(tr.warmup_rounds + 1),
            ev.detail
        );
    }
    println!();
    print!("{}", out.phase_table.to_table());
    println!();
    print!("{}", out.class_table.to_table());
    println!();
    println!("=== scripted-day roll-up ===");
    println!(
        "sites                : {sites}; {} slots of {:.0} s; {} users/site mean",
        tr.slots_per_day,
        tr.slot_s(),
        tr.users_per_site
    );
    println!(
        "fleet day energy     : {:.1} kJ under FROST vs {:.1} kJ stock caps \
         ({:.1}% saving)",
        out.frost_day_energy_j / 1e3,
        out.base_day_energy_j / 1e3,
        out.day_saving_frac * 100.0
    );
    for p in &out.phases {
        println!(
            "phase {:<14} : saving {:>5.1}%  lc p99 {:>7.1} ms  attainment {:>6.2}%{}",
            p.name,
            p.saving_frac * 100.0,
            p.frost_lc_p99_s * 1e3,
            p.frost_attainment * 100.0,
            if p.outage { "  [outage window]" } else { "" }
        );
    }
    if out.budget_audited_rounds > 0 {
        println!(
            "budget conservation  : {} rounds audited, max cap excess {:+.1} W — {}",
            out.budget_audited_rounds,
            out.max_cap_excess_w,
            if out.max_cap_excess_w <= 1e-6 {
                "never exceeded the scripted budget"
            } else {
                "EXCEEDED (unexpected)"
            }
        );
    }
    if out.region_audited_rounds > 0 {
        println!(
            "region tier audit    : {} rounds audited, max Σ-sub-budget excess {:+.1} W, \
             max region cap excess {:+.1} W — {}",
            out.region_audited_rounds,
            out.max_subbudget_excess_w,
            out.max_region_excess_w,
            if out.max_subbudget_excess_w <= 1e-6 && out.max_region_excess_w <= 1e-6 {
                "both levels conserved"
            } else {
                "EXCEEDED (unexpected)"
            }
        );
    }
    let lc_deadline = tr.slo.deadline_for(frost::frost::QosClass::LatencyCritical);
    let lc_ok = out
        .phases
        .iter()
        .filter(|p| !p.outage && p.offered > 0)
        .all(|p| p.frost_lc_p99_s <= lc_deadline);
    println!(
        "latency_critical gate: p99 {} {:.0} ms deadline in every non-outage phase",
        if lc_ok { "within" } else { "PAST" },
        lc_deadline * 1e3
    );
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        for (name, csv) in [
            ("scenario_phases.csv", out.phase_table.to_csv()),
            ("scenario_slo.csv", out.class_table.to_csv()),
        ] {
            let path = std::path::Path::new(dir).join(name);
            std::fs::write(&path, csv)?;
            println!("wrote {}", path.display());
        }
    }
    if let Some(p) = trace_path {
        frost::obs::export::write_trace(std::path::Path::new(p), &out.trace)?;
        println!("wrote {p} ({} trace events)", out.trace.len());
    }
    if let Some(p) = args.get("json") {
        write_scenario_json(p, out)?;
        println!("wrote {p}");
    }
    Ok(())
}

/// Streamed `--json` report for `frost scenario` (DESIGN.md §14).
fn write_scenario_json(path: &str, out: &figures::ScenarioFigOutput) -> Result<()> {
    use frost::obs::export::JsonStream;
    let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut s = JsonStream::new(std::io::BufWriter::new(file));
    s.begin_obj(None);
    s.str_field(Some("report"), "scenario");
    s.num_field(Some("frost_day_energy_j"), out.frost_day_energy_j);
    s.num_field(Some("base_day_energy_j"), out.base_day_energy_j);
    s.num_field(Some("day_saving_frac"), out.day_saving_frac);
    s.num_field(Some("max_cap_excess_w"), out.max_cap_excess_w);
    s.u64_field(Some("budget_audited_rounds"), out.budget_audited_rounds as u64);
    s.u64_field(Some("region_audited_rounds"), out.region_audited_rounds as u64);
    s.num_field(Some("max_subbudget_excess_w"), out.max_subbudget_excess_w);
    s.num_field(Some("max_region_excess_w"), out.max_region_excess_w);
    s.begin_arr(Some("events"));
    for ev in &out.event_log {
        s.begin_obj(None);
        s.u64_field(Some("round"), u64::from(ev.round));
        s.str_field(Some("detail"), &ev.detail);
        s.end_obj();
    }
    s.end_arr();
    s.begin_arr(Some("phases"));
    for p in &out.phases {
        s.begin_obj(None);
        s.str_field(Some("name"), &p.name);
        s.bool_field(Some("outage"), p.outage);
        s.u64_field(Some("offered"), p.offered);
        s.u64_field(Some("served"), p.served);
        s.u64_field(Some("dropped"), p.dropped);
        s.u64_field(Some("late"), p.late);
        s.num_field(Some("frost_energy_j"), p.frost_energy_j);
        s.num_field(Some("base_energy_j"), p.base_energy_j);
        s.num_field(Some("saving_frac"), p.saving_frac);
        s.num_field(Some("frost_lc_p99_s"), p.frost_lc_p99_s);
        s.num_field(Some("frost_attainment"), p.frost_attainment);
        s.end_obj();
    }
    s.end_arr();
    write_metrics_json(&mut s, &out.frost.metrics);
    s.end_obj();
    s.finish().context("writing json report")?;
    Ok(())
}

/// A fault-injected fleet day (DESIGN.md §13): run one chaos preset over
/// a seeded, traffic-driven fleet with every resilience knob on —
/// policy leases, profile retry/quarantine, bounded hold-back — and
/// audit the budget conservation invariant round by round.  Exits
/// non-zero if any audited round busted the budget or the fleet did not
/// heal over the quiet tail, so a CI smoke run is a real gate.
fn cmd_chaos(args: &Args) -> Result<()> {
    use frost::oran::CHAOS_PRESETS;
    let smoke = args.get("smoke").is_some();
    // Required positionally (or via --preset) for the same reason as
    // `frost scenario`: defaulting would silently run the wrong preset
    // when a boolean flag eats the positional name.
    let Some(preset) = args.get("preset").or_else(|| args.pos(0)) else {
        anyhow::bail!(
            "missing chaos preset: frost chaos PRESET (one of: {})",
            CHAOS_PRESETS.join(", ")
        );
    };
    anyhow::ensure!(
        CHAOS_PRESETS.contains(&preset),
        "unknown chaos preset '{preset}' (expected one of: {})",
        CHAOS_PRESETS.join(", ")
    );
    let sites = args.require_u64("sites", if smoke { 4 } else { 6 }, 1)? as usize;
    let seed = args.require_u64("seed", 11, 0)?;
    let trace_path = args.get("trace");
    let mut config = figures::chaos_config(preset, sites, seed, smoke)?;
    config.threads = args.require_u64("threads", 0, 0)? as usize;
    config.regions = region_map(args, sites)?;
    config.trace = trace_path.is_some();
    let faults = config.faults.clone().expect("chaos_config always sets a plan");
    let opts = ckpt_options(args)?;
    match figures::chaos_run_ckpt(&config, preset, &opts)? {
        frost::ckpt::DriveOutcome::Crashed { round, snapshot } => {
            announce_crash(round, &snapshot);
            Ok(())
        }
        frost::ckpt::DriveOutcome::Done(out) => {
            print_chaos_output(args, &out, preset, &faults, sites, seed, config.rounds)
        }
    }
}

/// Print/export the `frost chaos` report and apply its CI gates (budget
/// conservation, self-healing).  Shared verbatim with `frost resume`, so
/// a resumed run's output and exit status match the uninterrupted run's.
fn print_chaos_output(
    args: &Args,
    out: &figures::ChaosFigOutput,
    preset: &str,
    faults: &frost::oran::FaultConfig,
    sites: usize,
    seed: u64,
    rounds: u32,
) -> Result<()> {
    let trace_path = args.get("trace");
    println!(
        "=== chaos '{preset}': {sites} sites, seed {seed}, faults in rounds {}..={} of {} ===",
        faults.start_round, faults.end_round, rounds
    );
    print!("{}", out.round_table.to_table());
    println!();
    let l = &out.ledger;
    println!(
        "fault ledger         : {} dropped, {} delayed (+{} overflowed, {} released), \
         {} duplicated, {} reordered",
        l.dropped, l.delayed, l.delay_dropped, l.released, l.duplicated, l.reordered
    );
    println!(
        "telemetry corruption : {} NaN, {} stale, {} NVML-fail; SMO rejected {} KPMs",
        l.corrupted_nan, l.corrupted_stale, l.corrupted_nvml, out.report.kpm_rejected
    );
    println!(
        "control plane        : {} lease renewals, {} lease expiries, {} quarantines, \
         {} hold-back drops",
        out.report.lease_renewals,
        out.report.lease_expiries,
        out.report.quarantine_events,
        out.report.holdback_dropped
    );
    println!(
        "budget conservation  : {} rounds audited, max cap excess {:+.1} W — {}",
        out.budget_audited_rounds,
        out.max_cap_excess_w,
        if out.max_cap_excess_w <= 1e-6 {
            "never exceeded the in-force budget"
        } else {
            "EXCEEDED (unexpected)"
        }
    );
    if out.region_audited_rounds > 0 {
        println!(
            "region tier audit    : {} rounds audited, max Σ-sub-budget excess {:+.1} W, \
             max region cap excess {:+.1} W — {}",
            out.region_audited_rounds,
            out.max_subbudget_excess_w,
            out.max_region_excess_w,
            if out.max_subbudget_excess_w <= 1e-6 && out.max_region_excess_w <= 1e-6 {
                "both levels conserved"
            } else {
                "EXCEEDED (unexpected)"
            }
        );
    }
    println!(
        "self-healing         : last degraded round {}, fault window closed at {} — {}",
        out.last_unhealthy_round,
        faults.end_round,
        if out.healed { "fully healed" } else { "NOT HEALED" }
    );
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join("chaos_rounds.csv");
        std::fs::write(&path, out.round_table.to_csv())?;
        println!("wrote {}", path.display());
    }
    if let Some(p) = trace_path {
        frost::obs::export::write_trace(std::path::Path::new(p), &out.trace)?;
        println!("wrote {p} ({} trace events)", out.trace.len());
    }
    anyhow::ensure!(
        out.max_cap_excess_w <= 1e-6,
        "budget conservation violated: max cap excess {:+.3} W",
        out.max_cap_excess_w
    );
    anyhow::ensure!(
        out.max_subbudget_excess_w <= 1e-6 && out.max_region_excess_w <= 1e-6,
        "region-tier conservation violated: Σ-sub-budget excess {:+.3} W, \
         region cap excess {:+.3} W",
        out.max_subbudget_excess_w,
        out.max_region_excess_w
    );
    anyhow::ensure!(out.healed, "fleet did not heal over the quiet tail");
    Ok(())
}

/// Parse `--checkpoint DIR [--every N] [--keep K] [--crash-at-round R]`
/// into [`frost::ckpt::CkptOptions`].  The cadence/retention/crash flags
/// are hard errors without `--checkpoint` — silently ignoring them would
/// turn a typo into a run with no snapshots.  Rounds are 1-based (round
/// 0 is the pre-run state; re-running from config covers it).
fn ckpt_options(args: &Args) -> Result<frost::ckpt::CkptOptions> {
    let mut opts = frost::ckpt::CkptOptions::disabled();
    if let Some(dir) = args.get("checkpoint") {
        // A bare `--checkpoint` (the boolean-flag parse) has no directory.
        anyhow::ensure!(
            dir != "true",
            "--checkpoint needs a directory argument \
             (use ./true for a directory literally named 'true')"
        );
        opts.dir = Some(std::path::PathBuf::from(dir));
    }
    opts.every = args.require_u32("every", 1, 1)?;
    opts.keep = args.require_u64("keep", frost::ckpt::DEFAULT_KEEP as u64, 1)? as usize;
    if args.get("crash-at-round").is_some() {
        opts.crash_at = Some(args.require_u32("crash-at-round", 1, 1)?);
    }
    if !opts.enabled() {
        anyhow::ensure!(
            args.get("every").is_none()
                && args.get("keep").is_none()
                && args.get("crash-at-round").is_none(),
            "--every/--keep/--crash-at-round require --checkpoint DIR"
        );
    }
    Ok(opts)
}

/// Report an injected crash (`--crash-at-round`): the run stopped dead
/// right after the round's snapshot became durable; nothing after the
/// crash point (baseline leg, reports, exports) has run.
fn announce_crash(round: u32, snapshot: &std::path::Path) {
    println!("crash injected at round {round}; snapshot durable at {}", snapshot.display());
    println!("resume with: frost resume {}", snapshot.display());
}

/// Resume a crashed `frost fleet|scenario|chaos --checkpoint` run from a
/// snapshot file, dispatching on the snapshot's `kind` header.  The
/// fleet is restored bit-exactly (optionally under a different
/// `--threads`) and run to completion; output flags behave exactly as on
/// the original command and produce byte-identical reports.
fn cmd_resume(args: &Args) -> Result<()> {
    use frost::ckpt::{DriveOutcome, Snapshot};
    let Some(path) = args.get("file").or_else(|| args.pos(0)) else {
        anyhow::bail!(
            "missing snapshot: frost resume SNAPSHOT.{} [--threads T] \
             [--checkpoint DIR [--every N] [--keep K] [--crash-at-round R]] \
             [--out DIR] [--trace FILE] [--json FILE]",
            frost::ckpt::SNAP_EXT
        );
    };
    let opts = ckpt_options(args)?;
    let threads = if args.get("threads").is_some() {
        Some(args.require_u64("threads", 0, 0)? as usize)
    } else {
        None
    };
    let snap = Snapshot::load(std::path::Path::new(path))?;
    let config = frost::ckpt::snapshot_config(&snap)?;
    // Stderr so stdout stays byte-comparable to the uninterrupted run.
    eprintln!(
        "resuming {} run from round {} of {} ({} sites, seed {})",
        snap.header.kind, snap.header.round, config.rounds, config.sites, config.seed
    );
    match snap.header.kind.as_str() {
        "fleet" => match figures::fleet_resume(&snap, threads, &opts)? {
            DriveOutcome::Crashed { round, snapshot } => {
                announce_crash(round, &snapshot);
                Ok(())
            }
            DriveOutcome::Done(out) => print_fleet_output(args, &out, config.sites),
        },
        "scenario" => {
            let tr = config.traffic.clone().context("scenario snapshot has no traffic config")?;
            let scen_name = config
                .scenario
                .as_ref()
                .map(|s| s.name.clone())
                .context("scenario snapshot has no scenario script")?;
            match figures::scenario_resume(&snap, threads, &opts)? {
                DriveOutcome::Crashed { round, snapshot } => {
                    announce_crash(round, &snapshot);
                    Ok(())
                }
                DriveOutcome::Done(out) => {
                    print_scenario_output(args, &out, &tr, &scen_name, config.sites)
                }
            }
        }
        "chaos" => {
            let faults = config.faults.clone().context("chaos snapshot has no fault plan")?;
            match figures::chaos_resume(&snap, threads, &opts)? {
                DriveOutcome::Crashed { round, snapshot } => {
                    announce_crash(round, &snapshot);
                    Ok(())
                }
                DriveOutcome::Done(out) => print_chaos_output(
                    args,
                    &out,
                    &snap.header.preset,
                    &faults,
                    config.sites,
                    config.seed,
                    config.rounds,
                ),
            }
        }
        other => anyhow::bail!(
            "snapshot kind '{other}' is not resumable (expected fleet, scenario, or chaos)"
        ),
    }
}

/// Query a recorded `TRACE_*.jsonl` (DESIGN.md §14): stream matching
/// lines (`--site`, `--round A..B`, `--kind`), roll up event counts
/// (`--summary`), or reconstruct the causal chain behind every cap
/// change at one site (`--explain SITE`).  Scanning is lazy — a cheap
/// substring prefilter decides which lines are parsed at all.
fn cmd_trace(args: &Args) -> Result<()> {
    use frost::obs::query::{self, TraceFilter};
    let Some(path) = args.get("file").or_else(|| args.pos(0)) else {
        anyhow::bail!(
            "missing trace file: frost trace FILE.jsonl \
             [--site N] [--region N] [--round A..B] [--kind K] [--explain SITE] [--summary]"
        );
    };
    let path = std::path::Path::new(path);
    if args.get("summary").is_some() {
        print!("{}", query::summarise(path)?);
        return Ok(());
    }
    if let Some(raw) = args.get("explain") {
        let site: i64 = raw.parse().map_err(|_| {
            anyhow::anyhow!("invalid value for --explain: '{raw}' is not a site index")
        })?;
        print!("{}", query::explain_report(path, site)?);
        return Ok(());
    }
    let mut filter = TraceFilter::default();
    if let Some(raw) = args.get("site") {
        filter.site = Some(raw.parse().map_err(|_| {
            anyhow::anyhow!("invalid value for --site: '{raw}' is not a site index")
        })?);
    }
    if let Some(raw) = args.get("region") {
        filter.region = Some(raw.parse().map_err(|_| {
            anyhow::anyhow!("invalid value for --region: '{raw}' is not a region index")
        })?);
    }
    if let Some(raw) = args.get("round") {
        filter.round = Some(query::parse_round_range(raw)?);
    }
    if let Some(kind) = args.get("kind") {
        filter.kind = Some(kind.to_string());
    }
    let (scanned, matched) = query::scan(path, &filter, |line, _| println!("{line}"))?;
    eprintln!("{matched} of {scanned} events matched");
    Ok(())
}

/// Hot-path benches from the CLI: the fleet suite by default, the
/// traffic suite with `--traffic` (the same definitions as
/// `cargo bench --bench fleet` / `--bench traffic` — one definition
/// each, `oran::run_bench_suite` and `traffic::run_traffic_bench_suite`,
/// so the recorders cannot drift; DESIGN.md §8/§10), recorded to a
/// `BENCH_fleet.json` / `BENCH_traffic.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    use frost::oran::run_bench_suite;
    use frost::traffic::run_traffic_bench_suite;
    use frost::util::bench::{write_json, BenchStats};
    let traffic = args.get("traffic").is_some();
    let target = args.num("target-s", 2.0);
    let default_out = if traffic { "BENCH_traffic.json" } else { "BENCH_fleet.json" };
    let out = args.get_or("out", default_out);
    // Refuse to clobber the curated perf-trajectory record (the checked-in
    // root BENCH_fleet.json wraps baseline+optimized result sets) unless
    // explicitly forced; raw runs should land elsewhere (e.g. rust/, which
    // is gitignored).
    if args.get("force").is_none() {
        if let Ok(existing) = std::fs::read_to_string(out) {
            if existing.contains("frost-bench-v1+trajectory") {
                anyhow::bail!(
                    "{out} holds a curated trajectory record; \
                     pass --out FILE or --force to overwrite"
                );
            }
        }
    }
    let (suite, results) = if traffic {
        ("traffic", run_traffic_bench_suite(target)?)
    } else {
        ("fleet", run_bench_suite(target)?)
    };
    let refs: Vec<(&str, BenchStats)> =
        results.iter().map(|(n, s)| (n.as_str(), *s)).collect();
    write_json(out, suite, &refs)?;
    Ok(())
}

fn cmd_oran_demo(args: &Args) -> Result<()> {
    let model = args.get_or("model", "ResNet");
    let epochs = args.require_u32("epochs", 60, 1)?;
    let entry = model_by_name(model).with_context(|| format!("unknown model '{model}'"))?;
    let w = entry.workload(&setup_no1().gpu);
    let mut lc = MlLifecycle::new(vec![setup_no1(), setup_no2()], 0.80, 42);
    println!("O-RAN deployment: SMO + non-RT RIC + near-RT RIC + 2 hosts");
    let stages = lc.run_workflow(
        model,
        w,
        "host1",
        EnergyPolicy::default_policy(),
        epochs,
        50_000,
    )?;
    for (i, s) in stages.iter().enumerate() {
        println!("  step {}: {:?}", i + 1, s);
    }
    let cap = lc.nonrt.catalogue.get(model).unwrap().optimal_cap.unwrap();
    println!("FROST decision: cap {:.1}% of TDP", cap * 100.0);
    println!("KPM reports collected: {}", lc.smo.kpms.len());
    println!("fabric traffic: {:?}", lc.bus.stats());
    println!(
        "mean energy saving across decisions: {:.1}%",
        lc.smo.mean_energy_saving() * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(line: &[&str]) -> Args {
        Args::parse_from(line.iter().map(|s| s.to_string()))
    }

    #[test]
    fn out_of_range_flags_error_instead_of_clamping() {
        // `fleet --sites 0` used to run a silently clamped 1-site fleet.
        let a = args(&["fleet", "--sites", "0"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("--sites 0"), "got: {err}");
        assert!(err.contains("must be >= 1"), "got: {err}");
        let a = args(&["traffic", "--slots", "1"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("--slots 1"), "got: {err}");
        let a = args(&["fleet", "--budget-frac", "-0.5"]);
        assert!(cmd_fleet(&a).is_err());
    }

    #[test]
    fn malformed_numbers_error_clearly() {
        let a = args(&["fleet", "--sites", "many"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("invalid value for --sites"), "got: {err}");
        assert!(err.contains("'many'"), "got: {err}");
        let a = args(&["traffic", "--day-s", "1h"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("invalid value for --day-s"), "got: {err}");
        let a = args(&["traffic", "--arrivals", "lumpy"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("--arrivals"), "got: {err}");
        // NaN is out of range, not a silent pass-through.
        let a = args(&["fleet", "--budget-frac", "NaN"]);
        assert!(cmd_fleet(&a).is_err());
        // Values past u32::MAX error instead of silently wrapping (the
        // old `as u32` cast turned --rounds 4294967297 into 1 round).
        let a = args(&["fleet", "--rounds", "4294967297"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("out of range"), "got: {err}");
    }

    #[test]
    fn degenerate_diurnal_profile_is_a_hard_cli_error() {
        // A zero-peak profile would make the arrival thinning envelope
        // degenerate; the CLI must reject it, never clamp it runnable.
        let zeros = vec!["0"; 24].join(",");
        let a = args(&["traffic", "--diurnal", &zeros]);
        let err = format!("{:#}", cmd_traffic(&a).unwrap_err());
        assert!(err.contains("--diurnal"), "got: {err}");
        assert!(err.contains("positive and finite"), "got: {err}");
        // Non-finite and malformed weights error too.
        let mut weights: Vec<String> = (1..=24).map(|i| i.to_string()).collect();
        weights[5] = "inf".into();
        let a = args(&["traffic", "--diurnal", &weights.join(",")]);
        assert!(cmd_traffic(&a).is_err());
        weights[5] = "six".into();
        let a = args(&["traffic", "--diurnal", &weights.join(",")]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("'six'"), "got: {err}");
        // Wrong arity is called out with the count.
        let a = args(&["traffic", "--diurnal", "1,2,3"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("24"), "got: {err}");
        assert!(err.contains("got 3"), "got: {err}");
        // And the named presets plus unknown names behave.
        let a = args(&["traffic", "--path", "sideways"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("--path"), "got: {err}");
        let a = args(&["traffic", "--exact-threshold", "0"]);
        let err = cmd_traffic(&a).unwrap_err().to_string();
        assert!(err.contains("--exact-threshold"), "got: {err}");
    }

    #[test]
    fn scenario_cli_parses_positional_preset_and_rejects_unknown() {
        // Positional preset: `frost scenario outage-day --smoke`.
        let a = args(&["scenario", "outage-day", "--smoke"]);
        assert_eq!(a.pos(0), Some("outage-day"));
        assert!(a.get("smoke").is_some());
        // Unknown preset is a hard error naming the choices.
        let a = args(&["scenario", "solar-flare"]);
        let err = cmd_scenario(&a).unwrap_err().to_string();
        assert!(err.contains("solar-flare"), "got: {err}");
        assert!(err.contains("outage-day"), "got: {err}");
        // A missing preset errors instead of silently defaulting — a
        // boolean flag can otherwise eat the positional name
        // (`scenario --smoke flash-crowd` parses the preset as the
        // flag's value).
        let a = args(&["scenario", "--smoke", "flash-crowd"]);
        let err = cmd_scenario(&a).unwrap_err().to_string();
        assert!(err.contains("missing scenario preset"), "got: {err}");
        // Malformed numeric flags error like every other subcommand.
        let a = args(&["scenario", "outage-day", "--slots", "2"]);
        let err = cmd_scenario(&a).unwrap_err().to_string();
        assert!(err.contains("--slots"), "got: {err}");
        let a = args(&["scenario", "outage-day", "--sites", "none"]);
        assert!(cmd_scenario(&a).is_err());
    }

    #[test]
    fn chaos_cli_parses_positional_preset_and_rejects_unknown() {
        // Positional preset: `frost chaos lossy-fabric --smoke`.
        let a = args(&["chaos", "lossy-fabric", "--smoke"]);
        assert_eq!(a.pos(0), Some("lossy-fabric"));
        assert!(a.get("smoke").is_some());
        // Unknown preset is a hard error naming the choices.
        let a = args(&["chaos", "perfect-fabric"]);
        let err = cmd_chaos(&a).unwrap_err().to_string();
        assert!(err.contains("perfect-fabric"), "got: {err}");
        assert!(err.contains("lossy-fabric"), "got: {err}");
        // A missing preset errors instead of silently defaulting (a
        // boolean flag can eat the positional name).
        let a = args(&["chaos", "--smoke", "liar-telemetry"]);
        let err = cmd_chaos(&a).unwrap_err().to_string();
        assert!(err.contains("missing chaos preset"), "got: {err}");
        // Malformed numeric flags error like every other subcommand.
        let a = args(&["chaos", "slow-fabric", "--sites", "none"]);
        assert!(cmd_chaos(&a).is_err());
        let a = args(&["chaos", "slow-fabric", "--seed", "-1"]);
        assert!(cmd_chaos(&a).is_err());
    }

    #[test]
    fn region_flags_are_validated_hard_on_every_fleet_command() {
        // --regions 0 never clamps to a runnable single region.
        let a = args(&["fleet", "--sites", "4", "--regions", "0"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("at least one region"), "got: {err}");
        // More regions than sites is impossible to partition.
        let a = args(&["fleet", "--sites", "4", "--regions", "5"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("exceeds the fleet's 4 sites"), "got: {err}");
        // A site mapped past the declared region count is a hard error.
        let a = args(&["fleet", "--sites", "4", "--regions", "2", "--region-map", "0,0,1,2"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("site 3 mapped to undefined region 2"), "got: {err}");
        // Wrong-arity maps are called out with both counts.
        let a = args(&["fleet", "--sites", "4", "--region-map", "0,1"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("assigns 2 sites"), "got: {err}");
        // A declared region that owns no sites cannot water-fill.
        let a = args(&["fleet", "--sites", "4", "--regions", "3", "--region-map", "0,0,1,1"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("owns no sites"), "got: {err}");
        // Malformed map entries and a bare --region-map error clearly.
        let a = args(&["fleet", "--sites", "4", "--region-map", "0,west,1,1"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("'west'"), "got: {err}");
        let a = args(&["fleet", "--sites", "4", "--region-map"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("comma-separated"), "got: {err}");
        // scenario and chaos validate through the same path.
        let a = args(&["scenario", "outage-day", "--sites", "4", "--regions", "0"]);
        let err = cmd_scenario(&a).unwrap_err().to_string();
        assert!(err.contains("at least one region"), "got: {err}");
        let a = args(&["chaos", "lossy-fabric", "--sites", "4", "--regions", "9"]);
        let err = cmd_chaos(&a).unwrap_err().to_string();
        assert!(err.contains("exceeds the fleet's 4 sites"), "got: {err}");
        // A valid map alone infers the region count from its indices.
        let a = args(&["fleet", "--sites", "4", "--region-map", "0,0,1,1"]);
        let rm = region_map(&a, 4).unwrap().unwrap();
        assert_eq!(rm.regions.len(), 2);
        assert!(rm.is_hierarchical());
        // No region flags at all stays flat.
        let a = args(&["fleet", "--sites", "4"]);
        assert!(region_map(&a, 4).unwrap().is_none());
    }

    #[test]
    fn checkpoint_flags_require_the_checkpoint_dir() {
        // Cadence/retention/crash flags without --checkpoint are hard
        // errors, not silently ignored knobs.
        let a = args(&["fleet", "--crash-at-round", "3"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("require --checkpoint"), "got: {err}");
        let a = args(&["chaos", "lossy-fabric", "--smoke", "--every", "2"]);
        let err = cmd_chaos(&a).unwrap_err().to_string();
        assert!(err.contains("require --checkpoint"), "got: {err}");
        let a = args(&["scenario", "outage-day", "--smoke", "--keep", "5"]);
        let err = cmd_scenario(&a).unwrap_err().to_string();
        assert!(err.contains("require --checkpoint"), "got: {err}");
        // A bare `--checkpoint` parses as a boolean flag — no directory.
        let a = args(&["fleet", "--checkpoint", "--every", "2"]);
        let err = cmd_fleet(&a).unwrap_err().to_string();
        assert!(err.contains("needs a directory"), "got: {err}");
    }

    #[test]
    fn checkpoint_rounds_and_retention_are_one_based_hard_errors() {
        // Round 0 is the pre-run state (re-running from config covers
        // it) and keep 0 would retain nothing — both hard errors.
        let a = args(&["fleet", "--checkpoint", "ck", "--crash-at-round", "0"]);
        let err = ckpt_options(&a).unwrap_err().to_string();
        assert!(err.contains("--crash-at-round 0"), "got: {err}");
        assert!(err.contains("must be >= 1"), "got: {err}");
        let a = args(&["fleet", "--checkpoint", "ck", "--every", "0"]);
        let err = ckpt_options(&a).unwrap_err().to_string();
        assert!(err.contains("--every 0"), "got: {err}");
        let a = args(&["fleet", "--checkpoint", "ck", "--keep", "0"]);
        let err = ckpt_options(&a).unwrap_err().to_string();
        assert!(err.contains("--keep 0"), "got: {err}");
        // The happy path parses into enabled options.
        let a = args(&[
            "fleet",
            "--checkpoint",
            "ck",
            "--every",
            "2",
            "--keep",
            "5",
            "--crash-at-round",
            "3",
        ]);
        let o = ckpt_options(&a).unwrap();
        assert!(o.enabled());
        assert_eq!((o.every, o.keep, o.crash_at), (2, 5, Some(3)));
    }

    #[test]
    fn reversed_trace_round_range_errors_before_the_file_is_opened() {
        // `--round 7..3` is empty; the parse error must fire before the
        // (nonexistent) file would be opened — asserting on the range
        // message, not a file error, pins the ordering.
        let a = args(&["trace", "nofile.jsonl", "--round", "7..3"]);
        let err = cmd_trace(&a).unwrap_err().to_string();
        assert!(err.contains("is empty"), "got: {err}");
        let a = args(&["trace", "nofile.jsonl", "--round", ".."]);
        let err = cmd_trace(&a).unwrap_err().to_string();
        assert!(err.contains("empty round range"), "got: {err}");
    }

    #[test]
    fn resume_requires_a_snapshot_path_and_a_readable_file() {
        let a = args(&["resume"]);
        let err = cmd_resume(&a).unwrap_err().to_string();
        assert!(err.contains("missing snapshot"), "got: {err}");
        let a = args(&["resume", "/nonexistent/x.frostsnap"]);
        let err = format!("{:#}", cmd_resume(&a).unwrap_err());
        assert!(err.contains("read snapshot"), "got: {err}");
    }

    #[test]
    fn valid_flags_still_parse() {
        let a = args(&["fleet", "--sites", "3", "--budget-frac", "0.8"]);
        assert_eq!(a.require_u64("sites", 16, 1).unwrap(), 3);
        assert!((a.require_f64("budget-frac", 1.0, 1e-6, 10.0).unwrap() - 0.8).abs() < 1e-12);
        // Missing flags fall back to their defaults.
        assert_eq!(a.require_u64("rounds", 8, 1).unwrap(), 8);
    }
}
