//! Fixed-bin log-scale latency histogram (DESIGN.md §10).
//!
//! The traffic SLO roll-up used to keep every per-request latency of the
//! day in a `Vec<f64>` and sort it per round — O(users) memory and
//! O(n log n) time, which a 10⁶-users/site day cannot afford.  This
//! histogram is the O(1) replacement: a fixed array of log-spaced bins,
//! `record` is a handful of integer ops (no `ln`, no allocation), and
//! p50/p95/p99 come from a nearest-rank walk over at most [`BINS`] bins.
//!
//! Bin layout (HDR-style, derived from the f64 bit pattern, so it is
//! bit-deterministic on every platform):
//!
//! * the range [`MIN_S`] = 1 µs .. [`MAX_S`] ≈ 4.7 h is split into
//!   power-of-two octaves;
//! * each octave is split into [`SUB_BINS`] = 32 linear sub-bins (the top
//!   5 mantissa bits), so the relative bin width is at most 1/32 ≈ 3.1%;
//! * values at or below `MIN_S` land in bin 0; values at or above `MAX_S`
//!   land in the top bin; **non-finite** values (NaN, ±inf — serving never
//!   produces them, but a poisoned sample must not poison the day) are
//!   *skipped and counted* in a separate [`LatencyHistogram::non_finite`]
//!   tally instead of being filed anywhere: one NaN in a million samples
//!   used to saturate the top bin and drag p99 to ~4.7 h.  Nothing
//!   panics; `count` equals the number of *finite* recorded samples and
//!   `non_finite` accounts for the rest, so totals still conserve.
//!
//! Percentiles use the same nearest-rank convention as
//! [`crate::metrics::percentile_index`] (rank `ceil(q·n)`, clamped to
//! [1, n]) and return the **lower edge** of the selected bin.  For
//! samples inside the resolved range `[MIN_S, MAX_S)` — every latency
//! the serving model can produce; batch service times are ≥ the host
//! launch overhead, orders of magnitude above 1 µs — a histogram
//! percentile therefore never exceeds the exact order statistic and
//! sits within one bin (≤ 3.2% relative) below it; `tests` pin both
//! bounds.  Saturated samples are clamped to the range edges, so for a
//! (hypothetical) sub-µs order statistic the reported `MIN_S` would sit
//! *above* the exact value by less than 1 µs absolute.
//!
//! Histograms merge by bin-wise addition; fleet roll-ups merge per-site
//! histograms in site-index order (the §6 determinism contract's merge
//! rule — addition commutes, but keeping one canonical order means the
//! aggregation code path is identical for every worker-thread count).

/// Lower bound of the resolved range (1 µs).
pub const MIN_S: f64 = 1e-6;
/// Linear sub-bins per power-of-two octave.
pub const SUB_BINS: usize = 32;
const SUB_BITS: u32 = 5;
/// Octaves covered: 2^34 µs ≈ 1.7e4 s above `MIN_S`.
const OCTAVES: usize = 34;
/// Total bin count (34 octaves × 32 sub-bins).
pub const BINS: usize = OCTAVES * SUB_BINS;

/// Upper bound of the resolved range (everything above saturates into the
/// top bin).
pub const MAX_S: f64 = MIN_S * (1u64 << OCTAVES) as f64;

/// Fixed-memory log-scale histogram of latency samples (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    bins: Box<[u64; BINS]>,
    count: u64,
    /// Non-finite samples skipped by `record_n` (never binned — see the
    /// module docs).  Surfaced in `SloSummary::non_finite`.
    non_finite: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { bins: Box::new([0u64; BINS]), count: 0, non_finite: 0 }
    }

    /// Bin index of a latency value.  Pure bit arithmetic on the f64
    /// representation: exponent selects the octave, the top 5 mantissa
    /// bits the sub-bin.  Total (non-finite maps to the top bin so the
    /// function stays total, but `record_n` never routes non-finite
    /// samples here — they are skipped and counted instead).
    pub fn bin_index(x: f64) -> usize {
        if !x.is_finite() || x >= MAX_S {
            return BINS - 1;
        }
        if x <= MIN_S {
            return 0;
        }
        // y ∈ (1, 2^OCTAVES): exponent field is the octave, the mantissa's
        // top SUB_BITS bits the linear sub-bin within it.
        let y = x / MIN_S;
        let bits = y.to_bits();
        let octave = ((bits >> 52) as usize).saturating_sub(1023);
        let sub = ((bits >> (52 - SUB_BITS)) & (SUB_BINS as u64 - 1)) as usize;
        (octave * SUB_BINS + sub).min(BINS - 1)
    }

    /// Lower edge of bin `i` (seconds).  `bin_index(lower_edge(i)) == i`
    /// for every in-range bin.
    pub fn lower_edge(i: usize) -> f64 {
        let octave = i / SUB_BINS;
        let sub = (i % SUB_BINS) as f64;
        MIN_S * (1u64 << octave) as f64 * (1.0 + sub / SUB_BINS as f64)
    }

    /// Upper edge of bin `i` (seconds): the next bin's lower edge.
    pub fn upper_edge(i: usize) -> f64 {
        if i + 1 >= BINS {
            MAX_S
        } else {
            LatencyHistogram::lower_edge(i + 1)
        }
    }

    pub fn record(&mut self, x: f64) {
        self.record_n(x, 1);
    }

    /// Record `n` samples of the same value — the aggregated serving path
    /// retires whole request groups with one call (O(1) per group).
    /// Non-finite values are skipped and tallied in [`Self::non_finite`]:
    /// filing a NaN into the top bin would report a ~4.7 h p99 for an
    /// otherwise-healthy day.
    pub fn record_n(&mut self, x: f64, n: u64) {
        if n == 0 {
            return;
        }
        if !x.is_finite() {
            self.non_finite += n;
            return;
        }
        self.bins[LatencyHistogram::bin_index(x)] += n;
        self.count += n;
    }

    /// Finite samples recorded (what the percentiles rank over).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sparse view of the occupied bins, `(bin index, count)` ascending —
    /// the checkpoint representation (DESIGN.md §15): a day's histogram
    /// touches a handful of the 1088 bins.
    pub fn occupied_bins(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.bins.iter().enumerate().filter(|(_, &n)| n > 0).map(|(i, &n)| (i, n))
    }

    /// Rebuild from a checkpointed sparse bin list plus the non-finite
    /// tally.  `count` is re-derived from the bins, so a tampered
    /// snapshot cannot desynchronise the rank base from the bin mass.
    /// Out-of-range bin indices are an error, surfaced as `None`.
    pub fn from_sparse_bins(
        bins: impl IntoIterator<Item = (usize, u64)>,
        non_finite: u64,
    ) -> Option<LatencyHistogram> {
        let mut h = LatencyHistogram::new();
        for (i, n) in bins {
            if i >= BINS {
                return None;
            }
            h.bins[i] += n;
            h.count += n;
        }
        h.non_finite = non_finite;
        Some(h)
    }

    /// Non-finite samples skipped by [`Self::record_n`].
    pub fn non_finite(&self) -> u64 {
        self.non_finite
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Forget everything (day rollover); keeps the allocation.
    pub fn clear(&mut self) {
        self.bins.fill(0);
        self.count = 0;
        self.non_finite = 0;
    }

    /// Bin-wise merge.  Callers merge in site-index order (§6).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.bins.iter_mut().zip(other.bins.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.non_finite += other.non_finite;
    }

    /// Nearest-rank percentile by bin walk: the lower edge of the bin
    /// holding the `ceil(q·n)`-th smallest sample (rank clamped to
    /// [1, n]; same convention as [`crate::metrics::percentile_index`]).
    /// Lower-edge reporting means the result never exceeds the exact
    /// order statistic for in-range samples (see the module docs for the
    /// saturation caveat).  Empty histogram yields 0.0, matching
    /// `metrics::percentile`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return LatencyHistogram::lower_edge(i);
            }
        }
        LatencyHistogram::lower_edge(BINS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::percentile;
    use crate::util::Pcg32;

    #[test]
    fn bin_edges_round_trip_and_order() {
        for i in 0..BINS {
            let lo = LatencyHistogram::lower_edge(i);
            assert_eq!(LatencyHistogram::bin_index(lo), i, "bin {i} lower edge");
            assert!(LatencyHistogram::upper_edge(i) > lo, "bin {i} width");
        }
        // Monotone: larger values never land in smaller bins.
        let mut last = 0;
        let mut x = MIN_S;
        while x < MAX_S {
            let b = LatencyHistogram::bin_index(x);
            assert!(b >= last, "{x}: bin {b} < {last}");
            last = b;
            x *= 1.01;
        }
    }

    #[test]
    fn out_of_range_saturates_and_non_finite_is_skipped_and_counted() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(-5.0);
        h.record(1e-12);
        assert_eq!(h.percentile(0.5), LatencyHistogram::lower_edge(0));
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(1e9);
        // Finite out-of-range samples saturate into the edge bins; the
        // non-finite ones are skipped and tallied separately.
        assert_eq!(h.count(), 4);
        assert_eq!(h.non_finite(), 3);
        assert_eq!(h.percentile(1.0), LatencyHistogram::lower_edge(BINS - 1));
        h.clear();
        assert_eq!(h.non_finite(), 0);
    }

    #[test]
    fn a_single_nan_no_longer_poisons_the_day_p99() {
        // Regression: record_n used to file NaN/inf into the top bin, so
        // one poisoned sample among a day of ~50 ms requests reported a
        // p99 of MAX_S ≈ 4.7 h.
        let mut h = LatencyHistogram::new();
        for _ in 0..10_000 {
            h.record(0.05);
        }
        h.record_n(f64::NAN, 1);
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.non_finite(), 1);
        let p99 = h.percentile(0.99);
        assert!(p99 < 0.06, "p99 {p99} poisoned by the NaN");
        // And a poisoned group on the aggregated path is fully tallied.
        h.record_n(f64::INFINITY, 500);
        assert_eq!(h.non_finite(), 501);
        assert!(h.percentile(1.0) < 0.06);
    }

    #[test]
    fn percentiles_sit_within_one_bin_below_the_exact_order_statistic() {
        let mut rng = Pcg32::seeded(42);
        let mut h = LatencyHistogram::new();
        let mut xs: Vec<f64> = (0..5_000)
            .map(|_| {
                // Log-uniform latencies spanning µs to tens of seconds.
                let e = rng.uniform(-6.0, 1.5);
                10f64.powf(e)
            })
            .collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.total_cmp(b));
        for q in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let exact = percentile(&xs, q);
            let approx = h.percentile(q);
            assert!(approx <= exact + 1e-15, "q={q}: {approx} > exact {exact}");
            // Upper edge of the chosen bin bounds the exact value:
            // relative error ≤ one sub-bin (≤ 1/32 of the octave base).
            let i = LatencyHistogram::bin_index(exact);
            assert!(
                exact < LatencyHistogram::upper_edge(i) && approx >= LatencyHistogram::lower_edge(i),
                "q={q}: exact {exact} not bracketed by bin {i}"
            );
            assert!(
                (exact - approx) / exact <= 1.0 / SUB_BINS as f64 + 1e-12,
                "q={q}: gap {} past one bin",
                (exact - approx) / exact
            );
        }
    }

    #[test]
    fn nearest_rank_convention_matches_percentile_index() {
        // The bin walk must land in the bin holding exactly the order
        // statistic the shared nearest-rank helper selects.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        let mut h = LatencyHistogram::new();
        for &x in &xs {
            h.record(x);
        }
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let exact = percentile(&xs, q);
            let i = LatencyHistogram::bin_index(exact);
            assert_eq!(h.percentile(q), LatencyHistogram::lower_edge(i), "q={q}");
        }
    }

    #[test]
    fn merge_equals_concatenation_and_clear_resets() {
        let mut rng = Pcg32::seeded(7);
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for k in 0..2_000 {
            let x = rng.uniform(1e-4, 2.0);
            if k % 3 == 0 {
                a.record(x);
            } else {
                b.record_n(x, 2);
            }
            all.record_n(x, if k % 3 == 0 { 1 } else { 2 });
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, all);
        assert_eq!(merged.count(), a.count() + b.count());
        merged.clear();
        assert!(merged.is_empty());
        assert_eq!(merged.percentile(0.5), 0.0);
    }

    #[test]
    fn record_n_matches_repeated_record() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..17 {
            a.record(0.042);
        }
        b.record_n(0.042, 17);
        assert_eq!(a, b);
    }
}
