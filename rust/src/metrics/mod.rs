//! Statistics and derived metrics used across the evaluation.

pub mod stats;

pub use stats::{
    linear_fit, mean, pearson, percentile, percentile_index, std_dev, StreamingSummary, Summary,
};
