//! Statistics and derived metrics used across the evaluation.

pub mod hist;
pub mod stats;

pub use hist::LatencyHistogram;
pub use stats::{
    linear_fit, mean, pearson, percentile, percentile_index, std_dev, StreamingSummary, Summary,
};
