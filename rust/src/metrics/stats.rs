//! Summary statistics: Pearson correlation (the `r` values of Fig. 2),
//! linear regression, and basic moments.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient r — the paper quotes r = 0.34 for
/// accuracy-vs-energy and r = 0.999 for energy-vs-time (Fig. 2).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson requires equal-length series");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Ordinary least squares `y = a + b·x`; returns (intercept, slope).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return (mean(ys), 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (my - slope * mx, slope)
}

/// Nearest-rank index of quantile `q` in an ascending-sorted sample of
/// size `n`: the smallest rank covering `q·n` of the sample
/// (`ceil(q·n) − 1`), clamped to the valid index range.  Shared by the
/// bench harness (p95 summary line) and the traffic SLO reporting
/// (p50/p95/p99 latency), so the two cannot disagree on rank semantics.
pub fn percentile_index(n: usize, q: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (n as f64 * q).ceil().clamp(1.0, n as f64) as usize;
    rank - 1
}

/// Nearest-rank percentile of an ascending-sorted slice.  Empty input
/// yields 0.0, matching [`Summary::of`]'s empty-input convention.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    debug_assert!(
        sorted.windows(2).all(|p| p[0] <= p[1]),
        "percentile input must be sorted ascending"
    );
    sorted[percentile_index(sorted.len(), q)]
}

/// Five-number summary used in reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        // Empty input yields all-zero fields: folding from ±infinity would
        // leak `inf`/`-inf` into JSON reports, which is not valid JSON.
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Streaming (one-pass) counterpart of [`Summary::of`]: Welford's online
/// mean/variance plus running min/max, O(1) memory.  Feeds the telemetry
/// retention rings (DESIGN.md §8): summary statistics stay exact over the
/// *entire* stream even after old samples are evicted.  On any window both
/// have seen in full, `finish()` matches the vector-based `Summary::of` up
/// to floating-point accumulation order.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamingSummary {
    n: u64,
    mean: f64,
    /// Sum of squared deviations from the running mean (Welford's M2).
    m2: f64,
    min: f64,
    max: f64,
}

impl StreamingSummary {
    pub fn new() -> StreamingSummary {
        StreamingSummary::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if self.n == 1 {
            self.mean = x;
            self.m2 = 0.0;
            self.min = x;
            self.max = x;
            return;
        }
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Raw Welford accumulator state `(n, mean, m2, min, max)` for
    /// checkpointing (DESIGN.md §15).
    pub fn state_parts(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean, self.m2, self.min, self.max)
    }

    /// Rebuild the accumulator from checkpointed
    /// [`StreamingSummary::state_parts`]; the stream continues bit-exactly.
    pub fn from_parts(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> StreamingSummary {
        StreamingSummary { n, mean, m2, min, max }
    }

    /// The same five-number summary [`Summary::of`] computes, without the
    /// vector: empty → all zeros, n = 1 → std 0, else population std.
    pub fn finish(&self) -> Summary {
        if self.n == 0 {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        let std = if self.n < 2 { 0.0 } else { (self.m2 / self.n as f64).max(0.0).sqrt() };
        Summary { n: self.n as usize, mean: self.mean, std, min: self.min, max: self.max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) {
        let scale = a.abs().max(b.abs()).max(1e-12);
        assert!((a - b).abs() / scale < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn streaming_matches_vector_summary_on_full_window() {
        // Deterministic pseudo-random-ish series with spread and drift.
        let xs: Vec<f64> = (0..500)
            .map(|i| {
                let t = i as f64;
                50.0 + 30.0 * (t * 0.13).sin() + 0.02 * t
            })
            .collect();
        let mut acc = StreamingSummary::new();
        for &x in &xs {
            acc.push(x);
        }
        let streamed = acc.finish();
        let vector = Summary::of(&xs);
        assert_eq!(streamed.n, vector.n);
        close(streamed.mean, vector.mean);
        close(streamed.std, vector.std);
        assert_eq!(streamed.min, vector.min);
        assert_eq!(streamed.max, vector.max);
    }

    #[test]
    fn streaming_edge_cases_match_summary_of() {
        assert_eq!(StreamingSummary::new().finish(), Summary::of(&[]));
        let mut one = StreamingSummary::new();
        one.push(7.5);
        assert_eq!(one.finish(), Summary::of(&[7.5]));
    }

    #[test]
    fn percentile_index_is_nearest_rank() {
        // p95 boundaries (the bench harness's summary line).
        assert_eq!(percentile_index(1, 0.95), 0);
        assert_eq!(percentile_index(3, 0.95), 2);
        assert_eq!(percentile_index(10, 0.95), 9); // ceil(9.5) − 1
        assert_eq!(percentile_index(20, 0.95), 18); // exactly the 19th of 20
        assert_eq!(percentile_index(100, 0.95), 94);
        assert_eq!(percentile_index(101, 0.95), 95);
        // p50: the lower of the two middle ranks (`ceil(0.5n) − 1`),
        // never past the end.
        assert_eq!(percentile_index(1, 0.50), 0);
        assert_eq!(percentile_index(2, 0.50), 0);
        assert_eq!(percentile_index(4, 0.50), 1);
        assert_eq!(percentile_index(5, 0.50), 2);
        // p99 needs ≥ 100 samples to move off the p95 rank.
        assert_eq!(percentile_index(100, 0.99), 98);
        assert_eq!(percentile_index(1000, 0.99), 989);
        // Degenerate quantiles clamp to the ends.
        assert_eq!(percentile_index(10, 0.0), 0);
        assert_eq!(percentile_index(10, 1.0), 9);
        assert_eq!(percentile_index(10, 2.0), 9);
        assert_eq!(percentile_index(0, 0.5), 0);
    }

    #[test]
    fn percentile_reads_sorted_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
    }

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        let s = std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let y = [1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        assert!(pearson(&x, &y).abs() < 0.25);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn empty_summary_is_finite_zeros() {
        // Regression: min/max used to come out ±infinity, poisoning JSON.
        let s = Summary::of(&[]);
        assert_eq!(s, Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 });
        for v in [s.mean, s.std, s.min, s.max] {
            assert!(v.is_finite());
        }
    }
}
