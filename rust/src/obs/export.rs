//! Streaming JSON writers (DESIGN.md §14; the first slice of ROADMAP
//! item 4 — zero-alloc streaming reports).
//!
//! Two surfaces, neither of which builds an intermediate [`Json`] tree:
//!
//! * [`write_trace`] — one `TRACE_*.jsonl` line per [`TraceEvent`],
//!   written incrementally through a reused line buffer.  Schema:
//!   every line carries `id`, `round`, `t_s`, `kind`, plus `site` for
//!   site-scoped events and kind-specific payload fields (see
//!   [`trace_line`]).
//! * [`JsonStream`] — a push-style object/array writer for structured
//!   CLI reports (`frost fleet --json`, `frost scenario --json`).
//!
//! Escaping and number formatting are the *same functions* the [`Json`]
//! tree serialiser uses ([`crate::util::json::write_escaped`] /
//! [`crate::util::json::write_num`]), so the two serialisers cannot
//! drift — a round-trip test against `Json::parse` pins this.
//!
//! [`Json`]: crate::util::Json

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{write_escaped, write_num};

use super::{TraceData, TraceEvent, TraceSink};

/// Append `"key":` (with a leading comma unless first) to `buf`.
fn key(buf: &mut String, first: &mut bool, name: &str) {
    if !*first {
        buf.push(',');
    }
    *first = false;
    write_escaped(buf, name);
    buf.push(':');
}

fn field_num(buf: &mut String, first: &mut bool, name: &str, v: f64) {
    key(buf, first, name);
    if v.is_finite() {
        write_num(buf, v);
    } else {
        // A NaN/inf would poison the whole line (not valid JSON); the
        // simulator never emits one here, but a poisoned sample must not
        // make the trace unparseable.
        buf.push_str("null");
    }
}

fn field_u64(buf: &mut String, first: &mut bool, name: &str, v: u64) {
    key(buf, first, name);
    buf.push_str(&format!("{v}"));
}

fn field_str(buf: &mut String, first: &mut bool, name: &str, v: &str) {
    key(buf, first, name);
    write_escaped(buf, v);
}

fn field_bool(buf: &mut String, first: &mut bool, name: &str, v: bool) {
    key(buf, first, name);
    buf.push_str(if v { "true" } else { "false" });
}

/// Serialise one trace event as a single JSONL line (no trailing
/// newline) into `buf`, which is cleared first.
pub fn trace_line(sink: &TraceSink, ev: &TraceEvent, buf: &mut String) {
    buf.clear();
    buf.push('{');
    let mut first = true;
    field_u64(buf, &mut first, "id", ev.id);
    field_u64(buf, &mut first, "round", u64::from(ev.round));
    field_num(buf, &mut first, "t_s", sink.time_of(ev.round));
    field_str(buf, &mut first, "kind", ev.data.kind());
    if let Some(site) = ev.site {
        field_u64(buf, &mut first, "site", u64::from(site));
    }
    if let Some(region) = ev.region {
        field_u64(buf, &mut first, "region", u64::from(region));
    }
    match &ev.data {
        TraceData::RoundStart | TraceData::Reprofile => {}
        TraceData::RoundEnd { cap_power_w } => {
            field_num(buf, &mut first, "cap_w", *cap_power_w);
        }
        TraceData::SiteRound { cap_frac, down } => {
            field_num(buf, &mut first, "cap", *cap_frac);
            field_bool(buf, &mut first, "down", *down);
        }
        TraceData::Scenario { detail, .. } => {
            field_str(buf, &mut first, "detail", detail);
        }
        TraceData::Fault { fate, interface, count } => {
            field_str(buf, &mut first, "fate", fate);
            field_str(buf, &mut first, "iface", interface);
            field_u64(buf, &mut first, "count", *count);
        }
        TraceData::KpmReject { host, reason } => {
            field_str(buf, &mut first, "host", host);
            field_str(buf, &mut first, "reason", reason);
        }
        TraceData::Lifecycle { detail } => {
            field_str(buf, &mut first, "detail", detail);
        }
        TraceData::CapChange { cause, from, to, trigger } => {
            field_str(buf, &mut first, "cause", cause.as_str());
            field_num(buf, &mut first, "from", *from);
            field_num(buf, &mut first, "to", *to);
            match trigger {
                Some(t) => field_u64(buf, &mut first, "trigger", *t),
                None => {
                    key(buf, &mut first, "trigger");
                    buf.push_str("null");
                }
            }
        }
        TraceData::Quarantine { host, entered } => {
            field_str(buf, &mut first, "host", host);
            field_bool(buf, &mut first, "entered", *entered);
        }
    }
    buf.push('}');
}

/// Stream every recorded event into `w`, one JSONL line each, through a
/// single reused buffer.
pub fn write_trace_to<W: Write>(mut w: W, sink: &TraceSink) -> io::Result<()> {
    let mut buf = String::new();
    for ev in sink.events() {
        trace_line(sink, ev, &mut buf);
        buf.push('\n');
        w.write_all(buf.as_bytes())?;
    }
    Ok(())
}

/// Write the trace to `path` (`TRACE_*.jsonl` convention).
pub fn write_trace(path: &Path, sink: &TraceSink) -> Result<()> {
    let file = File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let mut out = BufWriter::new(file);
    write_trace_to(&mut out, sink).with_context(|| format!("writing {}", path.display()))?;
    out.flush().context("flushing trace file")?;
    Ok(())
}

/// The full trace as one JSONL string (tests; bit-identity comparisons).
pub fn trace_to_string(sink: &TraceSink) -> String {
    let mut out = Vec::new();
    write_trace_to(&mut out, sink).expect("Vec<u8> writes are infallible");
    String::from_utf8(out).expect("trace lines are UTF-8")
}

/// A push-style streaming JSON writer: begin/end nesting calls plus
/// typed fields, comma placement handled internally.  Inside an object
/// pass `Some(key)`; inside an array pass `None`.  IO errors are
/// deferred to [`JsonStream::finish`] so call sites stay linear.
pub struct JsonStream<W: Write> {
    out: W,
    buf: String,
    /// One "wrote an element yet" flag per open scope.
    stack: Vec<bool>,
    err: Option<io::Error>,
}

impl<W: Write> JsonStream<W> {
    pub fn new(out: W) -> JsonStream<W> {
        JsonStream { out, buf: String::new(), stack: Vec::new(), err: None }
    }

    fn flush_buf(&mut self) {
        if self.err.is_none() {
            if let Err(e) = self.out.write_all(self.buf.as_bytes()) {
                self.err = Some(e);
            }
        }
        self.buf.clear();
    }

    fn pre(&mut self, name: Option<&str>) {
        if let Some(last) = self.stack.last_mut() {
            if *last {
                self.buf.push(',');
            }
            *last = true;
        }
        if let Some(name) = name {
            write_escaped(&mut self.buf, name);
            self.buf.push(':');
        }
    }

    pub fn begin_obj(&mut self, name: Option<&str>) {
        self.pre(name);
        self.buf.push('{');
        self.stack.push(false);
        self.flush_buf();
    }

    pub fn end_obj(&mut self) {
        self.stack.pop();
        self.buf.push('}');
        self.flush_buf();
    }

    pub fn begin_arr(&mut self, name: Option<&str>) {
        self.pre(name);
        self.buf.push('[');
        self.stack.push(false);
        self.flush_buf();
    }

    pub fn end_arr(&mut self) {
        self.stack.pop();
        self.buf.push(']');
        self.flush_buf();
    }

    pub fn str_field(&mut self, name: Option<&str>, v: &str) {
        self.pre(name);
        write_escaped(&mut self.buf, v);
        self.flush_buf();
    }

    pub fn num_field(&mut self, name: Option<&str>, v: f64) {
        self.pre(name);
        if v.is_finite() {
            write_num(&mut self.buf, v);
        } else {
            self.buf.push_str("null");
        }
        self.flush_buf();
    }

    pub fn u64_field(&mut self, name: Option<&str>, v: u64) {
        self.pre(name);
        self.buf.push_str(&format!("{v}"));
        self.flush_buf();
    }

    pub fn bool_field(&mut self, name: Option<&str>, v: bool) {
        self.pre(name);
        self.buf.push_str(if v { "true" } else { "false" });
        self.flush_buf();
    }

    /// Close the writer: a trailing newline, then the first deferred IO
    /// error if any write failed.
    pub fn finish(mut self) -> io::Result<W> {
        self.buf.push('\n');
        self.flush_buf();
        match self.err {
            Some(e) => Err(e),
            None => Ok(self.out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::CapCause;
    use crate::util::Json;

    fn sink_with(data: Vec<(Option<u32>, TraceData)>) -> TraceSink {
        let mut sink = TraceSink::new(true, 150.0);
        sink.begin_round(1);
        for (site, d) in data {
            sink.record(site, d);
        }
        sink
    }

    #[test]
    fn every_line_is_parseable_json_with_the_common_fields() {
        let sink = sink_with(vec![
            (None, TraceData::RoundEnd { cap_power_w: 123.5 }),
            (Some(0), TraceData::SiteRound { cap_frac: 0.8, down: false }),
            (Some(1), TraceData::KpmReject { host: "site01".into(), reason: "non_finite" }),
            (None, TraceData::Fault { fate: "dropped", interface: "A1", count: 1 }),
            (
                Some(2),
                TraceData::CapChange {
                    cause: CapCause::LeaseFallback,
                    from: 0.9,
                    to: 0.4,
                    trigger: None,
                },
            ),
        ]);
        let text = trace_to_string(&sink);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sink.len());
        for (line, ev) in lines.iter().zip(sink.events()) {
            let v = Json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(v.get("id").unwrap().as_i64(), Some(ev.id as i64));
            assert_eq!(v.get("kind").unwrap().as_str(), Some(ev.data.kind()));
            assert!(v.get("round").is_some() && v.get("t_s").is_some());
        }
        // The null trigger serialises as JSON null, not a missing key.
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert!(last.get("trigger").unwrap().is_null());
        assert_eq!(last.get("cause").unwrap().as_str(), Some("lease-fallback"));
    }

    #[test]
    fn json_stream_nests_and_places_commas() {
        let mut s = JsonStream::new(Vec::new());
        s.begin_obj(None);
        s.str_field(Some("name"), "fleet");
        s.num_field(Some("sites"), 4.0);
        s.begin_arr(Some("rows"));
        s.num_field(None, 1.5);
        s.num_field(None, f64::NAN);
        s.begin_obj(None);
        s.bool_field(Some("ok"), true);
        s.end_obj();
        s.end_arr();
        s.u64_field(Some("count"), 7);
        s.end_obj();
        let out = String::from_utf8(s.finish().unwrap()).unwrap();
        let v = Json::parse(out.trim()).unwrap();
        assert_eq!(v.get("sites").unwrap().as_i64(), Some(4));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows[1].is_null(), "non-finite numbers become null");
        assert_eq!(rows[2].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("count").unwrap().as_i64(), Some(7));
    }
}
