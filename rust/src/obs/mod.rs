//! Deterministic flight recorder (DESIGN.md §14).
//!
//! The control plane grew four disjoint ledgers (scenario events, fault
//! injections, KPM rejects, lifecycle events) plus ad-hoc counters, and
//! none of them could answer "why did site 12's cap move in round 840?".
//! This module is the unified observability spine:
//!
//! * [`TraceSink`] — structured, sim-time-stamped events with stable ids,
//!   recorded **only on the coordinator thread in site-index order**, so a
//!   trace is bit-identical for any worker-thread count (§6).  Worker-side
//!   actions (lease fallbacks, policy clamps) are recorded site-locally
//!   and ingested by the coordinator after the parallel phase, in site
//!   order — the same pattern the fleet gateway uses for outboxes.
//! * [`CapCause`] — the closed taxonomy of reasons an A1 cap can move.
//!   Every recorded cap change carries its cause plus the id of the trace
//!   event that triggered it, so `frost trace --explain SITE` can print
//!   the full causal chain for each cap move.
//! * [`MetricsRegistry`] — named counters/gauges/summaries replacing the
//!   scattered per-struct counters, surfaced in `FleetReport`.
//!
//! Recording is gated: with tracing disabled (the default, and always the
//! case for benches) every record call is an early-return no-op, so the
//! hot path stays bit-identical and within noise of the untraced build.
//! Scenario events are the one exception — they are recorded
//! unconditionally (a handful per run) because the scenario harness's
//! event ledger is derived from the sink.

pub mod export;
pub mod query;

use std::collections::BTreeMap;
use std::fmt;

use crate::metrics::StreamingSummary;
use crate::scenario::ScenarioEvent;

/// Why an A1 cap moved (DESIGN.md §14 taxonomy).  Closed set: every cap
/// mutation in the fleet maps to exactly one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapCause {
    /// A scripted budget step rescaled the global budget fraction.
    BudgetStep,
    /// The budget water-fill re-weighted the fleet's caps.
    WaterFill,
    /// A thermal derate clamped the site's policy ceiling.
    DerateClamp,
    /// An expired A1 lease dropped the site to its safe cap.
    LeaseFallback,
    /// A profile quarantine froze/reserved the site's allocation.
    Quarantine,
    /// A healing path restored headroom (lease renewal, derate end,
    /// site recovery, quarantine release).
    Recovery,
}

impl CapCause {
    pub fn as_str(self) -> &'static str {
        match self {
            CapCause::BudgetStep => "budget-step",
            CapCause::WaterFill => "water-fill",
            CapCause::DerateClamp => "derate-clamp",
            CapCause::LeaseFallback => "lease-fallback",
            CapCause::Quarantine => "quarantine",
            CapCause::Recovery => "recovery",
        }
    }
}

impl CapCause {
    /// Inverse of [`CapCause::as_str`] (used by the checkpoint decoder,
    /// DESIGN.md §15).
    pub fn from_str_name(s: &str) -> Option<CapCause> {
        Some(match s {
            "budget-step" => CapCause::BudgetStep,
            "water-fill" => CapCause::WaterFill,
            "derate-clamp" => CapCause::DerateClamp,
            "lease-fallback" => CapCause::LeaseFallback,
            "quarantine" => CapCause::Quarantine,
            "recovery" => CapCause::Recovery,
            _ => return None,
        })
    }
}

impl fmt::Display for CapCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Payload of one trace event.  Variants mirror the JSONL `kind` field
/// (see `obs::export` for the serialised schema).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceData {
    /// Span open: the coordinator began an orchestration round.
    RoundStart,
    /// Span close: Σ applied-cap watts over all sites, in site order.
    RoundEnd { cap_power_w: f64 },
    /// Per-site per-round span: applied cap and availability.
    SiteRound { cap_frac: f64, down: bool },
    /// A scripted scenario event fired (recorded even when tracing is
    /// disabled — the scenario ledger is derived from the sink).
    Scenario { event: ScenarioEvent, detail: String },
    /// The fault plan injured a message (`fate` is the ledger name).
    Fault { fate: &'static str, interface: &'static str, count: u64 },
    /// The SMO rejected a KPM report at validation.
    KpmReject { host: String, reason: &'static str },
    /// An AI/ML lifecycle event crossed the O1 plane.
    Lifecycle { detail: String },
    /// An A1 cap moved: `from`/`to` are exact cap fractions, `trigger`
    /// is the id of the trace event that caused the move.
    CapChange { cause: CapCause, from: f64, to: f64, trigger: Option<u64> },
    /// The continuous monitor requested a re-profile for this site.
    Reprofile,
    /// A site entered (`entered`) or left a profile quarantine.
    Quarantine { host: String, entered: bool },
}

impl TraceData {
    /// The JSONL `kind` discriminant.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceData::RoundStart => "round_start",
            TraceData::RoundEnd { .. } => "round_end",
            TraceData::SiteRound { .. } => "site_round",
            TraceData::Scenario { .. } => "scenario",
            TraceData::Fault { .. } => "fault",
            TraceData::KpmReject { .. } => "kpm_reject",
            TraceData::Lifecycle { .. } => "lifecycle",
            TraceData::CapChange { .. } => "cap_change",
            TraceData::Reprofile => "reprofile",
            TraceData::Quarantine { .. } => "quarantine",
        }
    }
}

/// One recorded event.  Ids are 1-based and strictly increasing in
/// record order; `site` is the site index for site-scoped events, and
/// `region` is the site's region index when the fleet has a region map
/// (DESIGN.md §16) — derived by the sink at record time, so call sites
/// never pass it.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub id: u64,
    pub round: u32,
    pub site: Option<u32>,
    pub region: Option<u32>,
    pub data: TraceData,
}

/// The coordinator-owned event sink.  All recording happens on the
/// coordinator thread; worker-side events are ingested in site order.
#[derive(Debug, Clone, Default)]
pub struct TraceSink {
    enabled: bool,
    round: u32,
    /// Sim seconds per orchestration round (0.0 when the run is not
    /// traffic-driven; events then carry `t_s` 0).
    round_s: f64,
    events: Vec<TraceEvent>,
    /// Id of the current round's `round_start` event — the default
    /// trigger for cap changes with no more specific cause.
    round_anchor: Option<u64>,
    /// Site → region assignment (§16): when set, every site-scoped event
    /// is stamped with its region at record time.  None on region-free
    /// fleets, whose exported traces are byte-unchanged.
    site_region: Option<Vec<u32>>,
}

impl TraceSink {
    pub fn new(enabled: bool, round_s: f64) -> TraceSink {
        TraceSink {
            enabled,
            round: 0,
            round_s,
            events: Vec::new(),
            round_anchor: None,
            site_region: None,
        }
    }

    /// Install the fleet's site → region assignment (§16).  Set once at
    /// fleet construction, before any event is recorded.
    pub fn set_region_map(&mut self, site_region: Vec<u32>) {
        self.site_region = Some(site_region);
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Sim seconds per round (see [`TraceSink::time_of`]).
    pub fn round_s(&self) -> f64 {
        self.round_s
    }

    /// Sim-time stamp of a round's start: rounds are back-to-back slots
    /// of `round_s` seconds; round 1 starts at t = 0.
    pub fn time_of(&self, round: u32) -> f64 {
        f64::from(round.saturating_sub(1)) * self.round_s
    }

    /// Open a round span.  Returns the `round_start` event id (None when
    /// tracing is disabled).
    pub fn begin_round(&mut self, round: u32) -> Option<u64> {
        self.round = round;
        self.round_anchor = self.push(None, TraceData::RoundStart, true);
        self.round_anchor
    }

    /// Id of the current round's `round_start` event.
    pub fn round_anchor(&self) -> Option<u64> {
        self.round_anchor
    }

    /// Record one event (no-op unless tracing is enabled).  Returns the
    /// assigned id.
    pub fn record(&mut self, site: Option<u32>, data: TraceData) -> Option<u64> {
        self.push(site, data, true)
    }

    /// Record a scenario event unconditionally (the fired-event ledger is
    /// derived from the sink even in untraced runs).
    pub fn record_scenario(&mut self, site: Option<u32>, event: ScenarioEvent) -> Option<u64> {
        let detail = event.to_string();
        self.push(site, TraceData::Scenario { event, detail }, false)
    }

    fn push(&mut self, site: Option<u32>, data: TraceData, gated: bool) -> Option<u64> {
        if gated && !self.enabled {
            return None;
        }
        let id = self.events.len() as u64 + 1;
        let region = match (&self.site_region, site) {
            (Some(map), Some(s)) => map.get(s as usize).copied(),
            _ => None,
        };
        self.events.push(TraceEvent { id, round: self.round, site, region, data });
        Some(id)
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The fired scenario events, in record order — the typed replacement
    /// of the fleet's old `event_log` Vec.
    pub fn scenario_events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter().filter(|e| matches!(e.data, TraceData::Scenario { .. }))
    }

    /// Mutable sink state for checkpointing (DESIGN.md §15).  `enabled`
    /// and `round_s` are construction parameters.
    pub fn ckpt_state(&self) -> (u32, Option<u64>, &[TraceEvent]) {
        (self.round, self.round_anchor, &self.events)
    }

    /// Overwrite the sink state from a checkpoint; subsequent event ids
    /// continue from `events.len() + 1`.
    pub fn restore_ckpt_state(
        &mut self,
        round: u32,
        round_anchor: Option<u64>,
        events: Vec<TraceEvent>,
    ) {
        self.round = round;
        self.round_anchor = round_anchor;
        self.events = events;
    }
}

/// Named counters, gauges and streaming summaries (DESIGN.md §14).
/// Keys are `&'static str` so registering a metric costs nothing on the
/// hot path; `BTreeMap` keeps every iteration name-ordered (§6's merge
/// rule: one canonical order regardless of insertion history).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    summaries: BTreeMap<&'static str, StreamingSummary>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to a named counter (creating it at zero).
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Read a counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge to its latest value.
    pub fn set_gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Push one sample into a named streaming summary.
    pub fn observe(&mut self, name: &'static str, value: f64) {
        self.summaries.entry(name).or_default().push(value);
    }

    pub fn summary(&self, name: &str) -> Option<&StreamingSummary> {
        self.summaries.get(name)
    }

    /// Name-ordered counter view.
    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(&k, &v)| (k, v))
    }

    /// Name-ordered gauge view.
    pub fn gauges(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.gauges.iter().map(|(&k, &v)| (k, v))
    }

    /// Name-ordered summary view.
    pub fn summaries(&self) -> impl Iterator<Item = (&'static str, &StreamingSummary)> + '_ {
        self.summaries.iter().map(|(&k, v)| (k, v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.summaries.is_empty()
    }

    /// Overwrite the whole registry from a checkpoint (DESIGN.md §15).
    /// Keys must already be interned to `&'static str` by the caller (the
    /// checkpoint decoder resolves names against its known-name table).
    pub fn restore_ckpt_state(
        &mut self,
        counters: impl IntoIterator<Item = (&'static str, u64)>,
        gauges: impl IntoIterator<Item = (&'static str, f64)>,
        summaries: impl IntoIterator<Item = (&'static str, StreamingSummary)>,
    ) {
        self.counters = counters.into_iter().collect();
        self.gauges = gauges.into_iter().collect();
        self.summaries = summaries.into_iter().collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing_but_scenario_events() {
        let mut sink = TraceSink::new(false, 150.0);
        assert_eq!(sink.begin_round(1), None);
        assert_eq!(sink.record(Some(0), TraceData::Reprofile), None);
        let id = sink.record_scenario(Some(2), ScenarioEvent::SiteDown { site: 2 });
        assert_eq!(id, Some(1), "scenario events bypass the gate");
        assert_eq!(sink.len(), 1);
        assert_eq!(sink.scenario_events().count(), 1);
    }

    #[test]
    fn ids_are_stable_and_round_anchor_tracks_round_start() {
        let mut sink = TraceSink::new(true, 100.0);
        let a1 = sink.begin_round(1).unwrap();
        assert_eq!(a1, 1);
        assert_eq!(sink.round_anchor(), Some(1));
        let id = sink
            .record(
                Some(3),
                TraceData::CapChange {
                    cause: CapCause::WaterFill,
                    from: 1.0,
                    to: 0.6,
                    trigger: sink.round_anchor(),
                },
            )
            .unwrap();
        assert_eq!(id, 2);
        let a2 = sink.begin_round(2).unwrap();
        assert_eq!(a2, 3);
        assert_eq!(sink.events()[1].round, 1);
        assert_eq!(sink.events()[2].round, 2);
        assert_eq!(sink.time_of(1), 0.0);
        assert_eq!(sink.time_of(3), 200.0);
    }

    #[test]
    fn registry_counts_gauges_and_summarises() {
        let mut m = MetricsRegistry::new();
        m.inc("cache.hits", 3);
        m.inc("cache.hits", 2);
        m.set_gauge("pool.workers", 4.0);
        m.observe("round.cap_w", 100.0);
        m.observe("round.cap_w", 200.0);
        assert_eq!(m.counter("cache.hits"), 5);
        assert_eq!(m.counter("cache.misses"), 0);
        assert_eq!(m.gauge("pool.workers"), Some(4.0));
        let s = m.summary("round.cap_w").unwrap().finish();
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 150.0);
        // Iteration is name-ordered regardless of insertion order.
        m.inc("a.first", 1);
        let names: Vec<&str> = m.counters().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["a.first", "cache.hits"]);
    }

    #[test]
    fn cap_causes_have_stable_names() {
        let all = [
            CapCause::BudgetStep,
            CapCause::WaterFill,
            CapCause::DerateClamp,
            CapCause::LeaseFallback,
            CapCause::Quarantine,
            CapCause::Recovery,
        ];
        let names: Vec<&str> = all.iter().map(|c| c.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "budget-step",
                "water-fill",
                "derate-clamp",
                "lease-fallback",
                "quarantine",
                "recovery"
            ]
        );
    }
}
