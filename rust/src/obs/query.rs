//! Query engine for `TRACE_*.jsonl` files (the `frost trace` CLI).
//!
//! Scanning is lazy: every pass walks the file line by line through a
//! `BufReader`, applies a cheap substring prefilter (`"kind":"…"`,
//! `"site":N`) and only then parses the line with [`Json::parse`] for
//! the exact predicate — a filtered query over a large trace parses only
//! the candidate lines.  `--explain` resolves the causal chain of every
//! cap change: pass one collects the site's `cap_change` events and
//! their `trigger` ids, pass two resolves those ids to the triggering
//! events.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// Exact-match filters for a trace scan.  `round` is an inclusive range.
#[derive(Debug, Clone, Default)]
pub struct TraceFilter {
    pub site: Option<i64>,
    /// Region index (§16): matches lines the sink tagged with a region.
    /// Region-free traces carry no `region` key, so this matches nothing.
    pub region: Option<i64>,
    pub round: Option<(i64, i64)>,
    pub kind: Option<String>,
}

/// Parse a `--round` argument: `A..B` (inclusive), `A..`, `..B`, or a
/// single round `N`.
pub fn parse_round_range(s: &str) -> Result<(i64, i64)> {
    let parse = |p: &str, what: &str| -> Result<i64> {
        p.parse::<i64>().with_context(|| format!("invalid {what} round '{p}' in '{s}'"))
    };
    let range = match s.split_once("..") {
        Some(("", "")) => anyhow::bail!("empty round range '..'"),
        Some((a, "")) => (parse(a, "start")?, i64::MAX),
        Some(("", b)) => (0, parse(b, "end")?),
        Some((a, b)) => (parse(a, "start")?, parse(b, "end")?),
        None => {
            let n = parse(s, "single")?;
            (n, n)
        }
    };
    anyhow::ensure!(range.0 <= range.1, "round range '{s}' is empty");
    Ok(range)
}

/// Cheap substring prefilter: does the line even mention `"name":value`?
/// False positives are fine (the parse confirms); false negatives are
/// not, so the pattern matches the exporter's exact field syntax.
fn mentions_u64(line: &str, name: &str, value: i64) -> bool {
    let pat = format!("\"{name}\":{value}");
    line.match_indices(&pat).any(|(at, _)| {
        matches!(line.as_bytes().get(at + pat.len()), Some(b',') | Some(b'}') | None)
    })
}

fn field_i64(v: &Json, name: &str) -> Option<i64> {
    v.get(name).and_then(Json::as_i64)
}

impl TraceFilter {
    /// Prefilter on the raw line (never rejects a true match).
    fn line_may_match(&self, line: &str) -> bool {
        if let Some(kind) = &self.kind {
            if !line.contains(&format!("\"kind\":\"{kind}\"")) {
                return false;
            }
        }
        if let Some(site) = self.site {
            if !mentions_u64(line, "site", site) {
                return false;
            }
        }
        if let Some(region) = self.region {
            if !mentions_u64(line, "region", region) {
                return false;
            }
        }
        true
    }

    /// Exact predicate on the parsed line.
    fn matches(&self, v: &Json) -> bool {
        if let Some(kind) = &self.kind {
            if v.get("kind").and_then(Json::as_str) != Some(kind) {
                return false;
            }
        }
        if let Some(site) = self.site {
            if field_i64(v, "site") != Some(site) {
                return false;
            }
        }
        if let Some(region) = self.region {
            if field_i64(v, "region") != Some(region) {
                return false;
            }
        }
        if let Some((a, b)) = self.round {
            match field_i64(v, "round") {
                Some(r) if r >= a && r <= b => {}
                _ => return false,
            }
        }
        true
    }
}

/// Walk the trace, calling `visit(raw_line, parsed)` for every matching
/// event.  Returns (lines scanned, lines matched).  Unparseable lines
/// are hard errors — a trace that fails to parse is a bug, not noise.
pub fn scan(
    path: &Path,
    filter: &TraceFilter,
    mut visit: impl FnMut(&str, &Json),
) -> Result<(usize, usize)> {
    let file =
        File::open(path).with_context(|| format!("opening trace {}", path.display()))?;
    let mut scanned = 0usize;
    let mut matched = 0usize;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line.with_context(|| format!("reading {}", path.display()))?;
        if line.is_empty() {
            continue;
        }
        scanned += 1;
        if !filter.line_may_match(&line) {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| {
            anyhow::anyhow!("{}:{}: bad trace line: {e}", path.display(), lineno + 1)
        })?;
        if filter.matches(&v) {
            matched += 1;
            visit(&line, &v);
        }
    }
    Ok((scanned, matched))
}

/// One-pass roll-up of a trace: event counts by kind, cap changes by
/// cause, round span, distinct sites.
pub fn summarise(path: &Path) -> Result<String> {
    let mut by_kind: BTreeMap<String, u64> = BTreeMap::new();
    let mut by_cause: BTreeMap<String, u64> = BTreeMap::new();
    let mut rounds: Option<(i64, i64)> = None;
    let mut sites: BTreeSet<i64> = BTreeSet::new();
    let (scanned, _) = scan(path, &TraceFilter::default(), |_, v| {
        if let Some(kind) = v.get("kind").and_then(Json::as_str) {
            *by_kind.entry(kind.to_string()).or_insert(0) += 1;
            if kind == "cap_change" {
                if let Some(cause) = v.get("cause").and_then(Json::as_str) {
                    *by_cause.entry(cause.to_string()).or_insert(0) += 1;
                }
            }
        }
        if let Some(r) = field_i64(v, "round") {
            rounds = Some(match rounds {
                Some((a, b)) => (a.min(r), b.max(r)),
                None => (r, r),
            });
        }
        if let Some(s) = field_i64(v, "site") {
            sites.insert(s);
        }
    })?;
    let mut out = String::new();
    out.push_str(&format!("trace: {} events", scanned));
    if let Some((a, b)) = rounds {
        out.push_str(&format!(", rounds {a}..={b}"));
    }
    out.push_str(&format!(", {} sites\n", sites.len()));
    out.push_str("events by kind:\n");
    for (kind, n) in &by_kind {
        out.push_str(&format!("  {kind:<12} {n}\n"));
    }
    if !by_cause.is_empty() {
        out.push_str("cap changes by cause:\n");
        for (cause, n) in &by_cause {
            out.push_str(&format!("  {cause:<15} {n}\n"));
        }
    }
    Ok(out)
}

/// A resolved cap move for `--explain`.
#[derive(Debug, Clone, PartialEq)]
pub struct CapMove {
    pub id: i64,
    pub round: i64,
    pub cause: String,
    pub from: f64,
    pub to: f64,
    pub trigger: Option<i64>,
    /// `round kind detail` summary of the triggering event, when the
    /// trigger id resolved to a recorded event.
    pub trigger_summary: Option<String>,
}

/// Short human summary of one parsed trace event (for trigger lines).
fn event_summary(v: &Json) -> String {
    let kind = v.get("kind").and_then(Json::as_str).unwrap_or("?");
    let round = field_i64(v, "round").unwrap_or(0);
    let mut s = format!("r{round:02} {kind}");
    for key in ["detail", "host", "reason", "fate", "cause"] {
        if let Some(val) = v.get(key).and_then(Json::as_str) {
            s.push_str(&format!(" {val}"));
            break;
        }
    }
    s
}

/// Two-pass causal-chain reconstruction for one site's cap moves.
pub fn explain_site(path: &Path, site: i64) -> Result<Vec<CapMove>> {
    let filter = TraceFilter {
        site: Some(site),
        kind: Some("cap_change".into()),
        ..TraceFilter::default()
    };
    let mut moves: Vec<CapMove> = Vec::new();
    scan(path, &filter, |_, v| {
        moves.push(CapMove {
            id: field_i64(v, "id").unwrap_or(0),
            round: field_i64(v, "round").unwrap_or(0),
            cause: v.get("cause").and_then(Json::as_str).unwrap_or("?").to_string(),
            from: v.get("from").and_then(Json::as_f64).unwrap_or(f64::NAN),
            to: v.get("to").and_then(Json::as_f64).unwrap_or(f64::NAN),
            trigger: field_i64(v, "trigger"),
            trigger_summary: None,
        });
    })?;
    let needed: BTreeSet<i64> = moves.iter().filter_map(|m| m.trigger).collect();
    if !needed.is_empty() {
        let mut resolved: BTreeMap<i64, String> = BTreeMap::new();
        scan(path, &TraceFilter::default(), |_, v| {
            if let Some(id) = field_i64(v, "id") {
                if needed.contains(&id) {
                    resolved.insert(id, event_summary(v));
                }
            }
        })?;
        for m in &mut moves {
            m.trigger_summary = m.trigger.and_then(|t| resolved.get(&t).cloned());
        }
    }
    Ok(moves)
}

/// Render `--explain SITE` output: one line per cap move with its cause
/// and the resolved triggering event.
pub fn explain_report(path: &Path, site: i64) -> Result<String> {
    let moves = explain_site(path, site)?;
    let mut out = format!("site {site}: {} cap changes\n", moves.len());
    for m in &moves {
        out.push_str(&format!(
            "  #{:<5} r{:02}  cap {:>6.3} -> {:>6.3}  {:<15}",
            m.id, m.round, m.from, m.to, m.cause
        ));
        match (&m.trigger, &m.trigger_summary) {
            (Some(t), Some(s)) => out.push_str(&format!("  <= #{t} {s}\n")),
            (Some(t), None) => out.push_str(&format!("  <= #{t} (not in trace)\n")),
            (None, _) => out.push_str("  <= (no recorded trigger)\n"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_temp(name: &str, text: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        path
    }

    const TRACE: &str = "\
{\"id\":1,\"round\":1,\"t_s\":0,\"kind\":\"round_start\"}
{\"id\":2,\"round\":1,\"t_s\":0,\"kind\":\"scenario\",\"site\":2,\"region\":0,\"detail\":\"site 2 outage\"}
{\"id\":3,\"round\":1,\"t_s\":0,\"kind\":\"cap_change\",\"site\":2,\"region\":0,\"cause\":\"water-fill\",\"from\":1,\"to\":0.5,\"trigger\":2}
{\"id\":4,\"round\":2,\"t_s\":150,\"kind\":\"round_start\"}
{\"id\":5,\"round\":2,\"t_s\":150,\"kind\":\"cap_change\",\"site\":12,\"region\":1,\"cause\":\"lease-fallback\",\"from\":0.5,\"to\":0.2,\"trigger\":4}
";

    #[test]
    fn round_range_parsing() {
        assert_eq!(parse_round_range("3..7").unwrap(), (3, 7));
        assert_eq!(parse_round_range("5").unwrap(), (5, 5));
        assert_eq!(parse_round_range("4..").unwrap(), (4, i64::MAX));
        assert_eq!(parse_round_range("..9").unwrap(), (0, 9));
        assert!(parse_round_range("7..3").is_err());
        assert!(parse_round_range("a..b").is_err());
        assert!(parse_round_range("..").is_err());
    }

    #[test]
    fn filters_compose_and_prefilter_never_drops_a_match() {
        let path = write_temp("frost_trace_query_filters.jsonl", TRACE);
        let f = TraceFilter { site: Some(2), ..Default::default() };
        let mut seen = Vec::new();
        let (scanned, matched) =
            scan(&path, &f, |_, v| seen.push(field_i64(v, "id").unwrap())).unwrap();
        assert_eq!(scanned, 5);
        assert_eq!(matched, 2);
        assert_eq!(seen, vec![2, 3]);
        // site 2 must not substring-match site 12's line; site 12 works.
        let f12 = TraceFilter { site: Some(12), ..Default::default() };
        let (_, matched12) = scan(&path, &f12, |_, _| {}).unwrap();
        assert_eq!(matched12, 1);
        let fr = TraceFilter { round: Some((2, 2)), ..Default::default() };
        let (_, mr) = scan(&path, &fr, |_, _| {}).unwrap();
        assert_eq!(mr, 2);
        let fk = TraceFilter { kind: Some("cap_change".into()), ..Default::default() };
        let (_, mk) = scan(&path, &fk, |_, _| {}).unwrap();
        assert_eq!(mk, 2);
        // Region filter (§16): region 0 owns site 2's two events, region 1
        // owns site 12's one; region 9 was never recorded.
        let f0 = TraceFilter { region: Some(0), ..Default::default() };
        let (_, m0) = scan(&path, &f0, |_, _| {}).unwrap();
        assert_eq!(m0, 2);
        let f1 = TraceFilter { region: Some(1), ..Default::default() };
        let (_, m1) = scan(&path, &f1, |_, _| {}).unwrap();
        assert_eq!(m1, 1);
        let f9 = TraceFilter { region: Some(9), ..Default::default() };
        let (_, m9) = scan(&path, &f9, |_, _| {}).unwrap();
        assert_eq!(m9, 0);
    }

    #[test]
    fn summary_counts_kinds_and_causes() {
        let path = write_temp("frost_trace_query_summary.jsonl", TRACE);
        let s = summarise(&path).unwrap();
        assert!(s.contains("5 events"), "{s}");
        assert!(s.contains("rounds 1..=2"), "{s}");
        assert!(s.contains("cap_change"), "{s}");
        assert!(s.contains("water-fill"), "{s}");
        assert!(s.contains("lease-fallback"), "{s}");
    }

    #[test]
    fn explain_resolves_trigger_chains() {
        let path = write_temp("frost_trace_query_explain.jsonl", TRACE);
        let moves = explain_site(&path, 2).unwrap();
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].cause, "water-fill");
        assert_eq!(moves[0].trigger, Some(2));
        assert_eq!(moves[0].trigger_summary.as_deref(), Some("r01 scenario site 2 outage"));
        let report = explain_report(&path, 2).unwrap();
        assert!(report.contains("<= #2 r01 scenario site 2 outage"), "{report}");
        let fallback = explain_site(&path, 12).unwrap();
        assert_eq!(fallback[0].trigger_summary.as_deref(), Some("r02 round_start"));
    }

    #[test]
    fn bad_lines_are_hard_errors() {
        let path = write_temp("frost_trace_query_bad.jsonl", "{\"id\":1\nnot json\n");
        assert!(scan(&path, &TraceFilter::default(), |_, _| {}).is_err());
    }
}
