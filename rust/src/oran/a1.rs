//! The A1 Policy Management Service.
//!
//! Holds energy-policy instances (paper Sec. III-C: ED^mP choices "shaped
//! as policies managed by the A1 Policy Management Service") and
//! distributes create/update/delete over the fabric to subscribed
//! endpoints.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::frost::EnergyPolicy;

use super::bus::Bus;
use super::messages::OranMessage;

/// The policy service, owned by the SMO/non-RT-RIC side.
#[derive(Debug)]
pub struct A1PolicyService {
    bus: Arc<Bus>,
    /// This service's endpoint name on the fabric.
    pub name: String,
    /// Keyed by policy id; BTreeMap so late-subscriber replay (and any
    /// future iteration) runs in a deterministic order.
    policies: BTreeMap<String, EnergyPolicy>,
    subscribers: Vec<String>,
}

impl A1PolicyService {
    pub fn new(bus: Arc<Bus>, name: &str) -> Self {
        bus.endpoint(name);
        A1PolicyService {
            bus,
            name: name.to_string(),
            policies: BTreeMap::new(),
            subscribers: Vec::new(),
        }
    }

    /// Subscribe an endpoint to policy updates (idempotent).
    pub fn subscribe(&mut self, endpoint: &str) {
        if !self.subscribers.iter().any(|s| s == endpoint) {
            self.subscribers.push(endpoint.to_string());
            // Late subscribers receive the current policy set immediately.
            for p in self.policies.values() {
                self.bus.send(&self.name, endpoint, OranMessage::PolicyUpdate(p.clone()));
            }
        }
    }

    /// Create or update a policy instance; pushes to all subscribers.
    pub fn put_policy(&mut self, policy: EnergyPolicy) -> Result<()> {
        policy.validate()?;
        self.policies.insert(policy.id.clone(), policy.clone());
        for s in &self.subscribers {
            self.bus.send(&self.name, s, OranMessage::PolicyUpdate(policy.clone()));
        }
        Ok(())
    }

    /// Delete a policy instance; notifies subscribers.
    pub fn delete_policy(&mut self, id: &str) -> bool {
        let existed = self.policies.remove(id).is_some();
        if existed {
            for s in &self.subscribers {
                self.bus
                    .send(&self.name, s, OranMessage::PolicyDelete { id: id.to_string() });
            }
        }
        existed
    }

    pub fn get(&self, id: &str) -> Option<&EnergyPolicy> {
        self.policies.get(id)
    }

    /// Checkpoint hook (§15): id-ordered policy instances plus the
    /// subscriber list, in subscription order.
    pub fn ckpt_state(&self) -> (Vec<&EnergyPolicy>, &[String]) {
        (self.policies.values().collect(), &self.subscribers)
    }

    /// Restore the state captured by [`Self::ckpt_state`] directly —
    /// deliberately NOT through [`Self::subscribe`]/[`Self::put_policy`],
    /// which would replay the whole policy book onto the fabric and
    /// diverge from the uninterrupted run.
    pub fn restore_ckpt_state(
        &mut self,
        policies: impl IntoIterator<Item = EnergyPolicy>,
        subscribers: Vec<String>,
    ) {
        self.policies = policies.into_iter().map(|p| (p.id.clone(), p)).collect();
        self.subscribers = subscribers;
    }

    pub fn len(&self) -> usize {
        self.policies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.policies.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frost::QosClass;

    #[test]
    fn policies_pushed_to_subscribers() {
        let bus = Bus::new();
        let host = bus.endpoint("host1");
        let mut a1 = A1PolicyService::new(bus.clone(), "a1");
        a1.subscribe("host1");
        a1.put_policy(EnergyPolicy::default_policy()).unwrap();
        bus.deliver_all();
        let msgs = host.drain();
        assert_eq!(msgs.len(), 1);
        assert!(matches!(msgs[0].1, OranMessage::PolicyUpdate(_)));
    }

    #[test]
    fn late_subscriber_receives_current_policies() {
        let bus = Bus::new();
        let mut a1 = A1PolicyService::new(bus.clone(), "a1");
        a1.put_policy(EnergyPolicy::default_policy()).unwrap();
        let host = bus.endpoint("late");
        a1.subscribe("late");
        bus.deliver_all();
        assert_eq!(host.drain().len(), 1);
    }

    #[test]
    fn delete_notifies() {
        let bus = Bus::new();
        let host = bus.endpoint("h");
        let mut a1 = A1PolicyService::new(bus.clone(), "a1");
        a1.subscribe("h");
        a1.put_policy(EnergyPolicy::default_policy()).unwrap();
        assert!(a1.delete_policy("frost-default"));
        assert!(!a1.delete_policy("frost-default"));
        bus.deliver_all();
        let msgs = host.drain();
        assert_eq!(msgs.len(), 2);
        assert!(matches!(msgs[1].1, OranMessage::PolicyDelete { .. }));
    }

    #[test]
    fn invalid_policy_rejected() {
        let bus = Bus::new();
        let mut a1 = A1PolicyService::new(bus, "a1");
        let mut bad = EnergyPolicy::default_policy();
        bad.min_cap_frac = 2.0;
        assert!(a1.put_policy(bad).is_err());
        assert!(a1.is_empty());
    }

    /// A late subscriber's replay must arrive in policy-id order no matter
    /// what order the policies were created in (the old HashMap replayed
    /// in hash order, which varied across processes).
    #[test]
    fn late_replay_order_independent_of_creation_order() {
        let orders: [[&str; 3]; 2] = [["zeta", "alpha", "mid"], ["mid", "zeta", "alpha"]];
        let mut replays: Vec<Vec<String>> = Vec::new();
        for order in orders {
            let bus = Bus::new();
            let mut a1 = A1PolicyService::new(bus.clone(), "a1");
            for id in order {
                let mut p = EnergyPolicy::default_policy();
                p.id = id.to_string();
                a1.put_policy(p).unwrap();
            }
            let host = bus.endpoint("late");
            a1.subscribe("late");
            bus.deliver_all();
            let ids: Vec<String> = host
                .drain()
                .into_iter()
                .map(|(_, msg)| match msg {
                    OranMessage::PolicyUpdate(p) => p.id,
                    other => panic!("unexpected replay message: {other:?}"),
                })
                .collect();
            replays.push(ids);
        }
        assert_eq!(replays[0], vec!["alpha", "mid", "zeta"]);
        assert_eq!(replays[0], replays[1]);
    }

    #[test]
    fn update_overwrites_by_id() {
        let bus = Bus::new();
        let mut a1 = A1PolicyService::new(bus, "a1");
        a1.put_policy(EnergyPolicy::default_policy()).unwrap();
        let mut p2 = EnergyPolicy::default_policy();
        p2.qos = QosClass::LatencyCritical;
        a1.put_policy(p2).unwrap();
        assert_eq!(a1.len(), 1);
        assert_eq!(a1.get("frost-default").unwrap().qos, QosClass::LatencyCritical);
    }
}
