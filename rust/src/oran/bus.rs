//! The in-process message fabric standing in for O-RAN's standardised
//! interfaces.
//!
//! Deterministic by construction: messages are delivered in FIFO order via
//! explicit [`Bus::deliver_all`] pumping, so O-RAN simulations replay
//! bit-for-bit.  (The build environment has no async runtime — the fabric
//! is a from-scratch substrate, DESIGN.md §2.)

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::messages::OranMessage;

/// An addressable fabric endpoint (SMO, a RIC, a host).
#[derive(Debug)]
pub struct Endpoint {
    pub name: String,
    inbox: Mutex<VecDeque<(String, OranMessage)>>,
}

impl Endpoint {
    fn new(name: &str) -> Arc<Self> {
        Arc::new(Endpoint { name: name.to_string(), inbox: Mutex::new(VecDeque::new()) })
    }

    /// Drain all pending messages (sender, message).
    pub fn drain(&self) -> Vec<(String, OranMessage)> {
        self.inbox.lock().unwrap().drain(..).collect()
    }

    pub fn pending(&self) -> usize {
        self.inbox.lock().unwrap().len()
    }
}

/// The fabric: named endpoints + an undelivered queue + statistics.
#[derive(Debug, Default)]
pub struct Bus {
    endpoints: Mutex<HashMap<String, Arc<Endpoint>>>,
    /// (interface name → messages carried), for fabric statistics.
    stats: Mutex<HashMap<&'static str, u64>>,
    /// In-flight messages not yet pumped into inboxes.
    queue: Mutex<VecDeque<(String, String, OranMessage)>>,
}

impl Bus {
    pub fn new() -> Arc<Self> {
        Arc::new(Bus::default())
    }

    /// Register (or fetch) an endpoint by name.
    pub fn endpoint(&self, name: &str) -> Arc<Endpoint> {
        self.endpoints
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Endpoint::new(name))
            .clone()
    }

    /// Queue a message from `from` to `to`.
    pub fn send(&self, from: &str, to: &str, msg: OranMessage) {
        *self.stats.lock().unwrap().entry(msg.interface()).or_insert(0) += 1;
        self.queue.lock().unwrap().push_back((from.to_string(), to.to_string(), msg));
    }

    /// Send one message to several named recipients, in the given order —
    /// the fleet gateway uses this to fan lifecycle events out to both the
    /// SMO and the non-RT RIC (multi-host routing).
    pub fn fanout(&self, from: &str, tos: &[&str], msg: OranMessage) {
        for to in tos {
            self.send(from, to, msg.clone());
        }
    }

    /// Broadcast to every endpoint except the sender.
    pub fn broadcast(&self, from: &str, msg: OranMessage) {
        let names: Vec<String> =
            self.endpoints.lock().unwrap().keys().cloned().collect();
        for to in names {
            if to != from {
                self.send(from, &to, msg.clone());
            }
        }
    }

    /// Pump queued messages into inboxes; returns how many were delivered.
    /// Unknown recipients are dropped (counted as routing failures).
    pub fn deliver_all(&self) -> usize {
        let mut delivered = 0;
        loop {
            let next = self.queue.lock().unwrap().pop_front();
            let Some((from, to, msg)) = next else { break };
            let ep = self.endpoints.lock().unwrap().get(&to).cloned();
            match ep {
                Some(ep) => {
                    ep.inbox.lock().unwrap().push_back((from, msg));
                    delivered += 1;
                }
                None => {
                    *self.stats.lock().unwrap().entry("dropped").or_insert(0) += 1;
                }
            }
        }
        delivered
    }

    /// Per-interface traffic counters.
    pub fn stats(&self) -> HashMap<&'static str, u64> {
        self.stats.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frost::EnergyPolicy;

    #[test]
    fn fifo_delivery() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        let _b = bus.endpoint("b");
        bus.send("b", "a", OranMessage::PolicyDelete { id: "1".into() });
        bus.send("b", "a", OranMessage::PolicyDelete { id: "2".into() });
        assert_eq!(a.pending(), 0, "not delivered before pump");
        assert_eq!(bus.deliver_all(), 2);
        let msgs = a.drain();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].1, OranMessage::PolicyDelete { id: "1".into() });
        assert_eq!(msgs[1].1, OranMessage::PolicyDelete { id: "2".into() });
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn broadcast_excludes_sender() {
        let bus = Bus::new();
        let smo = bus.endpoint("smo");
        let h1 = bus.endpoint("h1");
        let h2 = bus.endpoint("h2");
        bus.broadcast("smo", OranMessage::PolicyUpdate(EnergyPolicy::default_policy()));
        bus.deliver_all();
        assert_eq!(smo.pending(), 0);
        assert_eq!(h1.pending(), 1);
        assert_eq!(h2.pending(), 1);
    }

    #[test]
    fn unknown_recipient_counted_as_dropped() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.send("a", "ghost", OranMessage::PolicyDelete { id: "x".into() });
        bus.deliver_all();
        assert_eq!(bus.stats().get("dropped"), Some(&1));
    }

    #[test]
    fn fanout_reaches_listed_recipients_in_order() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        let b = bus.endpoint("b");
        let _c = bus.endpoint("c");
        bus.fanout("x", &["a", "b"], OranMessage::PolicyDelete { id: "p".into() });
        bus.deliver_all();
        let msgs = a.drain();
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].1, OranMessage::PolicyDelete { id: "p".into() });
        assert_eq!(b.pending(), 1);
        assert_eq!(bus.endpoint("c").pending(), 0, "fanout is not broadcast");
    }

    #[test]
    fn interface_stats_tracked() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.send("x", "a", OranMessage::PolicyUpdate(EnergyPolicy::default_policy()));
        bus.send("x", "a", OranMessage::ProfileRequest { model: "m".into(), host: "a".into() });
        bus.deliver_all();
        let stats = bus.stats();
        assert_eq!(stats.get("A1"), Some(&1));
        assert_eq!(stats.get("O2"), Some(&1));
    }
}
