//! The in-process message fabric standing in for O-RAN's standardised
//! interfaces.
//!
//! Deterministic by construction: messages are delivered in FIFO order via
//! explicit [`Bus::deliver_all`] pumping, so O-RAN simulations replay
//! bit-for-bit.  (The build environment has no async runtime — the fabric
//! is a from-scratch substrate, DESIGN.md §2.)
//!
//! Hot-path design (DESIGN.md §8): endpoint names are **interned** to small
//! integer [`EndpointId`]s backed by an `Arc<str>` reverse table, so the
//! per-message queue entry is `(u32, u32, OranMessage)` and routing a
//! message allocates nothing.  String-keyed [`Bus::send`] survives as the
//! convenience path (two intern-table lookups); fleet-scale callers resolve
//! ids once and use [`Bus::send_ids`].

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::faults::{FabricFate, FaultLedger, FaultPlan};
use super::messages::OranMessage;

/// Interned endpoint identity: an index into the fabric's reverse table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EndpointId(u32);

impl EndpointId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// An addressable fabric endpoint (SMO, a RIC, a host).
#[derive(Debug)]
pub struct Endpoint {
    id: EndpointId,
    name: Arc<str>,
    inbox: Mutex<VecDeque<(Arc<str>, OranMessage)>>,
}

impl Endpoint {
    pub fn id(&self) -> EndpointId {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Drain all pending messages (sender, message).  Senders are shared
    /// `Arc<str>` handles into the fabric's intern table, not fresh copies.
    pub fn drain(&self) -> Vec<(Arc<str>, OranMessage)> {
        self.inbox.lock().unwrap().drain(..).collect()
    }

    pub fn pending(&self) -> usize {
        self.inbox.lock().unwrap().len()
    }

    /// Bound the inbox to `cap` queued messages by dropping the *oldest*
    /// beyond it; returns how many were dropped.  The fleet gateway uses
    /// this so a long site outage cannot grow the hold-back queue without
    /// bound (DESIGN.md §13).
    pub fn truncate_oldest(&self, cap: usize) -> usize {
        let mut inbox = self.inbox.lock().unwrap();
        let excess = inbox.len().saturating_sub(cap);
        inbox.drain(..excess).count()
    }
}

/// Intern table + registered endpoints, behind one lock.
#[derive(Debug, Default)]
struct Directory {
    // frost-lint: allow(R2, reason = "hot-path name-interning table; lookup-only, never iterated")
    ids: HashMap<Arc<str>, EndpointId>,
    /// Reverse table: id → display name.
    names: Vec<Arc<str>>,
    /// Registered inboxes, indexed by id.  Interned-but-unregistered names
    /// (unknown recipients) keep a `None` slot so sends to them still count
    /// as routing failures at delivery time.
    slots: Vec<Option<Arc<Endpoint>>>,
}

impl Directory {
    fn intern(&mut self, name: &str) -> EndpointId {
        if let Some(&id) = self.ids.get(name) {
            return id;
        }
        let id = EndpointId(self.names.len() as u32);
        let shared: Arc<str> = Arc::from(name);
        self.ids.insert(shared.clone(), id);
        self.names.push(shared);
        self.slots.push(None);
        id
    }
}

/// A queued message's destination.  Known names ride as interned ids (the
/// allocation-free hot path); names nobody has interned yet ride as a
/// transient `Arc<str>` that dies with the queue entry — so a stream of
/// sends to bogus recipients cannot grow the intern table without bound,
/// while an endpoint registered between send and pump is still found at
/// delivery time (the pre-interning semantics).
#[derive(Debug)]
enum Recipient {
    Id(EndpointId),
    Pending(Arc<str>),
}

/// Fault-injection state: the installed plan plus the bounded buffer of
/// delayed messages awaiting their due round.
#[derive(Debug, Default)]
struct FaultState {
    plan: Option<FaultPlan>,
    held: Vec<(u32, EndpointId, Recipient, OranMessage)>,
}

/// The fabric: interned endpoints + an undelivered queue + statistics.
#[derive(Debug, Default)]
pub struct Bus {
    dir: Mutex<Directory>,
    /// (interface name → messages carried), for fabric statistics;
    /// BTreeMap so reports iterate in interface-name order.
    stats: Mutex<BTreeMap<&'static str, u64>>,
    /// In-flight messages not yet pumped into inboxes.
    queue: Mutex<VecDeque<(EndpointId, Recipient, OranMessage)>>,
    /// Optional deterministic fault injection (DESIGN.md §13); only the
    /// fleet's *global* bus ever installs a plan, so every fault decision
    /// is made on the coordinator thread.
    fault: Mutex<FaultState>,
}

impl Bus {
    pub fn new() -> Arc<Self> {
        Arc::new(Bus::default())
    }

    /// Intern a name without registering an inbox for it.
    pub fn resolve(&self, name: &str) -> EndpointId {
        self.dir.lock().unwrap().intern(name)
    }

    /// Display name of an interned id (shared handle, no copy).
    pub fn name_of(&self, id: EndpointId) -> Arc<str> {
        self.dir.lock().unwrap().names[id.index()].clone()
    }

    /// Register (or fetch) an endpoint by name.
    pub fn endpoint(&self, name: &str) -> Arc<Endpoint> {
        let mut dir = self.dir.lock().unwrap();
        let id = dir.intern(name);
        if let Some(ep) = &dir.slots[id.index()] {
            return ep.clone();
        }
        let ep = Arc::new(Endpoint {
            id,
            name: dir.names[id.index()].clone(),
            inbox: Mutex::new(VecDeque::new()),
        });
        dir.slots[id.index()] = Some(ep.clone());
        ep
    }

    /// Queue a message from `from` to `to` (name-keyed convenience path).
    /// Senders intern (they are real actors); an unknown recipient does
    /// NOT intern — it travels as a transient name and either finds a
    /// late-registered endpoint at delivery or counts as dropped.
    pub fn send(&self, from: &str, to: &str, msg: OranMessage) {
        let (from, to) = {
            let mut dir = self.dir.lock().unwrap();
            let from = dir.intern(from);
            let to = match dir.ids.get(to) {
                Some(&id) => Recipient::Id(id),
                None => Recipient::Pending(Arc::from(to)),
            };
            (from, to)
        };
        *self.stats.lock().unwrap().entry(msg.interface()).or_insert(0) += 1;
        self.queue.lock().unwrap().push_back((from, to, msg));
    }

    /// Hot path: queue a message between already-interned endpoints — no
    /// name lookups, no allocation beyond the queue slot.
    pub fn send_ids(&self, from: EndpointId, to: EndpointId, msg: OranMessage) {
        *self.stats.lock().unwrap().entry(msg.interface()).or_insert(0) += 1;
        self.queue.lock().unwrap().push_back((from, Recipient::Id(to), msg));
    }

    /// Send one message to several named recipients, in the given order —
    /// the fleet gateway uses this to fan lifecycle events out to both the
    /// SMO and the non-RT RIC (multi-host routing).
    pub fn fanout(&self, from: &str, tos: &[&str], msg: OranMessage) {
        for to in tos {
            self.send(from, to, msg.clone());
        }
    }

    /// Id-keyed [`Bus::fanout`].
    pub fn fanout_ids(&self, from: EndpointId, tos: &[EndpointId], msg: OranMessage) {
        for &to in tos {
            self.send_ids(from, to, msg.clone());
        }
    }

    /// Broadcast to every registered endpoint except the sender, in
    /// registration order (deterministic).
    pub fn broadcast(&self, from: &str, msg: OranMessage) {
        let (from_id, targets) = {
            let mut dir = self.dir.lock().unwrap();
            let from_id = dir.intern(from);
            let targets: Vec<EndpointId> = dir
                .slots
                .iter()
                .enumerate()
                .filter(|(_, slot)| slot.is_some())
                .map(|(i, _)| EndpointId(i as u32))
                .filter(|&id| id != from_id)
                .collect();
            (from_id, targets)
        };
        for to in targets {
            self.send_ids(from_id, to, msg.clone());
        }
    }

    /// Install (or clear) a deterministic fault plan.  Replacing a plan
    /// discards any still-held delayed messages.
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        let mut fault = self.fault.lock().unwrap();
        fault.plan = plan;
        fault.held.clear();
    }

    /// Advance the installed fault plan to the next fleet round and
    /// re-enqueue every held-back message whose delay has elapsed (in
    /// hold order, ahead of traffic queued later this round).  A no-op
    /// without a plan.
    pub fn advance_fault_round(&self) {
        let mut fault = self.fault.lock().unwrap();
        let FaultState { plan, held } = &mut *fault;
        let Some(plan) = plan.as_mut() else { return };
        plan.begin_round();
        let round = plan.round();
        let mut released = 0u64;
        let mut still = Vec::with_capacity(held.len());
        {
            let mut queue = self.queue.lock().unwrap();
            for (due, from, to, msg) in held.drain(..) {
                if due <= round {
                    queue.push_back((from, to, msg));
                    released += 1;
                } else {
                    still.push((due, from, to, msg));
                }
            }
        }
        *held = still;
        plan.note_released(released);
    }

    /// Snapshot of the installed plan's fault ledger (None without one).
    pub fn fault_ledger(&self) -> Option<FaultLedger> {
        self.fault.lock().unwrap().plan.as_ref().map(|p| p.ledger().clone())
    }

    /// Drain the installed plan's buffered fault-trace records (§14):
    /// `(fate, interface, count)` in injection order.  Empty without a
    /// plan or with tracing off.  Called once per round by the fleet
    /// coordinator, which owns the only armed (global) bus.
    pub fn drain_fault_trace(&self) -> Vec<(&'static str, &'static str, u64)> {
        self.fault
            .lock()
            .unwrap()
            .plan
            .as_mut()
            .map(FaultPlan::drain_trace)
            .unwrap_or_default()
    }

    /// The per-message fault key: sender id mixed with the recipient
    /// (interned index, or a stable hash for not-yet-interned names).
    fn edge_of(from: EndpointId, to: &Recipient) -> u64 {
        let to64 = match to {
            Recipient::Id(id) => id.index() as u64,
            Recipient::Pending(name) => fnv1a64(name.as_bytes()) | (1 << 63),
        };
        ((from.index() as u64) << 32) ^ to64
    }

    /// Route one message to its (possibly late-registered) endpoint;
    /// returns 1 on delivery, 0 on a routing failure.
    fn deliver_one(&self, from: EndpointId, to: &Recipient, msg: OranMessage) -> usize {
        let (sender, ep) = {
            let dir = self.dir.lock().unwrap();
            let ep = match to {
                Recipient::Id(id) => dir.slots[id.index()].clone(),
                // Delivery-time lookup: the endpoint may have been
                // registered after the send.
                Recipient::Pending(name) => dir
                    .ids
                    .get(&**name)
                    .and_then(|id| dir.slots[id.index()].clone()),
            };
            (dir.names[from.index()].clone(), ep)
        };
        match ep {
            Some(ep) => {
                ep.inbox.lock().unwrap().push_back((sender, msg));
                1
            }
            None => {
                *self.stats.lock().unwrap().entry("dropped").or_insert(0) += 1;
                0
            }
        }
    }

    /// Pump queued messages into inboxes; returns how many were delivered.
    /// Unknown recipients are dropped (counted as routing failures).
    ///
    /// With a fault plan installed and armed, every popped message is
    /// examined once: it may be corrupted in place, dropped, held back
    /// for future rounds, duplicated, or deferred behind everything else
    /// pumped this pass.  Deferred (reordered) messages deliver
    /// unconditionally once the main queue drains, so the pump always
    /// terminates.
    pub fn deliver_all(&self) -> usize {
        let mut delivered = 0;
        let mut reorder_tail: Vec<(EndpointId, Recipient, OranMessage)> = Vec::new();
        loop {
            let next = self.queue.lock().unwrap().pop_front();
            let Some((from, to, mut msg)) = next else { break };
            let mut duplicate = false;
            {
                let mut fault = self.fault.lock().unwrap();
                let FaultState { plan, held } = &mut *fault;
                if let Some(plan) = plan.as_mut() {
                    if plan.armed() {
                        match plan.apply(Bus::edge_of(from, &to), &mut msg) {
                            FabricFate::Deliver => {}
                            FabricFate::Drop => continue,
                            FabricFate::DelayRounds(rounds) => {
                                if held.len() >= plan.max_held() {
                                    plan.note_delay_dropped(msg.interface());
                                } else {
                                    plan.note_delayed(msg.interface());
                                    held.push((plan.round() + rounds, from, to, msg));
                                }
                                continue;
                            }
                            FabricFate::Duplicate => duplicate = true,
                            FabricFate::Reorder => {
                                reorder_tail.push((from, to, msg));
                                continue;
                            }
                        }
                    }
                }
            }
            if duplicate {
                delivered += self.deliver_one(from, &to, msg.clone());
            }
            delivered += self.deliver_one(from, &to, msg);
        }
        for (from, to, msg) in reorder_tail {
            delivered += self.deliver_one(from, &to, msg);
        }
        delivered
    }

    /// Per-interface traffic counters, interface-name ordered.
    pub fn stats(&self) -> BTreeMap<&'static str, u64> {
        self.stats.lock().unwrap().clone()
    }

    // ---------------------------------------------- checkpoint hooks (§15)
    //
    // Messages cross the snapshot boundary **name-keyed**: numeric
    // `EndpointId`s are intern-order artifacts of one process, but the
    // fabric is constructed deterministically, so after reconstruction the
    // same names resolve to the same ids.  The pending-vs-interned
    // distinction of each recipient is preserved explicitly — it feeds the
    // fault-edge key ([`Bus::edge_of`]), so collapsing a `Pending` name to
    // an id would change downstream fault draws.

    /// The undelivered queue: `(from, to, pending, message)` in FIFO order.
    pub fn ckpt_queue(&self) -> Vec<(Arc<str>, Arc<str>, bool, OranMessage)> {
        let dir = self.dir.lock().unwrap();
        self.queue
            .lock()
            .unwrap()
            .iter()
            .map(|(from, to, msg)| {
                let (to, pending) = match to {
                    Recipient::Id(id) => (dir.names[id.index()].clone(), false),
                    Recipient::Pending(name) => (name.clone(), true),
                };
                (dir.names[from.index()].clone(), to, pending, msg.clone())
            })
            .collect()
    }

    /// Replace the undelivered queue with checkpointed contents
    /// (discarding anything construction left queued — the original run
    /// had already pumped it by the snapshot round).
    pub fn restore_ckpt_queue(
        &self,
        items: impl IntoIterator<Item = (Arc<str>, Arc<str>, bool, OranMessage)>,
    ) {
        let mut dir = self.dir.lock().unwrap();
        let mut queue = self.queue.lock().unwrap();
        queue.clear();
        for (from, to, pending, msg) in items {
            let from = dir.intern(&from);
            let to = if pending {
                Recipient::Pending(to)
            } else {
                Recipient::Id(dir.intern(&to))
            };
            queue.push_back((from, to, msg));
        }
    }

    /// Delay-held messages: `(due_round, from, to, pending, message)` in
    /// hold order.
    pub fn ckpt_held(&self) -> Vec<(u32, Arc<str>, Arc<str>, bool, OranMessage)> {
        let dir = self.dir.lock().unwrap();
        self.fault
            .lock()
            .unwrap()
            .held
            .iter()
            .map(|(due, from, to, msg)| {
                let (to, pending) = match to {
                    Recipient::Id(id) => (dir.names[id.index()].clone(), false),
                    Recipient::Pending(name) => (name.clone(), true),
                };
                (*due, dir.names[from.index()].clone(), to, pending, msg.clone())
            })
            .collect()
    }

    /// Restore the delay-hold buffer.  Must run AFTER the fault plan is
    /// installed — [`Bus::set_fault_plan`] clears `held`.
    pub fn restore_ckpt_held(
        &self,
        items: impl IntoIterator<Item = (u32, Arc<str>, Arc<str>, bool, OranMessage)>,
    ) {
        let mut dir = self.dir.lock().unwrap();
        let mut fault = self.fault.lock().unwrap();
        fault.held.clear();
        for (due, from, to, pending, msg) in items {
            let from = dir.intern(&from);
            let to = if pending {
                Recipient::Pending(to)
            } else {
                Recipient::Id(dir.intern(&to))
            };
            fault.held.push((due, from, to, msg));
        }
    }

    /// Delivered-but-undrained inbox contents, registration-ordered:
    /// `(endpoint, [(sender, message)])` for every non-empty inbox.
    pub fn ckpt_inboxes(&self) -> Vec<(Arc<str>, Vec<(Arc<str>, OranMessage)>)> {
        let dir = self.dir.lock().unwrap();
        let mut out = Vec::new();
        for slot in dir.slots.iter().flatten() {
            let inbox = slot.inbox.lock().unwrap();
            if !inbox.is_empty() {
                out.push((slot.name.clone(), inbox.iter().cloned().collect()));
            }
        }
        out
    }

    /// Clear every registered inbox, then refill the named ones with
    /// checkpointed contents.
    pub fn restore_ckpt_inboxes(
        &self,
        items: impl IntoIterator<Item = (Arc<str>, Vec<(Arc<str>, OranMessage)>)>,
    ) {
        {
            let dir = self.dir.lock().unwrap();
            for slot in dir.slots.iter().flatten() {
                slot.inbox.lock().unwrap().clear();
            }
        }
        for (name, msgs) in items {
            let ep = self.endpoint(&name);
            let mut inbox = ep.inbox.lock().unwrap();
            for (sender, msg) in msgs {
                // Senders re-intern so the restored handle shares the
                // fabric's table like a delivered message would.
                let sender = {
                    let mut dir = self.dir.lock().unwrap();
                    let id = dir.intern(&sender);
                    dir.names[id.index()].clone()
                };
                inbox.push_back((sender, msg));
            }
        }
    }

    /// Replace the per-interface statistics with checkpointed counters.
    pub fn restore_ckpt_stats(&self, stats: impl IntoIterator<Item = (&'static str, u64)>) {
        *self.stats.lock().unwrap() = stats.into_iter().collect();
    }

    /// The installed fault plan's live cursors and ledger (None without a
    /// plan).
    pub fn ckpt_fault_state(&self) -> Option<(u32, u64, FaultLedger)> {
        self.fault.lock().unwrap().plan.as_ref().map(FaultPlan::ckpt_state)
    }

    /// Restore the installed fault plan's cursors and ledger.  A no-op
    /// without a plan (the config that reconstructs the bus decides
    /// whether one is installed).
    pub fn restore_ckpt_fault_state(&self, round: u32, seq: u64, ledger: FaultLedger) {
        if let Some(plan) = self.fault.lock().unwrap().plan.as_mut() {
            plan.restore_ckpt_state(round, seq, ledger);
        }
    }
}

/// FNV-1a 64-bit: a stable, dependency-free hash for fault-edge keys of
/// recipients nobody has interned yet.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frost::EnergyPolicy;

    #[test]
    fn fifo_delivery() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        let _b = bus.endpoint("b");
        bus.send("b", "a", OranMessage::PolicyDelete { id: "1".into() });
        bus.send("b", "a", OranMessage::PolicyDelete { id: "2".into() });
        assert_eq!(a.pending(), 0, "not delivered before pump");
        assert_eq!(bus.deliver_all(), 2);
        let msgs = a.drain();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].1, OranMessage::PolicyDelete { id: "1".into() });
        assert_eq!(msgs[1].1, OranMessage::PolicyDelete { id: "2".into() });
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn interning_is_stable_and_names_round_trip() {
        let bus = Bus::new();
        let a = bus.resolve("alpha");
        let b = bus.resolve("beta");
        assert_ne!(a, b);
        assert_eq!(bus.resolve("alpha"), a, "same name, same id");
        assert_eq!(&*bus.name_of(a), "alpha");
        assert_eq!(&*bus.name_of(b), "beta");
        // Registration reuses the interned id and the shared name.
        let ep = bus.endpoint("alpha");
        assert_eq!(ep.id(), a);
        assert_eq!(ep.name(), "alpha");
    }

    #[test]
    fn id_send_is_equivalent_to_name_send() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        let from = bus.resolve("x");
        bus.send_ids(from, a.id(), OranMessage::PolicyDelete { id: "p".into() });
        bus.deliver_all();
        let msgs = a.drain();
        assert_eq!(msgs.len(), 1);
        assert_eq!(&*msgs[0].0, "x", "sender name resolves via reverse table");
    }

    #[test]
    fn broadcast_excludes_sender() {
        let bus = Bus::new();
        let smo = bus.endpoint("smo");
        let h1 = bus.endpoint("h1");
        let h2 = bus.endpoint("h2");
        bus.broadcast("smo", OranMessage::PolicyUpdate(EnergyPolicy::default_policy()));
        bus.deliver_all();
        assert_eq!(smo.pending(), 0);
        assert_eq!(h1.pending(), 1);
        assert_eq!(h2.pending(), 1);
    }

    #[test]
    fn unknown_recipient_counted_as_dropped() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.send("a", "ghost", OranMessage::PolicyDelete { id: "x".into() });
        bus.deliver_all();
        assert_eq!(bus.stats().get("dropped"), Some(&1));
        // Registering after the drop starts fresh: nothing was delivered.
        assert_eq!(bus.endpoint("ghost").pending(), 0);
    }

    #[test]
    fn late_registration_still_receives_queued_messages() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.send("a", "late", OranMessage::PolicyDelete { id: "x".into() });
        let late = bus.endpoint("late"); // registered after the send
        assert_eq!(bus.deliver_all(), 1);
        assert_eq!(late.pending(), 1);
        assert_eq!(bus.stats().get("dropped"), None);
    }

    #[test]
    fn fanout_reaches_listed_recipients_in_order() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        let b = bus.endpoint("b");
        let _c = bus.endpoint("c");
        bus.fanout("x", &["a", "b"], OranMessage::PolicyDelete { id: "p".into() });
        bus.deliver_all();
        let msgs = a.drain();
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].1, OranMessage::PolicyDelete { id: "p".into() });
        assert_eq!(b.pending(), 1);
        assert_eq!(bus.endpoint("c").pending(), 0, "fanout is not broadcast");
    }

    #[test]
    fn interface_stats_tracked() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.send("x", "a", OranMessage::PolicyUpdate(EnergyPolicy::default_policy()));
        bus.send("x", "a", OranMessage::ProfileRequest { model: "m".into(), host: "a".into() });
        bus.deliver_all();
        let stats = bus.stats();
        assert_eq!(stats.get("A1"), Some(&1));
        assert_eq!(stats.get("O2"), Some(&1));
    }

    // ------------------------------------------------- fault injection

    use crate::oran::faults::{FaultConfig, FaultPlan};

    fn plan(cfg: FaultConfig) -> FaultPlan {
        FaultPlan::new(cfg).unwrap()
    }

    #[test]
    fn drop_all_plan_loses_every_message() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        bus.set_fault_plan(Some(plan(FaultConfig {
            drop_p: 1.0,
            ..FaultConfig::default()
        })));
        bus.advance_fault_round();
        bus.send("x", "a", OranMessage::PolicyDelete { id: "1".into() });
        bus.send("x", "a", OranMessage::PolicyDelete { id: "2".into() });
        assert_eq!(bus.deliver_all(), 0);
        assert_eq!(a.pending(), 0);
        assert_eq!(bus.fault_ledger().unwrap().dropped, 2);
    }

    #[test]
    fn delayed_messages_release_after_their_rounds_elapse() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        bus.set_fault_plan(Some(plan(FaultConfig {
            delay_p: 1.0,
            max_delay_rounds: 1,
            ..FaultConfig::default()
        })));
        bus.advance_fault_round(); // round 1
        bus.send("x", "a", OranMessage::PolicyDelete { id: "1".into() });
        assert_eq!(bus.deliver_all(), 0, "held back");
        assert_eq!(bus.fault_ledger().unwrap().delayed, 1);
        bus.advance_fault_round(); // round 2: due
        assert_eq!(bus.deliver_all(), 1);
        assert_eq!(a.drain().len(), 1);
        assert_eq!(bus.fault_ledger().unwrap().released, 1);
    }

    #[test]
    fn delay_buffer_is_bounded_and_overflow_is_ledgered() {
        let bus = Bus::new();
        let _a = bus.endpoint("a");
        bus.set_fault_plan(Some(plan(FaultConfig {
            delay_p: 1.0,
            max_delay_rounds: 5,
            max_held: 2,
            ..FaultConfig::default()
        })));
        bus.advance_fault_round();
        for i in 0..5 {
            bus.send("x", "a", OranMessage::PolicyDelete { id: format!("{i}") });
        }
        assert_eq!(bus.deliver_all(), 0);
        let ledger = bus.fault_ledger().unwrap();
        assert_eq!(ledger.delayed, 2, "buffer holds only max_held");
        assert_eq!(ledger.delay_dropped, 3, "overflow dropped, not stored");
    }

    #[test]
    fn duplicated_messages_arrive_twice() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        bus.set_fault_plan(Some(plan(FaultConfig {
            dup_p: 1.0,
            ..FaultConfig::default()
        })));
        bus.advance_fault_round();
        bus.send("x", "a", OranMessage::PolicyDelete { id: "1".into() });
        assert_eq!(bus.deliver_all(), 2);
        assert_eq!(a.drain().len(), 2);
        assert_eq!(bus.fault_ledger().unwrap().duplicated, 1);
    }

    #[test]
    fn reordered_messages_defer_behind_the_rest_of_the_pump() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        // Reorder everything examined in rounds >= 1; the tail preserves
        // its own relative order, so with reorder_p = 1.0 the pump
        // delivers the full queue in original order via the tail — prove
        // deferral with a mixed plan instead: only the A1 interface is
        // scoped, so the O2 message overtakes the reordered A1 one.
        bus.set_fault_plan(Some(plan(FaultConfig {
            reorder_p: 1.0,
            fault_o2: false,
            ..FaultConfig::default()
        })));
        bus.advance_fault_round();
        bus.send("x", "a", OranMessage::PolicyDelete { id: "first".into() });
        bus.send("x", "a", OranMessage::ProfileRequest { model: "m".into(), host: "a".into() });
        assert_eq!(bus.deliver_all(), 2);
        let msgs = a.drain();
        assert!(matches!(msgs[0].1, OranMessage::ProfileRequest { .. }), "{msgs:?}");
        assert!(matches!(msgs[1].1, OranMessage::PolicyDelete { .. }), "{msgs:?}");
        assert_eq!(bus.fault_ledger().unwrap().reordered, 1);
    }

    #[test]
    fn inert_plan_leaves_delivery_identical() {
        let run = |with_plan: bool| -> Vec<(String, OranMessage)> {
            let bus = Bus::new();
            let a = bus.endpoint("a");
            if with_plan {
                bus.set_fault_plan(Some(plan(FaultConfig::default())));
            }
            bus.advance_fault_round();
            for i in 0..4 {
                bus.send("x", "a", OranMessage::PolicyDelete { id: format!("{i}") });
            }
            bus.deliver_all();
            a.drain().into_iter().map(|(s, m)| (s.to_string(), m)).collect()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn truncate_oldest_bounds_an_inbox_from_the_front() {
        let bus = Bus::new();
        let a = bus.endpoint("a");
        for i in 0..5 {
            bus.send("x", "a", OranMessage::PolicyDelete { id: format!("{i}") });
        }
        bus.deliver_all();
        assert_eq!(a.truncate_oldest(2), 3);
        let msgs = a.drain();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].1, OranMessage::PolicyDelete { id: "3".into() });
        assert_eq!(msgs[1].1, OranMessage::PolicyDelete { id: "4".into() });
        assert_eq!(a.truncate_oldest(2), 0, "under the cap is a no-op");
    }
}
