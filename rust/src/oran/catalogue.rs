//! The AI/ML model catalogue (paper Sec. II-B).
//!
//! Trained models are validated at the non-RT RIC and, if they pass,
//! published here; inference hosts deploy from the catalogue; the SMO can
//! flag entries for replacement, pulling a new version.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

/// Lifecycle state of a catalogue entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelState {
    Trained,
    Validated,
    Published,
    Deployed,
    FlaggedForUpdate,
    Retired,
}

impl ModelState {
    /// Legal state transitions of the catalogue workflow.
    pub fn can_transition_to(self, next: ModelState) -> bool {
        use ModelState::*;
        matches!(
            (self, next),
            (Trained, Validated)
                | (Validated, Published)
                | (Published, Deployed)
                | (Deployed, FlaggedForUpdate)
                | (FlaggedForUpdate, Retired)
                | (FlaggedForUpdate, Deployed)   // updated in place
                | (Deployed, Retired)
                | (Published, Retired)
        )
    }
}

/// One catalogue entry.
#[derive(Debug, Clone, PartialEq)]
pub struct CatalogueEntry {
    pub name: String,
    pub version: u32,
    pub state: ModelState,
    pub validation_accuracy: f64,
    /// Optimal power cap discovered by FROST (None before profiling).
    pub optimal_cap: Option<f64>,
    /// Artifact backing the model, when it is a real trainable one.
    pub artifact: Option<String>,
}

/// The catalogue itself.
#[derive(Debug, Default)]
pub struct ModelCatalogue {
    /// Keyed by model name; BTreeMap so listings iterate name-ordered
    /// regardless of registration order.
    entries: BTreeMap<String, CatalogueEntry>,
    /// Validation threshold: models below it are rejected for publishing.
    pub min_accuracy: f64,
}

impl ModelCatalogue {
    pub fn new(min_accuracy: f64) -> Self {
        ModelCatalogue { entries: BTreeMap::new(), min_accuracy }
    }

    /// Register a freshly trained model (state = Trained, version 1 or bump).
    pub fn register_trained(
        &mut self,
        name: &str,
        accuracy: f64,
        artifact: Option<String>,
    ) -> &CatalogueEntry {
        let version = self.entries.get(name).map(|e| e.version + 1).unwrap_or(1);
        self.entries.insert(
            name.to_string(),
            CatalogueEntry {
                name: name.to_string(),
                version,
                state: ModelState::Trained,
                validation_accuracy: accuracy,
                optimal_cap: None,
                artifact,
            },
        );
        &self.entries[name]
    }

    /// Validate: passes iff accuracy ≥ threshold; moves to Validated or
    /// leaves the model Trained (flagged for retraining by the caller).
    pub fn validate(&mut self, name: &str) -> Result<bool> {
        let min_acc = self.min_accuracy;
        let e = self.entry_mut(name)?;
        let passed = e.validation_accuracy >= min_acc;
        if passed {
            e.state = ModelState::Validated;
        }
        Ok(passed)
    }

    pub fn publish(&mut self, name: &str) -> Result<()> {
        self.transition(name, ModelState::Published)
    }

    pub fn mark_deployed(&mut self, name: &str) -> Result<()> {
        self.transition(name, ModelState::Deployed)
    }

    pub fn flag_for_update(&mut self, name: &str) -> Result<()> {
        self.transition(name, ModelState::FlaggedForUpdate)
    }

    pub fn retire(&mut self, name: &str) -> Result<()> {
        self.transition(name, ModelState::Retired)
    }

    /// Record FROST's profiling decision on the entry.
    pub fn set_optimal_cap(&mut self, name: &str, cap: f64) -> Result<()> {
        self.entry_mut(name)?.optimal_cap = Some(cap);
        Ok(())
    }

    /// Forget the recorded cap so the profile scheduler re-requests it
    /// under its stagger — how demand-shift re-profiling is routed
    /// without stampeding the fleet (DESIGN.md §9).
    pub fn clear_optimal_cap(&mut self, name: &str) -> Result<()> {
        self.entry_mut(name)?.optimal_cap = None;
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&CatalogueEntry> {
        self.entries.get(name)
    }

    /// All entries deployable right now (Published), in name order
    /// (BTreeMap keys are the names).
    pub fn published(&self) -> Vec<&CatalogueEntry> {
        self.entries.values().filter(|e| e.state == ModelState::Published).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Checkpoint hook (§15): name-ordered entry iteration (BTreeMap
    /// order, so the snapshot bytes are deterministic).
    pub fn ckpt_entries(&self) -> impl Iterator<Item = &CatalogueEntry> {
        self.entries.values()
    }

    /// Restore the entry map captured by [`Self::ckpt_entries`]
    /// (`min_accuracy` comes from reconstruction, not the snapshot).
    pub fn restore_ckpt_state(&mut self, entries: impl IntoIterator<Item = CatalogueEntry>) {
        self.entries = entries.into_iter().map(|e| (e.name.clone(), e)).collect();
    }

    fn entry_mut(&mut self, name: &str) -> Result<&mut CatalogueEntry> {
        self.entries.get_mut(name).with_context(|| format!("model '{name}' not in catalogue"))
    }

    fn transition(&mut self, name: &str, next: ModelState) -> Result<()> {
        let e = self.entry_mut(name)?;
        anyhow::ensure!(
            e.state.can_transition_to(next),
            "illegal transition {:?} -> {:?} for '{name}'",
            e.state,
            next
        );
        e.state = next;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path_to_deployment() {
        let mut cat = ModelCatalogue::new(0.9);
        cat.register_trained("resnet", 0.955, Some("resnet_mini".into()));
        assert!(cat.validate("resnet").unwrap());
        cat.publish("resnet").unwrap();
        assert_eq!(cat.published().len(), 1);
        cat.mark_deployed("resnet").unwrap();
        assert_eq!(cat.get("resnet").unwrap().state, ModelState::Deployed);
    }

    #[test]
    fn low_accuracy_fails_validation() {
        let mut cat = ModelCatalogue::new(0.9);
        cat.register_trained("lenet", 0.754, None);
        assert!(!cat.validate("lenet").unwrap());
        assert_eq!(cat.get("lenet").unwrap().state, ModelState::Trained);
        // Publishing an unvalidated model must be rejected.
        assert!(cat.publish("lenet").is_err());
    }

    #[test]
    fn version_bumps_on_retrain() {
        let mut cat = ModelCatalogue::new(0.5);
        cat.register_trained("m", 0.8, None);
        assert_eq!(cat.get("m").unwrap().version, 1);
        cat.register_trained("m", 0.85, None);
        assert_eq!(cat.get("m").unwrap().version, 2);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut cat = ModelCatalogue::new(0.5);
        cat.register_trained("m", 0.9, None);
        assert!(cat.mark_deployed("m").is_err()); // Trained -> Deployed skips steps
        assert!(cat.retire("m").is_err());
        assert!(cat.flag_for_update("missing").is_err());
    }

    #[test]
    fn update_cycle() {
        let mut cat = ModelCatalogue::new(0.5);
        cat.register_trained("m", 0.9, None);
        cat.validate("m").unwrap();
        cat.publish("m").unwrap();
        cat.mark_deployed("m").unwrap();
        cat.flag_for_update("m").unwrap();
        cat.mark_deployed("m").unwrap(); // replaced in place
        cat.set_optimal_cap("m", 0.6).unwrap();
        assert_eq!(cat.get("m").unwrap().optimal_cap, Some(0.6));
    }

    /// Listing order must depend only on the entry names, never on the
    /// order models were registered in (the old HashMap leaked insertion/
    /// hash order into `published()` before its explicit sort was added;
    /// the BTreeMap makes the whole structure order-stable).
    #[test]
    fn listing_order_independent_of_registration_order() {
        let orders: [[&str; 4]; 3] = [
            ["resnet", "lenet", "mobilenet", "bert"],
            ["bert", "mobilenet", "lenet", "resnet"],
            ["lenet", "bert", "resnet", "mobilenet"],
        ];
        let mut listings: Vec<Vec<String>> = Vec::new();
        for order in orders {
            let mut cat = ModelCatalogue::new(0.5);
            for name in order {
                cat.register_trained(name, 0.9, None);
                cat.validate(name).unwrap();
                cat.publish(name).unwrap();
            }
            listings.push(cat.published().iter().map(|e| e.name.clone()).collect());
        }
        assert_eq!(listings[0], vec!["bert", "lenet", "mobilenet", "resnet"]);
        assert_eq!(listings[0], listings[1]);
        assert_eq!(listings[0], listings[2]);
    }
}
