//! Deterministic fault injection for the O-RAN fabric (DESIGN.md §13).
//!
//! A [`FaultPlan`] is installed on the **global** bus only (the
//! coordinator-pumped fabric between the SMO/RICs and the site gateways).
//! Site-local buses carry no plan, so every fault decision is made on the
//! coordinator thread while the global queue's contents are already
//! settled in site-index order — thread-count determinism (§6) falls out
//! for free, exactly as it does for the scenario engine.
//!
//! Decisions are **stateless per message**: each examined message derives
//! a fresh [`Pcg32`] from `(seed, edge, round, seq)`, where `edge` mixes
//! the sender/recipient ids and `seq` counts messages examined this
//! round.  A disabled or all-zero plan constructs *no* generator and
//! mutates nothing, so a zero-fault plan is bit-identical to running with
//! no plan at all — the same guarantee the scenario engine makes for a
//! rate multiplier of exactly 1.0.
//!
//! Fabric faults (drop / delay-by-rounds / duplicate / reorder) apply per
//! interface (A1/O1/O2); telemetry corruption (NaN KPMs, stale
//! timestamps, NVML read failures) mutates `Kpm` payloads in place.  The
//! mechanics of delaying and reordering live in the bus; the plan only
//! decides fates and keeps the [`FaultLedger`].

use anyhow::Result;

use crate::util::rng::Pcg32;
use crate::util::Seconds;

use super::messages::OranMessage;

/// Names of the built-in chaos presets, in `frost chaos` help order.
pub const CHAOS_PRESETS: [&str; 4] =
    ["lossy-fabric", "slow-fabric", "liar-telemetry", "profile-flaps"];

/// Golden-ratio mix constant (same family the fleet's `site_seed` uses).
const MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// How far a stale-timestamp corruption shifts a KPM backwards (seconds).
/// Large enough that any previously accepted report outranks it.
const STALE_SHIFT_S: f64 = 1.0e7;

/// A seeded description of how unreliable the fabric is.
///
/// Probabilities are per message.  The four fabric fates are branches of
/// one uniform draw, so their sum must stay ≤ 1.  Corruption applies to
/// `Kpm` payloads only and is drawn independently of the fabric fate.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed for every per-message generator (mixed with edge/round/seq).
    pub seed: u64,
    /// P(message silently dropped).
    pub drop_p: f64,
    /// P(message held back for 1..=`max_delay_rounds` rounds).
    pub delay_p: f64,
    /// Upper bound on the per-message delay, in fleet rounds.
    pub max_delay_rounds: u32,
    /// P(message delivered twice).
    pub dup_p: f64,
    /// P(message deferred behind everything else pumped this pass).
    pub reorder_p: f64,
    /// P(KPM fields blanked to NaN).
    pub kpm_nan_p: f64,
    /// P(KPM timestamp shifted far into the past).
    pub kpm_stale_p: f64,
    /// P(KPM power reads like a failed NVML call: negative sentinel).
    pub nvml_fail_p: f64,
    /// First fleet round (1-based, inclusive) the plan is active.
    pub start_round: u32,
    /// Last fleet round (inclusive) the plan is active.
    pub end_round: u32,
    /// Bound on the delayed-message buffer; overflow drops the message
    /// (ledgered as `delay_dropped`) instead of growing without bound.
    pub max_held: usize,
    /// Which interfaces the fabric fates apply to.
    pub fault_a1: bool,
    pub fault_o1: bool,
    pub fault_o2: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            drop_p: 0.0,
            delay_p: 0.0,
            max_delay_rounds: 1,
            dup_p: 0.0,
            reorder_p: 0.0,
            kpm_nan_p: 0.0,
            kpm_stale_p: 0.0,
            nvml_fail_p: 0.0,
            start_round: 1,
            end_round: u32::MAX,
            max_held: 1024,
            fault_a1: true,
            fault_o1: true,
            fault_o2: true,
        }
    }
}

impl FaultConfig {
    /// Build a named chaos preset.  The window defaults to the whole run;
    /// harnesses narrow it so invariants can be checked over a quiet tail.
    pub fn preset(name: &str, seed: u64) -> Result<FaultConfig> {
        let base = FaultConfig { seed, ..FaultConfig::default() };
        let cfg = match name {
            // Every interface loses a quarter of its messages, some
            // arrive twice, some arrive late within the same pump.
            "lossy-fabric" => FaultConfig {
                drop_p: 0.25,
                dup_p: 0.05,
                reorder_p: 0.10,
                ..base
            },
            // Nothing is lost but a third of the fabric runs rounds
            // behind, with in-pump reordering on top.
            "slow-fabric" => FaultConfig {
                delay_p: 0.35,
                max_delay_rounds: 3,
                reorder_p: 0.10,
                ..base
            },
            // The fabric is perfect; the telemetry lies.
            "liar-telemetry" => FaultConfig {
                kpm_nan_p: 0.15,
                kpm_stale_p: 0.15,
                nvml_fail_p: 0.10,
                ..base
            },
            // Only the O2 profiling plane flaps: requests and results
            // vanish until the retry/quarantine machinery gives up.
            "profile-flaps" => FaultConfig {
                drop_p: 0.45,
                fault_a1: false,
                fault_o1: false,
                ..base
            },
            other => anyhow::bail!(
                "unknown chaos preset '{other}' (expected one of: {})",
                CHAOS_PRESETS.join(", ")
            ),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject malformed plans: non-finite or out-of-range probabilities,
    /// fabric fates that sum past 1, empty windows, or a delay with no
    /// room to hold anything.  Hard errors, never clamps (§6).
    pub fn validate(&self) -> Result<()> {
        let probs = [
            ("drop_p", self.drop_p),
            ("delay_p", self.delay_p),
            ("dup_p", self.dup_p),
            ("reorder_p", self.reorder_p),
            ("kpm_nan_p", self.kpm_nan_p),
            ("kpm_stale_p", self.kpm_stale_p),
            ("nvml_fail_p", self.nvml_fail_p),
        ];
        for (name, p) in probs {
            anyhow::ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "{name} = {p} must be a probability in [0, 1]"
            );
        }
        let fabric = self.drop_p + self.delay_p + self.dup_p + self.reorder_p;
        anyhow::ensure!(
            fabric <= 1.0 + 1e-12,
            "fabric fate probabilities sum to {fabric}, must be <= 1"
        );
        let corrupt = self.kpm_nan_p + self.kpm_stale_p + self.nvml_fail_p;
        anyhow::ensure!(
            corrupt <= 1.0 + 1e-12,
            "KPM corruption probabilities sum to {corrupt}, must be <= 1"
        );
        anyhow::ensure!(
            self.start_round >= 1 && self.start_round <= self.end_round,
            "fault window [{}, {}] must be non-empty and 1-based",
            self.start_round,
            self.end_round
        );
        if self.delay_p > 0.0 {
            anyhow::ensure!(
                self.max_delay_rounds >= 1,
                "delay_p > 0 needs max_delay_rounds >= 1"
            );
            anyhow::ensure!(self.max_held >= 1, "delay_p > 0 needs max_held >= 1");
        }
        Ok(())
    }

    /// True when no probability can ever fire — the plan is a no-op.
    pub fn is_inert(&self) -> bool {
        self.drop_p == 0.0
            && self.delay_p == 0.0
            && self.dup_p == 0.0
            && self.reorder_p == 0.0
            && self.kpm_nan_p == 0.0
            && self.kpm_stale_p == 0.0
            && self.nvml_fail_p == 0.0
    }

    fn active_in(&self, round: u32) -> bool {
        round >= self.start_round && round <= self.end_round
    }

    fn interface_scoped(&self, interface: &str) -> bool {
        match interface {
            "A1" => self.fault_a1,
            "O1" => self.fault_o1,
            "O2" => self.fault_o2,
            _ => false,
        }
    }
}

/// What the fabric does with one examined message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricFate {
    /// Deliver normally.
    Deliver,
    /// Silently drop.
    Drop,
    /// Hold back for this many fleet rounds (≥ 1).
    DelayRounds(u32),
    /// Deliver twice.
    Duplicate,
    /// Defer behind everything else pumped this pass.
    Reorder,
}

/// Counters of every fault the plan actually injected.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct FaultLedger {
    pub dropped: u64,
    pub delayed: u64,
    /// Delayed messages that overflowed the bounded hold buffer.
    pub delay_dropped: u64,
    pub duplicated: u64,
    pub reordered: u64,
    pub corrupted_nan: u64,
    pub corrupted_stale: u64,
    pub corrupted_nvml: u64,
    /// Held-back messages released after their delay elapsed.
    pub released: u64,
}

impl FaultLedger {
    /// Total injected faults (releases are the tail of a delay, not a
    /// separate fault, so they are excluded).
    pub fn total(&self) -> u64 {
        self.dropped
            + self.delayed
            + self.delay_dropped
            + self.duplicated
            + self.reordered
            + self.corrupted_nan
            + self.corrupted_stale
            + self.corrupted_nvml
    }
}

/// A live plan: config + round/seq cursors + the ledger.  Owned by the
/// bus it is installed on; all mutation happens on the coordinator
/// thread inside `deliver_all`.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    round: u32,
    seq: u64,
    ledger: FaultLedger,
    /// Flight-recorder gate (§14): when set, every injected fault also
    /// lands in `trace_events` for the coordinator to drain once per
    /// round.  Off by default — the buffer then stays empty and the
    /// ledger-only path is untouched.
    trace: bool,
    /// Buffered `(fate, interface, count)` records since the last drain.
    trace_events: Vec<(&'static str, &'static str, u64)>,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(FaultPlan {
            cfg,
            round: 0,
            seq: 0,
            ledger: FaultLedger::default(),
            trace: false,
            trace_events: Vec::new(),
        })
    }

    /// Enable/disable the fault-trace buffer (§14).
    pub fn set_trace(&mut self, on: bool) {
        self.trace = on;
    }

    /// Drain the buffered fault-trace records (empty unless tracing on).
    pub fn drain_trace(&mut self) -> Vec<(&'static str, &'static str, u64)> {
        std::mem::take(&mut self.trace_events)
    }

    fn note_trace(&mut self, fate: &'static str, iface: &'static str, count: u64) {
        if self.trace {
            self.trace_events.push((fate, iface, count));
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Checkpoint hook (§15): the live cursors and the ledger.  `cfg` is
    /// reconstructed from the serialized [`FaultConfig`], the trace gate
    /// is re-armed by the restorer via [`Self::set_trace`], and
    /// `trace_events` is empty at every round boundary (the coordinator
    /// drains it once per round).
    pub fn ckpt_state(&self) -> (u32, u64, FaultLedger) {
        (self.round, self.seq, self.ledger.clone())
    }

    /// Restore the cursors and ledger captured by [`Self::ckpt_state`].
    pub fn restore_ckpt_state(&mut self, round: u32, seq: u64, ledger: FaultLedger) {
        self.round = round;
        self.seq = seq;
        self.ledger = ledger;
    }

    pub fn ledger(&self) -> &FaultLedger {
        &self.ledger
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Advance to the next fleet round: resets the per-round message
    /// counter that keys the stateless generators.
    pub fn begin_round(&mut self) {
        self.round += 1;
        self.seq = 0;
    }

    /// True when any fault can fire this round (fast path: an inert or
    /// out-of-window plan examines nothing and draws nothing).
    pub fn armed(&self) -> bool {
        !self.cfg.is_inert() && self.cfg.active_in(self.round)
    }

    /// Bound on the delayed-message hold buffer.
    pub fn max_held(&self) -> usize {
        self.cfg.max_held
    }

    pub fn note_delayed(&mut self, iface: &'static str) {
        self.ledger.delayed += 1;
        self.note_trace("delayed", iface, 1);
    }

    pub fn note_delay_dropped(&mut self, iface: &'static str) {
        self.ledger.delay_dropped += 1;
        self.note_trace("delay_dropped", iface, 1);
    }

    pub fn note_released(&mut self, n: u64) {
        self.ledger.released += n;
        if n > 0 {
            self.note_trace("released", "-", n);
        }
    }

    /// Fresh per-message generator keyed by (seed, edge, round, seq).
    fn message_rng(&self, edge: u64, seq: u64) -> Pcg32 {
        let seed = self.cfg.seed ^ edge.wrapping_mul(MIX);
        let stream = ((self.round as u64) << 32) | (seq & 0xFFFF_FFFF);
        Pcg32::new(seed, stream)
    }

    /// Examine one message: corrupt `Kpm` payloads in place, then decide
    /// its fabric fate.  Draw order is fixed (corruption draws first)
    /// so every decision depends only on (seed, edge, round, seq).
    pub fn apply(&mut self, edge: u64, msg: &mut OranMessage) -> FabricFate {
        if !self.armed() {
            return FabricFate::Deliver;
        }
        let seq = self.seq;
        self.seq += 1;
        let iface = msg.interface();

        let cfg = &self.cfg;
        let corrupt_total = cfg.kpm_nan_p + cfg.kpm_stale_p + cfg.nvml_fail_p;
        let corruptible = corrupt_total > 0.0 && matches!(msg, OranMessage::Kpm(_));
        let fabric_total = cfg.drop_p + cfg.delay_p + cfg.dup_p + cfg.reorder_p;
        let fabric_scoped = fabric_total > 0.0 && cfg.interface_scoped(msg.interface());
        if !corruptible && !fabric_scoped {
            return FabricFate::Deliver;
        }

        let mut rng = self.message_rng(edge, seq);
        if corruptible {
            if let OranMessage::Kpm(kpm) = msg {
                let u = rng.next_f64();
                let cfg = &self.cfg;
                if u < cfg.kpm_nan_p {
                    kpm.gpu_power_w = f64::NAN;
                    kpm.gpu_util = f64::NAN;
                    self.ledger.corrupted_nan += 1;
                    if self.trace {
                        self.trace_events.push(("corrupted_nan", iface, 1));
                    }
                } else if u < cfg.kpm_nan_p + cfg.kpm_stale_p {
                    kpm.at = Seconds(kpm.at.0 - STALE_SHIFT_S);
                    self.ledger.corrupted_stale += 1;
                    if self.trace {
                        self.trace_events.push(("corrupted_stale", iface, 1));
                    }
                } else if u < cfg.kpm_nan_p + cfg.kpm_stale_p + cfg.nvml_fail_p {
                    // A failed NVML read surfaces as a negative sentinel
                    // rather than a plausible wattage.
                    kpm.gpu_power_w = -1.0;
                    self.ledger.corrupted_nvml += 1;
                    if self.trace {
                        self.trace_events.push(("corrupted_nvml", iface, 1));
                    }
                }
            }
        }
        if !fabric_scoped {
            return FabricFate::Deliver;
        }
        let cfg = &self.cfg;
        let u = rng.next_f64();
        if u < cfg.drop_p {
            self.ledger.dropped += 1;
            if self.trace {
                self.trace_events.push(("dropped", iface, 1));
            }
            FabricFate::Drop
        } else if u < cfg.drop_p + cfg.delay_p {
            let rounds = rng.below(cfg.max_delay_rounds) + 1;
            // The bus ledgers delayed vs delay_dropped once it knows
            // whether the hold buffer has room.
            FabricFate::DelayRounds(rounds)
        } else if u < cfg.drop_p + cfg.delay_p + cfg.dup_p {
            self.ledger.duplicated += 1;
            if self.trace {
                self.trace_events.push(("duplicated", iface, 1));
            }
            FabricFate::Duplicate
        } else if u < cfg.drop_p + cfg.delay_p + cfg.dup_p + cfg.reorder_p {
            self.ledger.reordered += 1;
            if self.trace {
                self.trace_events.push(("reordered", iface, 1));
            }
            FabricFate::Reorder
        } else {
            FabricFate::Deliver
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oran::messages::KpmReport;

    fn kpm(at: f64) -> OranMessage {
        OranMessage::Kpm(KpmReport {
            host: "h".into(),
            at: Seconds(at),
            model: None,
            gpu_power_w: 100.0,
            cpu_power_w: 10.0,
            dram_power_w: 5.0,
            gpu_util: 0.5,
            cap_frac: 1.0,
            samples_processed: 1,
            energy_j: 1.0,
            offered_load_per_s: 0.0,
            p99_latency_s: 0.0,
            seq: 1,
        })
    }

    #[test]
    fn presets_validate_and_unknown_is_rejected() {
        for name in CHAOS_PRESETS {
            let cfg = FaultConfig::preset(name, 7).unwrap();
            assert!(!cfg.is_inert(), "{name} must inject something");
        }
        assert!(FaultConfig::preset("perfect-fabric", 7).is_err());
    }

    #[test]
    fn validation_rejects_malformed_configs() {
        let bad = FaultConfig { drop_p: 1.5, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        let bad = FaultConfig { drop_p: f64::NAN, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        let bad = FaultConfig { drop_p: 0.6, delay_p: 0.6, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        let bad = FaultConfig { start_round: 5, end_round: 4, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        let bad = FaultConfig { delay_p: 0.1, max_delay_rounds: 0, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        let bad = FaultConfig { delay_p: 0.1, max_held: 0, ..FaultConfig::default() };
        assert!(bad.validate().is_err());
        assert!(FaultConfig::default().validate().is_ok());
    }

    #[test]
    fn inert_plan_touches_nothing() {
        let mut plan = FaultPlan::new(FaultConfig::default()).unwrap();
        plan.begin_round();
        assert!(!plan.armed());
        let mut msg = kpm(3.0);
        let before = msg.clone();
        assert_eq!(plan.apply(1, &mut msg), FabricFate::Deliver);
        assert_eq!(msg, before, "inert plans must not mutate payloads");
        assert_eq!(plan.ledger().total(), 0);
    }

    #[test]
    fn out_of_window_rounds_are_untouched() {
        let cfg = FaultConfig {
            drop_p: 1.0,
            start_round: 3,
            end_round: 3,
            ..FaultConfig::default()
        };
        let mut plan = FaultPlan::new(cfg).unwrap();
        let mut msg = kpm(0.0);
        plan.begin_round(); // round 1
        assert_eq!(plan.apply(0, &mut msg), FabricFate::Deliver);
        plan.begin_round();
        plan.begin_round(); // round 3: armed
        assert_eq!(plan.apply(0, &mut msg), FabricFate::Drop);
        plan.begin_round(); // round 4: quiet again
        assert_eq!(plan.apply(0, &mut msg), FabricFate::Deliver);
        assert_eq!(plan.ledger().dropped, 1);
    }

    #[test]
    fn decisions_are_deterministic_per_seed_edge_round_seq() {
        let cfg = FaultConfig {
            drop_p: 0.3,
            delay_p: 0.2,
            max_delay_rounds: 3,
            dup_p: 0.1,
            reorder_p: 0.1,
            kpm_nan_p: 0.2,
            ..FaultConfig::default()
        };
        let run = |cfg: &FaultConfig| -> Vec<FabricFate> {
            let mut plan = FaultPlan::new(cfg.clone()).unwrap();
            let mut fates = Vec::new();
            for _ in 0..4 {
                plan.begin_round();
                for edge in 0..8u64 {
                    let mut msg = kpm(1.0);
                    fates.push(plan.apply(edge, &mut msg));
                }
            }
            fates
        };
        assert_eq!(run(&cfg), run(&cfg), "same plan, same fates");
        let reseeded = FaultConfig { seed: 99, ..cfg.clone() };
        assert_ne!(run(&cfg), run(&reseeded), "different seed, different fates");
    }

    #[test]
    fn interface_scoping_limits_fabric_fates() {
        let cfg = FaultConfig {
            drop_p: 1.0,
            fault_a1: false,
            fault_o1: false,
            fault_o2: true,
            ..FaultConfig::default()
        };
        let mut plan = FaultPlan::new(cfg).unwrap();
        plan.begin_round();
        let mut k = kpm(0.0);
        assert_eq!(plan.apply(0, &mut k), FabricFate::Deliver, "O1 unscoped");
        let mut req = OranMessage::ProfileRequest { model: "m".into(), host: "h".into() };
        assert_eq!(plan.apply(0, &mut req), FabricFate::Drop, "O2 scoped");
    }

    #[test]
    fn corruption_mutates_kpms_in_the_advertised_ways() {
        // One corruption kind at a time so the mutation is unambiguous.
        let check = |cfg: FaultConfig, verify: fn(&KpmReport)| {
            let mut plan = FaultPlan::new(cfg).unwrap();
            plan.begin_round();
            let mut msg = kpm(50.0);
            plan.apply(4, &mut msg);
            match &msg {
                OranMessage::Kpm(k) => verify(k),
                other => panic!("unexpected message {other:?}"),
            }
        };
        check(
            FaultConfig { kpm_nan_p: 1.0, ..FaultConfig::default() },
            |k| assert!(k.gpu_power_w.is_nan() && k.gpu_util.is_nan()),
        );
        check(
            FaultConfig { kpm_stale_p: 1.0, ..FaultConfig::default() },
            |k| assert!(k.at.0 < -1.0e6, "timestamp shifted far backwards: {}", k.at.0),
        );
        check(
            FaultConfig { nvml_fail_p: 1.0, ..FaultConfig::default() },
            |k| assert_eq!(k.gpu_power_w, -1.0),
        );
    }

    #[test]
    fn delay_fate_is_bounded_by_max_delay_rounds() {
        let cfg = FaultConfig { delay_p: 1.0, max_delay_rounds: 3, ..FaultConfig::default() };
        let mut plan = FaultPlan::new(cfg).unwrap();
        plan.begin_round();
        for edge in 0..64u64 {
            let mut msg = kpm(0.0);
            match plan.apply(edge, &mut msg) {
                FabricFate::DelayRounds(r) => assert!((1..=3).contains(&r), "delay {r}"),
                other => panic!("expected a delay, got {other:?}"),
            }
        }
    }
}
