//! Fleet-scale O-RAN simulation: N heterogeneous inference hosts under one
//! SMO/non-RT RIC, with FROST profiling scheduled across the fleet.
//!
//! The paper evaluates FROST on a single host; O-RAN deployments that
//! matter are *fleets* of ML-enabled sites whose energy is optimised
//! RAN-wide. This module scales every single-host code path to N hosts:
//!
//! * each site owns an [`InferenceHost`] (virtual testbed + FROST
//!   microservice), a **private fabric shard** (its own [`Bus`]) and a
//!   **per-host [`TelemetryHub`] shard** with a bounded power-sample ring;
//! * sites step **concurrently on a persistent worker pool** (spawned once
//!   in [`Fleet::new`], fed over channels — no per-round thread spawning);
//!   cross-site traffic only crosses between phases, through a gateway that
//!   merges per-site outboxes onto the global fabric **in site-index
//!   order** — so a run is bit-for-bit identical for any worker-thread
//!   count;
//! * the non-RT RIC hosts a [`FleetProfileScheduler`] rApp that staggers
//!   FROST profiling (at most `max_concurrent_profiles` sites per round);
//! * the SMO enforces a **global GPU power budget** by water-filling the
//!   budget across the profiled throughput curves
//!   ([`crate::power::allocate_budget`]) and pushing the allocation down
//!   as per-site A1 policies.
//!
//! Round structure (one `run_round`):
//!
//! 0. scenario event dispatch (DESIGN.md §11, when a script is set):
//!    budget steps, site outages/recoveries, flash-crowd surge windows
//!    and thermal derates fire on the coordinator at the round boundary,
//!    so the round is one consistent world state for every worker-thread
//!    count (the per-event ledger is [`Fleet::event_log`]);
//! 1. non-RT RIC step: validation/publishing of finished training, then
//!    the scheduler rApp issues staggered `ProfileRequest`s;
//! 2. gateway **down**: site-addressed global traffic enters each site's
//!    local fabric;
//! 3. **parallel** site phase: each site applies policies, runs any
//!    requested FROST profile, then its workload (initial training in its
//!    first round; afterwards steady-state inference — or, in a
//!    traffic-driven scenario (`FleetConfig::traffic`, DESIGN.md §9), one
//!    seeded diurnal traffic slot through the queue + batch former),
//!    publishing to its telemetry shard;
//! 4. gateway **up** (site order) + SMO ingest of KPM/profile results;
//! 5. FROST decisions recorded into the model catalogue;
//! 6. budget allocation once every site is profiled;
//! 7. optional workload churn (sites rotate to the next zoo model).
//!
//! Hot-path notes (DESIGN.md §8): workload estimates are memoized per
//! testbed (`simulator::StepEstimateCache`), endpoints are interned
//! (`bus::EndpointId`), gateway transfers move messages instead of cloning
//! them, and SMO logs are ingested by index, so a steady-state round does
//! no avoidable repeated work.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{Context, Result};

use crate::config::{setup_no1, setup_no2, HardwareConfig};
use crate::frost::{
    ContinuousMonitor, EnergyPolicy, MonitorAction, MonitorConfig, Observation, QosClass,
};
use crate::metrics::LatencyHistogram;
use crate::obs::{CapCause, MetricsRegistry, TraceData, TraceSink};
use crate::power::{allocate_budget, HostProfile};
use crate::scenario::{Scenario, ScenarioEvent};
use crate::simulator::{Clock, Testbed, WorkloadDescriptor};
use crate::telemetry::hub::{PowerReading, TelemetryHub};
use crate::telemetry::sampler::PowerSampler;
use crate::traffic::{
    ArrivalBuffers, ArrivalGen, ArrivalKind, BatchFormer, SlotLatencies, SlotReport,
    SlotWindow, TrafficConfig, TrafficServer,
};
use crate::util::bench::{bench, group, BenchStats};
use crate::util::Seconds;
use crate::zoo::{all_models, model_by_name};

use super::bus::{Bus, Endpoint, EndpointId};
use super::faults::{FaultConfig, FaultLedger, FaultPlan};
use super::host::{HostCapKind, InferenceHost};
use super::messages::{LifecycleEvent, OranMessage};
use super::nonrt_ric::{
    lock_recovering, FleetAssignments, FleetProfileScheduler, NonRtRic, ProfileHealth,
    ProfileHealthState,
};
use super::smo::Smo;

/// Knobs of a fleet scenario.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of ML-enabled sites (hardware alternates between the paper's
    /// setup no.1 and no.2; models rotate through the 16-entry zoo).
    pub sites: usize,
    pub seed: u64,
    /// Worker threads for the parallel site phase (0 = one per core).
    /// Results are identical for every value — see module docs.
    pub threads: usize,
    /// Orchestration rounds to run.
    pub rounds: u32,
    /// Epochs of a model's initial training (first round of each model).
    pub train_epochs: u32,
    pub samples_per_epoch: u64,
    /// Inference batches per site in each steady-state round.
    pub infer_steps_per_round: u64,
    /// Global GPU power budget as a fraction of the fleet's summed TDP
    /// (>= 1.0 disables budget enforcement).
    pub budget_frac: f64,
    /// At most this many sites run a FROST profile in any one round.
    pub max_concurrent_profiles: usize,
    /// Master FROST switch; false = stock caps everywhere (baseline runs).
    pub frost_enabled: bool,
    /// Rotate every site to its next zoo model each `n` rounds (0 = never).
    pub churn_every: u32,
    /// Validation threshold at the non-RT RIC.
    pub min_accuracy: f64,
    /// Per-site power-sample retention: ring capacity of each site's
    /// `PowerSampler` (0 = unbounded). Bounded by default so arbitrarily
    /// long fleet runs stay O(1) in memory.
    pub sample_retention: usize,
    /// User-driven request load (DESIGN.md §9).  When set, trained sites
    /// serve seeded diurnal traffic slots instead of the fixed
    /// `infer_steps_per_round` loop once `TrafficConfig::warmup_rounds`
    /// have passed; None keeps the legacy fixed workload bit-identical.
    pub traffic: Option<TrafficConfig>,
    /// Scripted operational events (DESIGN.md §11): budget steps, site
    /// outages/recoveries, flash-crowd surges, thermal derating.  Events
    /// fire at round boundaries on the coordinator, so a scripted day is
    /// bit-identical for any worker-thread count.  Requires `traffic`.
    pub scenario: Option<Scenario>,
    /// Seeded fabric fault injection on the *global* bus (§13): drops,
    /// delays, duplicates, reorders and telemetry corruption, all decided
    /// per message on the coordinator thread so runs stay bit-identical
    /// for any worker-thread count.  None = a perfect fabric, exactly as
    /// before this knob existed.
    pub faults: Option<FaultConfig>,
    /// A1 policy lease TTL in rounds (§13): every pushed policy carries
    /// it, the SMO renews each round, and a host that misses this many
    /// consecutive renewals falls back to its conservative safe cap.
    /// 0 = no leases (the historical behavior).
    pub policy_lease_rounds: u32,
    /// Profile-request patience in scheduler rounds before a retry (§13);
    /// 0 disables timeout/retry/quarantine entirely (historical behavior:
    /// the scheduler re-requests every round a model stays cap-less).
    pub profile_timeout_rounds: u32,
    /// Issues per site (first + retries) before the scheduler quarantines
    /// it; only read when `profile_timeout_rounds > 0`.
    pub profile_max_attempts: u32,
    /// Rounds a quarantined site sits out before the coordinator restores
    /// its assignment and the scheduler re-staggers it.
    pub quarantine_rounds: u32,
    /// Bound on a down site's held-back global inbox: the oldest messages
    /// beyond the cap are dropped (counted in the `holdback.dropped`
    /// metric) so a long outage cannot grow the gateway queue without
    /// limit.  0 = unbounded (not recommended).
    pub holdback_cap: usize,
    /// Record the deterministic flight-recorder trace (DESIGN.md §14).
    /// Off by default: every `TraceSink::record` call is then a no-op,
    /// so the hot path stays bit-identical to an untraced build.
    /// Scenario events are still ledgered either way — the fired-event
    /// ledger ([`Fleet::fired_events`]) derives from the sink.
    pub trace: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            sites: 4,
            seed: 7,
            threads: 0,
            rounds: 8,
            train_epochs: 60,
            samples_per_epoch: 20_000,
            infer_steps_per_round: 40,
            budget_frac: 1.0,
            max_concurrent_profiles: 4,
            frost_enabled: true,
            churn_every: 0,
            min_accuracy: 0.68,
            sample_retention: 512,
            traffic: None,
            scenario: None,
            faults: None,
            policy_lease_rounds: 0,
            profile_timeout_rounds: 0,
            profile_max_attempts: 3,
            quarantine_rounds: 8,
            holdback_cap: 1024,
            trace: false,
        }
    }
}

/// Deterministic per-site seed derivation (public so tests can rebuild a
/// single site's exact testbed).
pub fn site_seed(fleet_seed: u64, site_index: usize) -> u64 {
    fleet_seed ^ (site_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Per-site traffic state: the seeded arrival stream, the persistent
/// serving queue, the SLO ledger and the demand monitor.  Lives entirely
/// on the site (stepped on the worker thread), so the §6 determinism
/// contract holds untouched.
pub struct SiteTraffic {
    gen: ArrivalGen,
    pub server: TrafficServer,
    former: BatchFormer,
    monitor: ContinuousMonitor,
    /// This site's QoS deadline (seconds of traffic time).
    pub deadline_s: f64,
    /// True when this site serves via the aggregated count path
    /// (DESIGN.md §10): decided once per scenario from the expected
    /// requests per slot vs `TrafficConfig::exact_request_threshold`
    /// (or forced by `TrafficConfig::path`), never mid-day.
    pub aggregated: bool,
    /// Arrival-count resolution of the aggregated path (sub-windows per
    /// slot, sized to a small fraction of this site's deadline).
    agg_windows: u32,
    /// Reusable per-slot arrival buffers (exact times / aggregated
    /// windows): steady-state slots allocate nothing, and generation +
    /// enqueueing share one definition with the traffic bench
    /// (`traffic::ArrivalBuffers`).
    bufs: ArrivalBuffers,
    /// Per-request latencies of the current day (cleared at day rollover
    /// so multi-day runs stay bounded in memory).  **Exact path only** —
    /// the aggregated path accounts latencies solely in [`Self::hist`],
    /// which is what makes a 10⁶-users/site day O(1) in memory.
    pub latencies: Vec<f64>,
    /// O(1) log-bin latency histogram of the current day (both paths;
    /// cleared at day rollover).  Fleet roll-ups merge these in
    /// site-index order (§6).
    pub hist: LatencyHistogram,
    /// Per-scenario-phase latency histograms (DESIGN.md §11): one per
    /// `Scenario::phases` entry, fed by the same recording pass as
    /// [`Self::hist`]; empty when the fleet runs no scenario.  Cleared at
    /// day rollover with the rest of the day ledgers.
    pub phase_hists: Vec<LatencyHistogram>,
    /// Requests shed when this site went down (queue failed at the outage
    /// event); charged as `dropped` to the first outage slot's report so
    /// slot-level accounting still conserves.
    pending_shed: u64,
    /// Per-slot records of the current day.
    pub slot_log: Vec<SlotReport>,
    /// Total slots served over the site's lifetime (day index derives
    /// from it).
    pub slots_served: u32,
    /// Current-day aggregates.
    pub offered_today: u64,
    pub day_energy_j: f64,
    /// Re-profiles the monitor has requested (signature drift OR demand
    /// shift; see [`Self::load_shift_reprofiles`] for the demand subset).
    pub reprofile_requests: u64,
    /// Set on the worker thread when the monitor fires; the coordinator
    /// consumes it by clearing the catalogue cap, so the re-profile goes
    /// through the scheduler's stagger instead of stampeding the fleet.
    reprofile_pending: bool,
}

impl SiteTraffic {
    /// How many of the requested re-profiles carried an offered-load
    /// shift past the monitor's threshold (demand-driven, as opposed to
    /// pure signature drift).
    pub fn load_shift_reprofiles(&self) -> u64 {
        self.monitor.load_shifts
    }

    /// The demand monitor's counter triple `(reprofiles, load_shifts,
    /// rejected)` — read whole by the fleet metrics registry (§14).
    pub fn monitor_counters(&self) -> (u64, u64, u64) {
        self.monitor.counters()
    }

    /// Checkpoint access to the arrival generator (§15).  Together with
    /// the monitor and the shed ledger these are the only private fields
    /// with live state at a round boundary: `reprofile_pending` is
    /// consumed by the coordinator every round, and the batch former /
    /// arrival buffers carry no state between slots, so all of those
    /// rebuild from config.
    pub fn ckpt_gen(&self) -> &ArrivalGen {
        &self.gen
    }

    pub fn ckpt_gen_mut(&mut self) -> &mut ArrivalGen {
        &mut self.gen
    }

    /// Checkpoint access to the demand monitor (§15).
    pub fn ckpt_monitor(&self) -> &ContinuousMonitor {
        &self.monitor
    }

    pub fn ckpt_monitor_mut(&mut self) -> &mut ContinuousMonitor {
        &mut self.monitor
    }

    /// Requests shed during an outage but not yet charged to a slot
    /// ledger — live across round boundaries while a site is dark (§15).
    pub fn ckpt_pending_shed(&self) -> u64 {
        self.pending_shed
    }

    pub fn restore_ckpt_pending_shed(&mut self, shed: u64) {
        self.pending_shed = shed;
    }

    /// Roll the day ledgers over when this slot starts a new day and
    /// return `(slot_in_day, t0)` — shared by the serving path and the
    /// outage idle path, so a down slot keeps the day clock honest.
    fn begin_slot(&mut self, tr: &TrafficConfig) -> (u32, f64) {
        let slot_in_day = self.slots_served % tr.slots_per_day;
        if slot_in_day == 0 && self.slots_served > 0 {
            // Day rollover: the previous day flushed its queue at the
            // last slot; reset the per-day ledgers so multi-day runs
            // stay bounded in memory.
            self.latencies.clear();
            self.hist.clear();
            for h in self.phase_hists.iter_mut() {
                h.clear();
            }
            self.slot_log.clear();
            self.offered_today = 0;
            self.day_energy_j = 0.0;
        }
        (slot_in_day, self.slots_served as f64 * tr.slot_s())
    }

    fn new(
        cfg: &TrafficConfig,
        site_index: usize,
        qos: QosClass,
        seed: u64,
        phases: usize,
    ) -> SiteTraffic {
        let deadline_s = cfg.slo.deadline_for(qos);
        SiteTraffic {
            gen: ArrivalGen::new(
                cfg.kind,
                cfg.diurnal.clone(),
                cfg.site_base_rate(site_index),
                cfg.day_s,
                seed,
            )
            .expect("validated traffic config"),
            server: TrafficServer::new(),
            former: BatchFormer::new(cfg.max_batch, deadline_s),
            aggregated: cfg.aggregate_for_site(site_index),
            agg_windows: cfg.agg_windows(deadline_s),
            bufs: ArrivalBuffers::new(),
            hist: LatencyHistogram::new(),
            phase_hists: (0..phases).map(|_| LatencyHistogram::new()).collect(),
            pending_shed: 0,
            // Slot-cadence monitoring: settle after a few slots, then
            // re-profile on demand shifts with a cooldown of roughly a
            // sixth of a day so one diurnal ramp triggers once.
            monitor: ContinuousMonitor::new(MonitorConfig {
                alpha: 0.4,
                drift_threshold: 0.25,
                warmup: 3,
                cooldown: Seconds(cfg.day_s / 6.0),
                load_shift_threshold: 0.5,
            }),
            deadline_s,
            latencies: Vec::new(),
            slot_log: Vec::new(),
            slots_served: 0,
            offered_today: 0,
            day_energy_j: 0.0,
            reprofile_requests: 0,
            reprofile_pending: false,
        }
    }
}

/// One ML-enabled site: host + private fabric shard + telemetry shard.
pub struct FleetSite {
    pub index: usize,
    pub name: String,
    /// This site's endpoint on the *global* fabric (downward gateway
    /// target; resolved once at construction).
    global_ep: Arc<Endpoint>,
    /// The site-local fabric: everything the host sends during the
    /// parallel phase stays here until the gateway merges it upward.
    local_bus: Arc<Bus>,
    local_smo: Arc<super::bus::Endpoint>,
    pub host: InferenceHost,
    /// Per-host telemetry shard (the fleet's sharded `TelemetryHub`).
    pub hub: Arc<TelemetryHub>,
    /// Periodic power sampling against this site's shard, with a bounded
    /// retention ring (`FleetConfig::sample_retention`).
    pub sampler: PowerSampler,
    zoo_index: usize,
    pub zoo_model: &'static str,
    /// Catalogue-unique deployment id, e.g. `ResNet@site03`.
    pub model_id: String,
    pub workload: WorkloadDescriptor,
    pub qos: QosClass,
    pub trained: bool,
    /// Cumulative epochs the current model has been trained for. Grows on
    /// each retraining pass (validation failures escalate the budget), so
    /// the accuracy ramp converges past any threshold below the model's
    /// reference accuracy.
    pub epochs_trained: u32,
    /// Messages bound for the SMO once the gateway merges outboxes upward
    /// (in site-index order). Moved, never cloned.
    outbox: Vec<OranMessage>,
    /// Workload (training + inference) energy, profiling excluded.
    pub workload_energy_j: f64,
    /// Workload energy of the most recent round only (steady-state metric).
    pub round_energy_j: f64,
    /// Energy charged to FROST profiling sweeps (Eqs. 4–5).
    pub profiling_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    pub accuracy: f64,
    pub last_gpu_power_w: f64,
    /// Rounds this site has run (drives the warm-up → traffic handover).
    rounds_run: u32,
    /// Scripted outage (DESIGN.md §11): set by the coordinator at event
    /// dispatch.  A down site serves nothing, processes no fabric
    /// traffic, and draws idle power for the slot.
    pub down: bool,
    /// Traffic state when the scenario is traffic-driven.
    pub traffic: Option<SiteTraffic>,
}

impl FleetSite {
    /// Checkpoint access to the site-local fabric shard (§15), so the
    /// snapshot layer can serialise its queue/inboxes/stats by endpoint
    /// name.
    pub fn ckpt_local_bus(&self) -> &Arc<Bus> {
        &self.local_bus
    }

    /// Private per-site scalars a checkpoint must carry (§15): the zoo
    /// cursor (churn state) and the round counter (drives the warm-up →
    /// traffic handover).  The outbox is always empty at a round
    /// boundary — the upward gateway drains it every round — so it is
    /// deliberately not part of the snapshot.
    pub fn ckpt_site_state(&self) -> (usize, u32) {
        (self.zoo_index, self.rounds_run)
    }

    pub fn restore_ckpt_site_state(&mut self, zoo_index: usize, rounds_run: u32) {
        self.zoo_index = zoo_index;
        self.rounds_run = rounds_run;
    }

    /// One site round, run on a worker thread. Touches only site-local
    /// state; cross-site traffic is deferred to `outbox`.
    fn run_round(&mut self, cfg: &FleetConfig) {
        if self.down {
            self.run_down_round(cfg);
            return;
        }
        self.rounds_run += 1;
        // Apply coordinator-injected traffic (A1 policies, profile
        // requests). Profiling runs here, on the worker thread.
        self.local_bus.deliver_all();
        let before = self.host.total_energy_j;
        self.host.step();
        self.profiling_energy_j += self.host.total_energy_j - before;
        // The A1 lease clock ticks after this round's policies applied:
        // a renewal that landed above re-armed it; a missed one brings
        // the host a round closer to its safe-cap fallback (§13).
        self.host.tick_lease();

        // Workload phase under the (possibly just-updated) cap. The
        // estimate is memoized: in steady state this is a cache hit, not a
        // fixed-point solve.
        let est = if self.trained {
            self.host.testbed.infer_estimate(&self.workload, self.host.batch)
        } else {
            self.host.testbed.train_estimate(&self.workload, self.host.batch)
        };
        let t0 = self.host.testbed.clock.now();
        let (gpu, cpu, dram) = self.host.testbed.instantaneous(Some(&est));
        self.hub.publish(PowerReading {
            at: t0,
            gpu,
            cpu,
            dram,
            gpu_util: est.gpu_util,
            freq_mhz: est.op.freq_mhz,
        });
        self.sampler.poll(t0);
        self.last_gpu_power_w = gpu.0;

        let before = self.host.total_energy_j;
        let traffic_now = self.trained
            && self.traffic.is_some()
            && cfg.traffic.as_ref().map_or(false, |t| self.rounds_run > t.warmup_rounds);
        if traffic_now {
            let tr = cfg.traffic.as_ref().expect("checked above");
            self.serve_traffic_slot(cfg, tr, cfg.frost_enabled);
        } else if self.trained {
            let _ = self.host.run_inference(&self.model_id, cfg.infer_steps_per_round);
            self.samples += cfg.infer_steps_per_round * self.host.batch as u64;
        } else {
            // Retraining after a validation failure escalates the epoch
            // budget (fresh run with more epochs), so accuracy ramps past
            // the threshold instead of repeating the same failing run.
            let epochs = self.epochs_trained.saturating_add(cfg.train_epochs);
            let (acc, _wall, _energy) = self
                .host
                .run_training(&self.model_id, epochs, cfg.samples_per_epoch)
                .expect("deployed model trains");
            self.accuracy = acc;
            self.trained = true;
            self.epochs_trained = epochs;
            self.samples += epochs as u64 * cfg.samples_per_epoch;
        }
        self.round_energy_j = self.host.total_energy_j - before;
        self.workload_energy_j += self.round_energy_j;

        let t1 = self.host.testbed.clock.now();
        let (gi, ci, di) = self.host.testbed.instantaneous(None);
        self.hub.publish(PowerReading {
            at: t1,
            gpu: gi,
            cpu: ci,
            dram: di,
            gpu_util: 0.0,
            freq_mhz: 0.0,
        });
        self.sampler.poll(t1);
        self.wall_s = t1.0;

        // Everything the host reported on the local fabric goes upward
        // once the coordinator merges outboxes (in site order). Messages
        // move; nothing is re-serialised or cloned on the hop.
        self.local_bus.deliver_all();
        for (_from, msg) in self.local_smo.drain() {
            self.outbox.push(msg);
        }
    }

    /// A scripted-outage round (DESIGN.md §11): the site is dark.  It
    /// processes no fabric messages (pending policies and profile
    /// requests wait in the queues for recovery), serves nothing, and
    /// draws idle power for one traffic slot — the slot counter keeps
    /// advancing so the diurnal clock is intact when it comes back, and
    /// the slot ledger records a zero-offered, idle-energy slot (plus any
    /// requests the outage shed from the queue, as drops).
    fn run_down_round(&mut self, cfg: &FleetConfig) {
        self.rounds_run += 1;
        let tr = cfg.traffic.as_ref().expect("scenario outages require traffic");
        let slot_s = tr.slot_s();
        let t0c = self.host.testbed.clock.now();
        let (gi, ci, di) = self.host.testbed.instantaneous(None);
        self.hub.publish(PowerReading {
            at: t0c,
            gpu: gi,
            cpu: ci,
            dram: di,
            gpu_util: 0.0,
            freq_mhz: 0.0,
        });
        self.sampler.poll(t0c);
        self.last_gpu_power_w = gi.0;

        let agg = self.host.testbed.idle_window(Seconds(slot_s));
        self.host.total_energy_j += agg.energy.0;
        self.round_energy_j = agg.energy.0;
        self.workload_energy_j += agg.energy.0;

        let t1 = self.host.testbed.clock.now();
        self.sampler.poll(t1);
        self.wall_s = t1.0;

        let cap_frac = self.host.testbed.cap_frac();
        let serving = self.trained && self.rounds_run > tr.warmup_rounds;
        if let Some(t) = self.traffic.as_mut() {
            if serving {
                let (slot_in_day, t0) = t.begin_slot(tr);
                let dropped = std::mem::take(&mut t.pending_shed);
                t.slot_log.push(SlotReport {
                    slot_in_day,
                    t0,
                    offered: 0,
                    served: 0,
                    dropped,
                    late: 0,
                    batches: 0,
                    batch_samples: 0,
                    busy_s: 0.0,
                    energy_j: agg.energy.0,
                    gpu_busy_power_w: 0.0,
                    offered_rate_per_s: 0.0,
                    cap_frac,
                });
                t.slots_served += 1;
                t.day_energy_j += agg.energy.0;
            }
        }
    }

    /// Serve the site's next traffic slot (DESIGN.md §9/§10): generate
    /// the slot's seeded arrivals — individually below the aggregation
    /// threshold, as per-window counts above it, both into reusable
    /// buffers — push them through the host's batch former under the
    /// current cap, and feed the demand monitor, which may ask FROST to
    /// re-profile (routed through the scheduler stagger via the
    /// coordinator — see `reprofile_pending`).
    fn serve_traffic_slot(&mut self, cfg: &FleetConfig, tr: &TrafficConfig, frost_enabled: bool) {
        let slot_s = tr.slot_s();
        let t = self.traffic.as_mut().expect("traffic state initialised");
        let (slot_in_day, t0) = t.begin_slot(tr);
        let deadline_s = t.deadline_s;
        let offered = t.bufs.generate_and_enqueue(
            &mut t.gen,
            &mut t.server,
            t.aggregated,
            t.agg_windows,
            t0,
            slot_s,
            deadline_s,
        );
        let window = SlotWindow {
            t0,
            dur: slot_s,
            slot_in_day,
            flush: slot_in_day + 1 == tr.slots_per_day,
        };
        // Scenario-driven fleets route this slot's samples into its phase
        // histogram as well (same recording pass; DESIGN.md §11).
        let phase_idx = cfg.scenario.as_ref().map(|s| s.phase_of_slot(slot_in_day));
        let mut lat = SlotLatencies {
            exact: if t.aggregated { None } else { Some(&mut t.latencies) },
            hist: &mut t.hist,
            phase: match phase_idx {
                Some(p) => t.phase_hists.get_mut(p),
                None => None,
            },
        };
        let mut report = self
            .host
            .serve_slot(&self.model_id, &mut t.server, &t.former, offered, window, &mut lat)
            .expect("deployed model serves traffic");
        // Shed drops that were never ledgered while the site was dark
        // (e.g. it was retraining through the outage, so no down-slot
        // report was pushed) land on the first served slot instead — the
        // slot ledger must account every drop the server counted.
        report.dropped += std::mem::take(&mut t.pending_shed);
        t.slots_served += 1;
        t.offered_today += report.offered;
        t.day_energy_j += report.energy_j;
        self.samples += report.served;
        // Close the loop: the monitor watches the busy-power /
        // service-throughput signature plus the offered load.
        let service_tput =
            if report.busy_s > 0.0 { report.batch_samples as f64 / report.busy_s } else { 0.0 };
        let action = t.monitor.observe(Observation {
            at: Seconds(t0 + slot_s),
            gpu_power_w: report.gpu_busy_power_w,
            samples_per_s: service_tput,
            offered_load_per_s: report.offered_rate_per_s,
        });
        if frost_enabled && action == MonitorAction::Reprofile {
            t.reprofile_requests += 1;
            // Don't self-issue a ProfileRequest: a diurnal ramp shifts
            // every site in the same round, and direct requests would
            // stampede N concurrent profiles.  The coordinator clears the
            // catalogue cap instead, and the FleetProfileScheduler
            // re-requests it under max_concurrent_profiles.
            t.reprofile_pending = true;
        }
        t.slot_log.push(report);
    }
}

/// Per-site slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct SiteReport {
    pub name: String,
    pub model: String,
    pub hw_name: String,
    pub qos: QosClass,
    pub cap_frac: f64,
    pub tdp_w: f64,
    pub accuracy: f64,
    pub workload_energy_j: f64,
    pub round_energy_j: f64,
    pub profiling_energy_j: f64,
    /// Energy integrated by this site's telemetry shard.
    pub hub_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    /// FROST's estimated energy saving for this site (0 if not profiled).
    pub est_saving: f64,
}

/// Fleet KPM/energy roll-up.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub sites: Vec<SiteReport>,
    pub fleet_workload_energy_j: f64,
    /// Workload energy of the final round only — the steady-state number
    /// baseline comparisons should use (training rounds dominate totals).
    pub fleet_round_energy_j: f64,
    pub fleet_profiling_energy_j: f64,
    pub fleet_samples: u64,
    pub kpm_reports: usize,
    /// Per-host KPM aggregation from the SMO: (host, energy J, samples,
    /// latest reported GPU power W), sorted by host.
    pub kpm_by_host: Vec<(String, f64, u64, f64)>,
    /// Latest KPM-reported day p99 request latency per host, in host
    /// order (traffic-driven fleets; empty otherwise).  The SMO-side
    /// view of the serving tail — what a latency-aware rApp would act
    /// on (DESIGN.md §10).
    pub kpm_p99_by_host: Vec<(String, f64)>,
    pub mean_cap_frac: f64,
    /// Mean of FROST's per-site estimated savings (profiled sites only).
    pub mean_est_saving: f64,
    /// Global GPU budget in watts, when enforcement is on.
    pub budget_w: Option<f64>,
    /// True once the water-fill allocation has actually been pushed to
    /// every site (false while the profiling stagger is still pending).
    pub budget_enforced: bool,
    /// Σ cap_frac·TDP — the fleet's enforced worst-case GPU power.
    pub cap_power_w: f64,
    /// Fault-injection ledger of the global fabric (None = no plan
    /// installed; §13).
    pub fault_ledger: Option<FaultLedger>,
    /// KPM reports the SMO rejected as corrupt/stale/duplicate (§13).
    pub kpm_rejected: u64,
    /// A1 lease expiries across the fleet (hosts that fell back to their
    /// safe cap at least once; §13).
    pub lease_expiries: u64,
    /// Profile-path quarantine entries over the run (§13).
    pub quarantine_events: u64,
    /// Messages dropped from down sites' bounded hold-back queues (§13).
    pub holdback_dropped: u64,
    /// A1 lease renewals the SMO pushed over the run (§13).
    pub lease_renewals: u64,
    /// Named counters/gauges/summaries aggregated fleet-wide (§14):
    /// estimate-cache hits/misses/invalidations, monitor triggers, bus
    /// message counts per interface, lease/holdback ledgers, and the
    /// per-round cap-wattage summary.
    pub metrics: MetricsRegistry,
}

/// Sites in flight between the coordinator and a worker: the original
/// site index rides along so the merge is in site-index order.
type SiteBatch = Vec<(usize, FleetSite)>;

/// Persistent channel-fed worker pool for the parallel site phase.
///
/// Spawned once in [`Fleet::new`]; every round the coordinator partitions
/// the sites into contiguous index chunks (the same deterministic
/// partition the old per-round `thread::scope` used), moves each chunk to
/// a worker, and reassembles the returned sites by index. Worker panics
/// are caught and re-raised on the coordinator thread.
struct SitePool {
    injectors: Vec<Sender<SiteBatch>>,
    results: Receiver<thread::Result<SiteBatch>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl SitePool {
    fn spawn(workers: usize, cfg: Arc<FleetConfig>) -> SitePool {
        let workers = workers.max(1);
        let (results_tx, results) = channel::<thread::Result<SiteBatch>>();
        let mut injectors = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<SiteBatch>();
            let results_tx = results_tx.clone();
            let cfg = cfg.clone();
            handles.push(thread::spawn(move || {
                while let Ok(mut batch) = rx.recv() {
                    let ran = catch_unwind(AssertUnwindSafe(|| {
                        for (_, site) in batch.iter_mut() {
                            site.run_round(&cfg);
                        }
                        batch
                    }));
                    if results_tx.send(ran).is_err() {
                        break; // coordinator gone
                    }
                }
            }));
            injectors.push(tx);
        }
        SitePool { injectors, results, handles }
    }

    fn workers(&self) -> usize {
        self.injectors.len()
    }

    /// Run one parallel site phase over `sites`, in place.
    ///
    /// A dead worker (its channel hung up without a panic payload —
    /// satellite of §13) surfaces as a proper `Err` instead of a
    /// coordinator panic, so the caller can report the fleet as failed.
    /// A *panicking* site is a site bug and is still re-raised verbatim.
    fn run_phase(&self, sites: &mut Vec<FleetSite>) -> Result<()> {
        let n = sites.len();
        if n == 0 {
            return Ok(());
        }
        let chunk = n.div_ceil(self.workers());
        let mut slots: Vec<Option<FleetSite>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);

        let mut batches = 0usize;
        let mut batch: SiteBatch = Vec::with_capacity(chunk);
        for (i, site) in std::mem::take(sites).into_iter().enumerate() {
            batch.push((i, site));
            if batch.len() == chunk {
                self.injectors[batches]
                    .send(std::mem::replace(&mut batch, Vec::with_capacity(chunk)))
                    .map_err(|_| {
                        anyhow::anyhow!("site worker {batches} died: injector hung up")
                    })?;
                batches += 1;
            }
        }
        if !batch.is_empty() {
            self.injectors[batches]
                .send(batch)
                .map_err(|_| anyhow::anyhow!("site worker {batches} died: injector hung up"))?;
            batches += 1;
        }

        let mut panicked: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..batches {
            match self.results.recv() {
                Err(_) => anyhow::bail!("site worker pool died mid-phase: results hung up"),
                Ok(Ok(done)) => {
                    for (i, site) in done {
                        slots[i] = Some(site);
                    }
                }
                // Keep draining the remaining batches so the pool is not
                // left with stale results, then re-raise.
                Ok(Err(payload)) => {
                    panicked.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = panicked {
            resume_unwind(payload);
        }
        let mut rebuilt = Vec::with_capacity(n);
        for slot in slots {
            rebuilt.push(slot.context("site lost by the worker pool")?);
        }
        *sites = rebuilt;
        Ok(())
    }

    /// Test hook: replace a worker's injector with a dead channel so the
    /// next phase observes a hung-up worker.
    #[cfg(test)]
    fn kill_worker_for_test(&mut self) {
        let (tx, _) = channel::<SiteBatch>();
        self.injectors[0] = tx;
    }
}

impl Drop for SitePool {
    fn drop(&mut self) {
        // Closing the injector channels ends every worker's recv loop.
        self.injectors.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Mutable state of a running scenario script (the script itself is
/// frozen inside the shared `FleetConfig`).  All transitions happen on
/// the coordinator thread at round boundaries, so the §6 determinism
/// contract is untouched.
struct ScenarioRt {
    /// Index of the next unfired event in `Scenario::events`.
    next: usize,
    /// Per-site flash-crowd multiplier (1.0 outside surge windows).
    /// (Outage state is NOT duplicated here — `FleetSite::down` is the
    /// single source of truth every reader consults.)
    surge: Vec<f64>,
    /// Per-site thermal cap ceiling (1.0 = no derate in force).
    derate: Vec<f64>,
    /// (policy max_cap_frac, enforced cap) captured at derate time, so
    /// `DerateEnd` can restore the ceiling (and, on stock-cap fleets, the
    /// cap itself).
    pre_derate: Vec<Option<(f64, f64)>>,
    /// The budget fraction currently in force (starts at
    /// `FleetConfig::budget_frac`, moved by `BudgetStep` events).
    budget_frac: f64,
}

/// One fired scenario event, for the per-event ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct FiredEvent {
    pub round: u32,
    pub event: ScenarioEvent,
    /// Human-readable description (the CLI ledger line).
    pub detail: String,
}

/// The fleet simulator (see module docs for the round structure).
pub struct Fleet {
    /// The scenario, frozen at construction: the worker pool and the
    /// coordinator read the same shared snapshot, so the configuration
    /// cannot drift mid-run (`Arc` makes it immutable by construction).
    pub config: Arc<FleetConfig>,
    pub bus: Arc<Bus>,
    pub smo: Smo,
    pub nonrt: NonRtRic,
    pub sites: Vec<FleetSite>,
    assignments: FleetAssignments,
    pool: SitePool,
    /// Interned global-fabric ids the gateway routes by.
    smo_id: EndpointId,
    nonrt_id: EndpointId,
    pub round: u32,
    profiles_ingested: usize,
    lifecycle_ingested: usize,
    budget_applied: bool,
    /// True once at least one full water-fill has been pushed (gates the
    /// reservation path in `enforce_budget`).
    ever_enforced: bool,
    /// Mutable scenario state (None when the fleet runs no scenario).
    scenario_rt: Option<ScenarioRt>,
    /// The flight recorder (§14): the coordinator-recorded trace spine.
    /// Scenario events land here even with tracing off — the per-event
    /// ledger ([`Fleet::fired_events`]) is derived from the sink.
    pub trace: TraceSink,
    /// Fleet-level named counters/gauges/summaries (§14); [`Fleet::report`]
    /// merges the per-site, SMO and bus counters on top of a clone.
    metrics: MetricsRegistry,
    /// The first cap-affecting trigger awaiting the next water-fill push:
    /// `(cause, trigger event id)`.  First setter per pending fill wins;
    /// consumed only when `enforce_budget` actually pushes allocations,
    /// so a trigger survives waiting rounds until the fill lands (§14).
    pending_cause: Option<(CapCause, Option<u64>)>,
    /// Profile-path health shared with the scheduler rApp (§13): the
    /// scheduler writes quarantine decisions, the coordinator acts on
    /// them (blank assignment + budget reservation) and lifts them.
    profile_health: ProfileHealth,
    /// Per-site quarantine release round (None = not quarantined).
    quarantine_release: Vec<Option<u32>>,
}

/// How often a traffic-driven fleet re-runs the load-weighted budget
/// water-fill (in rounds).  Non-traffic fleets allocate once, as before.
const BUDGET_REFRESH_ROUNDS: u32 = 4;
/// Lower bound on a site's offered-load budget weight: even a site whose
/// last slot saw zero demand keeps a quarter share, so its throughput
/// curve never collapses to all-zeros (which would pin it at min_cap).
const MIN_BUDGET_WEIGHT: f64 = 0.25;

impl Fleet {
    pub fn new(config: FleetConfig) -> Result<Fleet> {
        anyhow::ensure!(config.sites > 0, "fleet needs at least one site");
        anyhow::ensure!(config.budget_frac > 0.0, "budget_frac must be positive");
        anyhow::ensure!(
            config.policy_lease_rounds != 1,
            "policy_lease_rounds of 1 expires before any renewal can land; \
             use 0 (no leases) or >= 2"
        );
        if let Some(tr) = &config.traffic {
            tr.validate().context("invalid traffic config")?;
        }
        if let Some(scen) = &config.scenario {
            let tr = config
                .traffic
                .as_ref()
                .context("a scenario script requires FleetConfig::traffic")?;
            scen.validate(config.sites, tr).context("invalid scenario script")?;
        }
        let bus = Bus::new();
        if let Some(fc) = &config.faults {
            let mut plan = FaultPlan::new(fc.clone()).context("invalid fault config")?;
            plan.set_trace(config.trace);
            bus.set_fault_plan(Some(plan));
        }
        let mut smo = Smo::new(bus.clone());
        smo.set_trace(config.trace);
        let mut nonrt = NonRtRic::new(bus.clone(), config.min_accuracy);
        let smo_id = bus.resolve("smo");
        let nonrt_id = bus.resolve("nonrt-ric");
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        let assignments: FleetAssignments = Arc::new(Mutex::new(Vec::new()));
        let retention =
            if config.sample_retention > 0 { Some(config.sample_retention) } else { None };
        let mut sites = Vec::with_capacity(config.sites);
        for i in 0..config.sites {
            let name = format!("site{:02}", i + 1);
            let global_ep = bus.endpoint(&name); // downward routing target
            let hw: HardwareConfig = if i % 2 == 0 { setup_no1() } else { setup_no2() };
            let tdp_w = hw.gpu.tdp_w;
            let min_cap_frac = hw.gpu.min_cap_frac;
            let zoo_index = i % zoo.len();
            let entry = &zoo[zoo_index];
            let model_id = format!("{}@{}", entry.name, name);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            let local_bus = Bus::new();
            let local_smo = local_bus.endpoint("smo");
            local_bus.endpoint("nonrt-ric");
            let mut host =
                InferenceHost::new(local_bus.clone(), &name, hw, site_seed(config.seed, i));
            host.deploy(&model_id, workload.clone(), true);
            host.set_trace_caps(config.trace);
            let hub = Arc::new(TelemetryHub::new());
            let sampler = PowerSampler::with_retention(
                hub.clone(),
                tdp_w,
                min_cap_frac,
                Seconds(0.1),
                site_seed(config.seed, i) ^ 0x5A3F,
                retention,
            );
            let qos = [QosClass::EnergySaver, QosClass::Balanced, QosClass::LatencyCritical]
                [i % 3];
            // Traffic state is seeded per site so arrival streams replay
            // bit-for-bit regardless of worker-thread count (§6).
            let phases = config.scenario.as_ref().map_or(0, |s| s.phases.len());
            let traffic = config
                .traffic
                .as_ref()
                .map(|tr| SiteTraffic::new(tr, i, qos, site_seed(config.seed, i), phases));
            let policy = EnergyPolicy {
                id: format!("{name}-qos"),
                qos,
                enabled: config.frost_enabled,
                lease_rounds: config.policy_lease_rounds,
                ..EnergyPolicy::default_policy()
            };
            // Per-site A1 policy, waiting in the local fabric for round 1.
            // Recorded as the SMO's intent so lease renewals re-assert it.
            smo.record_policy(&name, policy.clone());
            local_bus.send("smo", &name, OranMessage::PolicyUpdate(policy));
            smo.enrol_host(&name);
            lock_recovering(&assignments).push((name.clone(), model_id.clone()));
            sites.push(FleetSite {
                index: i,
                name,
                global_ep,
                local_bus,
                local_smo,
                host,
                hub,
                sampler,
                zoo_index,
                zoo_model: entry.name,
                model_id,
                workload,
                qos,
                trained: false,
                epochs_trained: 0,
                outbox: Vec::new(),
                workload_energy_j: 0.0,
                round_energy_j: 0.0,
                profiling_energy_j: 0.0,
                wall_s: 0.0,
                samples: 0,
                accuracy: 0.0,
                last_gpu_power_w: 0.0,
                rounds_run: 0,
                down: false,
                traffic,
            });
        }
        if let Some(scen) = &config.scenario {
            // Derate ceilings must stay above each target site's driver
            // floor, or the clamp could not be enforced.  Checked against
            // the *constructed* sites so the hardware-mix rule lives in
            // exactly one place (the loop above).
            for te in &scen.events {
                if let ScenarioEvent::Derate { site, max_cap_frac } = te.event {
                    let gpu = &sites[site].host.testbed.hw.gpu;
                    anyhow::ensure!(
                        max_cap_frac >= gpu.min_cap_frac,
                        "derate cap {max_cap_frac} at site {site} is below the {} driver \
                         floor {}",
                        gpu.name,
                        gpu.min_cap_frac
                    );
                }
            }
        }
        let profile_health: ProfileHealth = Arc::new(Mutex::new(ProfileHealthState::default()));
        if config.frost_enabled {
            let mut scheduler =
                FleetProfileScheduler::new(assignments.clone(), config.max_concurrent_profiles);
            if config.profile_timeout_rounds > 0 {
                scheduler = scheduler.with_resilience(
                    config.profile_timeout_rounds,
                    config.profile_max_attempts,
                    config.seed ^ 0x0F0F_5CED,
                    profile_health.clone(),
                );
            }
            nonrt.add_rapp(Box::new(scheduler));
        }
        let requested = if config.threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            config.threads
        };
        let workers = requested.clamp(1, config.sites);
        let scenario_rt = config.scenario.as_ref().map(|_| ScenarioRt {
            next: 0,
            surge: vec![1.0; config.sites],
            derate: vec![1.0; config.sites],
            pre_derate: vec![None; config.sites],
            budget_frac: config.budget_frac,
        });
        let quarantine_release = vec![None; config.sites];
        // One trace round = one traffic slot of sim time (0 s/round for
        // fixed-workload fleets, which have no wall-synchronised clock).
        let round_s = config.traffic.as_ref().map_or(0.0, |t| t.slot_s());
        let trace = TraceSink::new(config.trace, round_s);
        let config = Arc::new(config);
        let pool = SitePool::spawn(workers, config.clone());
        Ok(Fleet {
            config,
            bus,
            smo,
            nonrt,
            sites,
            assignments,
            pool,
            smo_id,
            nonrt_id,
            round: 0,
            profiles_ingested: 0,
            lifecycle_ingested: 0,
            budget_applied: false,
            ever_enforced: false,
            scenario_rt,
            trace,
            metrics: MetricsRegistry::new(),
            pending_cause: None,
            profile_health,
            quarantine_release,
        })
    }

    /// Execute one orchestration round (module docs, steps 1–7).
    pub fn run_round(&mut self) -> Result<()> {
        self.round += 1;
        // Flight recorder (§14): open the round span; its id anchors any
        // cap change this round cannot attribute to a sharper trigger.
        self.trace.begin_round(self.round);
        // Fault clock (§13): the installed plan (if any) advances to this
        // round and releases held-back messages whose delay elapsed.
        self.bus.advance_fault_round();

        // 0. Scenario events due this round fire first, on the
        //    coordinator (DESIGN.md §11): outage/recovery topology,
        //    surge multipliers, budget steps and derates are all settled
        //    before the scheduler or any site acts, so the round is one
        //    consistent world state for every worker-thread count.
        self.apply_due_events()?;
        //    Quarantines due for release re-enter the fleet before the
        //    scheduler steps, so the re-stagger can start this round.
        self.release_due_quarantines();

        // 1. Non-RT RIC: ingest lifecycle events, stagger ProfileRequests.
        self.nonrt.step()?;
        //    Act on fresh quarantine decisions and renew A1 leases before
        //    the fabric pumps, so both ride this round's delivery (§13).
        self.absorb_quarantines();
        self.renew_leases()?;
        self.bus.deliver_all();

        // 2. Gateway down: global → site-local, moving each message (the
        //    sender rides along as a shared intern-table handle).  A down
        //    site receives nothing — its global endpoint queues traffic
        //    until recovery (bounded by `holdback_cap`, oldest dropped
        //    first), so a pre-outage profile request is processed at most
        //    once, after the site returns.
        for site in &self.sites {
            if site.down {
                if self.config.holdback_cap > 0 {
                    let dropped =
                        site.global_ep.truncate_oldest(self.config.holdback_cap) as u64;
                    self.metrics.inc("holdback.dropped", dropped);
                }
                continue;
            }
            for (from, msg) in site.global_ep.drain() {
                site.local_bus.send(&from, &site.name, msg);
            }
        }

        // 3. Parallel site phase on the persistent pool.
        self.pool.run_phase(&mut self.sites).context("parallel site phase")?;
        //    Ingest worker-side cap moves (lease fallbacks/restores,
        //    policy clamps) in site-index order on the coordinator —
        //    same §6 discipline as the gateway merge — so the trace is
        //    bit-identical for any worker-thread count.
        if self.trace.enabled() {
            let anchor = self.trace.round_anchor();
            for i in 0..self.sites.len() {
                for ev in self.sites[i].host.drain_cap_events() {
                    let cause = match ev.kind {
                        HostCapKind::LeaseFallback => CapCause::LeaseFallback,
                        HostCapKind::LeaseRestore => CapCause::Recovery,
                        HostCapKind::PolicyClamp => CapCause::WaterFill,
                    };
                    self.trace.record(
                        Some(i as u32),
                        TraceData::CapChange {
                            cause,
                            from: ev.from,
                            to: ev.to,
                            trigger: anchor,
                        },
                    );
                }
            }
        }

        // 4. Gateway up, in site order (thread-count independent), with
        //    training/deployment lifecycle fanned out to the non-RT RIC.
        for site in &mut self.sites {
            let from = site.global_ep.id();
            for msg in site.outbox.drain(..) {
                let for_ric = matches!(
                    &msg,
                    OranMessage::Lifecycle(
                        LifecycleEvent::TrainingFinished { .. }
                            | LifecycleEvent::Deployed { .. }
                    )
                );
                if for_ric {
                    self.bus.fanout_ids(from, &[self.smo_id, self.nonrt_id], msg);
                } else {
                    self.bus.send_ids(from, self.smo_id, msg);
                }
            }
        }
        self.bus.deliver_all();
        self.smo.step();
        if self.trace.enabled() {
            for (host, reason) in self.smo.drain_trace_rejects() {
                let site =
                    self.sites.iter().position(|s| s.name == host).map(|i| i as u32);
                self.trace.record(site, TraceData::KpmReject { host, reason });
            }
        }

        // 5. Record fresh FROST decisions in the catalogue so the
        //    scheduler stops re-requesting them, and react to validation
        //    failures: a flagged model retrains next round with an
        //    escalated epoch budget. Both logs are ingested by index —
        //    no per-record cloning.
        while self.profiles_ingested < self.smo.profile_records.len() {
            let r = &self.smo.profile_records[self.profiles_ingested];
            let _ = self.nonrt.catalogue.set_optimal_cap(&r.model, r.optimal_cap);
            self.profiles_ingested += 1;
        }
        while self.lifecycle_ingested < self.smo.lifecycle_log.len() {
            if self.trace.enabled() {
                let detail =
                    format!("{:?}", self.smo.lifecycle_log[self.lifecycle_ingested]);
                self.trace.record(None, TraceData::Lifecycle { detail });
            }
            if let LifecycleEvent::FlaggedForRetraining { model, .. } =
                &self.smo.lifecycle_log[self.lifecycle_ingested]
            {
                if let Some(site) = self.sites.iter_mut().find(|s| &s.model_id == model) {
                    site.trained = false;
                }
            }
            self.lifecycle_ingested += 1;
        }
        // Demand-shift re-profiles route through the scheduler: forget
        // the model's recorded cap, and the FleetProfileScheduler
        // re-requests it at ≤ max_concurrent_profiles sites per round.
        for site in &mut self.sites {
            if let Some(t) = site.traffic.as_mut() {
                if std::mem::take(&mut t.reprofile_pending) {
                    let _ = self.nonrt.catalogue.clear_optimal_cap(&site.model_id);
                    self.trace.record(Some(site.index as u32), TraceData::Reprofile);
                }
            }
        }

        // 6. Global power budget, as soon as enough of the stagger has
        //    profiled (unprofiled or down sites have their current cap
        //    wattage *reserved*, so partial allocations still conserve
        //    the budget).  Traffic-driven fleets re-balance periodically:
        //    the water-fill weights sites by offered load, and the
        //    diurnal day keeps moving that load around.  Scenario events
        //    (budget steps, outages, recoveries, derates) force an
        //    immediate re-water-fill by clearing `budget_applied`.
        if self.config.frost_enabled && self.current_budget_frac() < 1.0 {
            let refresh = self.config.traffic.is_some()
                && self.budget_applied
                && self.round % BUDGET_REFRESH_ROUNDS == 0;
            if !self.budget_applied || refresh {
                self.enforce_budget()?;
            }
        }

        // 7. Workload churn.
        if self.config.churn_every > 0 && self.round % self.config.churn_every == 0 {
            self.churn();
        }

        // Round close.  The cap-wattage sum is a cheap O(sites)
        // coordinator pass fed to the metrics summary on every run —
        // traced or not, so reports are identical either way; the trace
        // additionally records the fabric's fault fates, one line per
        // site, and the round_end span.
        let mut cap_w = 0.0;
        for site in &self.sites {
            cap_w += site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
        }
        self.metrics.observe("round.cap_w", cap_w);
        if self.trace.enabled() {
            for (fate, interface, count) in self.bus.drain_fault_trace() {
                self.trace.record(None, TraceData::Fault { fate, interface, count });
            }
            for site in &self.sites {
                self.trace.record(
                    Some(site.index as u32),
                    TraceData::SiteRound {
                        cap_frac: site.host.testbed.cap_frac(),
                        down: site.down,
                    },
                );
            }
            self.trace.record(None, TraceData::RoundEnd { cap_power_w: cap_w });
        }
        Ok(())
    }

    /// Remember the round's first cap-affecting trigger (§14): the next
    /// water-fill push attributes its cap changes to `(cause, trigger)`.
    /// No-op with tracing off; first setter wins until the pending fill
    /// consumes it.
    fn note_cause(&mut self, cause: CapCause, trigger: Option<u64>) {
        if self.trace.enabled() && self.pending_cause.is_none() {
            self.pending_cause = Some((cause, trigger));
        }
    }

    /// The site index a scenario event targets (None = fleet-wide).
    fn event_site(event: &ScenarioEvent) -> Option<u32> {
        match event {
            ScenarioEvent::SiteDown { site }
            | ScenarioEvent::SiteUp { site }
            | ScenarioEvent::Derate { site, .. }
            | ScenarioEvent::DerateEnd { site } => Some(*site as u32),
            ScenarioEvent::SurgeStart { site, .. } | ScenarioEvent::SurgeEnd { site } => {
                site.map(|s| s as u32)
            }
            ScenarioEvent::BudgetStep { .. } => None,
        }
    }

    /// The per-event scenario ledger, reconstructed from the trace spine
    /// (scenario events are recorded even with tracing off), in dispatch
    /// order — the typed successor of the old `event_log` field.
    pub fn fired_events(&self) -> Vec<FiredEvent> {
        self.trace
            .events()
            .iter()
            .filter_map(|e| match &e.data {
                TraceData::Scenario { event, detail } => Some(FiredEvent {
                    round: e.round,
                    event: *event,
                    detail: detail.clone(),
                }),
                _ => None,
            })
            .collect()
    }

    /// The budget fraction currently in force: the configured one, unless
    /// a scenario `BudgetStep` has moved it.
    pub fn current_budget_frac(&self) -> f64 {
        self.scenario_rt.as_ref().map_or(self.config.budget_frac, |rt| rt.budget_frac)
    }

    /// True while `site` sits in profile quarantine (§13).
    pub fn is_quarantined(&self, site: usize) -> bool {
        self.quarantine_release.get(site).map_or(false, |q| q.is_some())
    }

    /// Adopt fresh scheduler quarantine decisions (§13): blank the
    /// site's assignment (like a scripted outage does), forget its stale
    /// demand weight, and schedule its release.  The site keeps serving —
    /// only the profile/budget control path treats it as untrusted.
    fn absorb_quarantines(&mut self) {
        if self.config.profile_timeout_rounds == 0 {
            return;
        }
        let quarantined = lock_recovering(&self.profile_health).quarantined.clone();
        if quarantined.is_empty() {
            return;
        }
        for i in 0..self.sites.len() {
            if self.quarantine_release[i].is_some()
                || !quarantined.contains(self.sites[i].name.as_str())
            {
                continue;
            }
            self.quarantine_release[i] = Some(self.round + self.config.quarantine_rounds);
            lock_recovering(&self.assignments)[i].1 = String::new();
            let name = self.sites[i].name.clone();
            self.smo.clear_host_load(&name);
            let tid =
                self.trace.record(Some(i as u32), TraceData::Quarantine {
                    host: name,
                    entered: true,
                });
            self.note_cause(CapCause::Quarantine, tid);
            // Its cap wattage is reserved in the water-fill until release.
            self.budget_applied = false;
        }
    }

    /// Lift quarantines whose sit-out elapsed: restore the assignment so
    /// the scheduler's rolling cursor re-staggers the site into a fresh
    /// attempt cycle, and force a budget re-fill.
    fn release_due_quarantines(&mut self) {
        for i in 0..self.sites.len() {
            let due = matches!(self.quarantine_release[i], Some(r) if r <= self.round);
            if !due {
                continue;
            }
            self.quarantine_release[i] = None;
            let (name, down) = {
                let site = &self.sites[i];
                (site.name.clone(), site.down)
            };
            lock_recovering(&self.profile_health).quarantined.remove(name.as_str());
            // A down site stays blanked; its recovery event restores it.
            if !down {
                let pair = (name.clone(), self.sites[i].model_id.clone());
                lock_recovering(&self.assignments)[i] = pair;
            }
            let tid = self
                .trace
                .record(Some(i as u32), TraceData::Quarantine { host: name, entered: false });
            self.note_cause(CapCause::Recovery, tid);
            self.budget_applied = false;
        }
    }

    /// Renew every up site's A1 lease (§13) by re-pushing the policy the
    /// SMO *intends* for it (its policy book): on a healthy fabric no
    /// lease ever lapses, while a droppy one starves the host into its
    /// safe-cap fallback within `policy_lease_rounds` missed renewals.
    /// A host in fallback heals the moment one renewal lands (it
    /// restores the pre-fallback cap, clamped to the renewed bounds), and
    /// a dropped budget push is re-asserted by the very next renewal —
    /// the host's own view is never trusted, so a stale ceiling cannot
    /// outlive one delivered A1 message.
    fn renew_leases(&mut self) -> Result<()> {
        if self.config.policy_lease_rounds == 0 {
            return Ok(());
        }
        for site in &self.sites {
            // Skip sites that have not applied their construction-time
            // policy yet (round 1): it is still queued on the site-local
            // fabric and a renewal would only duplicate it.
            if site.down || site.rounds_run == 0 {
                continue;
            }
            let Some(intended) = self.smo.intended_policy(&site.name) else { continue };
            let mut policy = intended.clone();
            policy.lease_rounds = self.config.policy_lease_rounds;
            self.smo.push_policy_to(&site.name, policy)?;
            self.metrics.inc("lease.renewals", 1);
        }
        Ok(())
    }

    /// Fire every scripted event due at the current round (coordinator
    /// thread, before anything else in the round — see `run_round` step 0).
    fn apply_due_events(&mut self) -> Result<()> {
        loop {
            let due = {
                let Some(rt) = self.scenario_rt.as_ref() else { return Ok(()) };
                let scen = self.config.scenario.as_ref().expect("rt implies scenario");
                match scen.events.get(rt.next) {
                    Some(te) if te.round <= self.round => *te,
                    _ => return Ok(()),
                }
            };
            if let Some(rt) = self.scenario_rt.as_mut() {
                rt.next += 1;
            }
            // Ledger first (unconditionally — the fired-event log derives
            // from the sink), so the transition below can cite the event
            // id as the trigger of any cap change it records.
            let tid = self.trace.record_scenario(Self::event_site(&due.event), due.event);
            self.apply_event(due.event, tid)?;
            match due.event {
                ScenarioEvent::BudgetStep { .. } => {
                    self.note_cause(CapCause::BudgetStep, tid)
                }
                ScenarioEvent::SiteDown { .. } => self.note_cause(CapCause::WaterFill, tid),
                ScenarioEvent::SiteUp { .. } => self.note_cause(CapCause::Recovery, tid),
                ScenarioEvent::Derate { .. } => self.note_cause(CapCause::DerateClamp, tid),
                ScenarioEvent::DerateEnd { .. } => self.note_cause(CapCause::Recovery, tid),
                ScenarioEvent::SurgeStart { .. } | ScenarioEvent::SurgeEnd { .. } => {}
            }
        }
    }

    fn apply_event(&mut self, event: ScenarioEvent, tid: Option<u64>) -> Result<()> {
        // Take the runtime state out of `self` for the duration of the
        // transition so sites, SMO and catalogue can be borrowed freely.
        let mut rt = self.scenario_rt.take().expect("events only fire with a scenario");
        let mut topology_changed = false;
        match event {
            ScenarioEvent::BudgetStep { budget_frac } => {
                // Re-water-fill immediately at the new level (step 6 of
                // this same round).
                rt.budget_frac = budget_frac;
                self.budget_applied = false;
            }
            ScenarioEvent::SiteDown { site } => {
                let s = &mut self.sites[site];
                s.down = true;
                // Requests waiting at the failed site are lost, not
                // teleported: shed them now, charge them to the first
                // outage slot's ledger.
                if let Some(t) = s.traffic.as_mut() {
                    t.pending_shed += t.server.shed_all();
                }
                // Blank the scheduler assignment so the stagger skips the
                // dark site instead of queueing duplicate profile
                // requests against it every round (it would double-charge
                // profiling energy at recovery).
                lock_recovering(&self.assignments)[site].1 = String::new();
                // And drop its stale demand weight at the SMO.
                let name = self.sites[site].name.clone();
                self.smo.clear_host_load(&name);
                self.budget_applied = false;
                topology_changed = true;
            }
            ScenarioEvent::SiteUp { site } => {
                let s = &mut self.sites[site];
                s.down = false;
                let pair = (s.name.clone(), s.model_id.clone());
                lock_recovering(&self.assignments)[site] = pair;
                // Its profile is still fresh (same model), so the forced
                // refresh folds it straight back into the water-fill.
                self.budget_applied = false;
                topology_changed = true;
            }
            ScenarioEvent::SurgeStart { mult, site } => {
                match site {
                    Some(i) => rt.surge[i] = mult,
                    None => rt.surge.fill(mult),
                }
                topology_changed = true;
            }
            ScenarioEvent::SurgeEnd { site } => {
                match site {
                    Some(i) => rt.surge[i] = 1.0,
                    None => rt.surge.fill(1.0),
                }
                topology_changed = true;
            }
            ScenarioEvent::Derate { site, max_cap_frac } => {
                rt.derate[site] = max_cap_frac;
                let s = &mut self.sites[site];
                rt.pre_derate[site] =
                    Some((s.host.policy.max_cap_frac, s.host.testbed.cap_frac()));
                // Clamp the A1 ceiling (the profiler obeys policy bounds)
                // and the enforced cap itself; the cap change invalidates
                // the site's step-estimate cache (`Testbed::set_cap_frac`).
                s.host.policy.max_cap_frac = s.host.policy.max_cap_frac.min(max_cap_frac);
                let pre_cap = s.host.testbed.cap_frac();
                if pre_cap > max_cap_frac {
                    s.host.testbed.set_cap_frac(max_cap_frac);
                    self.trace.record(
                        Some(site as u32),
                        TraceData::CapChange {
                            cause: CapCause::DerateClamp,
                            from: pre_cap,
                            to: max_cap_frac,
                            trigger: tid,
                        },
                    );
                }
                if self.config.frost_enabled {
                    // Online system tuning: forget the recorded optimum so
                    // the scheduler re-profiles under the new ceiling.
                    let _ = self.nonrt.catalogue.clear_optimal_cap(&s.model_id);
                }
                self.budget_applied = false;
            }
            ScenarioEvent::DerateEnd { site } => {
                rt.derate[site] = 1.0;
                if let Some((policy_max, pre_cap)) = rt.pre_derate[site].take() {
                    let s = &mut self.sites[site];
                    s.host.policy.max_cap_frac = policy_max;
                    if self.config.frost_enabled {
                        // Re-profile to exploit the restored headroom (or
                        // let the budget refresh re-allocate it).
                        let _ = self.nonrt.catalogue.clear_optimal_cap(&s.model_id);
                    } else {
                        // Stock caps: return to the pre-derate setting.
                        let cur = s.host.testbed.cap_frac();
                        s.host.testbed.set_cap_frac(pre_cap);
                        if (cur - pre_cap).abs() > 1e-12 {
                            self.trace.record(
                                Some(site as u32),
                                TraceData::CapChange {
                                    cause: CapCause::Recovery,
                                    from: cur,
                                    to: pre_cap,
                                    trigger: tid,
                                },
                            );
                        }
                    }
                }
                self.budget_applied = false;
            }
        }
        self.scenario_rt = Some(rt);
        if topology_changed {
            self.recompute_rate_mults();
        }
        Ok(())
    }

    /// Push the effective arrival-rate multiplier to every site's
    /// generator: the surge factor layered with outage redistribution —
    /// a down site's users re-attach to the *up* sites of its region
    /// (contiguous `Scenario::region_size` blocks), weighted by user
    /// counts, so regional demand is conserved while a site is dark.
    /// With no sites down and no surge the product is exactly 1.0 and the
    /// arrival streams stay bit-identical to a scenario-free run.
    fn recompute_rate_mults(&mut self) {
        let Some(rt) = self.scenario_rt.as_ref() else { return };
        let scen = self.config.scenario.as_ref().expect("rt implies scenario");
        let Some(tr) = self.config.traffic.as_ref() else { return };
        let n = self.sites.len();
        let region = scen.region_size.max(1);
        let mut mults = vec![1.0f64; n];
        let mut start = 0usize;
        while start < n {
            let end = (start + region).min(n);
            let total: f64 = (start..end).map(|i| tr.site_users(i)).sum();
            let up: f64 = (start..end)
                .filter(|&i| !self.sites[i].down)
                .map(|i| tr.site_users(i))
                .sum();
            for i in start..end {
                let redistribute = if self.sites[i].down || up <= 0.0 {
                    // A dark site generates nothing; the multiplier is
                    // moot but kept sane for its recovery round.
                    1.0
                } else if up < total {
                    total / up
                } else {
                    1.0
                };
                mults[i] = rt.surge[i] * redistribute;
            }
            start = end;
        }
        for (site, m) in self.sites.iter_mut().zip(&mults) {
            if let Some(t) = site.traffic.as_mut() {
                t.gen.set_rate_mult(*m);
            }
        }
    }

    /// Water-fill the global GPU budget across the profiled throughput
    /// curves and push the allocation down as per-site A1 policies.
    ///
    /// **Budget conservation invariant (DESIGN.md §11).**  Sites that
    /// cannot join the water-fill — a stale profile right after churn, a
    /// scripted outage — do *not* silently vanish from the ledger (the
    /// old behaviour would have spread the full budget over the rest
    /// while the dropped site kept drawing under its old cap, busting the
    /// global budget).  Instead each such site's **current cap wattage is
    /// reserved** off the top, and only the remainder is allocated.  When
    /// the remainder cannot cover the participating sites' driver floors
    /// yet (early stagger), the allocation waits — caps are left as they
    /// are, which is exactly the pre-enforcement state.
    ///
    /// Traffic-driven sites report their offered load on KPM; the
    /// water-fill scales each site's throughput curve by its load share,
    /// so budget watts flow to the sites with the most demand behind
    /// them.  Without load reports every weight is exactly 1.0 and the
    /// allocation is bit-identical to the unweighted one.  Derated sites
    /// only offer operating points under their thermal ceiling.
    fn enforce_budget(&mut self) -> Result<()> {
        let loads = self.smo.offered_load_by_host();
        let mean_load = if loads.is_empty() {
            0.0
        } else {
            loads.values().sum::<f64>() / loads.len() as f64
        };
        let mut profiles = Vec::new();
        let mut alloc_sites: Vec<usize> = Vec::new();
        let mut reserved_w = 0.0;
        let mut waiting = 0usize; // stale-profile sites (stagger/churn)
        for (i, site) in self.sites.iter().enumerate() {
            let down = site.down;
            let quarantined = self.quarantine_release[i].is_some();
            let derate_max =
                self.scenario_rt.as_ref().map_or(1.0, |rt| rt.derate[i]);
            let fresh = matches!(
                site.host.profile_log.last(),
                Some(out) if out.model == site.model_id
            );
            if down || quarantined || !fresh {
                // Reserve the site's worst-case draw under its current
                // cap: a dark site still holds its cap for the recovery
                // round, an unprofiled site keeps running under its old
                // cap until the stagger reaches it, and a quarantined
                // site's profile path is untrusted until release (§13).
                // Neither dark nor quarantined sites count as "waiting" —
                // their reservation *is* their allocation.
                if !down && !quarantined {
                    waiting += 1;
                }
                reserved_w += site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                continue;
            }
            let out = site.host.profile_log.last().expect("checked fresh");
            // Points below the site's policy minimum are not legal
            // operating points; including them would let the allocator
            // "spend" less than the later `.max(min)` raise actually
            // enforces, silently busting the budget.  Points above a
            // thermal derate ceiling are equally illegal — the hardware
            // cannot run there.
            let min_frac = site.host.policy.min_cap_frac;
            let legal: Vec<_> = out
                .points
                .iter()
                .filter(|p| {
                    p.cap_frac >= min_frac - 1e-9 && p.cap_frac <= derate_max + 1e-9
                })
                .cloned()
                .collect();
            let pts = if legal.is_empty() {
                if derate_max < 1.0 {
                    // The profile has no point under the ceiling (a very
                    // deep derate): hold the site at its clamped cap and
                    // reserve those watts instead of allocating.
                    reserved_w +=
                        site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                    continue;
                }
                out.points.clone()
            } else {
                legal
            };
            let mut profile =
                HostProfile::from_profile(&site.name, site.host.testbed.hw.gpu.tdp_w, &pts);
            // Floored: a site that reported zero demand for one slot must
            // shrink, not vanish — weight 0 would zero its whole curve
            // and pin it at min_cap until the next refresh, which a
            // latency_critical site cannot afford at the next morning
            // ramp.
            let weight = match loads.get(&site.name) {
                Some(&l) if mean_load > 0.0 => (l / mean_load).max(MIN_BUDGET_WEIGHT),
                _ => 1.0,
            };
            for p in profile.points.iter_mut() {
                p.1 *= weight;
            }
            profiles.push(profile);
            alloc_sites.push(i);
        }
        if profiles.is_empty() {
            return Ok(()); // nothing profiled yet; retry next round
        }
        // The *first* allocation is always full-fleet: mid-stagger the
        // waiting sites still sit at stock caps, and allocating the thin
        // remainder would clamp the profiled sites far below their final
        // share (caps ratchet down, not up, between profiles).  Once a
        // full water-fill has run, later rounds use the reservation path
        // so churn, outages and derates re-balance immediately without
        // ever busting the budget.
        if waiting > 0 && !self.ever_enforced {
            return Ok(());
        }
        // The budget is defined over the whole fleet's TDP — including
        // reserved sites, whose watts come off the top.
        let total_tdp: f64 =
            self.sites.iter().map(|s| s.host.testbed.hw.gpu.tdp_w).sum();
        let budget_w = total_tdp * self.current_budget_frac();
        let remainder = budget_w - reserved_w;
        let Some(allocs) = allocate_budget(&profiles, remainder, 5.0) else {
            if reserved_w > 0.0 {
                // The remainder cannot cover the participants' floors
                // while reservations hold the rest: wait for the stagger
                // or the recovery to free watts.
                return Ok(());
            }
            anyhow::bail!("fleet power budget below the driver floors");
        };
        // Attribution (§14): consume the round's pending trigger — set by
        // whatever forced this fill (budget step, outage, derate,
        // quarantine) even if the fill had to wait a round — or fall back
        // to a plain water-fill anchored at the round span.
        let (cause, trigger) = self
            .pending_cause
            .take()
            .unwrap_or((CapCause::WaterFill, self.trace.round_anchor()));
        for (i, alloc) in alloc_sites.iter().zip(&allocs) {
            let site = &mut self.sites[*i];
            let mut policy = site.host.policy.clone();
            policy.id = format!("{}-budget", site.name);
            policy.max_cap_frac = alloc.cap_frac.max(policy.min_cap_frac);
            let from = site.host.policy.max_cap_frac;
            if (from - policy.max_cap_frac).abs() > 1e-12 {
                self.trace.record(
                    Some(*i as u32),
                    TraceData::CapChange { cause, from, to: policy.max_cap_frac, trigger },
                );
            }
            // Enact the ceiling immediately on the coordinator: budget
            // conservation is a per-round invariant (a scripted budget
            // step must bite in its own round), so the clamp cannot wait
            // for the A1 message to land at the site next round.  The
            // delivered policy then re-applies the same bound, a no-op.
            if site.host.testbed.cap_frac() > policy.max_cap_frac {
                site.host.testbed.set_cap_frac(policy.max_cap_frac);
            }
            self.smo.push_policy_to(&site.name, policy)?;
        }
        // Enforced-in-full only once no site is waiting on a fresh
        // profile; until then, retry every round (down sites are excluded
        // deliberately — their reservation *is* their allocation).
        self.ever_enforced = true;
        self.budget_applied = waiting == 0;
        Ok(())
    }

    /// Rotate every site to its next zoo model (workload churn): deploy it
    /// under a fresh catalogue id, mark the site untrained, and point the
    /// profile scheduler at the new assignment.
    fn churn(&mut self) {
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        for site in &mut self.sites {
            site.zoo_index = (site.zoo_index + 1) % zoo.len();
            let entry = &zoo[site.zoo_index];
            let model_id = format!("{}@{}#r{}", entry.name, site.name, self.round);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            site.host.deploy(&model_id, workload.clone(), true);
            site.workload = workload;
            site.zoo_model = entry.name;
            site.model_id = model_id.clone();
            site.trained = false;
            site.epochs_trained = 0;
            // A down site stays blanked for the scheduler; its new
            // assignment lands when the recovery event restores it.
            let assigned = if site.down { String::new() } else { model_id };
            lock_recovering(&self.assignments)[site.index] = (site.name.clone(), assigned);
        }
        // New models re-profile; refresh the budget allocation afterwards.
        self.budget_applied = false;
    }

    /// Run the configured number of rounds and return the roll-up.
    pub fn run(&mut self) -> Result<FleetReport> {
        for _ in 0..self.config.rounds {
            self.run_round()?;
        }
        Ok(self.report())
    }

    /// Fleet KPM/energy roll-up (deterministic: site order everywhere).
    pub fn report(&self) -> FleetReport {
        // Metrics (§14): clone the live registry (lease renewals,
        // holdback drops, round cap-wattage summary), then fold in the
        // per-site counters in site-index order and the SMO/bus totals —
        // one name-ordered surface replacing the scattered counters.
        let mut metrics = self.metrics.clone();
        for site in &self.sites {
            let (hits, misses) = site.host.testbed.cache.stats();
            metrics.inc("cache.hits", hits);
            metrics.inc("cache.misses", misses);
            metrics.inc("cache.invalidations", site.host.testbed.cache.invalidations());
            metrics.inc("lease.expiries", site.host.lease_expiries);
            if let Some(t) = &site.traffic {
                let (reprofiles, load_shifts, rejected) = t.monitor_counters();
                metrics.inc("monitor.reprofiles", reprofiles);
                metrics.inc("monitor.load_shifts", load_shifts);
                metrics.inc("monitor.rejected", rejected);
            }
        }
        metrics.inc("kpm.rejected", self.smo.kpm_rejected_total());
        metrics
            .inc("quarantine.events", lock_recovering(&self.profile_health).quarantine_events);
        for (key, count) in self.bus.stats() {
            let name = match key {
                "A1" => "bus.A1",
                "O1" => "bus.O1",
                "O2" => "bus.O2",
                "dropped" => "bus.dropped",
                _ => continue,
            };
            metrics.inc(name, count);
        }
        // Deliberately no worker-count gauge: the report must stay
        // bit-identical for any `threads` setting (§6).
        metrics.set_gauge("fleet.sites", self.sites.len() as f64);

        let mut sites = Vec::new();
        let mut workload_j = 0.0;
        let mut round_j = 0.0;
        let mut profiling_j = 0.0;
        let mut samples = 0u64;
        let mut cap_sum = 0.0;
        let mut cap_power_w = 0.0;
        let mut total_tdp = 0.0;
        let mut est_savings = Vec::new();
        for site in &self.sites {
            let cap = site.host.testbed.cap_frac();
            let tdp = site.host.testbed.hw.gpu.tdp_w;
            cap_sum += cap;
            cap_power_w += cap * tdp;
            total_tdp += tdp;
            let est_saving = self
                .smo
                .profile_records
                .iter()
                .rev()
                .find(|r| r.host == site.name)
                .map(|r| r.est_energy_saving)
                .unwrap_or(0.0);
            if site.host.profile_log.last().is_some() {
                est_savings.push(est_saving);
            }
            let (gpu_j, cpu_j, dram_j) = site.hub.true_energy();
            sites.push(SiteReport {
                name: site.name.clone(),
                model: site.model_id.clone(),
                hw_name: site.host.testbed.hw.name.clone(),
                qos: site.qos,
                cap_frac: cap,
                tdp_w: tdp,
                accuracy: site.accuracy,
                workload_energy_j: site.workload_energy_j,
                round_energy_j: site.round_energy_j,
                profiling_energy_j: site.profiling_energy_j,
                hub_energy_j: gpu_j + cpu_j + dram_j,
                wall_s: site.wall_s,
                samples: site.samples,
                est_saving,
            });
            workload_j += site.workload_energy_j;
            round_j += site.round_energy_j;
            profiling_j += site.profiling_energy_j;
            samples += site.samples;
        }
        let n = self.sites.len().max(1) as f64;
        FleetReport {
            sites,
            fleet_workload_energy_j: workload_j,
            fleet_round_energy_j: round_j,
            fleet_profiling_energy_j: profiling_j,
            fleet_samples: samples,
            kpm_reports: self.smo.kpms.len(),
            kpm_by_host: self.smo.kpm_rollup(),
            kpm_p99_by_host: self
                .smo
                .latency_p99_by_host()
                .iter()
                .map(|(h, p)| (h.clone(), *p))
                .collect(),
            mean_cap_frac: cap_sum / n,
            mean_est_saving: if est_savings.is_empty() {
                0.0
            } else {
                est_savings.iter().sum::<f64>() / est_savings.len() as f64
            },
            budget_w: if self.current_budget_frac() < 1.0 {
                Some(total_tdp * self.current_budget_frac())
            } else {
                None
            },
            budget_enforced: self.budget_applied,
            cap_power_w,
            fault_ledger: self.bus.fault_ledger(),
            kpm_rejected: self.smo.kpm_rejected_total(),
            lease_expiries: metrics.counter("lease.expiries"),
            quarantine_events: metrics.counter("quarantine.events"),
            holdback_dropped: metrics.counter("holdback.dropped"),
            lease_renewals: metrics.counter("lease.renewals"),
            metrics,
        }
    }

    // ---- checkpoint hooks (DESIGN.md §15) ------------------------------
    //
    // Everything below exists so `crate::ckpt::snapshot` can read and
    // restore the coordinator's *private* state; pub fields (round, smo,
    // nonrt, sites, bus, trace, config) are reached directly.  None of
    // these run on the hot path.

    /// Private coordinator scalars `(profiles_ingested,
    /// lifecycle_ingested, budget_applied, ever_enforced,
    /// pending_cause)`.  `round` is pub and travels in the snapshot
    /// header instead.
    #[allow(clippy::type_complexity)]
    pub fn ckpt_coord_state(
        &self,
    ) -> (usize, usize, bool, bool, Option<(CapCause, Option<u64>)>) {
        (
            self.profiles_ingested,
            self.lifecycle_ingested,
            self.budget_applied,
            self.ever_enforced,
            self.pending_cause,
        )
    }

    pub fn restore_ckpt_coord_state(
        &mut self,
        profiles_ingested: usize,
        lifecycle_ingested: usize,
        budget_applied: bool,
        ever_enforced: bool,
        pending_cause: Option<(CapCause, Option<u64>)>,
    ) {
        self.profiles_ingested = profiles_ingested;
        self.lifecycle_ingested = lifecycle_ingested;
        self.budget_applied = budget_applied;
        self.ever_enforced = ever_enforced;
        self.pending_cause = pending_cause;
    }

    /// Mutable scenario-runtime state `(next, surge, derate, pre_derate,
    /// budget_frac)`; None when the fleet runs no scenario.
    #[allow(clippy::type_complexity)]
    pub fn ckpt_scenario_state(
        &self,
    ) -> Option<(usize, &[f64], &[f64], &[Option<(f64, f64)>], f64)> {
        self.scenario_rt.as_ref().map(|rt| {
            (
                rt.next,
                rt.surge.as_slice(),
                rt.derate.as_slice(),
                rt.pre_derate.as_slice(),
                rt.budget_frac,
            )
        })
    }

    /// Restore the scenario runtime.  No-op on a scenario-free fleet
    /// (whose snapshots carry no scenario section either).
    pub fn restore_ckpt_scenario_state(
        &mut self,
        next: usize,
        surge: Vec<f64>,
        derate: Vec<f64>,
        pre_derate: Vec<Option<(f64, f64)>>,
        budget_frac: f64,
    ) {
        if let Some(rt) = self.scenario_rt.as_mut() {
            rt.next = next;
            rt.surge = surge;
            rt.derate = derate;
            rt.pre_derate = pre_derate;
            rt.budget_frac = budget_frac;
        }
    }

    /// Per-site quarantine release rounds (None = not quarantined).
    pub fn ckpt_quarantine_release(&self) -> &[Option<u32>] {
        &self.quarantine_release
    }

    pub fn restore_ckpt_quarantine_release(&mut self, release: Vec<Option<u32>>) {
        self.quarantine_release = release;
    }

    /// The shared profile-health ledger `(quarantined sites,
    /// quarantine_events)`, cloned out of its mutex.
    pub fn ckpt_profile_health(&self) -> (Vec<String>, u64) {
        let h = lock_recovering(&self.profile_health);
        (h.quarantined.iter().cloned().collect(), h.quarantine_events)
    }

    pub fn restore_ckpt_profile_health(
        &mut self,
        quarantined: Vec<String>,
        quarantine_events: u64,
    ) {
        let mut h = lock_recovering(&self.profile_health);
        h.quarantined = quarantined.into_iter().collect();
        h.quarantine_events = quarantine_events;
    }

    /// The scheduler's shared assignment table, cloned out of its mutex.
    pub fn ckpt_assignments(&self) -> Vec<(String, String)> {
        lock_recovering(&self.assignments).clone()
    }

    pub fn restore_ckpt_assignments(&mut self, assignments: Vec<(String, String)>) {
        *lock_recovering(&self.assignments) = assignments;
    }

    /// The live coordinator metrics registry (lease renewals, holdback
    /// drops, per-round cap-wattage summary — NOT the derived counters
    /// [`Fleet::report`] folds in, which recompute from live state).
    pub fn ckpt_metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn ckpt_metrics_mut(&mut self) -> &mut MetricsRegistry {
        &mut self.metrics
    }
}

/// Canonical hot-path bench scenario (DESIGN.md §8): site counts swept by
/// the perf-trajectory record.
pub const BENCH_SITE_COUNTS: [usize; 3] = [4, 16, 64];
/// Rounds run before measurement so every site is trained and profiled
/// (the stagger is widened to the site count) and measured rounds are
/// pure steady state — the cost a deployed fleet pays forever.
pub const BENCH_WARMUP_ROUNDS: u32 = 3;

/// The config of `frost fleet --sites N --seed 7`, stagger widened for a
/// fast warm-up.
pub fn bench_config(sites: usize) -> FleetConfig {
    FleetConfig { sites, seed: 7, max_concurrent_profiles: sites, ..FleetConfig::default() }
}

/// The whole fleet bench suite — steady-state round throughput across
/// [`BENCH_SITE_COUNTS`] plus the cached-vs-uncached execution-model
/// microbench. One definition, called by BOTH `benches/fleet.rs` and the
/// `frost bench` CLI subcommand, so the two `BENCH_fleet.json` recorders
/// cannot drift apart.
pub fn run_bench_suite(target_s: f64) -> Result<Vec<(String, BenchStats)>> {
    let mut results: Vec<(String, BenchStats)> = Vec::new();

    group("fleet steady-state round throughput (seed 7)");
    for sites in BENCH_SITE_COUNTS {
        let mut fleet = Fleet::new(bench_config(sites))?;
        for _ in 0..BENCH_WARMUP_ROUNDS {
            fleet.run_round()?;
        }
        let name = format!("fleet round ({sites} sites)");
        let stats = bench(&name, target_s, || {
            fleet.run_round().expect("steady-state round")
        });
        results.push((name, stats));
    }

    group("traffic: queue + batch-former round (8 sites, seed 7)");
    {
        let tr = TrafficConfig {
            users_per_site: 2_000,
            requests_per_user_per_day: 40.0,
            day_s: 1_200.0,
            slots_per_day: 12,
            warmup_rounds: 3,
            max_batch: 64,
            kind: ArrivalKind::bursty(),
            ..TrafficConfig::default()
        };
        let warmup = tr.warmup_rounds;
        let mut cfg = bench_config(8);
        cfg.traffic = Some(tr);
        let mut fleet = Fleet::new(cfg)?;
        // Warm past training + stagger so every benched round serves a
        // traffic slot (the day wraps, so rounds are unlimited).
        for _ in 0..=warmup {
            fleet.run_round()?;
        }
        let name = "traffic round (8 sites)";
        let stats = bench(name, target_s, || {
            fleet.run_round().expect("traffic round")
        });
        results.push((name.to_string(), stats));
    }

    group("execution model: fixed-point solver vs memoized estimate");
    let hw = setup_no1();
    let w = model_by_name("ResNet").expect("zoo model").workload(&hw.gpu);

    // Uncached: the raw 12-iteration fixed point (with the capping loop's
    // 48-step bisection engaged) on every call.
    let mut uncached = Testbed::new(hw.clone(), 7);
    uncached.set_cap_frac(0.6);
    let name = "train_step fixed-point solve (cap 60%)";
    let solver = bench(name, target_s / 2.0, || uncached.exec.train_step(&w, 128));
    results.push((name.to_string(), solver));

    // Cached: one miss, then pure lookups — the steady-state fleet path.
    let mut cached = Testbed::new(hw, 7);
    cached.set_cap_frac(0.6);
    let name = "train_estimate memoized (cap 60%)";
    let memo = bench(name, target_s / 2.0, || cached.train_estimate(&w, 128));
    results.push((name.to_string(), memo));
    // Cache behaviour goes through the same metrics surface the fleet
    // report uses (§14) instead of a hand-rolled stats line.
    let mut cache_metrics = MetricsRegistry::new();
    let (hits, misses) = cached.cache.stats();
    cache_metrics.inc("cache.hits", hits);
    cache_metrics.inc("cache.misses", misses);
    cache_metrics.inc("cache.invalidations", cached.cache.invalidations());
    for (name, count) in cache_metrics.counters() {
        println!("  {name}: {count}");
    }

    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            sites: 3,
            seed: 11,
            rounds: 5,
            train_epochs: 40,
            samples_per_epoch: 10_000,
            infer_steps_per_round: 20,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn fleet_profiles_all_sites_and_saves() {
        let mut fleet = Fleet::new(small_cfg()).unwrap();
        let report = fleet.run().unwrap();
        assert_eq!(report.sites.len(), 3);
        for site in &report.sites {
            assert!(site.workload_energy_j > 0.0, "{} energy", site.name);
            assert!(site.profiling_energy_j > 0.0, "{} must have profiled", site.name);
            assert!(site.cap_frac <= 1.0, "{} cap {}", site.name, site.cap_frac);
            assert!(site.accuracy > 0.5, "{} accuracy {}", site.name, site.accuracy);
            assert!(site.samples > 0);
        }
        // FROST capped most of the fleet below stock power.
        let capped = report.sites.iter().filter(|s| s.cap_frac < 0.999).count();
        assert!(capped >= 2, "only {capped} of 3 sites capped");
        assert!(report.mean_est_saving > 0.03, "mean est saving {}", report.mean_est_saving);
        assert!(report.kpm_reports > 0);
        // The telemetry shards integrated a comparable amount of energy to
        // the workload accounting (they track operating-point envelopes).
        for site in &report.sites {
            assert!(site.hub_energy_j > 0.0);
        }
    }

    #[test]
    fn same_seed_same_fleet_energy_bitwise() {
        let a = Fleet::new(small_cfg()).unwrap().run().unwrap();
        let b = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_profiling_energy_j.to_bits(), b.fleet_profiling_energy_j.to_bits());
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.workload_energy_j.to_bits(), y.workload_energy_j.to_bits());
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut one = small_cfg();
        one.threads = 1;
        let mut many = small_cfg();
        many.threads = 3;
        let a = Fleet::new(one).unwrap().run().unwrap();
        let b = Fleet::new(many).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_round_energy_j.to_bits(), b.fleet_round_energy_j.to_bits());
        assert_eq!(a.kpm_reports, b.kpm_reports);
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn pool_survives_more_workers_than_sites() {
        let mut cfg = small_cfg();
        cfg.threads = 16; // > sites: clamps to one worker per site
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.sites.len(), 3);
        let baseline = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(
            report.fleet_workload_energy_j.to_bits(),
            baseline.fleet_workload_energy_j.to_bits()
        );
    }

    #[test]
    fn dead_worker_surfaces_as_error_not_panic() {
        let mut cfg = small_cfg();
        cfg.threads = 1;
        let mut fleet = Fleet::new(cfg).unwrap();
        fleet.run_round().unwrap();
        fleet.pool.kill_worker_for_test();
        let err = fleet.run_round().expect_err("dead worker must be an Err");
        assert!(format!("{err:#}").contains("died"), "unexpected error: {err:#}");
    }

    #[test]
    fn lease_of_one_round_is_rejected_at_construction() {
        let mut cfg = small_cfg();
        cfg.policy_lease_rounds = 1;
        assert!(Fleet::new(cfg).is_err());
    }

    #[test]
    fn lease_renewals_on_a_healthy_fabric_never_expire() {
        let mut cfg = small_cfg();
        cfg.policy_lease_rounds = 3;
        let mut fleet = Fleet::new(cfg).unwrap();
        let report = fleet.run().unwrap();
        assert!(report.lease_renewals > 0, "renewals must have been pushed");
        assert_eq!(report.lease_expiries, 0, "no expiry without fabric faults");
        assert!(report.fault_ledger.is_none(), "no plan installed");
        // The run is bit-identical to a lease-less one: renewals re-apply
        // the in-force policy, which is a no-op on a healthy fabric.
        let base = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(
            report.fleet_workload_energy_j.to_bits(),
            base.fleet_workload_energy_j.to_bits()
        );
        for (x, y) in report.sites.iter().zip(&base.sites) {
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
        }
    }

    #[test]
    fn bounded_sampler_retention_holds_in_long_runs() {
        let mut cfg = small_cfg();
        cfg.sample_retention = 8;
        cfg.rounds = 12;
        let mut fleet = Fleet::new(cfg).unwrap();
        fleet.run().unwrap();
        for site in &fleet.sites {
            assert!(site.sampler.retained_len() <= 8, "{}", site.name);
            assert!(
                site.sampler.recorded() > site.sampler.retained_len() as u64,
                "{} should have evicted old samples",
                site.name
            );
        }
    }

    #[test]
    fn disabled_frost_keeps_stock_caps_and_skips_profiling() {
        let mut cfg = small_cfg();
        cfg.frost_enabled = false;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        for site in &report.sites {
            assert_eq!(site.cap_frac, 1.0, "{}", site.name);
            assert_eq!(site.profiling_energy_j, 0.0, "{}", site.name);
        }
        assert_eq!(report.mean_est_saving, 0.0);
    }

    #[test]
    fn budget_clamps_fleet_cap_power() {
        let mut cfg = small_cfg();
        cfg.budget_frac = 0.55;
        cfg.rounds = 6;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        let budget = report.budget_w.expect("budget on");
        assert!(report.budget_enforced, "stagger should have completed");
        assert!(
            report.cap_power_w <= budget + 1e-6,
            "cap power {} exceeds budget {}",
            report.cap_power_w,
            budget
        );
    }

    #[test]
    fn failed_validation_escalates_retraining_until_published() {
        // Six sites at 40 epochs: site06 runs LeNet, whose first-pass
        // accuracy (~0.663) misses the 0.68 threshold. The RIC flags it,
        // the site retrains with an escalated epoch budget (80), passes,
        // and eventually gets profiled like everyone else.
        let cfg = FleetConfig {
            sites: 6,
            seed: 13,
            rounds: 7,
            train_epochs: 40,
            samples_per_epoch: 5_000,
            infer_steps_per_round: 10,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        };
        let mut fleet = Fleet::new(cfg).unwrap();
        let report = fleet.run().unwrap();
        let lenet = fleet.sites.iter().find(|s| s.zoo_model == "LeNet").expect("LeNet site");
        assert!(lenet.epochs_trained > 40, "epochs escalated: {}", lenet.epochs_trained);
        assert!(lenet.accuracy >= 0.68, "accuracy {} after retraining", lenet.accuracy);
        for site in &report.sites {
            assert!(site.profiling_energy_j > 0.0, "{} never profiled", site.name);
        }
    }

    #[test]
    fn churn_redeploys_and_reprofiles() {
        let mut cfg = small_cfg();
        cfg.churn_every = 3;
        cfg.rounds = 6;
        let mut fleet = Fleet::new(cfg).unwrap();
        let first_models: Vec<String> =
            fleet.sites.iter().map(|s| s.model_id.clone()).collect();
        let report = fleet.run().unwrap();
        for (site, old) in report.sites.iter().zip(&first_models) {
            assert_ne!(&site.model, old, "site should have churned");
            assert!(site.model.contains("#r"), "churned id {}", site.model);
        }
        // Both generations were profiled.
        for site in &fleet.sites {
            assert!(site.host.profile_log.len() >= 2, "{}", site.name);
        }
    }
}
