//! Fleet-scale O-RAN simulation: N heterogeneous inference hosts under one
//! SMO/non-RT RIC, with FROST profiling scheduled across the fleet.
//!
//! The paper evaluates FROST on a single host; O-RAN deployments that
//! matter are *fleets* of ML-enabled sites whose energy is optimised
//! RAN-wide. This module scales every single-host code path to N hosts:
//!
//! * each site owns an [`InferenceHost`] (virtual testbed + FROST
//!   microservice), a **private fabric shard** (its own [`Bus`]) and a
//!   **per-host [`TelemetryHub`] shard**;
//! * sites step **concurrently on a thread pool**; cross-site traffic only
//!   crosses between phases, through a gateway that merges per-site
//!   outboxes onto the global fabric **in site order** — so a run is
//!   bit-for-bit identical for any worker-thread count;
//! * the non-RT RIC hosts a [`FleetProfileScheduler`] rApp that staggers
//!   FROST profiling (at most `max_concurrent_profiles` sites per round);
//! * the SMO enforces a **global GPU power budget** by water-filling the
//!   budget across the profiled throughput curves
//!   ([`crate::power::allocate_budget`]) and pushing the allocation down
//!   as per-site A1 policies.
//!
//! Round structure (one `run_round`):
//!
//! 1. non-RT RIC step: validation/publishing of finished training, then
//!    the scheduler rApp issues staggered `ProfileRequest`s;
//! 2. gateway **down**: site-addressed global traffic enters each site's
//!    local fabric;
//! 3. **parallel** site phase: each site applies policies, runs any
//!    requested FROST profile, then its workload (initial training in its
//!    first round, steady-state inference afterwards), publishing to its
//!    telemetry shard;
//! 4. gateway **up** (site order) + SMO ingest of KPM/profile results;
//! 5. FROST decisions recorded into the model catalogue;
//! 6. budget allocation once every site is profiled;
//! 7. optional workload churn (sites rotate to the next zoo model).

use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{Context, Result};

use crate::config::{setup_no1, setup_no2, HardwareConfig};
use crate::frost::{EnergyPolicy, QosClass};
use crate::power::{allocate_budget, HostProfile};
use crate::simulator::Clock;
use crate::simulator::WorkloadDescriptor;
use crate::telemetry::hub::{PowerReading, TelemetryHub};
use crate::zoo::all_models;

use super::bus::Bus;
use super::host::InferenceHost;
use super::messages::{LifecycleEvent, OranMessage};
use super::nonrt_ric::{FleetAssignments, FleetProfileScheduler, NonRtRic};
use super::smo::Smo;

/// Knobs of a fleet scenario.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of ML-enabled sites (hardware alternates between the paper's
    /// setup no.1 and no.2; models rotate through the 16-entry zoo).
    pub sites: usize,
    pub seed: u64,
    /// Worker threads for the parallel site phase (0 = one per core).
    /// Results are identical for every value — see module docs.
    pub threads: usize,
    /// Orchestration rounds to run.
    pub rounds: u32,
    /// Epochs of a model's initial training (first round of each model).
    pub train_epochs: u32,
    pub samples_per_epoch: u64,
    /// Inference batches per site in each steady-state round.
    pub infer_steps_per_round: u64,
    /// Global GPU power budget as a fraction of the fleet's summed TDP
    /// (>= 1.0 disables budget enforcement).
    pub budget_frac: f64,
    /// At most this many sites run a FROST profile in any one round.
    pub max_concurrent_profiles: usize,
    /// Master FROST switch; false = stock caps everywhere (baseline runs).
    pub frost_enabled: bool,
    /// Rotate every site to its next zoo model each `n` rounds (0 = never).
    pub churn_every: u32,
    /// Validation threshold at the non-RT RIC.
    pub min_accuracy: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            sites: 4,
            seed: 7,
            threads: 0,
            rounds: 8,
            train_epochs: 60,
            samples_per_epoch: 20_000,
            infer_steps_per_round: 40,
            budget_frac: 1.0,
            max_concurrent_profiles: 4,
            frost_enabled: true,
            churn_every: 0,
            min_accuracy: 0.68,
        }
    }
}

/// Deterministic per-site seed derivation (public so tests can rebuild a
/// single site's exact testbed).
pub fn site_seed(fleet_seed: u64, site_index: usize) -> u64 {
    fleet_seed ^ (site_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One ML-enabled site: host + private fabric shard + telemetry shard.
pub struct FleetSite {
    pub index: usize,
    pub name: String,
    /// The site-local fabric: everything the host sends during the
    /// parallel phase stays here until the gateway merges it upward.
    local_bus: Arc<Bus>,
    local_smo: Arc<super::bus::Endpoint>,
    pub host: InferenceHost,
    /// Per-host telemetry shard (the fleet's sharded `TelemetryHub`).
    pub hub: Arc<TelemetryHub>,
    zoo_index: usize,
    pub zoo_model: &'static str,
    /// Catalogue-unique deployment id, e.g. `ResNet@site03`.
    pub model_id: String,
    workload: WorkloadDescriptor,
    pub qos: QosClass,
    pub trained: bool,
    /// Cumulative epochs the current model has been trained for. Grows on
    /// each retraining pass (validation failures escalate the budget), so
    /// the accuracy ramp converges past any threshold below the model's
    /// reference accuracy.
    pub epochs_trained: u32,
    outbox: Vec<(String, OranMessage)>,
    /// Workload (training + inference) energy, profiling excluded.
    pub workload_energy_j: f64,
    /// Workload energy of the most recent round only (steady-state metric).
    pub round_energy_j: f64,
    /// Energy charged to FROST profiling sweeps (Eqs. 4–5).
    pub profiling_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    pub accuracy: f64,
    pub last_gpu_power_w: f64,
}

impl FleetSite {
    /// One site round, run on a worker thread. Touches only site-local
    /// state; cross-site traffic is deferred to `outbox`.
    fn run_round(&mut self, cfg: &FleetConfig) {
        // Apply coordinator-injected traffic (A1 policies, profile
        // requests). Profiling runs here, on the worker thread.
        self.local_bus.deliver_all();
        let before = self.host.total_energy_j;
        self.host.step();
        self.profiling_energy_j += self.host.total_energy_j - before;

        // Workload phase under the (possibly just-updated) cap.
        let est = if self.trained {
            self.host.testbed.exec.infer_step(&self.workload, self.host.batch)
        } else {
            self.host.testbed.exec.train_step(&self.workload, self.host.batch)
        };
        let t0 = self.host.testbed.clock.now();
        let (gpu, cpu, dram) = self.host.testbed.instantaneous(Some(&est));
        self.hub.publish(PowerReading {
            at: t0,
            gpu,
            cpu,
            dram,
            gpu_util: est.gpu_util,
            freq_mhz: est.op.freq_mhz,
        });
        self.last_gpu_power_w = gpu.0;

        let before = self.host.total_energy_j;
        if self.trained {
            let _ = self.host.run_inference(&self.model_id, cfg.infer_steps_per_round);
            self.samples += cfg.infer_steps_per_round * self.host.batch as u64;
        } else {
            // Retraining after a validation failure escalates the epoch
            // budget (fresh run with more epochs), so accuracy ramps past
            // the threshold instead of repeating the same failing run.
            let epochs = self.epochs_trained.saturating_add(cfg.train_epochs);
            let (acc, _wall, _energy) = self
                .host
                .run_training(&self.model_id, epochs, cfg.samples_per_epoch)
                .expect("deployed model trains");
            self.accuracy = acc;
            self.trained = true;
            self.epochs_trained = epochs;
            self.samples += epochs as u64 * cfg.samples_per_epoch;
        }
        self.round_energy_j = self.host.total_energy_j - before;
        self.workload_energy_j += self.round_energy_j;

        let t1 = self.host.testbed.clock.now();
        let (gi, ci, di) = self.host.testbed.instantaneous(None);
        self.hub.publish(PowerReading {
            at: t1,
            gpu: gi,
            cpu: ci,
            dram: di,
            gpu_util: 0.0,
            freq_mhz: 0.0,
        });
        self.wall_s = t1.0;

        // Everything the host reported on the local fabric goes upward
        // once the coordinator merges outboxes (in site order).
        self.local_bus.deliver_all();
        for (_from, msg) in self.local_smo.drain() {
            self.outbox.push(("smo".to_string(), msg));
        }
    }
}

/// Per-site slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct SiteReport {
    pub name: String,
    pub model: String,
    pub hw_name: String,
    pub qos: QosClass,
    pub cap_frac: f64,
    pub tdp_w: f64,
    pub accuracy: f64,
    pub workload_energy_j: f64,
    pub round_energy_j: f64,
    pub profiling_energy_j: f64,
    /// Energy integrated by this site's telemetry shard.
    pub hub_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    /// FROST's estimated energy saving for this site (0 if not profiled).
    pub est_saving: f64,
}

/// Fleet KPM/energy roll-up.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub sites: Vec<SiteReport>,
    pub fleet_workload_energy_j: f64,
    /// Workload energy of the final round only — the steady-state number
    /// baseline comparisons should use (training rounds dominate totals).
    pub fleet_round_energy_j: f64,
    pub fleet_profiling_energy_j: f64,
    pub fleet_samples: u64,
    pub kpm_reports: usize,
    /// Per-host KPM aggregation from the SMO: (host, energy J, samples,
    /// latest reported GPU power W), sorted by host.
    pub kpm_by_host: Vec<(String, f64, u64, f64)>,
    pub mean_cap_frac: f64,
    /// Mean of FROST's per-site estimated savings (profiled sites only).
    pub mean_est_saving: f64,
    /// Global GPU budget in watts, when enforcement is on.
    pub budget_w: Option<f64>,
    /// True once the water-fill allocation has actually been pushed to
    /// every site (false while the profiling stagger is still pending).
    pub budget_enforced: bool,
    /// Σ cap_frac·TDP — the fleet's enforced worst-case GPU power.
    pub cap_power_w: f64,
}

/// The fleet simulator (see module docs for the round structure).
pub struct Fleet {
    pub config: FleetConfig,
    pub bus: Arc<Bus>,
    pub smo: Smo,
    pub nonrt: NonRtRic,
    pub sites: Vec<FleetSite>,
    assignments: FleetAssignments,
    pub round: u32,
    profiles_ingested: usize,
    lifecycle_ingested: usize,
    budget_applied: bool,
}

impl Fleet {
    pub fn new(config: FleetConfig) -> Result<Fleet> {
        anyhow::ensure!(config.sites > 0, "fleet needs at least one site");
        anyhow::ensure!(config.budget_frac > 0.0, "budget_frac must be positive");
        let bus = Bus::new();
        let mut smo = Smo::new(bus.clone());
        let mut nonrt = NonRtRic::new(bus.clone(), config.min_accuracy);
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        let assignments: FleetAssignments = Arc::new(Mutex::new(Vec::new()));
        let mut sites = Vec::with_capacity(config.sites);
        for i in 0..config.sites {
            let name = format!("site{:02}", i + 1);
            bus.endpoint(&name); // global endpoint: downward routing target
            let hw: HardwareConfig = if i % 2 == 0 { setup_no1() } else { setup_no2() };
            let zoo_index = i % zoo.len();
            let entry = &zoo[zoo_index];
            let model_id = format!("{}@{}", entry.name, name);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            let local_bus = Bus::new();
            let local_smo = local_bus.endpoint("smo");
            local_bus.endpoint("nonrt-ric");
            let mut host =
                InferenceHost::new(local_bus.clone(), &name, hw, site_seed(config.seed, i));
            host.deploy(&model_id, workload.clone(), true);
            let qos = [QosClass::EnergySaver, QosClass::Balanced, QosClass::LatencyCritical]
                [i % 3];
            let policy = EnergyPolicy {
                id: format!("{name}-qos"),
                qos,
                enabled: config.frost_enabled,
                ..EnergyPolicy::default_policy()
            };
            // Per-site A1 policy, waiting in the local fabric for round 1.
            local_bus.send("smo", &name, OranMessage::PolicyUpdate(policy));
            smo.enrol_host(&name);
            assignments.lock().unwrap().push((name.clone(), model_id.clone()));
            sites.push(FleetSite {
                index: i,
                name,
                local_bus,
                local_smo,
                host,
                hub: Arc::new(TelemetryHub::new()),
                zoo_index,
                zoo_model: entry.name,
                model_id,
                workload,
                qos,
                trained: false,
                epochs_trained: 0,
                outbox: Vec::new(),
                workload_energy_j: 0.0,
                round_energy_j: 0.0,
                profiling_energy_j: 0.0,
                wall_s: 0.0,
                samples: 0,
                accuracy: 0.0,
                last_gpu_power_w: 0.0,
            });
        }
        if config.frost_enabled {
            nonrt.add_rapp(Box::new(FleetProfileScheduler::new(
                assignments.clone(),
                config.max_concurrent_profiles,
            )));
        }
        Ok(Fleet {
            config,
            bus,
            smo,
            nonrt,
            sites,
            assignments,
            round: 0,
            profiles_ingested: 0,
            lifecycle_ingested: 0,
            budget_applied: false,
        })
    }

    /// Execute one orchestration round (module docs, steps 1–7).
    pub fn run_round(&mut self) -> Result<()> {
        self.round += 1;

        // 1. Non-RT RIC: ingest lifecycle events, stagger ProfileRequests.
        self.nonrt.step()?;
        self.bus.deliver_all();

        // 2. Gateway down.
        for site in &mut self.sites {
            let down = self.bus.endpoint(&site.name).drain();
            for (from, msg) in down {
                site.local_bus.send(&from, &site.name, msg);
            }
        }

        // 3. Parallel site phase.
        let cfg = self.config.clone();
        let requested = if cfg.threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        let threads = requested.clamp(1, self.sites.len());
        let chunk = self.sites.len().div_ceil(threads);
        thread::scope(|scope| {
            for chunk_sites in self.sites.chunks_mut(chunk) {
                let cfg = &cfg;
                scope.spawn(move || {
                    for site in chunk_sites {
                        site.run_round(cfg);
                    }
                });
            }
        });

        // 4. Gateway up, in site order (thread-count independent), with
        //    training/deployment lifecycle fanned out to the non-RT RIC.
        for site in &mut self.sites {
            for (to, msg) in std::mem::take(&mut site.outbox) {
                let for_ric = matches!(
                    &msg,
                    OranMessage::Lifecycle(
                        LifecycleEvent::TrainingFinished { .. }
                            | LifecycleEvent::Deployed { .. }
                    )
                );
                if to == "smo" && for_ric {
                    self.bus.fanout(&site.name, &["smo", "nonrt-ric"], msg);
                } else {
                    self.bus.send(&site.name, &to, msg);
                }
            }
        }
        self.bus.deliver_all();
        self.smo.step();

        // 5. Record fresh FROST decisions in the catalogue so the
        //    scheduler stops re-requesting them, and react to validation
        //    failures: a flagged model retrains next round with an
        //    escalated epoch budget.
        while self.profiles_ingested < self.smo.profile_records.len() {
            let r = self.smo.profile_records[self.profiles_ingested].clone();
            self.profiles_ingested += 1;
            let _ = self.nonrt.catalogue.set_optimal_cap(&r.model, r.optimal_cap);
        }
        while self.lifecycle_ingested < self.smo.lifecycle_log.len() {
            let ev = self.smo.lifecycle_log[self.lifecycle_ingested].clone();
            self.lifecycle_ingested += 1;
            if let LifecycleEvent::FlaggedForRetraining { model, .. } = ev {
                if let Some(site) = self.sites.iter_mut().find(|s| s.model_id == model) {
                    site.trained = false;
                }
            }
        }

        // 6. Global power budget, once the stagger has profiled every site.
        if self.config.frost_enabled && self.config.budget_frac < 1.0 && !self.budget_applied
        {
            self.enforce_budget()?;
        }

        // 7. Workload churn.
        if self.config.churn_every > 0 && self.round % self.config.churn_every == 0 {
            self.churn();
        }
        Ok(())
    }

    /// Water-fill the global GPU budget across the profiled throughput
    /// curves and push the allocation down as per-site A1 policies.
    fn enforce_budget(&mut self) -> Result<()> {
        let mut profiles = Vec::new();
        for site in &self.sites {
            match site.host.profile_log.last() {
                // Only water-fill on *fresh* curves: the latest profile must
                // be of the model the site currently runs, otherwise (e.g.
                // right after churn) wait for the stagger to re-profile.
                Some(out) if out.model == site.model_id => {
                    // Points below the site's policy minimum are not legal
                    // operating points; including them would let the
                    // allocator "spend" less than the later `.max(min)`
                    // raise actually enforces, silently busting the budget.
                    let min_frac = site.host.policy.min_cap_frac;
                    let legal: Vec<_> = out
                        .points
                        .iter()
                        .filter(|p| p.cap_frac >= min_frac - 1e-9)
                        .cloned()
                        .collect();
                    let pts = if legal.is_empty() { out.points.clone() } else { legal };
                    profiles.push(HostProfile::from_profile(
                        &site.name,
                        site.host.testbed.hw.gpu.tdp_w,
                        &pts,
                    ));
                }
                _ => return Ok(()), // stagger not done yet; retry next round
            }
        }
        let total_tdp: f64 = profiles.iter().map(|p| p.tdp_w).sum();
        let budget_w = total_tdp * self.config.budget_frac;
        let allocs = allocate_budget(&profiles, budget_w, 5.0)
            .context("fleet power budget below the driver floors")?;
        for (site, alloc) in self.sites.iter().zip(&allocs) {
            let mut policy = site.host.policy.clone();
            policy.id = format!("{}-budget", site.name);
            policy.max_cap_frac = alloc.cap_frac.max(policy.min_cap_frac);
            self.smo.push_policy_to(&site.name, policy)?;
        }
        self.budget_applied = true;
        Ok(())
    }

    /// Rotate every site to its next zoo model (workload churn): deploy it
    /// under a fresh catalogue id, mark the site untrained, and point the
    /// profile scheduler at the new assignment.
    fn churn(&mut self) {
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        for site in &mut self.sites {
            site.zoo_index = (site.zoo_index + 1) % zoo.len();
            let entry = &zoo[site.zoo_index];
            let model_id = format!("{}@{}#r{}", entry.name, site.name, self.round);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            site.host.deploy(&model_id, workload.clone(), true);
            site.workload = workload;
            site.zoo_model = entry.name;
            site.model_id = model_id.clone();
            site.trained = false;
            site.epochs_trained = 0;
            self.assignments.lock().unwrap()[site.index] = (site.name.clone(), model_id);
        }
        // New models re-profile; refresh the budget allocation afterwards.
        self.budget_applied = false;
    }

    /// Run the configured number of rounds and return the roll-up.
    pub fn run(&mut self) -> Result<FleetReport> {
        for _ in 0..self.config.rounds {
            self.run_round()?;
        }
        Ok(self.report())
    }

    /// Fleet KPM/energy roll-up (deterministic: site order everywhere).
    pub fn report(&self) -> FleetReport {
        let mut sites = Vec::new();
        let mut workload_j = 0.0;
        let mut round_j = 0.0;
        let mut profiling_j = 0.0;
        let mut samples = 0u64;
        let mut cap_sum = 0.0;
        let mut cap_power_w = 0.0;
        let mut total_tdp = 0.0;
        let mut est_savings = Vec::new();
        for site in &self.sites {
            let cap = site.host.testbed.cap_frac();
            let tdp = site.host.testbed.hw.gpu.tdp_w;
            cap_sum += cap;
            cap_power_w += cap * tdp;
            total_tdp += tdp;
            let est_saving = self
                .smo
                .profile_records
                .iter()
                .rev()
                .find(|r| r.host == site.name)
                .map(|r| r.est_energy_saving)
                .unwrap_or(0.0);
            if site.host.profile_log.last().is_some() {
                est_savings.push(est_saving);
            }
            let (gpu_j, cpu_j, dram_j) = site.hub.true_energy();
            sites.push(SiteReport {
                name: site.name.clone(),
                model: site.model_id.clone(),
                hw_name: site.host.testbed.hw.name.clone(),
                qos: site.qos,
                cap_frac: cap,
                tdp_w: tdp,
                accuracy: site.accuracy,
                workload_energy_j: site.workload_energy_j,
                round_energy_j: site.round_energy_j,
                profiling_energy_j: site.profiling_energy_j,
                hub_energy_j: gpu_j + cpu_j + dram_j,
                wall_s: site.wall_s,
                samples: site.samples,
                est_saving,
            });
            workload_j += site.workload_energy_j;
            round_j += site.round_energy_j;
            profiling_j += site.profiling_energy_j;
            samples += site.samples;
        }
        let n = self.sites.len().max(1) as f64;
        FleetReport {
            sites,
            fleet_workload_energy_j: workload_j,
            fleet_round_energy_j: round_j,
            fleet_profiling_energy_j: profiling_j,
            fleet_samples: samples,
            kpm_reports: self.smo.kpms.len(),
            kpm_by_host: self.smo.kpm_rollup(),
            mean_cap_frac: cap_sum / n,
            mean_est_saving: if est_savings.is_empty() {
                0.0
            } else {
                est_savings.iter().sum::<f64>() / est_savings.len() as f64
            },
            budget_w: if self.config.budget_frac < 1.0 {
                Some(total_tdp * self.config.budget_frac)
            } else {
                None
            },
            budget_enforced: self.budget_applied,
            cap_power_w,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            sites: 3,
            seed: 11,
            rounds: 5,
            train_epochs: 40,
            samples_per_epoch: 10_000,
            infer_steps_per_round: 20,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn fleet_profiles_all_sites_and_saves() {
        let mut fleet = Fleet::new(small_cfg()).unwrap();
        let report = fleet.run().unwrap();
        assert_eq!(report.sites.len(), 3);
        for site in &report.sites {
            assert!(site.workload_energy_j > 0.0, "{} energy", site.name);
            assert!(site.profiling_energy_j > 0.0, "{} must have profiled", site.name);
            assert!(site.cap_frac <= 1.0, "{} cap {}", site.name, site.cap_frac);
            assert!(site.accuracy > 0.5, "{} accuracy {}", site.name, site.accuracy);
            assert!(site.samples > 0);
        }
        // FROST capped most of the fleet below stock power.
        let capped = report.sites.iter().filter(|s| s.cap_frac < 0.999).count();
        assert!(capped >= 2, "only {capped} of 3 sites capped");
        assert!(report.mean_est_saving > 0.03, "mean est saving {}", report.mean_est_saving);
        assert!(report.kpm_reports > 0);
        // The telemetry shards integrated a comparable amount of energy to
        // the workload accounting (they track operating-point envelopes).
        for site in &report.sites {
            assert!(site.hub_energy_j > 0.0);
        }
    }

    #[test]
    fn same_seed_same_fleet_energy_bitwise() {
        let a = Fleet::new(small_cfg()).unwrap().run().unwrap();
        let b = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_profiling_energy_j.to_bits(), b.fleet_profiling_energy_j.to_bits());
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.workload_energy_j.to_bits(), y.workload_energy_j.to_bits());
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut one = small_cfg();
        one.threads = 1;
        let mut many = small_cfg();
        many.threads = 3;
        let a = Fleet::new(one).unwrap().run().unwrap();
        let b = Fleet::new(many).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_round_energy_j.to_bits(), b.fleet_round_energy_j.to_bits());
        assert_eq!(a.kpm_reports, b.kpm_reports);
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn disabled_frost_keeps_stock_caps_and_skips_profiling() {
        let mut cfg = small_cfg();
        cfg.frost_enabled = false;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        for site in &report.sites {
            assert_eq!(site.cap_frac, 1.0, "{}", site.name);
            assert_eq!(site.profiling_energy_j, 0.0, "{}", site.name);
        }
        assert_eq!(report.mean_est_saving, 0.0);
    }

    #[test]
    fn budget_clamps_fleet_cap_power() {
        let mut cfg = small_cfg();
        cfg.budget_frac = 0.55;
        cfg.rounds = 6;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        let budget = report.budget_w.expect("budget on");
        assert!(report.budget_enforced, "stagger should have completed");
        assert!(
            report.cap_power_w <= budget + 1e-6,
            "cap power {} exceeds budget {}",
            report.cap_power_w,
            budget
        );
    }

    #[test]
    fn failed_validation_escalates_retraining_until_published() {
        // Six sites at 40 epochs: site06 runs LeNet, whose first-pass
        // accuracy (~0.663) misses the 0.68 threshold. The RIC flags it,
        // the site retrains with an escalated epoch budget (80), passes,
        // and eventually gets profiled like everyone else.
        let cfg = FleetConfig {
            sites: 6,
            seed: 13,
            rounds: 7,
            train_epochs: 40,
            samples_per_epoch: 5_000,
            infer_steps_per_round: 10,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        };
        let mut fleet = Fleet::new(cfg).unwrap();
        let report = fleet.run().unwrap();
        let lenet = fleet.sites.iter().find(|s| s.zoo_model == "LeNet").expect("LeNet site");
        assert!(lenet.epochs_trained > 40, "epochs escalated: {}", lenet.epochs_trained);
        assert!(lenet.accuracy >= 0.68, "accuracy {} after retraining", lenet.accuracy);
        for site in &report.sites {
            assert!(site.profiling_energy_j > 0.0, "{} never profiled", site.name);
        }
    }

    #[test]
    fn churn_redeploys_and_reprofiles() {
        let mut cfg = small_cfg();
        cfg.churn_every = 3;
        cfg.rounds = 6;
        let mut fleet = Fleet::new(cfg).unwrap();
        let first_models: Vec<String> =
            fleet.sites.iter().map(|s| s.model_id.clone()).collect();
        let report = fleet.run().unwrap();
        for (site, old) in report.sites.iter().zip(&first_models) {
            assert_ne!(&site.model, old, "site should have churned");
            assert!(site.model.contains("#r"), "churned id {}", site.model);
        }
        // Both generations were profiled.
        for site in &fleet.sites {
            assert!(site.host.profile_log.len() >= 2, "{}", site.name);
        }
    }
}
