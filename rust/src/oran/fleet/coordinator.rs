//! The fleet coordinator: construction, the per-round orchestration
//! loop, scenario-event dispatch, the flat budget water-fill and the
//! checkpoint hooks.  The region tier's round phases (steady replay,
//! gateway fold, two-level water-fill) live in [`super::region`]; the
//! per-site round and the worker pool live in [`super::round`].

use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{Context, Result};

use crate::config::{setup_no1, setup_no2, HardwareConfig};
use crate::frost::{EnergyPolicy, QosClass};
use crate::obs::{CapCause, MetricsRegistry, TraceData, TraceSink};
use crate::power::{allocate_budget, HostProfile};
use crate::scenario::ScenarioEvent;
use crate::telemetry::hub::TelemetryHub;
use crate::telemetry::sampler::PowerSampler;
use crate::util::Seconds;
use crate::zoo::all_models;

use crate::oran::bus::{Bus, EndpointId};
use crate::oran::faults::FaultPlan;
use crate::oran::host::{HostCapKind, InferenceHost};
use crate::oran::messages::{LifecycleEvent, OranMessage};
use crate::oran::nonrt_ric::{
    lock_recovering, FleetAssignments, FleetProfileScheduler, NonRtRic, ProfileHealth,
    ProfileHealthState,
};
use crate::oran::smo::Smo;

use super::region::RegionRt;
use super::round::{FleetSite, SitePool, SiteTraffic};
use super::{site_seed, FleetConfig, FleetReport};

/// Mutable state of a running scenario script (the script itself is
/// frozen inside the shared `FleetConfig`).  All transitions happen on
/// the coordinator thread at round boundaries, so the §6 determinism
/// contract is untouched.
struct ScenarioRt {
    /// Index of the next unfired event in `Scenario::events`.
    next: usize,
    /// Per-site flash-crowd multiplier (1.0 outside surge windows).
    /// (Outage state is NOT duplicated here — `FleetSite::down` is the
    /// single source of truth every reader consults.)
    surge: Vec<f64>,
    /// Per-site thermal cap ceiling (1.0 = no derate in force).
    derate: Vec<f64>,
    /// (policy max_cap_frac, enforced cap) captured at derate time, so
    /// `DerateEnd` can restore the ceiling (and, on stock-cap fleets, the
    /// cap itself).
    pre_derate: Vec<Option<(f64, f64)>>,
    /// The budget fraction currently in force (starts at
    /// `FleetConfig::budget_frac`, moved by `BudgetStep` events).
    budget_frac: f64,
}

/// One fired scenario event, for the per-event ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct FiredEvent {
    pub round: u32,
    pub event: ScenarioEvent,
    /// Human-readable description (the CLI ledger line).
    pub detail: String,
}

/// The fleet simulator (see module docs for the round structure).
pub struct Fleet {
    /// The scenario, frozen at construction: the worker pool and the
    /// coordinator read the same shared snapshot, so the configuration
    /// cannot drift mid-run (`Arc` makes it immutable by construction).
    pub config: Arc<FleetConfig>,
    pub bus: Arc<Bus>,
    pub smo: Smo,
    pub nonrt: NonRtRic,
    pub sites: Vec<FleetSite>,
    assignments: FleetAssignments,
    pub(crate) pool: SitePool,
    /// Interned global-fabric ids the gateway routes by.
    pub(crate) smo_id: EndpointId,
    pub(crate) nonrt_id: EndpointId,
    pub round: u32,
    profiles_ingested: usize,
    lifecycle_ingested: usize,
    pub(crate) budget_applied: bool,
    /// True once at least one full water-fill has been pushed (gates the
    /// reservation path in `enforce_budget`).
    pub(crate) ever_enforced: bool,
    /// Mutable scenario state (None when the fleet runs no scenario).
    scenario_rt: Option<ScenarioRt>,
    /// Region-tier runtime (§16): Some iff the configured [`RegionMap`]
    /// is hierarchical (more than one region).  A flat fleet — or a
    /// single-region map, which is roll-up metadata only — keeps this
    /// None and steps exactly as before.
    ///
    /// [`RegionMap`]: super::RegionMap
    pub(crate) region_rt: Option<RegionRt>,
    /// The flight recorder (§14): the coordinator-recorded trace spine.
    /// Scenario events land here even with tracing off — the per-event
    /// ledger ([`Fleet::fired_events`]) is derived from the sink.
    pub trace: TraceSink,
    /// Fleet-level named counters/gauges/summaries (§14); [`Fleet::report`]
    /// merges the per-site, SMO and bus counters on top of a clone.
    pub(crate) metrics: MetricsRegistry,
    /// The first cap-affecting trigger awaiting the next water-fill push:
    /// `(cause, trigger event id)`.  First setter per pending fill wins;
    /// consumed only when `enforce_budget` actually pushes allocations,
    /// so a trigger survives waiting rounds until the fill lands (§14).
    pub(crate) pending_cause: Option<(CapCause, Option<u64>)>,
    /// Profile-path health shared with the scheduler rApp (§13): the
    /// scheduler writes quarantine decisions, the coordinator acts on
    /// them (blank assignment + budget reservation) and lifts them.
    pub(crate) profile_health: ProfileHealth,
    /// Per-site quarantine release round (None = not quarantined).
    quarantine_release: Vec<Option<u32>>,
}

/// How often a traffic-driven fleet re-runs the load-weighted budget
/// water-fill (in rounds).  Non-traffic fleets allocate once, as before.
const BUDGET_REFRESH_ROUNDS: u32 = 4;
/// Lower bound on a site's offered-load budget weight: even a site whose
/// last slot saw zero demand keeps a quarter share, so its throughput
/// curve never collapses to all-zeros (which would pin it at min_cap).
/// The top-level regional split (§16) applies the same floor to a
/// region's load factor.
pub(crate) const MIN_BUDGET_WEIGHT: f64 = 0.25;

impl Fleet {
    pub fn new(config: FleetConfig) -> Result<Fleet> {
        anyhow::ensure!(config.sites > 0, "fleet needs at least one site");
        anyhow::ensure!(config.budget_frac > 0.0, "budget_frac must be positive");
        anyhow::ensure!(
            config.policy_lease_rounds != 1,
            "policy_lease_rounds of 1 expires before any renewal can land; \
             use 0 (no leases) or >= 2"
        );
        if let Some(tr) = &config.traffic {
            tr.validate().context("invalid traffic config")?;
        }
        if let Some(scen) = &config.scenario {
            let tr = config
                .traffic
                .as_ref()
                .context("a scenario script requires FleetConfig::traffic")?;
            scen.validate(config.sites, tr).context("invalid scenario script")?;
        }
        if let Some(rm) = &config.regions {
            rm.validate(config.sites).context("invalid region map")?;
        }
        let bus = Bus::new();
        if let Some(fc) = &config.faults {
            let mut plan = FaultPlan::new(fc.clone()).context("invalid fault config")?;
            plan.set_trace(config.trace);
            bus.set_fault_plan(Some(plan));
        }
        let mut smo = Smo::new(bus.clone());
        smo.set_trace(config.trace);
        let mut nonrt = NonRtRic::new(bus.clone(), config.min_accuracy);
        let smo_id = bus.resolve("smo");
        let nonrt_id = bus.resolve("nonrt-ric");
        // Region gateways intern their fabric handles up front (§16);
        // hierarchical only — a single-region map is roll-up metadata and
        // must leave the stepping path (and the fabric) untouched.
        let region_rt = config
            .regions
            .as_ref()
            .filter(|rm| rm.is_hierarchical())
            .map(|rm| RegionRt::new(rm.clone(), &bus));
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        let assignments: FleetAssignments = Arc::new(Mutex::new(Vec::new()));
        let retention =
            if config.sample_retention > 0 { Some(config.sample_retention) } else { None };
        let mut sites = Vec::with_capacity(config.sites);
        for i in 0..config.sites {
            let name = format!("site{:02}", i + 1);
            let global_ep = bus.endpoint(&name); // downward routing target
            let hw: HardwareConfig = if i % 2 == 0 { setup_no1() } else { setup_no2() };
            let tdp_w = hw.gpu.tdp_w;
            let min_cap_frac = hw.gpu.min_cap_frac;
            let zoo_index = i % zoo.len();
            let entry = &zoo[zoo_index];
            let model_id = format!("{}@{}", entry.name, name);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            let local_bus = Bus::new();
            let local_smo = local_bus.endpoint("smo");
            local_bus.endpoint("nonrt-ric");
            let mut host =
                InferenceHost::new(local_bus.clone(), &name, hw, site_seed(config.seed, i));
            host.deploy(&model_id, workload.clone(), true);
            host.set_trace_caps(config.trace);
            let hub = Arc::new(TelemetryHub::new());
            let sampler = PowerSampler::with_retention(
                hub.clone(),
                tdp_w,
                min_cap_frac,
                Seconds(0.1),
                site_seed(config.seed, i) ^ 0x5A3F,
                retention,
            );
            let qos = [QosClass::EnergySaver, QosClass::Balanced, QosClass::LatencyCritical]
                [i % 3];
            // Traffic state is seeded per site so arrival streams replay
            // bit-for-bit regardless of worker-thread count (§6).
            let phases = config.scenario.as_ref().map_or(0, |s| s.phases.len());
            let traffic = config
                .traffic
                .as_ref()
                .map(|tr| SiteTraffic::new(tr, i, qos, site_seed(config.seed, i), phases));
            let policy = EnergyPolicy {
                id: format!("{name}-qos"),
                qos,
                enabled: config.frost_enabled,
                lease_rounds: config.policy_lease_rounds,
                ..EnergyPolicy::default_policy()
            };
            // Per-site A1 policy, waiting in the local fabric for round 1.
            // Recorded as the SMO's intent so lease renewals re-assert it.
            smo.record_policy(&name, policy.clone());
            local_bus.send("smo", &name, OranMessage::PolicyUpdate(policy));
            smo.enrol_host(&name);
            lock_recovering(&assignments).push((name.clone(), model_id.clone()));
            sites.push(FleetSite {
                index: i,
                name,
                global_ep,
                local_bus,
                local_smo,
                host,
                hub,
                sampler,
                zoo_index,
                zoo_model: entry.name,
                model_id,
                workload,
                qos,
                trained: false,
                epochs_trained: 0,
                outbox: Vec::new(),
                workload_energy_j: 0.0,
                round_energy_j: 0.0,
                profiling_energy_j: 0.0,
                wall_s: 0.0,
                samples: 0,
                accuracy: 0.0,
                last_gpu_power_w: 0.0,
                rounds_run: 0,
                down: false,
                traffic,
            });
        }
        if let Some(scen) = &config.scenario {
            // Derate ceilings must stay above each target site's driver
            // floor, or the clamp could not be enforced.  Checked against
            // the *constructed* sites so the hardware-mix rule lives in
            // exactly one place (the loop above).
            for te in &scen.events {
                if let ScenarioEvent::Derate { site, max_cap_frac } = te.event {
                    let gpu = &sites[site].host.testbed.hw.gpu;
                    anyhow::ensure!(
                        max_cap_frac >= gpu.min_cap_frac,
                        "derate cap {max_cap_frac} at site {site} is below the {} driver \
                         floor {}",
                        gpu.name,
                        gpu.min_cap_frac
                    );
                }
            }
        }
        let profile_health: ProfileHealth = Arc::new(Mutex::new(ProfileHealthState::default()));
        if config.frost_enabled {
            let mut scheduler =
                FleetProfileScheduler::new(assignments.clone(), config.max_concurrent_profiles);
            if config.profile_timeout_rounds > 0 {
                scheduler = scheduler.with_resilience(
                    config.profile_timeout_rounds,
                    config.profile_max_attempts,
                    config.seed ^ 0x0F0F_5CED,
                    profile_health.clone(),
                );
            }
            nonrt.add_rapp(Box::new(scheduler));
        }
        let requested = if config.threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            config.threads
        };
        let workers = requested.clamp(1, config.sites);
        let scenario_rt = config.scenario.as_ref().map(|_| ScenarioRt {
            next: 0,
            surge: vec![1.0; config.sites],
            derate: vec![1.0; config.sites],
            pre_derate: vec![None; config.sites],
            budget_frac: config.budget_frac,
        });
        let quarantine_release = vec![None; config.sites];
        // One trace round = one traffic slot of sim time (0 s/round for
        // fixed-workload fleets, which have no wall-synchronised clock).
        let round_s = config.traffic.as_ref().map_or(0.0, |t| t.slot_s());
        let mut trace = TraceSink::new(config.trace, round_s);
        if let Some(rm) = &config.regions {
            // Single-region maps register too: the roll-up dimension is
            // metadata, valid whether or not the fleet steps hierarchically.
            trace.set_region_map(rm.site_region.clone());
        }
        let config = Arc::new(config);
        let pool = SitePool::spawn(workers, config.clone());
        Ok(Fleet {
            config,
            bus,
            smo,
            nonrt,
            sites,
            assignments,
            pool,
            smo_id,
            nonrt_id,
            round: 0,
            profiles_ingested: 0,
            lifecycle_ingested: 0,
            budget_applied: false,
            ever_enforced: false,
            scenario_rt,
            region_rt,
            trace,
            metrics: MetricsRegistry::new(),
            pending_cause: None,
            profile_health,
            quarantine_release,
        })
    }

    /// Execute one orchestration round (module docs, steps 1–7).
    pub fn run_round(&mut self) -> Result<()> {
        self.round += 1;
        // Flight recorder (§14): open the round span; its id anchors any
        // cap change this round cannot attribute to a sharper trigger.
        self.trace.begin_round(self.round);
        // Fault clock (§13): the installed plan (if any) advances to this
        // round and releases held-back messages whose delay elapsed.
        self.bus.advance_fault_round();

        // 0. Scenario events due this round fire first, on the
        //    coordinator (DESIGN.md §11): outage/recovery topology,
        //    surge multipliers, budget steps and derates are all settled
        //    before the scheduler or any site acts, so the round is one
        //    consistent world state for every worker-thread count.
        self.apply_due_events()?;
        //    Quarantines due for release re-enter the fleet before the
        //    scheduler steps, so the re-stagger can start this round.
        self.release_due_quarantines();

        // 1. Non-RT RIC: ingest lifecycle events, stagger ProfileRequests.
        self.nonrt.step()?;
        //    Act on fresh quarantine decisions and renew A1 leases before
        //    the fabric pumps, so both ride this round's delivery (§13).
        self.absorb_quarantines();
        self.renew_leases()?;
        self.bus.deliver_all();

        // 2. Gateway down: global → site-local, moving each message (the
        //    sender rides along as a shared intern-table handle).  A down
        //    site receives nothing — its global endpoint queues traffic
        //    until recovery (bounded by `holdback_cap`, oldest dropped
        //    first), so a pre-outage profile request is processed at most
        //    once, after the site returns.  Any delivered message is a
        //    disturbance (§16): it evicts the site from steady replay so
        //    the message is actually processed.
        for (i, site) in self.sites.iter().enumerate() {
            if site.down {
                if self.config.holdback_cap > 0 {
                    let dropped =
                        site.global_ep.truncate_oldest(self.config.holdback_cap) as u64;
                    self.metrics.inc("holdback.dropped", dropped);
                }
                continue;
            }
            let mut delivered = false;
            for (from, msg) in site.global_ep.drain() {
                site.local_bus.send(&from, &site.name, msg);
                delivered = true;
            }
            if delivered {
                if let Some(rt) = self.region_rt.as_mut() {
                    rt.dirty[i] = true;
                }
            }
        }

        // 3. Parallel site phase on the persistent pool; hierarchical
        //    fleets replay steady sites on the coordinator first (§16)
        //    and run only the active remainder.
        if self.region_rt.is_some() {
            self.run_site_phase_regions()?;
        } else {
            self.pool.run_phase(&mut self.sites).context("parallel site phase")?;
        }
        //    Ingest worker-side cap moves (lease fallbacks/restores,
        //    policy clamps) in site-index order on the coordinator —
        //    same §6 discipline as the gateway merge — so the trace is
        //    bit-identical for any worker-thread count.
        if self.trace.enabled() {
            let anchor = self.trace.round_anchor();
            for i in 0..self.sites.len() {
                for ev in self.sites[i].host.drain_cap_events() {
                    let cause = match ev.kind {
                        HostCapKind::LeaseFallback => CapCause::LeaseFallback,
                        HostCapKind::LeaseRestore => CapCause::Recovery,
                        HostCapKind::PolicyClamp => CapCause::WaterFill,
                    };
                    self.trace.record(
                        Some(i as u32),
                        TraceData::CapChange {
                            cause,
                            from: ev.from,
                            to: ev.to,
                            trigger: anchor,
                        },
                    );
                }
            }
        }

        // 4. Gateway up, in site order (thread-count independent), with
        //    training/deployment lifecycle fanned out to the non-RT RIC.
        //    Hierarchical fleets fold per-site KPMs into one aggregate
        //    per region instead (§16) — O(regions) on the global fabric.
        if self.region_rt.is_some() {
            self.gateway_up_regions();
        } else {
            for site in &mut self.sites {
                let from = site.global_ep.id();
                for msg in site.outbox.drain(..) {
                    let for_ric = matches!(
                        &msg,
                        OranMessage::Lifecycle(
                            LifecycleEvent::TrainingFinished { .. }
                                | LifecycleEvent::Deployed { .. }
                        )
                    );
                    if for_ric {
                        self.bus.fanout_ids(from, &[self.smo_id, self.nonrt_id], msg);
                    } else {
                        self.bus.send_ids(from, self.smo_id, msg);
                    }
                }
            }
        }
        self.bus.deliver_all();
        self.smo.step();
        if self.trace.enabled() {
            for (host, reason) in self.smo.drain_trace_rejects() {
                let site =
                    self.sites.iter().position(|s| s.name == host).map(|i| i as u32);
                self.trace.record(site, TraceData::KpmReject { host, reason });
            }
        }

        // 5. Record fresh FROST decisions in the catalogue so the
        //    scheduler stops re-requesting them, and react to validation
        //    failures: a flagged model retrains next round with an
        //    escalated epoch budget. Both logs are ingested by index —
        //    no per-record cloning.
        while self.profiles_ingested < self.smo.profile_records.len() {
            let r = &self.smo.profile_records[self.profiles_ingested];
            let _ = self.nonrt.catalogue.set_optimal_cap(&r.model, r.optimal_cap);
            self.profiles_ingested += 1;
        }
        while self.lifecycle_ingested < self.smo.lifecycle_log.len() {
            if self.trace.enabled() {
                let detail =
                    format!("{:?}", self.smo.lifecycle_log[self.lifecycle_ingested]);
                self.trace.record(None, TraceData::Lifecycle { detail });
            }
            if let LifecycleEvent::FlaggedForRetraining { model, .. } =
                &self.smo.lifecycle_log[self.lifecycle_ingested]
            {
                if let Some(site) = self.sites.iter_mut().find(|s| &s.model_id == model) {
                    site.trained = false;
                }
            }
            self.lifecycle_ingested += 1;
        }
        // Demand-shift re-profiles route through the scheduler: forget
        // the model's recorded cap, and the FleetProfileScheduler
        // re-requests it at ≤ max_concurrent_profiles sites per round.
        for site in &mut self.sites {
            if let Some(t) = site.traffic.as_mut() {
                if std::mem::take(&mut t.reprofile_pending) {
                    let _ = self.nonrt.catalogue.clear_optimal_cap(&site.model_id);
                    self.trace.record(Some(site.index as u32), TraceData::Reprofile);
                }
            }
        }

        // 6. Global power budget, as soon as enough of the stagger has
        //    profiled (unprofiled or down sites have their current cap
        //    wattage *reserved*, so partial allocations still conserve
        //    the budget).  Traffic-driven fleets re-balance periodically:
        //    the water-fill weights sites by offered load, and the
        //    diurnal day keeps moving that load around.  Scenario events
        //    (budget steps, outages, recoveries, derates) force an
        //    immediate re-water-fill by clearing `budget_applied`.
        //    Hierarchical fleets run the two-level fill (§16).
        if self.config.frost_enabled && self.current_budget_frac() < 1.0 {
            let refresh = self.config.traffic.is_some()
                && self.budget_applied
                && self.round % BUDGET_REFRESH_ROUNDS == 0;
            if !self.budget_applied || refresh {
                if self.region_rt.is_some() {
                    self.enforce_budget_regions()?;
                } else {
                    self.enforce_budget()?;
                }
            }
        }

        // 7. Workload churn.
        if self.config.churn_every > 0 && self.round % self.config.churn_every == 0 {
            self.churn();
        }

        // Round close.  The cap-wattage sum is a cheap O(sites)
        // coordinator pass fed to the metrics summary on every run —
        // traced or not, so reports are identical either way; the trace
        // additionally records the fabric's fault fates, one line per
        // site, and the round_end span.
        let mut cap_w = 0.0;
        for site in &self.sites {
            cap_w += site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
        }
        self.metrics.observe("round.cap_w", cap_w);
        if self.trace.enabled() {
            for (fate, interface, count) in self.bus.drain_fault_trace() {
                self.trace.record(None, TraceData::Fault { fate, interface, count });
            }
            for site in &self.sites {
                self.trace.record(
                    Some(site.index as u32),
                    TraceData::SiteRound {
                        cap_frac: site.host.testbed.cap_frac(),
                        down: site.down,
                    },
                );
            }
            self.trace.record(None, TraceData::RoundEnd { cap_power_w: cap_w });
        }
        Ok(())
    }

    /// Remember the round's first cap-affecting trigger (§14): the next
    /// water-fill push attributes its cap changes to `(cause, trigger)`.
    /// No-op with tracing off; first setter wins until the pending fill
    /// consumes it.
    fn note_cause(&mut self, cause: CapCause, trigger: Option<u64>) {
        if self.trace.enabled() && self.pending_cause.is_none() {
            self.pending_cause = Some((cause, trigger));
        }
    }

    /// The site index a scenario event targets (None = fleet-wide).
    fn event_site(event: &ScenarioEvent) -> Option<u32> {
        match event {
            ScenarioEvent::SiteDown { site }
            | ScenarioEvent::SiteUp { site }
            | ScenarioEvent::Derate { site, .. }
            | ScenarioEvent::DerateEnd { site } => Some(*site as u32),
            ScenarioEvent::SurgeStart { site, .. } | ScenarioEvent::SurgeEnd { site } => {
                site.map(|s| s as u32)
            }
            ScenarioEvent::BudgetStep { .. } => None,
        }
    }

    /// The per-event scenario ledger, reconstructed from the trace spine
    /// (scenario events are recorded even with tracing off), in dispatch
    /// order — the typed successor of the old `event_log` field.
    pub fn fired_events(&self) -> Vec<FiredEvent> {
        self.trace
            .events()
            .iter()
            .filter_map(|e| match &e.data {
                TraceData::Scenario { event, detail } => Some(FiredEvent {
                    round: e.round,
                    event: *event,
                    detail: detail.clone(),
                }),
                _ => None,
            })
            .collect()
    }

    /// The budget fraction currently in force: the configured one, unless
    /// a scenario `BudgetStep` has moved it.
    pub fn current_budget_frac(&self) -> f64 {
        self.scenario_rt.as_ref().map_or(self.config.budget_frac, |rt| rt.budget_frac)
    }

    /// The thermal cap ceiling in force at `site` (1.0 = no derate).
    /// The flat and the regional water-fill both filter legal operating
    /// points against it.
    pub(crate) fn derate_ceiling(&self, site: usize) -> f64 {
        self.scenario_rt.as_ref().map_or(1.0, |rt| rt.derate[site])
    }

    /// True while `site` sits in profile quarantine (§13).
    pub fn is_quarantined(&self, site: usize) -> bool {
        self.quarantine_release.get(site).map_or(false, |q| q.is_some())
    }

    /// Adopt fresh scheduler quarantine decisions (§13): blank the
    /// site's assignment (like a scripted outage does), forget its stale
    /// demand weight, and schedule its release.  The site keeps serving —
    /// only the profile/budget control path treats it as untrusted.
    fn absorb_quarantines(&mut self) {
        if self.config.profile_timeout_rounds == 0 {
            return;
        }
        let quarantined = lock_recovering(&self.profile_health).quarantined.clone();
        if quarantined.is_empty() {
            return;
        }
        for i in 0..self.sites.len() {
            if self.quarantine_release[i].is_some()
                || !quarantined.contains(self.sites[i].name.as_str())
            {
                continue;
            }
            self.quarantine_release[i] = Some(self.round + self.config.quarantine_rounds);
            lock_recovering(&self.assignments)[i].1 = String::new();
            let name = self.sites[i].name.clone();
            self.smo.clear_host_load(&name);
            let tid =
                self.trace.record(Some(i as u32), TraceData::Quarantine {
                    host: name,
                    entered: true,
                });
            self.note_cause(CapCause::Quarantine, tid);
            // Its cap wattage is reserved in the water-fill until release.
            self.budget_applied = false;
        }
    }

    /// Lift quarantines whose sit-out elapsed: restore the assignment so
    /// the scheduler's rolling cursor re-staggers the site into a fresh
    /// attempt cycle, and force a budget re-fill.
    fn release_due_quarantines(&mut self) {
        for i in 0..self.sites.len() {
            let due = matches!(self.quarantine_release[i], Some(r) if r <= self.round);
            if !due {
                continue;
            }
            self.quarantine_release[i] = None;
            let (name, down) = {
                let site = &self.sites[i];
                (site.name.clone(), site.down)
            };
            lock_recovering(&self.profile_health).quarantined.remove(name.as_str());
            // A down site stays blanked; its recovery event restores it.
            if !down {
                let pair = (name.clone(), self.sites[i].model_id.clone());
                lock_recovering(&self.assignments)[i] = pair;
            }
            let tid = self
                .trace
                .record(Some(i as u32), TraceData::Quarantine { host: name, entered: false });
            self.note_cause(CapCause::Recovery, tid);
            self.budget_applied = false;
        }
    }

    /// Renew every up site's A1 lease (§13) by re-pushing the policy the
    /// SMO *intends* for it (its policy book): on a healthy fabric no
    /// lease ever lapses, while a droppy one starves the host into its
    /// safe-cap fallback within `policy_lease_rounds` missed renewals.
    /// A host in fallback heals the moment one renewal lands (it
    /// restores the pre-fallback cap, clamped to the renewed bounds), and
    /// a dropped budget push is re-asserted by the very next renewal —
    /// the host's own view is never trusted, so a stale ceiling cannot
    /// outlive one delivered A1 message.
    fn renew_leases(&mut self) -> Result<()> {
        if self.config.policy_lease_rounds == 0 {
            return Ok(());
        }
        for site in &self.sites {
            // Skip sites that have not applied their construction-time
            // policy yet (round 1): it is still queued on the site-local
            // fabric and a renewal would only duplicate it.
            if site.down || site.rounds_run == 0 {
                continue;
            }
            let Some(intended) = self.smo.intended_policy(&site.name) else { continue };
            let mut policy = intended.clone();
            policy.lease_rounds = self.config.policy_lease_rounds;
            self.smo.push_policy_to(&site.name, policy)?;
            self.metrics.inc("lease.renewals", 1);
        }
        Ok(())
    }

    /// Fire every scripted event due at the current round (coordinator
    /// thread, before anything else in the round — see `run_round` step 0).
    fn apply_due_events(&mut self) -> Result<()> {
        loop {
            let due = {
                let Some(rt) = self.scenario_rt.as_ref() else { return Ok(()) };
                let scen = self.config.scenario.as_ref().expect("rt implies scenario");
                match scen.events.get(rt.next) {
                    Some(te) if te.round <= self.round => *te,
                    _ => return Ok(()),
                }
            };
            if let Some(rt) = self.scenario_rt.as_mut() {
                rt.next += 1;
            }
            // Ledger first (unconditionally — the fired-event log derives
            // from the sink), so the transition below can cite the event
            // id as the trigger of any cap change it records.
            let tid = self.trace.record_scenario(Self::event_site(&due.event), due.event);
            self.apply_event(due.event, tid)?;
            match due.event {
                ScenarioEvent::BudgetStep { .. } => {
                    self.note_cause(CapCause::BudgetStep, tid)
                }
                ScenarioEvent::SiteDown { .. } => self.note_cause(CapCause::WaterFill, tid),
                ScenarioEvent::SiteUp { .. } => self.note_cause(CapCause::Recovery, tid),
                ScenarioEvent::Derate { .. } => self.note_cause(CapCause::DerateClamp, tid),
                ScenarioEvent::DerateEnd { .. } => self.note_cause(CapCause::Recovery, tid),
                ScenarioEvent::SurgeStart { .. } | ScenarioEvent::SurgeEnd { .. } => {}
            }
        }
    }

    fn apply_event(&mut self, event: ScenarioEvent, tid: Option<u64>) -> Result<()> {
        // Take the runtime state out of `self` for the duration of the
        // transition so sites, SMO and catalogue can be borrowed freely.
        let mut rt = self.scenario_rt.take().expect("events only fire with a scenario");
        let mut topology_changed = false;
        match event {
            ScenarioEvent::BudgetStep { budget_frac } => {
                // Re-water-fill immediately at the new level (step 6 of
                // this same round).
                rt.budget_frac = budget_frac;
                self.budget_applied = false;
            }
            ScenarioEvent::SiteDown { site } => {
                let s = &mut self.sites[site];
                s.down = true;
                // Requests waiting at the failed site are lost, not
                // teleported: shed them now, charge them to the first
                // outage slot's ledger.
                if let Some(t) = s.traffic.as_mut() {
                    t.pending_shed += t.server.shed_all();
                }
                // Blank the scheduler assignment so the stagger skips the
                // dark site instead of queueing duplicate profile
                // requests against it every round (it would double-charge
                // profiling energy at recovery).
                lock_recovering(&self.assignments)[site].1 = String::new();
                // And drop its stale demand weight at the SMO.
                let name = self.sites[site].name.clone();
                self.smo.clear_host_load(&name);
                // Region tier: the intra-region ledger forgets the dark
                // site too, and when its *last* up-site goes down the
                // top-level allocator must forget the region's aggregate
                // load weight — a stale entry would keep steering budget
                // share to a region that offers nothing (§16).
                if let Some(rrt) = self.region_rt.as_mut() {
                    rrt.site_load[site] = 0.0;
                    let r = rrt.map.site_region[site] as usize;
                    if rrt.members[r].iter().all(|&i| self.sites[i].down) {
                        let region_name = rrt.map.regions[r].name.clone();
                        self.smo.clear_host_load(&region_name);
                    }
                }
                self.budget_applied = false;
                topology_changed = true;
            }
            ScenarioEvent::SiteUp { site } => {
                let s = &mut self.sites[site];
                s.down = false;
                let pair = (s.name.clone(), s.model_id.clone());
                lock_recovering(&self.assignments)[site] = pair;
                // Its profile is still fresh (same model), so the forced
                // refresh folds it straight back into the water-fill.
                self.budget_applied = false;
                topology_changed = true;
            }
            ScenarioEvent::SurgeStart { mult, site } => {
                match site {
                    Some(i) => rt.surge[i] = mult,
                    None => rt.surge.fill(mult),
                }
                topology_changed = true;
            }
            ScenarioEvent::SurgeEnd { site } => {
                match site {
                    Some(i) => rt.surge[i] = 1.0,
                    None => rt.surge.fill(1.0),
                }
                topology_changed = true;
            }
            ScenarioEvent::Derate { site, max_cap_frac } => {
                rt.derate[site] = max_cap_frac;
                let s = &mut self.sites[site];
                rt.pre_derate[site] =
                    Some((s.host.policy.max_cap_frac, s.host.testbed.cap_frac()));
                // Clamp the A1 ceiling (the profiler obeys policy bounds)
                // and the enforced cap itself; the cap change invalidates
                // the site's step-estimate cache (`Testbed::set_cap_frac`).
                s.host.policy.max_cap_frac = s.host.policy.max_cap_frac.min(max_cap_frac);
                let pre_cap = s.host.testbed.cap_frac();
                if pre_cap > max_cap_frac {
                    s.host.testbed.set_cap_frac(max_cap_frac);
                    self.trace.record(
                        Some(site as u32),
                        TraceData::CapChange {
                            cause: CapCause::DerateClamp,
                            from: pre_cap,
                            to: max_cap_frac,
                            trigger: tid,
                        },
                    );
                }
                if self.config.frost_enabled {
                    // Online system tuning: forget the recorded optimum so
                    // the scheduler re-profiles under the new ceiling.
                    let _ = self.nonrt.catalogue.clear_optimal_cap(&s.model_id);
                }
                self.budget_applied = false;
            }
            ScenarioEvent::DerateEnd { site } => {
                rt.derate[site] = 1.0;
                if let Some((policy_max, pre_cap)) = rt.pre_derate[site].take() {
                    let s = &mut self.sites[site];
                    s.host.policy.max_cap_frac = policy_max;
                    if self.config.frost_enabled {
                        // Re-profile to exploit the restored headroom (or
                        // let the budget refresh re-allocate it).
                        let _ = self.nonrt.catalogue.clear_optimal_cap(&s.model_id);
                    } else {
                        // Stock caps: return to the pre-derate setting.
                        let cur = s.host.testbed.cap_frac();
                        s.host.testbed.set_cap_frac(pre_cap);
                        if (cur - pre_cap).abs() > 1e-12 {
                            self.trace.record(
                                Some(site as u32),
                                TraceData::CapChange {
                                    cause: CapCause::Recovery,
                                    from: cur,
                                    to: pre_cap,
                                    trigger: tid,
                                },
                            );
                        }
                    }
                }
                self.budget_applied = false;
            }
        }
        self.scenario_rt = Some(rt);
        if topology_changed {
            self.recompute_rate_mults();
        }
        Ok(())
    }

    /// Push the effective arrival-rate multiplier to every site's
    /// generator: the surge factor layered with outage redistribution —
    /// a down site's users re-attach to the *up* sites of its region,
    /// weighted by user counts, so regional demand is conserved while a
    /// site is dark.  The redistribution domain is the configured
    /// [`RegionMap`]'s region when one is present (§16), else contiguous
    /// `Scenario::region_size` blocks — for region-free fleets the
    /// float-sum order is unchanged, so runs stay bit-identical.
    /// With no sites down and no surge the product is exactly 1.0 and the
    /// arrival streams stay bit-identical to a scenario-free run.
    ///
    /// [`RegionMap`]: super::RegionMap
    fn recompute_rate_mults(&mut self) {
        let Some(rt) = self.scenario_rt.as_ref() else { return };
        let scen = self.config.scenario.as_ref().expect("rt implies scenario");
        let Some(tr) = self.config.traffic.as_ref() else { return };
        let n = self.sites.len();
        let groups: Vec<Vec<usize>> = match &self.config.regions {
            Some(rm) => rm.members(),
            None => {
                let region = scen.region_size.max(1);
                let mut groups = Vec::new();
                let mut start = 0usize;
                while start < n {
                    let end = (start + region).min(n);
                    groups.push((start..end).collect());
                    start = end;
                }
                groups
            }
        };
        let mut mults = vec![1.0f64; n];
        for group in &groups {
            let total: f64 = group.iter().map(|&i| tr.site_users(i)).sum();
            let up: f64 = group
                .iter()
                .filter(|&&i| !self.sites[i].down)
                .map(|&i| tr.site_users(i))
                .sum();
            for &i in group {
                let redistribute = if self.sites[i].down || up <= 0.0 {
                    // A dark site generates nothing; the multiplier is
                    // moot but kept sane for its recovery round.
                    1.0
                } else if up < total {
                    total / up
                } else {
                    1.0
                };
                mults[i] = rt.surge[i] * redistribute;
            }
        }
        for (site, m) in self.sites.iter_mut().zip(&mults) {
            if let Some(t) = site.traffic.as_mut() {
                t.gen.set_rate_mult(*m);
            }
        }
    }

    /// Water-fill the global GPU budget across the profiled throughput
    /// curves and push the allocation down as per-site A1 policies.
    ///
    /// **Budget conservation invariant (DESIGN.md §11).**  Sites that
    /// cannot join the water-fill — a stale profile right after churn, a
    /// scripted outage — do *not* silently vanish from the ledger (the
    /// old behaviour would have spread the full budget over the rest
    /// while the dropped site kept drawing under its old cap, busting the
    /// global budget).  Instead each such site's **current cap wattage is
    /// reserved** off the top, and only the remainder is allocated.  When
    /// the remainder cannot cover the participating sites' driver floors
    /// yet (early stagger), the allocation waits — caps are left as they
    /// are, which is exactly the pre-enforcement state.
    ///
    /// Traffic-driven sites report their offered load on KPM; the
    /// water-fill scales each site's throughput curve by its load share,
    /// so budget watts flow to the sites with the most demand behind
    /// them.  Without load reports every weight is exactly 1.0 and the
    /// allocation is bit-identical to the unweighted one.  Derated sites
    /// only offer operating points under their thermal ceiling.
    fn enforce_budget(&mut self) -> Result<()> {
        let loads = self.smo.offered_load_by_host();
        let mean_load = if loads.is_empty() {
            0.0
        } else {
            loads.values().sum::<f64>() / loads.len() as f64
        };
        let mut profiles = Vec::new();
        let mut alloc_sites: Vec<usize> = Vec::new();
        let mut reserved_w = 0.0;
        let mut waiting = 0usize; // stale-profile sites (stagger/churn)
        for (i, site) in self.sites.iter().enumerate() {
            let down = site.down;
            let quarantined = self.quarantine_release[i].is_some();
            let derate_max = self.scenario_rt.as_ref().map_or(1.0, |rt| rt.derate[i]);
            let fresh = matches!(
                site.host.profile_log.last(),
                Some(out) if out.model == site.model_id
            );
            if down || quarantined || !fresh {
                // Reserve the site's worst-case draw under its current
                // cap: a dark site still holds its cap for the recovery
                // round, an unprofiled site keeps running under its old
                // cap until the stagger reaches it, and a quarantined
                // site's profile path is untrusted until release (§13).
                // Neither dark nor quarantined sites count as "waiting" —
                // their reservation *is* their allocation.
                if !down && !quarantined {
                    waiting += 1;
                }
                reserved_w += site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                continue;
            }
            let out = site.host.profile_log.last().expect("checked fresh");
            // Points below the site's policy minimum are not legal
            // operating points; including them would let the allocator
            // "spend" less than the later `.max(min)` raise actually
            // enforces, silently busting the budget.  Points above a
            // thermal derate ceiling are equally illegal — the hardware
            // cannot run there.
            let min_frac = site.host.policy.min_cap_frac;
            let legal: Vec<_> = out
                .points
                .iter()
                .filter(|p| {
                    p.cap_frac >= min_frac - 1e-9 && p.cap_frac <= derate_max + 1e-9
                })
                .cloned()
                .collect();
            let pts = if legal.is_empty() {
                if derate_max < 1.0 {
                    // The profile has no point under the ceiling (a very
                    // deep derate): hold the site at its clamped cap and
                    // reserve those watts instead of allocating.
                    reserved_w +=
                        site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                    continue;
                }
                out.points.clone()
            } else {
                legal
            };
            let mut profile =
                HostProfile::from_profile(&site.name, site.host.testbed.hw.gpu.tdp_w, &pts);
            // Floored: a site that reported zero demand for one slot must
            // shrink, not vanish — weight 0 would zero its whole curve
            // and pin it at min_cap until the next refresh, which a
            // latency_critical site cannot afford at the next morning
            // ramp.
            let weight = match loads.get(&site.name) {
                Some(&l) if mean_load > 0.0 => (l / mean_load).max(MIN_BUDGET_WEIGHT),
                _ => 1.0,
            };
            for p in profile.points.iter_mut() {
                p.1 *= weight;
            }
            profiles.push(profile);
            alloc_sites.push(i);
        }
        if profiles.is_empty() {
            return Ok(()); // nothing profiled yet; retry next round
        }
        // The *first* allocation is always full-fleet: mid-stagger the
        // waiting sites still sit at stock caps, and allocating the thin
        // remainder would clamp the profiled sites far below their final
        // share (caps ratchet down, not up, between profiles).  Once a
        // full water-fill has run, later rounds use the reservation path
        // so churn, outages and derates re-balance immediately without
        // ever busting the budget.
        if waiting > 0 && !self.ever_enforced {
            return Ok(());
        }
        // The budget is defined over the whole fleet's TDP — including
        // reserved sites, whose watts come off the top.
        let total_tdp: f64 =
            self.sites.iter().map(|s| s.host.testbed.hw.gpu.tdp_w).sum();
        let budget_w = total_tdp * self.current_budget_frac();
        let remainder = budget_w - reserved_w;
        let Some(allocs) = allocate_budget(&profiles, remainder, 5.0) else {
            if reserved_w > 0.0 {
                // The remainder cannot cover the participants' floors
                // while reservations hold the rest: wait for the stagger
                // or the recovery to free watts.
                return Ok(());
            }
            anyhow::bail!("fleet power budget below the driver floors");
        };
        // Attribution (§14): consume the round's pending trigger — set by
        // whatever forced this fill (budget step, outage, derate,
        // quarantine) even if the fill had to wait a round — or fall back
        // to a plain water-fill anchored at the round span.
        let (cause, trigger) = self
            .pending_cause
            .take()
            .unwrap_or((CapCause::WaterFill, self.trace.round_anchor()));
        for (i, alloc) in alloc_sites.iter().zip(&allocs) {
            let site = &mut self.sites[*i];
            let mut policy = site.host.policy.clone();
            policy.id = format!("{}-budget", site.name);
            policy.max_cap_frac = alloc.cap_frac.max(policy.min_cap_frac);
            let from = site.host.policy.max_cap_frac;
            if (from - policy.max_cap_frac).abs() > 1e-12 {
                self.trace.record(
                    Some(*i as u32),
                    TraceData::CapChange { cause, from, to: policy.max_cap_frac, trigger },
                );
            }
            // Enact the ceiling immediately on the coordinator: budget
            // conservation is a per-round invariant (a scripted budget
            // step must bite in its own round), so the clamp cannot wait
            // for the A1 message to land at the site next round.  The
            // delivered policy then re-applies the same bound, a no-op.
            if site.host.testbed.cap_frac() > policy.max_cap_frac {
                site.host.testbed.set_cap_frac(policy.max_cap_frac);
            }
            self.smo.push_policy_to(&site.name, policy)?;
        }
        // Enforced-in-full only once no site is waiting on a fresh
        // profile; until then, retry every round (down sites are excluded
        // deliberately — their reservation *is* their allocation).
        self.ever_enforced = true;
        self.budget_applied = waiting == 0;
        Ok(())
    }

    /// Rotate every site to its next zoo model (workload churn): deploy it
    /// under a fresh catalogue id, mark the site untrained, and point the
    /// profile scheduler at the new assignment.
    fn churn(&mut self) {
        let zoo = all_models();
        let reference_gpu = setup_no1().gpu;
        for site in &mut self.sites {
            site.zoo_index = (site.zoo_index + 1) % zoo.len();
            let entry = &zoo[site.zoo_index];
            let model_id = format!("{}@{}#r{}", entry.name, site.name, self.round);
            let mut workload = entry.workload(&reference_gpu);
            workload.name = model_id.clone();
            site.host.deploy(&model_id, workload.clone(), true);
            site.workload = workload;
            site.zoo_model = entry.name;
            site.model_id = model_id.clone();
            site.trained = false;
            site.epochs_trained = 0;
            // A down site stays blanked for the scheduler; its new
            // assignment lands when the recovery event restores it.
            let assigned = if site.down { String::new() } else { model_id };
            lock_recovering(&self.assignments)[site.index] = (site.name.clone(), assigned);
        }
        // Churn is a fleet-wide disturbance: every site retrains from
        // scratch, so no recorded steady delta can survive it (§16).
        if let Some(rt) = self.region_rt.as_mut() {
            rt.dirty.fill(true);
        }
        // New models re-profile; refresh the budget allocation afterwards.
        self.budget_applied = false;
    }

    /// Run the configured number of rounds and return the roll-up.
    pub fn run(&mut self) -> Result<FleetReport> {
        for _ in 0..self.config.rounds {
            self.run_round()?;
        }
        Ok(self.report())
    }

    // ---- checkpoint hooks (DESIGN.md §15) ------------------------------
    //
    // Everything below exists so `crate::ckpt::snapshot` can read and
    // restore the coordinator's *private* state; pub fields (round, smo,
    // nonrt, sites, bus, trace, config) are reached directly.  None of
    // these run on the hot path.

    /// Private coordinator scalars `(profiles_ingested,
    /// lifecycle_ingested, budget_applied, ever_enforced,
    /// pending_cause)`.  `round` is pub and travels in the snapshot
    /// header instead.
    #[allow(clippy::type_complexity)]
    pub fn ckpt_coord_state(
        &self,
    ) -> (usize, usize, bool, bool, Option<(CapCause, Option<u64>)>) {
        (
            self.profiles_ingested,
            self.lifecycle_ingested,
            self.budget_applied,
            self.ever_enforced,
            self.pending_cause,
        )
    }

    pub fn restore_ckpt_coord_state(
        &mut self,
        profiles_ingested: usize,
        lifecycle_ingested: usize,
        budget_applied: bool,
        ever_enforced: bool,
        pending_cause: Option<(CapCause, Option<u64>)>,
    ) {
        self.profiles_ingested = profiles_ingested;
        self.lifecycle_ingested = lifecycle_ingested;
        self.budget_applied = budget_applied;
        self.ever_enforced = ever_enforced;
        self.pending_cause = pending_cause;
    }

    /// Mutable scenario-runtime state `(next, surge, derate, pre_derate,
    /// budget_frac)`; None when the fleet runs no scenario.
    #[allow(clippy::type_complexity)]
    pub fn ckpt_scenario_state(
        &self,
    ) -> Option<(usize, &[f64], &[f64], &[Option<(f64, f64)>], f64)> {
        self.scenario_rt.as_ref().map(|rt| {
            (
                rt.next,
                rt.surge.as_slice(),
                rt.derate.as_slice(),
                rt.pre_derate.as_slice(),
                rt.budget_frac,
            )
        })
    }

    /// Restore the scenario runtime.  No-op on a scenario-free fleet
    /// (whose snapshots carry no scenario section either).
    pub fn restore_ckpt_scenario_state(
        &mut self,
        next: usize,
        surge: Vec<f64>,
        derate: Vec<f64>,
        pre_derate: Vec<Option<(f64, f64)>>,
        budget_frac: f64,
    ) {
        if let Some(rt) = self.scenario_rt.as_mut() {
            rt.next = next;
            rt.surge = surge;
            rt.derate = derate;
            rt.pre_derate = pre_derate;
            rt.budget_frac = budget_frac;
        }
    }

    /// Per-site quarantine release rounds (None = not quarantined).
    pub fn ckpt_quarantine_release(&self) -> &[Option<u32>] {
        &self.quarantine_release
    }

    pub fn restore_ckpt_quarantine_release(&mut self, release: Vec<Option<u32>>) {
        self.quarantine_release = release;
    }

    /// The shared profile-health ledger `(quarantined sites,
    /// quarantine_events)`, cloned out of its mutex.
    pub fn ckpt_profile_health(&self) -> (Vec<String>, u64) {
        let h = lock_recovering(&self.profile_health);
        (h.quarantined.iter().cloned().collect(), h.quarantine_events)
    }

    pub fn restore_ckpt_profile_health(
        &mut self,
        quarantined: Vec<String>,
        quarantine_events: u64,
    ) {
        let mut h = lock_recovering(&self.profile_health);
        h.quarantined = quarantined.into_iter().collect();
        h.quarantine_events = quarantine_events;
    }

    /// The scheduler's shared assignment table, cloned out of its mutex.
    pub fn ckpt_assignments(&self) -> Vec<(String, String)> {
        lock_recovering(&self.assignments).clone()
    }

    pub fn restore_ckpt_assignments(&mut self, assignments: Vec<(String, String)>) {
        *lock_recovering(&self.assignments) = assignments;
    }

    /// The live coordinator metrics registry (lease renewals, holdback
    /// drops, per-round cap-wattage summary — NOT the derived counters
    /// [`Fleet::report`] folds in, which recompute from live state).
    pub fn ckpt_metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn ckpt_metrics_mut(&mut self) -> &mut MetricsRegistry {
        &mut self.metrics
    }
}
