//! Fleet-scale O-RAN simulation: N heterogeneous inference hosts under one
//! SMO/non-RT RIC, with FROST profiling scheduled across the fleet.
//!
//! The paper evaluates FROST on a single host; O-RAN deployments that
//! matter are *fleets* of ML-enabled sites whose energy is optimised
//! RAN-wide. This module scales every single-host code path to N hosts:
//!
//! * each site owns an [`InferenceHost`] (virtual testbed + FROST
//!   microservice), a **private fabric shard** (its own [`Bus`]) and a
//!   **per-host [`TelemetryHub`] shard** with a bounded power-sample ring;
//! * sites step **concurrently on a persistent worker pool** (spawned once
//!   in [`Fleet::new`], fed over channels — no per-round thread spawning);
//!   cross-site traffic only crosses between phases, through a gateway that
//!   merges per-site outboxes onto the global fabric **in site-index
//!   order** — so a run is bit-for-bit identical for any worker-thread
//!   count;
//! * the non-RT RIC hosts a [`FleetProfileScheduler`] rApp that staggers
//!   FROST profiling (at most `max_concurrent_profiles` sites per round);
//! * the SMO enforces a **global GPU power budget** by water-filling the
//!   budget across the profiled throughput curves
//!   ([`crate::power::allocate_budget`]) and pushing the allocation down
//!   as per-site A1 policies;
//! * a [`RegionMap`] (DESIGN.md §16) partitions the fleet into regions:
//!   steady sites replay cached deltas on the coordinator, per-site KPMs
//!   fold into one aggregate per region at a gateway, and the budget
//!   water-fill runs in two levels (SMO splits across regions, each
//!   region fills locally) — top-level per-round work is O(regions), not
//!   O(sites), which is what carries the fleet to 10,000 sites.
//!
//! Round structure (one `run_round`):
//!
//! 0. scenario event dispatch (DESIGN.md §11, when a script is set):
//!    budget steps, site outages/recoveries, flash-crowd surge windows
//!    and thermal derates fire on the coordinator at the round boundary,
//!    so the round is one consistent world state for every worker-thread
//!    count (the per-event ledger is [`Fleet::fired_events`]);
//! 1. non-RT RIC step: validation/publishing of finished training, then
//!    the scheduler rApp issues staggered `ProfileRequest`s;
//! 2. gateway **down**: site-addressed global traffic enters each site's
//!    local fabric;
//! 3. **parallel** site phase: each site applies policies, runs any
//!    requested FROST profile, then its workload (initial training in its
//!    first round; afterwards steady-state inference — or, in a
//!    traffic-driven scenario (`FleetConfig::traffic`, DESIGN.md §9), one
//!    seeded diurnal traffic slot through the queue + batch former),
//!    publishing to its telemetry shard;
//! 4. gateway **up** (site order) + SMO ingest of KPM/profile results;
//! 5. FROST decisions recorded into the model catalogue;
//! 6. budget allocation once every site is profiled;
//! 7. optional workload churn (sites rotate to the next zoo model).
//!
//! Hot-path notes (DESIGN.md §8): workload estimates are memoized per
//! testbed (`simulator::StepEstimateCache`), endpoints are interned
//! (`bus::EndpointId`), gateway transfers move messages instead of cloning
//! them, and SMO logs are ingested by index, so a steady-state round does
//! no avoidable repeated work.
//!
//! Module layout: [`coordinator`] owns [`Fleet`] (construction, the round
//! loop, scenario dispatch, the flat water-fill, checkpoint hooks);
//! [`region`] owns the region tier (§16); [`round`] owns the per-site
//! round and the worker pool; [`report`] owns the roll-up types.
//!
//! [`InferenceHost`]: super::host::InferenceHost
//! [`Bus`]: super::bus::Bus
//! [`TelemetryHub`]: crate::telemetry::hub::TelemetryHub
//! [`FleetProfileScheduler`]: super::nonrt_ric::FleetProfileScheduler

mod coordinator;
mod region;
mod report;
mod round;

pub use coordinator::{FiredEvent, Fleet};
pub use region::{RegionMap, RegionSpec};
pub(crate) use region::{RegionRt, SteadyDelta};
pub use report::{FleetReport, RegionReport, SiteReport};
pub use round::{FleetSite, SiteTraffic};

use anyhow::Result;

use crate::config::setup_no1;
use crate::obs::MetricsRegistry;
use crate::scenario::Scenario;
use crate::simulator::Testbed;
use crate::traffic::{ArrivalKind, TrafficConfig};
use crate::util::bench::{bench, group, BenchStats};
use crate::zoo::model_by_name;

use super::faults::FaultConfig;

/// Knobs of a fleet scenario.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of ML-enabled sites (hardware alternates between the paper's
    /// setup no.1 and no.2; models rotate through the 16-entry zoo).
    pub sites: usize,
    pub seed: u64,
    /// Worker threads for the parallel site phase (0 = one per core).
    /// Results are identical for every value — see module docs.
    pub threads: usize,
    /// Orchestration rounds to run.
    pub rounds: u32,
    /// Epochs of a model's initial training (first round of each model).
    pub train_epochs: u32,
    pub samples_per_epoch: u64,
    /// Inference batches per site in each steady-state round.
    pub infer_steps_per_round: u64,
    /// Global GPU power budget as a fraction of the fleet's summed TDP
    /// (>= 1.0 disables budget enforcement).
    pub budget_frac: f64,
    /// At most this many sites run a FROST profile in any one round.
    pub max_concurrent_profiles: usize,
    /// Master FROST switch; false = stock caps everywhere (baseline runs).
    pub frost_enabled: bool,
    /// Rotate every site to its next zoo model each `n` rounds (0 = never).
    pub churn_every: u32,
    /// Validation threshold at the non-RT RIC.
    pub min_accuracy: f64,
    /// Per-site power-sample retention: ring capacity of each site's
    /// `PowerSampler` (0 = unbounded). Bounded by default so arbitrarily
    /// long fleet runs stay O(1) in memory.
    pub sample_retention: usize,
    /// User-driven request load (DESIGN.md §9).  When set, trained sites
    /// serve seeded diurnal traffic slots instead of the fixed
    /// `infer_steps_per_round` loop once `TrafficConfig::warmup_rounds`
    /// have passed; None keeps the legacy fixed workload bit-identical.
    pub traffic: Option<TrafficConfig>,
    /// Scripted operational events (DESIGN.md §11): budget steps, site
    /// outages/recoveries, flash-crowd surges, thermal derating.  Events
    /// fire at round boundaries on the coordinator, so a scripted day is
    /// bit-identical for any worker-thread count.  Requires `traffic`.
    pub scenario: Option<Scenario>,
    /// Seeded fabric fault injection on the *global* bus (§13): drops,
    /// delays, duplicates, reorders and telemetry corruption, all decided
    /// per message on the coordinator thread so runs stay bit-identical
    /// for any worker-thread count.  None = a perfect fabric, exactly as
    /// before this knob existed.
    pub faults: Option<FaultConfig>,
    /// A1 policy lease TTL in rounds (§13): every pushed policy carries
    /// it, the SMO renews each round, and a host that misses this many
    /// consecutive renewals falls back to its conservative safe cap.
    /// 0 = no leases (the historical behavior).
    pub policy_lease_rounds: u32,
    /// Profile-request patience in scheduler rounds before a retry (§13);
    /// 0 disables timeout/retry/quarantine entirely (historical behavior:
    /// the scheduler re-requests every round a model stays cap-less).
    pub profile_timeout_rounds: u32,
    /// Issues per site (first + retries) before the scheduler quarantines
    /// it; only read when `profile_timeout_rounds > 0`.
    pub profile_max_attempts: u32,
    /// Rounds a quarantined site sits out before the coordinator restores
    /// its assignment and the scheduler re-staggers it.
    pub quarantine_rounds: u32,
    /// Bound on a down site's held-back global inbox: the oldest messages
    /// beyond the cap are dropped (counted in the `holdback.dropped`
    /// metric) so a long outage cannot grow the gateway queue without
    /// limit.  0 = unbounded (not recommended).
    pub holdback_cap: usize,
    /// Record the deterministic flight-recorder trace (DESIGN.md §14).
    /// Off by default: every `TraceSink::record` call is then a no-op,
    /// so the hot path stays bit-identical to an untraced build.
    /// Scenario events are still ledgered either way — the fired-event
    /// ledger ([`Fleet::fired_events`]) derives from the sink.
    pub trace: bool,
    /// Region tier (DESIGN.md §16): the site → region partition with
    /// per-region names and budget weights.  None = flat fleet,
    /// bit-identical to pre-region builds.  A single-region map is
    /// roll-up metadata only (the flat stepping path runs, still
    /// bit-identical); with more than one region the fleet steps
    /// hierarchically — steady-delta replay, gateway KPM folding and the
    /// two-level budget water-fill.
    pub regions: Option<RegionMap>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            sites: 4,
            seed: 7,
            threads: 0,
            rounds: 8,
            train_epochs: 60,
            samples_per_epoch: 20_000,
            infer_steps_per_round: 40,
            budget_frac: 1.0,
            max_concurrent_profiles: 4,
            frost_enabled: true,
            churn_every: 0,
            min_accuracy: 0.68,
            sample_retention: 512,
            traffic: None,
            scenario: None,
            faults: None,
            policy_lease_rounds: 0,
            profile_timeout_rounds: 0,
            profile_max_attempts: 3,
            quarantine_rounds: 8,
            holdback_cap: 1024,
            trace: false,
            regions: None,
        }
    }
}

/// Deterministic per-site seed derivation (public so tests can rebuild a
/// single site's exact testbed).
pub fn site_seed(fleet_seed: u64, site_index: usize) -> u64 {
    fleet_seed ^ (site_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Canonical hot-path bench scenario (DESIGN.md §8): site counts swept by
/// the perf-trajectory record.
pub const BENCH_SITE_COUNTS: [usize; 3] = [4, 16, 64];
/// Region-tier sweep (§16): `(sites, regions)` pairs at roughly √N
/// regions, up to the 10,000-site target.  The 64-site point pairs with
/// the flat 64-site bench for the flat-vs-hierarchical comparison.
pub const REGION_BENCH_POINTS: [(usize, usize); 4] =
    [(64, 8), (256, 16), (1_000, 32), (10_000, 100)];
/// Rounds run before measurement so every site is trained and profiled
/// (the stagger is widened to the site count) and measured rounds are
/// pure steady state — the cost a deployed fleet pays forever.
pub const BENCH_WARMUP_ROUNDS: u32 = 3;

/// The config of `frost fleet --sites N --seed 7`, stagger widened for a
/// fast warm-up.
pub fn bench_config(sites: usize) -> FleetConfig {
    FleetConfig { sites, seed: 7, max_concurrent_profiles: sites, ..FleetConfig::default() }
}

/// The region-tier bench config: [`bench_config`] plus an auto-partition
/// into `regions`.  Above 64 sites the warm-up workload is shrunk
/// (training epochs, samples, sampler retention) — the measured quantity
/// is the steady-state *round*, and a 10,000-site sweep cannot afford
/// minutes of warm-up training per point.
pub fn region_bench_config(sites: usize, regions: usize) -> FleetConfig {
    let mut cfg = bench_config(sites);
    cfg.regions = Some(RegionMap::auto(sites, regions).expect("bench region shapes are valid"));
    if sites > 64 {
        cfg.train_epochs = 8;
        cfg.samples_per_epoch = 2_000;
        cfg.sample_retention = 64;
    }
    cfg
}

/// The whole fleet bench suite — steady-state round throughput across
/// [`BENCH_SITE_COUNTS`], the region-tier sweep across
/// [`REGION_BENCH_POINTS`], plus the cached-vs-uncached execution-model
/// microbench. One definition, called by BOTH `benches/fleet.rs` and the
/// `frost bench` CLI subcommand, so the two `BENCH_fleet.json` recorders
/// cannot drift apart.
pub fn run_bench_suite(target_s: f64) -> Result<Vec<(String, BenchStats)>> {
    let mut results: Vec<(String, BenchStats)> = Vec::new();

    group("fleet steady-state round throughput (seed 7)");
    for sites in BENCH_SITE_COUNTS {
        let mut fleet = Fleet::new(bench_config(sites))?;
        for _ in 0..BENCH_WARMUP_ROUNDS {
            fleet.run_round()?;
        }
        let name = format!("fleet round ({sites} sites)");
        let stats = bench(&name, target_s, || {
            fleet.run_round().expect("steady-state round")
        });
        results.push((name, stats));
    }

    group("region tier: steady-state round throughput (seed 7, §16)");
    for (sites, regions) in REGION_BENCH_POINTS {
        let mut fleet = Fleet::new(region_bench_config(sites, regions))?;
        // Three extra warm-up rounds past the flat suite's: steady-delta
        // promotion needs two bitwise-identical post-profile rounds, and
        // the measured round should replay, not promote.
        for _ in 0..BENCH_WARMUP_ROUNDS + 3 {
            fleet.run_round()?;
        }
        let name = format!("region round ({sites} sites, {regions} regions)");
        let stats = bench(&name, target_s, || {
            fleet.run_round().expect("steady-state region round")
        });
        results.push((name, stats));
    }

    group("traffic: queue + batch-former round (8 sites, seed 7)");
    {
        let tr = TrafficConfig {
            users_per_site: 2_000,
            requests_per_user_per_day: 40.0,
            day_s: 1_200.0,
            slots_per_day: 12,
            warmup_rounds: 3,
            max_batch: 64,
            kind: ArrivalKind::bursty(),
            ..TrafficConfig::default()
        };
        let warmup = tr.warmup_rounds;
        let mut cfg = bench_config(8);
        cfg.traffic = Some(tr);
        let mut fleet = Fleet::new(cfg)?;
        // Warm past training + stagger so every benched round serves a
        // traffic slot (the day wraps, so rounds are unlimited).
        for _ in 0..=warmup {
            fleet.run_round()?;
        }
        let name = "traffic round (8 sites)";
        let stats = bench(name, target_s, || {
            fleet.run_round().expect("traffic round")
        });
        results.push((name.to_string(), stats));
    }

    group("execution model: fixed-point solver vs memoized estimate");
    let hw = setup_no1();
    let w = model_by_name("ResNet").expect("zoo model").workload(&hw.gpu);

    // Uncached: the raw 12-iteration fixed point (with the capping loop's
    // 48-step bisection engaged) on every call.
    let mut uncached = Testbed::new(hw.clone(), 7);
    uncached.set_cap_frac(0.6);
    let name = "train_step fixed-point solve (cap 60%)";
    let solver = bench(name, target_s / 2.0, || uncached.exec.train_step(&w, 128));
    results.push((name.to_string(), solver));

    // Cached: one miss, then pure lookups — the steady-state fleet path.
    let mut cached = Testbed::new(hw, 7);
    cached.set_cap_frac(0.6);
    let name = "train_estimate memoized (cap 60%)";
    let memo = bench(name, target_s / 2.0, || cached.train_estimate(&w, 128));
    results.push((name.to_string(), memo));
    // Cache behaviour goes through the same metrics surface the fleet
    // report uses (§14) instead of a hand-rolled stats line.
    let mut cache_metrics = MetricsRegistry::new();
    let (hits, misses) = cached.cache.stats();
    cache_metrics.inc("cache.hits", hits);
    cache_metrics.inc("cache.misses", misses);
    cache_metrics.inc("cache.invalidations", cached.cache.invalidations());
    for (name, count) in cache_metrics.counters() {
        println!("  {name}: {count}");
    }

    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            sites: 3,
            seed: 11,
            rounds: 5,
            train_epochs: 40,
            samples_per_epoch: 10_000,
            infer_steps_per_round: 20,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn fleet_profiles_all_sites_and_saves() {
        let mut fleet = Fleet::new(small_cfg()).unwrap();
        let report = fleet.run().unwrap();
        assert_eq!(report.sites.len(), 3);
        for site in &report.sites {
            assert!(site.workload_energy_j > 0.0, "{} energy", site.name);
            assert!(site.profiling_energy_j > 0.0, "{} must have profiled", site.name);
            assert!(site.cap_frac <= 1.0, "{} cap {}", site.name, site.cap_frac);
            assert!(site.accuracy > 0.5, "{} accuracy {}", site.name, site.accuracy);
            assert!(site.samples > 0);
        }
        // FROST capped most of the fleet below stock power.
        let capped = report.sites.iter().filter(|s| s.cap_frac < 0.999).count();
        assert!(capped >= 2, "only {capped} of 3 sites capped");
        assert!(report.mean_est_saving > 0.03, "mean est saving {}", report.mean_est_saving);
        assert!(report.kpm_reports > 0);
        // The telemetry shards integrated a comparable amount of energy to
        // the workload accounting (they track operating-point envelopes).
        for site in &report.sites {
            assert!(site.hub_energy_j > 0.0);
        }
    }

    #[test]
    fn same_seed_same_fleet_energy_bitwise() {
        let a = Fleet::new(small_cfg()).unwrap().run().unwrap();
        let b = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_profiling_energy_j.to_bits(), b.fleet_profiling_energy_j.to_bits());
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.workload_energy_j.to_bits(), y.workload_energy_j.to_bits());
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut one = small_cfg();
        one.threads = 1;
        let mut many = small_cfg();
        many.threads = 3;
        let a = Fleet::new(one).unwrap().run().unwrap();
        let b = Fleet::new(many).unwrap().run().unwrap();
        assert_eq!(a.fleet_workload_energy_j.to_bits(), b.fleet_workload_energy_j.to_bits());
        assert_eq!(a.fleet_round_energy_j.to_bits(), b.fleet_round_energy_j.to_bits());
        assert_eq!(a.kpm_reports, b.kpm_reports);
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn pool_survives_more_workers_than_sites() {
        let mut cfg = small_cfg();
        cfg.threads = 16; // > sites: clamps to one worker per site
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.sites.len(), 3);
        let baseline = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(
            report.fleet_workload_energy_j.to_bits(),
            baseline.fleet_workload_energy_j.to_bits()
        );
    }

    #[test]
    fn dead_worker_surfaces_as_error_not_panic() {
        let mut cfg = small_cfg();
        cfg.threads = 1;
        let mut fleet = Fleet::new(cfg).unwrap();
        fleet.run_round().unwrap();
        fleet.pool.kill_worker_for_test();
        let err = fleet.run_round().expect_err("dead worker must be an Err");
        assert!(format!("{err:#}").contains("died"), "unexpected error: {err:#}");
    }

    #[test]
    fn lease_of_one_round_is_rejected_at_construction() {
        let mut cfg = small_cfg();
        cfg.policy_lease_rounds = 1;
        assert!(Fleet::new(cfg).is_err());
    }

    #[test]
    fn lease_renewals_on_a_healthy_fabric_never_expire() {
        let mut cfg = small_cfg();
        cfg.policy_lease_rounds = 3;
        let mut fleet = Fleet::new(cfg).unwrap();
        let report = fleet.run().unwrap();
        assert!(report.lease_renewals > 0, "renewals must have been pushed");
        assert_eq!(report.lease_expiries, 0, "no expiry without fabric faults");
        assert!(report.fault_ledger.is_none(), "no plan installed");
        // The run is bit-identical to a lease-less one: renewals re-apply
        // the in-force policy, which is a no-op on a healthy fabric.
        let base = Fleet::new(small_cfg()).unwrap().run().unwrap();
        assert_eq!(
            report.fleet_workload_energy_j.to_bits(),
            base.fleet_workload_energy_j.to_bits()
        );
        for (x, y) in report.sites.iter().zip(&base.sites) {
            assert_eq!(x.cap_frac.to_bits(), y.cap_frac.to_bits());
        }
    }

    #[test]
    fn bounded_sampler_retention_holds_in_long_runs() {
        let mut cfg = small_cfg();
        cfg.sample_retention = 8;
        cfg.rounds = 12;
        let mut fleet = Fleet::new(cfg).unwrap();
        fleet.run().unwrap();
        for site in &fleet.sites {
            assert!(site.sampler.retained_len() <= 8, "{}", site.name);
            assert!(
                site.sampler.recorded() > site.sampler.retained_len() as u64,
                "{} should have evicted old samples",
                site.name
            );
        }
    }

    #[test]
    fn disabled_frost_keeps_stock_caps_and_skips_profiling() {
        let mut cfg = small_cfg();
        cfg.frost_enabled = false;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        for site in &report.sites {
            assert_eq!(site.cap_frac, 1.0, "{}", site.name);
            assert_eq!(site.profiling_energy_j, 0.0, "{}", site.name);
        }
        assert_eq!(report.mean_est_saving, 0.0);
    }

    #[test]
    fn budget_clamps_fleet_cap_power() {
        let mut cfg = small_cfg();
        cfg.budget_frac = 0.55;
        cfg.rounds = 6;
        let report = Fleet::new(cfg).unwrap().run().unwrap();
        let budget = report.budget_w.expect("budget on");
        assert!(report.budget_enforced, "stagger should have completed");
        assert!(
            report.cap_power_w <= budget + 1e-6,
            "cap power {} exceeds budget {}",
            report.cap_power_w,
            budget
        );
    }

    #[test]
    fn failed_validation_escalates_retraining_until_published() {
        // Six sites at 40 epochs: site06 runs LeNet, whose first-pass
        // accuracy (~0.663) misses the 0.68 threshold. The RIC flags it,
        // the site retrains with an escalated epoch budget (80), passes,
        // and eventually gets profiled like everyone else.
        let cfg = FleetConfig {
            sites: 6,
            seed: 13,
            rounds: 7,
            train_epochs: 40,
            samples_per_epoch: 5_000,
            infer_steps_per_round: 10,
            max_concurrent_profiles: 2,
            ..FleetConfig::default()
        };
        let mut fleet = Fleet::new(cfg).unwrap();
        let report = fleet.run().unwrap();
        let lenet = fleet.sites.iter().find(|s| s.zoo_model == "LeNet").expect("LeNet site");
        assert!(lenet.epochs_trained > 40, "epochs escalated: {}", lenet.epochs_trained);
        assert!(lenet.accuracy >= 0.68, "accuracy {} after retraining", lenet.accuracy);
        for site in &report.sites {
            assert!(site.profiling_energy_j > 0.0, "{} never profiled", site.name);
        }
    }

    #[test]
    fn churn_redeploys_and_reprofiles() {
        let mut cfg = small_cfg();
        cfg.churn_every = 3;
        cfg.rounds = 6;
        let mut fleet = Fleet::new(cfg).unwrap();
        let first_models: Vec<String> =
            fleet.sites.iter().map(|s| s.model_id.clone()).collect();
        let report = fleet.run().unwrap();
        for (site, old) in report.sites.iter().zip(&first_models) {
            assert_ne!(&site.model, old, "site should have churned");
            assert!(site.model.contains("#r"), "churned id {}", site.model);
        }
        // Both generations were profiled.
        for site in &fleet.sites {
            assert!(site.host.profile_log.len() >= 2, "{}", site.name);
        }
    }
}
