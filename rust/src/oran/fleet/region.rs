//! The region tier (DESIGN.md §16): hierarchical coordination that takes
//! the fleet from tens of sites to 10,000.
//!
//! A [`RegionMap`] partitions the sites into named, weighted regions.
//! With more than one region the fleet steps through three region-local
//! mechanisms, each replacing an O(sites) top-level pass with O(regions)
//! top-level work plus region-local remainders:
//!
//! * **steady-state replay** — a site whose round-over-round state delta
//!   is bitwise-identical twice in a row is *promoted*: its next rounds
//!   are replayed on the coordinator by re-applying the recorded
//!   [`SteadyDelta`] instead of travelling to a worker thread.  Any
//!   disturbance (a delivered message, a budget push, churn) evicts it
//!   back to the active set.  The promotion criterion is self-protecting:
//!   state that draws RNG or drifts never produces two identical deltas,
//!   so it simply stays active;
//! * **gateway fabric** — per-site KPMs terminate at the region gateway,
//!   which folds them into ONE aggregate KPM per region per round on the
//!   global bus (sums for power/energy/samples, maxima for
//!   utilisation/cap/p99, the region's offered-load ledger, a monotone
//!   per-gateway sequence number and a logical round clock).  Profile
//!   results and lifecycle events still ride upward individually —
//!   the SMO and non-RT RIC need them per site;
//! * **two-level water-fill** — the top level splits the budget
//!   remainder across regions by `spec.weight × regional offered-load
//!   factor` (O(regions)), and each region water-fills its sub-budget
//!   over its own members' throughput curves.  Per-site classification
//!   (down/quarantined/stale reservations, legal-point filtering,
//!   deep-derate holds) is byte-for-byte the flat algorithm's, so the
//!   §11 conservation invariant extends: Σ regional sub-budgets ≤ the
//!   in-force global budget, and within each region Σ applied cap
//!   wattage ≤ its sub-budget.
//!
//! A `RegionMap` with a single region is roll-up metadata only: the fleet
//! steps on the flat path and stays bit-identical to a region-free run.

use std::collections::BTreeSet;

use anyhow::{Context, Result};

use crate::obs::{CapCause, TraceData};
use crate::oran::bus::{Bus, EndpointId};
use crate::oran::messages::{KpmReport, LifecycleEvent, OranMessage};
use crate::power::{allocate_budget, Allocation, HostProfile};
use crate::util::Seconds;

use super::coordinator::MIN_BUDGET_WEIGHT;
use super::Fleet;

/// One named region of the fleet: a top-level water-fill participant.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSpec {
    /// Unique region name; the gateway reports KPMs under it, so it is
    /// also the key of the SMO's per-region offered-load ledger.
    pub name: String,
    /// Static budget weight (multiplied by the live load factor at the
    /// top-level split).  Must be positive and finite.
    pub weight: f64,
}

/// The site → region partition of a fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionMap {
    pub regions: Vec<RegionSpec>,
    /// `site_region[i]` = index into `regions` of site `i`'s region.
    pub site_region: Vec<u32>,
}

impl RegionMap {
    /// Partition `sites` into `n` contiguous regions of near-equal size:
    /// the first `sites % n` regions take one extra site, so **no region
    /// is ever empty** (a chunked `div_ceil` split would leave trailing
    /// regions without sites, e.g. 9 sites over 4 regions).
    pub fn auto(sites: usize, n: usize) -> Result<RegionMap> {
        anyhow::ensure!(n >= 1, "a fleet needs at least one region");
        anyhow::ensure!(n <= sites, "--regions {n} exceeds the fleet's {sites} sites");
        let base = sites / n;
        let extra = sites % n;
        let mut site_region = Vec::with_capacity(sites);
        for r in 0..n {
            let len = base + usize::from(r < extra);
            site_region.extend(std::iter::repeat(r as u32).take(len));
        }
        let regions = (0..n)
            .map(|r| RegionSpec { name: format!("region{:02}", r + 1), weight: 1.0 })
            .collect();
        Ok(RegionMap { regions, site_region })
    }

    /// True when the fleet actually steps hierarchically.  A one-region
    /// map is roll-up metadata: the flat path runs and stays
    /// bit-identical to a region-free fleet.
    pub fn is_hierarchical(&self) -> bool {
        self.regions.len() > 1
    }

    /// Member site indices per region, in site-index order.
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut m = vec![Vec::new(); self.regions.len()];
        for (site, &r) in self.site_region.iter().enumerate() {
            m[r as usize].push(site);
        }
        m
    }

    /// Hard-validate the map against the fleet size: full site coverage,
    /// in-range assignments, unique non-empty names, positive finite
    /// weights, and no empty region (a region owning no sites would
    /// divide by zero in its regional load mean).
    pub fn validate(&self, sites: usize) -> Result<()> {
        anyhow::ensure!(!self.regions.is_empty(), "region map needs at least one region");
        anyhow::ensure!(
            self.site_region.len() == sites,
            "region map assigns {} sites but the fleet has {sites}",
            self.site_region.len()
        );
        let mut names = BTreeSet::new();
        for spec in &self.regions {
            anyhow::ensure!(!spec.name.is_empty(), "region names must be non-empty");
            anyhow::ensure!(
                spec.weight.is_finite() && spec.weight > 0.0,
                "region '{}' weight {} must be positive and finite",
                spec.name,
                spec.weight
            );
            anyhow::ensure!(
                names.insert(spec.name.as_str()),
                "duplicate region name '{}'",
                spec.name
            );
        }
        let mut owned = vec![false; self.regions.len()];
        for (site, &r) in self.site_region.iter().enumerate() {
            anyhow::ensure!(
                (r as usize) < self.regions.len(),
                "site {site} mapped to undefined region {r}"
            );
            owned[r as usize] = true;
        }
        for (r, has) in owned.iter().enumerate() {
            anyhow::ensure!(
                *has,
                "region '{}' owns no sites (every region must own at least one)",
                self.regions[r].name
            );
        }
        Ok(())
    }
}

/// The recorded round-over-round state delta of a steady site.  Replay
/// re-applies it with the exact float adds the live round would have
/// produced, so a promoted site's scalars stay bitwise on-trajectory; the
/// site's telemetry shard and sampler are frozen while it is steady
/// (documented telemetry decimation, §16).
#[derive(Debug, Clone, Copy)]
pub(crate) struct SteadyDelta {
    pub(crate) d_total_j: f64,
    pub(crate) d_profiling_j: f64,
    /// SET per round (not accumulated): `round_energy_j` is the
    /// last-round figure; `workload_energy_j` grows by it.
    pub(crate) round_j: f64,
    pub(crate) d_wall_s: f64,
    pub(crate) d_samples: u64,
    /// SET per round, like the live path does.
    pub(crate) last_gpu_power_w: f64,
}

impl SteadyDelta {
    /// Bitwise equality — promotion demands the exact same delta twice;
    /// "close enough" would let replay drift off the live trajectory.
    pub(crate) fn bits_eq(&self, other: &SteadyDelta) -> bool {
        self.d_total_j.to_bits() == other.d_total_j.to_bits()
            && self.d_profiling_j.to_bits() == other.d_profiling_j.to_bits()
            && self.round_j.to_bits() == other.round_j.to_bits()
            && self.d_wall_s.to_bits() == other.d_wall_s.to_bits()
            && self.d_samples == other.d_samples
            && self.last_gpu_power_w.to_bits() == other.last_gpu_power_w.to_bits()
    }
}

/// Mutable region-tier runtime (None on flat fleets).  All transitions
/// happen on the coordinator thread at round boundaries, so the §6
/// determinism contract is untouched.
pub(crate) struct RegionRt {
    pub(crate) map: RegionMap,
    /// Member site indices per region, in site-index order (derived from
    /// the map once at construction).
    pub(crate) members: Vec<Vec<usize>>,
    /// Interned global-fabric sender handles of the region gateways
    /// (`"<region>-gw"`).  Send-only: nothing addresses a gateway, so no
    /// `Endpoint` is ever created for one.
    pub(crate) gateway_ids: Vec<EndpointId>,
    /// Per-gateway monotone KPM sequence numbers.
    pub(crate) gw_seq: Vec<u64>,
    /// Last allocated regional sub-budget in watts (None until the first
    /// two-level fill lands, or when the region's sub-fill failed).
    pub(crate) sub_budget_w: Vec<Option<f64>>,
    /// Per-site offered-load ledger (requests/s), updated from each KPM
    /// the gateway folds; survives steady rounds, so the aggregate's
    /// offered load is the region's standing demand, not just this
    /// round's reporters.
    pub(crate) site_load: Vec<f64>,
    /// Per-site promoted delta (None = active).
    pub(crate) steady: Vec<Option<SteadyDelta>>,
    /// Per-site previous round's delta, awaiting its confirming twin.
    pub(crate) prev_delta: Vec<Option<SteadyDelta>>,
    /// Per-site disturbance flag: set whenever coordinator-side state
    /// touched the site this round (a delivered message, a budget push,
    /// churn); consumed at the next phase, evicting the site from steady.
    pub(crate) dirty: Vec<bool>,
    /// Per-region count of replayed (steady) site-rounds.
    pub(crate) steady_rounds: Vec<u64>,
    /// Times a promoted site was evicted by a disturbance.
    pub(crate) disturbances: u64,
}

impl RegionRt {
    pub(crate) fn new(map: RegionMap, bus: &Bus) -> RegionRt {
        let members = map.members();
        let gateway_ids = map
            .regions
            .iter()
            .map(|spec| bus.resolve(&format!("{}-gw", spec.name)))
            .collect();
        let nregions = map.regions.len();
        let nsites = map.site_region.len();
        RegionRt {
            members,
            gateway_ids,
            gw_seq: vec![0; nregions],
            sub_budget_w: vec![None; nregions],
            site_load: vec![0.0; nsites],
            steady: vec![None; nsites],
            prev_delta: vec![None; nsites],
            dirty: vec![false; nsites],
            steady_rounds: vec![0; nregions],
            disturbances: 0,
            map,
        }
    }
}

impl Fleet {
    /// Could this site be promoted to steady replay?  Conservative: any
    /// mechanism that can change per-round behaviour (traffic slots,
    /// lease clocks, an unprofiled or churning model, an outage or
    /// quarantine) keeps it active.
    fn steady_eligible(&self, i: usize) -> bool {
        let site = &self.sites[i];
        if self.config.traffic.is_some()
            || self.config.policy_lease_rounds > 0
            || !site.trained
            || site.down
            || self.is_quarantined(i)
        {
            return false;
        }
        if self.config.frost_enabled
            && !matches!(site.host.profile_log.last(), Some(out) if out.model == site.model_id)
        {
            return false;
        }
        true
    }

    /// The region tier's site phase: replay steady sites on the
    /// coordinator (region-then-site index order, §6), run the active
    /// rest on the worker pool, then promote sites whose last two deltas
    /// match bitwise.
    pub(crate) fn run_site_phase_regions(&mut self) -> Result<()> {
        let mut rt = self.region_rt.take().expect("region phase requires a region runtime");
        let mut active: Vec<usize> = Vec::new();
        // (site, total_j, profiling_j, wall_s, samples) before the phase,
        // for delta extraction afterwards.
        let mut snaps: Vec<(usize, f64, f64, f64, u64)> = Vec::new();
        for r in 0..rt.members.len() {
            for idx in 0..rt.members[r].len() {
                let i = rt.members[r][idx];
                let was_dirty = std::mem::take(&mut rt.dirty[i]);
                if was_dirty {
                    // Disturbed: back to the active set; a promoted site
                    // counts as an eviction.
                    if rt.steady[i].take().is_some() {
                        rt.disturbances += 1;
                    }
                    rt.prev_delta[i] = None;
                    active.push(i);
                    continue;
                }
                if let Some(delta) = rt.steady[i] {
                    // Replay on the coordinator: the same scalar moves the
                    // live round made, in the same order.  `wall_s` and the
                    // sim clock advance by the same float add from the same
                    // base, so they stay bitwise consistent with each other.
                    let site = &mut self.sites[i];
                    site.host.total_energy_j += delta.d_total_j;
                    site.profiling_energy_j += delta.d_profiling_j;
                    site.round_energy_j = delta.round_j;
                    site.workload_energy_j += delta.round_j;
                    site.wall_s += delta.d_wall_s;
                    site.host.testbed.clock.advance(Seconds(delta.d_wall_s));
                    site.samples += delta.d_samples;
                    site.last_gpu_power_w = delta.last_gpu_power_w;
                    site.rounds_run += 1;
                    rt.steady_rounds[r] += 1;
                    continue;
                }
                if self.steady_eligible(i) {
                    let site = &self.sites[i];
                    snaps.push((
                        i,
                        site.host.total_energy_j,
                        site.profiling_energy_j,
                        site.wall_s,
                        site.samples,
                    ));
                } else {
                    rt.prev_delta[i] = None;
                }
                active.push(i);
            }
        }
        if let Err(e) = self.pool.run_phase_indices(&mut self.sites, &active) {
            self.region_rt = Some(rt);
            return Err(e).context("parallel site phase");
        }
        for (i, total0, prof0, wall0, samples0) in snaps {
            let site = &self.sites[i];
            let delta = SteadyDelta {
                d_total_j: site.host.total_energy_j - total0,
                d_profiling_j: site.profiling_energy_j - prof0,
                round_j: site.round_energy_j,
                d_wall_s: site.wall_s - wall0,
                d_samples: site.samples - samples0,
                last_gpu_power_w: site.last_gpu_power_w,
            };
            match rt.prev_delta[i] {
                Some(prev) if prev.bits_eq(&delta) => {
                    rt.steady[i] = Some(delta);
                    rt.prev_delta[i] = None;
                }
                _ => rt.prev_delta[i] = Some(delta),
            }
        }
        self.region_rt = Some(rt);
        Ok(())
    }

    /// The region tier's upward gateway: fold each region's per-site KPMs
    /// into one aggregate KPM on the global bus, forward everything else
    /// (profile results, lifecycle) individually from the gateway handle.
    /// Intra-region telemetry never touches the global bus — the
    /// top-level fabric carries O(regions) KPM traffic per round.
    pub(crate) fn gateway_up_regions(&mut self) {
        let mut rt = self.region_rt.take().expect("region gateway requires a region runtime");
        for r in 0..rt.members.len() {
            let gw = rt.gateway_ids[r];
            let mut saw_kpm = false;
            let mut gpu_w = 0.0;
            let mut cpu_w = 0.0;
            let mut dram_w = 0.0;
            let mut energy_j = 0.0;
            let mut samples = 0u64;
            let mut gpu_util = 0.0f64;
            let mut cap_frac = 0.0f64;
            let mut p99 = 0.0f64;
            for idx in 0..rt.members[r].len() {
                let i = rt.members[r][idx];
                for msg in self.sites[i].outbox.drain(..) {
                    match msg {
                        OranMessage::Kpm(k) => {
                            saw_kpm = true;
                            rt.site_load[i] = k.offered_load_per_s;
                            gpu_w += k.gpu_power_w;
                            cpu_w += k.cpu_power_w;
                            dram_w += k.dram_power_w;
                            energy_j += k.energy_j;
                            samples += k.samples_processed;
                            gpu_util = gpu_util.max(k.gpu_util);
                            cap_frac = cap_frac.max(k.cap_frac);
                            p99 = p99.max(k.p99_latency_s);
                        }
                        msg @ OranMessage::Lifecycle(
                            LifecycleEvent::TrainingFinished { .. }
                            | LifecycleEvent::Deployed { .. },
                        ) => {
                            self.bus.fanout_ids(gw, &[self.smo_id, self.nonrt_id], msg);
                        }
                        other => self.bus.send_ids(gw, self.smo_id, other),
                    }
                }
            }
            if saw_kpm {
                rt.gw_seq[r] += 1;
                let offered: f64 = rt.members[r].iter().map(|&i| rt.site_load[i]).sum();
                // The aggregate's timestamp is the *logical round clock*:
                // member sim-clocks run at different rates (profiling,
                // retraining), so the max member time could regress
                // between rounds and trip the SMO staleness watermark;
                // the round counter is monotone by construction.
                let kpm = KpmReport {
                    host: rt.map.regions[r].name.clone(),
                    at: Seconds(f64::from(self.round)),
                    model: None,
                    gpu_power_w: gpu_w,
                    cpu_power_w: cpu_w,
                    dram_power_w: dram_w,
                    gpu_util,
                    cap_frac,
                    samples_processed: samples,
                    energy_j,
                    offered_load_per_s: offered,
                    p99_latency_s: p99,
                    seq: rt.gw_seq[r],
                };
                self.bus.send_ids(gw, self.smo_id, OranMessage::Kpm(kpm));
                self.metrics.inc("region.gateway_kpms", 1);
            }
        }
        self.region_rt = Some(rt);
    }

    /// The two-level water-fill (§16).  Top level: split the budget
    /// remainder across regions with participants, by static weight ×
    /// live regional load factor — O(regions) allocator work.  Regional
    /// level: water-fill each sub-remainder over the region's own legal
    /// throughput curves and push the allocation region-locally.
    ///
    /// Two-pass: every region's sub-fill is solved before ANY policy is
    /// pushed, so one region's infeasible sub-budget (all members below
    /// their driver floors) leaves the whole fleet's caps untouched for
    /// that region while the others proceed.
    pub(crate) fn enforce_budget_regions(&mut self) -> Result<()> {
        let mut rt = self.region_rt.take().expect("region budget requires a region runtime");
        let result = self.enforce_budget_regions_inner(&mut rt);
        self.region_rt = Some(rt);
        result
    }

    fn enforce_budget_regions_inner(&mut self, rt: &mut RegionRt) -> Result<()> {
        let nregions = rt.members.len();
        // Per-site classification — byte-for-byte the flat algorithm
        // (`enforce_budget`), bucketed per region: down/quarantined/stale
        // sites reserve their current cap wattage, legal operating points
        // are filtered against the policy floor and any derate ceiling,
        // and a deep derate with no legal point holds its clamped watts.
        let mut profiles: Vec<Vec<HostProfile>> = vec![Vec::new(); nregions];
        let mut alloc_sites: Vec<Vec<usize>> = vec![Vec::new(); nregions];
        let mut reserved: Vec<f64> = vec![0.0; nregions];
        let mut waiting = 0usize; // stale-profile sites (stagger/churn)
        for r in 0..nregions {
            let mean_load = if rt.members[r].is_empty() {
                0.0
            } else {
                let sum: f64 = rt.members[r].iter().map(|&i| rt.site_load[i]).sum();
                sum / rt.members[r].len() as f64
            };
            for idx in 0..rt.members[r].len() {
                let i = rt.members[r][idx];
                let site = &self.sites[i];
                let down = site.down;
                let quarantined = self.is_quarantined(i);
                let derate_max = self.derate_ceiling(i);
                let fresh = matches!(
                    site.host.profile_log.last(),
                    Some(out) if out.model == site.model_id
                );
                if down || quarantined || !fresh {
                    if !down && !quarantined {
                        waiting += 1;
                    }
                    reserved[r] +=
                        site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                    continue;
                }
                let out = site.host.profile_log.last().expect("checked fresh");
                let min_frac = site.host.policy.min_cap_frac;
                let legal: Vec<_> = out
                    .points
                    .iter()
                    .filter(|p| {
                        p.cap_frac >= min_frac - 1e-9 && p.cap_frac <= derate_max + 1e-9
                    })
                    .cloned()
                    .collect();
                let pts = if legal.is_empty() {
                    if derate_max < 1.0 {
                        reserved[r] +=
                            site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                        continue;
                    }
                    out.points.clone()
                } else {
                    legal
                };
                let mut profile = HostProfile::from_profile(
                    &site.name,
                    site.host.testbed.hw.gpu.tdp_w,
                    &pts,
                );
                // Intra-region demand weight, floored like the flat path:
                // one zero-demand slot shrinks a site, never zeroes it.
                let weight = if mean_load > 0.0 {
                    (rt.site_load[i] / mean_load).max(MIN_BUDGET_WEIGHT)
                } else {
                    1.0
                };
                for p in profile.points.iter_mut() {
                    p.1 *= weight;
                }
                profiles[r].push(profile);
                alloc_sites[r].push(i);
            }
        }
        if profiles.iter().all(|p| p.is_empty()) {
            return Ok(()); // nothing profiled yet; retry next round
        }
        // The first allocation is always full-fleet, as on the flat path:
        // caps ratchet down between profiles, so a thin early remainder
        // would clamp the profiled sites far below their final share.
        if waiting > 0 && !self.ever_enforced {
            return Ok(());
        }
        let total_tdp: f64 = self.sites.iter().map(|s| s.host.testbed.hw.gpu.tdp_w).sum();
        let total_reserved: f64 = reserved.iter().sum();
        let budget_w = total_tdp * self.current_budget_frac();
        let remainder = budget_w - total_reserved;

        // Top-level split: regions with participants get
        // `spec.weight × load factor` shares of the remainder.  The load
        // factor comes from the SMO's gateway-aggregate ledger (keyed by
        // region name) against the mean over reporting regions, floored
        // like a site weight; regions that never reported stay at 1.0.
        let region_loads = self.smo.offered_load_by_host();
        let mut load_sum = 0.0;
        let mut load_n = 0usize;
        for r in 0..nregions {
            if let Some(&l) = region_loads.get(rt.map.regions[r].name.as_str()) {
                load_sum += l;
                load_n += 1;
            }
        }
        let mean_load = if load_n > 0 { load_sum / load_n as f64 } else { 0.0 };
        let mut weights = vec![0.0f64; nregions];
        let mut weight_sum = 0.0;
        for r in 0..nregions {
            if profiles[r].is_empty() {
                continue;
            }
            let factor = match region_loads.get(rt.map.regions[r].name.as_str()) {
                Some(&l) if mean_load > 0.0 => (l / mean_load).max(MIN_BUDGET_WEIGHT),
                _ => 1.0,
            };
            weights[r] = rt.map.regions[r].weight * factor;
            weight_sum += weights[r];
        }

        // Pass 1: solve every region's sub-fill.  A no-participant
        // region's reservation IS its sub-budget.
        let mut allocs: Vec<Option<Vec<Allocation>>> = Vec::with_capacity(nregions);
        let mut any_failed = false;
        let mut any_success = false;
        for r in 0..nregions {
            if profiles[r].is_empty() {
                rt.sub_budget_w[r] = Some(reserved[r]);
                allocs.push(None);
                continue;
            }
            let share = if weight_sum > 0.0 { weights[r] / weight_sum } else { 0.0 };
            let sub_remainder = remainder * share;
            match allocate_budget(&profiles[r], sub_remainder, 5.0) {
                Some(list) => {
                    rt.sub_budget_w[r] = Some(reserved[r] + sub_remainder);
                    any_success = true;
                    allocs.push(Some(list));
                }
                None => {
                    // This region's share cannot cover its members'
                    // driver floors: no pushes for it this round, and its
                    // sub-budget is unknown until a feasible fill lands.
                    rt.sub_budget_w[r] = None;
                    any_failed = true;
                    allocs.push(None);
                }
            }
        }
        if !any_success {
            if total_reserved > 0.0 {
                // Reservations hold the rest of the budget: wait for the
                // stagger or a recovery to free watts, as the flat path
                // does.
                return Ok(());
            }
            anyhow::bail!("fleet power budget below the driver floors");
        }

        // Pass 2: push.  Attribution consumes the round's pending trigger
        // once, shared by every regional push (§14).
        let (cause, trigger) = self
            .pending_cause
            .take()
            .unwrap_or((CapCause::WaterFill, self.trace.round_anchor()));
        for r in 0..nregions {
            let Some(list) = &allocs[r] else { continue };
            for (i, alloc) in alloc_sites[r].iter().zip(list) {
                let site = &mut self.sites[*i];
                let mut policy = site.host.policy.clone();
                policy.id = format!("{}-budget", site.name);
                policy.max_cap_frac = alloc.cap_frac.max(policy.min_cap_frac);
                let from = site.host.policy.max_cap_frac;
                if (from - policy.max_cap_frac).abs() > 1e-12 {
                    self.trace.record(
                        Some(*i as u32),
                        TraceData::CapChange { cause, from, to: policy.max_cap_frac, trigger },
                    );
                }
                // Enact the ceiling immediately on the coordinator, same
                // as the flat path: conservation is a per-round invariant.
                if site.host.testbed.cap_frac() > policy.max_cap_frac {
                    site.host.testbed.set_cap_frac(policy.max_cap_frac);
                }
                policy.validate().context("region water-fill policy")?;
                // Region-local push: the policy rides the site's own
                // fabric shard, never the global bus, while the SMO's
                // policy book records the same intent so lease renewals
                // re-assert it.  The push disturbs the site out of any
                // steady replay — the delivered policy must be applied.
                self.smo.record_policy(&site.name, policy.clone());
                site.local_bus.send("smo", &site.name, OranMessage::PolicyUpdate(policy));
                rt.dirty[*i] = true;
            }
        }
        self.ever_enforced = true;
        self.budget_applied = waiting == 0 && !any_failed;
        Ok(())
    }

    /// Checkpoint access to the region runtime (§15); None on flat
    /// fleets, whose snapshots carry no regions section.
    pub(crate) fn ckpt_region_state(&self) -> Option<&RegionRt> {
        self.region_rt.as_ref()
    }

    pub(crate) fn ckpt_region_state_mut(&mut self) -> Option<&mut RegionRt> {
        self.region_rt.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_partition_never_leaves_a_region_empty() {
        // 9 sites over 4 regions: base/extra distribution gives 3,2,2,2 —
        // a div_ceil chunking would have produced 3,3,3,0.
        let map = RegionMap::auto(9, 4).unwrap();
        assert_eq!(map.regions.len(), 4);
        let members = map.members();
        assert_eq!(members.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 2, 2, 2]);
        map.validate(9).unwrap();
        // Contiguous assignment, first region first.
        assert_eq!(map.site_region, vec![0, 0, 0, 1, 1, 2, 2, 3, 3]);
        // Degenerate shapes are hard errors, not clamps.
        assert!(RegionMap::auto(4, 0).is_err());
        assert!(RegionMap::auto(4, 5).is_err());
        // One region per site is legal.
        let map = RegionMap::auto(3, 3).unwrap();
        assert!(map.members().iter().all(|m| m.len() == 1));
    }

    #[test]
    fn region_map_validation_rejects_bad_shapes() {
        let ok = RegionMap::auto(6, 2).unwrap();
        ok.validate(6).unwrap();
        // Coverage mismatch.
        assert!(ok.validate(7).is_err());
        // Out-of-range assignment names the site and the region.
        let mut bad = ok.clone();
        bad.site_region[5] = 9;
        let err = bad.validate(6).unwrap_err().to_string();
        assert!(err.contains("site 5 mapped to undefined region 9"), "got: {err}");
        // Empty region (all sites crowd region 0).
        let mut bad = ok.clone();
        bad.site_region.fill(0);
        let err = bad.validate(6).unwrap_err().to_string();
        assert!(err.contains("owns no sites"), "got: {err}");
        // Duplicate names.
        let mut bad = ok.clone();
        bad.regions[1].name = bad.regions[0].name.clone();
        assert!(bad.validate(6).is_err());
        // Non-positive or non-finite weights.
        let mut bad = ok.clone();
        bad.regions[0].weight = 0.0;
        assert!(bad.validate(6).is_err());
        bad.regions[0].weight = f64::NAN;
        assert!(bad.validate(6).is_err());
    }

    #[test]
    fn steady_delta_promotion_is_bitwise() {
        let a = SteadyDelta {
            d_total_j: 1.25,
            d_profiling_j: 0.0,
            round_j: 1.25,
            d_wall_s: 0.5,
            d_samples: 128,
            last_gpu_power_w: 200.0,
        };
        let mut b = a;
        assert!(a.bits_eq(&b));
        b.d_total_j += 1e-12;
        assert!(!a.bits_eq(&b));
    }
}
