//! Fleet roll-up types and [`Fleet::report`]: the deterministic
//! KPM/energy/metrics summary every front-end (CLI tables, JSON export,
//! figures) consumes.  Region-tier fleets (§16) additionally roll up one
//! [`RegionReport`] per region.

use crate::frost::QosClass;
use crate::obs::MetricsRegistry;
use crate::oran::faults::FaultLedger;
use crate::oran::nonrt_ric::lock_recovering;

use super::Fleet;

/// Per-site slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct SiteReport {
    pub name: String,
    pub model: String,
    pub hw_name: String,
    pub qos: QosClass,
    pub cap_frac: f64,
    pub tdp_w: f64,
    pub accuracy: f64,
    pub workload_energy_j: f64,
    pub round_energy_j: f64,
    pub profiling_energy_j: f64,
    /// Energy integrated by this site's telemetry shard.
    pub hub_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    /// FROST's estimated energy saving for this site (0 if not profiled).
    pub est_saving: f64,
}

/// Per-region slice of a [`FleetReport`] (§16).  Present whenever the
/// fleet was configured with a [`RegionMap`] — including a single-region
/// map, whose one row is the whole-fleet roll-up.
///
/// [`RegionMap`]: super::RegionMap
#[derive(Debug, Clone)]
pub struct RegionReport {
    pub name: String,
    /// Sites assigned to the region.
    pub sites: usize,
    /// Members currently up (not in a scripted outage).
    pub up_sites: usize,
    pub workload_energy_j: f64,
    /// Final-round workload energy of the region's members.
    pub round_energy_j: f64,
    pub samples: u64,
    /// Σ cap_frac·TDP over the members — the region's enforced
    /// worst-case GPU power.
    pub cap_power_w: f64,
    /// The region's last allocated sub-budget in watts (None on flat
    /// stepping, before the first two-level fill, or while the region's
    /// sub-fill is infeasible).  Invariant: Σ over regions ≤ the in-force
    /// global budget.
    pub sub_budget_w: Option<f64>,
    /// The region's standing offered load (requests/s) from the gateway
    /// ledger (hierarchical) or the SMO's per-site ledger (single-region).
    pub offered_load_per_s: f64,
    /// Site-rounds served by steady replay instead of a worker trip.
    pub steady_site_rounds: u64,
}

/// Fleet KPM/energy roll-up.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub sites: Vec<SiteReport>,
    /// Per-region roll-up (§16); empty on region-free fleets.
    pub regions: Vec<RegionReport>,
    pub fleet_workload_energy_j: f64,
    /// Workload energy of the final round only — the steady-state number
    /// baseline comparisons should use (training rounds dominate totals).
    pub fleet_round_energy_j: f64,
    pub fleet_profiling_energy_j: f64,
    pub fleet_samples: u64,
    pub kpm_reports: usize,
    /// Per-host KPM aggregation from the SMO: (host, energy J, samples,
    /// latest reported GPU power W), sorted by host.
    pub kpm_by_host: Vec<(String, f64, u64, f64)>,
    /// Latest KPM-reported day p99 request latency per host, in host
    /// order (traffic-driven fleets; empty otherwise).  The SMO-side
    /// view of the serving tail — what a latency-aware rApp would act
    /// on (DESIGN.md §10).
    pub kpm_p99_by_host: Vec<(String, f64)>,
    pub mean_cap_frac: f64,
    /// Mean of FROST's per-site estimated savings (profiled sites only).
    pub mean_est_saving: f64,
    /// Global GPU budget in watts, when enforcement is on.
    pub budget_w: Option<f64>,
    /// True once the water-fill allocation has actually been pushed to
    /// every site (false while the profiling stagger is still pending).
    pub budget_enforced: bool,
    /// Σ cap_frac·TDP — the fleet's enforced worst-case GPU power.
    pub cap_power_w: f64,
    /// Fault-injection ledger of the global fabric (None = no plan
    /// installed; §13).
    pub fault_ledger: Option<FaultLedger>,
    /// KPM reports the SMO rejected as corrupt/stale/duplicate (§13).
    pub kpm_rejected: u64,
    /// A1 lease expiries across the fleet (hosts that fell back to their
    /// safe cap at least once; §13).
    pub lease_expiries: u64,
    /// Profile-path quarantine entries over the run (§13).
    pub quarantine_events: u64,
    /// Messages dropped from down sites' bounded hold-back queues (§13).
    pub holdback_dropped: u64,
    /// A1 lease renewals the SMO pushed over the run (§13).
    pub lease_renewals: u64,
    /// Named counters/gauges/summaries aggregated fleet-wide (§14):
    /// estimate-cache hits/misses/invalidations, monitor triggers, bus
    /// message counts per interface, lease/holdback ledgers, and the
    /// per-round cap-wattage summary.
    pub metrics: MetricsRegistry,
}

impl Fleet {
    /// Fleet KPM/energy roll-up (deterministic: site order everywhere).
    pub fn report(&self) -> FleetReport {
        // Metrics (§14): clone the live registry (lease renewals,
        // holdback drops, round cap-wattage summary), then fold in the
        // per-site counters in site-index order and the SMO/bus totals —
        // one name-ordered surface replacing the scattered counters.
        let mut metrics = self.metrics.clone();
        for site in &self.sites {
            let (hits, misses) = site.host.testbed.cache.stats();
            metrics.inc("cache.hits", hits);
            metrics.inc("cache.misses", misses);
            metrics.inc("cache.invalidations", site.host.testbed.cache.invalidations());
            metrics.inc("lease.expiries", site.host.lease_expiries);
            if let Some(t) = &site.traffic {
                let (reprofiles, load_shifts, rejected) = t.monitor_counters();
                metrics.inc("monitor.reprofiles", reprofiles);
                metrics.inc("monitor.load_shifts", load_shifts);
                metrics.inc("monitor.rejected", rejected);
            }
        }
        metrics.inc("kpm.rejected", self.smo.kpm_rejected_total());
        metrics
            .inc("quarantine.events", lock_recovering(&self.profile_health).quarantine_events);
        for (key, count) in self.bus.stats() {
            let name = match key {
                "A1" => "bus.A1",
                "O1" => "bus.O1",
                "O2" => "bus.O2",
                "dropped" => "bus.dropped",
                _ => continue,
            };
            metrics.inc(name, count);
        }
        // Deliberately no worker-count gauge: the report must stay
        // bit-identical for any `threads` setting (§6).
        metrics.set_gauge("fleet.sites", self.sites.len() as f64);
        if let Some(rm) = &self.config.regions {
            metrics.set_gauge("fleet.regions", rm.regions.len() as f64);
        }
        if let Some(rt) = &self.region_rt {
            metrics.inc("region.steady_rounds", rt.steady_rounds.iter().sum());
            metrics.inc("region.disturbances", rt.disturbances);
        }

        let mut sites = Vec::new();
        let mut workload_j = 0.0;
        let mut round_j = 0.0;
        let mut profiling_j = 0.0;
        let mut samples = 0u64;
        let mut cap_sum = 0.0;
        let mut cap_power_w = 0.0;
        let mut total_tdp = 0.0;
        let mut est_savings = Vec::new();
        for site in &self.sites {
            let cap = site.host.testbed.cap_frac();
            let tdp = site.host.testbed.hw.gpu.tdp_w;
            cap_sum += cap;
            cap_power_w += cap * tdp;
            total_tdp += tdp;
            let est_saving = self
                .smo
                .profile_records
                .iter()
                .rev()
                .find(|r| r.host == site.name)
                .map(|r| r.est_energy_saving)
                .unwrap_or(0.0);
            if site.host.profile_log.last().is_some() {
                est_savings.push(est_saving);
            }
            let (gpu_j, cpu_j, dram_j) = site.hub.true_energy();
            sites.push(SiteReport {
                name: site.name.clone(),
                model: site.model_id.clone(),
                hw_name: site.host.testbed.hw.name.clone(),
                qos: site.qos,
                cap_frac: cap,
                tdp_w: tdp,
                accuracy: site.accuracy,
                workload_energy_j: site.workload_energy_j,
                round_energy_j: site.round_energy_j,
                profiling_energy_j: site.profiling_energy_j,
                hub_energy_j: gpu_j + cpu_j + dram_j,
                wall_s: site.wall_s,
                samples: site.samples,
                est_saving,
            });
            workload_j += site.workload_energy_j;
            round_j += site.round_energy_j;
            profiling_j += site.profiling_energy_j;
            samples += site.samples;
        }

        // Region roll-up (§16): one row per configured region, member
        // sums in region-then-site index order.  On the flat stepping
        // path (single-region map) the offered load comes from the SMO's
        // per-site ledger and there is no sub-budget.
        let mut regions = Vec::new();
        if let Some(rm) = &self.config.regions {
            let members = rm.members();
            for (r, spec) in rm.regions.iter().enumerate() {
                let mut workload_energy_j = 0.0;
                let mut region_round_j = 0.0;
                let mut region_samples = 0u64;
                let mut region_cap_w = 0.0;
                let mut up_sites = 0usize;
                let mut offered = 0.0;
                for &i in &members[r] {
                    let site = &self.sites[i];
                    workload_energy_j += site.workload_energy_j;
                    region_round_j += site.round_energy_j;
                    region_samples += site.samples;
                    region_cap_w +=
                        site.host.testbed.cap_frac() * site.host.testbed.hw.gpu.tdp_w;
                    if !site.down {
                        up_sites += 1;
                    }
                    offered += match &self.region_rt {
                        Some(rt) => rt.site_load[i],
                        None => self
                            .smo
                            .offered_load_by_host()
                            .get(&site.name)
                            .copied()
                            .unwrap_or(0.0),
                    };
                }
                let (sub_budget_w, steady_site_rounds) = match &self.region_rt {
                    Some(rt) => (rt.sub_budget_w[r], rt.steady_rounds[r]),
                    None => (None, 0),
                };
                regions.push(RegionReport {
                    name: spec.name.clone(),
                    sites: members[r].len(),
                    up_sites,
                    workload_energy_j,
                    round_energy_j: region_round_j,
                    samples: region_samples,
                    cap_power_w: region_cap_w,
                    sub_budget_w,
                    offered_load_per_s: offered,
                    steady_site_rounds,
                });
            }
        }

        let n = self.sites.len().max(1) as f64;
        FleetReport {
            sites,
            regions,
            fleet_workload_energy_j: workload_j,
            fleet_round_energy_j: round_j,
            fleet_profiling_energy_j: profiling_j,
            fleet_samples: samples,
            kpm_reports: self.smo.kpms.len(),
            kpm_by_host: self.smo.kpm_rollup(),
            kpm_p99_by_host: self
                .smo
                .latency_p99_by_host()
                .iter()
                .map(|(h, p)| (h.clone(), *p))
                .collect(),
            mean_cap_frac: cap_sum / n,
            mean_est_saving: if est_savings.is_empty() {
                0.0
            } else {
                est_savings.iter().sum::<f64>() / est_savings.len() as f64
            },
            budget_w: if self.current_budget_frac() < 1.0 {
                Some(total_tdp * self.current_budget_frac())
            } else {
                None
            },
            budget_enforced: self.budget_applied,
            cap_power_w,
            fault_ledger: self.bus.fault_ledger(),
            kpm_rejected: self.smo.kpm_rejected_total(),
            lease_expiries: metrics.counter("lease.expiries"),
            quarantine_events: metrics.counter("quarantine.events"),
            holdback_dropped: metrics.counter("holdback.dropped"),
            lease_renewals: metrics.counter("lease.renewals"),
            metrics,
        }
    }
}
