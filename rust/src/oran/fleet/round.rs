//! The per-site round: traffic state ([`SiteTraffic`]), the site itself
//! ([`FleetSite`]) and the persistent worker pool ([`SitePool`]) that
//! steps sites in parallel.  Everything here runs on (or feeds) the
//! worker threads; all cross-site traffic is deferred to each site's
//! outbox, which the coordinator's gateway merges in site-index order —
//! the §6 determinism contract.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;

use anyhow::{Context, Result};

use crate::frost::{ContinuousMonitor, MonitorAction, MonitorConfig, Observation, QosClass};
use crate::metrics::LatencyHistogram;
use crate::oran::bus::{Bus, Endpoint};
use crate::oran::host::InferenceHost;
use crate::oran::messages::OranMessage;
use crate::simulator::WorkloadDescriptor;
use crate::telemetry::hub::{PowerReading, TelemetryHub};
use crate::telemetry::sampler::PowerSampler;
use crate::traffic::{
    ArrivalBuffers, ArrivalGen, BatchFormer, SlotLatencies, SlotReport, SlotWindow,
    TrafficConfig, TrafficServer,
};
use crate::util::Seconds;

use super::FleetConfig;

/// Per-site traffic state: the seeded arrival stream, the persistent
/// serving queue, the SLO ledger and the demand monitor.  Lives entirely
/// on the site (stepped on the worker thread), so the §6 determinism
/// contract holds untouched.
pub struct SiteTraffic {
    pub(crate) gen: ArrivalGen,
    pub server: TrafficServer,
    former: BatchFormer,
    monitor: ContinuousMonitor,
    /// This site's QoS deadline (seconds of traffic time).
    pub deadline_s: f64,
    /// True when this site serves via the aggregated count path
    /// (DESIGN.md §10): decided once per scenario from the expected
    /// requests per slot vs `TrafficConfig::exact_request_threshold`
    /// (or forced by `TrafficConfig::path`), never mid-day.
    pub aggregated: bool,
    /// Arrival-count resolution of the aggregated path (sub-windows per
    /// slot, sized to a small fraction of this site's deadline).
    agg_windows: u32,
    /// Reusable per-slot arrival buffers (exact times / aggregated
    /// windows): steady-state slots allocate nothing, and generation +
    /// enqueueing share one definition with the traffic bench
    /// (`traffic::ArrivalBuffers`).
    bufs: ArrivalBuffers,
    /// Per-request latencies of the current day (cleared at day rollover
    /// so multi-day runs stay bounded in memory).  **Exact path only** —
    /// the aggregated path accounts latencies solely in [`Self::hist`],
    /// which is what makes a 10⁶-users/site day O(1) in memory.
    pub latencies: Vec<f64>,
    /// O(1) log-bin latency histogram of the current day (both paths;
    /// cleared at day rollover).  Fleet roll-ups merge these in
    /// site-index order (§6).
    pub hist: LatencyHistogram,
    /// Per-scenario-phase latency histograms (DESIGN.md §11): one per
    /// `Scenario::phases` entry, fed by the same recording pass as
    /// [`Self::hist`]; empty when the fleet runs no scenario.  Cleared at
    /// day rollover with the rest of the day ledgers.
    pub phase_hists: Vec<LatencyHistogram>,
    /// Requests shed when this site went down (queue failed at the outage
    /// event); charged as `dropped` to the first outage slot's report so
    /// slot-level accounting still conserves.
    pub(crate) pending_shed: u64,
    /// Per-slot records of the current day.
    pub slot_log: Vec<SlotReport>,
    /// Total slots served over the site's lifetime (day index derives
    /// from it).
    pub slots_served: u32,
    /// Current-day aggregates.
    pub offered_today: u64,
    pub day_energy_j: f64,
    /// Re-profiles the monitor has requested (signature drift OR demand
    /// shift; see [`Self::load_shift_reprofiles`] for the demand subset).
    pub reprofile_requests: u64,
    /// Set on the worker thread when the monitor fires; the coordinator
    /// consumes it by clearing the catalogue cap, so the re-profile goes
    /// through the scheduler's stagger instead of stampeding the fleet.
    pub(crate) reprofile_pending: bool,
}

impl SiteTraffic {
    /// How many of the requested re-profiles carried an offered-load
    /// shift past the monitor's threshold (demand-driven, as opposed to
    /// pure signature drift).
    pub fn load_shift_reprofiles(&self) -> u64 {
        self.monitor.load_shifts
    }

    /// The demand monitor's counter triple `(reprofiles, load_shifts,
    /// rejected)` — read whole by the fleet metrics registry (§14).
    pub fn monitor_counters(&self) -> (u64, u64, u64) {
        self.monitor.counters()
    }

    /// Checkpoint access to the arrival generator (§15).  Together with
    /// the monitor and the shed ledger these are the only private fields
    /// with live state at a round boundary: `reprofile_pending` is
    /// consumed by the coordinator every round, and the batch former /
    /// arrival buffers carry no state between slots, so all of those
    /// rebuild from config.
    pub fn ckpt_gen(&self) -> &ArrivalGen {
        &self.gen
    }

    pub fn ckpt_gen_mut(&mut self) -> &mut ArrivalGen {
        &mut self.gen
    }

    /// Checkpoint access to the demand monitor (§15).
    pub fn ckpt_monitor(&self) -> &ContinuousMonitor {
        &self.monitor
    }

    pub fn ckpt_monitor_mut(&mut self) -> &mut ContinuousMonitor {
        &mut self.monitor
    }

    /// Requests shed during an outage but not yet charged to a slot
    /// ledger — live across round boundaries while a site is dark (§15).
    pub fn ckpt_pending_shed(&self) -> u64 {
        self.pending_shed
    }

    pub fn restore_ckpt_pending_shed(&mut self, shed: u64) {
        self.pending_shed = shed;
    }

    /// Roll the day ledgers over when this slot starts a new day and
    /// return `(slot_in_day, t0)` — shared by the serving path and the
    /// outage idle path, so a down slot keeps the day clock honest.
    fn begin_slot(&mut self, tr: &TrafficConfig) -> (u32, f64) {
        let slot_in_day = self.slots_served % tr.slots_per_day;
        if slot_in_day == 0 && self.slots_served > 0 {
            // Day rollover: the previous day flushed its queue at the
            // last slot; reset the per-day ledgers so multi-day runs
            // stay bounded in memory.
            self.latencies.clear();
            self.hist.clear();
            for h in self.phase_hists.iter_mut() {
                h.clear();
            }
            self.slot_log.clear();
            self.offered_today = 0;
            self.day_energy_j = 0.0;
        }
        (slot_in_day, self.slots_served as f64 * tr.slot_s())
    }

    pub(crate) fn new(
        cfg: &TrafficConfig,
        site_index: usize,
        qos: QosClass,
        seed: u64,
        phases: usize,
    ) -> SiteTraffic {
        let deadline_s = cfg.slo.deadline_for(qos);
        SiteTraffic {
            gen: ArrivalGen::new(
                cfg.kind,
                cfg.diurnal.clone(),
                cfg.site_base_rate(site_index),
                cfg.day_s,
                seed,
            )
            .expect("validated traffic config"),
            server: TrafficServer::new(),
            former: BatchFormer::new(cfg.max_batch, deadline_s),
            aggregated: cfg.aggregate_for_site(site_index),
            agg_windows: cfg.agg_windows(deadline_s),
            bufs: ArrivalBuffers::new(),
            hist: LatencyHistogram::new(),
            phase_hists: (0..phases).map(|_| LatencyHistogram::new()).collect(),
            pending_shed: 0,
            // Slot-cadence monitoring: settle after a few slots, then
            // re-profile on demand shifts with a cooldown of roughly a
            // sixth of a day so one diurnal ramp triggers once.
            monitor: ContinuousMonitor::new(MonitorConfig {
                alpha: 0.4,
                drift_threshold: 0.25,
                warmup: 3,
                cooldown: Seconds(cfg.day_s / 6.0),
                load_shift_threshold: 0.5,
            }),
            deadline_s,
            latencies: Vec::new(),
            slot_log: Vec::new(),
            slots_served: 0,
            offered_today: 0,
            day_energy_j: 0.0,
            reprofile_requests: 0,
            reprofile_pending: false,
        }
    }
}

/// One ML-enabled site: host + private fabric shard + telemetry shard.
pub struct FleetSite {
    pub index: usize,
    pub name: String,
    /// This site's endpoint on the *global* fabric (downward gateway
    /// target; resolved once at construction).
    pub(crate) global_ep: Arc<Endpoint>,
    /// The site-local fabric: everything the host sends during the
    /// parallel phase stays here until the gateway merges it upward.
    pub(crate) local_bus: Arc<Bus>,
    pub(crate) local_smo: Arc<Endpoint>,
    pub host: InferenceHost,
    /// Per-host telemetry shard (the fleet's sharded `TelemetryHub`).
    pub hub: Arc<TelemetryHub>,
    /// Periodic power sampling against this site's shard, with a bounded
    /// retention ring (`FleetConfig::sample_retention`).
    pub sampler: PowerSampler,
    pub(crate) zoo_index: usize,
    pub zoo_model: &'static str,
    /// Catalogue-unique deployment id, e.g. `ResNet@site03`.
    pub model_id: String,
    pub workload: WorkloadDescriptor,
    pub qos: QosClass,
    pub trained: bool,
    /// Cumulative epochs the current model has been trained for. Grows on
    /// each retraining pass (validation failures escalate the budget), so
    /// the accuracy ramp converges past any threshold below the model's
    /// reference accuracy.
    pub epochs_trained: u32,
    /// Messages bound for the SMO once the gateway merges outboxes upward
    /// (in site-index order). Moved, never cloned.
    pub(crate) outbox: Vec<OranMessage>,
    /// Workload (training + inference) energy, profiling excluded.
    pub workload_energy_j: f64,
    /// Workload energy of the most recent round only (steady-state metric).
    pub round_energy_j: f64,
    /// Energy charged to FROST profiling sweeps (Eqs. 4–5).
    pub profiling_energy_j: f64,
    pub wall_s: f64,
    pub samples: u64,
    pub accuracy: f64,
    pub last_gpu_power_w: f64,
    /// Rounds this site has run (drives the warm-up → traffic handover).
    pub(crate) rounds_run: u32,
    /// Scripted outage (DESIGN.md §11): set by the coordinator at event
    /// dispatch.  A down site serves nothing, processes no fabric
    /// traffic, and draws idle power for the slot.
    pub down: bool,
    /// Traffic state when the scenario is traffic-driven.
    pub traffic: Option<SiteTraffic>,
}

impl FleetSite {
    /// Checkpoint access to the site-local fabric shard (§15), so the
    /// snapshot layer can serialise its queue/inboxes/stats by endpoint
    /// name.
    pub fn ckpt_local_bus(&self) -> &Arc<Bus> {
        &self.local_bus
    }

    /// Private per-site scalars a checkpoint must carry (§15): the zoo
    /// cursor (churn state) and the round counter (drives the warm-up →
    /// traffic handover).  The outbox is always empty at a round
    /// boundary — the upward gateway drains it every round — so it is
    /// deliberately not part of the snapshot.
    pub fn ckpt_site_state(&self) -> (usize, u32) {
        (self.zoo_index, self.rounds_run)
    }

    pub fn restore_ckpt_site_state(&mut self, zoo_index: usize, rounds_run: u32) {
        self.zoo_index = zoo_index;
        self.rounds_run = rounds_run;
    }

    /// One site round, run on a worker thread. Touches only site-local
    /// state; cross-site traffic is deferred to `outbox`.
    fn run_round(&mut self, cfg: &FleetConfig) {
        if self.down {
            self.run_down_round(cfg);
            return;
        }
        self.rounds_run += 1;
        // Apply coordinator-injected traffic (A1 policies, profile
        // requests). Profiling runs here, on the worker thread.
        self.local_bus.deliver_all();
        let before = self.host.total_energy_j;
        self.host.step();
        self.profiling_energy_j += self.host.total_energy_j - before;
        // The A1 lease clock ticks after this round's policies applied:
        // a renewal that landed above re-armed it; a missed one brings
        // the host a round closer to its safe-cap fallback (§13).
        self.host.tick_lease();

        // Workload phase under the (possibly just-updated) cap. The
        // estimate is memoized: in steady state this is a cache hit, not a
        // fixed-point solve.
        let est = if self.trained {
            self.host.testbed.infer_estimate(&self.workload, self.host.batch)
        } else {
            self.host.testbed.train_estimate(&self.workload, self.host.batch)
        };
        let t0 = self.host.testbed.clock.now();
        let (gpu, cpu, dram) = self.host.testbed.instantaneous(Some(&est));
        self.hub.publish(PowerReading {
            at: t0,
            gpu,
            cpu,
            dram,
            gpu_util: est.gpu_util,
            freq_mhz: est.op.freq_mhz,
        });
        self.sampler.poll(t0);
        self.last_gpu_power_w = gpu.0;

        let before = self.host.total_energy_j;
        let traffic_now = self.trained
            && self.traffic.is_some()
            && cfg.traffic.as_ref().map_or(false, |t| self.rounds_run > t.warmup_rounds);
        if traffic_now {
            let tr = cfg.traffic.as_ref().expect("checked above");
            self.serve_traffic_slot(cfg, tr, cfg.frost_enabled);
        } else if self.trained {
            let _ = self.host.run_inference(&self.model_id, cfg.infer_steps_per_round);
            self.samples += cfg.infer_steps_per_round * self.host.batch as u64;
        } else {
            // Retraining after a validation failure escalates the epoch
            // budget (fresh run with more epochs), so accuracy ramps past
            // the threshold instead of repeating the same failing run.
            let epochs = self.epochs_trained.saturating_add(cfg.train_epochs);
            let (acc, _wall, _energy) = self
                .host
                .run_training(&self.model_id, epochs, cfg.samples_per_epoch)
                .expect("deployed model trains");
            self.accuracy = acc;
            self.trained = true;
            self.epochs_trained = epochs;
            self.samples += epochs as u64 * cfg.samples_per_epoch;
        }
        self.round_energy_j = self.host.total_energy_j - before;
        self.workload_energy_j += self.round_energy_j;

        let t1 = self.host.testbed.clock.now();
        let (gi, ci, di) = self.host.testbed.instantaneous(None);
        self.hub.publish(PowerReading {
            at: t1,
            gpu: gi,
            cpu: ci,
            dram: di,
            gpu_util: 0.0,
            freq_mhz: 0.0,
        });
        self.sampler.poll(t1);
        self.wall_s = t1.0;

        // Everything the host reported on the local fabric goes upward
        // once the coordinator merges outboxes (in site order). Messages
        // move; nothing is re-serialised or cloned on the hop.
        self.local_bus.deliver_all();
        for (_from, msg) in self.local_smo.drain() {
            self.outbox.push(msg);
        }
    }

    /// A scripted-outage round (DESIGN.md §11): the site is dark.  It
    /// processes no fabric messages (pending policies and profile
    /// requests wait in the queues for recovery), serves nothing, and
    /// draws idle power for one traffic slot — the slot counter keeps
    /// advancing so the diurnal clock is intact when it comes back, and
    /// the slot ledger records a zero-offered, idle-energy slot (plus any
    /// requests the outage shed from the queue, as drops).
    fn run_down_round(&mut self, cfg: &FleetConfig) {
        self.rounds_run += 1;
        let tr = cfg.traffic.as_ref().expect("scenario outages require traffic");
        let slot_s = tr.slot_s();
        let t0c = self.host.testbed.clock.now();
        let (gi, ci, di) = self.host.testbed.instantaneous(None);
        self.hub.publish(PowerReading {
            at: t0c,
            gpu: gi,
            cpu: ci,
            dram: di,
            gpu_util: 0.0,
            freq_mhz: 0.0,
        });
        self.sampler.poll(t0c);
        self.last_gpu_power_w = gi.0;

        let agg = self.host.testbed.idle_window(Seconds(slot_s));
        self.host.total_energy_j += agg.energy.0;
        self.round_energy_j = agg.energy.0;
        self.workload_energy_j += agg.energy.0;

        let t1 = self.host.testbed.clock.now();
        self.sampler.poll(t1);
        self.wall_s = t1.0;

        let cap_frac = self.host.testbed.cap_frac();
        let serving = self.trained && self.rounds_run > tr.warmup_rounds;
        if let Some(t) = self.traffic.as_mut() {
            if serving {
                let (slot_in_day, t0) = t.begin_slot(tr);
                let dropped = std::mem::take(&mut t.pending_shed);
                t.slot_log.push(SlotReport {
                    slot_in_day,
                    t0,
                    offered: 0,
                    served: 0,
                    dropped,
                    late: 0,
                    batches: 0,
                    batch_samples: 0,
                    busy_s: 0.0,
                    energy_j: agg.energy.0,
                    gpu_busy_power_w: 0.0,
                    offered_rate_per_s: 0.0,
                    cap_frac,
                });
                t.slots_served += 1;
                t.day_energy_j += agg.energy.0;
            }
        }
    }

    /// Serve the site's next traffic slot (DESIGN.md §9/§10): generate
    /// the slot's seeded arrivals — individually below the aggregation
    /// threshold, as per-window counts above it, both into reusable
    /// buffers — push them through the host's batch former under the
    /// current cap, and feed the demand monitor, which may ask FROST to
    /// re-profile (routed through the scheduler stagger via the
    /// coordinator — see `reprofile_pending`).
    fn serve_traffic_slot(&mut self, cfg: &FleetConfig, tr: &TrafficConfig, frost_enabled: bool) {
        let slot_s = tr.slot_s();
        let t = self.traffic.as_mut().expect("traffic state initialised");
        let (slot_in_day, t0) = t.begin_slot(tr);
        let deadline_s = t.deadline_s;
        let offered = t.bufs.generate_and_enqueue(
            &mut t.gen,
            &mut t.server,
            t.aggregated,
            t.agg_windows,
            t0,
            slot_s,
            deadline_s,
        );
        let window = SlotWindow {
            t0,
            dur: slot_s,
            slot_in_day,
            flush: slot_in_day + 1 == tr.slots_per_day,
        };
        // Scenario-driven fleets route this slot's samples into its phase
        // histogram as well (same recording pass; DESIGN.md §11).
        let phase_idx = cfg.scenario.as_ref().map(|s| s.phase_of_slot(slot_in_day));
        let mut lat = SlotLatencies {
            exact: if t.aggregated { None } else { Some(&mut t.latencies) },
            hist: &mut t.hist,
            phase: match phase_idx {
                Some(p) => t.phase_hists.get_mut(p),
                None => None,
            },
        };
        let mut report = self
            .host
            .serve_slot(&self.model_id, &mut t.server, &t.former, offered, window, &mut lat)
            .expect("deployed model serves traffic");
        // Shed drops that were never ledgered while the site was dark
        // (e.g. it was retraining through the outage, so no down-slot
        // report was pushed) land on the first served slot instead — the
        // slot ledger must account every drop the server counted.
        report.dropped += std::mem::take(&mut t.pending_shed);
        t.slots_served += 1;
        t.offered_today += report.offered;
        t.day_energy_j += report.energy_j;
        self.samples += report.served;
        // Close the loop: the monitor watches the busy-power /
        // service-throughput signature plus the offered load.
        let service_tput =
            if report.busy_s > 0.0 { report.batch_samples as f64 / report.busy_s } else { 0.0 };
        let action = t.monitor.observe(Observation {
            at: Seconds(t0 + slot_s),
            gpu_power_w: report.gpu_busy_power_w,
            samples_per_s: service_tput,
            offered_load_per_s: report.offered_rate_per_s,
        });
        if frost_enabled && action == MonitorAction::Reprofile {
            t.reprofile_requests += 1;
            // Don't self-issue a ProfileRequest: a diurnal ramp shifts
            // every site in the same round, and direct requests would
            // stampede N concurrent profiles.  The coordinator clears the
            // catalogue cap instead, and the FleetProfileScheduler
            // re-requests it under max_concurrent_profiles.
            t.reprofile_pending = true;
        }
        t.slot_log.push(report);
    }
}

/// Sites in flight between the coordinator and a worker: the original
/// site index rides along so the merge is in site-index order.
type SiteBatch = Vec<(usize, FleetSite)>;

/// Persistent channel-fed worker pool for the parallel site phase.
///
/// Spawned once in [`super::Fleet::new`]; every round the coordinator
/// partitions the sites into contiguous index chunks (the same
/// deterministic partition the old per-round `thread::scope` used), moves
/// each chunk to a worker, and reassembles the returned sites by index.
/// Worker panics are caught and re-raised on the coordinator thread.
pub(crate) struct SitePool {
    injectors: Vec<Sender<SiteBatch>>,
    results: Receiver<thread::Result<SiteBatch>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl SitePool {
    pub(crate) fn spawn(workers: usize, cfg: Arc<FleetConfig>) -> SitePool {
        let workers = workers.max(1);
        let (results_tx, results) = channel::<thread::Result<SiteBatch>>();
        let mut injectors = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<SiteBatch>();
            let results_tx = results_tx.clone();
            let cfg = cfg.clone();
            handles.push(thread::spawn(move || {
                while let Ok(mut batch) = rx.recv() {
                    let ran = catch_unwind(AssertUnwindSafe(|| {
                        for (_, site) in batch.iter_mut() {
                            site.run_round(&cfg);
                        }
                        batch
                    }));
                    if results_tx.send(ran).is_err() {
                        break; // coordinator gone
                    }
                }
            }));
            injectors.push(tx);
        }
        SitePool { injectors, results, handles }
    }

    pub(crate) fn workers(&self) -> usize {
        self.injectors.len()
    }

    /// Run one parallel site phase over `sites`, in place.
    ///
    /// A dead worker (its channel hung up without a panic payload —
    /// satellite of §13) surfaces as a proper `Err` instead of a
    /// coordinator panic, so the caller can report the fleet as failed.
    /// A *panicking* site is a site bug and is still re-raised verbatim.
    pub(crate) fn run_phase(&self, sites: &mut Vec<FleetSite>) -> Result<()> {
        let n = sites.len();
        if n == 0 {
            return Ok(());
        }
        let chunk = n.div_ceil(self.workers());
        let mut slots: Vec<Option<FleetSite>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);

        let mut batches = 0usize;
        let mut batch: SiteBatch = Vec::with_capacity(chunk);
        for (i, site) in std::mem::take(sites).into_iter().enumerate() {
            batch.push((i, site));
            if batch.len() == chunk {
                self.injectors[batches]
                    .send(std::mem::replace(&mut batch, Vec::with_capacity(chunk)))
                    .map_err(|_| {
                        anyhow::anyhow!("site worker {batches} died: injector hung up")
                    })?;
                batches += 1;
            }
        }
        if !batch.is_empty() {
            self.injectors[batches]
                .send(batch)
                .map_err(|_| anyhow::anyhow!("site worker {batches} died: injector hung up"))?;
            batches += 1;
        }

        self.collect(sites, slots, batches, n)
    }

    /// Run one parallel phase over only the listed site indices, in
    /// place — the region tier's *active set* (sites replaying a steady
    /// delta never travel to a worker at all).  The chunking is over the
    /// active list, but merge order, panic handling and the dead-worker
    /// error surface are identical to [`Self::run_phase`]; with every
    /// index listed the partition matches `run_phase` exactly, which is
    /// what keeps a single-region fleet bit-identical to a flat one.
    pub(crate) fn run_phase_indices(
        &self,
        sites: &mut Vec<FleetSite>,
        indices: &[usize],
    ) -> Result<()> {
        if indices.is_empty() {
            return Ok(()); // fully steady fleet: nothing travels
        }
        let n = sites.len();
        let chunk = indices.len().div_ceil(self.workers());
        let mut slots: Vec<Option<FleetSite>> =
            std::mem::take(sites).into_iter().map(Some).collect();

        let mut batches = 0usize;
        let mut batch: SiteBatch = Vec::with_capacity(chunk);
        for &i in indices {
            let site = slots[i].take().expect("active index listed once");
            batch.push((i, site));
            if batch.len() == chunk {
                self.injectors[batches]
                    .send(std::mem::replace(&mut batch, Vec::with_capacity(chunk)))
                    .map_err(|_| {
                        anyhow::anyhow!("site worker {batches} died: injector hung up")
                    })?;
                batches += 1;
            }
        }
        if !batch.is_empty() {
            self.injectors[batches]
                .send(batch)
                .map_err(|_| anyhow::anyhow!("site worker {batches} died: injector hung up"))?;
            batches += 1;
        }

        self.collect(sites, slots, batches, n)
    }

    /// Receive `batches` results, merge them back into `slots` by index,
    /// re-raise the first worker panic, and rebuild `sites` in index
    /// order — shared tail of both phase runners.
    fn collect(
        &self,
        sites: &mut Vec<FleetSite>,
        mut slots: Vec<Option<FleetSite>>,
        batches: usize,
        n: usize,
    ) -> Result<()> {
        let mut panicked: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..batches {
            match self.results.recv() {
                Err(_) => anyhow::bail!("site worker pool died mid-phase: results hung up"),
                Ok(Ok(done)) => {
                    for (i, site) in done {
                        slots[i] = Some(site);
                    }
                }
                // Keep draining the remaining batches so the pool is not
                // left with stale results, then re-raise.
                Ok(Err(payload)) => {
                    panicked.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = panicked {
            resume_unwind(payload);
        }
        let mut rebuilt = Vec::with_capacity(n);
        for slot in slots {
            rebuilt.push(slot.context("site lost by the worker pool")?);
        }
        *sites = rebuilt;
        Ok(())
    }

    /// Test hook: replace a worker's injector with a dead channel so the
    /// next phase observes a hung-up worker.
    #[cfg(test)]
    pub(crate) fn kill_worker_for_test(&mut self) {
        let (tx, _) = channel::<SiteBatch>();
        self.injectors[0] = tx;
    }
}

impl Drop for SitePool {
    fn drop(&mut self) {
        // Closing the injector channels ends every worker's recv loop.
        self.injectors.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}
